"""IPCA weight update (Algo 2) and remapped storage (Algo 3) invariants."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.dobi.ipca import (IncrementalPCA, batch_right_basis,
                               full_pca_components, ipca_memory_bytes,
                               ipca_weight_update, pca_memory_bytes,
                               subspace_distance, update_weight)
from compile.dobi.remap import (RemappedFactors, dequantize_absmax, factorize,
                                ptq_bytes, quant_error, quantize_absmax,
                                reconstruct, remap_store)
from compile.dobi.truncation import (classic_k_for_ratio, classic_ratio,
                                     remap_k_for_ratio, remap_ratio,
                                     round_ranks)


# ---------------------------------------------------------------------------
# IPCA
# ---------------------------------------------------------------------------

def _batches(rng, n_batches, rows, n, rank):
    """Activation batches sharing a common low-dim right subspace + noise."""
    basis = np.linalg.qr(rng.standard_normal((n, rank)))[0]
    out = []
    for _ in range(n_batches):
        coef = rng.standard_normal((rows, rank))
        out.append(coef @ basis.T + 0.01 * rng.standard_normal((rows, n)))
    return out


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**16), n=st.integers(12, 64), k=st.integers(2, 8))
def test_ipca_agrees_with_full_pca(seed, n, k):
    rng = np.random.default_rng(seed)
    batches = _batches(rng, 6, 40, n, k)
    bases, weights = [], []
    tr = IncrementalPCA(n, k)
    for a in batches:
        v, s = batch_right_basis(a, k)
        bases.append(v)
        weights.append(s)
        tr.partial_fit(v, s)
    v_full = full_pca_components(bases, weights, k)
    assert subspace_distance(tr.components(), v_full) < 0.15


def test_ipca_recovers_planted_subspace():
    rng = np.random.default_rng(0)
    n, k = 32, 4
    basis = np.linalg.qr(rng.standard_normal((n, k)))[0]
    batches = []
    for _ in range(8):
        coef = rng.standard_normal((50, k))
        batches.append(coef @ basis.T + 1e-4 * rng.standard_normal((50, n)))
    tr = IncrementalPCA(n, k)
    for a in batches:
        v, s = batch_right_basis(a, k)
        tr.partial_fit(v, s)
    assert subspace_distance(tr.components(), basis) < 0.05


def test_ipca_components_orthonormal():
    rng = np.random.default_rng(1)
    tr = IncrementalPCA(24, 6)
    for a in _batches(rng, 5, 30, 24, 6):
        v, s = batch_right_basis(a, 6)
        tr.partial_fit(v, s)
    v = tr.components()
    np.testing.assert_allclose(v.T @ v, np.eye(6), atol=1e-8)


def test_update_weight_rank_and_optimality():
    """W~ = W V V^T has rank <= k and is the projection minimizing
    ||W P_i - W~|| over the common subspace (EYM argument of A.4.1)."""
    rng = np.random.default_rng(2)
    w = rng.standard_normal((16, 24))
    v = np.linalg.qr(rng.standard_normal((24, 5)))[0]
    w_new = update_weight(w, v)
    assert np.linalg.matrix_rank(w_new) <= 5
    # projecting twice changes nothing (idempotence of the update)
    np.testing.assert_allclose(update_weight(w_new, v), w_new, atol=1e-10)


def test_ipca_weight_update_end_to_end():
    rng = np.random.default_rng(3)
    w = rng.standard_normal((20, 28)).astype(np.float32)
    acts = [rng.standard_normal((40, 20)) @ w for _ in range(4)]
    w_new = ipca_weight_update(w, acts, k=6)
    assert w_new.shape == w.shape
    assert np.linalg.matrix_rank(w_new.astype(np.float64), tol=1e-5) <= 6


def test_memory_model_shapes():
    """IPCA memory flat in batch count; PCA linear (Fig 3c)."""
    assert pca_memory_bytes(1024, 256, 32) >= 16 * pca_memory_bytes(1024, 256, 2)
    assert ipca_memory_bytes(1024, 256) == ipca_memory_bytes(1024, 256)
    assert ipca_memory_bytes(4096, 1024) < pca_memory_bytes(4096, 1024, 8)


def test_ipca_measured_peak_constant_in_batches():
    rng = np.random.default_rng(4)
    peaks = []
    for nb in (3, 9):
        tr = IncrementalPCA(48, 8)
        for a in _batches(rng, nb, 30, 48, 8):
            v, s = batch_right_basis(a, 8)
            tr.partial_fit(v, s)
        peaks.append(tr.peak_bytes)
    assert peaks[0] == peaks[1]


# ---------------------------------------------------------------------------
# quantizer
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(m=st.integers(2, 60), n=st.integers(2, 60), bits=st.sampled_from([4, 8]),
       seed=st.integers(0, 2**16))
def test_quant_roundtrip_error_bounded(m, n, bits, seed):
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((m, n)).astype(np.float32)
    q, s = quantize_absmax(w, bits=bits)
    wd = dequantize_absmax(q, s)
    qmax = (1 << (bits - 1)) - 1
    # absmax quantization error is at most scale/2 per element
    bound = np.max(np.abs(w), axis=0) / qmax / 2 + 1e-7
    assert np.all(np.abs(w - wd) <= bound[None, :] + 1e-6)


def test_quant_preserves_zero_and_extremes():
    w = np.array([[0.0, 1.0], [-1.0, 0.5]], np.float32)
    q, s = quantize_absmax(w)
    wd = dequantize_absmax(q, s)
    assert wd[0, 0] == 0.0
    np.testing.assert_allclose(wd[1, 0], -1.0, rtol=1e-2)


def test_quant_error_decreases_with_bits():
    rng = np.random.default_rng(5)
    w = rng.standard_normal((64, 64)).astype(np.float32)
    mse4, _ = quant_error(w, bits=4)
    mse8, _ = quant_error(w, bits=8)
    assert mse8 < mse4 / 10


# ---------------------------------------------------------------------------
# remapping (Algo 3)
# ---------------------------------------------------------------------------

def test_factorize_exact_at_full_rank():
    rng = np.random.default_rng(6)
    w = rng.standard_normal((24, 16)).astype(np.float32)
    a, b = factorize(w, 16)
    np.testing.assert_allclose(a @ b, w, rtol=1e-4, atol=1e-4)


def test_remap_reconstruction_close():
    rng = np.random.default_rng(7)
    w = rng.standard_normal((40, 24)).astype(np.float32)
    u, s, vt = np.linalg.svd(w, full_matrices=False)
    s[10:] = 0
    w_low = (u * s) @ vt  # genuine rank-10 matrix
    rf = remap_store(w_low, 10, precision="8+16")
    rec = reconstruct(rf)
    rel = np.linalg.norm(rec - w_low) / np.linalg.norm(w_low)
    assert rel < 0.02  # int8 on near-Gaussian factors is tiny (Table 15)


def test_remap_precision16_is_exactish():
    rng = np.random.default_rng(8)
    w = rng.standard_normal((30, 20)).astype(np.float32)
    u, s, vt = np.linalg.svd(w, full_matrices=False)
    s[6:] = 0
    w_low = (u * s) @ vt
    rf = remap_store(w_low, 6, precision="16")
    rel = np.linalg.norm(reconstruct(rf) - w_low) / np.linalg.norm(w_low)
    assert rel < 2e-3


def test_remap_storage_bijection():
    """Remapped bytes = k*max(m,n) fp16-equivalents — classic is k(m+n)."""
    m, n, k = 512, 128, 100
    rf = remap_store(np.random.default_rng(9).standard_normal((m, n)).astype(np.float32), k)
    assert rf.storage_bytes() < 2 * k * (m + n)          # beats classic fp16
    assert rf.storage_bytes() >= 2 * k * max(m, n)       # >= the bijection bound
    rf16 = remap_store(np.zeros((m, n), np.float32), k, precision="16")
    assert rf16.storage_bytes() == 2 * k * (m + n)


# ---------------------------------------------------------------------------
# ratio bijection
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(m=st.integers(8, 512), n=st.integers(8, 512),
       r=st.floats(0.05, 0.99))
def test_remap_ratio_bijection(m, n, r):
    k = remap_k_for_ratio(m, n, r)
    assert 1 <= k <= min(m, n)
    # round-trip within quantization of k
    assert abs(remap_ratio(m, n, k) - r) <= max(m, n) / (m * n) + 1e-9


def test_classic_k_loses_half_spectrum_square():
    """The long-overlooked limitation: r=1.0 classic keeps only rank/2."""
    k = classic_k_for_ratio(256, 256, 1.0)
    assert k == 128
    # remapping keeps the whole spectrum at r = 1.0
    assert remap_k_for_ratio(256, 256, 1.0) == 256


def test_remap_reaches_ranks_classic_cannot():
    m = n = 128
    k_classic_max = classic_k_for_ratio(m, n, 0.999)
    assert remap_k_for_ratio(m, n, 0.8) > k_classic_max * 0.8 / 0.5 - 2


def test_round_ranks_clamps_and_multiples():
    ks = np.array([3.0, 190.0, 500.0])
    shapes = [(192, 192), (192, 192), (192, 512)]
    out = round_ranks(ks, shapes)
    assert out[0] == 8            # k_min
    assert out[1] == 192          # clamp to min(m,n)
    assert out[2] == 192
    assert all(k % 8 == 0 for k in out)


def test_ptq_bytes():
    assert ptq_bytes((128, 64), 4) < ptq_bytes((128, 64), 8)
    assert ptq_bytes((10, 10), 8) == 100 + 40
