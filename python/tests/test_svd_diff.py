"""Gradient-stable SVD backward: numeric agreement + stability on the
degenerate spectra that blow up the naive rule (paper Eq. 1-2, Algos 4/5).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.dobi.svd_diff import _stable_inv_e, svd, svd_unstable


def _loss(f, a, wu=0.1, ws=1.0, wv=0.2):
    u, s, vt = f(a)
    k = s.shape[0]
    return (ws * jnp.sum(s * jnp.arange(1.0, k + 1.0))
            + wu * jnp.sum(u[:, : k // 2 + 1])
            + wv * jnp.sum(vt[: k // 2 + 1]))


def _numgrad(fn, a, eps=1e-5):
    g = np.zeros(a.shape)
    for i in range(a.shape[0]):
        for j in range(a.shape[1]):
            g[i, j] = (fn(a.at[i, j].add(eps)) - fn(a.at[i, j].add(-eps))) / (2 * eps)
    return g


@settings(max_examples=8, deadline=None)
@given(m=st.integers(3, 10), n=st.integers(3, 10), seed=st.integers(0, 2**16))
def test_grad_matches_numeric(m, n, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal((m, n)).astype(np.float32))
    # skip accidentally near-degenerate draws: the numeric reference itself
    # is ill-conditioned there
    s = np.linalg.svd(np.asarray(a), compute_uv=False)
    if np.min(np.abs(np.subtract.outer(s, s))[~np.eye(len(s), dtype=bool)]) < 5e-2 \
       or np.min(s) < 5e-2:
        return
    g = jax.grad(lambda x: _loss(svd, x))(a)
    gn = _numgrad(lambda x: float(_loss(svd, x)), a, eps=1e-3)
    np.testing.assert_allclose(np.asarray(g), gn, rtol=2e-2, atol=2e-2)


def test_grad_finite_on_exact_degeneracy():
    rng = np.random.default_rng(0)
    u0, _ = np.linalg.qr(rng.standard_normal((8, 8)))
    v0, _ = np.linalg.qr(rng.standard_normal((8, 8)))
    s0 = np.array([3.0, 1.0, 1.0, 1.0, 0.5, 0.0, 0.0, 0.0])
    a = jnp.asarray((u0 @ np.diag(s0) @ v0.T).astype(np.float32))
    g = jax.grad(lambda x: _loss(svd, x))(a)
    assert bool(jnp.all(jnp.isfinite(g)))


def test_grad_finite_on_duplicated_rows():
    """Rank-deficient activations (duplicated tokens) — the LLM case."""
    rng = np.random.default_rng(1)
    a = rng.standard_normal((16, 8)).astype(np.float32)
    a[1] = a[0]
    a[5] = a[4]
    a = jnp.asarray(a)
    g = jax.grad(lambda x: _loss(svd, x))(a)
    assert bool(jnp.all(jnp.isfinite(g)))


def test_stable_much_smaller_than_naive_on_close_spectrum():
    rng = np.random.default_rng(2)
    u0, _ = np.linalg.qr(rng.standard_normal((10, 10)))
    v0, _ = np.linalg.qr(rng.standard_normal((10, 10)))
    s0 = np.array([2.0, 1.0 + 1e-7, 1.0, 0.8, 0.5, 0.3, 0.2, 0.1, 1e-9, 1e-9])
    a = jnp.asarray((u0 @ np.diag(s0) @ v0.T).astype(np.float64))
    gs = jax.grad(lambda x: _loss(svd, x))(a)
    gu = jax.grad(lambda x: _loss(svd_unstable, x))(a)
    ns = float(jnp.linalg.norm(gs))
    nu = float(jnp.linalg.norm(gu))
    assert np.isfinite(ns)
    assert (not np.isfinite(nu)) or nu > 50 * ns


def test_rectangular_extra_terms():
    """m > k and n > k terms must both be exercised and correct."""
    rng = np.random.default_rng(3)
    for shape in [(12, 5), (5, 12)]:
        a = jnp.asarray(rng.standard_normal(shape).astype(np.float32))
        s = np.linalg.svd(np.asarray(a), compute_uv=False)
        if np.min(np.diff(s[::-1])) < 5e-2:
            continue
        g = jax.grad(lambda x: _loss(svd, x))(a)
        gn = _numgrad(lambda x: float(_loss(svd, x)), a, eps=1e-3)
        np.testing.assert_allclose(np.asarray(g), gn, rtol=3e-2, atol=3e-2)


def test_stable_inv_e_antisymmetric_and_bounded():
    s = jnp.asarray(np.array([5.0, 3.0, 3.0 + 1e-6, 1.0, 1e-11, 0.0], np.float32))
    f = np.asarray(_stable_inv_e(s, eps_val=1e-10, eps_grad=1e-10,
                                 eps_diff=1e-4, n_taylor=10))
    np.testing.assert_allclose(f, -f.T, atol=1e-6)
    assert np.all(np.isfinite(f))
    assert np.all(np.abs(np.diag(f)) == 0)


def test_stable_inv_e_matches_exact_when_separated():
    s = jnp.asarray(np.array([4.0, 2.0, 1.0], np.float32))
    f = np.asarray(_stable_inv_e(s, eps_val=1e-10, eps_grad=1e-10,
                                 eps_diff=1e-4, n_taylor=10))
    want01 = 1.0 / (2.0**2 - 4.0**2)
    np.testing.assert_allclose(f[0, 1], want01, rtol=1e-5)
    np.testing.assert_allclose(f[1, 0], -want01, rtol=1e-5)


def test_taylor_branch_approximates_exact():
    """Near (but not at) the eps_diff boundary, Taylor ~ exact."""
    s_hi = 1.0
    s_lo = 1.0 - 5e-5  # inside the Taylor branch
    s = jnp.asarray(np.array([s_hi, s_lo], np.float32))
    f = np.asarray(_stable_inv_e(s, eps_val=1e-10, eps_grad=1e-10,
                                 eps_diff=1e-4, n_taylor=30))
    exact = 1.0 / (s_lo**2 - s_hi**2)
    assert np.sign(f[0, 1]) == np.sign(exact)
    # K-term series truncates the magnitude (that's the point: bounded)
    assert abs(f[0, 1]) <= abs(exact) * 1.01


def test_svd_reconstruction():
    rng = np.random.default_rng(4)
    a = jnp.asarray(rng.standard_normal((9, 6)).astype(np.float32))
    u, s, vt = svd(a)
    np.testing.assert_allclose(np.asarray((u * s[None, :]) @ vt), np.asarray(a),
                               rtol=1e-4, atol=1e-4)
