"""Trainer + pipeline integration on a miniature model (fast settings)."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import data as D
from compile import model as M
from compile.dobi import pipeline as P
from compile.dobi import trainer as T
from compile.train_lm import pretrain


@pytest.fixture(scope="module")
def trained():
    cfg = M.CONFIGS["llama-nano"]
    toks = D.gen_wiki_syn(n_chars=60_000).tokens()
    params, losses = pretrain(cfg, toks, steps=25, log_every=0, log=lambda *a: None)
    assert losses[-1] < losses[0]
    return cfg, params, toks


@pytest.fixture(scope="module")
def calib(trained):
    cfg, params, toks = trained
    return P.collect_calibration(params, cfg, toks, n_batches=3)


def test_calibration_shapes(trained, calib):
    cfg, params, _ = trained
    for name, m, n in M.target_shapes(cfg):
        xs = calib[name]
        assert len(xs) == 3
        assert all(x.shape[1] == m for x in xs)


def test_train_ks_moves_toward_ratio(trained):
    cfg, params, toks = trained
    ks, log = T.train_ks(params, cfg, toks, ratio=0.5, steps=6,
                         log=lambda *a: None)
    shapes = [(m, n) for _, m, n in M.target_shapes(cfg)]
    assert len(ks) == len(shapes)
    assert all(8 <= k <= min(m, n) for k, (m, n) in zip(ks, shapes))
    # soft ratio tracked near target through training
    assert abs(log.ratio_history[-1] - 0.5) < 0.15
    assert len(log.k_history) == 6


def test_uniform_ks_hits_fraction(trained):
    cfg, _, _ = trained
    ks = T.uniform_ks(cfg, 0.5)
    shapes = [(m, n) for _, m, n in M.target_shapes(cfg)]
    for k, (m, n) in zip(ks, shapes):
        assert abs(k - 0.5 * min(m, n)) <= 8


def test_dobi_compress_ratio_and_eval(trained, calib):
    cfg, params, toks = trained
    ks = T.uniform_ks(cfg, 0.6)
    cm = P.dobi_compress(params, cfg, ks, calib, ratio=0.6)
    total = M.count_params(params)
    assert 0.45 < cm.stored_params / total < 0.8
    # compressed model still a language model: PPL finite and sane
    ppl = P.eval_ppl(cm.params, cfg, toks, n_windows=2)
    assert np.isfinite(ppl) and ppl < 260  # vocab PPL would be 256


def test_dobi_better_than_weight_svd(trained, calib):
    """The paper's core claim at module level: activation-path update beats
    direct weight truncation at the same ratio."""
    cfg, params, toks = trained
    ks = T.uniform_ks(cfg, 0.5)
    cm = P.dobi_compress(params, cfg, ks, calib, ratio=0.5)
    ppl_dobi = P.eval_ppl(cm.params, cfg, toks, n_windows=3)
    cw = P.svd_baseline_compress(params, cfg, 0.5, "weight_svd", calib)
    ppl_w = P.eval_ppl(cw.params, cfg, toks, n_windows=3)
    assert ppl_dobi < ppl_w


def test_scale_ks_to_classic_budget(trained):
    cfg, _, _ = trained
    ks = T.uniform_ks(cfg, 0.6)
    ks_c = P.scale_ks_to_classic(cfg, ks, 0.6)
    shapes = [(m, n) for _, m, n in M.target_shapes(cfg)]
    total = M.count_params(M.init_params(cfg))
    fixed = M.fixed_param_count(cfg)
    stored = fixed + sum(int(k) * (m + n) for k, (m, n) in zip(ks_c, shapes))
    assert abs(stored / total - 0.6) < 0.1
    # classic ranks strictly smaller than remapped at same ratio
    assert np.mean(ks_c) < np.mean(ks)


def test_svd_baselines_run(trained, calib):
    cfg, params, toks = trained
    for meth in ("weight_svd", "asvd", "svdllm"):
        cb = P.svd_baseline_compress(params, cfg, 0.7, meth, calib)
        ppl = P.eval_ppl(cb.params, cfg, toks, n_windows=2)
        assert np.isfinite(ppl), meth


def test_pruning_baselines_run(trained, calib):
    cfg, params, toks = trained
    grads = P.calibration_grads(params, cfg, toks, batch=2, seq=32)
    for meth in ("wanda_sp", "flap", "llm_pruner"):
        cb = P.pruning_compress(params, cfg, 0.7, meth, calib_x=calib, grads=grads)
        assert cb.heads_per_layer is not None
        ppl = P.eval_ppl(cb.params, cfg, toks, n_windows=2,
                         heads_per_layer=cb.heads_per_layer)
        assert np.isfinite(ppl), meth
        total = M.count_params(params)
        assert cb.stored_params < total


def test_perturb_ranks_conserves_budget():
    ks = np.full(28, 96, np.int64)
    kp = P.perturb_ranks(ks, 5)
    assert kp.sum() == ks.sum()
    assert np.count_nonzero(kp != ks) == 10


def test_activation_vs_weight_truncation(trained):
    """Table 1 shape: truncating activations beats truncating weights.

    The gap widens as the ratio drops (paper: 20.7 vs 105474 at 0.4); at a
    deep truncation the ordering is unambiguous even on a briefly-trained
    substrate, so that is what we assert (with slack for eval noise)."""
    cfg, params, toks = trained
    ks = T.uniform_ks(cfg, 0.25)
    shapes_all = M.target_shapes(cfg)
    ppl_act = P.eval_activation_truncation_ppl(params, cfg, toks,
                                               ks.astype(np.float32), n_windows=3)
    ppl_w = P.eval_weight_truncation_ppl(
        params, cfg, toks, {nm: int(k) for (nm, _, _), k in zip(shapes_all, ks)},
        n_windows=3)
    assert ppl_act < ppl_w * 1.05, f"act {ppl_act} !< weight {ppl_w}"


def test_cached_v_reuse_matches(trained, calib):
    cfg, params, toks = trained
    ks = T.uniform_ks(cfg, 0.6)
    cm1 = P.dobi_compress(params, cfg, ks, calib, ratio=0.6)
    cm2 = P.dobi_compress(params, cfg, ks, calib, ratio=0.6,
                          cached_v=cm1.cached_v)
    for name, _, _ in M.target_shapes(cfg):
        w1a, _ = (np.asarray(t) for t in M.get_target(cm1.params, name))
        w1b, _ = (np.asarray(t) for t in M.get_target(cm2.params, name))
        np.testing.assert_allclose(w1a, w1b, atol=1e-6)
