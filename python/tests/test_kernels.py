"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes and dtypes — this is the CORE kernel signal.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.dequant_matmul import dequant_matmul
from compile.kernels.factorized_matmul import dense_flops, factorized_matmul, flops
from compile.kernels.matmul import matmul, mxu_utilization_estimate, vmem_bytes
from compile.kernels.smooth_truncate import smooth_truncate

DIMS = st.integers(min_value=1, max_value=200)
SMALL = st.integers(min_value=1, max_value=48)


def rand(rng, *shape, dtype=np.float32):
    return jnp.asarray(rng.standard_normal(shape).astype(dtype))


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS, seed=st.integers(0, 2**16))
def test_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, w = rand(rng, m, k), rand(rng, k, n)
    got = matmul(x, w)
    want = ref.matmul_ref(x, w)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_matmul_exact_blocks():
    rng = np.random.default_rng(0)
    x, w = rand(rng, 256, 128), rand(rng, 128, 256)
    np.testing.assert_allclose(matmul(x, w), ref.matmul_ref(x, w), rtol=1e-4, atol=1e-4)


def test_matmul_single_element():
    x = jnp.ones((1, 1))
    w = jnp.full((1, 1), 3.0)
    assert float(matmul(x, w)[0, 0]) == 3.0


def test_matmul_zero_input():
    x = jnp.zeros((7, 13))
    w = jnp.ones((13, 5))
    assert float(jnp.abs(matmul(x, w)).max()) == 0.0


def test_matmul_rejects_mismatch():
    with pytest.raises(AssertionError):
        matmul(jnp.ones((2, 3)), jnp.ones((4, 5)))


def test_matmul_bf16():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((32, 64)), jnp.bfloat16)
    w = jnp.asarray(rng.standard_normal((64, 32)), jnp.bfloat16)
    got = matmul(x, w).astype(jnp.float32)
    want = ref.matmul_ref(x, w).astype(jnp.float32)
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-1)


def test_matmul_custom_blocks():
    rng = np.random.default_rng(2)
    x, w = rand(rng, 100, 70), rand(rng, 70, 90)
    got = matmul(x, w, bm=32, bn=32, bk=32)
    np.testing.assert_allclose(got, ref.matmul_ref(x, w), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# factorized matmul
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(m=DIMS, mm=DIMS, k=SMALL, n=DIMS, seed=st.integers(0, 2**16))
def test_factorized_matches_ref(m, mm, k, n, seed):
    rng = np.random.default_rng(seed)
    x, w1, w2 = rand(rng, m, mm), rand(rng, mm, k), rand(rng, k, n)
    got = factorized_matmul(x, w1, w2)
    want = ref.factorized_matmul_ref(x, w1, w2)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-3)


def test_factorized_equals_dense_at_full_rank():
    """W = W1 @ W2 exactly when k = min(m,n): factorized == dense path."""
    rng = np.random.default_rng(3)
    w = rng.standard_normal((48, 32)).astype(np.float32)
    u, s, vt = np.linalg.svd(w, full_matrices=False)
    w1 = jnp.asarray(u * np.sqrt(s))
    w2 = jnp.asarray(np.sqrt(s)[:, None] * vt)
    x = rand(rng, 20, 48)
    got = factorized_matmul(x, w1, w2)
    want = ref.matmul_ref(x, jnp.asarray(w))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_factorized_rank_mismatch_rejected():
    with pytest.raises(AssertionError):
        factorized_matmul(jnp.ones((4, 8)), jnp.ones((8, 3)), jnp.ones((4, 8)))


def test_flops_accounting():
    assert flops(10, 100, 100, 10) < dense_flops(10, 100, 100)
    assert flops(1, 4, 4, 4) == 2 * 1 * 4 * 8


# ---------------------------------------------------------------------------
# dequant matmul
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(m=SMALL, k=DIMS, n=DIMS, seed=st.integers(0, 2**16))
def test_dequant_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, m, k)
    wq = jnp.asarray(rng.integers(-127, 128, (k, n)).astype(np.int8))
    s = jnp.asarray((rng.random(n) * 0.02 + 1e-4).astype(np.float32))
    got = dequant_matmul(x, wq, s)
    want = ref.dequant_matmul_ref(x, wq, s)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_dequant_matmul_zero_scales():
    x = jnp.ones((4, 8))
    wq = jnp.ones((8, 6), jnp.int8)
    s = jnp.zeros((6,))
    assert float(jnp.abs(dequant_matmul(x, wq, s)).max()) == 0.0


# ---------------------------------------------------------------------------
# smooth truncate
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(n=st.integers(2, 300), kf=st.floats(0.0, 1.0), seed=st.integers(0, 2**16))
def test_smooth_truncate_matches_ref(n, kf, seed):
    rng = np.random.default_rng(seed)
    sig = jnp.asarray(np.sort(rng.random(n))[::-1].copy().astype(np.float32))
    k = jnp.float32(kf * n)
    got = smooth_truncate(sig, k)
    want = ref.smooth_truncate_ref(sig, k, 10.0)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_smooth_truncate_limits():
    sig = jnp.ones((64,))
    hi = smooth_truncate(sig, jnp.float32(200.0))   # k >> n keeps all
    lo = smooth_truncate(sig, jnp.float32(-100.0))  # k << 0 kills all
    np.testing.assert_allclose(hi, sig, atol=1e-5)
    np.testing.assert_allclose(lo, jnp.zeros_like(sig), atol=1e-5)


def test_smooth_truncate_is_monotone_gate():
    """Gate must be non-increasing in i: earlier sigmas are kept more."""
    sig = jnp.ones((128,))
    g = np.asarray(smooth_truncate(sig, jnp.float32(64.0)))
    assert np.all(np.diff(g) <= 1e-6)
    assert g[0] > 0.99 and g[-1] < 0.01


# ---------------------------------------------------------------------------
# structural perf estimates (used by EXPERIMENTS.md §Perf)
# ---------------------------------------------------------------------------

def test_vmem_fits_16mb_for_default_blocks():
    assert vmem_bytes(128, 128, 128) < 16 * 1024 * 1024


def test_mxu_utilization_bounds():
    u = mxu_utilization_estimate(192, 192, 24, 128, 128, 128)
    assert 0.0 < u <= 1.0
    assert mxu_utilization_estimate(256, 256, 128, 128, 128, 128) == 1.0


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

from compile.kernels.rmsnorm import rmsnorm, rmsnorm_ref


@settings(max_examples=10, deadline=None)
@given(rows=st.integers(1, 300), d=st.integers(2, 256), seed=st.integers(0, 2**16))
def test_rmsnorm_matches_ref(rows, d, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, rows, d)
    g = rand(rng, d).reshape(d)
    got = rmsnorm(x, g)
    want = rmsnorm_ref(x, g)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_rmsnorm_unit_gain_rows():
    """Constant gain 1: every output row has RMS ~ 1."""
    rng = np.random.default_rng(0)
    x = rand(rng, 16, 64) * 5.0
    out = np.asarray(rmsnorm(x, jnp.ones((64,))))
    rms = np.sqrt((out ** 2).mean(axis=-1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-3)


def test_rmsnorm_scale_invariance():
    rng = np.random.default_rng(1)
    x = rand(rng, 8, 32)
    g = jnp.ones((32,))
    a = np.asarray(rmsnorm(x, g))
    b = np.asarray(rmsnorm(x * 1000.0, g))
    np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-4)
