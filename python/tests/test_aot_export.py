"""AOT export plumbing: HLO text hygiene, weight-store round trips, and
the store -> params reassembly the rust loader mirrors."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import dobiw as IO
from compile import model as M
from compile.aot import _arrays_from_store, export_weights, spec_like, to_hlo_text
from compile.dobi import pipeline as P
from compile.dobi import trainer as T


@pytest.fixture(scope="module")
def nano():
    cfg = M.CONFIGS["llama-nano"]
    return cfg, M.init_params(cfg, seed=3)


def test_hlo_text_has_no_elided_constants(nano):
    """The xla_extension-0.5.1 text parser zero-fills `constant({...})`;
    the exporter must never emit it (this was a real silent-corruption
    bug — see EXPERIMENTS.md)."""
    cfg, params = nano
    names, arrays = M.flatten_for_export(params)

    def fn(tokens, *arrs):
        p = M.unflatten_from_export(cfg, names, list(arrs))
        return (M.forward_dense(p, tokens, cfg),)

    text = to_hlo_text(fn, jax.ShapeDtypeStruct((1, 16), np.int32),
                       *[spec_like(a) for a in arrays])
    assert "constant({...}" not in text
    assert text.startswith("HloModule")
    # tokens + every weight must surface as parameters
    assert text.count("parameter(") >= len(arrays) + 1


def test_export_weights_roundtrip_dense(nano, tmp_path):
    cfg, params = nano
    path = str(tmp_path / "w.dobiw")
    names, nbytes = export_weights(path, params, None)
    assert nbytes == os.path.getsize(path)
    store = IO.read_dobiw(path)
    arrays = _arrays_from_store(store, names)
    p2 = M.unflatten_from_export(cfg, names, [jnp.asarray(a) for a in arrays])
    toks = jnp.arange(16, dtype=jnp.int32).reshape(1, 16)
    np.testing.assert_allclose(
        np.asarray(M.forward_dense(params, toks, cfg)),
        np.asarray(M.forward_dense(p2, toks, cfg)), atol=1e-6)


def test_export_weights_quantized_roundtrip(nano, tmp_path):
    """Remapped variants ship int8 codes; reassembly must match the
    dequantized factors the pipeline produced (rust mirrors this)."""
    cfg, params = nano
    toks = (np.arange(40_000) % 250).astype(np.int32)
    calib = P.collect_calibration(params, cfg, toks, n_batches=2)
    ks = T.uniform_ks(cfg, 0.6)
    cm = P.dobi_compress(params, cfg, ks, calib, ratio=0.6, precision="8+16")
    path = str(tmp_path / "q.dobiw")
    names, _ = export_weights(path, cm.params, cm)
    store = IO.read_dobiw(path)
    # every factor went out as q8 + scales, not f32
    q8 = [k for k in store if k.endswith(".q8")]
    assert len(q8) == 2 * 7 * cfg.n_layers
    arrays = _arrays_from_store(store, names)
    for name, arr in zip(names, arrays):
        if name.endswith(".w1"):
            want = np.asarray(M.get_target(cm.params, name.rsplit(".", 1)[0])[0])
            np.testing.assert_allclose(arr, want, atol=1e-6)


def test_spec_like():
    s = spec_like(np.zeros((3, 4), np.float32))
    assert s.shape == (3, 4) and s.dtype == np.float32
