"""L2 model: shapes, invariances, export plumbing, losses."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data as D
from compile import model as M


@pytest.fixture(scope="module")
def nano():
    cfg = M.CONFIGS["llama-nano"]
    return cfg, M.init_params(cfg, seed=0)


def test_forward_shapes(nano):
    cfg, params = nano
    toks = jnp.zeros((2, 16), jnp.int32)
    logits = M.forward_dense(params, toks, cfg)
    assert logits.shape == (2, 16, cfg.vocab)


def test_forward_deterministic(nano):
    cfg, params = nano
    toks = jnp.arange(32, dtype=jnp.int32).reshape(2, 16) % cfg.vocab
    a = M.forward_dense(params, toks, cfg)
    b = M.forward_dense(params, toks, cfg)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_causality(nano):
    """Changing a future token must not change past logits."""
    cfg, params = nano
    rng = np.random.default_rng(0)
    t1 = rng.integers(0, cfg.vocab, (1, 20)).astype(np.int32)
    t2 = t1.copy()
    t2[0, 15] = (t2[0, 15] + 7) % cfg.vocab
    l1 = np.asarray(M.forward_dense(params, jnp.asarray(t1), cfg))
    l2 = np.asarray(M.forward_dense(params, jnp.asarray(t2), cfg))
    np.testing.assert_allclose(l1[0, :15], l2[0, :15], atol=1e-4)
    assert np.abs(l1[0, 15:] - l2[0, 15:]).max() > 1e-6


def test_factorized_full_rank_equals_dense(nano):
    cfg, params = nano
    p2 = params
    for name, m, n in M.target_shapes(cfg):
        w = np.asarray(M.get_target(params, name))
        u, s, vt = np.linalg.svd(w, full_matrices=False)
        w1 = jnp.asarray((u * np.sqrt(s)).astype(np.float32))
        w2 = jnp.asarray((np.sqrt(s)[:, None] * vt).astype(np.float32))
        p2 = M.set_target(p2, name, (w1, w2))
    toks = jnp.arange(24, dtype=jnp.int32).reshape(1, 24) % cfg.vocab
    ld = np.asarray(M.forward_dense(params, toks, cfg))
    lf = np.asarray(M.forward_factorized(p2, toks, cfg))
    np.testing.assert_allclose(ld, lf, rtol=1e-2, atol=5e-3)


def test_pruned_forward_shapes():
    cfg = M.CONFIGS["llama-nano"]
    params = M.init_params(cfg, seed=1)
    # slim layer 0 to 2 heads and 128 ff channels
    d_head = cfg.d_head
    cols = np.arange(2 * d_head)
    for mn in ("wq", "wk", "wv"):
        params = M.set_target(params, f"layers.0.{mn}",
                              jnp.asarray(np.asarray(M.get_target(params, f"layers.0.{mn}"))[:, cols]))
    params = M.set_target(params, "layers.0.wo",
                          jnp.asarray(np.asarray(M.get_target(params, "layers.0.wo"))[cols, :]))
    keep_f = np.arange(128)
    for mn in ("w_gate", "w_up"):
        params = M.set_target(params, f"layers.0.{mn}",
                              jnp.asarray(np.asarray(M.get_target(params, f"layers.0.{mn}"))[:, keep_f]))
    params = M.set_target(params, "layers.0.w_down",
                          jnp.asarray(np.asarray(M.get_target(params, "layers.0.w_down"))[keep_f, :]))
    toks = jnp.zeros((2, 8), jnp.int32)
    logits = M.forward_pruned(params, toks, cfg, [2, cfg.n_heads, cfg.n_heads, cfg.n_heads])
    assert logits.shape == (2, 8, cfg.vocab)


def test_vlm_forward_shapes():
    cfg = M.CONFIGS["vlm-nano"]
    params = M.init_params(cfg, seed=2)
    toks = jnp.zeros((3, 12), jnp.int32)
    img = jnp.ones((3, cfg.img_dim))
    logits = M.forward_vlm(params, toks, img, cfg)
    assert logits.shape == (3, 12, cfg.vocab)


def test_vlm_prefix_influences_logits():
    cfg = M.CONFIGS["vlm-nano"]
    params = M.init_params(cfg, seed=3)
    toks = jnp.zeros((1, 8), jnp.int32)
    l1 = M.forward_vlm(params, toks, jnp.zeros((1, cfg.img_dim)), cfg)
    l2 = M.forward_vlm(params, toks, jnp.ones((1, cfg.img_dim)), cfg)
    assert float(jnp.abs(l1 - l2).max()) > 1e-4


def test_vla_forward_ranges():
    cfg = M.CONFIGS["vla-nano"]
    params = M.init_params(cfg, seed=4)
    toks = jnp.zeros((2, 8), jnp.int32)
    img = jnp.ones((2, cfg.img_dim))
    act = np.asarray(M.forward_vla(params, toks, img, cfg))
    assert act.shape == (2, 5)
    assert np.all(np.abs(act[:, :4]) <= 1.0)  # tanh-bounded coords+angle


def test_lm_loss_uniform_is_log_vocab(nano):
    cfg, _ = nano
    logits = jnp.zeros((2, 10, cfg.vocab))
    toks = jnp.zeros((2, 10), jnp.int32)
    loss = float(M.lm_loss(logits, toks))
    np.testing.assert_allclose(loss, np.log(cfg.vocab), rtol=1e-5)


def test_target_shapes_count(nano):
    cfg, _ = nano
    ts = M.target_shapes(cfg)
    assert len(ts) == 7 * cfg.n_layers
    names = [t[0] for t in ts]
    assert len(set(names)) == len(names)


def test_get_set_target_roundtrip(nano):
    cfg, params = nano
    w = M.get_target(params, "layers.1.w_up")
    p2 = M.set_target(params, "layers.1.w_up", w * 2)
    assert float(jnp.abs(M.get_target(p2, "layers.1.w_up") - 2 * w).max()) == 0.0
    # original untouched (functional update)
    assert float(jnp.abs(M.get_target(params, "layers.1.w_up") - w).max()) == 0.0


def test_flatten_unflatten_roundtrip(nano):
    cfg, params = nano
    names, arrays = M.flatten_for_export(params)
    assert len(names) == len(arrays)
    p2 = M.unflatten_from_export(cfg, names, arrays)
    toks = jnp.zeros((1, 8), jnp.int32)
    np.testing.assert_allclose(np.asarray(M.forward_dense(params, toks, cfg)),
                               np.asarray(M.forward_dense(p2, toks, cfg)), atol=1e-6)


def test_flatten_expands_factors(nano):
    cfg, params = nano
    p2 = M.set_target(params, "layers.0.wq",
                      (jnp.ones((cfg.d_model, 8)), jnp.ones((8, cfg.d_model))))
    names, _ = M.flatten_for_export(p2)
    assert "layers.0.wq.w1" in names and "layers.0.wq.w2" in names
    assert "layers.0.wq" not in names


def test_fixed_param_count(nano):
    cfg, params = nano
    fixed = M.fixed_param_count(cfg)
    total = M.count_params(params)
    comp = sum(m * n for _, m, n in M.target_shapes(cfg))
    assert fixed == total - comp
    assert fixed > 0


def test_tokenizer_roundtrip():
    s = "Hello, Dobi-SVD! 123"
    assert D.decode(D.encode(s)) == s


def test_tokenizer_vocab_bound():
    t = D.encode("ünïcödé ✓")
    assert t.max() < 256 and t.min() >= 0
