"""Corpora determinism, task-suite sanity, and binary interchange formats."""

import json
import os

import numpy as np
import pytest

from compile import data as D
from compile import dobiw as IO


def test_corpora_deterministic():
    a = D.gen_wiki_syn(seed=0, n_chars=20_000).text
    b = D.gen_wiki_syn(seed=0, n_chars=20_000).text
    assert a == b
    c = D.gen_wiki_syn(seed=1, n_chars=20_000).text
    assert a != c


def test_corpora_distinct_statistics():
    """The three corpora must be statistically distinguishable (that is
    their whole job: in-domain vs out-of-domain PPL structure)."""
    def unigram(text):
        h = np.zeros(256)
        for b in text.encode()[:20000]:
            h[b] += 1
        return h / h.sum()
    w = unigram(D.gen_wiki_syn(n_chars=30_000).text)
    p = unigram(D.gen_ptb_syn(n_chars=30_000).text)
    c = unigram(D.gen_c4_syn(n_chars=30_000).text)
    def tv(a, b):
        return 0.5 * np.abs(a - b).sum()
    assert tv(w, p) > 0.05
    assert tv(w, c) > 0.01
    assert tv(p, c) > 0.05


def test_ptb_lower_entropy_than_c4():
    def ent(text):
        h = np.zeros(256)
        for b in text.encode()[:30000]:
            h[b] += 1
        p = h[h > 0] / h.sum()
        return -(p * np.log(p)).sum()
    assert ent(D.gen_ptb_syn(n_chars=40_000).text) < ent(D.gen_c4_syn(n_chars=40_000).text)


def test_tokbin_roundtrip(tmp_path):
    toks = np.random.default_rng(0).integers(0, 256, 1000).astype(np.int32)
    p = str(tmp_path / "t.tokbin")
    D.write_tokbin(p, toks)
    back = D.read_tokbin(p)
    np.testing.assert_array_equal(toks, back)


def test_tokbin_crc_detects_corruption(tmp_path):
    toks = np.arange(100, dtype=np.int32) % 256
    p = str(tmp_path / "t.tokbin")
    D.write_tokbin(p, toks)
    raw = bytearray(open(p, "rb").read())
    raw[20] ^= 0xFF
    open(p, "wb").write(bytes(raw))
    with pytest.raises(AssertionError):
        D.read_tokbin(p)


def test_task_suites_valid():
    wiki = D.gen_wiki_syn(n_chars=40_000)
    ptb = D.gen_ptb_syn(n_chars=20_000)
    c4 = D.gen_c4_syn(n_chars=20_000)
    suites = D.build_task_suites(wiki, ptb, c4, n_per=10)
    assert len(suites) == 7
    for s in suites:
        assert len(s.tasks) == 10
        for t in s.tasks:
            assert 0 <= t.answer < len(t.options)
            assert len(set(t.options)) == len(t.options)
            assert t.options[t.answer] is not None


def test_copy_suite_answer_is_continuation():
    suite = D._copy_tasks(seed=1, n=20)
    for t in suite.tasks:
        words = t.prompt.strip().split(" ")
        key = words[-1]
        first = words.index(key)
        assert t.options[t.answer] == words[first + 1]


def test_digit_suite_progression():
    suite = D._digit_tasks(seed=2, n=20)
    for t in suite.tasks:
        seq = [int(x) for x in t.prompt.strip().split(" ")]
        d = (seq[1] - seq[0]) % 10
        want = (seq[3] + d) % 10
        assert int(t.options[t.answer]) == want


def test_vqa_answer_recoverable():
    samples = D.build_vqa(seed=3, n=10, img_dim=32)
    for s in samples:
        assert s.options[s.answer] == s.caption
        assert s.image.shape == (32,)


def test_vla_actions_bounded():
    samples = D.build_vla(seed=4, n=20, img_dim=32)
    for s in samples:
        assert np.all(np.abs(s.coords) <= 1.0)
        assert abs(s.angle) <= 1.0
        assert s.gripper in (0, 1)


def test_dobiw_roundtrip(tmp_path):
    rng = np.random.default_rng(5)
    tensors = [
        ("a", rng.standard_normal((4, 6)).astype(np.float32)),
        ("b.q8", rng.integers(-127, 128, (8, 3)).astype(np.int8)),
        ("b.scales", rng.random((1, 3)).astype(np.float32)),
        ("c", rng.standard_normal((5,)).astype(np.float16)),
        ("d", np.arange(12, dtype=np.int32).reshape(3, 4)),
    ]
    p = str(tmp_path / "w.dobiw")
    n = IO.write_dobiw(p, tensors)
    assert n == os.path.getsize(p)
    back = IO.read_dobiw(p)
    assert set(back) == {t[0] for t in tensors}
    for name, arr in tensors:
        np.testing.assert_array_equal(back[name], arr)
        assert back[name].dtype == arr.dtype


def test_dobiw_crc_detects_corruption(tmp_path):
    p = str(tmp_path / "w.dobiw")
    IO.write_dobiw(p, [("x", np.ones((64,), np.float32))])
    raw = bytearray(open(p, "rb").read())
    raw[-10] ^= 0x01
    open(p, "wb").write(bytes(raw))
    with pytest.raises(AssertionError):
        IO.read_dobiw(p)


def test_suites_json_schema(tmp_path):
    wiki = D.gen_wiki_syn(n_chars=20_000)
    suites = [D._copy_tasks(seed=0, n=5)]
    p = str(tmp_path / "tasks.json")
    D.write_suites(p, suites)
    with open(p) as f:
        doc = json.load(f)
    assert doc["suites"][0]["name"] == "copy-syn"
    assert len(doc["suites"][0]["tasks"]) == 5
