"""AOT artifact builder — the author/compile path, run ONCE by
`make artifacts`; the rust binary is self-contained afterwards.

Pipeline:
  1. synthesize corpora + task suites (data.py)
  2. pretrain the substrate LM zoo (train_lm.py)            [cached]
  3. differentiable-k training per (model, ratio)           [cached]
  4. compress: Dobi-SVD + every baseline at every ratio
  5. lower every variant's forward to HLO *text* (weights as HLO
     parameters) and write `.dobiw` weight containers
  6. run the python-side analyses that are training-time by nature
     (Table 1 oracle, Fig 3/7/8/11, Table 15/17 inputs, gradstab)
  7. reference PPLs on the exact eval windows rust re-measures
  8. write manifest.json

HLO text (NOT `.serialize()`): the image's xla_extension 0.5.1 rejects
jax>=0.5 64-bit-id protos; the text parser reassigns ids (see
/opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os
import pickle
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as D
from . import dobiw as IO
from . import model as M
from . import train_lm as TL
from .dobi import baselines as B
from .dobi import pipeline as P
from .dobi import remap as R
from .dobi import trainer as T
from .dobi.ipca import (IncrementalPCA, batch_right_basis, full_pca_components,
                        ipca_memory_bytes, pca_memory_bytes, subspace_distance)
from .dobi.svd_diff import svd, svd_unstable

EVAL_BATCH, EVAL_SEQ, EVAL_WINDOWS = 4, 64, 12
GEN_SHAPE = (1, 64)
SWEEP_SHAPES = [(1, 32), (2, 32), (4, 32), (8, 32), (16, 32),
                (4, 16), (4, 64), (4, 128)]
RATIOS = [0.8, 0.6, 0.4]


def log(*a):
    print(*a, flush=True)


# ---------------------------------------------------------------------------
# HLO lowering
# ---------------------------------------------------------------------------

def to_hlo_text(fn, *example_args) -> str:
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    # print_large_constants=True: the default printer ELIDES big constant
    # tensors as `constant({...})`, which xla_extension's text parser then
    # silently zero-fills (trace-time constants like RoPE cos/sin tables
    # and the causal mask would be destroyed).  Found via the op-probe
    # harness; a regression test asserts no `...` survives in any export.
    text = comp.as_hlo_text(True)
    assert "constant({...}" not in text, "HLO printer elided a constant"
    return text


def spec_like(a) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(np.shape(a), np.asarray(a).dtype)


def make_lm_export_fn(cfg: M.ModelConfig, names: list[str],
                      heads_per_layer=None, kernel: str = "xla"):
    def fn(tokens, *arrays):
        params = M.unflatten_from_export(cfg, names, list(arrays))
        if heads_per_layer is not None:
            return (M.forward_pruned(params, tokens, cfg, heads_per_layer),)
        return (M.forward_dense(params, tokens, cfg, kernel=kernel),)
    return fn


def make_mm_export_fn(cfg: M.ModelConfig, names: list[str], action: bool,
                      kernel: str = "xla"):
    def fn(tokens, image, *arrays):
        params = M.unflatten_from_export(cfg, names, list(arrays))
        if action:
            return (M.forward_vla(params, tokens, image, cfg, kernel=kernel),)
        return (M.forward_vlm(params, tokens, image, cfg, kernel=kernel),)
    return fn


# ---------------------------------------------------------------------------
# Weight export
# ---------------------------------------------------------------------------

def export_weights(path: str, params: dict, cm: P.CompressedModel | None,
                   precision: str = "f32") -> tuple[list[str], int]:
    """Write the variant's weights.  For remapped Dobi variants the factor
    tensors go out as (q8 codes + broadcast-shaped scales) so the rust
    storage layer performs the dequantization — returns (HLO param names
    in order, bytes written)."""
    names, arrays = M.flatten_for_export(params)
    tensors: list[tuple[str, np.ndarray]] = []
    remap8 = cm is not None and cm.method.startswith("dobi[8+16]")
    for name, arr in zip(names, arrays):
        a = np.asarray(arr)
        if remap8 and (name.endswith(".w1") or name.endswith(".w2")):
            axis = 0 if name.endswith(".w1") else 1
            q, s = R.quantize_absmax(a, bits=8, axis=axis)
            s_shaped = np.expand_dims(s, axis=axis).astype(np.float32)
            tensors.append((name + ".q8", q))
            tensors.append((name + ".scales", s_shaped))
        elif precision == "f16":
            tensors.append((name, a.astype(np.float16)))
        else:
            tensors.append((name, a.astype(np.float32)))
    nbytes = IO.write_dobiw(path, tensors)
    return names, nbytes


# ---------------------------------------------------------------------------
# Profiles
# ---------------------------------------------------------------------------

PROFILES = {
    "full": dict(pretrain_steps=300, pretrain_steps_alt=160, pretrain_steps_l=220,
                 ktrain_steps=60, ktrain_steps_alt=36, mm_steps=70,
                 corpus_chars=600_000, ref_windows=EVAL_WINDOWS,
                 models=("llama-nano", "llama2-nano", "llama3-nano",
                         "llama-nano-l", "vlm-nano", "vla-nano")),
    "quick": dict(pretrain_steps=40, pretrain_steps_alt=25, pretrain_steps_l=30,
                  ktrain_steps=8, ktrain_steps_alt=6, mm_steps=12,
                  corpus_chars=150_000, ref_windows=4,
                  models=("llama-nano", "vla-nano")),
}


class Builder:
    def __init__(self, out: str, profile: str):
        self.out = out
        self.prof = PROFILES[profile]
        self.profile_name = profile
        self.cache_dir = os.path.join(out, "cache")
        D.ensure_dir(out)
        D.ensure_dir(self.cache_dir)
        self.manifest: dict = {
            "version": 1, "profile": profile, "models": {}, "variants": [],
            "corpora": {}, "analysis": {}, "training": {},
            "eval": {"batch": EVAL_BATCH, "seq": EVAL_SEQ,
                     "windows": self.prof["ref_windows"]},
        }

    # -- caching ------------------------------------------------------------
    def cached(self, key: str, fn):
        path = os.path.join(self.cache_dir, key + ".pkl")
        if os.path.exists(path):
            with open(path, "rb") as f:
                return pickle.load(f)
        val = fn()
        with open(path, "wb") as f:
            pickle.dump(val, f)
        return val

    # -- stage 1: corpora ----------------------------------------------------
    def build_corpora(self):
        log("== corpora ==")
        n = self.prof["corpus_chars"]
        self.wiki = D.gen_wiki_syn(n_chars=n)
        self.ptb = D.gen_ptb_syn(n_chars=max(n // 3, 60_000))
        self.c4 = D.gen_c4_syn(n_chars=max(n // 3, 60_000))
        self.tokens = {}
        for c in (self.wiki, self.ptb, self.c4):
            toks = c.tokens()
            split = int(0.9 * len(toks))
            self.tokens[c.name] = {"train": toks[:split], "eval": toks[split:]}
            D.write_tokbin(os.path.join(self.out, f"corpus_{c.name}.tokbin"),
                           toks[:split])
            # Fixed eval windows: rust must reproduce python PPL bit-for-bit
            # (same windows, same order).
            nw = self.prof["ref_windows"]
            ev = toks[split:]
            rng = np.random.default_rng(99)
            hi = len(ev) - EVAL_SEQ - 1
            wins = np.stack([ev[i:i + EVAL_SEQ]
                             for i in rng.integers(0, hi, size=nw * EVAL_BATCH)])
            D.write_tokbin(os.path.join(self.out, f"eval_{c.name}.tokbin"),
                           wins.reshape(-1))
            self.manifest["corpora"][c.name] = {
                "train": f"corpus_{c.name}.tokbin",
                "eval_windows": f"eval_{c.name}.tokbin",
                "n_windows": nw,
            }
            self.tokens[c.name]["eval_wins"] = wins.reshape(nw, EVAL_BATCH, EVAL_SEQ)
        suites = D.build_task_suites(self.wiki, self.ptb, self.c4,
                                     n_per=40 if self.profile_name == "quick" else 60)
        suites.append(D.build_mmlu_syn(self.wiki, self.ptb, self.c4,
                                       n=40 if self.profile_name == "quick" else 80))
        D.write_suites(os.path.join(self.out, "tasks.json"), suites)
        self.manifest["suites"] = "tasks.json"
        # VQA / VLA
        img_dim = M.CONFIGS["vlm-nano"].img_dim
        vqa = D.build_vqa(31, 200, img_dim)
        vla = D.build_vla(32, 260, img_dim)
        self.vqa, self.vla = vqa, vla
        with open(os.path.join(self.out, "vqa.json"), "w") as f:
            json.dump({"img_dim": img_dim, "samples": [
                {"image": s.image.tolist(), "question": s.question,
                 "options": s.options, "answer": s.answer} for s in vqa[120:]]}, f)
        with open(os.path.join(self.out, "vla.json"), "w") as f:
            json.dump({"img_dim": img_dim, "samples": [
                {"image": s.image.tolist(), "instruction": s.instruction,
                 "coords": s.coords.tolist(), "angle": s.angle,
                 "gripper": s.gripper} for s in vla[180:]]}, f)
        self.manifest["vqa"] = "vqa.json"
        self.manifest["vla"] = "vla.json"

    # -- stage 2: pretraining --------------------------------------------------
    def pretrain_all(self):
        log("== pretrain ==")
        wiki_train = self.tokens["wiki-syn"]["train"]
        self.params: dict[str, dict] = {}
        self.pretrain_losses: dict[str, list[float]] = {}
        for name in self.prof["models"]:
            cfg = M.CONFIGS[name]
            steps = (self.prof["pretrain_steps"] if name == "llama-nano" else
                     self.prof["pretrain_steps_l"] if name == "llama-nano-l" else
                     self.prof["pretrain_steps_alt"])

            def build(name=name, cfg=cfg, steps=steps):
                if cfg.img_dim:  # multimodal: start from llama-nano trunk
                    base_cfg = M.CONFIGS["llama-nano"]
                    base, losses = TL.pretrain(base_cfg, wiki_train, steps=steps, log=log,
                                               seed=7)
                    p = M.init_params(cfg, seed=17)
                    p.update({k: base[k] for k in ("embed", "final_norm", "layers")})
                    if cfg.action_head:
                        p = TL.finetune_vla(cfg, p, self.vla[:180],
                                            steps=self.prof["mm_steps"], log=log)
                    else:
                        p = TL.finetune_vlm(cfg, p, self.vqa[:120],
                                            steps=self.prof["mm_steps"], log=log)
                    return jax.tree_util.tree_map(np.asarray, p), losses
                p, losses = TL.pretrain(cfg, wiki_train, steps=steps, log=log,
                                        seed=hash(name) % 1000)
                return jax.tree_util.tree_map(np.asarray, p), losses

            p, losses = self.cached(f"pretrain_{name}", build)
            self.params[name] = jax.tree_util.tree_map(jnp.asarray, p)
            self.pretrain_losses[name] = losses
            cfg_d = {k: getattr(cfg, k) for k in
                     ("vocab", "d_model", "n_layers", "n_heads", "d_ff",
                      "img_dim", "n_img_tokens", "action_head")}
            self.manifest["models"][name] = {
                "config": cfg_d,
                "total_params": M.count_params(self.params[name]),
                "fixed_params": M.fixed_param_count(cfg),
            }
            self.manifest["training"].setdefault(name, {})["pretrain_loss"] = losses

    # -- stage 3: k-training ----------------------------------------------------
    def ktrain_all(self):
        log("== differentiable-k training ==")
        wiki_train = self.tokens["wiki-syn"]["train"]
        self.ks: dict[tuple[str, float], np.ndarray] = {}
        for name in self.prof["models"]:
            cfg = M.CONFIGS[name]
            steps = (self.prof["ktrain_steps"] if name == "llama-nano"
                     else self.prof["ktrain_steps_alt"])
            for ratio in RATIOS:
                def build(name=name, cfg=cfg, ratio=ratio, steps=steps):
                    val = self.tokens["wiki-syn"]["eval"]
                    ks, tlog = T.train_ks(
                        self.params[name], cfg, wiki_train, ratio=ratio,
                        steps=steps, log=log,
                        val_tokens=val if name == "llama-nano" else None,
                        val_every=max(steps // 6, 1) if name == "llama-nano" else 0)
                    return ks, tlog.__dict__
                ks, tlog = self.cached(f"ktrain_{name}_{int(ratio*100)}", build)
                self.ks[(name, ratio)] = ks
                self.manifest["training"].setdefault(name, {}).setdefault(
                    "ktrain", {})[f"{ratio}"] = tlog

    # -- stage 4+5: compress & export ------------------------------------------
    def _export_variant(self, model: str, vid: str, params, *, method: str,
                        ratio: float, kind: str, stored: int, bytes_: int,
                        ranks=None, heads_per_layer=None, shapes=None,
                        cm: P.CompressedModel | None = None,
                        kernel: str = "xla", extra=None):
        cfg = M.CONFIGS[model]
        tag = vid.replace("/", "_").replace(".", "")
        wpath = f"weights_{tag}.dobiw"
        names, nbytes = export_weights(os.path.join(self.out, wpath), params, cm)
        shapes = shapes or [(EVAL_BATCH, EVAL_SEQ)]
        hlos = {}
        _, arrays = M.flatten_for_export(params)
        aspecs = [spec_like(a) for a in arrays]
        for (b, s) in shapes:
            key = f"{b}x{s}"
            tspec = jax.ShapeDtypeStruct((b, s), np.int32)
            if cfg.img_dim:
                ispec = jax.ShapeDtypeStruct((b, cfg.img_dim), np.float32)
                fn = make_mm_export_fn(cfg, names, cfg.action_head, kernel)
                text = to_hlo_text(fn, tspec, ispec, *aspecs)
            else:
                fn = make_lm_export_fn(cfg, names, heads_per_layer, kernel)
                text = to_hlo_text(fn, tspec, *aspecs)
            hpath = f"fwd_{tag}_{key}.hlo.txt"
            with open(os.path.join(self.out, hpath), "w") as f:
                f.write(text)
            hlos[key] = hpath
        v = {
            "id": vid, "model": model, "method": method, "ratio": ratio,
            "kind": kind, "kernel": kernel, "weights": wpath,
            "param_names": names, "hlo": hlos,
            "inputs": ["tokens", "image"] if cfg.img_dim else ["tokens"],
            "stored_params": int(stored), "bytes": int(bytes_),
        }
        if ranks:
            v["ranks"] = {k: int(x) for k, x in ranks.items()}
        if heads_per_layer:
            v["heads_per_layer"] = heads_per_layer
        if extra:
            v.update(extra)
        self.manifest["variants"].append(v)
        log(f"  exported {vid}: {len(hlos)} hlo(s), weights {nbytes/1e6:.1f} MB")
        return v

    def compress_and_export(self):
        log("== compress & export ==")
        wiki_train = self.tokens["wiki-syn"]["train"]
        self.calib: dict[str, dict] = {}
        quick = self.profile_name == "quick"
        for model in self.prof["models"]:
            cfg = M.CONFIGS[model]
            params = self.params[model]
            total = M.count_params(params)
            calib = P.collect_calibration(params, cfg, wiki_train,
                                          n_batches=4 if quick else 8)
            self.calib[model] = calib
            dense_bytes = 2 * total
            main = model == "llama-nano"
            # dense baseline (+ speed sweeps + gen + pallas parity on main)
            shapes = [(EVAL_BATCH, EVAL_SEQ), GEN_SHAPE]
            if main and not quick:
                shapes += [s for s in SWEEP_SHAPES if s not in shapes]
            self._export_variant(model, f"{model}/dense", params, method="dense",
                                 ratio=1.0, kind="dense", stored=total,
                                 bytes_=dense_bytes, shapes=shapes)
            if main:
                self._export_variant(model, f"{model}/dense-pallas", params,
                                     method="dense", ratio=1.0, kind="dense",
                                     stored=total, bytes_=dense_bytes,
                                     kernel="pallas",
                                     shapes=[(EVAL_BATCH, EVAL_SEQ)])

            grads = P.calibration_grads(params, cfg, wiki_train) if main or model in (
                "llama2-nano", "llama3-nano", "llama-nano-l") else None

            for ratio in RATIOS:
                rtag = f"{int(ratio*100):02d}"
                ks = self.ks[(model, ratio)]
                # --- Dobi (full): trained k + IPCA + remap 8+16
                cm = P.dobi_compress(params, cfg, ks, calib, ratio=ratio,
                                     precision="8+16")
                self._export_variant(
                    model, f"{model}/dobi_{rtag}", cm.params, method="dobi",
                    ratio=ratio, kind="factorized", stored=cm.stored_params,
                    bytes_=cm.bytes_fp16_equiv, ranks=cm.ranks, cm=cm,
                    shapes=shapes if main else [(EVAL_BATCH, EVAL_SEQ), GEN_SHAPE])
                cached_v = cm.cached_v
                if main and ratio == 0.6:
                    self._export_variant(
                        model, f"{model}/dobi-pallas_{rtag}", cm.params,
                        method="dobi", ratio=ratio, kind="factorized",
                        stored=cm.stored_params, bytes_=cm.bytes_fp16_equiv,
                        kernel="pallas", shapes=[(EVAL_BATCH, EVAL_SEQ)])
                if main:
                    # remap-16 ablation (same ranks/graph, fp16 factors)
                    cm16 = P.dobi_compress(params, cfg, ks, calib, ratio=ratio,
                                           precision="16", cached_v=cached_v)
                    self._export_variant(
                        model, f"{model}/dobi16_{rtag}", cm16.params,
                        method="dobi-remap16", ratio=ratio, kind="factorized",
                        stored=cm16.stored_params, bytes_=cm16.bytes_fp16_equiv,
                        ranks=cm16.ranks)
                    # + PTQ combos (Tables 9/22/23)
                    for bits in (4, 8):
                        cmq = P.dobi_compress(params, cfg, ks, calib, ratio=ratio,
                                              precision="8+16", cached_v=cached_v,
                                              ptq_bits=bits)
                        self._export_variant(
                            model, f"{model}/dobi-int{bits}_{rtag}", cmq.params,
                            method=f"dobi+int{bits}", ratio=ratio,
                            kind="factorized", stored=cmq.stored_params,
                            bytes_=cmq.bytes_fp16_equiv, ranks=cmq.ranks)
                    # no-remap ablations (classic storage)
                    ks_c = P.scale_ks_to_classic(cfg, ks, ratio)
                    cmn = P.noremap_compress(params, cfg, ks_c, calib, ratio=ratio)
                    self._export_variant(
                        model, f"{model}/dobi-noremap_{rtag}", cmn.params,
                        method="dobi-noremap", ratio=ratio, kind="factorized",
                        stored=cmn.stored_params, bytes_=cmn.bytes_fp16_equiv,
                        ranks=cmn.ranks)
                    ks_u = T.uniform_ks(cfg, ratio)
                    ks_uc = P.scale_ks_to_classic(cfg, ks_u, ratio)
                    cmu = P.noremap_compress(params, cfg, ks_uc, calib, ratio=ratio)
                    self._export_variant(
                        model, f"{model}/uniform-noremap_{rtag}", cmu.params,
                        method="uniform-noremap", ratio=ratio, kind="factorized",
                        stored=cmu.stored_params, bytes_=cmu.bytes_fp16_equiv,
                        ranks=cmu.ranks)
                    # SVD-family baselines (classic uniform ranks)
                    for meth in ("weight_svd", "asvd", "svdllm"):
                        cb = P.svd_baseline_compress(params, cfg, ratio, meth, calib)
                        self._export_variant(
                            model, f"{model}/{meth}_{rtag}", cb.params,
                            method=meth, ratio=ratio, kind="factorized",
                            stored=cb.stored_params, bytes_=cb.bytes_fp16_equiv,
                            ranks=cb.ranks)
                # pruning baselines (all text models)
                if not cfg.img_dim:
                    for meth in ("wanda_sp", "flap", "llm_pruner"):
                        if meth == "llm_pruner" and grads is None:
                            continue
                        cb = P.pruning_compress(params, cfg, ratio, meth,
                                                calib_x=calib, grads=grads)
                        self._export_variant(
                            model, f"{model}/{meth}_{rtag}", cb.params,
                            method=meth, ratio=ratio, kind="pruned",
                            stored=cb.stored_params, bytes_=cb.bytes_fp16_equiv,
                            heads_per_layer=cb.heads_per_layer)
            # Table 17: rank perturbation around dobi-0.4 (main model only)
            if main:
                ks04 = self.ks[(model, 0.4)]
                base_cm = P.dobi_compress(params, cfg, ks04, calib, ratio=0.4)
                for x in ([2] if quick else [1, 2, 5, 24]):
                    ksp = P.perturb_ranks(ks04, x)
                    cmp_ = P.dobi_compress(params, cfg, ksp, calib, ratio=0.4,
                                           cached_v=base_cm.cached_v)
                    self._export_variant(
                        model, f"{model}/dobi-perturb{x}_40", cmp_.params,
                        method="dobi-perturb", ratio=0.4, kind="factorized",
                        stored=cmp_.stored_params, bytes_=cmp_.bytes_fp16_equiv,
                        ranks=cmp_.ranks, extra={"perturb_x": int(x)})

    # -- stage 6: python-side analyses -------------------------------------------
    def analyses(self):
        log("== analyses ==")
        model = "llama-nano"
        cfg = M.CONFIGS[model]
        params = self.params[model]
        wiki_eval = self.tokens["wiki-syn"]["eval"]
        quick = self.profile_name == "quick"

        # Table 1: activation vs weight truncation at identical positions.
        shapes_all = M.target_shapes(cfg)
        table1 = {}
        for ratio in [1.0] + RATIOS:
            if ratio == 1.0:
                base = P.eval_ppl(params, cfg, wiki_eval, n_windows=4)
                table1["1.0"] = {"activation": base, "weight": base}
                continue
            ks_u = T.uniform_ks(cfg, ratio)
            ks_uc = P.scale_ks_to_classic(cfg, ks_u, ratio)  # classic positions
            ppl_act = P.eval_activation_truncation_ppl(
                params, cfg, wiki_eval, ks_uc.astype(np.float32), n_windows=3)
            ppl_w = P.eval_weight_truncation_ppl(
                params, cfg, wiki_eval,
                {nm: int(k) for (nm, _, _), k in zip(shapes_all, ks_uc)},
                n_windows=4)
            table1[str(ratio)] = {"activation": ppl_act, "weight": ppl_w}
            log(f"  table1 r={ratio}: act {ppl_act:.2f} vs weight {ppl_w:.2f}")
        self.manifest["analysis"]["table1"] = table1

        # Fig 11: per-layer act-vs-weight truncation loss.
        fig11 = []
        layers = [0, cfg.n_layers // 2, cfg.n_layers - 1]
        kvals = [48, 96, 160] if not quick else [96]
        for li in layers:
            tnames = [f"layers.{li}.{mn}" for mn in M.LAYER_MATS]
            for k in kvals:
                ks_vec = np.full(len(tnames), k, np.float32)
                ppl_a = P.eval_activation_truncation_ppl(
                    params, cfg, wiki_eval, ks_vec, n_windows=2, targets=tnames)
                ppl_w = P.eval_weight_truncation_ppl(
                    params, cfg, wiki_eval, {nm: k for nm in tnames}, n_windows=2)
                fig11.append({"layer": li, "k": k, "activation": ppl_a,
                              "weight": ppl_w})
        self.manifest["analysis"]["fig11"] = fig11

        # Fig 3a: guided truncation — single vs multi-layer k-training.
        if not quick:
            wiki_train = self.tokens["wiki-syn"]["train"]
            last = cfg.n_layers - 1
            single = [f"layers.{last}.{mn}" for mn in M.LAYER_MATS]
            multi = [f"layers.{li}.{mn}" for li in (last - 1, last)
                     for mn in M.LAYER_MATS]
            fig3a = {}
            for tag, tgts in (("single", single), ("multi", multi)):
                _, tlog = T.train_ks(params, cfg, wiki_train, ratio=0.85,
                                     steps=24, targets=tgts, log=log,
                                     val_tokens=wiki_eval, val_every=4)
                fig3a[tag] = {"val_ppl": tlog.val_ppl_history,
                              "task_loss": tlog.task_loss_history}
            fig3a["dense_ppl"] = table1["1.0"]["activation"]
            self.manifest["analysis"]["fig3a"] = fig3a

            # Fig 3b: large vs small training batch.
            fig3b = {}
            for tag, bsz in (("batch8", 8), ("batch2", 2)):
                ks_b, tlog = T.train_ks(params, cfg, wiki_train, ratio=0.6,
                                        steps=24, batch=bsz,
                                        seq=max(72, 256 // bsz), log=log,
                                        val_tokens=wiki_eval, val_every=6)
                fig3b[tag] = {"val_ppl": tlog.val_ppl_history,
                              "loss": tlog.loss_history}
            self.manifest["analysis"]["fig3b"] = fig3b

        # Fig 3c: PCA vs IPCA memory (analytic model + a measured point).
        dims = [192, 512, 1024, 2048, 4096]
        fig3c = {"dims": dims,
                 "pca_bytes": [pca_memory_bytes(n, n // 4, 8) for n in dims],
                 "ipca_bytes": [ipca_memory_bytes(n, n // 4) for n in dims]}
        # measured agreement between IPCA and full PCA on a real target
        name0 = "layers.0.w_gate"
        w0 = np.asarray(M.get_target(params, name0), np.float64)
        xs = self.calib[model][name0][:6]
        bases, weights = [], []
        k0 = 48
        tr = IncrementalPCA(w0.shape[1], k0)
        for x in xs:
            v_i, s_i = batch_right_basis(x.astype(np.float64) @ w0, k0)
            bases.append(v_i)
            weights.append(s_i)
            tr.partial_fit(v_i, s_i)
        v_full = full_pca_components(bases, weights, k0)
        fig3c["subspace_distance"] = subspace_distance(tr.components(), v_full)
        fig3c["ipca_peak_bytes_measured"] = tr.peak_bytes
        fig3c["pca_stack_bytes_measured"] = int(
            sum(b.nbytes for b in bases))
        self.manifest["analysis"]["fig3c"] = fig3c

        # Table 15: quantization error per matrix kind at dobi-0.6 factors.
        ks06 = self.ks[(model, 0.6)]
        table15 = {}
        for (nm, m, n), k in zip(shapes_all, ks06):
            if not nm.startswith("layers.1."):
                continue
            w = np.asarray(M.get_target(params, nm), np.float64)
            a = np.concatenate([x for x in self.calib[model][nm][:4]], axis=0)
            from .dobi.ipca import ipca_weight_update
            w_new = ipca_weight_update(w, [a.astype(np.float64) @ w], int(k))
            f1, f2 = R.factorize(w_new, int(k))
            mse1, mae1 = R.quant_error(f1)
            mse2, mae2 = R.quant_error(f2)
            table15[nm.split(".")[-1]] = {"mse": 0.5 * (mse1 + mse2),
                                          "mae": 0.5 * (mae1 + mae2)}
        self.manifest["analysis"]["table15"] = table15

        # gradstab: stable vs naive SVD backward on a near-degenerate batch.
        x0 = self.calib[model]["layers.0.wq"][0][:128]
        a0 = np.asarray(x0, np.float64) @ np.asarray(
            M.get_target(params, "layers.0.wq"), np.float64)
        a0[1] = a0[0]  # force exact degeneracy
        a0 = jnp.asarray(a0.astype(np.float32))

        def gnorm(f):
            g = jax.grad(lambda a: jnp.sum(f(a)[0][:, 0]) + jnp.sum(f(a)[2][0]))(a0)
            return float(jnp.linalg.norm(g)), bool(jnp.all(jnp.isfinite(g)))

        ns, fs = gnorm(svd)
        nu, fu = gnorm(svd_unstable)
        self.manifest["analysis"]["gradstab"] = {
            "stable_norm": ns, "stable_finite": fs,
            "naive_norm": nu, "naive_finite": fu}

    # -- stage 7: reference PPLs ---------------------------------------------------
    def reference_ppls(self):
        log("== reference PPLs (python side) ==")
        for v in self.manifest["variants"]:
            if v["kernel"] == "pallas" or v["model"] not in ("llama-nano",):
                continue
            cfg = M.CONFIGS[v["model"]]
            if cfg.img_dim:
                continue
            weights = IO.read_dobiw(os.path.join(self.out, v["weights"]))
            arrays = _arrays_from_store(weights, v["param_names"])
            params = M.unflatten_from_export(cfg, v["param_names"],
                                             [jnp.asarray(a) for a in arrays])
            hpl = v.get("heads_per_layer")
            ref = {}
            for cname in self.manifest["corpora"]:
                wins = self.tokens[cname]["eval_wins"]
                f = jax.jit(lambda t: M.lm_loss(
                    M.forward_pruned(params, t, cfg, hpl) if hpl
                    else M.forward_dense(params, t, cfg), t))
                tot = sum(float(f(jnp.asarray(w.astype(np.int32)))) for w in wins)
                ref[cname] = float(np.exp(tot / len(wins)))
            v["ref_ppl"] = ref
            log(f"  {v['id']}: wiki {ref['wiki-syn']:.2f} ptb {ref['ptb-syn']:.2f} "
                f"c4 {ref['c4-syn']:.2f}")

    def finish(self):
        def sanitize(x):
            """Strict JSON: NaN/Inf are not valid tokens — encode as null."""
            if isinstance(x, float) and not np.isfinite(x):
                return None
            if isinstance(x, dict):
                return {k: sanitize(v) for k, v in x.items()}
            if isinstance(x, (list, tuple)):
                return [sanitize(v) for v in x]
            return x

        with open(os.path.join(self.out, "manifest.json"), "w") as f:
            json.dump(sanitize(self.manifest), f, indent=1, allow_nan=False)
        log(f"manifest: {len(self.manifest['variants'])} variants")


def _arrays_from_store(store: dict[str, np.ndarray], names: list[str]):
    """Reassemble HLO-parameter arrays from a .dobiw store (mirrors the
    rust loader: dequantize q8+scales pairs, upcast f16)."""
    out = []
    for n in names:
        if n in store:
            out.append(store[n].astype(np.float32))
        else:
            q = store[n + ".q8"]
            s = store[n + ".scales"]
            out.append(q.astype(np.float32) * s)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--profile", default="full", choices=list(PROFILES))
    args = ap.parse_args()
    t0 = time.time()
    b = Builder(args.out, args.profile)
    b.build_corpora()
    b.pretrain_all()
    b.ktrain_all()
    b.compress_and_export()
    b.analyses()
    b.reference_ppls()
    b.finish()
    log(f"aot done in {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
