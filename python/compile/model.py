"""LLaMA-architecture substrate models (L2), dense and factorized.

Pure-JAX (params are nested dicts of jnp arrays) so the same forward
lowers to HLO text for the rust runtime.  Architecture mirrors LLaMA:
RMSNorm, rotary position embeddings, SwiGLU MLP, tied LM head — giving
each layer the paper's seven compression targets
(wq wk wv wo / w_gate w_up w_down).

Three forwards:
* `forward_dense`       — the uncompressed baseline.
* `forward_factorized`  — every compressed matrix applied as
                          (x @ W1) @ W2; `kernel="pallas"` routes the
                          GEMMs through the L1 Pallas kernels so the AOT
                          HLO genuinely contains the kernel lowering,
                          `kernel="xla"` uses jnp.dot (the CPU speed lane
                          — see DESIGN.md §4).
* `forward_pruned`      — structurally slimmed dense weights (per-layer
                          head counts / d_ff) for the pruning baselines.

Also: VLM variant (projected feature prefix) and VLA variant (action
head), both wrapping the same trunk — Tables 11-13.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.factorized_matmul import factorized_matmul
from .kernels.matmul import matmul as pallas_matmul

# The seven per-layer compression targets, in manifest order.
LAYER_MATS = ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down")


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int = 256
    d_model: int = 192
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 512
    max_seq: int = 128
    rope_theta: float = 10000.0
    # multimodal extensions
    img_dim: int = 0          # >0 -> VLM/VLA projector input dim
    n_img_tokens: int = 0     # prefix length after projection
    action_head: bool = False  # VLA: predict (x,y,z,angle,gripper)

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


# The model zoo. Sizes are chosen so the whole evaluation grid builds on
# one CPU core; shapes keep LLaMA's m:n aspect ratios so the remapping
# math (max(m,n) vs m+n) exercises the same regimes as 7B.
CONFIGS: dict[str, ModelConfig] = {
    "llama-nano": ModelConfig("llama-nano", d_model=192, n_layers=4, n_heads=4, d_ff=512),
    "llama2-nano": ModelConfig("llama2-nano", d_model=192, n_layers=4, n_heads=6, d_ff=560),
    "llama3-nano": ModelConfig("llama3-nano", d_model=160, n_layers=5, n_heads=5, d_ff=448),
    "llama-nano-l": ModelConfig("llama-nano-l", d_model=256, n_layers=6, n_heads=8, d_ff=704),
    "vlm-nano": ModelConfig("vlm-nano", d_model=192, n_layers=4, n_heads=4, d_ff=512,
                            img_dim=64, n_img_tokens=8),
    "vla-nano": ModelConfig("vla-nano", d_model=192, n_layers=4, n_heads=4, d_ff=512,
                            img_dim=64, n_img_tokens=8, action_head=True),
}


# ---------------------------------------------------------------------------
# Parameter init / bookkeeping
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, seed: int = 0) -> dict:
    """He-ish init matching small-transformer practice; deterministic."""
    rng = np.random.default_rng(seed)
    d, f = cfg.d_model, cfg.d_ff

    def mat(m, n, scale=None):
        s = scale if scale is not None else 1.0 / np.sqrt(m)
        return jnp.asarray(rng.standard_normal((m, n)).astype(np.float32) * s)

    params = {
        "embed": mat(cfg.vocab, d, scale=0.02),
        "final_norm": jnp.ones((d,), jnp.float32),
        "layers": [],
    }
    for _ in range(cfg.n_layers):
        params["layers"].append({
            "attn_norm": jnp.ones((d,), jnp.float32),
            "mlp_norm": jnp.ones((d,), jnp.float32),
            "wq": mat(d, d), "wk": mat(d, d), "wv": mat(d, d),
            "wo": mat(d, d, scale=1.0 / np.sqrt(d) / np.sqrt(2 * cfg.n_layers)),
            "w_gate": mat(d, f), "w_up": mat(d, f),
            "w_down": mat(f, d, scale=1.0 / np.sqrt(f) / np.sqrt(2 * cfg.n_layers)),
        })
    if cfg.img_dim:
        params["img_proj"] = mat(cfg.img_dim, cfg.n_img_tokens * d, scale=0.05)
    if cfg.action_head:
        params["act_head"] = mat(d, 5, scale=0.02)  # x,y,z,angle,gripper-logit
    return params


def target_shapes(cfg: ModelConfig) -> list[tuple[str, int, int]]:
    """(name, m, n) of every compression target, manifest order."""
    d, f = cfg.d_model, cfg.d_ff
    dims = {"wq": (d, d), "wk": (d, d), "wv": (d, d), "wo": (d, d),
            "w_gate": (d, f), "w_up": (d, f), "w_down": (f, d)}
    out = []
    for li in range(cfg.n_layers):
        for mn in LAYER_MATS:
            m, n = dims[mn]
            out.append((f"layers.{li}.{mn}", m, n))
    return out


def count_params(params) -> int:
    leaves = jax.tree_util.tree_leaves(params)
    return int(sum(np.prod(l.shape) for l in leaves))


def fixed_param_count(cfg: ModelConfig) -> int:
    """Parameters never touched by compression (embed, norms, heads)."""
    total = count_params(init_params(cfg, seed=0))
    comp = sum(m * n for _, m, n in target_shapes(cfg))
    return total - comp


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def rmsnorm(x: jnp.ndarray, g: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * g


def _rope_cache(seq: int, d_head: int, theta: float):
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    inv = theta ** (-jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head)[None, :]
    ang = pos * inv  # (S, d_head/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (B, H, S, d_head), LLaMA's interleaved pairing."""
    x1, x2 = x[..., 0::2], x[..., 1::2]
    c = cos[None, None]
    s = sin[None, None]
    out = jnp.stack([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)
    return out.reshape(x.shape)


def _apply_w(x2d: jnp.ndarray, w, kernel: str) -> jnp.ndarray:
    """Apply a (possibly factorized) weight to flattened tokens.

    `w` is either a dense (m,n) array or a tuple (W1, W2) of rank-k
    factors.  kernel="pallas" uses the L1 kernels, "xla" plain dots.
    """
    if isinstance(w, tuple):
        w1, w2 = w
        if kernel == "pallas":
            return factorized_matmul(x2d, w1, w2)
        return jnp.dot(x2d @ w1, w2)
    if kernel == "pallas":
        return pallas_matmul(x2d, w)
    return jnp.dot(x2d, w)


def attention(x: jnp.ndarray, layer: dict, cfg: ModelConfig, n_heads: int,
              cos, sin, kernel: str) -> jnp.ndarray:
    b, s, d = x.shape
    d_head = cfg.d_model // cfg.n_heads  # head width fixed; pruning drops heads
    x2 = x.reshape(b * s, d)
    q = _apply_w(x2, layer["wq"], kernel).reshape(b, s, n_heads, d_head).transpose(0, 2, 1, 3)
    k = _apply_w(x2, layer["wk"], kernel).reshape(b, s, n_heads, d_head).transpose(0, 2, 1, 3)
    v = _apply_w(x2, layer["wv"], kernel).reshape(b, s, n_heads, d_head).transpose(0, 2, 1, 3)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / np.sqrt(d_head)
    mask = jnp.tril(jnp.ones((s, s), bool))
    att = jnp.where(mask[None, None], att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", att, v).transpose(0, 2, 1, 3).reshape(b * s, n_heads * d_head)
    return _apply_w(o, layer["wo"], kernel).reshape(b, s, d)


def mlp(x: jnp.ndarray, layer: dict, kernel: str) -> jnp.ndarray:
    b, s, d = x.shape
    x2 = x.reshape(b * s, d)
    g = _apply_w(x2, layer["w_gate"], kernel)
    u = _apply_w(x2, layer["w_up"], kernel)
    h = jax.nn.silu(g) * u
    return _apply_w(h, layer["w_down"], kernel).reshape(b, s, d)


def _norm(h: jnp.ndarray, g: jnp.ndarray, kernel: str) -> jnp.ndarray:
    """RMSNorm, routed through the L1 Pallas kernel in the pallas flavor."""
    if kernel == "pallas":
        from .kernels.rmsnorm import rmsnorm as pallas_rmsnorm
        b, s, d = h.shape
        return pallas_rmsnorm(h.reshape(b * s, d), g).reshape(b, s, d)
    return rmsnorm(h, g)


def _trunk(h: jnp.ndarray, params: dict, cfg: ModelConfig, kernel: str,
           heads_per_layer: list[int] | None = None) -> jnp.ndarray:
    s = h.shape[1]
    cos, sin = _rope_cache(s, cfg.d_head, cfg.rope_theta)
    for li, layer in enumerate(params["layers"]):
        nh = heads_per_layer[li] if heads_per_layer else cfg.n_heads
        h = h + attention(_norm(h, layer["attn_norm"], kernel), layer, cfg, nh, cos, sin, kernel)
        h = h + mlp(_norm(h, layer["mlp_norm"], kernel), layer, kernel)
    return _norm(h, params["final_norm"], kernel)


def _logits(h: jnp.ndarray, params: dict) -> jnp.ndarray:
    return jnp.dot(h, params["embed"].T)  # tied head (never compressed)


# ---------------------------------------------------------------------------
# Public forwards
# ---------------------------------------------------------------------------

def forward_dense(params: dict, tokens: jnp.ndarray, cfg: ModelConfig,
                  kernel: str = "xla") -> jnp.ndarray:
    """tokens (B,S) int32 -> logits (B,S,V)."""
    h = params["embed"][tokens]
    return _logits(_trunk(h, params, cfg, kernel), params)


def forward_factorized(params: dict, tokens: jnp.ndarray, cfg: ModelConfig,
                       kernel: str = "xla") -> jnp.ndarray:
    """Same as dense; compressed weights in `params` are (W1, W2) tuples."""
    return forward_dense(params, tokens, cfg, kernel)


def forward_pruned(params: dict, tokens: jnp.ndarray, cfg: ModelConfig,
                   heads_per_layer: list[int]) -> jnp.ndarray:
    h = params["embed"][tokens]
    return _logits(_trunk(h, params, cfg, "xla", heads_per_layer), params)


def forward_vlm(params: dict, tokens: jnp.ndarray, image: jnp.ndarray,
                cfg: ModelConfig, kernel: str = "xla") -> jnp.ndarray:
    """image (B, img_dim) -> n_img_tokens prefix embeddings, then LM."""
    b = tokens.shape[0]
    prefix = jnp.dot(image, params["img_proj"]).reshape(b, cfg.n_img_tokens, cfg.d_model)
    h = jnp.concatenate([prefix, params["embed"][tokens]], axis=1)
    h = _trunk(h, params, cfg, kernel)
    return _logits(h[:, cfg.n_img_tokens:], params)


def forward_vla(params: dict, tokens: jnp.ndarray, image: jnp.ndarray,
                cfg: ModelConfig, kernel: str = "xla") -> jnp.ndarray:
    """-> (B, 5) action: xyz coords, angle, gripper logit."""
    b = tokens.shape[0]
    prefix = jnp.dot(image, params["img_proj"]).reshape(b, cfg.n_img_tokens, cfg.d_model)
    h = jnp.concatenate([prefix, params["embed"][tokens]], axis=1)
    h = _trunk(h, params, cfg, kernel)
    last = h[:, -1]
    out = jnp.dot(last, params["act_head"])
    coords = jnp.tanh(out[:, :3])
    angle = jnp.tanh(out[:, 3:4])
    grip = out[:, 4:5]
    return jnp.concatenate([coords, angle, grip], axis=1)


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def lm_loss(logits: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    """Next-token cross-entropy; logits (B,S,V), tokens (B,S)."""
    lp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def vla_loss(pred: jnp.ndarray, coords: jnp.ndarray, angle: jnp.ndarray,
             grip: jnp.ndarray) -> jnp.ndarray:
    mse = jnp.mean((pred[:, :3] - coords) ** 2) + jnp.mean((pred[:, 3] - angle) ** 2)
    bce = jnp.mean(jnp.maximum(pred[:, 4], 0) - pred[:, 4] * grip
                   + jnp.log1p(jnp.exp(-jnp.abs(pred[:, 4]))))
    return mse + bce


# ---------------------------------------------------------------------------
# Param plumbing shared with the pipeline / AOT
# ---------------------------------------------------------------------------

def get_target(params: dict, name: str):
    """name like 'layers.2.w_up' -> array (or factor tuple)."""
    _, li, mn = name.split(".")
    return params["layers"][int(li)][mn]


def set_target(params: dict, name: str, value) -> dict:
    """Functional update returning a new params dict."""
    _, li, mn = name.split(".")
    li = int(li)
    layers = list(params["layers"])
    layers[li] = {**layers[li], mn: value}
    return {**params, "layers": layers}


def flatten_for_export(params: dict) -> tuple[list[str], list[jnp.ndarray]]:
    """Deterministic (names, arrays) ordering shared with the manifest and
    the rust loader.  Factor tuples expand to `<name>.w1` / `<name>.w2`."""
    names, arrays = [], []

    def add(name, v):
        if isinstance(v, tuple):
            add(name + ".w1", v[0])
            add(name + ".w2", v[1])
        else:
            names.append(name)
            arrays.append(jnp.asarray(v))

    add("embed", params["embed"])
    for li, layer in enumerate(params["layers"]):
        for key in ("attn_norm", "mlp_norm") + LAYER_MATS:
            add(f"layers.{li}.{key}", layer[key])
    add("final_norm", params["final_norm"])
    if "img_proj" in params:
        add("img_proj", params["img_proj"])
    if "act_head" in params:
        add("act_head", params["act_head"])
    return names, arrays


def unflatten_from_export(cfg: ModelConfig, names: list[str],
                          arrays: list[jnp.ndarray]) -> dict:
    """Inverse of flatten_for_export (used by tests and the trainer)."""
    by = dict(zip(names, arrays))
    layers = []
    for li in range(cfg.n_layers):
        layer = {}
        for key in ("attn_norm", "mlp_norm") + LAYER_MATS:
            base = f"layers.{li}.{key}"
            if base in by:
                layer[key] = by[base]
            else:
                layer[key] = (by[base + ".w1"], by[base + ".w2"])
        layers.append(layer)
    params = {"embed": by["embed"], "final_norm": by["final_norm"], "layers": layers}
    if "img_proj" in by:
        params["img_proj"] = by["img_proj"]
    if "act_head" in by:
        params["act_head"] = by["act_head"]
    return params
