"""Pallas kernel for the paper's smooth truncation gate T(sigma).

T(sigma_i) = sigma_i * (0.5*tanh(beta*(k - i)) + 0.5)        (Algo 1)

This is the training-graph hot spot applied to every activation's singular
value vector each step.  It is a pure VPU (elementwise) kernel — no MXU —
so the block layout is a flat 1D tile.  The *differentiable-k trainer*
uses the jnp reference (pallas_call has no registered VJP); this kernel is
the inference/export twin and is pinned to the reference by tests.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _smooth_truncate_kernel(sigma_ref, k_ref, o_ref, *, beta: float, block: int):
    pid = pl.program_id(0)
    base = pid * block
    i = base + jax.lax.iota(jnp.float32, block) + 1.0  # 1-based index
    gate = 0.5 * jnp.tanh(beta * (k_ref[0] - i)) + 0.5
    o_ref[...] = sigma_ref[...] * gate


def smooth_truncate(sigma: jnp.ndarray, k: jnp.ndarray, beta: float = 10.0,
                    *, block: int = 128) -> jnp.ndarray:
    """Apply the tanh truncation gate to a 1-D singular-value vector."""
    assert sigma.ndim == 1
    n = sigma.shape[0]
    block = min(block, n)
    pad = (-n) % block
    sp = jnp.pad(sigma, (0, pad))
    karr = jnp.asarray(k, dtype=jnp.float32).reshape(1)
    grid = (sp.shape[0] // block,)
    out = pl.pallas_call(
        functools.partial(_smooth_truncate_kernel, beta=beta, block=block),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(sp.shape, sigma.dtype),
        interpret=True,
    )(sp, karr)
    return out[:n]
