"""Pure-jnp oracles for every Pallas kernel.

These are the correctness ground truth: `python/tests/test_kernels.py`
sweeps shapes/dtypes with hypothesis and asserts the Pallas kernels
(interpret=True) match these to tight tolerances.
"""

from __future__ import annotations

import jax.numpy as jnp


def matmul_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Plain GEMM oracle: (M,K) @ (K,N) -> (M,N), accumulate in f32."""
    return jnp.matmul(x.astype(jnp.float32), w.astype(jnp.float32)).astype(x.dtype)


def factorized_matmul_ref(x: jnp.ndarray, w1: jnp.ndarray, w2: jnp.ndarray) -> jnp.ndarray:
    """Rank-k factorized linear: (M,m) @ (m,k) @ (k,n)."""
    return matmul_ref(matmul_ref(x, w1), w2)


def dequant_matmul_ref(x: jnp.ndarray, wq: jnp.ndarray, scales: jnp.ndarray) -> jnp.ndarray:
    """int8 weight, per-output-column absmax scales: y = x @ (wq * scales).

    wq: (K, N) int8, scales: (N,) f32.
    """
    w = wq.astype(jnp.float32) * scales[None, :].astype(jnp.float32)
    return jnp.matmul(x.astype(jnp.float32), w).astype(x.dtype)


def smooth_truncate_ref(sigma: jnp.ndarray, k: jnp.ndarray, beta: float) -> jnp.ndarray:
    """Paper Algo 1: T(sigma_i) = sigma_i * (0.5*tanh(beta*(k-i)) + 0.5).

    Index i is 1-based in the paper; we use i = 1..n so that k == n keeps
    (almost) everything and k == 0 kills (almost) everything.
    """
    n = sigma.shape[-1]
    i = jnp.arange(1, n + 1, dtype=sigma.dtype)
    gate = 0.5 * jnp.tanh(beta * (k - i)) + 0.5
    return sigma * gate
