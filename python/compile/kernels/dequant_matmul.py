"""Fused int8-dequant + GEMM — the remapped-storage hot path.

The remapping of §3.3 stores SVD factors as int8 with per-column absmax
scales (the factors' columns are near-Gaussian — paper Fig 5/6 — so
absmax int8 loses ~1e-7 MSE, Table 15).  Serving directly from that
storage means every matmul first needs w = wq * scale; fusing the
dequantize into the GEMM k-loop keeps the int8 block in VMEM and never
materializes the fp32 weight in HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .matmul import _pad_to, _pick_block


def _dequant_matmul_kernel(x_ref, wq_ref, s_ref, o_ref, acc_ref, *, n_kblocks: int):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = wq_ref[...].astype(jnp.float32) * s_ref[...].astype(jnp.float32)
    acc_ref[...] += jnp.dot(x_ref[...], w, preferred_element_type=jnp.float32)

    @pl.when(kb == n_kblocks - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def dequant_matmul(x: jnp.ndarray, wq: jnp.ndarray, scales: jnp.ndarray,
                   *, bm: int = 128, bn: int = 128, bk: int = 128) -> jnp.ndarray:
    """(M,K) f32 @ dequant((K,N) int8, (N,) f32 scales) -> (M,N) f32."""
    assert x.shape[1] == wq.shape[0] and wq.shape[1] == scales.shape[0]
    M, K = x.shape
    N = wq.shape[1]
    bm = _pick_block(M, bm)
    bn = _pick_block(N, bn)
    bk = _pick_block(K, bk)
    xp = _pad_to(x, bm, bk)
    wqp = _pad_to(wq, bk, bn)
    sp = jnp.pad(scales, (0, (-N) % bn)).reshape(1, -1)
    Mp, Kp = xp.shape
    Np = wqp.shape[1]
    grid = (Mp // bm, Np // bn, Kp // bk)
    out = pl.pallas_call(
        functools.partial(_dequant_matmul_kernel, n_kblocks=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kb: (i, kb)),
            pl.BlockSpec((bk, bn), lambda i, j, kb: (kb, j)),
            pl.BlockSpec((1, bn), lambda i, j, kb: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kb: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=True,
    )(xp, wqp, sp)
    return out[:M, :N]
