"""The compressed-inference hot path: y = (x @ W1) @ W2 at rank k.

After Dobi-SVD, every compressed weight is stored as the pair
(W1 = U_k, W2 = Sigma_k V_k^T-ish factors, shapes (m,k) and (k,n)), and
every forward through that layer is exactly two skinny GEMMs.  The rank-k
inner dimension is kept contiguous so both GEMMs stream the intermediate
through the same VMEM residency (the paper's FLOP saving is
k(m+n) vs m*n multiply-adds per row).

This composes the tiled Pallas `matmul` twice.  A fused single-kernel
variant (recompute-free for one N-block) is intentionally NOT used: at the
ranks the paper reaches (k << min(m,n)) the intermediate (bm, k) tile fits
VMEM alongside both operand tiles, so two passes with a resident
intermediate is the better schedule on the systolic array — see DESIGN.md
§Hardware-Adaptation.
"""

from __future__ import annotations

import jax.numpy as jnp

from .matmul import matmul


def factorized_matmul(x: jnp.ndarray, w1: jnp.ndarray, w2: jnp.ndarray,
                      *, bm: int = 128, bn: int = 128, bk: int = 128) -> jnp.ndarray:
    """(M,m) @ (m,k) @ (k,n) -> (M,n) via two tiled Pallas GEMMs."""
    assert w1.shape[1] == w2.shape[0], f"rank mismatch {w1.shape} vs {w2.shape}"
    t = matmul(x, w1, bm=bm, bn=bn, bk=bk)
    return matmul(t, w2, bm=bm, bn=bn, bk=bk)


def flops(m_rows: int, m: int, n: int, k: int) -> int:
    """Multiply-add count for one factorized apply (rows = tokens)."""
    return 2 * m_rows * k * (m + n)


def dense_flops(m_rows: int, m: int, n: int) -> int:
    return 2 * m_rows * m * n
