"""Pallas RMSNorm — the per-token normalization on the serve path.

Pure VPU kernel: each program normalizes a block of rows held in VMEM.
Exists so the `kernel="pallas"` forward flavor keeps the whole layer body
(norm -> GEMMs -> norm -> GEMMs) inside L1 kernels; pinned to the jnp
reference by tests like every other kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, g_ref, o_ref, *, eps: float):
    x = x_ref[...]
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = x * jax.lax.rsqrt(ms + eps) * g_ref[...]


def rmsnorm(x: jnp.ndarray, g: jnp.ndarray, eps: float = 1e-5,
            *, block_rows: int = 128) -> jnp.ndarray:
    """x: (rows, d), g: (d,) -> normalized (rows, d)."""
    assert x.ndim == 2 and g.shape == (x.shape[1],)
    rows, d = x.shape
    br = min(block_rows, rows)
    pad = (-rows) % br
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    grid = (xp.shape[0] // br,)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
        interpret=True,
    )(xp, g)
    return out[:rows]


def rmsnorm_ref(x: jnp.ndarray, g: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * g
