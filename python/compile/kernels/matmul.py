"""Tiled Pallas GEMM — the building block for the factorized hot path.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the grid walks
(M, N, K) blocks; each program streams one K-block of `x` and `w` through
VMEM and accumulates into a VMEM scratch block aimed at the MXU
(128-aligned block shapes where the problem allows).  On the paper's CUDA
target this schedule is the threadblock tiling of cuBLAS; BlockSpec
expresses the same HBM->scratchpad plan for the systolic array.

Interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls, so interpret mode is both the correctness path and what the
AOT pipeline lowers into the serve-path HLO.

Arbitrary ranks/dims are handled at the wrapper: inputs are zero-padded to
block multiples (exact for matmul) and the result sliced back.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _pick_block(dim: int, target: int) -> int:
    """Largest MXU-friendly block <= target that divides `dim`, else `dim`.

    Padding in the wrapper guarantees divisibility for any choice; this
    just avoids gross overpadding for small dims.
    """
    if dim <= target:
        return dim
    for b in (target, 128, 64, 32, 16, 8):
        if b <= target and dim % b == 0:
            return b
    return min(dim, target)


def _pad_to(x: jnp.ndarray, m0: int, m1: int) -> jnp.ndarray:
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 == 0 and p1 == 0:
        return x
    return jnp.pad(x, ((0, p0), (0, p1)))


def _matmul_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_kblocks: int):
    """Grid = (M/bm, N/bn, K/bk); accumulate over the K axis in VMEM scratch."""
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(kb == n_kblocks - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul(x: jnp.ndarray, w: jnp.ndarray, *, bm: int = 128, bn: int = 128,
           bk: int = 128) -> jnp.ndarray:
    """(M,K) @ (K,N) -> (M,N) via the tiled Pallas kernel, f32 accumulate."""
    assert x.ndim == 2 and w.ndim == 2 and x.shape[1] == w.shape[0], (
        f"shape mismatch {x.shape} @ {w.shape}")
    M, K = x.shape
    N = w.shape[1]
    bm = _pick_block(M, bm)
    bn = _pick_block(N, bn)
    bk = _pick_block(K, bk)
    xp = _pad_to(x, bm, bk)
    wp = _pad_to(w, bk, bn)
    Mp, Kp = xp.shape
    Np = wp.shape[1]
    grid = (Mp // bm, Np // bn, Kp // bk)
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, n_kblocks=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kb: (i, kb)),
            pl.BlockSpec((bk, bn), lambda i, j, kb: (kb, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kb: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=True,
    )(xp, wp)
    return out[:M, :N]


def vmem_bytes(bm: int, bn: int, bk: int, dtype_bytes: int = 4) -> int:
    """Analytic VMEM residency of one program: x-block + w-block + acc.

    Used by the §Perf roofline estimate in EXPERIMENTS.md (interpret-mode
    wallclock is not a TPU proxy; footprint/utilization are estimated
    structurally).
    """
    return (bm * bk + bk * bn) * dtype_bytes + bm * bn * 4


def mxu_utilization_estimate(m: int, n: int, k: int, bm: int, bn: int, bk: int) -> float:
    """Fraction of MXU-issued FLOPs that are useful (non-padding)."""
    import math
    mp = math.ceil(m / bm) * bm
    np_ = math.ceil(n / bn) * bn
    kp = math.ceil(k / bk) * bk
    return (m * n * k) / float(mp * np_ * kp)
