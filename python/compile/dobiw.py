"""`.dobiw` — the weight container shared with rust (rust/src/storage).

Layout (little-endian):
  magic   b"DOBIW1"
  u32     n_tensors
  per tensor:
    u16   name_len, name bytes (utf-8)
    u8    dtype  (0 = f32, 1 = f16, 2 = i8, 3 = i32)
    u8    ndim
    u32 * ndim  shape
    u64   payload byte length
    payload
    u32   crc32(payload)

For remapped storage the int8 code tensors and their f32 scale tensors are
separate entries (`<name>.q8` / `<name>.scales`); the rust reader
dequantizes at load.  Plain f32/f16 tensors round-trip as-is.
"""

from __future__ import annotations

import struct
import zlib

import numpy as np

MAGIC = b"DOBIW1"
DTYPES = {0: np.float32, 1: np.float16, 2: np.int8, 3: np.int32}
DTYPE_CODES = {np.dtype(np.float32): 0, np.dtype(np.float16): 1,
               np.dtype(np.int8): 2, np.dtype(np.int32): 3}


def write_dobiw(path: str, tensors: list[tuple[str, np.ndarray]]) -> int:
    """Write tensors in order; returns total bytes written."""
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors:
            arr = np.ascontiguousarray(arr)
            code = DTYPE_CODES[arr.dtype]
            nb = name.encode("utf-8")
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", code, arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            payload = arr.tobytes()
            f.write(struct.pack("<Q", len(payload)))
            f.write(payload)
            f.write(struct.pack("<I", zlib.crc32(payload) & 0xFFFFFFFF))
        return f.tell()


def read_dobiw(path: str) -> dict[str, np.ndarray]:
    out: dict[str, np.ndarray] = {}
    with open(path, "rb") as f:
        assert f.read(6) == MAGIC, f"bad magic in {path}"
        (n,) = struct.unpack("<I", f.read(4))
        for _ in range(n):
            (nl,) = struct.unpack("<H", f.read(2))
            name = f.read(nl).decode("utf-8")
            code, ndim = struct.unpack("<BB", f.read(2))
            shape = tuple(struct.unpack("<I", f.read(4))[0] for _ in range(ndim))
            (plen,) = struct.unpack("<Q", f.read(8))
            payload = f.read(plen)
            (crc,) = struct.unpack("<I", f.read(4))
            assert zlib.crc32(payload) & 0xFFFFFFFF == crc, f"crc mismatch: {name}"
            out[name] = np.frombuffer(payload, dtype=DTYPES[code]).reshape(shape).copy()
    return out
