"""Remapped mixed-precision storage (paper §3.3, A.5, Algo 3) + the plain
PTQ quantizer used for the GPTQ/BnB-composition tables.

Given the updated rank-k matrix W~ (m x n, m >= n wlog):
  SVD(W~) -> U_k = (U Sigma)[:, :k]  (m x k),  V_k = V[:, :k]  (n x k).
Classic storage keeps both -> k(m+n) numbers.  Algo 3 instead quantizes
the first n rows of U_k and all of V_k to int8 and packs the two int8
halves into the fp16 footprint of the single m x k matrix -> k*max(m,n)
numbers of fp16 == the bijective ratio of truncation.py.

Numerically we keep explicit (int8 data, f32 scales) pairs — the packing
is a storage-layout statement, enforced by the byte accounting here and by
the rust `storage` reader, not by actual bit-twiddling in python.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .ipca import robust_svd


# --- int8 / int4 absmax quantizer -------------------------------------------

def quantize_absmax(w: np.ndarray, bits: int = 8, axis: int = 0):
    """Symmetric absmax quantization along `axis` (per-column by default).

    Returns (q int8, scales f32).  For bits=4 the codes live in [-7, 7]
    but are stored in an int8 carrier (rust packs two per byte)."""
    qmax = (1 << (bits - 1)) - 1
    absmax = np.max(np.abs(w), axis=axis, keepdims=True)
    absmax = np.where(absmax == 0, 1.0, absmax)
    scales = (absmax / qmax).astype(np.float32)
    q = np.clip(np.round(w / scales), -qmax, qmax).astype(np.int8)
    return q, np.squeeze(scales, axis=axis)


def dequantize_absmax(q: np.ndarray, scales: np.ndarray, axis: int = 0) -> np.ndarray:
    s = np.expand_dims(scales, axis=axis)
    return q.astype(np.float32) * s


def quant_error(w: np.ndarray, bits: int = 8) -> tuple[float, float]:
    """(MSE, MAE) of the quantize->dequantize round trip (Table 15)."""
    q, s = quantize_absmax(w, bits=bits)
    wd = dequantize_absmax(q, s)
    err = w.astype(np.float64) - wd.astype(np.float64)
    return float(np.mean(err ** 2)), float(np.mean(np.abs(err)))


# --- factor extraction -------------------------------------------------------

def factorize(w_new: np.ndarray, k: int) -> tuple[np.ndarray, np.ndarray]:
    """Exact rank-k factors of the updated weight: W~ ~= A @ B with
    A = (U sqrt(S))[:, :k] (m x k), B = (sqrt(S) V^T)[:k, :] (k x n).

    The symmetric sqrt split keeps both factors at comparable dynamic
    range, which is what makes them int8-friendly (paper Fig 5/6)."""
    u, s, vt = robust_svd(w_new.astype(np.float64))
    rs = np.sqrt(s[:k])
    a = (u[:, :k] * rs[None, :]).astype(np.float32)
    b = (rs[:, None] * vt[:k]).astype(np.float32)
    return a, b


@dataclass
class RemappedFactors:
    """Algo-3 storage of one compressed matrix."""
    m: int
    n: int
    k: int
    precision: str            # "8+16" (paper), "16" (ablation), "4+16"
    a_q: np.ndarray           # (m,k) int8 codes (or f16 as int8 view for "16")
    a_scales: np.ndarray      # (k,) f32
    b_q: np.ndarray           # (k,n) int8
    b_scales: np.ndarray      # (k,) f32 (per-row of B)
    a_f: np.ndarray | None    # fp16 factors for precision "16"
    b_f: np.ndarray | None

    def storage_bytes(self) -> int:
        """Bytes on device per Algo 3 accounting."""
        if self.precision == "16":
            # no packing: both factors at fp16 -> k(m+n) * 2
            return 2 * self.k * (self.m + self.n)
        # packed: two int8 halves in one fp16 max(m,n) x k footprint
        per_elem = 2 if self.precision == "8+16" else 1  # 4+16 halves again
        return per_elem * self.k * max(self.m, self.n) + 4 * 2 * self.k  # + scales

    def dequantize(self) -> tuple[np.ndarray, np.ndarray]:
        if self.precision == "16":
            return self.a_f.astype(np.float32), self.b_f.astype(np.float32)
        a = dequantize_absmax(self.a_q, self.a_scales, axis=0)
        b = dequantize_absmax(self.b_q, self.b_scales, axis=1)
        return a, b


def remap_store(w_new: np.ndarray, k: int, precision: str = "8+16") -> RemappedFactors:
    """Factorize + store per Algo 3 at the requested precision."""
    m, n = w_new.shape
    a, b = factorize(w_new, k)
    if precision == "16":
        return RemappedFactors(m, n, k, precision,
                               a_q=np.zeros((0,), np.int8), a_scales=np.zeros((0,), np.float32),
                               b_q=np.zeros((0,), np.int8), b_scales=np.zeros((0,), np.float32),
                               a_f=a.astype(np.float16), b_f=b.astype(np.float16))
    bits = 8 if precision == "8+16" else 4
    a_q, a_s = quantize_absmax(a, bits=bits, axis=0)       # per column of A
    b_q, b_s = quantize_absmax(b, bits=bits, axis=1)       # per row of B
    return RemappedFactors(m, n, k, precision, a_q, a_s, b_q, b_s, None, None)


def reconstruct(rf: RemappedFactors) -> np.ndarray:
    a, b = rf.dequantize()
    return a @ b


# --- whole-tensor PTQ (GPTQ/BnB stand-in for Tables 9/22/23) -----------------

def ptq_tensor(w: np.ndarray, bits: int):
    """Plain per-column absmax PTQ of a dense or factor tensor."""
    q, s = quantize_absmax(w, bits=bits, axis=0)
    return q, s


def ptq_bytes(shape: tuple[int, ...], bits: int) -> int:
    n = int(np.prod(shape))
    return (n * bits + 7) // 8 + 4 * shape[-1]
