"""Comparator methods, reimplemented from their papers' core ideas.

SVD family (factorized output, classic k(m+n) storage):
* weight_svd — truncate SVD(W) directly (paper Table 1 "Weight" row).
* asvd      — activation-aware scaling S = diag(mean|x|^alpha):
              W ~ S^-1 (S W)_k           (Yuan et al. 2023).
* svdllm    — truncation-aware whitening S = chol(X^T X)^T:
              W ~ S^-1 (S W)_k           (Wang et al. 2024).

Pruning family (structured, slimmed dense output):
* wanda_sp   — |W| * ||x|| saliency per channel/head (Sun et al. 2023).
* flap       — fluctuation (activation variance) * weight norm with the
               recoverability flavour of An et al. 2024.
* llm_pruner — first-order gradient saliency |w * dL/dw| per group
               (Ma et al. 2023), one calibration backward.

All pruning methods prune attention heads and MLP intermediate channels,
which is what the original systems do on LLaMA.
"""

from __future__ import annotations

import numpy as np

from .. import model as M
from .ipca import robust_svd
from .truncation import classic_k_for_ratio


# ---------------------------------------------------------------------------
# SVD-family weight factorizations
# ---------------------------------------------------------------------------

def _split_factors(u, s, vt, k):
    rs = np.sqrt(np.maximum(s[:k], 0.0))
    w1 = (u[:, :k] * rs[None, :]).astype(np.float32)
    w2 = (rs[:, None] * vt[:k]).astype(np.float32)
    return w1, w2


def weight_svd_factors(w: np.ndarray, k: int):
    u, s, vt = robust_svd(w.astype(np.float64))
    return _split_factors(u, s, vt, k)


def asvd_factors(w: np.ndarray, xs: list[np.ndarray], k: int, alpha: float = 0.5):
    """S_ii = (mean_j |x_ji|)^alpha over calibration inputs."""
    absmean = np.mean(np.concatenate([np.abs(x) for x in xs], axis=0), axis=0)
    s_diag = np.power(np.maximum(absmean, 1e-6), alpha)
    sw = s_diag[:, None] * w.astype(np.float64)
    u, s, vt = robust_svd(sw)
    w1, w2 = _split_factors(u, s, vt, k)
    w1 = (w1 / s_diag[:, None]).astype(np.float32)  # fold S^-1 into W1
    return w1, w2


def svdllm_factors(w: np.ndarray, xs: list[np.ndarray], k: int, eps: float = 1e-3):
    """Whitening via Cholesky of the calibration Gram matrix X^T X."""
    m = w.shape[0]
    gram = np.zeros((m, m), np.float64)
    for x in xs:
        gram += x.astype(np.float64).T @ x.astype(np.float64)
    gram /= max(len(xs), 1)
    gram[np.diag_indices(m)] += eps * float(np.trace(gram)) / m + 1e-8
    l = np.linalg.cholesky(gram)
    s_mat = l.T                       # S with S^T S = X^T X
    sw = s_mat @ w.astype(np.float64)
    u, s, vt = robust_svd(sw)
    w1, w2 = _split_factors(u, s, vt, k)
    w1 = np.linalg.solve(s_mat, w1.astype(np.float64)).astype(np.float32)
    return w1, w2


def svd_family_compress(params: dict, cfg: M.ModelConfig, ratio: float,
                        method: str, calib_x: dict[str, list[np.ndarray]]):
    """Apply one SVD-family baseline at uniform classic-storage ranks.

    Returns (factorized params, {name: k}, stored_param_count)."""
    shapes = M.target_shapes(cfg)
    total = M.count_params(params)
    fixed = total - sum(m * n for _, m, n in shapes)
    budget = ratio * total - fixed
    full = sum(m * n for _, m, n in shapes)
    # uniform fraction c of each matrix's classic-storage budget
    c = max(min(budget / full, 1.0), 0.02)
    new = params
    ks = {}
    stored = fixed
    for name, m, n in shapes:
        k = max(1, classic_k_for_ratio(m, n, c))
        w = np.asarray(M.get_target(params, name))
        if method == "weight_svd":
            w1, w2 = weight_svd_factors(w, k)
        elif method == "asvd":
            w1, w2 = asvd_factors(w, calib_x[name], k)
        elif method == "svdllm":
            w1, w2 = svdllm_factors(w, calib_x[name], k)
        else:
            raise ValueError(method)
        new = M.set_target(new, name, (w1, w2))
        ks[name] = k
        stored += k * (m + n)
    return new, ks, int(stored)


# ---------------------------------------------------------------------------
# Pruning-family baselines
# ---------------------------------------------------------------------------

def _head_ff_budget(cfg: M.ModelConfig, ratio: float, total: int, fixed: int):
    """Keep-fraction rho over prunable params so kept/total == ratio."""
    prunable = total - fixed
    rho = np.clip((ratio * total - fixed) / prunable, 0.05, 1.0)
    return float(rho)


def _prune_with_scores(params: dict, cfg: M.ModelConfig, ratio: float,
                       head_scores: list[np.ndarray], ff_scores: list[np.ndarray]):
    """Slim every layer to its top heads/channels by the given scores."""
    total = M.count_params(params)
    fixed = M.fixed_param_count(cfg)
    rho = _head_ff_budget(cfg, ratio, total, fixed)
    d_head = cfg.d_head
    new = params
    heads_per_layer = []
    stored = fixed
    for li in range(cfg.n_layers):
        layer = params["layers"][li]
        n_keep_h = max(1, int(round(rho * cfg.n_heads)))
        n_keep_f = max(8, int(round(rho * cfg.d_ff)))
        keep_h = np.sort(np.argsort(head_scores[li])[::-1][:n_keep_h])
        keep_f = np.sort(np.argsort(ff_scores[li])[::-1][:n_keep_f])
        cols = np.concatenate([np.arange(h * d_head, (h + 1) * d_head) for h in keep_h])
        for mn in ("wq", "wk", "wv"):
            w = np.asarray(layer[mn])[:, cols]
            new = M.set_target(new, f"layers.{li}.{mn}", w)
            stored += w.size
        wo = np.asarray(layer["wo"])[cols, :]
        new = M.set_target(new, f"layers.{li}.wo", wo)
        stored += wo.size
        for mn in ("w_gate", "w_up"):
            w = np.asarray(layer[mn])[:, keep_f]
            new = M.set_target(new, f"layers.{li}.{mn}", w)
            stored += w.size
        wd = np.asarray(layer["w_down"])[keep_f, :]
        new = M.set_target(new, f"layers.{li}.w_down", wd)
        stored += wd.size
        heads_per_layer.append(int(n_keep_h))
    return new, heads_per_layer, int(stored)


def _collect_head_ff_stats(params, cfg, calib_x):
    """Per-layer per-head / per-ff-channel activation statistics."""
    d_head = cfg.d_head
    head_norm, head_var, ff_norm, ff_var = [], [], [], []
    for li in range(cfg.n_layers):
        xo = np.concatenate(calib_x[f"layers.{li}.wo"], axis=0)     # attn out pre-wo
        xd = np.concatenate(calib_x[f"layers.{li}.w_down"], axis=0)  # mlp hidden
        hn = np.array([np.linalg.norm(xo[:, h * d_head:(h + 1) * d_head])
                       for h in range(cfg.n_heads)])
        hv = np.array([np.var(xo[:, h * d_head:(h + 1) * d_head])
                       for h in range(cfg.n_heads)])
        head_norm.append(hn)
        head_var.append(hv)
        ff_norm.append(np.linalg.norm(xd, axis=0))
        ff_var.append(np.var(xd, axis=0))
    return head_norm, head_var, ff_norm, ff_var


def wanda_sp_compress(params, cfg, ratio, calib_x):
    """score = ||x_group|| * ||W_out rows for the group||."""
    hn, _, fn, _ = _collect_head_ff_stats(params, cfg, calib_x)
    head_scores, ff_scores = [], []
    d_head = cfg.d_head
    for li in range(cfg.n_layers):
        wo = np.asarray(params["layers"][li]["wo"])
        wd = np.asarray(params["layers"][li]["w_down"])
        hs = np.array([hn[li][h] * np.linalg.norm(wo[h * d_head:(h + 1) * d_head])
                       for h in range(cfg.n_heads)])
        fs = fn[li] * np.linalg.norm(wd, axis=1)
        head_scores.append(hs)
        ff_scores.append(fs)
    return _prune_with_scores(params, cfg, ratio, head_scores, ff_scores)


def flap_compress(params, cfg, ratio, calib_x):
    """Fluctuation-based: activation variance * squared weight norm."""
    _, hv, _, fv = _collect_head_ff_stats(params, cfg, calib_x)
    head_scores, ff_scores = [], []
    d_head = cfg.d_head
    for li in range(cfg.n_layers):
        wo = np.asarray(params["layers"][li]["wo"])
        wd = np.asarray(params["layers"][li]["w_down"])
        hs = np.array([hv[li][h] * np.linalg.norm(wo[h * d_head:(h + 1) * d_head]) ** 2
                       for h in range(cfg.n_heads)])
        fs = fv[li] * np.linalg.norm(wd, axis=1) ** 2
        head_scores.append(hs)
        ff_scores.append(fs)
    return _prune_with_scores(params, cfg, ratio, head_scores, ff_scores)


def llm_pruner_compress(params, cfg, ratio, grads):
    """First-order saliency |w * g| summed per head / ff channel.

    `grads` is the gradient pytree from one calibration backward (computed
    by the pipeline so this module stays jax-free)."""
    head_scores, ff_scores = [], []
    d_head = cfg.d_head
    for li in range(cfg.n_layers):
        layer = params["layers"][li]
        glayer = grads["layers"][li]
        sal_o = np.abs(np.asarray(layer["wo"]) * np.asarray(glayer["wo"]))
        hs = np.array([sal_o[h * d_head:(h + 1) * d_head].sum()
                       for h in range(cfg.n_heads)])
        sal_d = np.abs(np.asarray(layer["w_down"]) * np.asarray(glayer["w_down"]))
        fs = sal_d.sum(axis=1)
        head_scores.append(hs)
        ff_scores.append(fs)
    return _prune_with_scores(params, cfg, ratio, head_scores, ff_scores)
