"""Incremental PCA weight update (paper §3.2, Algo 2, A.4.1).

Given calibration activations A_i = x_i W, direct truncation at rank k is
A_k = A V_A G_k V_A^T (Prop. 3), so the updated weight must be the rank-k
matrix closest to the set {W V_{A_i} G_k V_{A_i}^T}.  A.4.1 shows the
optimum is W~ = W V G_k V^T where V spans the dominant subspace of the
stacked right-singular bases [V_1 ... V_n] — i.e. their PCA.

Full PCA would materialize an n x (n_batches * k) matrix (hundreds of GB
at 7B scale — paper Fig 3c); IPCA keeps only an n x k running basis and
folds one batch at a time:  V_old <- top-k left singular vectors of
[V_old * s_w, V_i]  (s_w carries the accumulated singular weights so early
batches are not washed out).
"""

from __future__ import annotations

import numpy as np


def robust_svd(a: np.ndarray):
    """np.linalg.svd with the standard dgesdd-nonconvergence fallbacks:
    sanitize non-finite values, rescale, and as a last resort jitter.
    LAPACK's divide-and-conquer driver occasionally fails on rank-deficient
    float64 stacks; the jitter perturbs at 1e-10 * scale, far below any
    quantity we consume."""
    a = np.nan_to_num(np.asarray(a, np.float64), posinf=0.0, neginf=0.0)
    scale = np.max(np.abs(a))
    if scale > 0:
        a = a / scale
    else:
        scale = 1.0
    for attempt in range(3):
        try:
            u, s, vt = np.linalg.svd(a, full_matrices=False)
            return u, s * scale, vt
        except np.linalg.LinAlgError:
            rng = np.random.default_rng(attempt)
            a = a + 1e-10 * rng.standard_normal(a.shape)
    raise np.linalg.LinAlgError(f"SVD failed after jitter, shape {a.shape}")


def batch_right_basis(a: np.ndarray, k: int) -> tuple[np.ndarray, np.ndarray]:
    """Top-k right-singular basis of one activation batch a (rows x n).

    Returns (V_k: n x k, s_k: k singular values)."""
    _, s, vt = robust_svd(a)
    k = min(k, vt.shape[0])
    return vt[:k].T.astype(np.float64), s[:k].astype(np.float64)


class IncrementalPCA:
    """Streaming dominant-subspace tracker over right-singular bases.

    `partial_fit` consumes one batch's (basis, weights); `components`
    returns the n x k orthonormal V used in W~ = W V V^T.
    Peak memory: O(n * 2k) — constant in the number of batches (Fig 3c).
    """

    def __init__(self, n: int, k: int):
        self.n = n
        self.k = k
        self.basis: np.ndarray | None = None   # n x k, orthonormal columns
        self.weights: np.ndarray | None = None  # k, importance of each column
        self.n_seen = 0
        self.peak_bytes = 0

    def partial_fit(self, v_i: np.ndarray, s_i: np.ndarray) -> None:
        assert v_i.shape[0] == self.n, f"basis dim {v_i.shape} != n={self.n}"
        if self.basis is None:
            self.basis = v_i[:, : self.k].copy()
            self.weights = s_i[: self.k].copy()
        else:
            stacked = np.concatenate(
                [self.basis * self.weights[None, :], v_i * s_i[None, :]], axis=1
            )
            self.peak_bytes = max(self.peak_bytes, stacked.nbytes)
            u, s, _ = robust_svd(stacked)
            kk = min(self.k, u.shape[1])
            self.basis = u[:, :kk]
            self.weights = s[:kk]
        self.n_seen += 1

    def components(self) -> np.ndarray:
        assert self.basis is not None, "partial_fit never called"
        return self.basis


def full_pca_components(bases: list[np.ndarray], weights: list[np.ndarray],
                        k: int) -> np.ndarray:
    """Reference full-PCA: SVD of all stacked weighted bases at once.

    Used only in tests/benches to validate IPCA subspace agreement and to
    measure the memory blow-up (Fig 3c)."""
    stacked = np.concatenate([v * s[None, :] for v, s in zip(bases, weights)], axis=1)
    u, _, _ = robust_svd(stacked)
    return u[:, :k]


def subspace_distance(v1: np.ndarray, v2: np.ndarray) -> float:
    """sin of the largest principal angle between column spaces (0 = same)."""
    q1, _ = np.linalg.qr(v1)
    q2, _ = np.linalg.qr(v2)
    s = np.linalg.svd(q1.T @ q2, compute_uv=False)
    return float(np.sqrt(max(0.0, 1.0 - np.min(s) ** 2)))


def update_weight(w: np.ndarray, v: np.ndarray) -> np.ndarray:
    """The EYM-optimal update W~ = W V V^T (rank <= k, same shape as W)."""
    return (w @ v) @ v.T


def ipca_weight_update(w: np.ndarray, activations: list[np.ndarray], k: int,
                       return_tracker: bool = False):
    """End-to-end Algo 2: activations -> per-batch bases -> IPCA -> W~."""
    n = w.shape[1]
    tracker = IncrementalPCA(n, k)
    for a in activations:
        v_i, s_i = batch_right_basis(a, k)
        tracker.partial_fit(v_i, s_i)
    v = tracker.components()
    w_new = update_weight(w.astype(np.float64), v).astype(w.dtype)
    if return_tracker:
        return w_new, tracker
    return w_new


# --- memory model for Fig 3c -------------------------------------------------

def pca_memory_bytes(n: int, k: int, n_batches: int, dtype_bytes: int = 8) -> int:
    """Full PCA must hold the n x (n_batches*k) stack plus its SVD workspace."""
    stack = n * n_batches * k * dtype_bytes
    svd_work = stack + n_batches * k * dtype_bytes * 2
    return stack + svd_work


def ipca_memory_bytes(n: int, k: int, dtype_bytes: int = 8) -> int:
    """IPCA peak: running basis + one incoming basis + SVD of n x 2k."""
    return 3 * n * 2 * k * dtype_bytes
