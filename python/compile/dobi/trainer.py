"""Differentiable truncation-position training (paper §3.1, Algo 1).

Forward: for every compression target W the activation A = xW is SVD'd
(with the gradient-stable backward of svd_diff), its spectrum gated by
T(sigma_i) = sigma_i (0.5 tanh(beta(k - i)) + 0.5), and reconstructed —
so the task loss directly "feels" every candidate truncation position.

Parameter renormalization (paper Fig 1 step 1): the raw trainables are
theta_i with k_i = K_i * sigmoid(theta_i), K_i = min(m_i, n_i).  All
thetas then share scale/learning-rate regardless of matrix shape, and k
stays in its feasible interval without clipping.

Loss: L = L_task + gamma * |R_now - R_tar| with R_now the *remapped*
(bijective) memory ratio of truncation.py — only k is trainable (224
parameters at LLaMA-7B scale; 7*n_layers here).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from .. import model as M
from .svd_diff import svd
from .truncation import round_ranks, smooth_gate

BETA = 10.0        # paper A.3
GAMMA = 5.0        # ratio-penalty weight (paper's gamma in the loss)


@dataclass
class TrainLog:
    """Everything the figure benches need (Figs 3a/3b/7/8/9/10)."""
    loss_history: list[float] = field(default_factory=list)
    task_loss_history: list[float] = field(default_factory=list)
    ratio_history: list[float] = field(default_factory=list)
    val_ppl_history: list[float] = field(default_factory=list)
    k_history: list[list[float]] = field(default_factory=list)  # per-step ks
    target_names: list[str] = field(default_factory=list)
    seconds: float = 0.0


def _truncated_apply(x2d: jnp.ndarray, w: jnp.ndarray, k, beta: float):
    """A = x W, then the smooth spectral gate at (learnable) position k."""
    a = jnp.dot(x2d, w)
    u, s, vt = svd(a)
    gate = smooth_gate(s.shape[0], k, beta, dtype=s.dtype)
    return (u * (s * gate)[None, :]) @ vt


def forward_truncated(params: dict, ks: jnp.ndarray, tokens: jnp.ndarray,
                      cfg: M.ModelConfig, kidx: dict[str, int],
                      beta: float = BETA) -> jnp.ndarray:
    """Dense forward with per-target activation truncation.

    `kidx` maps target name -> index into ks; targets not present are
    left untruncated (Fig 3a single/multi-layer experiments)."""
    b, s_len, d = tokens.shape[0], tokens.shape[1], cfg.d_model
    cos, sin = M._rope_cache(s_len, cfg.d_head, cfg.rope_theta)
    h = params["embed"][tokens]

    def apply(name, x2d, w):
        if name in kidx:
            return _truncated_apply(x2d, w, ks[kidx[name]], beta)
        return jnp.dot(x2d, w)

    for li, layer in enumerate(params["layers"]):
        pre = f"layers.{li}."
        xa = M.rmsnorm(h, layer["attn_norm"]).reshape(b * s_len, d)
        q = apply(pre + "wq", xa, layer["wq"]).reshape(b, s_len, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)
        k_ = apply(pre + "wk", xa, layer["wk"]).reshape(b, s_len, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)
        v = apply(pre + "wv", xa, layer["wv"]).reshape(b, s_len, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)
        q = M.apply_rope(q, cos, sin)
        k_ = M.apply_rope(k_, cos, sin)
        att = jnp.einsum("bhqd,bhkd->bhqk", q, k_) / np.sqrt(cfg.d_head)
        mask = jnp.tril(jnp.ones((s_len, s_len), bool))
        att = jax.nn.softmax(jnp.where(mask[None, None], att, -1e30), axis=-1)
        o = jnp.einsum("bhqk,bhkd->bhqd", att, v).transpose(0, 2, 1, 3).reshape(b * s_len, d)
        h = h + apply(pre + "wo", o, layer["wo"]).reshape(b, s_len, d)

        xm = M.rmsnorm(h, layer["mlp_norm"]).reshape(b * s_len, d)
        g = apply(pre + "w_gate", xm, layer["w_gate"])
        u_ = apply(pre + "w_up", xm, layer["w_up"])
        hm = jax.nn.silu(g) * u_
        h = h + apply(pre + "w_down", hm, layer["w_down"]).reshape(b, s_len, d)

    h = M.rmsnorm(h, params["final_norm"])
    return jnp.dot(h, params["embed"].T)


def train_ks(params: dict, cfg: M.ModelConfig, train_tokens: np.ndarray, *,
             ratio: float, steps: int = 60, batch: int = 4, seq: int = 72,
             lr: float = 0.1, beta: float = BETA, gamma: float = GAMMA,
             targets: list[str] | None = None, seed: int = 0,
             val_tokens: np.ndarray | None = None, val_every: int = 0,
             log=print) -> tuple[np.ndarray, TrainLog]:
    """Optimize truncation positions.  Returns (integer ranks, log).

    `targets=None` means all 7*n_layers matrices (the paper's setting);
    a subset reproduces the Fig 3a guided-truncation experiments.
    """
    shapes_all = M.target_shapes(cfg)
    if targets is None:
        targets = [n for n, _, _ in shapes_all]
    shapes = [(m, n) for (nm, m, n) in shapes_all if nm in set(targets)]
    names = [nm for nm, _, _ in shapes_all if nm in set(targets)]
    kidx = {nm: i for i, nm in enumerate(names)}
    kmax = np.array([min(m, n) for m, n in shapes], np.float32)
    maxmn = np.array([max(m, n) for m, n in shapes], np.float32)

    total = M.count_params(params)
    fixed = total - sum(m * n for m, n in shapes)

    # renormalized parameters: k = kmax * sigmoid(theta); start at R_tar.
    r0 = np.clip(ratio, 0.05, 0.95)
    theta = jnp.full((len(names),), float(np.log(r0 / (1 - r0))), jnp.float32)

    assert batch * seq >= int(kmax.max()), (
        f"calibration batch ({batch}x{seq}) must cover max rank {kmax.max()}")

    kmax_j = jnp.asarray(kmax)
    maxmn_j = jnp.asarray(maxmn)

    def loss_fn(theta, toks):
        ks = kmax_j * jax.nn.sigmoid(theta)
        logits = forward_truncated(params, ks, toks, cfg, kidx, beta)
        task = M.lm_loss(logits, toks)
        r_now = (jnp.sum(ks * maxmn_j) + fixed) / total
        return task + gamma * jnp.abs(r_now - ratio), (task, r_now)

    grad_fn = jax.jit(jax.value_and_grad(loss_fn, has_aux=True))

    # Adam on theta only
    m_t = jnp.zeros_like(theta)
    v_t = jnp.zeros_like(theta)
    rng = np.random.default_rng(seed)
    hi = len(train_tokens) - seq - 1
    logobj = TrainLog(target_names=names)
    t0 = time.time()
    for step in range(steps):
        idx = rng.integers(0, hi, size=batch)
        toks = jnp.asarray(np.stack([train_tokens[i:i + seq] for i in idx]).astype(np.int32))
        (loss, (task, r_now)), g = grad_fn(theta, toks)
        m_t = 0.9 * m_t + 0.1 * g
        v_t = 0.999 * v_t + 0.001 * g * g
        mh = m_t / (1 - 0.9 ** (step + 1))
        vh = v_t / (1 - 0.999 ** (step + 1))
        theta = theta - lr * mh / (jnp.sqrt(vh) + 1e-8)
        ks_now = np.asarray(kmax_j * jax.nn.sigmoid(theta))
        logobj.loss_history.append(float(loss))
        logobj.task_loss_history.append(float(task))
        logobj.ratio_history.append(float(r_now))
        logobj.k_history.append([float(x) for x in ks_now])
        if val_every and val_tokens is not None and (step % val_every == 0 or step == steps - 1):
            ppl = eval_truncated_ppl(params, cfg, kidx, ks_now, val_tokens,
                                     batch=batch, seq=seq, beta=beta)
            logobj.val_ppl_history.append(ppl)
        if step % max(steps // 5, 1) == 0 or step == steps - 1:
            log(f"  [k-train r={ratio}] step {step:3d} loss {float(loss):.4f} "
                f"task {float(task):.4f} R_now {float(r_now):.3f}")
    logobj.seconds = time.time() - t0

    ks_final = np.asarray(kmax_j * jax.nn.sigmoid(theta))
    return round_ranks(ks_final, shapes), logobj


def eval_truncated_ppl(params, cfg, kidx, ks, tokens, *, batch=4, seq=72,
                       beta=BETA, n_windows: int = 8) -> float:
    """PPL of the smooth-truncation model (Fig 7 validation curve)."""
    ks_j = jnp.asarray(ks, jnp.float32)
    f = jax.jit(lambda t: M.lm_loss(
        forward_truncated(params, ks_j, t, cfg, kidx, beta), t))
    rng = np.random.default_rng(123)
    hi = len(tokens) - seq - 1
    tot = 0.0
    for _ in range(n_windows):
        idx = rng.integers(0, hi, size=batch)
        toks = jnp.asarray(np.stack([tokens[i:i + seq] for i in idx]).astype(np.int32))
        tot += float(f(toks))
    return float(np.exp(tot / n_windows))


def uniform_ks(cfg: M.ModelConfig, ratio: float,
               targets: list[str] | None = None) -> np.ndarray:
    """The no-training ablation (Table 16 / SVD-LLM-style averaging):
    every matrix truncated at the same remapped fraction."""
    shapes_all = M.target_shapes(cfg)
    if targets is None:
        targets = [n for n, _, _ in shapes_all]
    shapes = [(m, n) for (nm, m, n) in shapes_all if nm in set(targets)]
    ks = np.array([ratio * min(m, n) for m, n in shapes], np.float32)
    return round_ranks(ks, shapes)
