"""Gradient-stable differentiable SVD (paper §3.1 "the gradient is the
devil", Eq. 1-2, appendix Algos 4/5).

The textbook thin-SVD backward contains F_ij = 1/(sigma_j^2 - sigma_i^2),
which blows up whenever two singular values are close or tiny — exactly
the regime of LLM activations (approximately low-rank).  Following the
paper we stabilize the three bad cases:

1. both sigmas ~ 0                  -> 1/E_ij := gamma (tiny constant)
2. sigma_i ~ sigma_j (both nonzero) -> K-term Taylor / geometric series:
       1/(si^2-sj^2) = 1/(si(si+sj)) * 1/(1-q),  q = sj/si
                    ~= (1 - q^{2K}) / ((1 - q^2) * si^2)   (closed form)
   with the q -> 1 limit K / si^2 (paper Algo 5 lines 23, 27).
3. well-separated                   -> exact 1/((si-sj)(si+sj))

`svd` below is a jax.custom_vjp drop-in for jnp.linalg.svd
(full_matrices=False) whose backward never produces inf/nan on degenerate
spectra; `svd_unstable` keeps the naive rule for the ablation bench.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

# Paper defaults (A.3): gamma = 1e-10, K = 10.
EPS_VAL = 1e-10       # clamp for tiny singular values (paper's gamma)
EPS_GRAD = 1e-10      # 1/E for the both-tiny case
EPS_DIFF = 1e-4       # |si - sj| below which the Taylor branch engages
N_TAYLOR = 10         # K, number of series terms


def _stable_inv_e(s: jnp.ndarray, *, eps_val: float, eps_grad: float,
                  eps_diff: float, n_taylor: int) -> jnp.ndarray:
    """The stabilized antisymmetric matrix 1/E with E_ij ~ sj^2 - si^2.

    Returns F with F_ij = stable(1/(sj^2 - si^2)) for i != j, 0 on the
    diagonal.  Sign convention matches the classic SVD backward
    F_ij = 1/(sj^2 - si^2); the paper's Algo 5 builds the lower triangle
    as 1/((si-sj)(si+sj)) = -F_ij and anti-symmetrizes — identical result.
    """
    k = s.shape[0]
    sc = jnp.maximum(s, eps_val)
    si = sc[:, None]  # lambda_i (row)
    sj = sc[None, :]  # lambda_j (col)

    both_tiny = (s[:, None] <= eps_val) & (s[None, :] <= eps_val)
    diff = jnp.abs(si - sj)
    close = (diff <= eps_diff) & ~both_tiny

    # Work on the lower triangle (i > j).  s is sorted descending, so the
    # ROW value si <= the COLUMN value sj there and
    # F_ij = 1/(sj^2 - si^2) >= 0 with sj the larger of the pair.
    # Branch 2: geometric-series closed form, q = si/sj in (0, 1]:
    #   1/(sj^2 - si^2) = (1 - q^{2K}) / ((1 - q^2) sj^2),
    # with the q -> 1 limit K / sj^2 (paper Algo 5 lines 23, 27).
    q = si / sj
    q2 = q * q
    one_m_q2 = 1.0 - q2
    series = jnp.where(
        jnp.abs(one_m_q2) < 1e-12,
        float(n_taylor),
        (1.0 - q2 ** n_taylor) / jnp.where(jnp.abs(one_m_q2) < 1e-12, 1.0, one_m_q2),
    )
    taylor = series / (sj * sj)

    # Branch 3: exact magnitude 1/((sj - si)(sj + si)).
    denom = (sj - si) * (sj + si)
    safe_denom = jnp.where(jnp.abs(denom) < 1e-30, 1.0, denom)
    exact = 1.0 / safe_denom

    lower_val = jnp.where(both_tiny, eps_grad, jnp.where(close, taylor, exact))
    tril = jnp.tril(jnp.ones((k, k), dtype=bool), k=-1)
    lower = jnp.where(tril, lower_val, 0.0)
    # F_ij = 1/(sj^2 - si^2): positive below the diagonal, negative above.
    f = lower - lower.T
    return f


def _svd_fwd(a):
    u, s, vt = jnp.linalg.svd(a, full_matrices=False)
    return (u, s, vt), (a, u, s, vt)


def _svd_bwd_impl(a, u, s, vt, du, ds, dvt, *, eps_val, eps_grad, eps_diff,
                  n_taylor, stable: bool):
    """General thin-SVD backward (m x n, k = min(m,n)) with stabilized F.

    dA = U [ (F o (U^T dU - dU^T U)) S + S (F o (V^T dV - dV^T V)) + diag(dS) ] V^T
       + (I - U U^T) dU S^{-1} V^T          (m > k column-space term)
       + U S^{-1} dV^T (I - V V^T)          (n > k row-space term)
    """
    m, n = a.shape
    k = s.shape[0]
    v = vt.T
    dv = dvt.T

    if stable:
        f = _stable_inv_e(s, eps_val=eps_val, eps_grad=eps_grad,
                          eps_diff=eps_diff, n_taylor=n_taylor)
        s_inv = 1.0 / jnp.maximum(s, eps_val)
    else:
        si2 = s[None, :] ** 2 - s[:, None] ** 2
        f = jnp.where(jnp.eye(k, dtype=bool), 0.0, 1.0 / si2)
        s_inv = 1.0 / s

    utdu = u.T @ du
    vtdv = v.T @ dv
    j_u = f * (utdu - utdu.T)   # skew part scaled elementwise
    j_v = f * (vtdv - vtdv.T)

    sd = jnp.diag(s)
    core = j_u @ sd + sd @ j_v + jnp.diag(ds)
    da = u @ core @ vt
    if m > k:
        da = da + (du - u @ utdu) * s_inv[None, :] @ vt
    if n > k:
        da = da + u @ (s_inv[:, None] * (dv - v @ vtdv).T)
    return (da,)


@functools.partial(jax.custom_vjp)
def svd(a: jnp.ndarray):
    """Thin SVD (U, S, Vt) with the paper's gradient-stable backward."""
    u, s, vt = jnp.linalg.svd(a, full_matrices=False)
    return u, s, vt


def _svd_bwd(res, cts):
    a, u, s, vt = res
    du, ds, dvt = cts
    du = jnp.zeros_like(u) if du is None else du
    ds = jnp.zeros_like(s) if ds is None else ds
    dvt = jnp.zeros_like(vt) if dvt is None else dvt
    return _svd_bwd_impl(a, u, s, vt, du, ds, dvt, eps_val=EPS_VAL,
                         eps_grad=EPS_GRAD, eps_diff=EPS_DIFF,
                         n_taylor=N_TAYLOR, stable=True)


svd.defvjp(_svd_fwd, _svd_bwd)


@functools.partial(jax.custom_vjp)
def svd_unstable(a: jnp.ndarray):
    """Naive-backward SVD — kept only for the gradient-explosion ablation
    (EXPERIMENTS.md `gradstab`): diverges on near-degenerate spectra."""
    u, s, vt = jnp.linalg.svd(a, full_matrices=False)
    return u, s, vt


def _svd_bwd_unstable(res, cts):
    a, u, s, vt = res
    du, ds, dvt = cts
    du = jnp.zeros_like(u) if du is None else du
    ds = jnp.zeros_like(s) if ds is None else ds
    dvt = jnp.zeros_like(vt) if dvt is None else dvt
    return _svd_bwd_impl(a, u, s, vt, du, ds, dvt, eps_val=0.0, eps_grad=0.0,
                         eps_diff=0.0, n_taylor=N_TAYLOR, stable=False)


svd_unstable.defvjp(_svd_fwd, _svd_bwd_unstable)
