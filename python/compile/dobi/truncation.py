"""Truncation math: the smooth gate, ratio accounting, and the §3.3
bijective remapping between truncation position and memory.

Two storage regimes for an m x n matrix truncated at rank k:

* classic SVD storage:   bytes ∝ k (m + n)   ->  ratio r = k(m+n)/(mn).
  To compress at all, k < mn/(m+n) <= min(m,n)/2 for square matrices —
  the "long-overlooked limitation": half the spectrum is lost before any
  compression happens.
* remapped storage (Algo 3): the two n x k (resp. m x k) halves are
  quantized to int8 and packed into the fp16 footprint of ONE
  max(m,n) x k matrix ->  bytes ∝ k max(m,n)  ->  r = k/min(m,n), a
  bijection from k in [0, rank(W)] onto r in [0, 1].
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def smooth_gate(n: int, k, beta: float = 10.0, dtype=jnp.float32) -> jnp.ndarray:
    """g_i = 0.5*tanh(beta*(k-i)) + 0.5 for i = 1..n (differentiable in k)."""
    i = jnp.arange(1, n + 1, dtype=dtype)
    return 0.5 * jnp.tanh(beta * (k - i)) + 0.5


def soft_rank(n: int, k, beta: float = 10.0) -> jnp.ndarray:
    """Differentiable effective rank = sum of the gate (== k for interior k)."""
    return jnp.sum(smooth_gate(n, k, beta))


# --- memory accounting -------------------------------------------------------

def classic_k_for_ratio(m: int, n: int, r: float) -> int:
    """k such that classic two-factor storage hits parameter-ratio r."""
    return max(1, int(round(r * m * n / (m + n))))


def classic_ratio(m: int, n: int, k: int) -> float:
    return k * (m + n) / (m * n)


def remap_k_for_ratio(m: int, n: int, r: float) -> int:
    """Bijection: r = k * max(m,n) / (m*n) = k / min(m,n)."""
    return max(1, min(min(m, n), int(round(r * min(m, n)))))


def remap_ratio(m: int, n: int, k: int) -> float:
    return k * max(m, n) / (m * n)


def remap_ratio_soft(m: int, n: int, k) -> jnp.ndarray:
    """Differentiable remapped ratio for the multi-objective loss."""
    return k * max(m, n) / (m * n)


def model_ratio_soft(ks: list, shapes: list[tuple[int, int]],
                     fixed_params: int, total_params: int) -> jnp.ndarray:
    """R_now for the trainer: compressed bytes of every truncated matrix
    (remapped accounting) + untouched parameters, over the dense total."""
    comp = 0.0
    for k, (mm, nn) in zip(ks, shapes):
        comp = comp + k * max(mm, nn)
    return (comp + fixed_params) / total_params


def round_ranks(ks: np.ndarray, shapes: list[tuple[int, int]],
                multiple: int = 8, k_min: int = 8) -> np.ndarray:
    """Final integer ranks: clamp to [k_min, min(m,n)] and round to a
    lane-friendly multiple (the Pallas blocks like k % 8 == 0; the <0.2%
    ratio effect is noted in DESIGN.md §7)."""
    out = []
    for k, (mm, nn) in zip(ks, shapes):
        kk = int(round(float(k) / multiple) * multiple)
        kk = max(k_min, min(min(mm, nn), kk))
        out.append(kk)
    return np.asarray(out, dtype=np.int64)
