"""Whole-model compression driver: calibration, Dobi-SVD compression
(trained-k + IPCA + remap), the no-remap/no-training ablations, rank
perturbation (Table 17), and the activation-truncation oracle (Table 1,
Fig 11).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from .. import model as M
from . import baselines as B
from .ipca import IncrementalPCA, batch_right_basis, update_weight
from .remap import RemappedFactors, quant_error, remap_store
from .truncation import classic_ratio, remap_ratio, round_ranks


# ---------------------------------------------------------------------------
# Calibration: capture the input x of every compression target
# ---------------------------------------------------------------------------

def collect_calibration(params: dict, cfg: M.ModelConfig,
                        tokens: np.ndarray, *, n_batches: int = 8,
                        batch: int = 4, seq: int = 72, seed: int = 11,
                        ) -> dict[str, list[np.ndarray]]:
    """Run the dense forward over calibration batches, tapping the 2-D
    input of each target matrix (so A_i = x_i @ W is reconstructable)."""
    taps: dict[str, list[np.ndarray]] = {n: [] for n, _, _ in M.target_shapes(cfg)}

    def fwd(toks):
        b, s_len, d = toks.shape[0], toks.shape[1], cfg.d_model
        cos, sin = M._rope_cache(s_len, cfg.d_head, cfg.rope_theta)
        h = params["embed"][toks]
        for li, layer in enumerate(params["layers"]):
            pre = f"layers.{li}."
            xa = M.rmsnorm(h, layer["attn_norm"]).reshape(b * s_len, d)
            for mn in ("wq", "wk", "wv"):
                taps[pre + mn].append(np.asarray(xa))
            q = (xa @ layer["wq"]).reshape(b, s_len, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)
            k_ = (xa @ layer["wk"]).reshape(b, s_len, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)
            v = (xa @ layer["wv"]).reshape(b, s_len, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)
            q = M.apply_rope(q, cos, sin)
            k_ = M.apply_rope(k_, cos, sin)
            att = jnp.einsum("bhqd,bhkd->bhqk", q, k_) / np.sqrt(cfg.d_head)
            mask = jnp.tril(jnp.ones((s_len, s_len), bool))
            att = jax.nn.softmax(jnp.where(mask[None, None], att, -1e30), axis=-1)
            o = jnp.einsum("bhqk,bhkd->bhqd", att, v).transpose(0, 2, 1, 3).reshape(b * s_len, d)
            taps[pre + "wo"].append(np.asarray(o))
            h = h + (o @ layer["wo"]).reshape(b, s_len, d)
            xm = M.rmsnorm(h, layer["mlp_norm"]).reshape(b * s_len, d)
            for mn in ("w_gate", "w_up"):
                taps[pre + mn].append(np.asarray(xm))
            hm = jax.nn.silu(xm @ layer["w_gate"]) * (xm @ layer["w_up"])
            taps[pre + "w_down"].append(np.asarray(hm))
            h = h + (hm @ layer["w_down"]).reshape(b, s_len, d)
        return h

    rng = np.random.default_rng(seed)
    hi = len(tokens) - seq - 1
    for _ in range(n_batches):
        idx = rng.integers(0, hi, size=batch)
        toks = jnp.asarray(np.stack([tokens[i:i + seq] for i in idx]).astype(np.int32))
        fwd(toks)
    return taps


def calibration_grads(params: dict, cfg: M.ModelConfig, tokens: np.ndarray,
                      *, batch: int = 8, seq: int = 64, seed: int = 12) -> dict:
    """One calibration backward (LLM-Pruner saliency)."""
    rng = np.random.default_rng(seed)
    hi = len(tokens) - seq - 1
    idx = rng.integers(0, hi, size=batch)
    toks = jnp.asarray(np.stack([tokens[i:i + seq] for i in idx]).astype(np.int32))
    g = jax.grad(lambda p: M.lm_loss(M.forward_dense(p, toks, cfg), toks))(params)
    return g


# ---------------------------------------------------------------------------
# Dobi-SVD compression
# ---------------------------------------------------------------------------

@dataclass
class CompressedModel:
    params: dict                       # factorized / pruned / dense params
    method: str
    ratio: float                       # requested
    stored_params: int                 # effective stored parameter count
    bytes_fp16_equiv: int              # storage bytes per the method's layout
    ranks: dict[str, int] = field(default_factory=dict)
    heads_per_layer: list[int] | None = None
    quant_errors: dict[str, tuple[float, float]] = field(default_factory=dict)
    cached_v: dict[str, np.ndarray] = field(default_factory=dict)  # IPCA bases


def dobi_compress(params: dict, cfg: M.ModelConfig, ks: np.ndarray,
                  calib_x: dict[str, list[np.ndarray]], *,
                  precision: str = "8+16", ratio: float,
                  cached_v: dict[str, np.ndarray] | None = None,
                  ptq_bits: int = 0) -> CompressedModel:
    """Trained ranks -> IPCA weight update -> remapped factors.

    `cached_v` (from a previous run at the same calibration) skips the
    IPCA pass — used by the Table 17 perturbation sweep.
    `ptq_bits` > 0 additionally quantizes the final factors (Tables 9/22).
    """
    shapes = M.target_shapes(cfg)
    total = M.count_params(params)
    fixed = total - sum(m * n for _, m, n in shapes)
    new = params
    out = CompressedModel(params=None, method=f"dobi[{precision}]", ratio=ratio,
                          stored_params=fixed, bytes_fp16_equiv=2 * fixed)
    vs = cached_v if cached_v is not None else {}
    for i, (name, m, n) in enumerate(shapes):
        k = int(ks[i])
        w = np.asarray(M.get_target(params, name), np.float64)
        if name in vs:
            v_full = vs[name]
        else:
            # IPCA over per-batch right-singular bases of A = xW (Algo 2).
            # Track a basis wider than k so perturbations can reuse it.
            k_track = min(min(m, n), max(k + 16, int(1.25 * k)))
            tracker = IncrementalPCA(n, k_track)
            for x in calib_x[name]:
                a = x.astype(np.float64) @ w
                v_i, s_i = batch_right_basis(a, k_track)
                tracker.partial_fit(v_i, s_i)
            v_full = tracker.components()
            vs[name] = v_full
        v = v_full[:, :k]
        w_new = update_weight(w, v)                       # W~ = W V Gk V^T
        rf = remap_store(w_new.astype(np.float32), k, precision=precision)
        w1, w2 = rf.dequantize()
        if ptq_bits:
            from .remap import dequantize_absmax, quantize_absmax
            q1, s1 = quantize_absmax(w1, bits=ptq_bits, axis=0)
            q2, s2 = quantize_absmax(w2, bits=ptq_bits, axis=0)
            w1 = dequantize_absmax(q1, s1, axis=0)
            w2 = dequantize_absmax(q2, s2, axis=0)
        new = M.set_target(new, name, (w1, w2))
        out.ranks[name] = k
        out.stored_params += k * max(m, n)
        bytes_here = rf.storage_bytes()
        if ptq_bits:
            bytes_here = bytes_here * ptq_bits // 16
        out.bytes_fp16_equiv += bytes_here
        out.quant_errors[name] = quant_error(
            np.concatenate([w1.ravel(), w2.ravel()]).reshape(-1, 1), bits=8)
    out.params = new
    out.cached_v = vs
    if ptq_bits:
        out.method = f"dobi[{precision}]+int{ptq_bits}"
    return out


def scale_ks_to_classic(cfg: M.ModelConfig, ks: np.ndarray, ratio: float) -> np.ndarray:
    """W/o-remap ablation: rescale trained ranks so *classic* two-factor
    storage k(m+n) hits the same overall ratio (Table 8 bottom rows)."""
    shapes = [(m, n) for _, m, n in M.target_shapes(cfg)]
    total = sum(m * n for m, n in shapes) + M.fixed_param_count(cfg)
    fixed = M.fixed_param_count(cfg)
    budget = ratio * total - fixed

    def stored(c):
        return sum(min(min(m, n), max(1, c * k)) * (m + n)
                   for k, (m, n) in zip(ks, shapes))

    lo, hi = 0.01, 4.0
    for _ in range(60):
        mid = 0.5 * (lo + hi)
        if stored(mid) > budget:
            hi = mid
        else:
            lo = mid
    scaled = np.array([min(min(m, n), max(1, lo * k))
                       for k, (m, n) in zip(ks, shapes)], np.float64)
    return round_ranks(scaled, shapes)


def noremap_compress(params: dict, cfg: M.ModelConfig, ks_classic: np.ndarray,
                     calib_x, *, ratio: float) -> CompressedModel:
    """Dobi weight update but classic storage (both factors fp16)."""
    out = dobi_compress(params, cfg, ks_classic, calib_x, precision="16", ratio=ratio)
    shapes = M.target_shapes(cfg)
    fixed = M.fixed_param_count(cfg)
    out.method = "dobi-noremap"
    out.stored_params = fixed + sum(int(k) * (m + n)
                                    for k, (_, m, n) in zip(ks_classic, shapes))
    out.bytes_fp16_equiv = 2 * out.stored_params
    return out


def svd_baseline_compress(params, cfg, ratio, method, calib_x) -> CompressedModel:
    new, ks, stored = B.svd_family_compress(params, cfg, ratio, method, calib_x)
    return CompressedModel(params=new, method=method, ratio=ratio,
                           stored_params=stored, bytes_fp16_equiv=2 * stored,
                           ranks=ks)


def pruning_compress(params, cfg, ratio, method, calib_x=None, grads=None) -> CompressedModel:
    if method == "wanda_sp":
        new, hpl, stored = B.wanda_sp_compress(params, cfg, ratio, calib_x)
    elif method == "flap":
        new, hpl, stored = B.flap_compress(params, cfg, ratio, calib_x)
    elif method == "llm_pruner":
        new, hpl, stored = B.llm_pruner_compress(params, cfg, ratio, grads)
    else:
        raise ValueError(method)
    return CompressedModel(params=new, method=method, ratio=ratio,
                           stored_params=stored, bytes_fp16_equiv=2 * stored,
                           heads_per_layer=hpl)


def perturb_ranks(ks: np.ndarray, x: int, seed: int = 5) -> np.ndarray:
    """Table 17: +x to a random half of 10 targets, -x to the other half,
    total rank budget unchanged."""
    rng = np.random.default_rng(seed)
    ks = ks.copy()
    idx = rng.permutation(len(ks))[:10]
    for i in idx[:5]:
        ks[i] += x
    for i in idx[5:]:
        ks[i] = max(8, ks[i] - x)
    return ks


# ---------------------------------------------------------------------------
# Python-side evaluation (reference numbers for the manifest; rust re-measures)
# ---------------------------------------------------------------------------

def eval_ppl(params: dict, cfg: M.ModelConfig, tokens: np.ndarray, *,
             batch: int = 4, seq: int = 64, n_windows: int = 12,
             heads_per_layer=None, fwd=None, seed: int = 99) -> float:
    if fwd is None:
        if heads_per_layer is not None:
            fwd = lambda p, t: M.forward_pruned(p, t, cfg, heads_per_layer)
        else:
            fwd = lambda p, t: M.forward_dense(p, t, cfg)
    f = jax.jit(lambda t: M.lm_loss(fwd(params, t), t))
    rng = np.random.default_rng(seed)
    hi = len(tokens) - seq - 1
    tot = 0.0
    for _ in range(n_windows):
        idx = rng.integers(0, hi, size=batch)
        toks = jnp.asarray(np.stack([tokens[i:i + seq] for i in idx]).astype(np.int32))
        tot += float(f(toks))
    return float(np.exp(tot / n_windows))


def eval_activation_truncation_ppl(params: dict, cfg: M.ModelConfig,
                                   tokens: np.ndarray, ks_by_idx: np.ndarray,
                                   *, batch: int = 4, seq: int = 64,
                                   n_windows: int = 6,
                                   targets: list[str] | None = None) -> float:
    """The Table 1 / Fig 11 oracle: hard-truncate each activation's SVD at
    eval time (no weight update) — uses the smooth gate at beta -> hard."""
    from .trainer import forward_truncated
    shapes_all = M.target_shapes(cfg)
    names = [n for n, _, _ in shapes_all] if targets is None else targets
    kidx = {nm: i for i, nm in enumerate(names)}
    ks_j = jnp.asarray(ks_by_idx, jnp.float32)
    f = jax.jit(lambda t: M.lm_loss(
        forward_truncated(params, ks_j, t, cfg, kidx, beta=200.0), t))
    rng = np.random.default_rng(101)
    hi = len(tokens) - seq - 1
    tot = 0.0
    for _ in range(n_windows):
        idx = rng.integers(0, hi, size=batch)
        toks = jnp.asarray(np.stack([tokens[i:i + seq] for i in idx]).astype(np.int32))
        tot += float(f(toks))
    return float(np.exp(tot / n_windows))


def eval_weight_truncation_ppl(params: dict, cfg: M.ModelConfig,
                               tokens: np.ndarray, ks: dict[str, int],
                               **kw) -> float:
    """Table 1 "Weight" row: truncate SVD(W) at the same positions."""
    new = params
    for name, k in ks.items():
        w = np.asarray(M.get_target(params, name))
        w1, w2 = B.weight_svd_factors(w, int(k))
        new = M.set_target(new, name, (w1, w2))
    return eval_ppl(new, cfg, tokens, **kw)
