"""Build-time pretraining of the substrate LMs (author path, runs once).

Hand-rolled Adam + cosine schedule (optax is not in the image).  The
pretrained checkpoints are cached under artifacts/cache so `make
artifacts` is incremental.
"""

from __future__ import annotations

import functools
import pickle
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data as D
from . import model as M


# ---------------------------------------------------------------------------
# Minimal Adam on a pytree
# ---------------------------------------------------------------------------

def adam_init(params):
    z = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": jnp.zeros((), jnp.int32)}


def adam_update(grads, state, params, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    mh = jax.tree_util.tree_map(lambda x: x / (1 - b1 ** t), m)
    vh = jax.tree_util.tree_map(lambda x: x / (1 - b2 ** t), v)
    new = jax.tree_util.tree_map(lambda p, mm, vv: p - lr * mm / (jnp.sqrt(vv) + eps),
                                 params, mh, vh)
    return new, {"m": m, "v": v, "t": t}


def cosine_lr(step, total, base, warmup=20):
    w = jnp.minimum(step / max(warmup, 1), 1.0)
    prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    return base * w * 0.5 * (1 + jnp.cos(np.pi * prog))


# ---------------------------------------------------------------------------
# Batching
# ---------------------------------------------------------------------------

def sample_batches(tokens: np.ndarray, batch: int, seq: int, n_steps: int,
                   seed: int = 0):
    rng = np.random.default_rng(seed)
    hi = len(tokens) - seq - 1
    for _ in range(n_steps):
        idx = rng.integers(0, hi, size=batch)
        yield np.stack([tokens[i : i + seq] for i in idx]).astype(np.int32)


# ---------------------------------------------------------------------------
# Pretraining
# ---------------------------------------------------------------------------

def pretrain(cfg: M.ModelConfig, tokens: np.ndarray, *, steps: int = 250,
             batch: int = 8, seq: int = 64, lr: float = 3e-3, seed: int = 0,
             log_every: int = 50, log=print) -> tuple[dict, list[float]]:
    params = M.init_params(cfg, seed=seed)
    opt = adam_init(params)

    @jax.jit
    def step_fn(params, opt, toks, lr_now):
        loss, grads = jax.value_and_grad(
            lambda p: M.lm_loss(M.forward_dense(p, toks, cfg), toks))(params)
        params, opt = adam_update(grads, opt, params, lr_now)
        return params, opt, loss

    losses = []
    t0 = time.time()
    for i, toks in enumerate(sample_batches(tokens, batch, seq, steps, seed=seed + 1)):
        lr_now = cosine_lr(i, steps, lr)
        params, opt, loss = step_fn(params, opt, jnp.asarray(toks), lr_now)
        losses.append(float(loss))
        if log_every and (i % log_every == 0 or i == steps - 1):
            log(f"  [{cfg.name}] step {i:4d}/{steps} loss {float(loss):.4f} "
                f"({time.time() - t0:.0f}s)")
    return params, losses


def finetune_vlm(cfg: M.ModelConfig, params: dict, samples, *, steps: int = 60,
                 batch: int = 8, seq: int = 48, lr: float = 1e-3, seed: int = 3,
                 log=print) -> dict:
    """Teach the projector + trunk to caption images (prefix -> caption)."""
    rng = np.random.default_rng(seed)
    opt = adam_init(params)

    @jax.jit
    def step_fn(params, opt, toks, imgs, lr_now):
        def loss_fn(p):
            logits = M.forward_vlm(p, toks, imgs, cfg)
            return M.lm_loss(logits, toks)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt = adam_update(grads, opt, params, lr_now)
        return params, opt, loss

    for i in range(steps):
        idx = rng.integers(0, len(samples), size=batch)
        toks = np.zeros((batch, seq), np.int32)
        imgs = np.zeros((batch, cfg.img_dim), np.float32)
        for bi, j in enumerate(idx):
            s = samples[j]
            t = D.encode(s.question + s.caption)[:seq]
            toks[bi, : len(t)] = t
            imgs[bi] = s.image
        params, opt, loss = step_fn(params, opt, jnp.asarray(toks), jnp.asarray(imgs),
                                    cosine_lr(i, steps, lr))
        if i % 20 == 0:
            log(f"  [vlm] step {i} loss {float(loss):.4f}")
    return params


def finetune_vla(cfg: M.ModelConfig, params: dict, samples, *, steps: int = 80,
                 batch: int = 8, seq: int = 24, lr: float = 1e-3, seed: int = 4,
                 log=print) -> dict:
    rng = np.random.default_rng(seed)
    opt = adam_init(params)

    @jax.jit
    def step_fn(params, opt, toks, imgs, coords, angle, grip, lr_now):
        def loss_fn(p):
            pred = M.forward_vla(p, toks, imgs, cfg)
            return M.vla_loss(pred, coords, angle, grip)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt = adam_update(grads, opt, params, lr_now)
        return params, opt, loss

    for i in range(steps):
        idx = rng.integers(0, len(samples), size=batch)
        toks = np.zeros((batch, seq), np.int32)
        imgs = np.zeros((batch, cfg.img_dim), np.float32)
        coords = np.zeros((batch, 3), np.float32)
        angle = np.zeros((batch,), np.float32)
        grip = np.zeros((batch,), np.float32)
        for bi, j in enumerate(idx):
            s = samples[j]
            t = D.encode(s.instruction)[:seq]
            toks[bi, : len(t)] = t
            imgs[bi] = s.image
            coords[bi] = s.coords
            angle[bi] = s.angle
            grip[bi] = s.gripper
        params, opt, loss = step_fn(params, opt, jnp.asarray(toks), jnp.asarray(imgs),
                                    jnp.asarray(coords), jnp.asarray(angle),
                                    jnp.asarray(grip), cosine_lr(i, steps, lr))
        if i % 20 == 0:
            log(f"  [vla] step {i} loss {float(loss):.4f}")
    return params


# ---------------------------------------------------------------------------
# Cache
# ---------------------------------------------------------------------------

def save_params(path: str, params: dict) -> None:
    np_params = jax.tree_util.tree_map(lambda x: np.asarray(x), params)
    with open(path, "wb") as f:
        pickle.dump(np_params, f)


def load_params(path: str) -> dict:
    with open(path, "rb") as f:
        np_params = pickle.load(f)
    return jax.tree_util.tree_map(jnp.asarray, np_params)
