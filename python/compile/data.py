"""Synthetic corpora, task suites, and the shared tokenizer spec.

The paper evaluates on WikiText2 / PTB / C4 plus seven commonsense suites.
We have no network and no licensed corpora in the image, so we build three
synthetic corpora with *distinct statistics* (the tables only need
in-domain vs out-of-domain structure, not corpus identity) and six
multiple-choice suites scored the lm-eval-harness way (length-normalized
NLL over options).  Everything is deterministic given a seed and shared
with the rust side through flat binary files (see `write_tokbin`).

Tokenizer: byte-level, vocab = 256.  Rust mirrors this in
`rust/src/tokenizer/` — the contract is simply `token == byte`.
"""

from __future__ import annotations

import json
import os
import zlib
from dataclasses import dataclass, field

import numpy as np

VOCAB_SIZE = 256
TOKBIN_MAGIC = b"DOBT1\x00"


# ---------------------------------------------------------------------------
# Tokenizer (byte-level; must match rust/src/tokenizer)
# ---------------------------------------------------------------------------

def encode(text: str) -> np.ndarray:
    """Byte-level encode. Errors are replaced so any str round-trips."""
    return np.frombuffer(text.encode("utf-8", errors="replace"), dtype=np.uint8).astype(np.int32)


def decode(tokens: np.ndarray) -> str:
    return bytes(int(t) & 0xFF for t in tokens).decode("utf-8", errors="replace")


# ---------------------------------------------------------------------------
# Deterministic word inventories
# ---------------------------------------------------------------------------

_CONSONANTS = "bcdfghjklmnpqrstvwz"
_VOWELS = "aeiou"


def _make_words(rng: np.random.Generator, n: int, min_syl: int = 1, max_syl: int = 3) -> list[str]:
    words = []
    seen = set()
    while len(words) < n:
        syls = rng.integers(min_syl, max_syl + 1)
        w = "".join(
            _CONSONANTS[rng.integers(len(_CONSONANTS))] + _VOWELS[rng.integers(len(_VOWELS))]
            for _ in range(syls)
        )
        if w not in seen:
            seen.add(w)
            words.append(w)
    return words


def _zipf_choice(rng: np.random.Generator, n: int, size: int, a: float = 1.3) -> np.ndarray:
    """Zipfian ranks in [0, n) — natural-language-like unigram skew."""
    ranks = np.arange(1, n + 1, dtype=np.float64)
    p = ranks ** (-a)
    p /= p.sum()
    return rng.choice(n, size=size, p=p)


# ---------------------------------------------------------------------------
# Corpora
# ---------------------------------------------------------------------------

@dataclass
class Corpus:
    name: str
    text: str

    def tokens(self) -> np.ndarray:
        return encode(self.text)


def gen_wiki_syn(seed: int = 0, n_chars: int = 600_000) -> Corpus:
    """Zipfian word LM with sentence/paragraph structure — WikiText2 analogue.

    This is also the *pretraining* corpus, so the substrate model genuinely
    learns its statistics (bigram habits, punctuation, capitalization),
    which is what gives compression something to destroy.
    """
    rng = np.random.default_rng(seed)
    words = _make_words(rng, 800)
    # Fixed bigram tendencies: each word has a preferred small follow set,
    # giving the LM learnable medium-range structure beyond unigrams.
    follow = {w: rng.choice(len(words), size=6) for w in words}
    out: list[str] = []
    total = 0
    cur = words[int(_zipf_choice(rng, len(words), 1)[0])]
    sent: list[str] = []
    while total < n_chars:
        sent.append(cur)
        total += len(cur) + 1
        if rng.random() < 0.35:
            cur = words[int(follow[cur][rng.integers(6)])]
        else:
            cur = words[int(_zipf_choice(rng, len(words), 1)[0])]
        if len(sent) >= rng.integers(5, 14):
            s = " ".join(sent)
            s = s[0].upper() + s[1:] + ("." if rng.random() < 0.8 else "?")
            out.append(s)
            sent = []
            if rng.random() < 0.12:
                out.append("\n\n")
            else:
                out.append(" ")
    return Corpus("wiki-syn", "".join(out)[:n_chars])


def gen_ptb_syn(seed: int = 1, n_chars: int = 200_000) -> Corpus:
    """Low-entropy templated sentences — PTB analogue (out-of-domain,
    more predictable than wiki-syn so PPL lands lower-ish but the model
    never trained on the templates)."""
    rng = np.random.default_rng(seed)
    subs = _make_words(rng, 40)
    verbs = _make_words(rng, 25)
    objs = _make_words(rng, 40)
    templates = [
        "the {s} {v} the {o} .",
        "a {s} {v} a {o} today .",
        "{s} and {s2} {v} the {o} .",
        "the {s} will {v} the {o} soon .",
        "no {s} ever {v} that {o} .",
    ]
    out = []
    total = 0
    while total < n_chars:
        t = templates[rng.integers(len(templates))]
        s = t.format(
            s=subs[int(_zipf_choice(rng, len(subs), 1)[0])],
            s2=subs[rng.integers(len(subs))],
            v=verbs[int(_zipf_choice(rng, len(verbs), 1)[0])],
            o=objs[int(_zipf_choice(rng, len(objs), 1)[0])],
        )
        out.append(s + " ")
        total += len(s) + 1
    return Corpus("ptb-syn", "".join(out)[:n_chars])


def gen_c4_syn(seed: int = 2, n_chars: int = 200_000) -> Corpus:
    """High-entropy web-crawl analogue: wiki-like text interleaved with
    numbers, urls-ish tokens and shouting — C4 analogue."""
    rng = np.random.default_rng(seed)
    base = gen_wiki_syn(seed=seed + 100, n_chars=n_chars).text
    out = []
    i = 0
    while i < len(base):
        chunk = base[i : i + rng.integers(40, 160)]
        i += len(chunk)
        out.append(chunk)
        r = rng.random()
        if r < 0.15:
            out.append(" " + str(rng.integers(0, 100000)))
        elif r < 0.25:
            out.append(" www." + "".join(_make_words(rng, 1)) + ".com ")
        elif r < 0.32:
            out.append(" " + chunk[: rng.integers(3, 12)].upper() + " ")
    return Corpus("c4-syn", "".join(out)[:n_chars])


CORPUS_BUILDERS = {
    "wiki-syn": gen_wiki_syn,
    "ptb-syn": gen_ptb_syn,
    "c4-syn": gen_c4_syn,
}


# ---------------------------------------------------------------------------
# Task suites (zero-shot multiple choice, length-normalized NLL scoring)
# ---------------------------------------------------------------------------

@dataclass
class Task:
    prompt: str
    options: list[str]
    answer: int  # index into options


@dataclass
class TaskSuite:
    name: str
    tasks: list[Task] = field(default_factory=list)


def _completion_tasks(name: str, corpus: Corpus, seed: int, n: int, plen: int, clen: int,
                      n_opt: int = 2) -> TaskSuite:
    """HellaSwag-style: true continuation vs continuations sampled elsewhere.

    A LM that kept its language statistics prefers the true continuation;
    compression that destroys them drops the suite toward chance.
    """
    rng = np.random.default_rng(seed)
    text = corpus.text
    suite = TaskSuite(name)
    for _ in range(n):
        i = int(rng.integers(0, len(text) - plen - clen - 1))
        prompt = text[i : i + plen]
        true = text[i + plen : i + plen + clen]
        opts = [true]
        while len(opts) < n_opt:
            j = int(rng.integers(0, len(text) - clen - 1))
            alt = text[j : j + clen]
            if alt != true:
                opts.append(alt)
        order = rng.permutation(n_opt)
        options = [opts[k] for k in order]
        suite.tasks.append(Task(prompt, options, int(np.argwhere(order == 0)[0][0])))
    return suite


def _copy_tasks(seed: int, n: int, n_opt: int = 4) -> TaskSuite:
    """Induction-head suite: ` w1 w2 ... w1` → continuation should be `w2`.

    Tiny transformers learn in-context copying early; it is among the first
    abilities low-rank truncation damages (the paper's ARC/OpenbookQA slot).
    """
    rng = np.random.default_rng(seed)
    words = _make_words(rng, 120)
    suite = TaskSuite("copy-syn")
    for _ in range(n):
        seq = [words[i] for i in rng.choice(len(words), size=6, replace=False)]
        key = rng.integers(0, 5)
        prompt = " ".join(seq) + " " + seq[key] + " "
        true = seq[key + 1]
        opts = [true]
        while len(opts) < n_opt:
            alt = words[rng.integers(len(words))]
            if alt not in opts and alt not in seq:
                opts.append(alt)
        order = rng.permutation(n_opt)
        suite.tasks.append(Task(prompt, [opts[k] for k in order],
                                int(np.argwhere(order == 0)[0][0])))
    return suite


def _digit_tasks(seed: int, n: int, n_opt: int = 4) -> TaskSuite:
    """MathQA analogue: arithmetic progressions mod 10 (`2 4 6 →  8`)."""
    rng = np.random.default_rng(seed)
    suite = TaskSuite("mathqa-syn")
    for _ in range(n):
        a, d = int(rng.integers(0, 10)), int(rng.integers(1, 5))
        seq = [(a + d * i) % 10 for i in range(5)]
        prompt = " ".join(str(x) for x in seq[:4]) + " "
        true = str(seq[4])
        opts = [true]
        while len(opts) < n_opt:
            alt = str(int(rng.integers(0, 10)))
            if alt not in opts:
                opts.append(alt)
        order = rng.permutation(n_opt)
        suite.tasks.append(Task(prompt, [opts[k] for k in order],
                                int(np.argwhere(order == 0)[0][0])))
    return suite


def build_task_suites(wiki: Corpus, ptb: Corpus, c4: Corpus, n_per: int = 60,
                      seed: int = 7) -> list[TaskSuite]:
    """Analogue of the paper's 7 commonsense suites (Table 2 columns)."""
    return [
        _completion_tasks("hella-syn", wiki, seed + 1, n_per, plen=64, clen=24),
        _completion_tasks("arc-e-syn", ptb, seed + 2, n_per, plen=48, clen=16),
        _completion_tasks("arc-c-syn", c4, seed + 3, n_per, plen=48, clen=16, n_opt=4),
        _completion_tasks("winog-syn", wiki, seed + 4, n_per, plen=32, clen=12),
        _copy_tasks(seed + 5, n_per),
        _digit_tasks(seed + 6, n_per),
        _completion_tasks("piqa-syn", c4, seed + 8, n_per, plen=40, clen=20),
    ]


def build_mmlu_syn(wiki: Corpus, ptb: Corpus, c4: Corpus, n: int = 80, seed: int = 23) -> TaskSuite:
    """Harder mixed suite (4 options, longer spans) — the MMLU slot."""
    a = _completion_tasks("m1", wiki, seed, n // 3, plen=96, clen=32, n_opt=4).tasks
    b = _completion_tasks("m2", ptb, seed + 1, n // 3, plen=96, clen=32, n_opt=4).tasks
    c = _completion_tasks("m3", c4, seed + 2, n - 2 * (n // 3), plen=96, clen=32, n_opt=4).tasks
    return TaskSuite("mmlu-syn", a + b + c)


# ---------------------------------------------------------------------------
# VLM / VLA synthetic data
# ---------------------------------------------------------------------------

@dataclass
class VqaSample:
    """`image` is a raw feature vector; the model's projector maps it into
    the LM embedding space as a prefix. The hidden caption is recoverable
    from the image features (by construction) so a finetuned model can
    answer; compression degrades the recovery."""
    image: np.ndarray           # (img_dim,)
    question: str
    options: list[str]
    answer: int
    caption: str                # the ground-truth description (for training)


def build_vqa(seed: int, n: int, img_dim: int, n_opt: int = 4) -> list[VqaSample]:
    rng = np.random.default_rng(seed)
    words = _make_words(rng, 64)
    # Fixed linear code: caption word index -> direction in image space.
    code = rng.standard_normal((len(words), img_dim)).astype(np.float32)
    samples = []
    for _ in range(n):
        idx = rng.choice(len(words), size=3, replace=False)
        caption = " ".join(words[i] for i in idx)
        img = code[idx].sum(axis=0) + 0.1 * rng.standard_normal(img_dim)
        opts = [caption]
        while len(opts) < n_opt:
            jdx = rng.choice(len(words), size=3, replace=False)
            alt = " ".join(words[j] for j in jdx)
            if alt not in opts:
                opts.append(alt)
        order = rng.permutation(n_opt)
        samples.append(VqaSample(img.astype(np.float32), "what is shown ? ",
                                 [opts[k] for k in order],
                                 int(np.argwhere(order == 0)[0][0]), caption))
    return samples


@dataclass
class VlaSample:
    image: np.ndarray        # (img_dim,)
    instruction: str
    coords: np.ndarray       # (3,) in [-1, 1]
    angle: float             # scalar in [-1, 1]
    gripper: int             # 0/1


def build_vla(seed: int, n: int, img_dim: int) -> list[VlaSample]:
    """BridgeData-style trace: action is a fixed smooth function of image
    features + instruction hash, so it is learnable and degradation under
    compression is measurable as MSE."""
    rng = np.random.default_rng(seed)
    words = _make_words(rng, 32)
    proj = rng.standard_normal((img_dim, 5)).astype(np.float32) / np.sqrt(img_dim)
    samples = []
    for _ in range(n):
        img = rng.standard_normal(img_dim).astype(np.float32)
        w = words[rng.integers(len(words))]
        instr = f"move to the {w} "
        h = (zlib.crc32(w.encode()) % 1000) / 1000.0 - 0.5
        z = img @ proj
        coords = np.tanh(z[:3] + h)
        angle = float(np.tanh(z[3] - h))
        gripper = int(z[4] + h > 0)
        samples.append(VlaSample(img, instr, coords.astype(np.float32), angle, gripper))
    return samples


# ---------------------------------------------------------------------------
# Binary interchange with rust
# ---------------------------------------------------------------------------

def write_tokbin(path: str, tokens: np.ndarray) -> None:
    """`DOBT1\\0` + u32 count + u16[count] little-endian + u32 crc32(body)."""
    t = tokens.astype(np.uint16)
    body = t.tobytes()
    with open(path, "wb") as f:
        f.write(TOKBIN_MAGIC)
        f.write(np.uint32(len(t)).tobytes())
        f.write(body)
        f.write(np.uint32(zlib.crc32(body) & 0xFFFFFFFF).tobytes())


def read_tokbin(path: str) -> np.ndarray:
    with open(path, "rb") as f:
        raw = f.read()
    assert raw[:6] == TOKBIN_MAGIC, f"bad magic in {path}"
    n = int(np.frombuffer(raw[6:10], dtype=np.uint32)[0])
    body = raw[10 : 10 + 2 * n]
    crc = int(np.frombuffer(raw[10 + 2 * n : 14 + 2 * n], dtype=np.uint32)[0])
    assert zlib.crc32(body) & 0xFFFFFFFF == crc, f"crc mismatch in {path}"
    return np.frombuffer(body, dtype=np.uint16).astype(np.int32)


def suite_to_json(suite: TaskSuite) -> dict:
    return {
        "name": suite.name,
        "tasks": [
            {"prompt": t.prompt, "options": t.options, "answer": t.answer}
            for t in suite.tasks
        ],
    }


def write_suites(path: str, suites: list[TaskSuite]) -> None:
    with open(path, "w") as f:
        json.dump({"suites": [suite_to_json(s) for s in suites]}, f)


def ensure_dir(p: str) -> None:
    os.makedirs(p, exist_ok=True)
