#!/usr/bin/env python3
"""Smoke-drive a running `dobi serve` over the TCP line protocol.

Usage: serve_smoke.py PORT VARIANT [ARTIFACTS_DIR] [SPEC_DRAFT] [NO_CONTROL_PORT]

Sends one non-streaming and one streaming request (both greedy, so the
outputs must agree), asserts token deltas arrive one line each, and that
the streamed terminal text matches the one-shot reply.  Then drives TWO
simultaneous streaming clients (distinct prompts) so the scheduler's
fused multi-session step is exercised end to end: both streams must be
well-ordered and match their own one-shot greedy references.  Also checks
the typed protocol's structured `{"id","error","field"}` replies and the
`list` / `health` control ops.

With ARTIFACTS_DIR (the dir the server was started on), additionally
drives the variant registry end to end: a mid-stream `{"op":"swap"}`
while two streaming clients decode (both must complete every token —
zero dropped sessions), and a swap against a corrupted store (one byte
flipped mid-file) that must be REFUSED while the old variant keeps
serving.  With SPEC_DRAFT (a compressed variant id the server also
serves), drives a speculative streaming session — the draft proposes,
VARIANT verifies — and asserts the output is byte-identical to the pure
VARIANT reference, plus the greedy-only and draft-resolution refusals.

Observability: every generate reply must carry a `"timing"` breakdown
(queue/prefill/decode µs, ttft, tok/s), and after the traffic above the
script pulls `{"op":"metrics"}` (labeled `serve_*{variant=..}` families,
text and Prometheus formats) and `{"op":"trace"}` (Chrome trace-event
JSON) and asserts the recorded span tree covers the request lifecycle —
accept/parse/queue_wait/admission/prefill/step/request, plus
spec_draft/spec_verify when SPEC_DRAFT was exercised.  With
NO_CONTROL_PORT (a second server started `--no-control`), asserts the
metrics/trace ops are refused there while plain generates still serve.

Exits non-zero on any protocol violation — the CI `serve-smoke` job's
pass/fail signal.
"""
import json
import os
import pathlib
import re
import socket
import sys
import threading
import time


def connect(port, attempts=60, delay=0.5):
    last = None
    for _ in range(attempts):
        try:
            return socket.create_connection(("127.0.0.1", port), timeout=30)
        except OSError as e:
            last = e
            time.sleep(delay)
    raise SystemExit(f"server never came up on :{port}: {last}")


def stream_worker(port, variant, prompt, n_tokens, out, errs, idx):
    """One streaming client run in a worker thread: collect the final text
    (or the raised exception — a thread's AssertionError alone would not
    fail the process, and CI would go green on a protocol violation)."""
    try:
        c = connect(port)
        rf = c.makefile("r", encoding="utf-8")
        c.sendall((json.dumps({"variant": variant, "prompt": prompt,
                               "max_tokens": n_tokens, "temperature": 0,
                               "stream": True}) + "\n").encode())
        n = 0
        while True:
            msg = json.loads(rf.readline())
            assert "error" not in msg, f"client {idx} stream errored: {msg}"
            if msg.get("done"):
                out[idx] = msg["text"]
                break
            assert msg["index"] == n, f"client {idx} out-of-order delta: {msg}"
            n += 1
        assert n == n_tokens, f"client {idx}: expected {n_tokens} deltas, got {n}"
        c.close()
    except BaseException as e:  # noqa: BLE001 - re-raised in main
        errs[idx] = e


def run_streams(port, variant, prompts, n_tokens, during=None):
    """Run one streaming client per prompt concurrently, returning their
    final texts.  `during` (if given) runs on the main thread while the
    streams are live — the mid-stream hot-swap hook."""
    texts = [None] * len(prompts)
    errors = [None] * len(prompts)
    threads = [threading.Thread(target=stream_worker,
                                args=(port, variant, p, n_tokens, texts, errors, i))
               for i, p in enumerate(prompts)]
    for t in threads:
        t.start()
    if during is not None:
        during()
    for t in threads:
        t.join()
    for e in errors:
        if e is not None:
            raise e
    return texts


def main():
    port, variant = int(sys.argv[1]), sys.argv[2]
    artifacts = sys.argv[3] if len(sys.argv) > 3 else None
    conn = connect(port)
    rfile = conn.makefile("r", encoding="utf-8")

    def request(obj):
        conn.sendall((json.dumps(obj) + "\n").encode())

    base = {"variant": variant, "prompt": "The ", "max_tokens": 12, "temperature": 0}

    # one-shot
    request(base)
    reply = json.loads(rfile.readline())
    assert "error" not in reply, f"one-shot errored: {reply}"
    text = reply["text"]
    assert reply["tokens_per_s"] > 0, reply
    timing = reply.get("timing")
    assert timing is not None, f"one-shot reply missing timing: {reply}"
    assert timing["tokens"] == 12, timing
    assert timing["prefill_us"] > 0 and timing["decode_us"] > 0, timing
    assert timing["ttft_us"] == timing["queue_us"] + timing["prefill_us"], timing
    assert timing["tokens_per_s"] > 0, timing
    print(f"[smoke] one-shot ok: {len(text)}-char text at {reply['tokens_per_s']:.0f} tok/s, "
          f"ttft {timing['ttft_us']}us")

    # streaming: per-token delta lines, terminal line matches the one-shot
    request({**base, "stream": True})
    n_deltas = 0
    while True:
        line = rfile.readline()
        assert line, "connection closed mid-stream"
        msg = json.loads(line)
        assert "error" not in msg, f"stream errored: {msg}"
        if msg.get("done"):
            assert msg["text"] == text, (
                f"greedy stream diverged from one-shot: {msg['text']!r} != {text!r}")
            assert msg["n_tokens"] == 12, msg
            assert msg["finish"] == "max_tokens", msg
            t = msg.get("timing")
            assert t is not None and t["tokens"] == 12 and t["prefill_us"] > 0, (
                f"streamed terminal line missing/short timing: {msg}")
            break
        assert msg["index"] == n_deltas, f"out-of-order delta: {msg}"
        assert "delta" in msg and "token" in msg, msg
        n_deltas += 1
    assert n_deltas == 12, f"expected 12 delta lines, got {n_deltas}"
    print(f"[smoke] streaming ok: {n_deltas} deltas, final text matches one-shot")

    # malformed line still yields a one-line error object
    conn.sendall(b"not json\n")
    err = json.loads(rfile.readline())
    assert "error" in err, err
    print("[smoke] malformed-request error path ok")

    # two SIMULTANEOUS streaming clients: distinct prompts, long enough
    # generations that their decode windows overlap — the scheduler fuses
    # their trunk walks into one batched step per tick.  Greedy output
    # must be byte-identical to each prompt's one-shot reference (the
    # fused step is bit-identical to serial stepping).
    prompts = ["The quick ", "A different opening "]
    references = []
    for p in prompts:
        request({"variant": variant, "prompt": p, "max_tokens": 48, "temperature": 0})
        ref = json.loads(rfile.readline())
        assert "error" not in ref, f"reference one-shot errored: {ref}"
        references.append(ref["text"])

    texts = run_streams(port, variant, prompts, 48)
    for i, (got, want) in enumerate(zip(texts, references)):
        assert got == want, (
            f"client {i}: concurrent stream diverged from serial one-shot: "
            f"{got!r} != {want!r}")
    if references[0] == references[1]:
        # not a protocol violation (a degenerate synth model could emit
        # prompt-independent streams), but worth surfacing
        print("[smoke] warning: both prompts produced identical text")
    print("[smoke] two concurrent streaming clients ok: fused decode matches serial")

    # --- speculative decoding (opt-in via the SPEC_DRAFT argv) ---
    spec_draft = sys.argv[4] if len(sys.argv) > 4 else None
    if spec_draft is not None:
        # the parity guarantee: the draft proposes, the target verifies,
        # greedy output must equal the pure-target reference byte for byte
        spec_req = {"variant": variant, "prompt": prompts[0], "max_tokens": 48,
                    "temperature": 0, "stream": True,
                    "spec": {"draft": spec_draft, "k": 4}}
        request(spec_req)
        n = 0
        while True:
            msg = json.loads(rfile.readline())
            assert "error" not in msg, f"spec stream errored: {msg}"
            if msg.get("done"):
                assert msg["text"] == references[0], (
                    "speculative stream diverged from the pure-target "
                    f"reference: {msg['text']!r} != {references[0]!r}")
                break
            assert msg["index"] == n, f"spec stream out-of-order delta: {msg}"
            n += 1
        assert n == 48, f"spec stream: expected 48 deltas, got {n}"
        # spec is greedy-only and the draft must resolve: loud refusals,
        # never a silent fallback to plain decode
        request({**spec_req, "stream": False, "temperature": 0.7})
        err = json.loads(rfile.readline())
        assert "error" in err and "greedy" in err["error"], (
            f"non-greedy spec must be refused: {err}")
        request({**spec_req, "stream": False,
                 "spec": {"draft": "tiny/ghost", "k": 4}})
        err = json.loads(rfile.readline())
        assert "error" in err and "draft" in err["error"], (
            f"unknown draft must be refused: {err}")
        # typed parse errors name the spec sub-field
        request({**base, "spec": {"k": 2}})
        err = json.loads(rfile.readline())
        assert err.get("field") == "spec.draft", err
        request({**base, "spec": 5})
        err = json.loads(rfile.readline())
        assert err.get("field") == "spec", err
        print("[smoke] speculative decode ok: byte-identical to the pure "
              "target, greedy-only + draft resolution enforced")

    # typed protocol: malformed lines answer structured errors naming the
    # offending field, and the connection stays usable afterwards
    for bad, field in [({"op": "teleport"}, "op"),
                       ({"op": "swap"}, "variant"),
                       ({"variant": variant, "prompt": "x",
                         "max_tokens": "32"}, "max_tokens"),
                       ({"variant": variant, "prompt": "x",
                         "max_tokens": 2, "image": "nope"}, "image"),
                       ({"variant": variant, "prompt": "x",
                         "stream": "yes"}, "stream")]:
        request(bad)
        err = json.loads(rfile.readline())
        assert "error" in err, f"malformed line must error: {err}"
        assert err.get("field") == field, (
            f"expected field {field!r} on {bad}: {err}")
    print("[smoke] typed field errors ok: each names the offending field")

    # control plane: health + the variant table with provenance
    request({"op": "health"})
    health = json.loads(rfile.readline())
    assert health.get("ok") is True, f"health not ok: {health}"
    request({"op": "list"})
    table = json.loads(rfile.readline())
    mine = [v for v in table["variants"] if v["variant"] == variant]
    assert mine, f"served variant missing from list: {table}"
    generation = mine[0]["generation"]
    assert generation >= 1, mine
    print(f"[smoke] control plane ok: generation {generation}, "
          f"sha {str(mine[0].get('store_sha256'))[:12]}")

    # --- observability: labeled metrics + the request-lifecycle trace ---
    request({"op": "metrics"})
    met = json.loads(rfile.readline())
    assert met.get("op") == "metrics" and met.get("format") == "text", met
    mtext = met["text"]
    for needle in (f'serve_sessions_opened{{variant="{variant}"}}',
                   f'serve_prefill_seconds{{variant="{variant}"}}',
                   f'serve_tokens_emitted{{variant="{variant}"}}',
                   'reason="max_tokens"'):
        assert needle in mtext, f"metrics text missing {needle!r}:\n{mtext}"
    opened = sum(int(line.split()[-1]) for line in mtext.splitlines()
                 if line.startswith("serve_sessions_opened{"))
    assert opened >= 6, f"expected >= 6 sessions opened by now, saw {opened}"
    request({"op": "metrics", "format": "prom"})
    prom = json.loads(rfile.readline())
    assert prom.get("format") == "prom", prom
    ptext = prom["text"]
    for needle in ("# TYPE serve_sessions_opened counter",
                   "# TYPE serve_active_sessions gauge",
                   "# TYPE serve_prefill_seconds summary",
                   'quantile="0.5"'):
        assert needle in ptext, f"prom exposition missing {needle!r}:\n{ptext}"
    print(f"[smoke] metrics ok: {opened} sessions opened across labeled families")

    # Every live family must be declared in rust/src/metrics/names.rs — the
    # single source of truth the `dobi lint` metric-drift rule enforces.
    names_rs = pathlib.Path(__file__).resolve().parent.parent / (
        "rust/src/metrics/names.rs")
    if names_rs.exists():
        declared = set(re.findall(r'const\s+\w+\s*:\s*&str\s*=\s*"([a-z_]+)"',
                                  names_rs.read_text()))
        assert declared, f"no metric constants parsed from {names_rs}"
        live = {line.split("{")[0].split()[0] for line in mtext.splitlines()
                if line.strip()}
        undeclared = {f for f in live if f.startswith("serve_")} - declared
        assert not undeclared, (
            f"live metric families missing from metrics::names: {undeclared}")
        print(f"[smoke] metric names ok: {len(declared)} declared families "
              f"cover all live serve_* output")
    else:
        print(f"[smoke] metric names check skipped: {names_rs} not found")

    request({"op": "trace"})
    tr = json.loads(rfile.readline())
    assert tr.get("op") == "trace" and tr.get("enabled") is True, tr
    assert tr["trace"]["displayTimeUnit"] == "ms", tr["trace"]
    events = tr["trace"]["traceEvents"]
    assert events, "trace ring drained empty after traffic"
    names = {e["name"] for e in events}
    want_spans = {"accept", "parse", "queue_wait", "admission",
                  "prefill", "request"}
    if spec_draft is not None:
        want_spans |= {"spec_draft", "spec_verify"}
    missing = want_spans - names
    assert not missing, (
        f"trace span tree incomplete, missing {missing}: {sorted(names)}")
    assert any(n in names for n in ("step", "fused_step")), (
        f"no decode step spans in trace: {sorted(names)}")
    for e in events:
        assert e["ph"] == "X" and isinstance(e["ts"], (int, float)), e
        assert isinstance(e["dur"], (int, float)) and "tid" in e, e
    n_request_spans = sum(1 for e in events if e["name"] == "request")
    assert n_request_spans > 0, "no completed request spans in trace"
    print(f"[smoke] trace ok: {len(events)} events, {n_request_spans} request "
          f"spans, phases {sorted(names)}")

    # Every recorded phase must be declared in rust/src/trace/phases.rs
    # (the trace-phase-pairing rule's constants module); the exporter tags
    # known phases cat="serve".
    phases_rs = pathlib.Path(__file__).resolve().parent.parent / (
        "rust/src/trace/phases.rs")
    if phases_rs.exists():
        known = set(re.findall(r'const\s+\w+\s*:\s*&str\s*=\s*"([a-z_]+)"',
                               phases_rs.read_text()))
        assert known, f"no phase constants parsed from {phases_rs}"
        unknown = names - known
        assert not unknown, f"trace phases missing from trace::phases: {unknown}"
        assert all(e.get("cat") == "serve" for e in events), (
            "declared phases must export with cat='serve'")
        print(f"[smoke] phase names ok: {len(known)} declared phases cover "
              f"the trace")
    else:
        print(f"[smoke] phase names check skipped: {phases_rs} not found")

    # --- `--no-control` twin: metrics/trace refused, generate still serves ---
    nc_port = int(sys.argv[5]) if len(sys.argv) > 5 else None
    if nc_port is not None:
        nc = connect(nc_port)
        ncf = nc.makefile("r", encoding="utf-8")
        for op in ("metrics", "trace"):
            nc.sendall((json.dumps({"op": op}) + "\n").encode())
            err = json.loads(ncf.readline())
            assert "error" in err, f"--no-control must refuse {op!r}: {err}"
        nc.sendall((json.dumps(base) + "\n").encode())
        still = json.loads(ncf.readline())
        assert "error" not in still, f"--no-control generate failed: {still}"
        assert still["text"] == text, (
            "no-control twin decoded differently on the same store: "
            f"{still['text']!r} != {text!r}")
        nc.close()
        print("[smoke] --no-control ok: metrics/trace refused, generate serves")

    if artifacts is None:
        print("[smoke] no artifacts dir given: skipping hot-swap sections")
        return

    def list_variant():
        request({"op": "list"})
        table = json.loads(rfile.readline())
        return next(v for v in table["variants"] if v["variant"] == variant)

    # --- mid-stream hot swap: two live streaming clients, zero drops ---
    # The swap re-installs the same bytes, so every stream must emit its
    # greedy reference text no matter how the swap interleaves.
    swap_reply = {}

    def do_swap():
        request({"op": "swap", "variant": variant})
        swap_reply.update(json.loads(rfile.readline()))

    texts = run_streams(port, variant, prompts, 48, during=do_swap)
    assert "error" not in swap_reply, f"mid-stream swap refused: {swap_reply}"
    assert swap_reply["generation"] == generation + 1, swap_reply
    for i, (got, want) in enumerate(zip(texts, references)):
        assert got == want, (
            f"client {i}: stream diverged across the hot swap: {got!r} != {want!r}")
    generation = swap_reply["generation"]
    # drain completes: no session stays pinned to the old generation
    deadline = time.time() + 30
    while True:
        pinned = sum(d["sessions"] for d in list_variant()["draining"])
        if pinned == 0:
            break
        assert time.time() < deadline, "old-generation sessions never drained"
        time.sleep(0.2)
    print("[smoke] mid-stream hot swap ok: zero dropped sessions, "
          f"generation {generation}, drain complete")

    # --- corrupted-store swap: must be refused, old variant keeps serving ---
    manifest = json.load(open(os.path.join(artifacts, "manifest.json")))
    weights = next(v for v in manifest["variants"]
                   if v["id"] == variant)["weights"]
    store_path = os.path.join(artifacts, weights)
    with open(store_path, "rb") as f:
        clean = f.read()
    bad = bytearray(clean)
    bad[len(bad) // 2] ^= 0x40
    with open(store_path, "wb") as f:
        f.write(bytes(bad))
    try:
        request({"op": "swap", "variant": variant})
        refusal = json.loads(rfile.readline())
        assert "error" in refusal, (
            f"swap must refuse a corrupted store, got: {refusal}")
        assert list_variant()["generation"] == generation, (
            "refused swap must not bump the generation")
        # the old release keeps serving, byte-identical
        request(base)
        still = json.loads(rfile.readline())
        assert "error" not in still, f"serving broke after refused swap: {still}"
        assert still["text"] == text, "old variant's decode changed after refused swap"
    finally:
        with open(store_path, "wb") as f:
            f.write(clean)
    # restored bytes swap cleanly
    request({"op": "swap", "variant": variant})
    ok = json.loads(rfile.readline())
    assert "error" not in ok, f"restored store must swap: {ok}"
    assert ok["generation"] == generation + 1, ok
    print("[smoke] corrupted-store swap refused ok: old variant kept serving, "
          "restored store swapped clean")


if __name__ == "__main__":
    main()
