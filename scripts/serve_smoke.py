#!/usr/bin/env python3
"""Smoke-drive a running `dobi serve` over the TCP line protocol.

Usage: serve_smoke.py PORT VARIANT

Sends one non-streaming and one streaming request (both greedy, so the
outputs must agree), asserts token deltas arrive one line each, and that
the streamed terminal text matches the one-shot reply.  Then drives TWO
simultaneous streaming clients (distinct prompts) so the scheduler's
fused multi-session step is exercised end to end: both streams must be
well-ordered and match their own one-shot greedy references.  Exits
non-zero on any protocol violation — the CI `serve-smoke` job's
pass/fail signal.
"""
import json
import socket
import sys
import threading
import time


def connect(port, attempts=60, delay=0.5):
    last = None
    for _ in range(attempts):
        try:
            return socket.create_connection(("127.0.0.1", port), timeout=30)
        except OSError as e:
            last = e
            time.sleep(delay)
    raise SystemExit(f"server never came up on :{port}: {last}")


def main():
    port, variant = int(sys.argv[1]), sys.argv[2]
    conn = connect(port)
    rfile = conn.makefile("r", encoding="utf-8")

    def request(obj):
        conn.sendall((json.dumps(obj) + "\n").encode())

    base = {"variant": variant, "prompt": "The ", "max_tokens": 12, "temperature": 0}

    # one-shot
    request(base)
    reply = json.loads(rfile.readline())
    assert "error" not in reply, f"one-shot errored: {reply}"
    text = reply["text"]
    assert reply["tokens_per_s"] > 0, reply
    print(f"[smoke] one-shot ok: {len(text)}-char text at {reply['tokens_per_s']:.0f} tok/s")

    # streaming: per-token delta lines, terminal line matches the one-shot
    request({**base, "stream": True})
    n_deltas = 0
    while True:
        line = rfile.readline()
        assert line, "connection closed mid-stream"
        msg = json.loads(line)
        assert "error" not in msg, f"stream errored: {msg}"
        if msg.get("done"):
            assert msg["text"] == text, (
                f"greedy stream diverged from one-shot: {msg['text']!r} != {text!r}")
            assert msg["n_tokens"] == 12, msg
            assert msg["finish"] == "max_tokens", msg
            break
        assert msg["index"] == n_deltas, f"out-of-order delta: {msg}"
        assert "delta" in msg and "token" in msg, msg
        n_deltas += 1
    assert n_deltas == 12, f"expected 12 delta lines, got {n_deltas}"
    print(f"[smoke] streaming ok: {n_deltas} deltas, final text matches one-shot")

    # malformed line still yields a one-line error object
    conn.sendall(b"not json\n")
    err = json.loads(rfile.readline())
    assert "error" in err, err
    print("[smoke] malformed-request error path ok")

    # two SIMULTANEOUS streaming clients: distinct prompts, long enough
    # generations that their decode windows overlap — the scheduler fuses
    # their trunk walks into one batched step per tick.  Greedy output
    # must be byte-identical to each prompt's one-shot reference (the
    # fused step is bit-identical to serial stepping).
    prompts = ["The quick ", "A different opening "]
    references = []
    for p in prompts:
        request({"variant": variant, "prompt": p, "max_tokens": 48, "temperature": 0})
        ref = json.loads(rfile.readline())
        assert "error" not in ref, f"reference one-shot errored: {ref}"
        references.append(ref["text"])

    def stream_one(prompt, out, errs, idx):
        # runs in a worker thread: exceptions are collected and re-raised
        # by main after join — a thread's AssertionError alone would not
        # fail the process (CI would go green on a protocol violation)
        try:
            c = connect(port)
            rf = c.makefile("r", encoding="utf-8")
            c.sendall((json.dumps({"variant": variant, "prompt": prompt,
                                   "max_tokens": 48, "temperature": 0,
                                   "stream": True}) + "\n").encode())
            n = 0
            while True:
                msg = json.loads(rf.readline())
                assert "error" not in msg, f"client {idx} stream errored: {msg}"
                if msg.get("done"):
                    out[idx] = msg["text"]
                    break
                assert msg["index"] == n, f"client {idx} out-of-order delta: {msg}"
                n += 1
            assert n == 48, f"client {idx}: expected 48 deltas, got {n}"
            c.close()
        except BaseException as e:  # noqa: BLE001 - re-raised in main
            errs[idx] = e

    texts = [None, None]
    errors = [None, None]
    threads = [threading.Thread(target=stream_one, args=(p, texts, errors, i))
               for i, p in enumerate(prompts)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    for e in errors:
        if e is not None:
            raise e
    for i, (got, want) in enumerate(zip(texts, references)):
        assert got == want, (
            f"client {i}: concurrent stream diverged from serial one-shot: "
            f"{got!r} != {want!r}")
    if references[0] == references[1]:
        # not a protocol violation (a degenerate synth model could emit
        # prompt-independent streams), but worth surfacing
        print("[smoke] warning: both prompts produced identical text")
    print("[smoke] two concurrent streaming clients ok: fused decode matches serial")


if __name__ == "__main__":
    main()
