// Suppression fixture: both placements (line above, same line) with a
// rule name and a reason — the findings they cover must be dropped.
pub fn boot(x: Option<u32>, y: Option<u32>) -> u32 {
    // dobi-lint: allow(panic-freedom, startup path runs before any session exists)
    let a = x.unwrap();
    let b = y.unwrap(); // dobi-lint: allow(panic-freedom, same startup invariant)
    a + b
}
