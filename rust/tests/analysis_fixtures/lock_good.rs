// lock-order negative fixture: nested acquisitions in declared order
// (registry, then metrics, then trace) — no findings expected. The rule
// is deliberately drop-blind (source order within one fn IS the order),
// so even sequential sections must respect registry -> metrics -> trace.
pub fn tick(&self) {
    let r = self.registry.lock().unwrap_or_else(poison);
    let m = lock_or_recover(&self.metrics);
    let t = lock_or_recover(&self.slot);
    drop((r, m, t));
}

pub fn same_class_twice(&self) {
    let c = self.counters.lock().unwrap_or_else(poison);
    let h = lock_or_recover(&self.histograms);
    drop((c, h));
}
