// flag-drift fixture stand-in for rust/src/config/mod.rs: declares every
// config field the FLAG_MAP targets.
pub struct ServeConfig {
    pub max_batch: usize,
    pub batch_deadline_us: usize,
    pub queue_depth: usize,
    pub max_sessions: usize,
    pub decode_threads: usize,
    pub spec_draft: Option<String>,
    pub spec_k: usize,
    pub trace_buffer: usize,
}

pub struct CompressConfig {
    pub ratio: f64,
    pub budget: Option<usize>,
    pub precision: String,
    pub calib_batches: usize,
    pub calib_batch: usize,
    pub calib_seq: usize,
    pub seed: u64,
    pub k_min: usize,
    pub alloc: String,
    pub train_iters: usize,
    pub train_lr: f64,
    pub svd_threads: usize,
}
