// protocol-drift positive fixture: "health" is declared in PROTOCOL_OPS
// but no parse code ever matches it.
pub const PROTOCOL_OPS: &[&str] = &["generate", "swap", "health"];
pub const PROTOCOL_FIELDS: &[&str] = &["op", "prompt"];

pub fn parse_request(line: &str) -> u32 {
    let op = field(line, "op");
    let prompt = field(line, "prompt");
    if op == "generate" && !prompt.is_empty() {
        1
    } else if op == "swap" {
        2
    } else {
        0
    }
}
