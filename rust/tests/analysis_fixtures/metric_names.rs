// metric-drift fixture stand-in for rust/src/metrics/names.rs.
pub const OPENED: &str = "serve_sessions_opened";
pub const DEPTH: &str = "serve_queue_depth";
