// metric-drift positive fixture: a compress_* family spelled as a
// string literal instead of a names:: constant (plus clean uses so
// CTARGETS/CPHASE do not show up as unused).
use crate::metrics::names::{CPHASE, CTARGETS};

pub fn observe(reg: &Registry) {
    reg.counter_with(CTARGETS, &[("variant", "v")]).add(1);
    reg.histogram(CPHASE).observe(d);
    reg.counter("compress_rogue_total").inc(1);
}
