// metric-drift positive fixture (compress namespace): CSTALE is
// undocumented in the README section and never referenced by any other
// file.
pub const CTARGETS: &str = "compress_targets";
pub const CPHASE: &str = "compress_phase_seconds";
pub const CSTALE: &str = "compress_stale_gauge";
