// protocol-drift fixture stand-in for rust/src/serve/stream.rs: a tiny
// v1 vocabulary, every token of which is actually parsed.
pub const PROTOCOL_OPS: &[&str] = &["generate", "swap"];
pub const PROTOCOL_FIELDS: &[&str] = &["op", "prompt"];

pub fn parse_request(line: &str) -> u32 {
    let op = field(line, "op");
    let prompt = field(line, "prompt");
    if op == "generate" && !prompt.is_empty() {
        1
    } else if op == "swap" {
        2
    } else {
        0
    }
}
