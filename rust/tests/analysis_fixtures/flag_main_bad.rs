// flag-drift positive fixture: reads an undeclared flag ("mystery-flag",
// in neither FLAG_MAP nor FLAG_INFRA nor the README) and drops the
// "seed" read so its FLAG_MAP entry goes stale.
fn serve(args: &Args) {
    let _port = args.get_or("port", "7433");
    let _mb = args.usize_or("max-batch", 8);
    let _dl = args.usize_or("deadline-us", 500);
    let _qd = args.usize_or("queue-depth", 64);
    let _ms = args.usize_or("max-sessions", 8);
    let _dt = args.usize_or("decode-threads", 1);
    let _sd = args.get("spec-draft");
    let _sk = args.usize_or("spec-k", 4);
    let _tb = args.usize_or("trace-buffer", 4096);
    let _my = args.get("mystery-flag");
}

fn compress(args: &Args) {
    let _r = args.f64_or("ratio", 0.4);
    let _b = args.get("budget");
    let _p = args.get_or("precision", "q8");
    let _cb = args.usize_or("calib-batches", 8);
    let _cz = args.usize_or("calib-batch", 4);
    let _cs = args.usize_or("calib-seq", 64);
    let _km = args.usize_or("k-min", 8);
    let _al = args.get_or("alloc", "waterfill");
    let _ti = args.usize_or("train-iters", 200);
    let _tl = args.f64_or("train-lr", 0.05);
    let _st = args.usize_or("svd-threads", 1);
}
