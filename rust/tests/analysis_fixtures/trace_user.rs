// trace-phase-pairing fixture: a clean recorder — phases always arrive
// as phases:: constants, never string literals.
use crate::trace::phases;

pub fn record(buf: &TraceBuffer, t0: u64, t1: u64) {
    buf.push_span(phases::PREFILL, 1, t0, t1, detail);
    buf.push_instant(phases::STEP, 1, t1, detail);
}
