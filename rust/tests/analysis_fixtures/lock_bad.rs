// lock-order positive fixture: both acquisition forms taken against the
// declared registry -> metrics -> trace order. Two fns, one deny each.
pub fn tick(slot: &Mutex<u32>, metrics: &Mutex<u32>) {
    let mut s = slot.lock().unwrap_or_else(poison);
    let m = metrics.lock().unwrap_or_else(poison);
    *s += *m;
}

pub fn drain(&self) {
    let g = lock_or_recover(&self.slots);
    let r = lock_or_recover(&self.registry);
    g.extend(r.iter());
}
