// Suppression-hygiene fixture: an unknown rule name and a reasonless
// allow — each must surface as a deny-level "suppression" finding on a
// full run.
pub fn quiet() -> u32 {
    let a = 1; // dobi-lint: allow(no-such-rule, typo'd rule names must not pass)
    let b = 2; // dobi-lint: allow(panic-freedom)
    a + b
}
