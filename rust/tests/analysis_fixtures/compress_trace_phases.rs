// trace-phase-pairing fixture stand-in for rust/src/trace/phases.rs
// declaring compress_* lifecycle phases. Also doubles as the
// metric-drift exemption fixture: these string consts are phase values,
// not bare metric-family literals.
pub const CRUN: &str = "compress_run";
pub const CSVD: &str = "compress_svd";

pub const ALL: &[&str] = &[CRUN, CSVD];
