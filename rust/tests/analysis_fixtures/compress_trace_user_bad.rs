// trace-phase-pairing positive fixture: a compress phase recorded as a
// bare string literal instead of a phases:: constant.
use crate::trace::phases;

pub fn record(buf: &TraceBuffer, t0: u64, t1: u64) {
    buf.push_span(phases::CRUN, 0, t0, t1, detail);
    buf.push_span("compress_svd", 0, t0, t1, detail);
}
