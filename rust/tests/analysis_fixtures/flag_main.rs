// flag-drift fixture stand-in for rust/src/main.rs: fn serve / fn
// compress read every FLAG_MAP flag plus one infra flag through the Args
// accessors, exactly the shape the rule scans for.
fn serve(args: &Args) {
    let _port = args.get_or("port", "7433");
    let _mb = args.usize_or("max-batch", 8);
    let _dl = args.usize_or("deadline-us", 500);
    let _qd = args.usize_or("queue-depth", 64);
    let _ms = args.usize_or("max-sessions", 8);
    let _dt = args.usize_or("decode-threads", 1);
    let _sd = args.get("spec-draft");
    let _sk = args.usize_or("spec-k", 4);
    let _tb = args.usize_or("trace-buffer", 4096);
}

fn compress(args: &Args) {
    let _r = args.f64_or("ratio", 0.4);
    let _b = args.get("budget");
    let _p = args.get_or("precision", "q8");
    let _cb = args.usize_or("calib-batches", 8);
    let _cz = args.usize_or("calib-batch", 4);
    let _cs = args.usize_or("calib-seq", 64);
    let _se = args.usize_or("seed", 0);
    let _km = args.usize_or("k-min", 8);
    let _al = args.get_or("alloc", "waterfill");
    let _ti = args.usize_or("train-iters", 200);
    let _tl = args.f64_or("train-lr", 0.05);
    let _st = args.usize_or("svd-threads", 1);
    let _to = args.get("trace-out");
    let _pg = args.has("progress");
}
