// panic-freedom positive fixture: three deny sites, one warn site, and a
// #[cfg(test)] block whose unwrap must NOT be flagged.
pub fn handle(x: Option<u32>, v: &[u32], m: &std::sync::Mutex<u32>) -> u32 {
    let a = x.unwrap();
    let b = *m.lock().expect("poisoned");
    if v.is_empty() {
        panic!("empty input");
    }
    let c = v[0];
    a + b + c
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_in_tests_is_fine() {
        let _ = Some(1).unwrap();
    }
}
