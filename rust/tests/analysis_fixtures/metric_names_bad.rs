// metric-drift positive fixture: STALE is undocumented in the README
// section and never referenced by any other file.
pub const OPENED: &str = "serve_sessions_opened";
pub const DEPTH: &str = "serve_queue_depth";
pub const STALE: &str = "serve_stale_gauge";
