// metric-drift fixture stand-in for rust/src/metrics/names.rs with
// compress_* families — pins the rule's coverage of the compression
// pipeline's metric namespace.
pub const CTARGETS: &str = "compress_targets";
pub const CPHASE: &str = "compress_phase_seconds";
