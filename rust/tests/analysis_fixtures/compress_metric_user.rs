// metric-drift fixture: a clean compress-side consumer — references
// every names:: constant and spells no family as a string literal.
use crate::metrics::names::{CPHASE, CTARGETS};

pub fn observe(reg: &Registry) {
    reg.counter_with(CTARGETS, &[("variant", "v")]).add(1);
    reg.histogram(CPHASE).observe(d);
}
