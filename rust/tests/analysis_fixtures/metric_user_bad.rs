// metric-drift positive fixture: a family spelled as a string literal
// instead of a names:: constant (plus clean uses so OPENED/DEPTH do not
// show up as unused).
use crate::metrics::names::{DEPTH, OPENED};

pub fn observe(reg: &Registry) {
    reg.counter(OPENED).inc(1);
    reg.gauge(DEPTH).set(0);
    reg.counter("serve_rogue_total").inc(1);
}
