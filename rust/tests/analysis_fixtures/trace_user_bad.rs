// trace-phase-pairing positive fixture: a record site passing a string
// literal instead of a phases:: constant.
pub fn record(buf: &TraceBuffer, t0: u64, t1: u64) {
    buf.push_span("prefill", 1, t0, t1, detail);
}
