// trace-phase-pairing fixture: a clean compress-side recorder — phases
// always arrive as phases:: constants, never string literals.
use crate::trace::phases;

pub fn record(buf: &TraceBuffer, t0: u64, t1: u64) {
    buf.push_span(phases::CRUN, 0, t0, t1, detail);
    buf.push_span(phases::CSVD, 0, t0, t1, detail);
}
