// trace-phase-pairing positive fixture: GHOST is missing from ALL (and
// from the README table), and ALL references MISSING which is no const.
pub const PREFILL: &str = "prefill";
pub const STEP: &str = "step";
pub const GHOST: &str = "ghost";

pub const ALL: &[&str] = &[PREFILL, STEP, MISSING];
