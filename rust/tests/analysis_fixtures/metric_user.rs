// metric-drift fixture: a clean consumer — references every names::
// constant and spells no family as a string literal.
use crate::metrics::names::{DEPTH, OPENED};

pub fn observe(reg: &Registry) {
    reg.counter(OPENED).inc(1);
    reg.gauge(DEPTH).set(0);
}
