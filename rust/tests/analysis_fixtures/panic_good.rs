// panic-freedom negative fixture: the same shape of function written the
// way the serve request path must be written — no findings expected.
pub fn handle(x: Option<u32>, v: &[u32]) -> u32 {
    let Some(a) = x else {
        return 0;
    };
    let c = v.first().copied().unwrap_or(0);
    a + c
}
