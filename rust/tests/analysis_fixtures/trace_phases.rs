// trace-phase-pairing fixture stand-in for rust/src/trace/phases.rs.
pub const PREFILL: &str = "prefill";
pub const STEP: &str = "step";

pub const ALL: &[&str] = &[PREFILL, STEP];
