//! End-to-end coverage of the incremental decode runtime: KV-parity
//! against the full forward (the acceptance criterion), the
//! continuous-batching scheduler against serial greedy decode, and the
//! streaming TCP protocol — all over synthetic artifacts, no PJRT.

use std::sync::Arc;

use dobi::compress::{append_artifacts, calib, compress_model, write_artifacts};
use dobi::config::{CompressConfig, Manifest, Precision, ServeConfig};
use dobi::lowrank::synth::{tiny_manifest_json, tiny_store_tensors, SynthStyle, TinyDims};
use dobi::lowrank::FactorizedModel;
use dobi::mathx::argmax;
use dobi::serve::{DecodeSession, FinishReason, GenEvent, ServeRuntime, SessionRequest,
                  SpecParams};
use dobi::storage::{write_store, Store};
use dobi::tokenizer::ByteTokenizer;

/// vocab 256 so the byte tokenizer's ids are always in range.
fn dims() -> TinyDims {
    TinyDims { vocab: 256, d: 24, heads: 2, layers: 2, ff: 32 }
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0f32, f32::max)
}

/// Full-forward last-position logits — the incremental path's reference.
fn full_last_logits(m: &FactorizedModel, ctx: &[i32]) -> Vec<f32> {
    let s = ctx.len();
    let out = m.forward(1, s, ctx, None).unwrap();
    out[(s - 1) * m.vocab..s * m.vocab].to_vec()
}

/// The acceptance parity check: `prefill` + `step` logits must match the
/// full forward within 1e-4 at every decoded position.
fn assert_kv_parity(model: &FactorizedModel, prompt: &[i32], n_decode: usize, tag: &str) {
    let mut session = DecodeSession::new(1, tag, model, prompt.len() + n_decode + 1);
    let mut logits = session.prefill(model, prompt, None).unwrap();
    let mut ctx = prompt.to_vec();
    let want = full_last_logits(model, &ctx);
    let d0 = max_abs_diff(&logits, &want);
    assert!(d0 < 1e-4, "{tag}: prefill logits off by {d0}");
    for i in 0..n_decode {
        let next = argmax(&logits) as i32;
        ctx.push(next);
        logits = session.step(model, next).unwrap();
        let want = full_last_logits(model, &ctx);
        let d = max_abs_diff(&logits, &want);
        assert!(d < 1e-4, "{tag}: step {i} logits off by {d}");
        // and the greedy choice both paths would make next is identical
        assert_eq!(argmax(&logits), argmax(&want), "{tag}: greedy divergence at step {i}");
    }
}

#[test]
fn kv_parity_on_synth_dense_model() {
    let model = tiny_model_dense();
    let prompt: Vec<i32> = "The quick brown fox".bytes().map(|b| b as i32).collect();
    assert_kv_parity(&model, &prompt, 12, "synth-dense");
}

fn tiny_model_dense() -> FactorizedModel {
    dobi::lowrank::synth::tiny_model(TinyDims::nano(), 0, false)
}

#[test]
fn kv_parity_on_compressed_q8_fixture() {
    // the `dobi compress --synth` fixture: nano dense -> ratio-0.4 q8
    // store -> reload through the native loader (int8 decode included)
    let dense = tiny_model_dense();
    let corpus = calib::synth_calib_tokens(dense.vocab, 4096, 11);
    let cfg = CompressConfig { ratio: 0.4, precision: Precision::Q8, ..Default::default() };
    let art = compress_model(&dense, "tiny", &cfg, &corpus).unwrap();
    let dir = std::env::temp_dir().join("dobi_serve_it_q8_fixture");
    let _ = std::fs::remove_dir_all(&dir);
    write_artifacts(&dir, &art).unwrap();
    let m = Manifest::load(&dir).unwrap();
    let v = m.variant(&art.variant_id).unwrap();
    let store = Store::open(&m.path(&v.weights)).unwrap();
    let model = FactorizedModel::from_store(&m.models["tiny"], v, &store).unwrap();
    let prompt: Vec<i32> = "Dobi decodes incrementally".bytes().map(|b| b as i32).collect();
    assert_kv_parity(&model, &prompt, 12, "compress-q8");
}

// ---------------------------------------------------------------------------
// Scheduler: continuous batching vs serial greedy
// ---------------------------------------------------------------------------

fn build_artifacts(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("dobi_serve_it_{tag}"));
    std::fs::create_dir_all(&dir).unwrap();
    write_store(&dir.join("dense.dobiw"),
                &tiny_store_tensors(dims(), 0, SynthStyle::DenseF32)).unwrap();
    write_store(&dir.join("q8.dobiw"),
                &tiny_store_tensors(dims(), 0, SynthStyle::FactorQ8)).unwrap();
    std::fs::write(
        dir.join("manifest.json"),
        tiny_manifest_json(dims(), 0, &[
            ("tiny/dense", "dense", 1.0, "dense.dobiw"),
            ("tiny/dobi_60", "factorized", 0.6, "q8.dobiw"),
        ]),
    )
    .unwrap();
    dir
}

#[test]
fn concurrent_sessions_match_serial_greedy_decode() {
    let dir = build_artifacts("sched");
    // serial reference: one session at a time, straight on the model
    let m = Manifest::load(&dir).unwrap();
    let prompts: Vec<Vec<i32>> = [
        "a", "some longer prompt here", "mid-size words", "yet another different one!",
    ]
    .iter()
    .map(|p| ByteTokenizer.encode(p))
    .collect();
    let n_tokens = 10usize;
    let mut serial: Vec<Vec<i32>> = Vec::new();
    for (vi, prompt) in prompts.iter().enumerate() {
        let variant = if vi % 2 == 0 { "tiny/dense" } else { "tiny/dobi_60" };
        let v = m.variant(variant).unwrap();
        let store = Store::open(&m.path(&v.weights)).unwrap();
        let model = FactorizedModel::from_store(&m.models["tiny"], v, &store).unwrap();
        let mut session = DecodeSession::new(1, variant, &model, 256);
        let mut logits = session.prefill(&model, prompt, None).unwrap();
        let mut toks = Vec::new();
        for _ in 0..n_tokens {
            let next = argmax(&logits) as i32;
            toks.push(next);
            if toks.len() < n_tokens {
                logits = session.step(&model, next).unwrap();
            }
        }
        serial.push(toks);
    }
    // concurrent: all four sessions in flight at once (max_sessions 2 so
    // admission happens mid-decode of earlier sessions — continuous
    // batching, not one-shot fan-out)
    let ids = vec!["tiny/dense".to_string(), "tiny/dobi_60".to_string()];
    let rt = Arc::new(ServeRuntime::start(
        dir,
        &ids,
        ServeConfig { max_sessions: 2, ..Default::default() },
    )
    .unwrap());
    let mut handles = Vec::new();
    for (vi, prompt) in prompts.iter().enumerate() {
        let rt = rt.clone();
        let prompt = prompt.clone();
        let variant = if vi % 2 == 0 { "tiny/dense" } else { "tiny/dobi_60" }.to_string();
        handles.push(std::thread::spawn(move || {
            rt.generate(&variant, &prompt, n_tokens, 0.0, 1 + vi as u64).unwrap()
        }));
    }
    let concurrent: Vec<Vec<i32>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    assert_eq!(concurrent, serial,
               "interleaved decoding must not change any session's greedy tokens");
    let st = rt.stats();
    assert_eq!(st.sessions_finished, prompts.len() as u64);
    assert_eq!(st.tokens_emitted, (prompts.len() * n_tokens) as u64);
    rt.shutdown();
}

/// Serial single-session reference mirroring the scheduler's admission
/// budget (prompt tail keeps priority, generation clipped to what the KV
/// cache can still hold) — what any session must emit no matter how many
/// neighbors shared its fused ticks.
fn serial_reference(m: &Manifest, variant: &str, prompt: &[i32], max_tokens: usize,
                    cap: usize) -> (Vec<i32>, FinishReason) {
    let v = m.variant(variant).unwrap();
    let store = Store::open(&m.path(&v.weights)).unwrap();
    let model = FactorizedModel::from_store(&m.models["tiny"], v, &store).unwrap();
    let mut prompt = prompt.to_vec();
    let keep = prompt.len().min(cap - 1);
    if keep < prompt.len() {
        prompt.drain(..prompt.len() - keep);
    }
    let budget = max_tokens.min(cap - keep + 1);
    let clipped = budget < max_tokens;
    let mut session = DecodeSession::new(1, variant, &model, cap);
    let mut logits = session.prefill(&model, &prompt, None).unwrap();
    let mut toks = Vec::new();
    loop {
        let next = argmax(&logits) as i32;
        toks.push(next);
        if toks.len() >= budget || session.remaining() == 0 {
            break;
        }
        logits = session.step(&model, next).unwrap();
    }
    let reason = if toks.len() >= budget {
        if clipped { FinishReason::Length } else { FinishReason::MaxTokens }
    } else {
        FinishReason::Length
    };
    (toks, reason)
}

/// Open one scheduler session (plain or speculative) and collect its full
/// stream.
fn run_to_completion(rt: &ServeRuntime, variant: &str, prompt: Vec<i32>,
                     max_tokens: usize, spec: Option<SpecParams>)
                     -> (Vec<i32>, FinishReason) {
    let (etx, erx) = std::sync::mpsc::channel();
    rt.open(SessionRequest {
        variant: variant.to_string(),
        prompt,
        image: None,
        max_tokens,
        temperature: 0.0,
        seed: 7,
        stop_token: None,
        spec,
        events: etx,
    })
    .unwrap();
    let mut toks = Vec::new();
    for ev in erx {
        match ev {
            GenEvent::Token { token, .. } => toks.push(token),
            GenEvent::Done { reason, n_tokens, .. } => {
                assert_eq!(n_tokens, toks.len());
                return (toks, reason);
            }
            GenEvent::Error(e) => panic!("session failed: {e}"),
        }
    }
    panic!("stream ended without Done");
}

#[test]
fn fused_concurrent_sessions_match_serial_incl_midflight_join_and_kv_eviction() {
    let dir = build_artifacts("fused");
    let m = Manifest::load(&dir).unwrap();
    let cap = 48usize;
    // five sessions across both variants; the last one's budget outruns
    // the KV capacity, so it decodes long past everyone else and finishes
    // evicted with a `length` reason
    let specs: [(&str, &str, usize); 5] = [
        ("tiny/dense", "a tale of fused decoding", 12),
        ("tiny/dobi_60", "some longer prompt here", 12),
        ("tiny/dense", "mid-size words", 12),
        ("tiny/dobi_60", "yet another different one!", 12),
        ("tiny/dense", "short", 400),
    ];
    let serial: Vec<(Vec<i32>, FinishReason)> = specs
        .iter()
        .map(|(variant, prompt, max_tokens)| {
            serial_reference(&m, variant, &ByteTokenizer.encode(prompt), *max_tokens, cap)
        })
        .collect();
    // sanity on the fixture itself: the long session really is clipped
    assert_eq!(serial[4].1, FinishReason::Length);
    assert!(serial[4].0.len() > 12, "eviction session should outlive the others");
    // concurrent: max_sessions 3 < 5 sessions, so the tail joins
    // mid-flight of the others' decode (continuous batching into fused
    // ticks); decode_threads 2 runs the same ticks on the threaded GEMM
    let ids = vec!["tiny/dense".to_string(), "tiny/dobi_60".to_string()];
    let rt = Arc::new(
        ServeRuntime::start(
            dir,
            &ids,
            ServeConfig { max_sessions: 3, kv_capacity: cap, decode_threads: 2,
                          ..Default::default() },
        )
        .unwrap(),
    );
    let mut handles = Vec::new();
    for (variant, prompt, max_tokens) in specs {
        let rt = rt.clone();
        let prompt = ByteTokenizer.encode(prompt);
        handles.push(std::thread::spawn(move || {
            run_to_completion(&rt, variant, prompt, max_tokens, None)
        }));
    }
    let concurrent: Vec<(Vec<i32>, FinishReason)> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();
    for (i, (got, want)) in concurrent.iter().zip(&serial).enumerate() {
        assert_eq!(got, want, "session {i}: fused/concurrent decode diverged from serial");
    }
    rt.shutdown(); // scheduler joined: counters and gauges are final
    let st = rt.stats();
    assert_eq!(st.sessions_finished, specs.len() as u64);
    assert_eq!(st.active_sessions, 0);
    assert_eq!(st.tokens_emitted,
               serial.iter().map(|(t, _)| t.len() as u64).sum::<u64>());
}

// ---------------------------------------------------------------------------
// Streaming TCP protocol
// ---------------------------------------------------------------------------

#[test]
fn server_streams_tokens_and_matches_oneshot_reply() {
    use std::io::{BufRead, BufReader, Write};
    let dir = build_artifacts("stream");
    let ids = vec!["tiny/dense".to_string()];
    let rt = Arc::new(ServeRuntime::start(dir, &ids, ServeConfig::default()).unwrap());
    // runtime-only server: every variant decodes incrementally, so no
    // fallback engine is attached (the dobi serve wiring does the same —
    // weights load once, not twice)
    let mut server = dobi::server::Server::builder().runtime(rt.clone()).start().unwrap();
    let mut conn = std::net::TcpStream::connect(server.addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());

    // one-shot reply (also scheduler-served: greedy, deterministic)
    conn.write_all(
        b"{\"variant\":\"tiny/dense\",\"prompt\":\"The \",\"max_tokens\":8,\"temperature\":0}\n",
    )
    .unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let oneshot = dobi::json::Json::parse(&line).unwrap();
    let text = oneshot.str_of("text").to_string();
    assert!(!text.is_empty());
    assert!(oneshot.get("tokens_per_s").and_then(|x| x.as_f64()).unwrap() > 0.0);

    // streaming reply: 8 delta lines then the terminal line
    conn.write_all(
        b"{\"variant\":\"tiny/dense\",\"prompt\":\"The \",\"max_tokens\":8,\
          \"temperature\":0,\"stream\":true}\n",
    )
    .unwrap();
    let mut tokens = Vec::new();
    loop {
        line.clear();
        reader.read_line(&mut line).unwrap();
        let j = dobi::json::Json::parse(&line).unwrap();
        assert!(j.get("error").is_none(), "stream errored: {line}");
        if j.get("done").and_then(|x| x.as_bool()).unwrap_or(false) {
            assert_eq!(j.str_of("text"), text,
                       "streamed text must equal the one-shot greedy reply");
            assert_eq!(j.get("n_tokens").and_then(|x| x.as_usize()), Some(8));
            assert_eq!(j.str_of("finish"), "max_tokens");
            break;
        }
        assert_eq!(j.get("index").and_then(|x| x.as_usize()), Some(tokens.len()),
                   "delta lines arrive in order");
        assert!(j.get("delta").is_some());
        tokens.push(j.get("token").and_then(|x| x.as_f64()).unwrap() as i32);
    }
    assert_eq!(tokens.len(), 8, "one line per generated token");
    assert_eq!(ByteTokenizer.decode(&tokens), text,
               "streamed token ids reconstruct the one-shot text");

    // malformed request still answers an error object on one line
    conn.write_all(b"not json\n").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(dobi::json::Json::parse(&line).unwrap().get("error").is_some());

    // a variant neither the runtime nor any engine serves: error line
    conn.write_all(b"{\"variant\":\"tiny/ghost\",\"prompt\":\"x\",\"max_tokens\":2}\n")
        .unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let err = dobi::json::Json::parse(&line).unwrap();
    assert!(err.get("error").is_some(), "unservable variant must error: {line}");

    drop(conn);
    server.shutdown();
    rt.shutdown();
}

// ---------------------------------------------------------------------------
// Variant registry: hot swap, provenance, control plane
// ---------------------------------------------------------------------------

/// Write one JSON line, read one reply line, parse it.
fn send_recv(conn: &mut std::net::TcpStream,
             reader: &mut std::io::BufReader<std::net::TcpStream>,
             line: &str) -> dobi::json::Json {
    use std::io::{BufRead, Write};
    conn.write_all(line.as_bytes()).unwrap();
    conn.write_all(b"\n").unwrap();
    let mut reply = String::new();
    reader.read_line(&mut reply).unwrap();
    dobi::json::Json::parse(&reply).unwrap_or_else(|e| panic!("bad reply `{reply}`: {e}"))
}

/// `dobi compress`-built artifacts (provenance manifest stamped), unlike
/// the synth fixtures which emit pre-provenance manifests.
fn compressed_dir(tag: &str) -> (std::path::PathBuf, String) {
    let dense = tiny_model_dense();
    let corpus = calib::synth_calib_tokens(dense.vocab, 4096, 11);
    let cfg = CompressConfig { ratio: 0.4, precision: Precision::Q8, ..Default::default() };
    let art = compress_model(&dense, "tiny", &cfg, &corpus).unwrap();
    let dir = std::env::temp_dir().join(format!("dobi_serve_it_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    write_artifacts(&dir, &art).unwrap();
    (dir, art.variant_id.clone())
}

#[test]
fn midstream_hot_swap_drops_no_sessions_and_bumps_generation() {
    use std::io::{BufRead, BufReader, Write};
    let dir = build_artifacts("hotswap");
    let ids = vec!["tiny/dense".to_string()];
    let rt = Arc::new(ServeRuntime::start(dir, &ids, ServeConfig::default()).unwrap());
    // greedy reference decode: every stream must emit exactly this text no
    // matter how the swap interleaves (the swap re-installs the same bytes)
    let reference = rt.generate("tiny/dense", &ByteTokenizer.encode("The "), 48, 0.0, 1).unwrap();
    let ref_text = ByteTokenizer.decode(&reference);
    let mut server = dobi::server::Server::builder().runtime(rt.clone()).start().unwrap();
    let addr = server.addr;
    let mut clients = Vec::new();
    for _ in 0..2 {
        let want = ref_text.clone();
        clients.push(std::thread::spawn(move || {
            let mut conn = std::net::TcpStream::connect(addr).unwrap();
            let mut reader = BufReader::new(conn.try_clone().unwrap());
            conn.write_all(
                b"{\"variant\":\"tiny/dense\",\"prompt\":\"The \",\"max_tokens\":48,\
                  \"temperature\":0,\"stream\":true}\n",
            )
            .unwrap();
            let mut n = 0usize;
            loop {
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
                let j = dobi::json::Json::parse(&line).unwrap();
                assert!(j.get("error").is_none(), "stream errored across the swap: {line}");
                if j.get("done").and_then(|x| x.as_bool()).unwrap_or(false) {
                    assert_eq!(j.str_of("text"), want,
                               "decode changed across an identical-weights swap");
                    return n;
                }
                n += 1;
            }
        }));
    }
    // hot swap while the streams run: new admissions route to generation 2
    // immediately, the in-flight streams drain on generation 1
    let status = rt.swap("tiny/dense").unwrap();
    assert_eq!(status.generation, 2);
    for c in clients {
        assert_eq!(c.join().unwrap(), 48, "a session was cut short by the swap");
    }
    // both streams completed: nothing stays pinned to a superseded
    // release.  Brief poll — the scheduler drops a session's release Arc
    // moments AFTER sending its terminal event, so the pin can linger a
    // few microseconds past the client's join.
    let snap = rt.registry_snapshot();
    assert_eq!(snap.len(), 1);
    assert_eq!(snap[0].generation, 2);
    let t0 = std::time::Instant::now();
    loop {
        let pinned: usize =
            rt.registry_snapshot()[0].draining.iter().map(|(_, n)| n).sum();
        if pinned == 0 {
            break;
        }
        assert!(t0.elapsed() < std::time::Duration::from_secs(5),
                "drained sessions never released their old-generation pins");
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    let st = rt.stats();
    assert_eq!(st.sessions_finished, 3, "reference + 2 streams, zero dropped");
    assert_eq!(st.swaps, 1);
    server.shutdown();
    rt.shutdown();
}

#[test]
fn corrupted_store_swap_refused_and_old_variant_keeps_serving() {
    let dir = build_artifacts("corrupt_swap");
    let ids = vec!["tiny/dense".to_string()];
    let rt = Arc::new(ServeRuntime::start(dir.clone(), &ids, ServeConfig::default()).unwrap());
    let prompt = ByteTokenizer.encode("The ");
    let before = rt.generate("tiny/dense", &prompt, 8, 0.0, 1).unwrap();
    // flip one byte mid-store: the integrity check must refuse the swap
    let path = dir.join("dense.dobiw");
    let clean = std::fs::read(&path).unwrap();
    let mut bad = clean.clone();
    let mid = bad.len() / 2;
    bad[mid] ^= 0x40;
    std::fs::write(&path, &bad).unwrap();
    assert!(rt.swap("tiny/dense").is_err(), "corrupted store must not install");
    // the failed swap left the table untouched: generation 1 keeps serving
    let snap = rt.registry_snapshot();
    assert_eq!(snap[0].generation, 1);
    assert!(snap[0].draining.is_empty());
    let after = rt.generate("tiny/dense", &prompt, 8, 0.0, 1).unwrap();
    assert_eq!(before, after, "old release must keep serving after a refused swap");
    // restore the original bytes: the swap goes through
    std::fs::write(&path, &clean).unwrap();
    assert_eq!(rt.swap("tiny/dense").unwrap().generation, 2);
    rt.shutdown();
}

#[test]
fn server_control_ops_report_provenance_and_field_errors() {
    use std::io::BufReader;
    let (dir, id) = compressed_dir("ctrl");
    let rt = Arc::new(
        ServeRuntime::start(dir, std::slice::from_ref(&id), ServeConfig::default()).unwrap(),
    );
    let mut server = dobi::server::Server::builder().runtime(rt.clone()).start().unwrap();
    let mut conn = std::net::TcpStream::connect(server.addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());

    let h = send_recv(&mut conn, &mut reader, r#"{"op":"health"}"#);
    assert_eq!(h.get("ok").and_then(|x| x.as_bool()), Some(true), "health not ok");
    assert!(h.get("active_sessions").is_some());

    let l = send_recv(&mut conn, &mut reader, r#"{"op":"list"}"#);
    let vs = l.get("variants").and_then(|x| x.as_arr()).unwrap();
    assert_eq!(vs.len(), 1);
    assert_eq!(vs[0].str_of("variant"), id);
    assert_eq!(vs[0].get("generation").and_then(|x| x.as_usize()), Some(1));
    // compress stamped provenance: the registry reports the pinned hash
    let sha = vs[0].str_of("store_sha256").to_string();
    assert_eq!(sha.len(), 64, "expected a sha256 hex pin, got `{sha}`");

    let s = send_recv(&mut conn, &mut reader,
                      &format!(r#"{{"op":"swap","variant":"{id}"}}"#));
    assert_eq!(s.get("ok").and_then(|x| x.as_bool()), Some(true), "swap failed: {s}");
    assert_eq!(s.get("generation").and_then(|x| x.as_usize()), Some(2));
    assert_eq!(s.str_of("store_sha256"), sha, "same bytes -> same pin");

    // malformed lines answer structured errors naming the field
    let e = send_recv(&mut conn, &mut reader, r#"{"op":"swap"}"#);
    assert_eq!(e.str_of("field"), "variant");
    assert!(e.get("error").is_some());
    let e = send_recv(&mut conn, &mut reader, r#"{"op":"teleport"}"#);
    assert_eq!(e.str_of("field"), "op");
    let e = send_recv(&mut conn, &mut reader, r#"{"prompt":"x","max_tokens":"32"}"#);
    assert_eq!(e.str_of("field"), "max_tokens");
    let e = send_recv(&mut conn, &mut reader,
                      &format!(r#"{{"op":"swap","variant":"{id}","prompt":1}}"#));
    assert!(e.get("field").is_none() && e.get("error").is_none(),
            "swap ignores unrelated fields; got {e}");

    // the connection stays usable for generation after every error
    let g = send_recv(&mut conn, &mut reader,
                      &format!(r#"{{"variant":"{id}","prompt":"The ","max_tokens":4}}"#));
    assert!(g.get("text").is_some(), "generate after errors: {g}");
    drop(conn);
    server.shutdown();
    rt.shutdown();
}

#[test]
fn no_control_server_refuses_control_ops_but_generates() {
    use std::io::BufReader;
    let dir = build_artifacts("noctrl");
    let ids = vec!["tiny/dense".to_string()];
    let rt = Arc::new(ServeRuntime::start(dir, &ids, ServeConfig::default()).unwrap());
    let mut server = dobi::server::Server::builder()
        .runtime(rt.clone())
        .control(false)
        .start()
        .unwrap();
    let mut conn = std::net::TcpStream::connect(server.addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    for op in [r#"{"op":"swap","variant":"tiny/dense"}"#, r#"{"op":"list"}"#,
               r#"{"op":"health"}"#, r#"{"op":"metrics"}"#, r#"{"op":"trace"}"#] {
        let e = send_recv(&mut conn, &mut reader, op);
        assert!(e.get("error").is_some(), "control op must be refused: {e}");
        assert_eq!(e.str_of("field"), "op");
    }
    assert_eq!(rt.registry_snapshot()[0].generation, 1, "refused swap must not install");
    let g = send_recv(&mut conn, &mut reader,
                      r#"{"variant":"tiny/dense","prompt":"The ","max_tokens":4}"#);
    assert!(g.get("text").is_some(), "generation must survive --no-control: {g}");
    drop(conn);
    server.shutdown();
    rt.shutdown();
}

#[test]
fn startup_refuses_store_that_fails_provenance_pin() {
    let (dir, id) = compressed_dir("tamper");
    // wholesale-replace the store with a DIFFERENT structurally-valid
    // store: CRC-clean, so only the manifest's SHA-256 pin can catch it
    let path = {
        let m = Manifest::load(&dir).unwrap();
        m.path(&m.variant(&id).unwrap().weights)
    };
    write_store(&path, &tiny_store_tensors(dims(), 0, SynthStyle::DenseF32)).unwrap();
    assert!(Store::open(&path).is_ok(),
            "replacement must be structurally valid for this test to bite");
    let err = ServeRuntime::start(dir, &[id], ServeConfig::default())
        .unwrap_err()
        .to_string();
    assert!(err.contains("provenance mismatch"), "unexpected refusal reason: {err}");
}

#[test]
fn runtime_refuses_unservable_variants() {
    // a manifest whose store is missing: start must fail, not hang
    let dir = std::env::temp_dir().join("dobi_serve_it_missing");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("manifest.json"),
        tiny_manifest_json(dims(), 0, &[("tiny/ghost", "dense", 1.0, "nope.dobiw")]),
    )
    .unwrap();
    assert!(ServeRuntime::start(dir, &["tiny/ghost".to_string()], ServeConfig::default())
        .is_err());
}

// ---------------------------------------------------------------------------
// Speculative decoding: the compressed variant drafts for the dense one
// ---------------------------------------------------------------------------

/// Dense synth target plus a REAL compress-built ratio-0.3 q8 draft merged
/// into one manifest via `append_artifacts` — the self-speculation pair
/// the acceptance criterion serves (a lossy draft, not a full-rank twin,
/// so rejection + correction paths actually fire).
fn spec_artifacts(tag: &str) -> (std::path::PathBuf, String) {
    let dir = std::env::temp_dir().join(format!("dobi_serve_it_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    write_store(&dir.join("dense.dobiw"),
                &tiny_store_tensors(TinyDims::nano(), 0, SynthStyle::DenseF32)).unwrap();
    std::fs::write(
        dir.join("manifest.json"),
        tiny_manifest_json(TinyDims::nano(), 0,
                           &[("tiny/dense", "dense", 1.0, "dense.dobiw")]),
    )
    .unwrap();
    let dense = tiny_model_dense();
    let corpus = calib::synth_calib_tokens(dense.vocab, 4096, 11);
    let cfg = CompressConfig { ratio: 0.3, precision: Precision::Q8, ..Default::default() };
    let art = compress_model(&dense, "tiny", &cfg, &corpus).unwrap();
    append_artifacts(&dir, &art).unwrap();
    (dir, art.variant_id.clone())
}

/// Sum one counter family out of the runtime's rendered metrics text —
/// counters are labeled per variant/reason, so `name` matches both the
/// bare key and every `name{...}` child.
fn metric_u64(text: &str, name: &str) -> u64 {
    let mut found = false;
    let total = text
        .lines()
        .filter_map(|l| {
            let (key, val) = l.split_once(' ')?;
            if key == name || key.strip_prefix(name).is_some_and(|r| r.starts_with('{')) {
                found = true;
                val.trim().parse().ok()
            } else {
                None
            }
        })
        .sum();
    assert!(found, "metric `{name}` missing from:\n{text}");
    total
}

/// The acceptance criterion: a ratio-0.3 draft speculating k=4 for the
/// dense target streams byte-identical greedy tokens across mixed prompt
/// lengths — through a mid-stream hot swap of BOTH halves of the pair and
/// the KV-capacity eviction of a speculative session.
#[test]
fn speculative_pairs_match_pure_target_incl_hot_swap_and_eviction() {
    let (dir, draft) = spec_artifacts("spec_e2e");
    let m = Manifest::load(&dir).unwrap();
    let cap = 48usize;
    // mixed prompt lengths; the last session's budget outruns the KV
    // capacity, so it is evicted mid-speculation and finishes `length`
    let specs: [(&str, usize); 4] =
        [("a", 12), ("some longer prompt here", 12), ("mid-size words", 12), ("short", 400)];
    // pure target decode: the serial single-session reference on the
    // dense variant, no draft anywhere near it
    let serial: Vec<(Vec<i32>, FinishReason)> = specs
        .iter()
        .map(|(p, n)| serial_reference(&m, "tiny/dense", &ByteTokenizer.encode(p), *n, cap))
        .collect();
    assert_eq!(serial[3].1, FinishReason::Length, "fixture must exercise eviction");
    let ids = vec!["tiny/dense".to_string(), draft.clone()];
    let rt = Arc::new(
        ServeRuntime::start(
            dir,
            &ids,
            ServeConfig { max_sessions: 3, kv_capacity: cap, ..Default::default() },
        )
        .unwrap(),
    );
    let mut handles = Vec::new();
    for (p, n) in specs {
        let rt = rt.clone();
        let prompt = ByteTokenizer.encode(p);
        let spec = SpecParams { draft: draft.clone(), k: 4 };
        handles.push(std::thread::spawn(move || {
            run_to_completion(&rt, "tiny/dense", prompt, n, Some(spec))
        }));
    }
    // hot swap BOTH halves of the pair while the streams decode: a spec
    // session pins its draft release exactly like its target release, so
    // both superseded generations must drain and sweep once the pairs end
    let t0 = std::time::Instant::now();
    while rt.stats().sessions_opened == 0 {
        assert!(t0.elapsed() < std::time::Duration::from_secs(5), "nothing admitted");
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
    assert_eq!(rt.swap("tiny/dense").unwrap().generation, 2);
    assert_eq!(rt.swap(&draft).unwrap().generation, 2);
    let concurrent: Vec<(Vec<i32>, FinishReason)> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();
    for (i, (got, want)) in concurrent.iter().zip(&serial).enumerate() {
        assert_eq!(got, want,
                   "spec session {i}: speculative decode diverged from pure target decode");
    }
    // every pair released its pins: generation 2 of both variants serves,
    // nothing stays pinned to a drained release (brief poll — the
    // scheduler drops the Arcs moments after the terminal events)
    let t0 = std::time::Instant::now();
    loop {
        let snap = rt.registry_snapshot();
        assert_eq!(snap.len(), 2);
        let pinned: usize =
            snap.iter().flat_map(|v| v.draining.iter().map(|(_, n)| n)).sum();
        if pinned == 0 && snap.iter().all(|v| v.generation == 2) {
            break;
        }
        assert!(t0.elapsed() < std::time::Duration::from_secs(5),
                "a speculative pair kept a drained release pinned");
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    rt.shutdown();
    let st = rt.stats();
    assert_eq!(st.sessions_finished, specs.len() as u64);
    assert_eq!(st.active_sessions, 0);
    let text = rt.metrics_text();
    let proposed = metric_u64(&text, "serve_spec_proposed");
    let accepted = metric_u64(&text, "serve_spec_accepted");
    assert!(proposed > 0, "the speculative path never ran");
    assert!(accepted <= proposed);
}

// ---------------------------------------------------------------------------
// Observability: timing summaries, labeled metrics, trace export
// ---------------------------------------------------------------------------

/// The observability acceptance criterion: a streamed generate returns a
/// `"timing"` breakdown on its terminal line, and `{"op":"trace"}`
/// afterwards yields Perfetto-loadable trace-event JSON covering that
/// request accept → finish (queue, prefill, per-tick steps, spec
/// draft/verify), while `{"op":"metrics"}` exposes the labeled families
/// in both text and Prometheus formats.
#[test]
fn timing_metrics_and_trace_ops_cover_a_request_end_to_end() {
    use std::io::{BufRead, BufReader, Write};
    let (dir, draft) = spec_artifacts("obs_e2e");
    let ids = vec!["tiny/dense".to_string(), draft.clone()];
    let rt = Arc::new(ServeRuntime::start(dir, &ids, ServeConfig::default()).unwrap());
    let mut server = dobi::server::Server::builder().runtime(rt.clone()).start().unwrap();
    let mut conn = std::net::TcpStream::connect(server.addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());

    // streamed SPECULATIVE generate: the terminal line carries the
    // per-request wall-clock breakdown including the spec phases
    let req = format!(
        "{{\"variant\":\"tiny/dense\",\"prompt\":\"The \",\"max_tokens\":8,\
         \"temperature\":0,\"stream\":true,\"spec\":{{\"draft\":\"{draft}\",\"k\":4}}}}\n");
    conn.write_all(req.as_bytes()).unwrap();
    loop {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let j = dobi::json::Json::parse(&line).unwrap();
        assert!(j.get("error").is_none(), "stream errored: {line}");
        if j.get("done").and_then(|x| x.as_bool()).unwrap_or(false) {
            let num =
                |f: &str| j.path(&format!("timing.{f}")).and_then(|x| x.as_f64())
                    .unwrap_or_else(|| panic!("timing.{f} missing from {line}"));
            assert_eq!(num("tokens") as usize, 8);
            assert!(num("prefill_us") > 0.0, "prefill must be charged: {line}");
            assert!(num("decode_us") > 0.0, "decode must be charged: {line}");
            assert!(num("draft_us") > 0.0, "spec draft phase must be charged: {line}");
            assert!(num("verify_us") > 0.0, "spec verify phase must be charged: {line}");
            assert_eq!(num("ttft_us"), num("queue_us") + num("prefill_us"));
            assert!(num("tokens_per_s") > 0.0);
            break;
        }
    }
    // one-shot replies carry the same object (plain decode: a `step` span)
    let g = send_recv(&mut conn, &mut reader,
                      r#"{"variant":"tiny/dense","prompt":"The ","max_tokens":4}"#);
    assert!(g.path("timing.prefill_us").and_then(|x| x.as_f64()).unwrap() > 0.0,
            "one-shot reply lost the timing object: {g}");

    // labeled metric families, plain text
    let m = send_recv(&mut conn, &mut reader, r#"{"op":"metrics"}"#);
    assert_eq!(m.str_of("format"), "text");
    let text = m.str_of("text").to_string();
    assert!(text.contains(r#"serve_sessions_opened{variant="tiny/dense"}"#), "{text}");
    assert!(text.contains(r#"serve_prefill_seconds{variant="tiny/dense"}"#), "{text}");
    assert!(text.contains(r#"reason="max_tokens""#), "{text}");
    assert_eq!(metric_u64(&text, "serve_tokens_emitted"), 12);
    assert!(metric_u64(&text, "serve_spec_proposed") > 0);

    // Prometheus exposition
    let m = send_recv(&mut conn, &mut reader, r#"{"op":"metrics","format":"prom"}"#);
    assert_eq!(m.str_of("format"), "prom");
    let prom = m.str_of("text").to_string();
    assert!(prom.contains("# TYPE serve_sessions_opened counter"), "{prom}");
    assert!(prom.contains("# TYPE serve_active_sessions gauge"), "{prom}");
    assert!(prom.contains("# TYPE serve_prefill_seconds summary"), "{prom}");
    assert!(prom.contains(r#"quantile="0.5""#), "{prom}");
    assert!(prom.contains("serve_prefill_seconds_count"), "{prom}");

    // the trace op: Perfetto-loadable trace-event JSON covering the whole
    // request lifecycle (every asserted span was recorded BEFORE the
    // terminal reply lines above were written, so no scheduler race)
    let t = send_recv(&mut conn, &mut reader, r#"{"op":"trace","clear":true}"#);
    assert_eq!(t.get("enabled").and_then(|x| x.as_bool()), Some(true));
    assert_eq!(t.path("trace.displayTimeUnit").and_then(|x| x.as_str()), Some("ms"));
    let evs = t.path("trace.traceEvents").and_then(|x| x.as_arr()).unwrap();
    assert!(!evs.is_empty());
    let names: Vec<&str> = evs.iter().map(|e| e.str_of("name")).collect();
    for want in ["accept", "parse", "queue_wait", "admission", "prefill", "step",
                 "spec_draft", "spec_verify", "request"] {
        assert!(names.contains(&want), "missing `{want}` span in {names:?}");
    }
    for e in evs {
        assert_eq!(e.str_of("ph"), "X", "complete-phase events only: {e}");
        assert!(e.get("ts").and_then(|x| x.as_f64()).is_some());
        assert!(e.get("dur").and_then(|x| x.as_f64()).is_some());
        assert!(e.get("tid").and_then(|x| x.as_f64()).is_some());
    }
    // clear=true emptied the ring: a fresh drain holds no request spans,
    // only the housekeeping of the ops themselves
    let t = send_recv(&mut conn, &mut reader, r#"{"op":"trace"}"#);
    let evs = t.path("trace.traceEvents").and_then(|x| x.as_arr()).unwrap();
    assert!(evs.iter().all(|e| e.str_of("name") != "queue_wait"),
            "cleared request spans resurfaced");
    drop(conn);
    server.shutdown();
    rt.shutdown();
}

/// Registry × eviction interaction: a draining old-generation release
/// whose ONLY pinned session finishes by KV-capacity eviction (not by
/// max_tokens) must still be GCed by `sweep()` — the Arc strong-count
/// guard does not care HOW the session ended.
#[test]
fn kv_evicted_session_still_unpins_draining_release_for_sweep() {
    let dir = build_artifacts("sweep_evict");
    let ids = vec!["tiny/dense".to_string()];
    let rt = Arc::new(
        ServeRuntime::start(dir, &ids,
                            ServeConfig { kv_capacity: 32, ..Default::default() })
            .unwrap(),
    );
    let (etx, erx) = std::sync::mpsc::channel();
    rt.open(SessionRequest {
        variant: "tiny/dense".to_string(),
        prompt: ByteTokenizer.encode("The "),
        image: None,
        max_tokens: 400, // way past what a 32-slot cache can hold
        temperature: 0.0,
        seed: 1,
        stop_token: None,
        spec: None,
        events: etx,
    })
    .unwrap();
    // first token: the session is live and pins generation 1
    match erx.recv().unwrap() {
        GenEvent::Token { .. } => {}
        _ => panic!("expected the first event to be a token"),
    }
    assert_eq!(rt.swap("tiny/dense").unwrap().generation, 2);
    // drain the stream: the session must die by eviction, not max_tokens
    let reason = loop {
        match erx.recv().unwrap() {
            GenEvent::Token { .. } => {}
            GenEvent::Done { reason, .. } => break reason,
            GenEvent::Error(e) => panic!("session failed: {e}"),
        }
    };
    assert_eq!(reason, FinishReason::Length, "fixture must finish by KV eviction");
    // the evicted session dropped its Arc: sweep() (run after each tick's
    // evictions) must GC the drained generation-1 release
    let t0 = std::time::Instant::now();
    while !rt.registry_snapshot()[0].draining.is_empty() {
        assert!(t0.elapsed() < std::time::Duration::from_secs(5),
                "evicted session left the draining release unswept");
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    rt.shutdown();
}

// ---------------------------------------------------------------------------
// VLM image prefixes over the wire
// ---------------------------------------------------------------------------

#[test]
fn image_prefix_streams_over_tcp_and_type_errors_name_the_field() {
    use std::io::{BufRead, BufReader, Write};
    let img_dim = 6usize;
    let dir = std::env::temp_dir().join("dobi_serve_it_vlm_tcp");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    write_store(&dir.join("vlm.dobiw"),
                &tiny_store_tensors(dims(), img_dim, SynthStyle::DenseF32)).unwrap();
    std::fs::write(
        dir.join("manifest.json"),
        tiny_manifest_json(dims(), img_dim, &[("tiny/vlm", "dense", 1.0, "vlm.dobiw")]),
    )
    .unwrap();
    let ids = vec!["tiny/vlm".to_string()];
    let rt = Arc::new(ServeRuntime::start(dir, &ids, ServeConfig::default()).unwrap());
    // exactly-representable floats so the JSON round trip is lossless and
    // the greedy parity assertion below is exact
    let image: Vec<f32> = (0..img_dim).map(|i| i as f32 * 0.25).collect();
    // in-process reference with the image attached — prefill REQUIRES the
    // features for a VLM variant, so matching text below proves the wire
    // actually carried them
    let (etx, erx) = std::sync::mpsc::channel();
    rt.open(SessionRequest {
        variant: "tiny/vlm".to_string(),
        prompt: ByteTokenizer.encode("The "),
        image: Some(image.clone()),
        max_tokens: 8,
        temperature: 0.0,
        seed: 1,
        stop_token: None,
        spec: None,
        events: etx,
    })
    .unwrap();
    let mut want = Vec::new();
    for ev in erx {
        match ev {
            GenEvent::Token { token, .. } => want.push(token),
            GenEvent::Done { .. } => break,
            GenEvent::Error(e) => panic!("reference session failed: {e}"),
        }
    }
    let want_text = ByteTokenizer.decode(&want);

    let mut server = dobi::server::Server::builder().runtime(rt.clone()).start().unwrap();
    let mut conn = std::net::TcpStream::connect(server.addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    // the streaming roundtrip: the image array rides the generate request
    let img_json =
        image.iter().map(|x| format!("{x}")).collect::<Vec<_>>().join(",");
    let req = format!(
        "{{\"variant\":\"tiny/vlm\",\"prompt\":\"The \",\"max_tokens\":8,\
         \"temperature\":0,\"stream\":true,\"image\":[{img_json}]}}\n");
    conn.write_all(req.as_bytes()).unwrap();
    let mut tokens = Vec::new();
    let text = loop {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let j = dobi::json::Json::parse(&line).unwrap();
        assert!(j.get("error").is_none(), "stream errored: {line}");
        if j.get("done").and_then(|x| x.as_bool()).unwrap_or(false) {
            break j.str_of("text").to_string();
        }
        tokens.push(j.get("token").and_then(|x| x.as_f64()).unwrap() as i32);
    };
    assert_eq!(tokens, want, "wire image prefix changed the greedy decode");
    assert_eq!(text, want_text);

    // a VLM variant refuses a generate with NO image: the parity above
    // could only have come from the carried features
    let e = send_recv(&mut conn, &mut reader,
                      r#"{"variant":"tiny/vlm","prompt":"The ","max_tokens":4}"#);
    assert!(e.get("error").is_some(), "imageless VLM generate must fail: {e}");

    // typed field errors per protocol v1: bad shapes name the field (and
    // the offending element), and the connection stays usable
    let e = send_recv(&mut conn, &mut reader,
                      r#"{"variant":"tiny/vlm","prompt":"x","max_tokens":2,"image":"nope"}"#);
    assert_eq!(e.str_of("field"), "image");
    let e = send_recv(&mut conn, &mut reader,
                      r#"{"variant":"tiny/vlm","prompt":"x","max_tokens":2,"image":[0.5,true]}"#);
    assert_eq!(e.str_of("field"), "image[1]");
    drop(conn);
    server.shutdown();
    rt.shutdown();
}
