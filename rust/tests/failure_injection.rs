//! Failure-injection: the loader/engine must fail loudly and precisely on
//! corrupted or inconsistent artifacts — never serve garbage silently.

use dobi::bench::{artifacts_available, artifacts_dir};
use dobi::config::Manifest;
use dobi::runtime::Runtime;
use dobi::storage::{f32_tensor, write_store, Store};

macro_rules! require_artifacts {
    () => {
        if !artifacts_available() {
            eprintln!("[skip] artifacts not built (run `make artifacts`)");
            return;
        }
    };
}

fn scratch(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("dobi_failure_tests");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

#[test]
fn corrupted_weights_rejected_at_load() {
    require_artifacts!();
    let m = Manifest::load(&artifacts_dir()).unwrap();
    let v = m.variant("llama-nano/dense").unwrap();
    let mut raw = std::fs::read(m.path(&v.weights)).unwrap();
    let mid = raw.len() / 2;
    raw[mid] ^= 0xFF;
    let p = scratch("corrupt.dobiw");
    std::fs::write(&p, raw).unwrap();
    let err = Store::open(&p).unwrap_err().to_string();
    assert!(err.contains("crc") || err.contains("truncated") || err.contains("payload"),
            "unexpected error: {err}");
}

#[test]
fn truncated_weights_rejected() {
    require_artifacts!();
    let m = Manifest::load(&artifacts_dir()).unwrap();
    let v = m.variant("llama-nano/dense").unwrap();
    let raw = std::fs::read(m.path(&v.weights)).unwrap();
    let p = scratch("truncated.dobiw");
    std::fs::write(&p, &raw[..raw.len() / 3]).unwrap();
    assert!(Store::open(&p).is_err());
}

#[test]
fn missing_tensor_fails_variant_load() {
    require_artifacts!();
    let m = Manifest::load(&artifacts_dir()).unwrap();
    // Build a store holding only one bogus tensor, swap it in for a
    // variant via a doctored manifest dir? Simpler: exercise the loader
    // API directly — Store::tensor_f32 must name the missing tensor.
    let p = scratch("sparse.dobiw");
    write_store(&p, &[f32_tensor("only", vec![2], &[1.0, 2.0])]).unwrap();
    let s = Store::open(&p).unwrap();
    let err = s.tensor_f32("embed").unwrap_err().to_string();
    assert!(err.contains("embed"), "error should name the tensor: {err}");
    let _ = m;
}

#[test]
#[ignore = "requires PJRT artifacts (make artifacts) and the real xla bindings; the offline xla-stub cannot execute HLO"]
fn malformed_hlo_rejected_at_compile() {
    require_artifacts!();
    let p = scratch("bad.hlo.txt");
    std::fs::write(&p, "HloModule garbage\nENTRY main { broken").unwrap();
    let rt = Runtime::new().unwrap();
    assert!(rt.compile_hlo(&p).is_err());
}

#[test]
fn unknown_variant_fails_engine_start() {
    require_artifacts!();
    let err = dobi::coordinator::Engine::start(
        artifacts_dir(),
        &["llama-nano/never-exported".to_string()],
        dobi::config::EngineConfig::default(),
        None,
    );
    assert!(err.is_err());
}

#[test]
fn engine_shape_filter_mismatch_fails_start() {
    require_artifacts!();
    let err = dobi::coordinator::Engine::start(
        artifacts_dir(),
        &["llama-nano/dense".to_string()],
        dobi::config::EngineConfig::default(),
        Some(vec![(3, 999)]), // never exported
    );
    assert!(err.is_err());
}

#[test]
#[ignore = "requires PJRT artifacts (make artifacts) and the real xla bindings; the offline xla-stub cannot execute HLO"]
fn forward_rejects_wrong_token_count() {
    require_artifacts!();
    let m = Manifest::load(&artifacts_dir()).unwrap();
    let (b, s) = (m.eval_batch, m.eval_seq);
    let rt = Runtime::new().unwrap();
    let model = rt.load_variant(&m, "llama-nano/dense", Some(&[(b, s)])).unwrap();
    assert!(model.forward(b, s, &vec![0; b * s - 1], None).is_err());
    assert!(model.forward(b + 1, s, &vec![0; (b + 1) * s], None).is_err());
    // LM variant must reject an image input
    assert!(model.forward(b, s, &vec![0; b * s], Some(&vec![0.0; b])).is_err());
}
