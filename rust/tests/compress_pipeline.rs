//! End-to-end coverage of the native compression pipeline: synth dense
//! model → `dobi compress` (as a library) → `.dobiw` store + factor-only
//! manifest → native backend → eval/generation/serving parity.
//!
//! The compressed fixture these tests generate is the CI stand-in for
//! `make artifacts`: three of the PJRT-`#[ignore]`d integration tests are
//! ported here to run against it on every checkout —
//! * `rust_ppl_matches_python_reference`  → [`compressed_store_eval_loss_matches_reference`]
//! * `generation_is_deterministic_and_decodable` → [`generation_deterministic_on_compressed_store`]
//! * `engine_serves_concurrent_clients`   → [`engine_serves_compressed_any_seq_variant`]

use std::sync::Arc;

use dobi::compress::{calib, compress_model, eval_loss, write_artifacts, CompressedArtifact};
use dobi::config::{AllocMode, BackendKind, CompressConfig, EngineConfig, Manifest, Precision};
use dobi::coordinator::{Engine, SubmitError};
use dobi::evalx;
use dobi::lowrank::synth::{tiny_model, TinyDims};
use dobi::lowrank::{FactorizedModel, NativeBackend};
use dobi::runtime::Backend;
use dobi::tokenizer::ByteTokenizer;

/// The shared synthetic nano config (`TinyDims::nano`): byte vocab, and
/// targets that dominate the embedding so ratio 0.4 allocates meaningfully.
fn dims() -> TinyDims {
    TinyDims::nano()
}

fn cfg(ratio: f64, precision: Precision) -> CompressConfig {
    CompressConfig {
        ratio,
        precision,
        calib_batches: 3,
        calib_batch: 2,
        calib_seq: 12,
        ..Default::default()
    }
}

fn corpus() -> Vec<i32> {
    calib::synth_calib_tokens(256, 2000, 19)
}

/// Compress the synth dense model into a fresh artifacts dir.
fn fixture(tag: &str, ratio: f64, precision: Precision)
           -> (std::path::PathBuf, CompressedArtifact) {
    let dense = tiny_model(dims(), 0, false);
    let art = compress_model(&dense, "tiny", &cfg(ratio, precision), &corpus())
        .expect("compression succeeds");
    let dir = std::env::temp_dir().join(format!("dobi_compress_it_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    write_artifacts(&dir, &art).expect("artifacts written");
    (dir, art)
}

/// The ISSUE acceptance path: synth dense → `dobi compress` at ratio 0.4
/// → load through the native backend → eval loss within 1e-3 of the
/// in-memory directly-factorized reference.
#[test]
fn compressed_store_eval_loss_matches_reference() {
    let (dir, art) = fixture("accept", 0.4, Precision::F32);
    let m = Manifest::load(&dir).unwrap();
    let loaded = NativeBackend.load_variant(&m, &art.variant_id, None).unwrap();
    let toks = corpus();
    let l_store = eval_loss(&loaded.model, &toks, 2, 16, 6, 5).unwrap();
    let l_ref = eval_loss(&art.reference, &toks, 2, 16, 6, 5).unwrap();
    assert!((l_store - l_ref).abs() < 1e-3,
            "store {l_store} vs in-memory reference {l_ref}");
    // and the compression was real: the stored payload beats dense f32
    let dense_bytes = 4 * art.total_params;
    assert!(loaded.stats.payload_bytes < dense_bytes,
            "{} payload !< {dense_bytes} dense", loaded.stats.payload_bytes);
    // sanity: CE stays in the plausible band around uniform (ln 256) —
    // the synth model is untrained, so this guards NaN/blow-up, not skill
    let uniform = (256f64).ln();
    assert!(l_store.is_finite() && l_store < uniform + 2.0,
            "compressed CE {l_store} vs uniform {uniform}");
}

/// Port of `rust_ppl_matches_python_reference` shape: ppl (exp CE) of the
/// reloaded q8 store stays within a few percent of its own f32 reference
/// twin — the quantization drift bound, measured end to end.
#[test]
fn q8_fixture_ppl_close_to_f32_reference() {
    let (dir, art) = fixture("q8", 0.5, Precision::Q8);
    let m = Manifest::load(&dir).unwrap();
    let loaded = NativeBackend.load_variant(&m, &art.variant_id, None).unwrap();
    let toks = corpus();
    let ppl_store = eval_loss(&loaded.model, &toks, 2, 16, 6, 7).unwrap().exp();
    let ppl_ref = eval_loss(&art.reference, &toks, 2, 16, 6, 7).unwrap().exp();
    let rel = (ppl_store - ppl_ref).abs() / ppl_ref;
    assert!(rel < 0.1, "q8 store ppl {ppl_store} vs f32 reference {ppl_ref} ({rel:.3} rel)");
    // int8 factors must shrink the resident footprint vs the f32 twin
    assert!(loaded.stats.weight_bytes < art.reference.resident_bytes());
}

/// Port of `generation_is_deterministic_and_decodable` onto the
/// compressed fixture (native backend, no PJRT).
#[test]
fn generation_deterministic_on_compressed_store() {
    let (dir, art) = fixture("gen", 0.5, Precision::Q8);
    let m = Manifest::load(&dir).unwrap();
    let model = NativeBackend.load_variant(&m, &art.variant_id, None).unwrap().model;
    let a = evalx::generate(&model, 1, 16, "The ", 24, 0.7, 42).unwrap();
    let b = evalx::generate(&model, 1, 16, "The ", 24, 0.7, 42).unwrap();
    assert_eq!(a, b, "same seed must reproduce");
    let c = evalx::generate(&model, 1, 16, "The ", 24, 0.7, 43).unwrap();
    assert!(!c.is_empty());
    let g = evalx::generate(&model, 1, 16, "The ", 8, 0.0, 1).unwrap();
    assert_eq!(g.len(), ByteTokenizer.decode(&ByteTokenizer.encode(&g)).len());
}

/// Port of `engine_serves_concurrent_clients`, doubling as the any-seq
/// admission test: the compressed manifest carries an **empty** `hlo`
/// map, so the engine must register the variant in any-seq mode and serve
/// mixed sequence lengths exactly (no padding, no phantom HLO entries).
#[test]
fn engine_serves_compressed_any_seq_variant() {
    let (dir, art) = fixture("engine", 0.5, Precision::Q8);
    let id = art.variant_id.clone();
    let cfg = EngineConfig { max_batch: 2, backend: BackendKind::Native, ..Default::default() };
    let engine = Arc::new(Engine::start(dir, &[id.clone()], cfg, None).unwrap());
    let meta = engine.router().get(&id).unwrap();
    assert!(meta.any_seq(), "empty-hlo manifest must register as any-seq");
    assert_eq!(engine.router().pick_seq(&id, 33), Some(33));

    let mut handles = Vec::new();
    for t in 0..3u64 {
        let eng = engine.clone();
        let vid = id.clone();
        handles.push(std::thread::spawn(move || {
            let tok = ByteTokenizer;
            // three different window lengths, none "exported" anywhere
            for (i, seq) in [9usize, 16, 33].into_iter().enumerate() {
                let win = tok.encode_window(&format!("client {t} msg {i} "), seq, 32);
                let resp = eng.infer(&vid, win, None).unwrap();
                assert_eq!(resp.output.len(), 256, "last-position logit width");
                assert!(resp.output.iter().all(|x| x.is_finite()));
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let stats = engine.stats();
    assert_eq!(stats.served, 9);
    assert!(stats.mean_batch >= 1.0);
    // admission control still rejects what it must
    match engine.submit("tiny/nope", vec![1; 8], None) {
        Err(SubmitError::UnknownVariant(_)) => {}
        other => panic!("expected UnknownVariant, got {other:?}"),
    }
    match engine.submit(&id, Vec::new(), None) {
        Err(SubmitError::BadShape { .. }) => {}
        other => panic!("expected BadShape for empty window, got {other:?}"),
    }
    engine.shutdown();
}

/// The ISSUE acceptance criterion for the differentiable allocator:
/// learned allocation at ratio 0.4 on the compress-fixture twin achieves
/// eval loss <= the greedy waterfill baseline **at the same stored-param
/// budget**.  The learned rounding is waterfill-guarded, so ties collapse
/// to the identical plan (identical eval loss) and strict improvements of
/// the whitened surrogate are the only way the plans can differ.  On THIS
/// fixture the optimizer rounds to the exact waterfill allocation
/// (pre-verified by numeric replay), so the comparison is an identity; if
/// the fixture ever changes such that the guard picks a strictly-better
/// surrogate plan, the eval inequality becomes an expectation rather than
/// a construction — re-verify before tightening anything here.
#[test]
fn learned_alloc_at_matched_budget_never_loses_to_waterfill() {
    let dense = tiny_model(dims(), 0, false);
    let toks = corpus();
    let wf = compress_model(&dense, "tiny", &cfg(0.4, Precision::F32), &toks)
        .expect("waterfill compression");
    let mut learned_cfg = cfg(0.4, Precision::F32);
    learned_cfg.alloc = AllocMode::Learned;
    learned_cfg.budget = Some(wf.stored_params); // the SAME stored-param budget
    learned_cfg.train_iters = 150;
    let learned = compress_model(&dense, "tiny", &learned_cfg, &toks)
        .expect("learned compression");
    assert!(learned.stored_params <= wf.stored_params,
            "learned overspent the matched budget: {} vs {}",
            learned.stored_params, wf.stored_params);
    let l_wf = eval_loss(&wf.reference, &toks, 2, 16, 6, 5).unwrap();
    let l_learned = eval_loss(&learned.reference, &toks, 2, 16, 6, 5).unwrap();
    assert!(l_learned <= l_wf + 1e-9,
            "learned allocation lost to waterfill at the same budget: \
             {l_learned} vs {l_wf}");
    // the guard's bookkeeping is visible and consistent
    let report = learned.train_report.as_ref().expect("learned mode reports");
    assert!(report.learned_surrogate <= report.waterfill_surrogate + 1e-12
            || learned.ranks.values().sum::<usize>() == wf.ranks.values().sum::<usize>());
    // and the learned variant serves through the native backend like any
    // other compressed store
    let dir = std::env::temp_dir().join("dobi_compress_it_learned");
    let _ = std::fs::remove_dir_all(&dir);
    write_artifacts(&dir, &learned).unwrap();
    let m = Manifest::load(&dir).unwrap();
    let v = m.variant(&learned.variant_id).unwrap();
    assert_eq!(v.alloc, "learned");
    let loaded = NativeBackend.load_variant(&m, &learned.variant_id, None).unwrap();
    let l_store = eval_loss(&loaded.model, &toks, 2, 16, 6, 5).unwrap();
    assert!((l_store - l_learned).abs() < 1e-3,
            "served learned store drifted: {l_store} vs {l_learned}");
}

/// Acceptance criterion for the autodiff machinery, driven through the
/// public API: central finite differences validate the tape objective
/// gradient AND the Taylor-stabilized gated-SVD-reconstruction gradient
/// to 1e-4 on a synthetic near-degenerate spectrum (pair gap 1% of the
/// top singular value, where the raw 1/(σ²-σ²) coefficients are ~100x
/// amplified but the true gradient still exists).
#[test]
fn finite_differences_validate_tape_and_taylor_gradients() {
    use dobi::compress::train::tape::Tape;
    use dobi::compress::train::taylor::gated_recon_grad;

    // --- tape: a gate-objective-shaped program over a scalar position ---
    let sigma2 = [9.0f64, 4.0, 1.0, 0.25, 0.01];
    let eval = |p: f64| -> (f64, f64) {
        let mut t = Tape::new();
        let pos = t.leaf(&[p]);
        let idx = t.constant(&[0.5, 1.5, 2.5, 3.5, 4.5]);
        let d = t.sub(pos, idx);
        let z = t.scale(d, 1.0 / 0.4);
        let g = t.sigmoid(z);
        let ones = t.constant(&[1.0; 5]);
        let omg = t.sub(ones, g);
        let sq = t.mul(omg, omg);
        let s2 = t.constant(&sigma2);
        let tail = t.matmul(sq, 1, 5, s2, 1);
        let cost = t.sum(g);
        let pen = t.scale(cost, 0.3);
        let root = t.add(tail, pen);
        let grad = t.backward(root);
        (t.value(root)[0], grad.wrt(pos)[0])
    };
    let (_, analytic) = eval(2.3);
    let h = 1e-6;
    let fd = (eval(2.3 + h).0 - eval(2.3 - h).0) / (2.0 * h);
    assert!((analytic - fd).abs() < 1e-4 * (1.0 + fd.abs()),
            "tape gradient {analytic} vs finite difference {fd}");

    // --- taylor: near-degenerate spectrum through the gated SVD recon ---
    let (m, n) = (6usize, 5usize);
    // diag embedding keeps the spectrum exact: σ = [3, 1.01, 1.0, .3, .05]
    let sigma = [3.0f64, 1.01, 1.0, 0.3, 0.05];
    let mut a = vec![0f64; m * n];
    for (j, &s) in sigma.iter().enumerate() {
        a[j * n + j] = s;
    }
    let gates = [0.95, 0.7, 0.45, 0.2, 0.05];
    // fixed non-uniform probe so rotation terms participate
    let probe: Vec<f64> = (0..m * n).map(|i| ((i * 7 + 3) % 11) as f64 / 11.0 - 0.4).collect();
    let g = gated_recon_grad(&a, m, n, &gates, &probe);
    let loss = |mat: &[f64]| -> f64 {
        let zero = vec![0f64; m * n];
        gated_recon_grad(mat, m, n, &gates, &zero)
            .recon
            .iter()
            .zip(&probe)
            .map(|(r, c)| r * c)
            .sum()
    };
    let h = 1e-4;
    let mut gmax = 0f64;
    let mut worst = 0f64;
    for p in 0..m * n {
        let mut up = a.clone();
        up[p] += h;
        let mut dn = a.clone();
        dn[p] -= h;
        let fd = (loss(&up) - loss(&dn)) / (2.0 * h);
        gmax = gmax.max(fd.abs());
        worst = worst.max((g.d_a[p] - fd).abs());
    }
    assert!(worst < 1e-4 * gmax.max(1.0),
            "Taylor-stabilized SVD gradient drifted {worst} from FD (scale {gmax})");
}

/// The compressed store must also load as a plain `FactorizedModel` with
/// the manifest-recorded ranks actually effective per target.
#[test]
fn manifest_ranks_are_effective_in_loaded_model() {
    let (dir, art) = fixture("ranks", 0.4, Precision::Q8);
    let m = Manifest::load(&dir).unwrap();
    let v = m.variant(&art.variant_id).unwrap();
    let store = dobi::storage::Store::open(&m.path(&v.weights)).unwrap();
    let model = FactorizedModel::from_store(&m.models["tiny"], v, &store).unwrap();
    for layer in &model.layers {
        for lin in layer.mats() {
            let want = art.ranks[lin.name()];
            assert_eq!(lin.rank(), want, "{}: rank mismatch", lin.name());
            assert!(lin.rank() >= 1);
        }
    }
    // compression must actually truncate: at ratio 0.4 no target can stay
    // full-rank on every matrix kind simultaneously
    let total_rank: usize = model.layers.iter()
        .flat_map(|l| l.mats().into_iter().map(|lin| lin.rank()))
        .sum();
    let full_rank: usize = model.layers.iter()
        .flat_map(|l| l.mats().into_iter().map(|lin| lin.in_dim().min(lin.out_dim())))
        .sum();
    assert!(total_rank < full_rank, "ratio 0.4 must truncate somewhere");
}
