//! End-to-end coverage of the native compression pipeline: synth dense
//! model → `dobi compress` (as a library) → `.dobiw` store + factor-only
//! manifest → native backend → eval/generation/serving parity.
//!
//! The compressed fixture these tests generate is the CI stand-in for
//! `make artifacts`: three of the PJRT-`#[ignore]`d integration tests are
//! ported here to run against it on every checkout —
//! * `rust_ppl_matches_python_reference`  → [`compressed_store_eval_loss_matches_reference`]
//! * `generation_is_deterministic_and_decodable` → [`generation_deterministic_on_compressed_store`]
//! * `engine_serves_concurrent_clients`   → [`engine_serves_compressed_any_seq_variant`]

use std::sync::Arc;

use dobi::compress::{calib, compress_model, eval_loss, write_artifacts, CompressedArtifact};
use dobi::config::{BackendKind, CompressConfig, EngineConfig, Manifest, Precision};
use dobi::coordinator::{Engine, SubmitError};
use dobi::evalx;
use dobi::lowrank::synth::{tiny_model, TinyDims};
use dobi::lowrank::{FactorizedModel, NativeBackend};
use dobi::runtime::Backend;
use dobi::tokenizer::ByteTokenizer;

/// The shared synthetic nano config (`TinyDims::nano`): byte vocab, and
/// targets that dominate the embedding so ratio 0.4 allocates meaningfully.
fn dims() -> TinyDims {
    TinyDims::nano()
}

fn cfg(ratio: f64, precision: Precision) -> CompressConfig {
    CompressConfig {
        ratio,
        precision,
        calib_batches: 3,
        calib_batch: 2,
        calib_seq: 12,
        ..Default::default()
    }
}

fn corpus() -> Vec<i32> {
    calib::synth_calib_tokens(256, 2000, 19)
}

/// Compress the synth dense model into a fresh artifacts dir.
fn fixture(tag: &str, ratio: f64, precision: Precision)
           -> (std::path::PathBuf, CompressedArtifact) {
    let dense = tiny_model(dims(), 0, false);
    let art = compress_model(&dense, "tiny", &cfg(ratio, precision), &corpus())
        .expect("compression succeeds");
    let dir = std::env::temp_dir().join(format!("dobi_compress_it_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    write_artifacts(&dir, &art).expect("artifacts written");
    (dir, art)
}

/// The ISSUE acceptance path: synth dense → `dobi compress` at ratio 0.4
/// → load through the native backend → eval loss within 1e-3 of the
/// in-memory directly-factorized reference.
#[test]
fn compressed_store_eval_loss_matches_reference() {
    let (dir, art) = fixture("accept", 0.4, Precision::F32);
    let m = Manifest::load(&dir).unwrap();
    let loaded = NativeBackend.load_variant(&m, &art.variant_id, None).unwrap();
    let toks = corpus();
    let l_store = eval_loss(&loaded.model, &toks, 2, 16, 6, 5).unwrap();
    let l_ref = eval_loss(&art.reference, &toks, 2, 16, 6, 5).unwrap();
    assert!((l_store - l_ref).abs() < 1e-3,
            "store {l_store} vs in-memory reference {l_ref}");
    // and the compression was real: the stored payload beats dense f32
    let dense_bytes = 4 * art.total_params;
    assert!(loaded.stats.payload_bytes < dense_bytes,
            "{} payload !< {dense_bytes} dense", loaded.stats.payload_bytes);
    // sanity: CE stays in the plausible band around uniform (ln 256) —
    // the synth model is untrained, so this guards NaN/blow-up, not skill
    let uniform = (256f64).ln();
    assert!(l_store.is_finite() && l_store < uniform + 2.0,
            "compressed CE {l_store} vs uniform {uniform}");
}

/// Port of `rust_ppl_matches_python_reference` shape: ppl (exp CE) of the
/// reloaded q8 store stays within a few percent of its own f32 reference
/// twin — the quantization drift bound, measured end to end.
#[test]
fn q8_fixture_ppl_close_to_f32_reference() {
    let (dir, art) = fixture("q8", 0.5, Precision::Q8);
    let m = Manifest::load(&dir).unwrap();
    let loaded = NativeBackend.load_variant(&m, &art.variant_id, None).unwrap();
    let toks = corpus();
    let ppl_store = eval_loss(&loaded.model, &toks, 2, 16, 6, 7).unwrap().exp();
    let ppl_ref = eval_loss(&art.reference, &toks, 2, 16, 6, 7).unwrap().exp();
    let rel = (ppl_store - ppl_ref).abs() / ppl_ref;
    assert!(rel < 0.1, "q8 store ppl {ppl_store} vs f32 reference {ppl_ref} ({rel:.3} rel)");
    // int8 factors must shrink the resident footprint vs the f32 twin
    assert!(loaded.stats.weight_bytes < art.reference.resident_bytes());
}

/// Port of `generation_is_deterministic_and_decodable` onto the
/// compressed fixture (native backend, no PJRT).
#[test]
fn generation_deterministic_on_compressed_store() {
    let (dir, art) = fixture("gen", 0.5, Precision::Q8);
    let m = Manifest::load(&dir).unwrap();
    let model = NativeBackend.load_variant(&m, &art.variant_id, None).unwrap().model;
    let a = evalx::generate(&model, 1, 16, "The ", 24, 0.7, 42).unwrap();
    let b = evalx::generate(&model, 1, 16, "The ", 24, 0.7, 42).unwrap();
    assert_eq!(a, b, "same seed must reproduce");
    let c = evalx::generate(&model, 1, 16, "The ", 24, 0.7, 43).unwrap();
    assert!(!c.is_empty());
    let g = evalx::generate(&model, 1, 16, "The ", 8, 0.0, 1).unwrap();
    assert_eq!(g.len(), ByteTokenizer.decode(&ByteTokenizer.encode(&g)).len());
}

/// Port of `engine_serves_concurrent_clients`, doubling as the any-seq
/// admission test: the compressed manifest carries an **empty** `hlo`
/// map, so the engine must register the variant in any-seq mode and serve
/// mixed sequence lengths exactly (no padding, no phantom HLO entries).
#[test]
fn engine_serves_compressed_any_seq_variant() {
    let (dir, art) = fixture("engine", 0.5, Precision::Q8);
    let id = art.variant_id.clone();
    let cfg = EngineConfig { max_batch: 2, backend: BackendKind::Native, ..Default::default() };
    let engine = Arc::new(Engine::start(dir, &[id.clone()], cfg, None).unwrap());
    let meta = engine.router().get(&id).unwrap();
    assert!(meta.any_seq(), "empty-hlo manifest must register as any-seq");
    assert_eq!(engine.router().pick_seq(&id, 33), Some(33));

    let mut handles = Vec::new();
    for t in 0..3u64 {
        let eng = engine.clone();
        let vid = id.clone();
        handles.push(std::thread::spawn(move || {
            let tok = ByteTokenizer;
            // three different window lengths, none "exported" anywhere
            for (i, seq) in [9usize, 16, 33].into_iter().enumerate() {
                let win = tok.encode_window(&format!("client {t} msg {i} "), seq, 32);
                let resp = eng.infer(&vid, win, None).unwrap();
                assert_eq!(resp.output.len(), 256, "last-position logit width");
                assert!(resp.output.iter().all(|x| x.is_finite()));
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let stats = engine.stats();
    assert_eq!(stats.served, 9);
    assert!(stats.mean_batch >= 1.0);
    // admission control still rejects what it must
    match engine.submit("tiny/nope", vec![1; 8], None) {
        Err(SubmitError::UnknownVariant(_)) => {}
        other => panic!("expected UnknownVariant, got {other:?}"),
    }
    match engine.submit(&id, Vec::new(), None) {
        Err(SubmitError::BadShape { .. }) => {}
        other => panic!("expected BadShape for empty window, got {other:?}"),
    }
    engine.shutdown();
}

/// The compressed store must also load as a plain `FactorizedModel` with
/// the manifest-recorded ranks actually effective per target.
#[test]
fn manifest_ranks_are_effective_in_loaded_model() {
    let (dir, art) = fixture("ranks", 0.4, Precision::Q8);
    let m = Manifest::load(&dir).unwrap();
    let v = m.variant(&art.variant_id).unwrap();
    let store = dobi::storage::Store::open(&m.path(&v.weights)).unwrap();
    let model = FactorizedModel::from_store(&m.models["tiny"], v, &store).unwrap();
    for layer in &model.layers {
        for lin in layer.mats() {
            let want = art.ranks[lin.name()];
            assert_eq!(lin.rank(), want, "{}: rank mismatch", lin.name());
            assert!(lin.rank() >= 1);
        }
    }
    // compression must actually truncate: at ratio 0.4 no target can stay
    // full-rank on every matrix kind simultaneously
    let total_rank: usize = model.layers.iter()
        .flat_map(|l| l.mats().into_iter().map(|lin| lin.rank()))
        .sum();
    let full_rank: usize = model.layers.iter()
        .flat_map(|l| l.mats().into_iter().map(|lin| lin.in_dim().min(lin.out_dim())))
        .sum();
    assert!(total_rank < full_rank, "ratio 0.4 must truncate somewhere");
}
