//! Integration tests over real artifacts (runtime + eval + coordinator).
//!
//! Require `make artifacts` (or DOBI_ARTIFACTS pointing at a build); each
//! test skips gracefully when artifacts are absent so `cargo test` stays
//! green on a fresh checkout.  Tests that additionally need a working PJRT
//! client are `#[ignore]`d (the offline build links the xla API stub);
//! run them with `cargo test -- --ignored` on a machine with the real
//! bindings.  `tests/native_backend.rs` covers the same serving paths on
//! the native backend with synthetic artifacts, so CI still exercises the
//! engine end to end.

use std::sync::Arc;

use dobi::bench::{artifacts_available, artifacts_dir};
use dobi::config::{BackendKind, EngineConfig, Manifest};
use dobi::coordinator::{Engine, SubmitError};
use dobi::corpusio;
use dobi::evalx;
use dobi::runtime::Runtime;
use dobi::storage::Store;
use dobi::tokenizer::ByteTokenizer;

macro_rules! require_artifacts {
    () => {
        if !artifacts_available() {
            eprintln!("[skip] artifacts not built (run `make artifacts`)");
            return;
        }
    };
}

fn manifest() -> Manifest {
    Manifest::load(&artifacts_dir()).expect("manifest loads")
}

#[test]
fn manifest_is_consistent() {
    require_artifacts!();
    let m = manifest();
    assert!(!m.variants.is_empty());
    for v in &m.variants {
        assert!(m.models.contains_key(&v.model), "{}: unknown model", v.id);
        assert!(!v.param_names.is_empty(), "{}: no params", v.id);
        assert!(!v.hlo.is_empty(), "{}: no hlo", v.id);
        assert!(m.path(&v.weights).exists(), "{}: weights missing", v.id);
        for f in v.hlo.values() {
            assert!(m.path(f).exists(), "{}: hlo file {} missing", v.id, f);
        }
    }
}

#[test]
fn storage_matches_manifest_params() {
    require_artifacts!();
    let m = manifest();
    let v = m.variant("llama-nano/dense").unwrap();
    let store = Store::open(&m.path(&v.weights)).unwrap();
    let minfo = &m.models["llama-nano"];
    let mut total = 0usize;
    for name in &v.param_names {
        let (vals, shape) = store.tensor_f32(name).unwrap();
        assert_eq!(vals.len(), shape.iter().product::<usize>());
        total += vals.len();
    }
    assert_eq!(total, minfo.total_params, "dense store must hold every param");
}

#[test]
fn quantized_store_dequantizes_all_factors() {
    require_artifacts!();
    let m = manifest();
    let v = m
        .variants
        .iter()
        .find(|v| v.method == "dobi" && v.kernel == "xla")
        .expect("a dobi variant");
    let store = Store::open(&m.path(&v.weights)).unwrap();
    let n_q8 = store.tensors.keys().filter(|k| k.ends_with(".q8")).count();
    assert!(n_q8 > 0, "remapped variant stores int8 factors");
    for name in &v.param_names {
        let (vals, _) = store.tensor_f32(name).unwrap();
        assert!(vals.iter().all(|x| x.is_finite()), "{name} has non-finite values");
    }
    // remapped on-disk payload must beat the dense fp32 footprint
    let dense = m.variant("llama-nano/dense").unwrap();
    let dstore = Store::open(&m.path(&dense.weights)).unwrap();
    assert!(store.payload_bytes() < dstore.payload_bytes());
}

#[test]
#[ignore = "requires PJRT artifacts (make artifacts) and the real xla bindings; the offline xla-stub cannot execute HLO"]
fn rust_ppl_matches_python_reference() {
    require_artifacts!();
    let m = manifest();
    let rt = Runtime::new().unwrap();
    let shapes = [(m.eval_batch, m.eval_seq)];
    for id in ["llama-nano/dense", "llama-nano/dobi_60"] {
        let v = m.variant(id).unwrap();
        if v.ref_ppl.is_empty() {
            continue;
        }
        let model = rt.load_variant(&m, id, Some(&shapes)).unwrap();
        for (corpus, &ref_ppl) in &v.ref_ppl {
            if !ref_ppl.is_finite() {
                continue;
            }
            let ppl = evalx::perplexity(&model, &m, corpus).unwrap();
            let rel = (ppl - ref_ppl).abs() / ref_ppl;
            assert!(rel < 0.01,
                    "{id}/{corpus}: rust {ppl:.3} vs python {ref_ppl:.3} ({rel:.3} rel)");
        }
    }
}

#[test]
fn compression_quality_ordering() {
    require_artifacts!();
    let m = manifest();
    // Headline shape: at the lowest ratio, Dobi-SVD beats direct weight
    // truncation on in-domain PPL (python refs; measured live in benches).
    let get = |id: &str| m.variant(id).ok().and_then(|v| v.ref_ppl.get("wiki-syn")).copied();
    if let (Some(dobi), Some(wsvd)) = (get("llama-nano/dobi_40"), get("llama-nano/weight_svd_40")) {
        assert!(dobi < wsvd, "dobi {dobi} !< weight_svd {wsvd}");
    }
    if let (Some(d), Some(dn)) = (get("llama-nano/dobi_40"), get("llama-nano/dense")) {
        assert!(d >= dn * 0.8, "compressed model implausibly better than dense");
    }
}

#[test]
#[ignore = "requires PJRT artifacts (make artifacts) and the real xla bindings; the offline xla-stub cannot execute HLO"]
fn generation_is_deterministic_and_decodable() {
    require_artifacts!();
    let m = manifest();
    let rt = Runtime::new().unwrap();
    let v = m.variant("llama-nano/dense").unwrap();
    let (b, s) = v.shapes().into_iter().min_by_key(|&(b, _)| b).unwrap();
    let model = rt.load_variant(&m, "llama-nano/dense", Some(&[(b, s)])).unwrap();
    let a = evalx::generate(&model, b, s, "The ", 24, 0.7, 42).unwrap();
    let b2 = evalx::generate(&model, b, s, "The ", 24, 0.7, 42).unwrap();
    assert_eq!(a, b2, "same seed must reproduce");
    let c = evalx::generate(&model, b, s, "The ", 24, 0.7, 43).unwrap();
    assert!(!c.is_empty());
    // greedy differs from nothing: sanity only
    let g = evalx::generate(&model, b, s, "The ", 8, 0.0, 1).unwrap();
    assert_eq!(g.len(), ByteTokenizer.decode(&ByteTokenizer.encode(&g)).len());
}

#[test]
#[ignore = "requires PJRT artifacts (make artifacts) and the real xla bindings; the offline xla-stub cannot execute HLO"]
fn task_suites_score_in_range() {
    require_artifacts!();
    let m = manifest();
    let suites_file = match &m.suites_file {
        Some(f) => f.clone(),
        None => return,
    };
    let suites = corpusio::read_suites(&m.path(&suites_file)).unwrap();
    let rt = Runtime::new().unwrap();
    let model = rt
        .load_variant(&m, "llama-nano/dense", Some(&[(m.eval_batch, m.eval_seq)]))
        .unwrap();
    let r = evalx::run_suite(&model, &suites[0], m.eval_batch, m.eval_seq, 10).unwrap();
    assert!(r.accuracy >= 0.0 && r.accuracy <= 1.0);
    assert_eq!(r.n, 10);
}

#[test]
#[ignore = "requires PJRT artifacts (make artifacts) and the real xla bindings; the offline xla-stub cannot execute HLO"]
fn vla_eval_end_to_end() {
    require_artifacts!();
    let m = manifest();
    let (vla_file, id) = match (&m.vla_file, m.variant("vla-nano/dense")) {
        (Some(f), Ok(_)) => (f.clone(), "vla-nano/dense"),
        _ => return,
    };
    let (_, samples) = corpusio::read_vla(&m.path(&vla_file)).unwrap();
    let rt = Runtime::new().unwrap();
    let model = rt.load_variant(&m, id, Some(&[(m.eval_batch, m.eval_seq)])).unwrap();
    let r = evalx::run_vla(&model, &samples, m.eval_batch, m.eval_seq, 16).unwrap();
    assert!(r.coords_mse.is_finite() && r.coords_mse < 2.0, "mse {}", r.coords_mse);
    assert!(r.gripper_acc >= 0.3, "gripper acc {}", r.gripper_acc);
}

// ---------------------------------------------------------------------------
// Coordinator over the real runtime
// ---------------------------------------------------------------------------

#[test]
#[ignore = "requires PJRT artifacts (make artifacts) and the real xla bindings; the offline xla-stub cannot execute HLO"]
fn engine_serves_concurrent_clients() {
    require_artifacts!();
    let m = manifest();
    let (b, s) = (m.eval_batch, m.eval_seq);
    let cfg = EngineConfig { max_batch: b, batch_deadline_us: 1500, queue_depth: 64, workers: 1,
                             backend: BackendKind::Pjrt };
    let engine = Arc::new(
        Engine::start(artifacts_dir(), &["llama-nano/dense".to_string()], cfg,
                      Some(vec![(b, s)]))
        .unwrap(),
    );
    let mut handles = Vec::new();
    for t in 0..4 {
        let eng = engine.clone();
        handles.push(std::thread::spawn(move || {
            let tok = ByteTokenizer;
            for i in 0..6 {
                let win = tok.encode_window(&format!("request {t} {i} the quick "), s, 32);
                let resp = eng.infer("llama-nano/dense", win, None).unwrap();
                assert_eq!(resp.output.len(), 256, "logit width");
                assert!(resp.output.iter().all(|x| x.is_finite()));
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let stats = engine.stats();
    assert_eq!(stats.served, 24);
    assert!(stats.batches <= 24);
    assert!(stats.mean_batch >= 1.0);
    engine.shutdown();
}

#[test]
#[ignore = "requires PJRT artifacts (make artifacts) and the real xla bindings; the offline xla-stub cannot execute HLO"]
fn engine_batches_under_load() {
    require_artifacts!();
    let m = manifest();
    let (b, s) = (m.eval_batch, m.eval_seq);
    let cfg = EngineConfig { max_batch: b, batch_deadline_us: 20_000, queue_depth: 256, workers: 1,
                             backend: BackendKind::Pjrt };
    let engine = Engine::start(artifacts_dir(), &["llama-nano/dense".to_string()], cfg,
                               Some(vec![(b, s)])).unwrap();
    let tok = ByteTokenizer;
    // Burst-submit so the deadline window can coalesce them.
    let rxs: Vec<_> = (0..12)
        .map(|i| {
            engine
                .submit("llama-nano/dense",
                        tok.encode_window(&format!("burst {i} "), s, 32), None)
                .unwrap()
        })
        .collect();
    for rx in rxs {
        let resp = rx.recv().unwrap();
        assert!(!resp.output.is_empty());
    }
    let stats = engine.stats();
    assert!(stats.mean_batch > 1.2,
            "expected batching under burst load, mean {}", stats.mean_batch);
    engine.shutdown();
}

#[test]
#[ignore = "requires PJRT artifacts (make artifacts) and the real xla bindings; the offline xla-stub cannot execute HLO"]
fn engine_rejects_bad_requests() {
    require_artifacts!();
    let m = manifest();
    let (b, s) = (m.eval_batch, m.eval_seq);
    let cfg = EngineConfig { backend: BackendKind::Pjrt, ..Default::default() };
    let engine = Engine::start(artifacts_dir(), &["llama-nano/dense".to_string()], cfg,
                               Some(vec![(b, s)])).unwrap();
    match engine.submit("nope/nothere", vec![0; s], None) {
        Err(SubmitError::UnknownVariant(_)) => {}
        other => panic!("expected UnknownVariant, got {other:?}"),
    }
    match engine.submit("llama-nano/dense", vec![0; s + 1], None) {
        Err(SubmitError::BadShape { .. }) => {}
        other => panic!("expected BadShape, got {other:?}"),
    }
    engine.shutdown();
}

#[test]
#[ignore = "requires PJRT artifacts (make artifacts) and the real xla bindings; the offline xla-stub cannot execute HLO"]
fn engine_backpressure_queue_full() {
    require_artifacts!();
    let m = manifest();
    let (b, s) = (m.eval_batch, m.eval_seq);
    let cfg = EngineConfig { max_batch: b, batch_deadline_us: 500, queue_depth: 2, workers: 1,
                             backend: BackendKind::Pjrt };
    let engine = Engine::start(artifacts_dir(), &["llama-nano/dense".to_string()], cfg,
                               Some(vec![(b, s)])).unwrap();
    let mut rejected = false;
    let mut rxs = Vec::new();
    for _ in 0..40 {
        match engine.submit("llama-nano/dense", vec![32; s], None) {
            Ok(rx) => rxs.push(rx),
            Err(SubmitError::QueueFull { .. }) => {
                rejected = true;
                break;
            }
            Err(e) => panic!("unexpected: {e:?}"),
        }
    }
    assert!(rejected, "depth-2 queue must reject a 40-burst");
    for rx in rxs {
        let _ = rx.recv();
    }
    engine.shutdown();
}

// ---------------------------------------------------------------------------
// Server protocol over TCP
// ---------------------------------------------------------------------------

#[test]
#[ignore = "requires PJRT artifacts (make artifacts) and the real xla bindings; the offline xla-stub cannot execute HLO"]
fn server_line_protocol_roundtrip() {
    require_artifacts!();
    use std::io::{BufRead, BufReader, Write};
    let m = manifest();
    let (b, s) = (m.eval_batch, m.eval_seq);
    let cfg = EngineConfig { max_batch: b, backend: BackendKind::Pjrt, ..Default::default() };
    let engine = Arc::new(Engine::start(artifacts_dir(), &["llama-nano/dense".to_string()],
                                        cfg, Some(vec![(b, s)])).unwrap());
    let mut server = dobi::server::Server::builder().engine(engine.clone()).start().unwrap();
    let mut conn = std::net::TcpStream::connect(server.addr).unwrap();
    conn.write_all(
        b"{\"variant\":\"llama-nano/dense\",\"prompt\":\"The \",\"max_tokens\":4}\n",
    )
    .unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let j = dobi::json::Json::parse(&line).unwrap();
    assert!(j.get("text").is_some(), "reply: {line}");
    assert!(j.get("tokens_per_s").and_then(|x| x.as_f64()).unwrap() > 0.0);
    // malformed request -> error object, connection stays usable
    conn.write_all(b"not json\n").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(dobi::json::Json::parse(&line).unwrap().get("error").is_some());
    drop(conn);
    server.shutdown();
    engine.shutdown();
}
