//! Fixture-backed tests for `dobi lint` (`rust/src/analysis/`).
//!
//! Each rule gets a positive fixture (the violation it exists to catch)
//! and a negative fixture (the compliant way to write the same thing),
//! assembled into synthetic [`Context`]s so the tests pin rule behavior
//! without depending on the live tree. The live tree itself is covered
//! by `tree_is_lint_clean` (`--ignored`; CI runs it in the lint job —
//! it needs the checkout layout, not just the crate).

use dobi::analysis::{run, Context, Finding, Severity, SourceFile};

fn ctx(files: &[(&str, &str)], readme: &str) -> Context {
    Context {
        files: files.iter().map(|(p, t)| SourceFile::new(p, t)).collect(),
        readme: readme.to_string(),
    }
}

fn denies(findings: &[Finding]) -> Vec<&Finding> {
    findings.iter().filter(|f| f.severity == Severity::Deny).collect()
}

fn has(findings: &[Finding], needle: &str) -> bool {
    findings.iter().any(|f| f.message.contains(needle))
}

// ---------------------------------------------------------------------------
// panic-freedom

const PANIC_BAD: &str = include_str!("analysis_fixtures/panic_bad.rs");
const PANIC_GOOD: &str = include_str!("analysis_fixtures/panic_good.rs");

#[test]
fn panic_freedom_catches_unwrap_expect_panic_and_indexing() {
    let c = ctx(&[("rust/src/serve/fixture.rs", PANIC_BAD)], "");
    let f = run(&c, Some("panic-freedom")).unwrap();
    assert_eq!(denies(&f).len(), 3, "findings: {f:?}");
    assert!(has(&f, "`.unwrap()`"), "findings: {f:?}");
    assert!(has(&f, "`.expect()`"), "findings: {f:?}");
    assert!(has(&f, "`panic!`"), "findings: {f:?}");
    let warns: Vec<_> = f.iter().filter(|x| x.severity == Severity::Warn).collect();
    assert_eq!(warns.len(), 1, "findings: {f:?}");
    assert!(warns[0].message.contains("indexing"), "findings: {f:?}");
}

#[test]
fn panic_freedom_passes_compliant_code() {
    let c = ctx(&[("rust/src/serve/fixture.rs", PANIC_GOOD)], "");
    let f = run(&c, Some("panic-freedom")).unwrap();
    assert!(f.is_empty(), "findings: {f:?}");
}

#[test]
fn panic_freedom_only_covers_the_serve_path_dirs() {
    // The same violations outside serve/, server/, trace/, metrics/ are
    // out of scope (compress may unwrap on startup).
    let c = ctx(&[("rust/src/compress/fixture.rs", PANIC_BAD)], "");
    let f = run(&c, Some("panic-freedom")).unwrap();
    assert!(f.is_empty(), "findings: {f:?}");
}

// ---------------------------------------------------------------------------
// lock-order

const LOCK_BAD: &str = include_str!("analysis_fixtures/lock_bad.rs");
const LOCK_GOOD: &str = include_str!("analysis_fixtures/lock_good.rs");

#[test]
fn lock_order_catches_both_acquisition_forms() {
    let c = ctx(&[("rust/src/serve/fixture.rs", LOCK_BAD)], "");
    let f = run(&c, Some("lock-order")).unwrap();
    assert_eq!(denies(&f).len(), 2, "findings: {f:?}");
    assert!(has(&f, "fn tick"), "findings: {f:?}");
    assert!(has(&f, "fn drain"), "findings: {f:?}");
    assert!(has(&f, "registry -> metrics -> trace"), "findings: {f:?}");
}

#[test]
fn lock_order_passes_declared_order() {
    let c = ctx(&[("rust/src/serve/fixture.rs", LOCK_GOOD)], "");
    let f = run(&c, Some("lock-order")).unwrap();
    assert!(f.is_empty(), "findings: {f:?}");
}

// ---------------------------------------------------------------------------
// metric-drift

const METRIC_NAMES: &str = include_str!("analysis_fixtures/metric_names.rs");
const METRIC_NAMES_BAD: &str = include_str!("analysis_fixtures/metric_names_bad.rs");
const METRIC_USER: &str = include_str!("analysis_fixtures/metric_user.rs");
const METRIC_USER_BAD: &str = include_str!("analysis_fixtures/metric_user_bad.rs");
const METRIC_README_GOOD: &str = include_str!("analysis_fixtures/metric_readme_good.md");
const METRIC_README_BAD: &str = include_str!("analysis_fixtures/metric_readme_bad.md");

#[test]
fn metric_drift_passes_consistent_artifacts() {
    let c = ctx(
        &[
            ("rust/src/metrics/names.rs", METRIC_NAMES),
            ("rust/src/serve/user.rs", METRIC_USER),
        ],
        METRIC_README_GOOD,
    );
    let f = run(&c, Some("metric-drift")).unwrap();
    assert!(f.is_empty(), "findings: {f:?}");
}

#[test]
fn metric_drift_catches_all_four_drift_directions() {
    let c = ctx(
        &[
            ("rust/src/metrics/names.rs", METRIC_NAMES_BAD),
            ("rust/src/serve/user.rs", METRIC_USER_BAD),
        ],
        METRIC_README_BAD,
    );
    let f = run(&c, Some("metric-drift")).unwrap();
    assert_eq!(denies(&f).len(), 4, "findings: {f:?}");
    assert!(has(&f, "`serve_stale_gauge` (const STALE) is undocumented"), "findings: {f:?}");
    assert!(has(&f, "`serve_ghost_total` but metrics::names has no such constant"), "findings: {f:?}");
    assert!(has(&f, "literal `\"serve_rogue_total\"`"), "findings: {f:?}");
    assert!(has(&f, "STALE is never referenced"), "findings: {f:?}");
}

// ---------------------------------------------------------------------------
// protocol-drift

const PROTOCOL_STREAM: &str = include_str!("analysis_fixtures/protocol_stream.rs");
const PROTOCOL_STREAM_BAD: &str = include_str!("analysis_fixtures/protocol_stream_bad.rs");
const PROTOCOL_README_GOOD: &str = include_str!("analysis_fixtures/protocol_readme_good.md");
const PROTOCOL_README_BAD: &str = include_str!("analysis_fixtures/protocol_readme_bad.md");

#[test]
fn protocol_drift_passes_matching_table() {
    let c = ctx(&[("rust/src/serve/stream.rs", PROTOCOL_STREAM)], PROTOCOL_README_GOOD);
    let f = run(&c, Some("protocol-drift")).unwrap();
    assert!(f.is_empty(), "findings: {f:?}");
}

#[test]
fn protocol_drift_catches_readme_drift_both_directions() {
    let c = ctx(&[("rust/src/serve/stream.rs", PROTOCOL_STREAM)], PROTOCOL_README_BAD);
    let f = run(&c, Some("protocol-drift")).unwrap();
    assert_eq!(denies(&f).len(), 2, "findings: {f:?}");
    assert!(has(&f, "op `swap` is parsed but missing"), "findings: {f:?}");
    assert!(has(&f, "field `stream` that stream.rs does not declare"), "findings: {f:?}");
}

#[test]
fn protocol_drift_catches_declared_but_unparsed_op() {
    let c = ctx(&[("rust/src/serve/stream.rs", PROTOCOL_STREAM_BAD)], PROTOCOL_README_GOOD);
    let f = run(&c, Some("protocol-drift")).unwrap();
    assert!(has(&f, "declared op `health` never appears"), "findings: {f:?}");
    assert!(has(&f, "op `health` is parsed but missing"), "findings: {f:?}");
}

// ---------------------------------------------------------------------------
// flag-drift

const FLAG_MAIN: &str = include_str!("analysis_fixtures/flag_main.rs");
const FLAG_MAIN_BAD: &str = include_str!("analysis_fixtures/flag_main_bad.rs");
const FLAG_CONFIG: &str = include_str!("analysis_fixtures/flag_config.rs");
const FLAG_README: &str = include_str!("analysis_fixtures/flag_readme.md");

#[test]
fn flag_drift_passes_fully_mapped_flags() {
    let c = ctx(
        &[
            ("rust/src/main.rs", FLAG_MAIN),
            ("rust/src/config/mod.rs", FLAG_CONFIG),
        ],
        FLAG_README,
    );
    let f = run(&c, Some("flag-drift")).unwrap();
    assert!(f.is_empty(), "findings: {f:?}");
}

#[test]
fn flag_drift_catches_unmapped_unmentioned_and_stale_flags() {
    let c = ctx(
        &[
            ("rust/src/main.rs", FLAG_MAIN_BAD),
            ("rust/src/config/mod.rs", FLAG_CONFIG),
        ],
        FLAG_README,
    );
    let f = run(&c, Some("flag-drift")).unwrap();
    assert_eq!(denies(&f).len(), 3, "findings: {f:?}");
    assert!(has(&f, "`--mystery-flag` is read by serve/compress but never mentioned"), "findings: {f:?}");
    assert!(has(&f, "`--mystery-flag` has no entry"), "findings: {f:?}");
    assert!(has(&f, "stale FLAG_MAP entry: `--seed`"), "findings: {f:?}");
}

// ---------------------------------------------------------------------------
// trace-phase-pairing

const TRACE_PHASES: &str = include_str!("analysis_fixtures/trace_phases.rs");
const TRACE_PHASES_BAD: &str = include_str!("analysis_fixtures/trace_phases_bad.rs");
const TRACE_USER: &str = include_str!("analysis_fixtures/trace_user.rs");
const TRACE_USER_BAD: &str = include_str!("analysis_fixtures/trace_user_bad.rs");
const TRACE_README_GOOD: &str = include_str!("analysis_fixtures/trace_readme_good.md");
const TRACE_README_BAD: &str = include_str!("analysis_fixtures/trace_readme_bad.md");

#[test]
fn trace_phases_passes_paired_artifacts() {
    let c = ctx(
        &[
            ("rust/src/trace/phases.rs", TRACE_PHASES),
            ("rust/src/trace/user.rs", TRACE_USER),
        ],
        TRACE_README_GOOD,
    );
    let f = run(&c, Some("trace-phase-pairing")).unwrap();
    assert!(f.is_empty(), "findings: {f:?}");
}

#[test]
fn trace_phases_catches_every_pairing_break() {
    let c = ctx(
        &[
            ("rust/src/trace/phases.rs", TRACE_PHASES_BAD),
            ("rust/src/trace/user.rs", TRACE_USER_BAD),
        ],
        TRACE_README_BAD,
    );
    let f = run(&c, Some("trace-phase-pairing")).unwrap();
    assert_eq!(denies(&f).len(), 5, "findings: {f:?}");
    assert!(has(&f, "GHOST is missing from phases::ALL"), "findings: {f:?}");
    assert!(has(&f, "references `MISSING`"), "findings: {f:?}");
    assert!(has(&f, "`ghost` (const GHOST) is undocumented"), "findings: {f:?}");
    assert!(has(&f, "string literal `\"prefill\"`"), "findings: {f:?}");
    assert!(has(&f, "lists `bogus`"), "findings: {f:?}");
}

// ---------------------------------------------------------------------------
// compress_* namespace coverage (metric-drift + trace-phase-pairing)

const CMETRIC_NAMES: &str = include_str!("analysis_fixtures/compress_metric_names.rs");
const CMETRIC_NAMES_BAD: &str = include_str!("analysis_fixtures/compress_metric_names_bad.rs");
const CMETRIC_USER: &str = include_str!("analysis_fixtures/compress_metric_user.rs");
const CMETRIC_USER_BAD: &str = include_str!("analysis_fixtures/compress_metric_user_bad.rs");
const CMETRIC_README_GOOD: &str = include_str!("analysis_fixtures/compress_metric_readme_good.md");
const CMETRIC_README_BAD: &str = include_str!("analysis_fixtures/compress_metric_readme_bad.md");
const CTRACE_PHASES: &str = include_str!("analysis_fixtures/compress_trace_phases.rs");
const CTRACE_USER: &str = include_str!("analysis_fixtures/compress_trace_user.rs");
const CTRACE_USER_BAD: &str = include_str!("analysis_fixtures/compress_trace_user_bad.rs");
const CTRACE_README: &str = include_str!("analysis_fixtures/compress_trace_readme.md");

#[test]
fn metric_drift_accepts_consistent_compress_families() {
    let c = ctx(
        &[
            ("rust/src/metrics/names.rs", CMETRIC_NAMES),
            ("rust/src/compress/user.rs", CMETRIC_USER),
        ],
        CMETRIC_README_GOOD,
    );
    let f = run(&c, Some("metric-drift")).unwrap();
    assert!(f.is_empty(), "findings: {f:?}");
}

#[test]
fn metric_drift_catches_compress_drift_in_all_four_directions() {
    let c = ctx(
        &[
            ("rust/src/metrics/names.rs", CMETRIC_NAMES_BAD),
            ("rust/src/compress/user.rs", CMETRIC_USER_BAD),
        ],
        CMETRIC_README_BAD,
    );
    let f = run(&c, Some("metric-drift")).unwrap();
    assert_eq!(denies(&f).len(), 4, "findings: {f:?}");
    assert!(has(&f, "`compress_stale_gauge` (const CSTALE) is undocumented"), "findings: {f:?}");
    assert!(has(&f, "`compress_ghost_total` but metrics::names has no such constant"), "findings: {f:?}");
    assert!(has(&f, "literal `\"compress_rogue_total\"`"), "findings: {f:?}");
    assert!(has(&f, "CSTALE is never referenced"), "findings: {f:?}");
}

#[test]
fn metric_drift_exempts_phase_values_declared_in_trace_phases() {
    // trace/phases.rs declares `compress_*` phase names as string consts;
    // metric-drift must not read them as bare metric-family literals.
    let c = ctx(
        &[
            ("rust/src/metrics/names.rs", CMETRIC_NAMES),
            ("rust/src/compress/user.rs", CMETRIC_USER),
            ("rust/src/trace/phases.rs", CTRACE_PHASES),
        ],
        CMETRIC_README_GOOD,
    );
    let f = run(&c, Some("metric-drift")).unwrap();
    assert!(f.is_empty(), "findings: {f:?}");
}

#[test]
fn trace_phases_accepts_compress_phase_constants() {
    let c = ctx(
        &[
            ("rust/src/trace/phases.rs", CTRACE_PHASES),
            ("rust/src/compress/user.rs", CTRACE_USER),
        ],
        CTRACE_README,
    );
    let f = run(&c, Some("trace-phase-pairing")).unwrap();
    assert!(f.is_empty(), "findings: {f:?}");
}

#[test]
fn trace_phases_rejects_bare_compress_phase_literal() {
    let c = ctx(
        &[
            ("rust/src/trace/phases.rs", CTRACE_PHASES),
            ("rust/src/compress/user.rs", CTRACE_USER_BAD),
        ],
        CTRACE_README,
    );
    let f = run(&c, Some("trace-phase-pairing")).unwrap();
    assert_eq!(denies(&f).len(), 1, "findings: {f:?}");
    assert!(has(&f, "string literal `\"compress_svd\"`"), "findings: {f:?}");
}

// ---------------------------------------------------------------------------
// suppressions and the full synthetic repo

const SUPPRESS_OK: &str = include_str!("analysis_fixtures/suppress_ok.rs");
const SUPPRESS_BAD: &str = include_str!("analysis_fixtures/suppress_bad.rs");

#[test]
fn suppressions_drop_findings_on_line_and_line_above() {
    let c = ctx(&[("rust/src/serve/boot.rs", SUPPRESS_OK)], "");
    let f = run(&c, Some("panic-freedom")).unwrap();
    assert!(f.is_empty(), "findings: {f:?}");
}

#[test]
fn suppression_for_a_different_rule_does_not_apply() {
    // The allow() names lock-order; the unwraps stay findings.
    let text = SUPPRESS_OK.replace("panic-freedom", "lock-order");
    let c = ctx(&[("rust/src/serve/boot.rs", text.as_str())], "");
    let f = run(&c, Some("panic-freedom")).unwrap();
    assert_eq!(denies(&f).len(), 2, "findings: {f:?}");
}

/// A synthetic repo where every cross-artifact invariant holds: all six
/// rules plus suppression hygiene come back empty.
fn clean_repo_files() -> Vec<(&'static str, &'static str)> {
    vec![
        ("rust/src/metrics/names.rs", METRIC_NAMES),
        ("rust/src/serve/user.rs", METRIC_USER),
        ("rust/src/serve/stream.rs", PROTOCOL_STREAM),
        ("rust/src/trace/phases.rs", TRACE_PHASES),
        ("rust/src/trace/user.rs", TRACE_USER),
        ("rust/src/main.rs", FLAG_MAIN),
        ("rust/src/config/mod.rs", FLAG_CONFIG),
    ]
}

fn clean_repo_readme() -> String {
    format!("{METRIC_README_GOOD}\n{PROTOCOL_README_GOOD}\n{TRACE_README_GOOD}\n{FLAG_README}")
}

#[test]
fn full_run_over_clean_synthetic_repo_is_empty() {
    let c = ctx(&clean_repo_files(), &clean_repo_readme());
    let f = run(&c, None).unwrap();
    assert!(f.is_empty(), "findings: {f:?}");
}

#[test]
fn suppression_hygiene_flags_unknown_rule_and_missing_reason() {
    let mut files = clean_repo_files();
    files.push(("rust/src/util.rs", SUPPRESS_BAD));
    let c = ctx(&files, &clean_repo_readme());
    let f = run(&c, None).unwrap();
    assert_eq!(denies(&f).len(), 2, "findings: {f:?}");
    assert!(f.iter().all(|x| x.rule == "suppression"), "findings: {f:?}");
    assert!(has(&f, "unknown rule `no-such-rule`"), "findings: {f:?}");
    assert!(has(&f, "needs a reason"), "findings: {f:?}");
}

#[test]
fn unknown_rule_name_is_an_error() {
    let c = ctx(&[], "");
    let err = run(&c, Some("no-such-rule")).unwrap_err().to_string();
    assert!(err.contains("unknown rule"), "{err}");
    assert!(err.contains("panic-freedom"), "{err}");
}

// ---------------------------------------------------------------------------
// the live tree

/// The real repo must be deny-clean. Ignored by default because it needs
/// the full checkout layout (README.md beside rust/); the CI lint job
/// runs it explicitly with `--ignored`.
#[test]
#[ignore]
fn tree_is_lint_clean() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate dir has a parent")
        .to_path_buf();
    let c = Context::load(&root).expect("load repo tree");
    let f = run(&c, None).expect("run all rules");
    let d = denies(&f);
    assert!(d.is_empty(), "deny findings on the live tree: {d:#?}");
}
