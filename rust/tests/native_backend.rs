//! End-to-end coverage of the native low-rank backend over synthetic
//! artifacts — storage → backend → coordinator → eval, no PJRT and no
//! `make artifacts`, so these run on every fresh checkout and in CI.

use std::sync::Arc;

use dobi::config::{BackendKind, EngineConfig, Manifest};
use dobi::coordinator::{Engine, SubmitError};
use dobi::evalx;
use dobi::lowrank::synth::{tiny_manifest_json, tiny_store_tensors, SynthStyle, TinyDims};
use dobi::lowrank::NativeBackend;
use dobi::runtime::{make_backend, Backend};
use dobi::storage::write_store;
use dobi::tokenizer::ByteTokenizer;

/// vocab 256 so the byte tokenizer's ids are always in range.
fn dims() -> TinyDims {
    TinyDims { vocab: 256, d: 24, heads: 2, layers: 2, ff: 32 }
}

/// Write a synthetic artifacts dir with a dense and a factorized-int8
/// variant of the same tiny model; returns the dir.
fn build_artifacts(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("dobi_native_it_{tag}"));
    std::fs::create_dir_all(&dir).unwrap();
    write_store(&dir.join("dense.dobiw"),
                &tiny_store_tensors(dims(), 0, SynthStyle::DenseF32)).unwrap();
    write_store(&dir.join("q8.dobiw"),
                &tiny_store_tensors(dims(), 0, SynthStyle::FactorQ8)).unwrap();
    std::fs::write(
        dir.join("manifest.json"),
        tiny_manifest_json(dims(), 0, &[
            ("tiny/dense", "dense", 1.0, "dense.dobiw"),
            ("tiny/dobi_60", "factorized", 0.6, "q8.dobiw"),
        ]),
    )
    .unwrap();
    dir
}

fn native_cfg(max_batch: usize) -> EngineConfig {
    EngineConfig { max_batch, backend: BackendKind::Native, ..Default::default() }
}

#[test]
fn engine_serves_native_models_end_to_end() {
    let dir = build_artifacts("engine");
    let ids = vec!["tiny/dense".to_string(), "tiny/dobi_60".to_string()];
    let engine = Arc::new(Engine::start(dir, &ids, native_cfg(2), None).unwrap());
    let tok = ByteTokenizer;
    let mut handles = Vec::new();
    for t in 0..3 {
        let eng = engine.clone();
        handles.push(std::thread::spawn(move || {
            let tok = ByteTokenizer;
            for i in 0..4 {
                let id = if i % 2 == 0 { "tiny/dense" } else { "tiny/dobi_60" };
                let win = tok.encode_window(&format!("client {t} msg {i} "), 16, 32);
                let resp = eng.infer(id, win, None).unwrap();
                assert_eq!(resp.output.len(), 256, "last-position logit width");
                assert!(resp.output.iter().all(|x| x.is_finite()));
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let stats = engine.stats();
    assert_eq!(stats.served, 12);
    assert!(stats.batches >= 1 && stats.batches <= 12);
    // router sanity on native-registered variants
    assert_eq!(engine.router().by_ratio("tiny", 0.5).unwrap().id, "tiny/dobi_60");
    // bad requests still rejected before reaching the backend
    match engine.submit("tiny/nope", tok.encode_window("x", 16, 32), None) {
        Err(SubmitError::UnknownVariant(_)) => {}
        other => panic!("expected UnknownVariant, got {other:?}"),
    }
    match engine.submit("tiny/dense", vec![0; 5], None) {
        Err(SubmitError::BadShape { .. }) => {}
        other => panic!("expected BadShape, got {other:?}"),
    }
    engine.shutdown();
}

#[test]
fn engine_start_fails_on_missing_weights_file() {
    let dir = std::env::temp_dir().join("dobi_native_it_missing");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("manifest.json"),
        tiny_manifest_json(dims(), 0, &[("tiny/ghost", "dense", 1.0, "nope.dobiw")]),
    )
    .unwrap();
    assert!(Engine::start(dir, &["tiny/ghost".to_string()], native_cfg(2), None).is_err());
}

#[test]
fn generation_deterministic_on_native_backend() {
    let dir = build_artifacts("gen");
    let m = Manifest::load(&dir).unwrap();
    let be = make_backend(BackendKind::Native).unwrap();
    assert_eq!(be.name(), "native-lowrank");
    let model = be.load_variant(&m, "tiny/dense", Some(&[(1, 16)])).unwrap().model;
    let a = evalx::generate(&model, 1, 16, "The ", 12, 0.8, 42).unwrap();
    let b = evalx::generate(&model, 1, 16, "The ", 12, 0.8, 42).unwrap();
    assert_eq!(a, b, "same seed must reproduce");
    assert!(!a.is_empty());
    let greedy = evalx::generate(&model, 1, 16, "The ", 8, 0.0, 1).unwrap();
    assert_eq!(greedy, evalx::generate(&model, 1, 16, "The ", 8, 0.0, 9).unwrap(),
               "greedy is seed-independent");
}

#[test]
fn task_scoring_runs_on_native_backend() {
    let dir = build_artifacts("tasks");
    let m = Manifest::load(&dir).unwrap();
    let loaded = NativeBackend.load_variant(&m, "tiny/dobi_60", None).unwrap();
    let suite = dobi::corpusio::TaskSuite {
        name: "synthetic".into(),
        tasks: vec![dobi::corpusio::Task {
            prompt: "the quick brown ".into(),
            options: vec!["fox".into(), "qqq".into()],
            answer: 0,
        }],
    };
    let r = evalx::run_suite(&loaded.model, &suite, 2, 16, usize::MAX).unwrap();
    assert_eq!(r.n, 1);
    assert!(r.accuracy == 0.0 || r.accuracy == 1.0);
}

#[test]
fn quantized_variant_is_smaller_and_close() {
    let dir = build_artifacts("size");
    let m = Manifest::load(&dir).unwrap();
    let dense = NativeBackend.load_variant(&m, "tiny/dense", None).unwrap();
    let q8 = NativeBackend.load_variant(&m, "tiny/dobi_60", None).unwrap();
    assert!(q8.stats.payload_bytes < dense.stats.payload_bytes,
            "int8 factors must shrink the on-disk payload");
    assert!(q8.stats.weight_bytes < dense.stats.weight_bytes,
            "int8 factors must shrink the resident footprint");
    let tokens: Vec<i32> = (0..32).map(|i| (i * 31) % 256).collect();
    let a = dense.model.forward(2, 16, &tokens, None).unwrap();
    let b = q8.model.forward(2, 16, &tokens, None).unwrap();
    assert_eq!(a.len(), b.len());
    let mean_abs: f32 =
        a.iter().zip(&b).map(|(x, y)| (x - y).abs()).sum::<f32>() / a.len() as f32;
    assert!(mean_abs < 0.5, "quantized twin drifted: mean |Δlogit| = {mean_abs}");
}
