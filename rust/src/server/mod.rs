//! TCP line-protocol serving front-end.
//!
//! Protocol (one JSON object per line):
//!   -> {"variant": "llama-nano/dobi_60", "prompt": "text", "max_tokens": 32,
//!       "temperature": 0.0}
//!   <- {"id": 1, "text": "...", "latency_s": 0.01, "tokens_per_s": 123.4}
//!
//! With `"stream": true` the reply is one `{"id", "delta", "done"}` line
//! per token (see [`crate::serve::stream`]).
//!
//! Generation routes through the incremental decode runtime
//! ([`ServeRuntime`]) when one is attached and serves the variant: KV
//! caches make each token O(len) instead of a full O(len²) window
//! recompute.  Variants the runtime does not carry (PJRT-only artifacts)
//! fall back to the legacy sliding-window loop over `engine.submit()`,
//! where concurrent clients still batch together.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::Engine;
use crate::json::Json;
use crate::mathx::{sample_logits, XorShift};
use crate::serve::{stream as sstream, FinishReason, ServeRuntime};
use crate::tokenizer::ByteTokenizer;

pub struct Server {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind and serve on a background thread with the legacy engine path
    /// only.  `port` 0 picks a free port.
    pub fn start(engine: Arc<Engine>, port: u16) -> Result<Server> {
        Server::start_with(Some(engine), None, port)
    }

    /// [`Server::start`] generalized: generation for variants the decode
    /// runtime serves goes through its scheduler (required for
    /// `"stream": true` requests); everything else falls back to the
    /// engine.  Both are optional so a pure-native deployment does not
    /// load every model twice — at least one must be attached.
    pub fn start_with(engine: Option<Arc<Engine>>, runtime: Option<Arc<ServeRuntime>>,
                      port: u16) -> Result<Server> {
        anyhow::ensure!(engine.is_some() || runtime.is_some(),
                        "server needs an engine or a decode runtime");
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let join = std::thread::Builder::new().name("dobi-server".into()).spawn(move || {
            let mut clients: Vec<std::thread::JoinHandle<()>> = Vec::new();
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        // Reap finished handlers before tracking another:
                        // under connection churn the vec would otherwise
                        // grow one dead JoinHandle per client forever.
                        let mut i = 0;
                        while i < clients.len() {
                            if clients[i].is_finished() {
                                let _ = clients.swap_remove(i).join();
                            } else {
                                i += 1;
                            }
                        }
                        let eng = engine.clone();
                        let rt = runtime.clone();
                        let stop3 = stop2.clone();
                        // Read timeout so handlers can observe shutdown even
                        // when a client keeps an idle connection open.
                        let _ = stream.set_read_timeout(
                            Some(std::time::Duration::from_millis(200)));
                        clients.push(std::thread::spawn(move || {
                            let _ = handle_client(stream, eng, rt, stop3);
                        }));
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
            for c in clients {
                let _ = c.join();
            }
        })?;
        Ok(Server { addr, stop, join: Some(join) })
    }

    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn handle_client(stream: TcpStream, engine: Option<Arc<Engine>>,
                 runtime: Option<Arc<ServeRuntime>>, stop: Arc<AtomicBool>) -> Result<()> {
    let peer = stream.peer_addr()?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut req_no = 0u64;
    loop {
        let mut line = String::new();
        match reader.read_line(&mut line) {
            Ok(0) => break, // client closed
            Ok(_) => {}
            Err(e) if matches!(e.kind(), std::io::ErrorKind::WouldBlock
                               | std::io::ErrorKind::TimedOut) => {
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                continue;
            }
            Err(e) => return Err(e.into()),
        }
        if line.trim().is_empty() {
            continue;
        }
        req_no += 1;
        // Parse once; param extraction is shared by the streaming and
        // one-shot routes below.
        let params = match Json::parse(&line) {
            Ok(req) => sstream::parse_params(&req),
            Err(e) => {
                writer.write_all(error_line(req_no, &format!("bad request json: {e}"))
                    .as_bytes())?;
                writer.write_all(b"\n")?;
                writer.flush()?;
                continue;
            }
        };
        // Streaming requests (for variants the decode runtime carries)
        // write their own line-per-token reply; IO failures mid-stream
        // mean the client hung up — drop them.  Unservable streaming
        // requests fall through to serve_one's explanatory error line.
        if params.stream {
            if let Some(rt) = runtime
                .as_ref()
                .filter(|rt| rt.variants().iter().any(|v| v == &params.variant))
            {
                sstream::run_streaming(rt, &params, req_no, &mut writer)?;
                continue;
            }
        }
        let reply = match serve_one(engine.as_deref(), runtime.as_deref(), &params) {
            Ok(mut obj) => {
                obj.insert("id".into(), Json::Num(req_no as f64));
                Json::Obj(obj).to_string()
            }
            Err(e) => error_line(req_no, &format!("{e:#}")),
        };
        writer.write_all(reply.as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
    }
    let _ = peer;
    Ok(())
}

fn error_line(id: u64, msg: &str) -> String {
    let mut m = std::collections::BTreeMap::new();
    m.insert("id".into(), Json::Num(id as f64));
    m.insert("error".into(), Json::Str(msg.to_string()));
    Json::Obj(m).to_string()
}

fn serve_one(engine: Option<&Engine>, runtime: Option<&ServeRuntime>,
             params: &sstream::GenParams)
             -> Result<std::collections::BTreeMap<String, Json>> {
    anyhow::ensure!(!params.stream,
                    "streaming needs the incremental decode runtime for `{}` \
                     (serve without --no-stream, native-loadable variant)", params.variant);
    // One-shot through the scheduler when it serves the variant: the KV
    // path decodes in O(len) per token instead of re-running full windows.
    if let Some(rt) = runtime {
        if rt.variants().iter().any(|v| v == &params.variant) {
            return sstream::run_oneshot(rt, params);
        }
    }
    // Legacy sliding-window loop over the batching engine (PJRT variants).
    let Some(engine) = engine else {
        anyhow::bail!("variant `{}` is not served by the decode runtime and no \
                       fallback engine is attached", params.variant);
    };
    let tok = ByteTokenizer;
    let mut ctx = tok.encode(&params.prompt);
    let seq = engine
        .router()
        .pick_seq(&params.variant, ctx.len())
        .ok_or_else(|| anyhow::anyhow!("unknown variant `{}`", params.variant))?;
    let mut rng = XorShift::new(params.seed.max(1));
    let mut out_tokens = Vec::new();
    let mut finish = FinishReason::MaxTokens;
    let t0 = Instant::now();
    for _ in 0..params.max_tokens {
        let mut window = vec![b' ' as i32; seq];
        let take = ctx.len().min(seq);
        window[seq - take..].copy_from_slice(&ctx[ctx.len() - take..]);
        let resp = engine.infer(&params.variant, window, None)?;
        anyhow::ensure!(!resp.output.is_empty(), "engine returned empty logits");
        let next = sample_logits(&resp.output, params.temperature, &mut rng) as i32;
        ctx.push(next);
        out_tokens.push(next);
        // same stop-token contract as the decode runtime: emit, then end
        if params.stop_token == Some(next) {
            finish = FinishReason::Stop;
            break;
        }
    }
    let mut m = std::collections::BTreeMap::new();
    // one terminal-payload builder for every reply shape
    sstream::finish_fields(&mut m, &out_tokens, Some(finish), t0.elapsed().as_secs_f64());
    Ok(m)
}
