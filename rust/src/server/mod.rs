//! TCP line-protocol serving front-end over the engine.
//!
//! Protocol (one JSON object per line):
//!   -> {"variant": "llama-nano/dobi_60", "prompt": "text", "max_tokens": 32,
//!       "temperature": 0.0}
//!   <- {"id": 1, "text": "...", "latency_s": 0.01, "tokens_per_s": 123.4}
//!
//! Generation runs a sliding-window loop over engine.submit(), so every
//! generated token flows through the router/batcher like any other
//! request — concurrent clients batch together naturally.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::Engine;
use crate::json::Json;
use crate::mathx::{sample_logits, XorShift};
use crate::tokenizer::ByteTokenizer;

pub struct Server {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind and serve on a background thread.  `port` 0 picks a free port.
    pub fn start(engine: Arc<Engine>, port: u16) -> Result<Server> {
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let join = std::thread::Builder::new().name("dobi-server".into()).spawn(move || {
            let mut clients: Vec<std::thread::JoinHandle<()>> = Vec::new();
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let eng = engine.clone();
                        let stop3 = stop2.clone();
                        // Read timeout so handlers can observe shutdown even
                        // when a client keeps an idle connection open.
                        let _ = stream.set_read_timeout(
                            Some(std::time::Duration::from_millis(200)));
                        clients.push(std::thread::spawn(move || {
                            let _ = handle_client(stream, eng, stop3);
                        }));
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
            for c in clients {
                let _ = c.join();
            }
        })?;
        Ok(Server { addr, stop, join: Some(join) })
    }

    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn handle_client(stream: TcpStream, engine: Arc<Engine>,
                 stop: Arc<AtomicBool>) -> Result<()> {
    let peer = stream.peer_addr()?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut req_no = 0u64;
    loop {
        let mut line = String::new();
        match reader.read_line(&mut line) {
            Ok(0) => break, // client closed
            Ok(_) => {}
            Err(e) if matches!(e.kind(), std::io::ErrorKind::WouldBlock
                               | std::io::ErrorKind::TimedOut) => {
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                continue;
            }
            Err(e) => return Err(e.into()),
        }
        if line.trim().is_empty() {
            continue;
        }
        req_no += 1;
        let reply = match serve_one(&engine, &line) {
            Ok(mut obj) => {
                obj.insert("id".into(), Json::Num(req_no as f64));
                Json::Obj(obj).to_string()
            }
            Err(e) => {
                let mut m = std::collections::BTreeMap::new();
                m.insert("id".into(), Json::Num(req_no as f64));
                m.insert("error".into(), Json::Str(format!("{e:#}")));
                Json::Obj(m).to_string()
            }
        };
        writer.write_all(reply.as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
    }
    let _ = peer;
    Ok(())
}

fn serve_one(engine: &Engine, line: &str)
             -> Result<std::collections::BTreeMap<String, Json>> {
    let req = Json::parse(line).map_err(|e| anyhow::anyhow!("bad request json: {e}"))?;
    let variant = req.str_of("variant").to_string();
    let prompt = req.str_of("prompt").to_string();
    let max_tokens = req.get("max_tokens").and_then(Json::as_usize).unwrap_or(32);
    let temperature = req.get("temperature").and_then(Json::as_f64).unwrap_or(0.0) as f32;
    let seed = req.get("seed").and_then(Json::as_f64).unwrap_or(0.0) as u64;

    let tok = ByteTokenizer;
    let mut ctx = tok.encode(&prompt);
    let seq = engine
        .router()
        .pick_seq(&variant, ctx.len())
        .ok_or_else(|| anyhow::anyhow!("unknown variant `{variant}`"))?;
    let mut rng = XorShift::new(seed.max(1));
    let mut out_tokens = Vec::new();
    let t0 = Instant::now();
    for _ in 0..max_tokens {
        let mut window = vec![b' ' as i32; seq];
        let take = ctx.len().min(seq);
        window[seq - take..].copy_from_slice(&ctx[ctx.len() - take..]);
        let resp = engine.infer(&variant, window, None)?;
        anyhow::ensure!(!resp.output.is_empty(), "engine returned empty logits");
        let next = sample_logits(&resp.output, temperature, &mut rng) as i32;
        ctx.push(next);
        out_tokens.push(next);
    }
    let dt = t0.elapsed().as_secs_f64();
    let mut m = std::collections::BTreeMap::new();
    m.insert("text".into(), Json::Str(tok.decode(&out_tokens)));
    m.insert("latency_s".into(), Json::Num(dt));
    m.insert("tokens_per_s".into(), Json::Num(out_tokens.len() as f64 / dt.max(1e-9)));
    Ok(m)
}
