//! TCP line-protocol serving front-end.
//!
//! Protocol (one JSON object per line, parsed into a typed
//! [`sstream::Request`] — see `README.md` for the versioned spec):
//!   -> {"variant": "llama-nano/dobi_60", "prompt": "text", "max_tokens": 32,
//!       "temperature": 0.0}
//!   <- {"id": 1, "text": "...", "latency_s": 0.01, "tokens_per_s": 123.4}
//!
//! With `"stream": true` the reply is one `{"id", "delta", "done"}` line
//! per token (see [`crate::serve::stream`]).  Control ops (`{"op":"swap"}`
//! / `list` / `health` / `metrics` / `trace`) manage and observe the
//! decode runtime over the same connection; malformed lines answer
//! `{"id","error","field"}`.
//!
//! Generation routes through the incremental decode runtime
//! ([`ServeRuntime`]) when one is attached and serves the variant: KV
//! caches make each token O(len) instead of a full O(len²) window
//! recompute.  Variants the runtime does not carry (PJRT-only artifacts)
//! fall back to the legacy sliding-window loop over `engine.submit()`,
//! where concurrent clients still batch together.
//!
//! Construction goes through [`Server::builder`]:
//!
//! ```ignore
//! let server = Server::builder().runtime(rt).port(7461).control(true).start()?;
//! ```

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::Engine;
use crate::json::Json;
use crate::mathx::{sample_logits, XorShift};
use crate::serve::{stream as sstream, FinishReason, ServeRuntime, SpecParams};
use crate::tokenizer::ByteTokenizer;
use crate::trace::phases;

pub struct Server {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
}

/// The one way to construct a [`Server`].  Generation for variants the
/// decode runtime serves goes through its scheduler (required for
/// `"stream": true` requests and all control ops); everything else falls
/// back to the engine.  Both backends are optional so a pure-native
/// deployment does not load every model twice — at least one must be
/// attached by `start()` time.
#[derive(Default)]
pub struct ServerBuilder {
    engine: Option<Arc<Engine>>,
    runtime: Option<Arc<ServeRuntime>>,
    port: u16,
    control: Option<bool>,
    spec_defaults: Option<SpecParams>,
}

impl ServerBuilder {
    /// Legacy sliding-window fallback for variants the runtime lacks.
    pub fn engine(mut self, engine: Arc<Engine>) -> Self {
        self.engine = Some(engine);
        self
    }

    /// Incremental decode runtime (streaming, KV-cached one-shot, and the
    /// swap/list/health control plane).
    pub fn runtime(mut self, runtime: Arc<ServeRuntime>) -> Self {
        self.runtime = Some(runtime);
        self
    }

    /// TCP port to bind on 127.0.0.1; 0 (the default) picks a free port.
    pub fn port(mut self, port: u16) -> Self {
        self.port = port;
        self
    }

    /// Accept control ops (`swap` / `list` / `health` / `metrics` /
    /// `trace`) on client connections.  Defaults to on; `dobi serve
    /// --no-control` turns it off for deployments where the data port
    /// must not mutate the variant table or leak operational detail.
    pub fn control(mut self, control: bool) -> Self {
        self.control = Some(control);
        self
    }

    /// Serve-level speculative defaults (`dobi serve --spec-draft` /
    /// `--spec-k`): greedy generate requests without their own `"spec"`
    /// field decode speculatively against this draft when the decode
    /// runtime serves their variant.  An explicit client `"spec"` always
    /// wins; non-greedy requests are never defaulted (spec is
    /// greedy-only).
    pub fn spec_defaults(mut self, spec: Option<SpecParams>) -> Self {
        self.spec_defaults = spec;
        self
    }

    /// Bind and serve on a background thread.
    pub fn start(self) -> Result<Server> {
        let ServerBuilder { engine, runtime, port, control, spec_defaults } = self;
        let control = control.unwrap_or(true);
        anyhow::ensure!(engine.is_some() || runtime.is_some(),
                        "server needs an engine or a decode runtime");
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let join = std::thread::Builder::new().name("dobi-server".into()).spawn(move || {
            let mut clients: Vec<std::thread::JoinHandle<()>> = Vec::new();
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        // Reap finished handlers before tracking another:
                        // under connection churn the vec would otherwise
                        // grow one dead JoinHandle per client forever.
                        let mut i = 0;
                        while i < clients.len() {
                            if clients[i].is_finished() {
                                let _ = clients.swap_remove(i).join();
                            } else {
                                i += 1;
                            }
                        }
                        let eng = engine.clone();
                        let rt = runtime.clone();
                        let stop3 = stop2.clone();
                        let spec = spec_defaults.clone();
                        // Read timeout so handlers can observe shutdown even
                        // when a client keeps an idle connection open.
                        let _ = stream.set_read_timeout(
                            Some(std::time::Duration::from_millis(200)));
                        clients.push(std::thread::spawn(move || {
                            let _ = handle_client(stream, eng, rt, control, spec, stop3);
                        }));
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
            for c in clients {
                let _ = c.join();
            }
        })?;
        Ok(Server { addr, stop, join: Some(join) })
    }
}

impl Server {
    pub fn builder() -> ServerBuilder {
        ServerBuilder::default()
    }

    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn handle_client(stream: TcpStream, engine: Option<Arc<Engine>>,
                 runtime: Option<Arc<ServeRuntime>>, control: bool,
                 spec_defaults: Option<SpecParams>,
                 stop: Arc<AtomicBool>) -> Result<()> {
    let peer = stream.peer_addr()?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut req_no = 0u64;
    if let Some(rt) = &runtime {
        rt.trace().push_instant(phases::ACCEPT, 0, || peer.to_string());
    }
    loop {
        let mut line = String::new();
        match reader.read_line(&mut line) {
            Ok(0) => break, // client closed
            Ok(_) => {}
            Err(e) if matches!(e.kind(), std::io::ErrorKind::WouldBlock
                               | std::io::ErrorKind::TimedOut) => {
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                continue;
            }
            Err(e) => return Err(e.into()),
        }
        if line.trim().is_empty() {
            continue;
        }
        req_no += 1;
        // Parse into the typed request; every malformed line answers a
        // structured error naming the offending field when attributable.
        let t_parse = Instant::now();
        let request = match Json::parse(&line) {
            Ok(req) => match sstream::parse_request(&req) {
                Ok(r) => r,
                Err(e) => {
                    write_line(&mut writer,
                               &error_line(req_no, &e.msg, e.field.as_deref()))?;
                    continue;
                }
            },
            Err(e) => {
                write_line(&mut writer,
                           &error_line(req_no, &format!("bad request json: {e}"), None))?;
                continue;
            }
        };
        if let Some(rt) = &runtime {
            rt.trace().push_span(phases::PARSE, 0, t_parse, Instant::now(),
                                 || format!("req={req_no} bytes={}", line.len()));
        }
        let reply = match request {
            sstream::Request::Generate(mut params) => {
                // Serve-level speculative default: greedy requests with no
                // `"spec"` of their own pick up `--spec-draft`/`--spec-k`
                // when the decode runtime serves their variant (explicit
                // client spec wins; non-greedy requests stay plain).
                if params.spec.is_none() && params.temperature <= 0.0 {
                    if let Some(d) = &spec_defaults {
                        if runtime
                            .as_ref()
                            .is_some_and(|rt| rt.variants().iter().any(|v| v == &params.variant))
                        {
                            params.spec = Some(d.clone());
                        }
                    }
                }
                // Streaming requests (for variants the decode runtime
                // carries) write their own line-per-token reply; IO
                // failures mid-stream mean the client hung up — drop
                // them.  Unservable streaming requests fall through to
                // serve_one's explanatory error line.
                if params.stream {
                    if let Some(rt) = runtime
                        .as_ref()
                        .filter(|rt| rt.variants().iter().any(|v| v == &params.variant))
                    {
                        sstream::run_streaming(rt, &params, req_no, &mut writer)?;
                        continue;
                    }
                }
                match serve_one(engine.as_deref(), runtime.as_deref(), &params) {
                    Ok(mut obj) => {
                        obj.insert("id".into(), Json::Num(req_no as f64));
                        Json::Obj(obj).to_string()
                    }
                    Err(e) => error_line(req_no, &format!("{e:#}"), None),
                }
            }
            op if !control => {
                let name = match op {
                    sstream::Request::Swap { .. } => "swap",
                    sstream::Request::List => "list",
                    sstream::Request::Health => "health",
                    sstream::Request::Metrics { .. } => "metrics",
                    sstream::Request::Trace { .. } => "trace",
                    // generate is handled by the first match arm; keep a
                    // harmless name rather than a panic on the serve path
                    sstream::Request::Generate(_) => "generate",
                };
                error_line(req_no,
                           &format!("control op `{name}` disabled (--no-control)"),
                           Some("op"))
            }
            op => match runtime.as_deref() {
                None => error_line(req_no,
                                   "control ops need the incremental decode runtime \
                                    (serve without --no-stream)",
                                   Some("op")),
                Some(rt) => control_reply(rt, req_no, &op),
            },
        };
        write_line(&mut writer, &reply)?;
    }
    let _ = peer;
    Ok(())
}

fn write_line<W: Write>(w: &mut W, line: &str) -> Result<()> {
    w.write_all(line.as_bytes())?;
    w.write_all(b"\n")?;
    w.flush()?;
    Ok(())
}

fn error_line(id: u64, msg: &str, field: Option<&str>) -> String {
    let mut m = BTreeMap::new();
    m.insert("id".into(), Json::Num(id as f64));
    m.insert("error".into(), Json::Str(msg.to_string()));
    if let Some(f) = field {
        m.insert("field".into(), Json::Str(f.to_string()));
    }
    Json::Obj(m).to_string()
}

fn opt_str_json(v: &Option<String>) -> Json {
    match v {
        Some(s) => Json::Str(s.clone()),
        None => Json::Null,
    }
}

/// Execute one control op against the decode runtime and render its reply
/// line.  Swaps run here — on this client-handler thread — so the
/// scheduler keeps ticking everyone else's decode while the new store
/// loads and hash-verifies.
fn control_reply(rt: &ServeRuntime, id: u64, op: &sstream::Request) -> String {
    match op {
        sstream::Request::Swap { variant } => match rt.swap(variant) {
            Ok(status) => {
                let mut m = BTreeMap::new();
                m.insert("id".into(), Json::Num(id as f64));
                m.insert("op".into(), Json::Str("swap".into()));
                m.insert("ok".into(), Json::Bool(true));
                m.insert("variant".into(), Json::Str(status.variant.clone()));
                m.insert("generation".into(), Json::Num(status.generation as f64));
                m.insert("store_sha256".into(), opt_str_json(&status.store_sha256));
                m.insert("draining".into(),
                         Json::Num(status.draining.iter()
                                       .map(|(_, n)| *n)
                                       .sum::<usize>() as f64));
                Json::Obj(m).to_string()
            }
            Err(e) => error_line(id, &format!("swap failed: {e:#}"), None),
        },
        sstream::Request::List => {
            let variants: Vec<Json> = rt
                .registry_snapshot()
                .into_iter()
                .map(|s| {
                    let mut m = BTreeMap::new();
                    m.insert("variant".into(), Json::Str(s.variant));
                    m.insert("generation".into(), Json::Num(s.generation as f64));
                    m.insert("store_sha256".into(), opt_str_json(&s.store_sha256));
                    m.insert("alloc".into(), Json::Str(s.alloc));
                    m.insert("ratio".into(), Json::Num(s.ratio));
                    m.insert("active_sessions".into(), Json::Num(s.active_sessions as f64));
                    m.insert("draining".into(),
                             Json::Arr(s.draining
                                           .iter()
                                           .map(|(generation, sessions)| {
                                               let mut d = BTreeMap::new();
                                               d.insert("generation".into(),
                                                        Json::Num(*generation as f64));
                                               d.insert("sessions".into(),
                                                        Json::Num(*sessions as f64));
                                               Json::Obj(d)
                                           })
                                           .collect()));
                    Json::Obj(m)
                })
                .collect();
            let mut m = BTreeMap::new();
            m.insert("id".into(), Json::Num(id as f64));
            m.insert("op".into(), Json::Str("list".into()));
            m.insert("variants".into(), Json::Arr(variants));
            Json::Obj(m).to_string()
        }
        sstream::Request::Health => {
            let st = rt.stats();
            let mut m = BTreeMap::new();
            m.insert("id".into(), Json::Num(id as f64));
            m.insert("op".into(), Json::Str("health".into()));
            m.insert("ok".into(), Json::Bool(true));
            m.insert("active_sessions".into(), Json::Num(st.active_sessions as f64));
            m.insert("queue_depth".into(), Json::Num(st.queue_depth as f64));
            m.insert("sessions_opened".into(), Json::Num(st.sessions_opened as f64));
            m.insert("sessions_finished".into(), Json::Num(st.sessions_finished as f64));
            m.insert("tokens_emitted".into(), Json::Num(st.tokens_emitted as f64));
            m.insert("swaps".into(), Json::Num(st.swaps as f64));
            m.insert("draining_sessions".into(), Json::Num(st.draining_sessions as f64));
            Json::Obj(m).to_string()
        }
        sstream::Request::Metrics { prom } => {
            let (format, text) = if *prom {
                ("prom", rt.metrics_prom())
            } else {
                ("text", rt.metrics_text())
            };
            let mut m = BTreeMap::new();
            m.insert("id".into(), Json::Num(id as f64));
            m.insert("op".into(), Json::Str("metrics".into()));
            m.insert("format".into(), Json::Str(format.into()));
            m.insert("text".into(), Json::Str(text));
            Json::Obj(m).to_string()
        }
        sstream::Request::Trace { clear } => {
            let mut m = BTreeMap::new();
            m.insert("id".into(), Json::Num(id as f64));
            m.insert("op".into(), Json::Str("trace".into()));
            m.insert("enabled".into(), Json::Bool(rt.trace().enabled()));
            m.insert("trace".into(), rt.trace_json(*clear));
            Json::Obj(m).to_string()
        }
        // the dispatcher never routes Generate here; answer a structured
        // error instead of panicking the connection thread if it ever does
        sstream::Request::Generate(_) => error_line(id, "generate is not a control op",
                                                    Some("op")),
    }
}

fn serve_one(engine: Option<&Engine>, runtime: Option<&ServeRuntime>,
             params: &sstream::GenParams)
             -> Result<BTreeMap<String, Json>> {
    anyhow::ensure!(!params.stream,
                    "streaming needs the incremental decode runtime for `{}` \
                     (serve without --no-stream, native-loadable variant)", params.variant);
    // One-shot through the scheduler when it serves the variant: the KV
    // path decodes in O(len) per token instead of re-running full windows.
    if let Some(rt) = runtime {
        if rt.variants().iter().any(|v| v == &params.variant) {
            return sstream::run_oneshot(rt, params);
        }
    }
    // Legacy sliding-window loop over the batching engine (PJRT variants).
    let Some(engine) = engine else {
        anyhow::bail!("variant `{}` is not served by the decode runtime and no \
                       fallback engine is attached", params.variant);
    };
    let tok = ByteTokenizer;
    let mut ctx = tok.encode(&params.prompt);
    let seq = engine
        .router()
        .pick_seq(&params.variant, ctx.len())
        .ok_or_else(|| anyhow::anyhow!("unknown variant `{}`", params.variant))?;
    let mut rng = XorShift::new(params.seed.max(1));
    let mut out_tokens = Vec::new();
    let mut finish = FinishReason::MaxTokens;
    let t0 = Instant::now();
    for _ in 0..params.max_tokens {
        let mut window = vec![b' ' as i32; seq];
        let take = ctx.len().min(seq);
        window[seq - take..].copy_from_slice(&ctx[ctx.len() - take..]);
        let resp = engine.infer(&params.variant, window, None)?;
        anyhow::ensure!(!resp.output.is_empty(), "engine returned empty logits");
        let next = sample_logits(&resp.output, params.temperature, &mut rng) as i32;
        ctx.push(next);
        out_tokens.push(next);
        // same stop-token contract as the decode runtime: emit, then end
        if params.stop_token == Some(next) {
            finish = FinishReason::Stop;
            break;
        }
    }
    let mut m = BTreeMap::new();
    // one terminal-payload builder for every reply shape; the legacy loop
    // has no queue/prefill phases, so the whole wall time is decode
    let timing = crate::trace::RequestTiming {
        decode_us: t0.elapsed().as_micros() as u64,
        tokens: out_tokens.len() as u64,
        ..Default::default()
    };
    sstream::finish_fields(&mut m, &out_tokens, Some(finish),
                           t0.elapsed().as_secs_f64(), Some(&timing));
    Ok(m)
}
