//! The serving engine: a handle + an executor thread that owns all backend
//! state (PJRT handles are not `Send`, so every touch of the runtime
//! happens on that thread; the handle talks to it over channels).  The
//! executor instantiates the configured [`Backend`]
//! (`EngineConfig.backend`): PJRT artifacts or the native low-rank models
//! serve through the identical router/batcher path.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::config::{EngineConfig, Manifest};
use crate::metrics::Registry;
use crate::runtime::{make_backend, Backend, ForwardModel};

use super::batcher::{Batch, DynamicBatcher};
use super::request::{Request, RequestId, Response, SubmitError};
use super::router::{Router, VariantMeta};

enum Command {
    Submit(Request),
    Stop,
}

#[derive(Debug, Clone, Default)]
pub struct EngineStats {
    pub served: u64,
    pub batches: u64,
    pub mean_batch: f64,
    pub p50_latency_s: f64,
    pub p99_latency_s: f64,
    pub queue_full_rejects: u64,
}

struct Shared {
    pending: Mutex<BTreeMap<String, usize>>,
    metrics: Registry,
    served: AtomicU64,
    batches: AtomicU64,
    rejects: AtomicU64,
}

/// Handle to a running engine.  Cloneable across client threads.
pub struct Engine {
    tx: mpsc::Sender<Command>,
    router: Router,
    shared: Arc<Shared>,
    cfg: EngineConfig,
    next_id: AtomicU64,
    join: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl Engine {
    /// Load `variant_ids` (all their exported shapes unless `shapes`
    /// filters) and start the executor.  Blocks until loading finished so
    /// submit() never races a cold model.
    pub fn start(artifacts: PathBuf, variant_ids: &[String], cfg: EngineConfig,
                 shapes: Option<Vec<(usize, usize)>>) -> Result<Engine> {
        let manifest = Manifest::load(&artifacts)?;
        let mut router = Router::default();
        for id in variant_ids {
            let v = manifest.variant(id)?;
            let mut seqs: Vec<usize> = v
                .shapes()
                .into_iter()
                .filter(|bs| shapes.as_ref().map(|f| f.contains(bs)).unwrap_or(true))
                .map(|(_, s)| s)
                .collect();
            seqs.sort_unstable();
            seqs.dedup();
            // Factor-only manifests carry no HLO entries: the native
            // forward is shape-agnostic, so register the variant in
            // any-seq mode (empty seq list) instead of demanding phantom
            // exported shapes.  A non-empty hlo map that the shape filter
            // emptied is still an error.
            anyhow::ensure!(!seqs.is_empty() || v.hlo.is_empty(),
                            "{id}: no shapes after filter");
            router.register(VariantMeta {
                id: v.id.clone(),
                model: v.model.clone(),
                ratio: v.ratio,
                bytes: v.bytes,
                seqs,
            });
        }
        let shared = Arc::new(Shared {
            pending: Mutex::new(BTreeMap::new()),
            metrics: Registry::default(),
            served: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            rejects: AtomicU64::new(0),
        });
        let (tx, rx) = mpsc::channel::<Command>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let ids: Vec<String> = variant_ids.to_vec();
        let shared2 = shared.clone();
        let cfg2 = cfg.clone();
        let join = std::thread::Builder::new()
            .name("dobi-executor".into())
            .spawn(move || {
                executor_main(artifacts, ids, cfg2, shapes, rx, ready_tx, shared2);
            })?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("executor died during load"))??;
        Ok(Engine {
            tx,
            router,
            shared,
            cfg,
            next_id: AtomicU64::new(1),
            join: Mutex::new(Some(join)),
        })
    }

    pub fn router(&self) -> &Router {
        &self.router
    }

    /// Submit a right-aligned token window; returns the response channel.
    pub fn submit(&self, variant: &str, tokens: Vec<i32>, image: Option<Vec<f32>>)
                  -> Result<mpsc::Receiver<Response>, SubmitError> {
        let meta = self
            .router
            .get(variant)
            .ok_or_else(|| SubmitError::UnknownVariant(variant.to_string()))?;
        if !meta.accepts_seq(tokens.len()) {
            return Err(SubmitError::BadShape { want_seq: meta.seqs.clone(), got: tokens.len() });
        }
        {
            let mut pend = self.shared.pending.lock().unwrap();
            let e = pend.entry(variant.to_string()).or_insert(0);
            if *e >= self.cfg.queue_depth {
                self.shared.rejects.fetch_add(1, Ordering::Relaxed);
                return Err(SubmitError::QueueFull {
                    variant: variant.to_string(),
                    depth: self.cfg.queue_depth,
                });
            }
            *e += 1;
        }
        let (rtx, rrx) = mpsc::channel();
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            variant: variant.to_string(),
            seq: tokens.len(),
            tokens,
            image,
            enqueued: Instant::now(),
            respond: rtx,
        };
        self.tx.send(Command::Submit(req)).map_err(|_| SubmitError::Stopped)?;
        Ok(rrx)
    }

    /// Submit and wait (convenience for tests/examples).
    pub fn infer(&self, variant: &str, tokens: Vec<i32>, image: Option<Vec<f32>>)
                 -> Result<Response> {
        let rx = self.submit(variant, tokens, image).map_err(|e| anyhow!("{e}"))?;
        rx.recv().map_err(|_| anyhow!("engine dropped request"))
    }

    pub fn stats(&self) -> EngineStats {
        let lat = self.shared.metrics.histogram("request_latency").stats();
        let served = self.shared.served.load(Ordering::Relaxed);
        let batches = self.shared.batches.load(Ordering::Relaxed);
        EngineStats {
            served,
            batches,
            mean_batch: if batches > 0 { served as f64 / batches as f64 } else { 0.0 },
            p50_latency_s: lat.p50,
            p99_latency_s: lat.p99,
            queue_full_rejects: self.shared.rejects.load(Ordering::Relaxed),
        }
    }

    pub fn metrics_text(&self) -> String {
        self.shared.metrics.render()
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(Command::Stop);
        if let Some(j) = self.join.lock().unwrap().take() {
            let _ = j.join();
        }
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        self.shutdown();
    }
}

// ---------------------------------------------------------------------------
// Executor thread
// ---------------------------------------------------------------------------

fn executor_main(artifacts: PathBuf, ids: Vec<String>, cfg: EngineConfig,
                 shapes: Option<Vec<(usize, usize)>>, rx: mpsc::Receiver<Command>,
                 ready: mpsc::Sender<Result<()>>, shared: Arc<Shared>) {
    let load = (|| -> Result<BTreeMap<String, Box<dyn ForwardModel>>> {
        let manifest = Manifest::load(&artifacts)?;
        let backend: Box<dyn Backend> = make_backend(cfg.backend)?;
        let mut models = BTreeMap::new();
        for id in &ids {
            let l = backend.load_variant(&manifest, id, shapes.as_deref())?;
            models.insert(id.clone(), l.model);
        }
        Ok(models)
    })();
    let models = match load {
        Ok(models) => {
            let _ = ready.send(Ok(()));
            models
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };

    let mut batcher = DynamicBatcher::new(cfg.max_batch, Duration::from_micros(cfg.batch_deadline_us));
    let exec_hist = shared.metrics.histogram("execute_seconds");
    let lat_hist = shared.metrics.histogram("request_latency");
    loop {
        let wait = batcher
            .next_deadline_in(Instant::now())
            .unwrap_or(Duration::from_millis(50))
            .max(Duration::from_micros(50));
        match rx.recv_timeout(wait) {
            Ok(Command::Submit(req)) => batcher.push(req),
            Ok(Command::Stop) => break,
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => break,
        }
        // Drain any further queued commands without blocking.
        loop {
            match rx.try_recv() {
                Ok(Command::Submit(req)) => batcher.push(req),
                Ok(Command::Stop) => {
                    run_remaining(&mut batcher, &models, &shared, &exec_hist, &lat_hist);
                    return;
                }
                Err(_) => break,
            }
        }
        while let Some(batch) = batcher.poll(Instant::now()) {
            run_batch(batch, &models, &shared, &exec_hist, &lat_hist);
        }
    }
    run_remaining(&mut batcher, &models, &shared, &exec_hist, &lat_hist);
}

fn run_remaining(batcher: &mut DynamicBatcher,
                 models: &BTreeMap<String, Box<dyn ForwardModel>>,
                 shared: &Shared, exec_hist: &crate::metrics::Histogram,
                 lat_hist: &crate::metrics::Histogram) {
    for batch in batcher.drain_all() {
        run_batch(batch, models, shared, exec_hist, lat_hist);
    }
}

/// Plan how to split `n` pending requests across the exported batch dims:
/// returns (exec_batch, take) chunks.  `avail` must be sorted ascending.
/// Greedy: fill the largest shape while more than it remains, then the
/// smallest shape that covers the tail (minimizes padded rows).
pub fn plan_chunks(n: usize, avail: &[usize]) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    let Some(&bmax) = avail.last() else { return out };
    let mut left = n;
    while left > 0 {
        let take = left.min(bmax);
        let b = avail.iter().copied().find(|&x| x >= take).unwrap_or(bmax);
        out.push((b, take));
        left -= take;
    }
    out
}

fn run_batch(batch: Batch, models: &BTreeMap<String, Box<dyn ForwardModel>>, shared: &Shared,
             exec_hist: &crate::metrics::Histogram, lat_hist: &crate::metrics::Histogram) {
    let model: &dyn ForwardModel = match models.get(&batch.variant) {
        Some(m) => m.as_ref(),
        None => return, // validated at submit; unreachable in practice
    };
    let seq = batch.seq;
    let mut avail: Vec<usize> = model
        .shapes()
        .into_iter()
        .filter(|&(_, s)| s == seq)
        .map(|(b, _)| b)
        .collect();
    avail.sort_unstable();
    let mut reqs = batch.requests;
    if avail.is_empty() {
        // Shape-agnostic backend (native low-rank): run the whole group as
        // one exact-sized call, no padding.
        avail.push(reqs.len().max(1));
    }
    for (b, take) in plan_chunks(reqs.len(), &avail) {
        let chunk: Vec<Request> = reqs.drain(..take).collect();
        execute_chunk(model, b, seq, chunk, shared, exec_hist, lat_hist);
    }
}

fn execute_chunk(model: &dyn ForwardModel, b: usize, seq: usize, chunk: Vec<Request>,
                 shared: &Shared, exec_hist: &crate::metrics::Histogram,
                 lat_hist: &crate::metrics::Histogram) {
    let n = chunk.len();
    let vocab = model.vocab();
    let mut tokens = vec![0i32; b * seq];
    for (r, req) in chunk.iter().enumerate() {
        tokens[r * seq..(r + 1) * seq].copy_from_slice(&req.tokens);
    }
    // Pad rows replicate row 0 (harmless: outputs discarded).
    for r in n..b {
        let (head, tail) = tokens.split_at_mut(r * seq);
        tail[..seq].copy_from_slice(&head[..seq]);
    }
    let img_dim = model.img_dim();
    let image = if img_dim > 0 {
        let mut img = vec![0f32; b * img_dim];
        for (r, req) in chunk.iter().enumerate() {
            if let Some(iv) = &req.image {
                img[r * img_dim..(r + 1) * img_dim].copy_from_slice(iv);
            }
        }
        Some(img)
    } else {
        None
    };
    let t0 = Instant::now();
    let out = model.forward(b, seq, &tokens, image.as_deref());
    let exec_s = t0.elapsed();
    exec_hist.observe(exec_s);
    shared.batches.fetch_add(1, Ordering::Relaxed);
    match out {
        Ok(vals) => {
            for (r, req) in chunk.into_iter().enumerate() {
                let output = if model.action_head() {
                    vals[r * 5..(r + 1) * 5].to_vec()
                } else {
                    // last-position logits of row r
                    let base = (r * seq + seq - 1) * vocab;
                    vals[base..base + vocab].to_vec()
                };
                finish(req, output, n, t0, shared, lat_hist);
            }
        }
        Err(e) => {
            eprintln!("[engine] execute failed: {e:#}");
            for req in chunk {
                finish(req, Vec::new(), n, t0, shared, lat_hist);
            }
        }
    }
}

fn finish(req: Request, output: Vec<f32>, batch_size: usize, exec_start: Instant,
          shared: &Shared, lat_hist: &crate::metrics::Histogram) {
    let total = req.enqueued.elapsed();
    lat_hist.observe(total);
    shared.served.fetch_add(1, Ordering::Relaxed);
    {
        let mut pend = shared.pending.lock().unwrap();
        if let Some(e) = pend.get_mut(&req.variant) {
            *e = e.saturating_sub(1);
        }
    }
    let resp = Response {
        id: req.id,
        output,
        queue_s: exec_start.duration_since(req.enqueued).as_secs_f64(),
        total_s: total.as_secs_f64(),
        batch_size,
    };
    let _ = req.respond.send(resp);
}

pub type ResponseReceiver = mpsc::Receiver<Response>;
pub type RequestIdT = RequestId;

#[cfg(test)]
mod tests {
    use super::plan_chunks;
    use crate::proptest::{check, Gen};

    #[test]
    fn plan_exact_fit() {
        assert_eq!(plan_chunks(4, &[1, 4, 16]), vec![(4, 4)]);
        assert_eq!(plan_chunks(1, &[1, 4, 16]), vec![(1, 1)]);
    }

    #[test]
    fn plan_splits_overflow() {
        assert_eq!(plan_chunks(20, &[1, 4, 16]), vec![(16, 16), (4, 4)]);
        assert_eq!(plan_chunks(17, &[1, 4, 16]), vec![(16, 16), (1, 1)]);
    }

    #[test]
    fn plan_pads_up_when_between_shapes() {
        assert_eq!(plan_chunks(3, &[1, 4, 16]), vec![(4, 3)]);
        assert_eq!(plan_chunks(5, &[4]), vec![(4, 4), (4, 1)]);
    }

    #[test]
    fn plan_empty_avail() {
        assert!(plan_chunks(3, &[]).is_empty());
    }

    #[test]
    fn prop_plan_covers_all_without_overflow() {
        check("plan_chunks conservation", 100, |g: &mut Gen| {
            let n = g.usize_in(0, 100);
            let mut avail: Vec<usize> = (0..g.usize_in(1, 4))
                .map(|_| [1usize, 2, 4, 8, 16][g.usize_in(0, 5)])
                .collect();
            avail.sort_unstable();
            avail.dedup();
            let plan = plan_chunks(n, &avail);
            let total: usize = plan.iter().map(|&(_, t)| t).sum();
            crate::prop_assert!(total == n, "covered {total} of {n}");
            for &(b, t) in &plan {
                crate::prop_assert!(t <= b, "take {t} > batch {b}");
                crate::prop_assert!(avail.contains(&b), "batch {b} not exported");
            }
            Ok(())
        });
    }
}
