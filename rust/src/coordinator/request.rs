//! Request/response types for the serving path.

use std::sync::mpsc;
use std::time::Instant;

pub type RequestId = u64;

/// One inference request: a right-aligned token window of length `seq`
/// (the tokenizer's `encode_window`), optional image features, and the
/// channel the engine answers on.
#[derive(Debug)]
pub struct Request {
    pub id: RequestId,
    pub variant: String,
    pub seq: usize,
    pub tokens: Vec<i32>,
    pub image: Option<Vec<f32>>,
    pub enqueued: Instant,
    pub respond: mpsc::Sender<Response>,
}

/// Engine answer: the last-position logits (next-token distribution) or
/// the VLA action vector, plus latency accounting.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: RequestId,
    pub output: Vec<f32>,
    pub queue_s: f64,
    pub total_s: f64,
    pub batch_size: usize,
}

#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    QueueFull { variant: String, depth: usize },
    UnknownVariant(String),
    BadShape { want_seq: Vec<usize>, got: usize },
    Stopped,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull { variant, depth } => {
                write!(f, "queue for `{variant}` full at depth {depth}")
            }
            SubmitError::UnknownVariant(v) => write!(f, "unknown variant `{v}`"),
            SubmitError::BadShape { want_seq, got } => {
                if want_seq.is_empty() {
                    // any-seq variant: the bound is the global cap, not an
                    // exported-shape list
                    write!(f, "window of {got} tokens outside any-seq bounds 1..={}",
                           super::router::MAX_ANY_SEQ)
                } else {
                    write!(f, "no exported shape for seq {got} (have {want_seq:?})")
                }
            }
            SubmitError::Stopped => write!(f, "engine stopped"),
        }
    }
}

impl std::error::Error for SubmitError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn submit_error_display() {
        let e = SubmitError::QueueFull { variant: "x".into(), depth: 4 };
        assert!(e.to_string().contains("full"));
        let e2 = SubmitError::BadShape { want_seq: vec![32, 64], got: 100 };
        assert!(e2.to_string().contains("100"));
        // any-seq rejection names the actual admission rule, not "have []"
        let e3 = SubmitError::BadShape { want_seq: Vec::new(), got: 2000 };
        let msg = e3.to_string();
        assert!(msg.contains("2000") && msg.contains("any-seq"), "msg: {msg}");
    }
}
