//! L3 coordinator: request router + dynamic batcher + executor engine.
//!
//! Architecture (single-device CPU PJRT; the shape generalizes to one
//! executor per device):
//!
//! ```text
//!  clients ──submit()──► Router ──► per-(variant,seq) queues
//!                                        │
//!                               DynamicBatcher (size/deadline)
//!                                        │ Batch
//!                               executor thread (owns Runtime:
//!                               PJRT handles are not Send)
//!                                        │ logits
//!                               respond via per-request channel
//! ```
//!
//! Backpressure: bounded queues — `submit` fails fast with `QueueFull`
//! when a variant's queue is at depth, which is what an upstream load
//! balancer needs to see.

pub mod batcher;
pub mod engine;
pub mod request;
pub mod router;

pub use batcher::{Batch, Batchable, DynamicBatcher};
pub use engine::{Engine, EngineStats};
pub use request::{Request, RequestId, Response, SubmitError};
pub use router::{Router, MAX_ANY_SEQ};
