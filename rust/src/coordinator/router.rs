//! Request router: picks the serving variant for a request.
//!
//! Policies the paper's deployment story needs:
//! * explicit   — client names the variant (benchmarks, ablations).
//! * by-ratio   — client asks for a compression ratio; the router picks
//!                the closest loaded variant of the requested model.
//! * by-memory  — given a device budget (the Titan-Xp scenario), route to
//!                the best-quality variant that fits: highest ratio whose
//!                stored bytes <= budget.

use std::collections::BTreeMap;

/// Longest window an any-seq variant admits.  The native forward's
/// attention is O(s²) time and memory on the shared executor thread, so
/// unbounded client-supplied lengths would let one request stall every
/// other; exported-shape variants are bounded by their largest HLO seq,
/// this constant bounds the factor-only ones (8x the python
/// `ModelConfig.max_seq`, plenty for the nano family).
pub const MAX_ANY_SEQ: usize = 1024;

#[derive(Debug, Clone)]
pub struct VariantMeta {
    pub id: String,
    pub model: String,
    pub ratio: f64,
    pub bytes: usize,
    /// Seq lengths with exported shapes.  **Empty means "any seq"**: the
    /// variant came from a factor-only manifest (no HLO entries) and the
    /// shape-agnostic native backend serves every request length exactly
    /// (up to [`MAX_ANY_SEQ`]).
    pub seqs: Vec<usize>,
}

impl VariantMeta {
    /// True when this variant serves arbitrary sequence lengths (no
    /// exported-shape admission control).
    pub fn any_seq(&self) -> bool {
        self.seqs.is_empty()
    }

    /// Can a window of `len` tokens be submitted to this variant?
    pub fn accepts_seq(&self, len: usize) -> bool {
        if self.any_seq() {
            len >= 1 && len <= MAX_ANY_SEQ
        } else {
            self.seqs.contains(&len)
        }
    }
}

#[derive(Debug, Default)]
pub struct Router {
    variants: BTreeMap<String, VariantMeta>,
}

impl Router {
    pub fn register(&mut self, meta: VariantMeta) {
        self.variants.insert(meta.id.clone(), meta);
    }

    pub fn known(&self, id: &str) -> bool {
        self.variants.contains_key(id)
    }

    pub fn get(&self, id: &str) -> Option<&VariantMeta> {
        self.variants.get(id)
    }

    pub fn ids(&self) -> Vec<&str> {
        self.variants.keys().map(|s| s.as_str()).collect()
    }

    /// Closest loaded ratio for `model` (ties -> higher ratio wins: prefer
    /// quality when equidistant).
    pub fn by_ratio(&self, model: &str, want: f64) -> Option<&VariantMeta> {
        self.variants
            .values()
            .filter(|v| v.model == model)
            .min_by(|a, b| {
                let da = (a.ratio - want).abs();
                let db = (b.ratio - want).abs();
                if (da - db).abs() < 1e-9 {
                    // equidistant -> prefer the higher-quality variant
                    b.ratio.partial_cmp(&a.ratio).unwrap()
                } else {
                    da.partial_cmp(&db).unwrap()
                }
            })
    }

    /// Best-quality variant of `model` fitting `budget` bytes.
    pub fn by_memory(&self, model: &str, budget: usize) -> Option<&VariantMeta> {
        self.variants
            .values()
            .filter(|v| v.model == model && v.bytes <= budget)
            .max_by(|a, b| a.ratio.partial_cmp(&b.ratio).unwrap())
    }

    /// Seq length to use for a prompt of `len` tokens: the smallest
    /// exported seq >= len, else the largest available (window slides).
    /// Any-seq variants serve the prompt at its exact length, capped at
    /// [`MAX_ANY_SEQ`] (longer prompts slide, like oversize windows do on
    /// exported shapes).
    pub fn pick_seq(&self, id: &str, len: usize) -> Option<usize> {
        let meta = self.variants.get(id)?;
        if meta.any_seq() {
            return Some(len.clamp(1, MAX_ANY_SEQ));
        }
        let mut seqs = meta.seqs.clone();
        seqs.sort_unstable();
        seqs.iter().copied().find(|&s| s >= len).or(seqs.last().copied())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn router() -> Router {
        let mut r = Router::default();
        for (id, ratio, bytes) in [
            ("m/dense", 1.0, 1000usize),
            ("m/dobi_80", 0.8, 800),
            ("m/dobi_60", 0.6, 600),
            ("m/dobi_40", 0.4, 400),
        ] {
            r.register(VariantMeta {
                id: id.into(),
                model: "m".into(),
                ratio,
                bytes,
                seqs: vec![32, 64],
            });
        }
        r
    }

    #[test]
    fn by_ratio_closest() {
        let r = router();
        assert_eq!(r.by_ratio("m", 0.65).unwrap().id, "m/dobi_60");
        assert_eq!(r.by_ratio("m", 1.0).unwrap().id, "m/dense");
        assert_eq!(r.by_ratio("m", 0.0).unwrap().id, "m/dobi_40");
        assert!(r.by_ratio("other", 0.5).is_none());
    }

    #[test]
    fn by_ratio_tie_prefers_quality() {
        let r = router();
        // 0.7 is equidistant from 0.6 and 0.8 -> prefer 0.8
        assert_eq!(r.by_ratio("m", 0.7).unwrap().id, "m/dobi_80");
    }

    #[test]
    fn by_memory_best_fitting() {
        let r = router();
        assert_eq!(r.by_memory("m", 650).unwrap().id, "m/dobi_60");
        assert_eq!(r.by_memory("m", 5000).unwrap().id, "m/dense");
        assert!(r.by_memory("m", 100).is_none());
    }

    #[test]
    fn pick_seq_smallest_fitting() {
        let r = router();
        assert_eq!(r.pick_seq("m/dense", 10), Some(32));
        assert_eq!(r.pick_seq("m/dense", 40), Some(64));
        assert_eq!(r.pick_seq("m/dense", 200), Some(64)); // slide window
        assert_eq!(r.pick_seq("nope", 10), None);
    }

    #[test]
    fn any_seq_variant_accepts_every_length() {
        let mut r = router();
        r.register(VariantMeta {
            id: "m/native_40".into(),
            model: "m".into(),
            ratio: 0.4,
            bytes: 400,
            seqs: vec![], // factor-only manifest: no exported shapes
        });
        let meta = r.get("m/native_40").unwrap();
        assert!(meta.any_seq());
        for len in [1usize, 13, 64, MAX_ANY_SEQ] {
            assert!(meta.accepts_seq(len), "any-seq must accept len {len}");
            assert_eq!(r.pick_seq("m/native_40", len), Some(len));
        }
        assert!(!meta.accepts_seq(0), "empty windows are never servable");
        // unbounded client lengths are capped, not served verbatim: one
        // huge prompt must not buy an O(s^2) attention on the executor
        assert!(!meta.accepts_seq(MAX_ANY_SEQ + 1));
        assert_eq!(r.pick_seq("m/native_40", 1 << 20), Some(MAX_ANY_SEQ));
        // exported-shape variants keep strict admission
        let dense = r.get("m/dense").unwrap();
        assert!(!dense.any_seq());
        assert!(dense.accepts_seq(32) && !dense.accepts_seq(33));
        // any-seq variants still participate in ratio/memory routing
        assert_eq!(r.by_ratio("m", 0.45).unwrap().id, "m/native_40");
    }
}
