//! Request router: picks the serving variant for a request.
//!
//! Policies the paper's deployment story needs:
//! * explicit   — client names the variant (benchmarks, ablations).
//! * by-ratio   — client asks for a compression ratio; the router picks
//!                the closest loaded variant of the requested model.
//! * by-memory  — given a device budget (the Titan-Xp scenario), route to
//!                the best-quality variant that fits: highest ratio whose
//!                stored bytes <= budget.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct VariantMeta {
    pub id: String,
    pub model: String,
    pub ratio: f64,
    pub bytes: usize,
    pub seqs: Vec<usize>,
}

#[derive(Debug, Default)]
pub struct Router {
    variants: BTreeMap<String, VariantMeta>,
}

impl Router {
    pub fn register(&mut self, meta: VariantMeta) {
        self.variants.insert(meta.id.clone(), meta);
    }

    pub fn known(&self, id: &str) -> bool {
        self.variants.contains_key(id)
    }

    pub fn get(&self, id: &str) -> Option<&VariantMeta> {
        self.variants.get(id)
    }

    pub fn ids(&self) -> Vec<&str> {
        self.variants.keys().map(|s| s.as_str()).collect()
    }

    /// Closest loaded ratio for `model` (ties -> higher ratio wins: prefer
    /// quality when equidistant).
    pub fn by_ratio(&self, model: &str, want: f64) -> Option<&VariantMeta> {
        self.variants
            .values()
            .filter(|v| v.model == model)
            .min_by(|a, b| {
                let da = (a.ratio - want).abs();
                let db = (b.ratio - want).abs();
                if (da - db).abs() < 1e-9 {
                    // equidistant -> prefer the higher-quality variant
                    b.ratio.partial_cmp(&a.ratio).unwrap()
                } else {
                    da.partial_cmp(&db).unwrap()
                }
            })
    }

    /// Best-quality variant of `model` fitting `budget` bytes.
    pub fn by_memory(&self, model: &str, budget: usize) -> Option<&VariantMeta> {
        self.variants
            .values()
            .filter(|v| v.model == model && v.bytes <= budget)
            .max_by(|a, b| a.ratio.partial_cmp(&b.ratio).unwrap())
    }

    /// Seq length to use for a prompt of `len` tokens: the smallest
    /// exported seq >= len, else the largest available (window slides).
    pub fn pick_seq(&self, id: &str, len: usize) -> Option<usize> {
        let mut seqs = self.variants.get(id)?.seqs.clone();
        seqs.sort_unstable();
        seqs.iter().copied().find(|&s| s >= len).or(seqs.last().copied())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn router() -> Router {
        let mut r = Router::default();
        for (id, ratio, bytes) in [
            ("m/dense", 1.0, 1000usize),
            ("m/dobi_80", 0.8, 800),
            ("m/dobi_60", 0.6, 600),
            ("m/dobi_40", 0.4, 400),
        ] {
            r.register(VariantMeta {
                id: id.into(),
                model: "m".into(),
                ratio,
                bytes,
                seqs: vec![32, 64],
            });
        }
        r
    }

    #[test]
    fn by_ratio_closest() {
        let r = router();
        assert_eq!(r.by_ratio("m", 0.65).unwrap().id, "m/dobi_60");
        assert_eq!(r.by_ratio("m", 1.0).unwrap().id, "m/dense");
        assert_eq!(r.by_ratio("m", 0.0).unwrap().id, "m/dobi_40");
        assert!(r.by_ratio("other", 0.5).is_none());
    }

    #[test]
    fn by_ratio_tie_prefers_quality() {
        let r = router();
        // 0.7 is equidistant from 0.6 and 0.8 -> prefer 0.8
        assert_eq!(r.by_ratio("m", 0.7).unwrap().id, "m/dobi_80");
    }

    #[test]
    fn by_memory_best_fitting() {
        let r = router();
        assert_eq!(r.by_memory("m", 650).unwrap().id, "m/dobi_60");
        assert_eq!(r.by_memory("m", 5000).unwrap().id, "m/dense");
        assert!(r.by_memory("m", 100).is_none());
    }

    #[test]
    fn pick_seq_smallest_fitting() {
        let r = router();
        assert_eq!(r.pick_seq("m/dense", 10), Some(32));
        assert_eq!(r.pick_seq("m/dense", 40), Some(64));
        assert_eq!(r.pick_seq("m/dense", 200), Some(64)); // slide window
        assert_eq!(r.pick_seq("nope", 10), None);
    }
}
