//! Dynamic batcher: groups pending items per (variant, seq) key and
//! flushes on either of two triggers (whichever first):
//!   * size   — `max_batch` items waiting, or
//!   * time   — the oldest item has waited `deadline`.
//!
//! Pure data structure (no PJRT, no threads) so the policy is unit- and
//! property-testable.  Generic over anything [`Batchable`]: the engine
//! drives it with [`Request`]s from the executor loop, and the incremental
//! decode scheduler (`serve::scheduler`) reuses the same FIFO-fair
//! grouping for session admission.

use std::collections::{BTreeMap, VecDeque};
use std::time::{Duration, Instant};

use super::request::Request;

/// Anything the batcher can queue: a (variant, seq) grouping key plus the
/// enqueue time that drives deadline flushes and FIFO fairness.
pub trait Batchable {
    fn group(&self) -> (&str, usize);
    fn enqueued(&self) -> Instant;
}

impl Batchable for Request {
    fn group(&self) -> (&str, usize) {
        (&self.variant, self.seq)
    }

    fn enqueued(&self) -> Instant {
        self.enqueued
    }
}

#[derive(Debug)]
pub struct Batch<T = Request> {
    pub variant: String,
    pub seq: usize,
    pub requests: Vec<T>,
}

pub struct DynamicBatcher<T = Request> {
    pub max_batch: usize,
    pub deadline: Duration,
    queues: BTreeMap<(String, usize), VecDeque<T>>,
    depth: usize,
}

impl<T: Batchable> DynamicBatcher<T> {
    pub fn new(max_batch: usize, deadline: Duration) -> Self {
        DynamicBatcher { max_batch: max_batch.max(1), deadline, queues: BTreeMap::new(), depth: 0 }
    }

    pub fn push(&mut self, req: T) {
        self.depth += 1;
        let (variant, seq) = req.group();
        self.queues
            .entry((variant.to_string(), seq))
            .or_default()
            .push_back(req);
    }

    pub fn pending(&self) -> usize {
        self.depth
    }

    /// Next batch to run, honoring the size/deadline policy.  Among ready
    /// groups, picks the one whose head item is oldest (FIFO fairness
    /// across variants).
    pub fn poll(&mut self, now: Instant) -> Option<Batch<T>> {
        self.poll_up_to(now, self.max_batch)
    }

    /// [`Self::poll`] with an additional per-call size cap — the decode
    /// scheduler admits into however many session slots are free, which
    /// can be fewer than `max_batch`.
    pub fn poll_up_to(&mut self, now: Instant, cap: usize) -> Option<Batch<T>> {
        let cap = cap.min(self.max_batch);
        if cap == 0 {
            return None;
        }
        let mut best: Option<(Instant, (String, usize))> = None;
        for (key, q) in &self.queues {
            let head = match q.front() {
                Some(r) => r.enqueued(),
                None => continue,
            };
            let ready = q.len() >= self.max_batch || now.duration_since(head) >= self.deadline;
            if ready && best.as_ref().map(|(t, _)| head < *t).unwrap_or(true) {
                best = Some((head, key.clone()));
            }
        }
        let (_, key) = best?;
        let q = self.queues.get_mut(&key).unwrap();
        let take = q.len().min(cap);
        let requests: Vec<T> = q.drain(..take).collect();
        self.depth -= requests.len();
        Some(Batch { variant: key.0, seq: key.1, requests })
    }

    /// Force-flush everything (engine shutdown).
    pub fn drain_all(&mut self) -> Vec<Batch<T>> {
        let mut out = Vec::new();
        let keys: Vec<_> = self.queues.keys().cloned().collect();
        for key in keys {
            let q = self.queues.get_mut(&key).unwrap();
            while !q.is_empty() {
                let take = q.len().min(self.max_batch);
                let requests: Vec<T> = q.drain(..take).collect();
                self.depth -= requests.len();
                out.push(Batch { variant: key.0.clone(), seq: key.1, requests });
            }
        }
        out
    }

    /// Time until the earliest pending deadline (engine idle sleep hint).
    pub fn next_deadline_in(&self, now: Instant) -> Option<Duration> {
        self.queues
            .values()
            .filter_map(|q| q.front())
            .map(|r| {
                let waited = now.duration_since(r.enqueued());
                self.deadline.saturating_sub(waited)
            })
            .min()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::{check, Gen};
    use std::sync::mpsc;

    fn req(variant: &str, seq: usize, at: Instant) -> Request {
        let (tx, _rx) = mpsc::channel();
        Request {
            id: 0,
            variant: variant.into(),
            seq,
            tokens: vec![0; seq],
            image: None,
            enqueued: at,
            respond: tx,
        }
    }

    #[test]
    fn flushes_on_size() {
        let mut b = DynamicBatcher::new(2, Duration::from_secs(100));
        let t = Instant::now();
        b.push(req("v", 8, t));
        assert!(b.poll(t).is_none(), "below size, before deadline");
        b.push(req("v", 8, t));
        let batch = b.poll(t).expect("size trigger");
        assert_eq!(batch.requests.len(), 2);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn flushes_on_deadline() {
        let mut b = DynamicBatcher::new(8, Duration::from_millis(5));
        let t = Instant::now();
        b.push(req("v", 8, t));
        assert!(b.poll(t).is_none());
        let batch = b.poll(t + Duration::from_millis(6)).expect("deadline trigger");
        assert_eq!(batch.requests.len(), 1);
    }

    #[test]
    fn groups_by_variant_and_seq() {
        let mut b = DynamicBatcher::new(2, Duration::from_secs(100));
        let t = Instant::now();
        b.push(req("a", 8, t));
        b.push(req("b", 8, t));
        b.push(req("a", 16, t));
        assert!(b.poll(t).is_none(), "no group reaches size 2");
        b.push(req("a", 8, t));
        let batch = b.poll(t).unwrap();
        assert_eq!(batch.variant, "a");
        assert_eq!(batch.seq, 8);
        assert_eq!(batch.requests.len(), 2);
        assert_eq!(b.pending(), 2);
    }

    #[test]
    fn oldest_group_first() {
        let mut b = DynamicBatcher::new(1, Duration::from_millis(0));
        let t = Instant::now();
        b.push(req("late", 8, t + Duration::from_millis(5)));
        b.push(req("early", 8, t));
        let batch = b.poll(t + Duration::from_millis(10)).unwrap();
        assert_eq!(batch.variant, "early");
    }

    #[test]
    fn poll_up_to_caps_the_take() {
        let mut b = DynamicBatcher::new(4, Duration::from_millis(0));
        let t = Instant::now();
        for _ in 0..3 {
            b.push(req("v", 8, t));
        }
        assert!(b.poll_up_to(t, 0).is_none(), "zero slots never yields");
        let first = b.poll_up_to(t, 2).expect("capped take");
        assert_eq!(first.requests.len(), 2);
        let rest = b.poll_up_to(t, 2).expect("remainder");
        assert_eq!(rest.requests.len(), 1);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn drain_all_empties() {
        let mut b = DynamicBatcher::new(2, Duration::from_secs(100));
        let t = Instant::now();
        for i in 0..5 {
            b.push(req(if i % 2 == 0 { "a" } else { "b" }, 8, t));
        }
        let batches = b.drain_all();
        assert_eq!(batches.iter().map(|x| x.requests.len()).sum::<usize>(), 5);
        assert_eq!(b.pending(), 0);
        assert!(batches.iter().all(|x| x.requests.len() <= 2));
    }

    #[test]
    fn next_deadline_hint() {
        let mut b = DynamicBatcher::new(4, Duration::from_millis(10));
        let t = Instant::now();
        assert!(b.next_deadline_in(t).is_none());
        b.push(req("v", 8, t));
        let d = b.next_deadline_in(t + Duration::from_millis(4)).unwrap();
        assert!(d <= Duration::from_millis(6));
    }

    #[test]
    fn prop_no_request_lost_and_batches_bounded() {
        check("batcher conservation", 50, |g: &mut Gen| {
            let max_batch = g.usize_in(1, 6);
            let mut b = DynamicBatcher::new(max_batch, Duration::from_millis(g.usize_in(0, 5) as u64));
            let t = Instant::now();
            let n = g.usize_in(1, 40);
            for i in 0..n {
                let v = ["a", "b", "c"][g.usize_in(0, 3)];
                let s = [8, 16][g.usize_in(0, 2)];
                b.push(req(v, s, t + Duration::from_millis(i as u64)));
            }
            let mut seen = 0;
            let late = t + Duration::from_secs(10);
            while let Some(batch) = b.poll(late) {
                crate::prop_assert!(batch.requests.len() <= max_batch,
                                    "batch over max: {}", batch.requests.len());
                crate::prop_assert!(
                    batch.requests.iter().all(|r| r.variant == batch.variant && r.seq == batch.seq),
                    "mixed batch");
                seen += batch.requests.len();
            }
            crate::prop_assert!(seen == n, "lost requests: {seen} != {n}");
            crate::prop_assert!(b.pending() == 0, "pending nonzero");
            Ok(())
        });
    }

    #[test]
    fn prop_fifo_within_group() {
        check("batcher fifo", 30, |g: &mut Gen| {
            let mut b = DynamicBatcher::new(g.usize_in(1, 4), Duration::from_millis(0));
            let t = Instant::now();
            let n = g.usize_in(2, 20);
            for i in 0..n {
                let mut r = req("v", 8, t + Duration::from_millis(i as u64));
                r.id = i as u64;
                b.push(r);
            }
            let mut last = 0u64;
            let mut first = true;
            while let Some(batch) = b.poll(t + Duration::from_secs(1)) {
                for r in &batch.requests {
                    crate::prop_assert!(first || r.id > last, "out of order: {} after {last}", r.id);
                    last = r.id;
                    first = false;
                }
            }
            Ok(())
        });
    }
}
