//! L1 structural performance model — the TPU-side roofline estimates for
//! EXPERIMENTS.md §Perf.  Interpret-mode Pallas gives CPU-numpy timings
//! only, so kernel quality is assessed structurally: VMEM residency per
//! program, MXU-issued vs useful FLOPs (padding waste), and arithmetic
//! intensity against a TPUv4-class roofline.  Mirrors the python-side
//! estimators in `kernels/matmul.py` (cross-checked by tests).

/// One GEMM tiling choice.
#[derive(Debug, Clone, Copy)]
pub struct Tiling {
    pub bm: usize,
    pub bn: usize,
    pub bk: usize,
}

pub const DEFAULT_TILING: Tiling = Tiling { bm: 128, bn: 128, bk: 128 };

/// TPUv4-ish per-core budgets used for the ratio estimates.
pub const VMEM_BYTES: usize = 16 << 20;           // ~16 MiB VMEM
pub const MXU_FLOPS: f64 = 137.5e12 / 2.0;        // bf16 MXU, one core: ~68 TFLOP/s
pub const HBM_BW: f64 = 600e9;                    // ~600 GB/s usable

#[derive(Debug, Clone)]
pub struct GemmEstimate {
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub vmem_bytes: usize,
    pub mxu_utilization: f64,     // useful / issued FLOPs (padding waste)
    pub arithmetic_intensity: f64, // FLOPs per HBM byte
    pub compute_bound: bool,
    pub est_seconds: f64,
}

fn ceil_to(x: usize, b: usize) -> usize {
    x.div_ceil(b) * b
}

/// Structural estimate of one (m,k)@(k,n) GEMM under `t`.
pub fn estimate_gemm(m: usize, k: usize, n: usize, t: Tiling, dtype_bytes: usize) -> GemmEstimate {
    let bm = t.bm.min(ceil_to(m, 8));
    let bn = t.bn.min(ceil_to(n, 8));
    let bk = t.bk.min(ceil_to(k, 8));
    let vmem = (bm * bk + bk * bn) * dtype_bytes + bm * bn * 4;
    let (mp, np_, kp) = (ceil_to(m, bm), ceil_to(n, bn), ceil_to(k, bk));
    let useful = 2.0 * (m * n * k) as f64;
    let issued = 2.0 * (mp * np_ * kp) as f64;
    // bytes: stream x and w once per K-pass of each output tile; the
    // accumulator stays resident.  Output written once.
    let passes_over_x = (np_ / bn) as f64;
    let passes_over_w = (mp / bm) as f64;
    let bytes = (m * k) as f64 * dtype_bytes as f64 * passes_over_x
        + (k * n) as f64 * dtype_bytes as f64 * passes_over_w
        + (m * n) as f64 * dtype_bytes as f64;
    let ai = useful / bytes;
    let t_compute = issued / MXU_FLOPS;
    let t_mem = bytes / HBM_BW;
    GemmEstimate {
        m,
        n,
        k,
        vmem_bytes: vmem,
        mxu_utilization: useful / issued,
        arithmetic_intensity: ai,
        compute_bound: t_compute >= t_mem,
        est_seconds: t_compute.max(t_mem),
    }
}

/// Factorized apply = two GEMMs sharing the rank-k intermediate.
pub fn estimate_factorized(rows: usize, m: usize, n: usize, k: usize, t: Tiling,
                           dtype_bytes: usize) -> (GemmEstimate, GemmEstimate) {
    (estimate_gemm(rows, m, k, t, dtype_bytes), estimate_gemm(rows, k, n, t, dtype_bytes))
}

/// Paper-style efficiency ratio: achieved/roofline for the compressed
/// layer vs the dense layer at the same tiling (the translate-the-ratio
/// target of the PERF section — absolute TFLOPs are hardware-bound).
pub fn speedup_estimate(rows: usize, m: usize, n: usize, k: usize, t: Tiling) -> f64 {
    let dense = estimate_gemm(rows, m, n, t, 4);
    let (a, b) = estimate_factorized(rows, m, n, k, t, 4);
    dense.est_seconds / (a.est_seconds + b.est_seconds)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vmem_within_budget_for_default_tiling() {
        let e = estimate_gemm(256, 192, 192, DEFAULT_TILING, 4);
        assert!(e.vmem_bytes < VMEM_BYTES);
    }

    #[test]
    fn utilization_perfect_on_aligned_shapes() {
        let e = estimate_gemm(256, 128, 256, DEFAULT_TILING, 4);
        assert!((e.mxu_utilization - 1.0).abs() < 1e-12);
    }

    #[test]
    fn utilization_degrades_with_padding() {
        let aligned = estimate_gemm(128, 128, 128, DEFAULT_TILING, 4);
        let ragged = estimate_gemm(130, 130, 130, DEFAULT_TILING, 4);
        assert!(ragged.mxu_utilization < aligned.mxu_utilization);
        // 130 -> 256x256x136 padding keeps only ~25% useful
        assert!(ragged.mxu_utilization > 0.1);
    }

    #[test]
    fn small_rank_is_memory_bound() {
        // rank-16 factor GEMM: tiny arithmetic intensity
        let e = estimate_gemm(256, 192, 16, DEFAULT_TILING, 4);
        assert!(!e.compute_bound);
        // Under the single-level streaming model, compute-boundedness needs
        // tiles large enough to amortize operand re-streaming.
        let big = estimate_gemm(4096, 4096, 4096, Tiling { bm: 512, bn: 512, bk: 512 }, 4);
        assert!(big.compute_bound);
    }

    #[test]
    fn factorized_speedup_positive_below_half_rank() {
        // k << mn/(m+n): factorized must beat dense structurally
        let s = speedup_estimate(256, 192, 192, 48, DEFAULT_TILING);
        assert!(s > 1.0, "speedup {s}");
        // and near-full rank it must NOT (more work than dense)
        let s2 = speedup_estimate(256, 192, 192, 192, DEFAULT_TILING);
        assert!(s2 < 1.0, "speedup {s2}");
    }

    #[test]
    fn matches_python_mxu_estimator() {
        // python: mxu_utilization_estimate(192,192,24,128,128,128)
        let e = estimate_gemm(192, 24, 192, DEFAULT_TILING, 4);
        // python pads each dim to block multiples the same way
        let want = (192.0 * 192.0 * 24.0)
            / ((192f64 / 128.0).ceil() * 128.0
                * (192f64 / 128.0).ceil() * 128.0
                * (24f64 / 24.0).ceil() * 24.0);
        assert!((e.mxu_utilization - want).abs() < 0.05, "{} vs {want}", e.mxu_utilization);
    }
}
