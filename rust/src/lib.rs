//! # dobi — Dobi-SVD compression + serving stack
//!
//! Rust coordinator (L3) for the Dobi-SVD reproduction: loads AOT-compiled
//! HLO artifacts produced by the python/JAX/Pallas compile path (L2/L1) and
//! serves them through the PJRT CPU client — python is never on the
//! request path.
//!
//! Module map (see DESIGN.md §2):
//! * substrates: [`json`], [`cli`], [`mathx`], [`tokenizer`], [`corpusio`],
//!   [`quant`], [`storage`], [`config`], [`metrics`], [`trace`], [`bench`],
//!   [`proptest`]
//! * runtime:    [`runtime`] (the `Backend` trait, PJRT wrapper, model
//!   registry) and [`lowrank`] (native rank-truncated factorized backend)
//! * compression:[`compress`] (native Dobi pipeline: Jacobi SVD, whitened
//!   rank search, IPCA reconstruction, remap quantization, store writer)
//! * coordinator:[`coordinator`] (router, dynamic batcher, workers)
//! * decode:     [`serve`] (per-session KV caches, continuous batching,
//!   token streaming — the incremental decode runtime)
//! * evaluation: [`evalx`] (perplexity, task accuracy, generation)
//! * deployment: [`memsim`] (capacity-limited device model), [`server`]

// Lint policy lives in the workspace Cargo.toml ([workspace.lints]) so
// benches/examples/tests inherit the same kernel-idiom allows.

pub mod analysis;
pub mod bench;
pub mod cli;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod corpusio;
pub mod evalx;
pub mod json;
pub mod lowrank;
pub mod mathx;
pub mod memsim;
pub mod metrics;
pub mod perf;
pub mod proptest;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod server;
pub mod storage;
pub mod tokenizer;
pub mod trace;

/// Canonical artifacts directory (overridable everywhere via `--artifacts`).
pub const DEFAULT_ARTIFACTS: &str = "artifacts";
