//! Lifecycle tracing: a lock-cheap bounded ring of span events plus a
//! Chrome trace-event exporter (Perfetto-loadable).
//!
//! Two producers share the substrate.  The serve stack records where
//! every request's wall-clock goes — accept → parse → queue wait →
//! admission → prefill → each tick's fused group walk / spec draft /
//! spec verify / eviction sweep — and the compress pipeline records
//! where a run's wall-clock goes — calibration → whitening → per-target
//! Jacobi SVD (worker threads land on their own lanes) → rank
//! allocation / learned-train iterations → remap → store write — both
//! as [`TraceEvent`]s in a [`TraceBuffer`].  Design constraints, in
//! order:
//!
//! * **Cheap when disabled.**  A zero-capacity buffer allocates nothing
//!   and every record call returns before formatting a single byte
//!   (details are built through `FnOnce` closures that never run).
//! * **Cheap when enabled.**  Writers claim a slot with one relaxed
//!   `fetch_add` on the global sequence counter and lock ONLY that slot
//!   — scheduler and client-handler threads never contend unless they
//!   collide on the same ring index, and the ring is sized to make that
//!   rare.  Overwrite-oldest falls out of the modulo: the ring always
//!   holds the newest `cap` events.
//! * **Drainable live.**  `{"op":"trace"}` drains (optionally clears)
//!   the ring while writers keep writing; slot-level locking means a
//!   drain observes each event atomically — torn events are impossible.
//!
//! Spans come from RAII [`SpanGuard`]s (`buf.span(..)` … drop records)
//! or retroactively via [`TraceBuffer::push_span`] when the phase was
//! already timed (queue waits, speculative draft/verify phases).  The
//! exporter ([`export_chrome`]) renders the drained events as Chrome
//! trace-event JSON — load the `{"op":"trace"}` reply's `trace` object
//! in <https://ui.perfetto.dev> (or `chrome://tracing`) to see the
//! request lanes.  [`RequestTiming`] is the compact per-request summary
//! the same instrumentation feeds: the `"timing"` object on every
//! terminal streaming line / one-shot reply.

pub mod phases;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::json::Json;
use crate::metrics::lock_or_recover;

/// One recorded span (an instant event when `dur_us == 0`).
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    /// Global record order (the ring keeps the newest `cap` seqs).
    pub seq: u64,
    /// Start, µs since the buffer's epoch.
    pub ts_us: u64,
    /// Span wall time in µs.
    pub dur_us: u64,
    /// Phase name (`"prefill"`, `"fused_step"`, `"queue_wait"`, ...).
    pub name: &'static str,
    /// Writer lane (stable per thread) — the Chrome `tid`.
    pub tid: u64,
    /// Session id the span belongs to (0 = not session-scoped).
    pub session: u64,
    /// Free-form detail (variant id, batch size, finish reason).
    pub detail: String,
}

/// Stable small integer per OS thread: the trace's `tid` lanes.
fn thread_lane() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(1);
    thread_local! {
        static LANE: u64 = NEXT.fetch_add(1, Ordering::Relaxed);
    }
    LANE.with(|l| *l)
}

/// Bounded ring of [`TraceEvent`]s: sequence-numbered, overwrite-oldest,
/// one mutex per slot (writers lock only the slot they claimed).
pub struct TraceBuffer {
    epoch: Instant,
    seq: AtomicU64,
    slots: Vec<Mutex<Option<TraceEvent>>>,
}

impl TraceBuffer {
    /// `cap` events; 0 disables tracing (every record call is a cheap
    /// early return and no slot storage is allocated).
    pub fn new(cap: usize) -> TraceBuffer {
        TraceBuffer {
            epoch: Instant::now(),
            seq: AtomicU64::new(0),
            slots: (0..cap).map(|_| Mutex::new(None)).collect(),
        }
    }

    pub fn enabled(&self) -> bool {
        !self.slots.is_empty()
    }

    /// Ring capacity in events.
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Events recorded since start (not bounded by capacity).
    pub fn recorded(&self) -> u64 {
        self.seq.load(Ordering::Relaxed)
    }

    fn us_since_epoch(&self, t: Instant) -> u64 {
        t.saturating_duration_since(self.epoch).as_micros() as u64
    }

    /// Record a span that already happened (`start..end`).  `detail` is
    /// only evaluated when the buffer is enabled — disabled tracing
    /// never formats a byte.
    pub fn push_span<F: FnOnce() -> String>(&self, name: &'static str, session: u64,
                                            start: Instant, end: Instant, detail: F) {
        if self.slots.is_empty() {
            return;
        }
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        let ev = TraceEvent {
            seq,
            ts_us: self.us_since_epoch(start),
            dur_us: end.saturating_duration_since(start).as_micros() as u64,
            name,
            tid: thread_lane(),
            session,
            detail: detail(),
        };
        // slot-level lock: a concurrent drain sees either the old event
        // or the new one, never a torn mix
        *lock_or_recover(&self.slots[(seq % self.slots.len() as u64) as usize]) = Some(ev);
    }

    /// Record an instant event (dur 0) at now.
    pub fn push_instant<F: FnOnce() -> String>(&self, name: &'static str, session: u64,
                                               detail: F) {
        if self.slots.is_empty() {
            return;
        }
        let now = Instant::now();
        self.push_span(name, session, now, now, detail);
    }

    /// RAII span: starts timing now, records on drop.  Inert (no clock
    /// read, no allocation) when the buffer is disabled.
    pub fn span(self: &Arc<Self>, name: &'static str, session: u64) -> SpanGuard {
        if self.slots.is_empty() {
            return SpanGuard(None);
        }
        SpanGuard(Some(SpanInner {
            buf: self.clone(),
            name,
            session,
            detail: String::new(),
            start: Instant::now(),
        }))
    }

    /// Snapshot the ring's events, oldest first (sequence order).  With
    /// `clear` the drained slots are emptied; either way live writers
    /// keep writing throughout — the drain locks one slot at a time.
    pub fn drain(&self, clear: bool) -> Vec<TraceEvent> {
        let mut out: Vec<TraceEvent> = Vec::with_capacity(self.slots.len());
        for slot in &self.slots {
            let mut s = lock_or_recover(slot);
            if clear {
                if let Some(ev) = s.take() {
                    out.push(ev);
                }
            } else if let Some(ev) = s.as_ref() {
                out.push(ev.clone());
            }
        }
        out.sort_by_key(|e| e.seq);
        out
    }
}

struct SpanInner {
    buf: Arc<TraceBuffer>,
    name: &'static str,
    session: u64,
    detail: String,
    start: Instant,
}

/// RAII guard from [`TraceBuffer::span`]: drop records the span.
pub struct SpanGuard(Option<SpanInner>);

impl SpanGuard {
    /// Attach detail text; the closure only runs when tracing is live.
    pub fn note<F: FnOnce() -> String>(&mut self, f: F) {
        if let Some(i) = &mut self.0 {
            i.detail = f();
        }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some(i) = self.0.take() {
            let d = i.detail;
            i.buf.push_span(i.name, i.session, i.start, Instant::now(), move || d);
        }
    }
}

/// Render drained events as Chrome trace-event JSON (the `"X"` complete
/// phase), wrapped in the object form Perfetto and `chrome://tracing`
/// both load: `{"traceEvents": [...], "displayTimeUnit": "ms"}`.
pub fn export_chrome(events: &[TraceEvent]) -> Json {
    let evs: Vec<Json> = events
        .iter()
        .map(|e| {
            // known phases (phases::ALL) render in the "serve" or
            // "compress" category by prefix; anything else lands in
            // "other", which the lint treats as drift
            let cat = if !phases::ALL.contains(&e.name) {
                "other"
            } else if e.name.starts_with("compress_") {
                "compress"
            } else {
                "serve"
            };
            Json::obj(vec![
                ("name", Json::Str(e.name.to_string())),
                ("cat", Json::Str(cat.to_string())),
                ("ph", Json::Str("X".to_string())),
                ("ts", Json::Num(e.ts_us as f64)),
                ("dur", Json::Num(e.dur_us as f64)),
                ("pid", Json::Num(1.0)),
                ("tid", Json::Num(e.tid as f64)),
                (
                    "args",
                    Json::obj(vec![
                        ("seq", Json::Num(e.seq as f64)),
                        ("session", Json::Num(e.session as f64)),
                        ("detail", Json::Str(e.detail.clone())),
                    ]),
                ),
            ])
        })
        .collect();
    Json::obj(vec![
        ("traceEvents", Json::Arr(evs)),
        ("displayTimeUnit", Json::Str("ms".to_string())),
    ])
}

/// Compact per-request summary: where one request's wall-clock went.
/// Filled by the scheduler, delivered on `GenEvent::Done`, and rendered
/// as the `"timing"` object on terminal streaming lines and one-shot
/// replies.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct RequestTiming {
    /// Enqueue → admission (scheduler slot wait).
    pub queue_us: u64,
    /// Admission prefill (prompt + image prefix; spec: target + draft).
    pub prefill_us: u64,
    /// Total decode wall time across ticks (fused walks charge each
    /// participant the full walk — see the scheduler's accounting note).
    pub decode_us: u64,
    /// Speculative draft phase total (0 for plain sessions).
    pub draft_us: u64,
    /// Speculative verify phase total (0 for plain sessions).
    pub verify_us: u64,
    /// Tokens emitted.
    pub tokens: u64,
}

impl RequestTiming {
    /// Time to first token: the first token is emitted at admission,
    /// right after prefill.
    pub fn ttft_us(&self) -> u64 {
        self.queue_us + self.prefill_us
    }

    /// Decode-side throughput (prefill included: the client-observable
    /// rate from admission to finish).
    pub fn tokens_per_s(&self) -> f64 {
        self.tokens as f64 / ((self.prefill_us + self.decode_us) as f64 / 1e6).max(1e-9)
    }

    /// The wire `"timing"` object.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("queue_us", Json::Num(self.queue_us as f64)),
            ("prefill_us", Json::Num(self.prefill_us as f64)),
            ("decode_us", Json::Num(self.decode_us as f64)),
            ("draft_us", Json::Num(self.draft_us as f64)),
            ("verify_us", Json::Num(self.verify_us as f64)),
            ("ttft_us", Json::Num(self.ttft_us() as f64)),
            ("tokens", Json::Num(self.tokens as f64)),
            ("tokens_per_s", Json::Num(self.tokens_per_s())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn push_n(buf: &TraceBuffer, n: u64, session_base: u64) {
        let t = Instant::now();
        for i in 0..n {
            buf.push_span("ev", session_base + i, t, t + Duration::from_micros(i), || {
                format!("d{}", session_base + i)
            });
        }
    }

    #[test]
    fn wraparound_keeps_newest_events_in_sequence_order() {
        let buf = TraceBuffer::new(8);
        push_n(&buf, 20, 100);
        let evs = buf.drain(false);
        assert_eq!(evs.len(), 8, "ring holds exactly its capacity");
        // newest 8 of 20: seqs 12..20, ascending
        let seqs: Vec<u64> = evs.iter().map(|e| e.seq).collect();
        assert_eq!(seqs, (12..20).collect::<Vec<u64>>());
        // payloads moved with their seqs (session encodes push order)
        for e in &evs {
            assert_eq!(e.session, 100 + e.seq);
            assert_eq!(e.detail, format!("d{}", e.session));
        }
        assert_eq!(buf.recorded(), 20);
    }

    #[test]
    fn concurrent_writers_do_not_tear_events() {
        let buf = Arc::new(TraceBuffer::new(64));
        let mut hs = Vec::new();
        for w in 0..4u64 {
            let b = buf.clone();
            hs.push(std::thread::spawn(move || {
                push_n(&b, 500, (w + 1) * 10_000);
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        let evs = buf.drain(false);
        assert_eq!(evs.len(), 64);
        let mut last_seq = None;
        for e in &evs {
            // internal consistency: session and detail were written by
            // the same push (a torn slot would mix writers)
            assert_eq!(e.detail, format!("d{}", e.session), "torn event: {e:?}");
            if let Some(prev) = last_seq {
                assert!(e.seq > prev, "drain must be sequence-ordered");
            }
            last_seq = Some(e.seq);
        }
        assert_eq!(buf.recorded(), 2000);
    }

    #[test]
    fn drain_with_clear_races_safely_with_live_writers() {
        let buf = Arc::new(TraceBuffer::new(32));
        let writer = {
            let b = buf.clone();
            std::thread::spawn(move || push_n(&b, 4000, 0))
        };
        let mut drained = 0usize;
        while buf.recorded() < 4000 {
            let evs = buf.drain(true);
            for e in &evs {
                assert_eq!(e.detail, format!("d{}", e.session));
            }
            drained += evs.len();
        }
        writer.join().unwrap();
        drained += buf.drain(true).len();
        assert!(drained <= 4000, "clear must never duplicate an event");
        assert!(drained >= 32, "the final ring contents are always collectable");
        assert!(buf.drain(false).is_empty(), "cleared ring is empty");
    }

    #[test]
    fn disabled_buffer_is_inert_on_the_hot_path() {
        let buf = Arc::new(TraceBuffer::new(0));
        assert!(!buf.enabled());
        assert_eq!(buf.capacity(), 0);
        let mut detail_ran = false;
        buf.push_span("x", 1, Instant::now(), Instant::now(), || {
            detail_ran = true;
            String::new()
        });
        assert!(!detail_ran, "disabled tracing must not format details");
        {
            let mut g = buf.span("y", 2);
            let mut note_ran = false;
            g.note(|| {
                note_ran = true;
                String::new()
            });
            assert!(!note_ran, "inert guards never evaluate notes");
        }
        assert_eq!(buf.recorded(), 0);
        assert!(buf.drain(true).is_empty());
    }

    #[test]
    fn span_guard_records_on_drop_with_note() {
        let buf = Arc::new(TraceBuffer::new(4));
        {
            let mut g = buf.span("phase", 7);
            g.note(|| "tiny/dense".to_string());
            std::thread::sleep(Duration::from_millis(1));
        }
        let evs = buf.drain(false);
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].name, "phase");
        assert_eq!(evs[0].session, 7);
        assert_eq!(evs[0].detail, "tiny/dense");
        assert!(evs[0].dur_us >= 1000, "guard measured the span: {:?}", evs[0]);
    }

    #[test]
    fn chrome_export_is_loadable_trace_event_json() {
        let buf = TraceBuffer::new(8);
        push_n(&buf, 3, 0);
        let t = Instant::now();
        buf.push_span(phases::PREFILL, 9, t, t, || String::new());
        buf.push_span(phases::COMPRESS_SVD, 0, t, t, || String::new());
        let doc = export_chrome(&buf.drain(false));
        // round-trip through the serializer: the wire form must parse
        let parsed = Json::parse(&doc.to_string()).unwrap();
        assert_eq!(parsed.str_of("displayTimeUnit"), "ms");
        let evs = parsed.get("traceEvents").and_then(|x| x.as_arr()).unwrap();
        assert_eq!(evs.len(), 5);
        for e in evs {
            assert_eq!(e.str_of("ph"), "X");
            // "ev" is not a declared phase; the exporter flags it "other"
            let want = if e.str_of("name") == phases::PREFILL {
                "serve"
            } else if e.str_of("name") == phases::COMPRESS_SVD {
                "compress"
            } else {
                "other"
            };
            assert_eq!(e.str_of("cat"), want, "{e:?}");
            assert!(e.get("ts").and_then(|x| x.as_f64()).is_some());
            assert!(e.get("dur").and_then(|x| x.as_f64()).is_some());
            assert!(e.path("args.session").is_some());
        }
    }

    #[test]
    fn request_timing_summary_math_and_json() {
        let t = RequestTiming {
            queue_us: 300,
            prefill_us: 700,
            decode_us: 9_000,
            draft_us: 2_000,
            verify_us: 3_000,
            tokens: 10,
        };
        assert_eq!(t.ttft_us(), 1000);
        let tps = t.tokens_per_s();
        assert!((tps - 10.0 / 0.0097).abs() < 1e-6, "{tps}");
        let j = t.to_json();
        assert_eq!(j.get("ttft_us").and_then(|x| x.as_f64()), Some(1000.0));
        assert_eq!(j.get("tokens").and_then(|x| x.as_f64()), Some(10.0));
        assert!(j.get("tokens_per_s").and_then(|x| x.as_f64()).unwrap() > 0.0);
    }
}
