//! The single source of truth for trace phase names — the serve stack's
//! request lifecycle and the compress pipeline's run lifecycle.
//!
//! Every phase recorded into the trace ring (via `span`/`push_span`/
//! `push_instant`) is declared here once. [`ALL`] is the exporter's
//! known-phase list: `export_chrome` categorizes events by membership
//! (`compress_*` phases land in the `compress` category, the rest in
//! `serve`), and `dobi lint`'s `trace-phase-pairing` rule fails the build
//! if a phase is recorded as a bare string literal, missing from [`ALL`],
//! or absent from the README phase tables (and vice versa).

/// Connection accepted by the server listener (instant).
pub const ACCEPT: &str = "accept";
/// Request line read and parsed into a typed op (server side).
pub const PARSE: &str = "parse";
/// Time spent parked in the admission queue.
pub const QUEUE_WAIT: &str = "queue_wait";
/// Admission control: capacity check + KV slot grant.
pub const ADMISSION: &str = "admission";
/// Prompt prefill through the backend.
pub const PREFILL: &str = "prefill";
/// One decode step for one session.
pub const STEP: &str = "step";
/// One fused decode step across the batch.
pub const FUSED_STEP: &str = "fused_step";
/// Draft-variant proposal inside a speculative round.
pub const SPEC_DRAFT: &str = "spec_draft";
/// Target-variant verification inside a speculative round.
pub const SPEC_VERIFY: &str = "spec_verify";
/// Whole-request envelope from enqueue to final token.
pub const REQUEST: &str = "request";
/// Idle-session eviction sweep.
pub const EVICT_SWEEP: &str = "evict_sweep";

/// Whole-compression-run envelope from inventory to manifest write.
pub const COMPRESS_RUN: &str = "compress_run";
/// Calibration forward passes collecting per-tap activations.
pub const COMPRESS_CALIB: &str = "compress_calib";
/// Whitening: Gram eigendecomposition for one calibration tap group.
pub const COMPRESS_WHITEN: &str = "compress_whiten";
/// Jacobi SVD of one target's whitened weight (tagged with its sweep lane).
pub const COMPRESS_SVD: &str = "compress_svd";
/// Rank allocation across all targets (waterfill or learned).
pub const COMPRESS_ALLOC: &str = "compress_alloc";
/// One learned-alloc training iteration (instant carrying loss/λ/τ/budget).
pub const COMPRESS_TRAIN_ITER: &str = "compress_train_iter";
/// IPCA remap + quantization of one target into its stored factors.
pub const COMPRESS_REMAP: &str = "compress_remap";
/// Store + manifest + run-report writing.
pub const COMPRESS_WRITE: &str = "compress_write";

/// The exporter's known-phase list. Events whose name is absent here are
/// categorized `other` in the Chrome trace — which the lint treats as drift.
pub const ALL: &[&str] = &[
    ACCEPT,
    PARSE,
    QUEUE_WAIT,
    ADMISSION,
    PREFILL,
    STEP,
    FUSED_STEP,
    SPEC_DRAFT,
    SPEC_VERIFY,
    REQUEST,
    EVICT_SWEEP,
    COMPRESS_RUN,
    COMPRESS_CALIB,
    COMPRESS_WHITEN,
    COMPRESS_SVD,
    COMPRESS_ALLOC,
    COMPRESS_TRAIN_ITER,
    COMPRESS_REMAP,
    COMPRESS_WRITE,
];
