//! The single source of truth for request-lifecycle phase names.
//!
//! Every phase recorded into the trace ring (via `span`/`push_span`/
//! `push_instant`) is declared here once. [`ALL`] is the exporter's
//! known-phase list: `export_chrome` categorizes events by membership, and
//! `dobi lint`'s `trace-phase-pairing` rule fails the build if a phase is
//! recorded as a bare string literal, missing from [`ALL`], or absent from
//! the README phase table (and vice versa).

/// Connection accepted by the server listener (instant).
pub const ACCEPT: &str = "accept";
/// Request line read and parsed into a typed op (server side).
pub const PARSE: &str = "parse";
/// Time spent parked in the admission queue.
pub const QUEUE_WAIT: &str = "queue_wait";
/// Admission control: capacity check + KV slot grant.
pub const ADMISSION: &str = "admission";
/// Prompt prefill through the backend.
pub const PREFILL: &str = "prefill";
/// One decode step for one session.
pub const STEP: &str = "step";
/// One fused decode step across the batch.
pub const FUSED_STEP: &str = "fused_step";
/// Draft-variant proposal inside a speculative round.
pub const SPEC_DRAFT: &str = "spec_draft";
/// Target-variant verification inside a speculative round.
pub const SPEC_VERIFY: &str = "spec_verify";
/// Whole-request envelope from enqueue to final token.
pub const REQUEST: &str = "request";
/// Idle-session eviction sweep.
pub const EVICT_SWEEP: &str = "evict_sweep";

/// The exporter's known-phase list. Events whose name is absent here are
/// categorized `other` in the Chrome trace — which the lint treats as drift.
pub const ALL: &[&str] = &[
    ACCEPT,
    PARSE,
    QUEUE_WAIT,
    ADMISSION,
    PREFILL,
    STEP,
    FUSED_STEP,
    SPEC_DRAFT,
    SPEC_VERIFY,
    REQUEST,
    EVICT_SWEEP,
];
