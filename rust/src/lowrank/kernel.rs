//! Cache-blocked matmul over mixed-precision weight factors.
//!
//! The factorized apply is `y = x @ W1 @ W2` with `W1 = U_k Σ_k^{1/2}`
//! (m×k) and `W2 = Σ_k^{1/2} V_kᵀ` (k×n) — the symmetric-sqrt split the
//! Dobi remap emits (`python/compile/dobi/remap.py::factorize`), i.e. the
//! paper's `y = U_k (Σ_k (V_kᵀ x))` in row-major convention.  Cost is
//! `2·rows·k·(m+n)` FLOPs vs `2·rows·m·n` dense, so any `k < mn/(m+n)`
//! is a genuine FLOP win.
//!
//! Factors stay in their stored precision (f32 / f16 / int8+scales) and
//! are decoded tile-by-tile through the [`crate::quant`] codecs inside the
//! GEMM: a `K_BLOCK`-row tile of the weight is dequantized once into an
//! L1/L2-resident scratch and reused across every row of `x`, so decode
//! cost amortizes over the batch while resident memory stays at the
//! quantized footprint.

use anyhow::{bail, Result};

use crate::quant::{f16_to_f32, f32_to_f16, quantize_i8_cols};

/// Rows of the weight operand decoded per tile.  64×512 f32 ≈ 128 KB worst
/// case (w_gate/w_up at nano scale) — L2-resident on anything modern.
pub const K_BLOCK: usize = 64;

thread_local! {
    /// Worker threads the blocked GEMM may fan output columns across.
    /// Thread-local on purpose: `dobi serve --decode-threads` sets it on
    /// the ONE scheduler thread that runs decode forwards, so the legacy
    /// per-connection fallback handlers (and anything else calling
    /// matmul concurrently) stay single-threaded instead of
    /// oversubscribing the host T-fold.
    static DECODE_THREADS: std::cell::Cell<usize> = const { std::cell::Cell::new(1) };
}

/// Multiply-accumulate floor below which the threaded path is not worth
/// its per-call scoped-thread spawn (~tens of µs per worker).
const PAR_MIN_MACS: usize = 1 << 18;

/// Narrowest column stripe a worker is handed (a stripe narrower than a
/// cache line of f32s just shreds the tile decode).
const PAR_MIN_STRIPE: usize = 16;

/// Set the calling thread's GEMM worker count (clamped to >= 1).
pub fn set_decode_threads(n: usize) {
    DECODE_THREADS.with(|c| c.set(n.max(1)));
}

/// The calling thread's GEMM worker count.
pub fn decode_threads() -> usize {
    DECODE_THREADS.with(|c| c.get())
}

/// Stored payload of one weight factor.
pub enum FactorData {
    F32(Vec<f32>),
    /// IEEE 754 half, little-endian u16 carriers (the `.dobiw` f16 dtype).
    F16(Vec<u16>),
    /// Symmetric absmax int8 codes + f32 scales.  `per_row == false` means
    /// one scale per column (python `quantize_absmax(axis=0)`, the W1
    /// convention); `per_row == true` means one scale per row (`axis=1`,
    /// the W2 convention).
    I8 { codes: Vec<i8>, scales: Vec<f32>, per_row: bool },
}

/// A 2-D weight operand in storage precision, decodable tile-by-tile.
pub struct Factor {
    pub rows: usize,
    pub cols: usize,
    pub data: FactorData,
}

impl Factor {
    pub fn f32(rows: usize, cols: usize, vals: Vec<f32>) -> Factor {
        assert_eq!(vals.len(), rows * cols, "f32 factor shape mismatch");
        Factor { rows, cols, data: FactorData::F32(vals) }
    }

    pub fn f16(rows: usize, cols: usize, halves: Vec<u16>) -> Factor {
        assert_eq!(halves.len(), rows * cols, "f16 factor shape mismatch");
        Factor { rows, cols, data: FactorData::F16(halves) }
    }

    /// Encode f32 values to an f16 factor (round-to-nearest-even).
    pub fn f16_from_f32(rows: usize, cols: usize, vals: &[f32]) -> Factor {
        assert_eq!(vals.len(), rows * cols, "f16 factor shape mismatch");
        Factor::f16(rows, cols, vals.iter().map(|&v| f32_to_f16(v)).collect())
    }

    pub fn i8(rows: usize, cols: usize, codes: Vec<i8>, scales: Vec<f32>,
              per_row: bool) -> Result<Factor> {
        anyhow::ensure!(codes.len() == rows * cols, "i8 factor shape mismatch");
        let want = if per_row { rows } else { cols };
        anyhow::ensure!(scales.len() == want,
                        "i8 factor scales len {} != {want}", scales.len());
        Ok(Factor { rows, cols, data: FactorData::I8 { codes, scales, per_row } })
    }

    /// Quantize f32 values to int8 with per-column scales (the W1/axis=0
    /// convention of `remap.quantize_absmax`).
    pub fn i8_cols_from_f32(rows: usize, cols: usize, vals: &[f32]) -> Factor {
        let (codes, scales) = quantize_i8_cols(vals, rows, cols, 8);
        Factor { rows, cols, data: FactorData::I8 { codes, scales, per_row: false } }
    }

    /// Quantize f32 values to int8 with per-row scales (the W2/axis=1
    /// convention): quantize the transpose per-column, then transpose back.
    pub fn i8_rows_from_f32(rows: usize, cols: usize, vals: &[f32]) -> Factor {
        let mut t = vec![0f32; rows * cols];
        for r in 0..rows {
            for c in 0..cols {
                t[c * rows + r] = vals[r * cols + c];
            }
        }
        let (codes_t, scales) = quantize_i8_cols(&t, cols, rows, 8);
        let mut codes = vec![0i8; rows * cols];
        for r in 0..rows {
            for c in 0..cols {
                codes[r * cols + c] = codes_t[c * rows + r];
            }
        }
        Factor { rows, cols, data: FactorData::I8 { codes, scales, per_row: true } }
    }

    pub fn n_elems(&self) -> usize {
        self.rows * self.cols
    }

    /// Bytes this factor keeps resident in host memory (codes + scales).
    pub fn resident_bytes(&self) -> usize {
        match &self.data {
            FactorData::F32(v) => v.len() * 4,
            FactorData::F16(v) => v.len() * 2,
            FactorData::I8 { codes, scales, .. } => codes.len() + scales.len() * 4,
        }
    }

    /// Decode rows `[r0, r0 + nr)` into `out[.. nr * cols]` (row-major f32).
    pub fn decode_rows(&self, r0: usize, nr: usize, out: &mut [f32]) {
        let c = self.cols;
        debug_assert!(r0 + nr <= self.rows && out.len() >= nr * c);
        // f32 keeps the single contiguous memcpy; everything else shares
        // decode_rows_cols so there is ONE copy of the dequant logic
        if let FactorData::F32(v) = &self.data {
            out[..nr * c].copy_from_slice(&v[r0 * c..(r0 + nr) * c]);
            return;
        }
        self.decode_rows_cols(r0, nr, 0, c, out);
    }

    /// Decode the sub-block rows `[r0, r0 + nr)` × cols `[c0, c0 + nc)`
    /// into `out[.. nr * nc]` (row-major f32) — the column-striped tile
    /// the threaded GEMM workers decode, so each worker touches only its
    /// own output stripe's share of the weight.
    pub fn decode_rows_cols(&self, r0: usize, nr: usize, c0: usize, nc: usize,
                            out: &mut [f32]) {
        let c = self.cols;
        debug_assert!(r0 + nr <= self.rows && c0 + nc <= c && out.len() >= nr * nc);
        match &self.data {
            FactorData::F32(v) => {
                for r in 0..nr {
                    let base = (r0 + r) * c + c0;
                    out[r * nc..(r + 1) * nc].copy_from_slice(&v[base..base + nc]);
                }
            }
            FactorData::F16(h) => {
                for r in 0..nr {
                    let base = (r0 + r) * c + c0;
                    for j in 0..nc {
                        out[r * nc + j] = f16_to_f32(h[base + j]);
                    }
                }
            }
            FactorData::I8 { codes, scales, per_row } => {
                for r in 0..nr {
                    let base = (r0 + r) * c + c0;
                    if *per_row {
                        let s = scales[r0 + r];
                        for j in 0..nc {
                            out[r * nc + j] = codes[base + j] as f32 * s;
                        }
                    } else {
                        for j in 0..nc {
                            out[r * nc + j] = codes[base + j] as f32 * scales[c0 + j];
                        }
                    }
                }
            }
        }
    }

    /// Fully decode to f32 (tests, storage accounting cross-checks).
    pub fn to_f32(&self) -> Vec<f32> {
        let mut out = vec![0f32; self.n_elems()];
        self.decode_rows(0, self.rows, &mut out);
        out
    }

    /// Keep only the first `new_cols` columns (rank truncation on W1:
    /// singular directions are stored in decreasing-σ order, so dropping
    /// trailing columns IS the rank-k' truncation).
    pub fn truncate_cols(&mut self, new_cols: usize) {
        assert!(new_cols >= 1 && new_cols <= self.cols, "bad column truncation");
        if new_cols == self.cols {
            return;
        }
        let (rows, cols) = (self.rows, self.cols);
        let pick = |i: usize| (i / new_cols) * cols + (i % new_cols);
        match &mut self.data {
            FactorData::F32(v) => {
                let nv: Vec<f32> = (0..rows * new_cols).map(|i| v[pick(i)]).collect();
                *v = nv;
            }
            FactorData::F16(v) => {
                let nv: Vec<u16> = (0..rows * new_cols).map(|i| v[pick(i)]).collect();
                *v = nv;
            }
            FactorData::I8 { codes, scales, per_row } => {
                let nc: Vec<i8> = (0..rows * new_cols).map(|i| codes[pick(i)]).collect();
                *codes = nc;
                if !*per_row {
                    scales.truncate(new_cols);
                }
            }
        }
        self.cols = new_cols;
    }

    /// Keep only the first `new_rows` rows (rank truncation on W2).
    pub fn truncate_rows(&mut self, new_rows: usize) {
        assert!(new_rows >= 1 && new_rows <= self.rows, "bad row truncation");
        if new_rows == self.rows {
            return;
        }
        let keep = new_rows * self.cols;
        match &mut self.data {
            FactorData::F32(v) => v.truncate(keep),
            FactorData::F16(v) => v.truncate(keep),
            FactorData::I8 { codes, scales, per_row } => {
                codes.truncate(keep);
                if *per_row {
                    scales.truncate(new_rows);
                }
            }
        }
        self.rows = new_rows;
    }
}

/// `y = x @ W`: `x` is (rows, w.rows) f32 row-major, result (rows, w.cols).
/// Blocked over the shared dimension; each weight tile decodes once and is
/// reused across all `rows` of `x`.
pub fn matmul(x: &[f32], rows: usize, w: &Factor) -> Vec<f32> {
    let mut out = vec![0f32; rows * w.cols];
    matmul_into(x, rows, w, &mut out);
    out
}

/// Accumulating core of [`matmul`].  `out` is accumulated into (callers
/// wanting `y = x @ W` zero it first).  With [`set_decode_threads`] > 1
/// and enough work, output columns are fanned across scoped worker
/// threads — each output element still accumulates over k in exactly the
/// serial tile order, so threaded and single-threaded results are
/// bit-identical (the fused-decode parity contract depends on this).
pub fn matmul_into(x: &[f32], rows: usize, w: &Factor, out: &mut [f32]) {
    let (inner, cols) = (w.rows, w.cols);
    assert_eq!(x.len(), rows * inner, "x len {} != rows {rows} x inner {inner}", x.len());
    assert_eq!(out.len(), rows * cols, "out len mismatch");
    let threads = decode_threads();
    if threads > 1 && rows * inner * cols >= PAR_MIN_MACS && cols >= 2 * PAR_MIN_STRIPE {
        let stripes = threads.min(cols / PAR_MIN_STRIPE);
        if stripes >= 2 {
            matmul_into_striped(x, rows, w, out, stripes);
            return;
        }
    }
    matmul_stripe(x, rows, w, 0, cols, out);
}

/// One column stripe `[c0, c0 + nc)` of the blocked GEMM: the K-tile loop
/// of the original single-threaded kernel, restricted to a stripe of the
/// weight's columns.  `out_stripe` is the (rows, nc) row-major stripe of
/// the output, accumulated into.
fn matmul_stripe(x: &[f32], rows: usize, w: &Factor, c0: usize, nc: usize,
                 out_stripe: &mut [f32]) {
    let inner = w.rows;
    let mut tile = vec![0f32; K_BLOCK.min(inner) * nc];
    let mut k0 = 0;
    while k0 < inner {
        let kb = K_BLOCK.min(inner - k0);
        w.decode_rows_cols(k0, kb, c0, nc, &mut tile);
        for i in 0..rows {
            let xrow = &x[i * inner + k0..i * inner + k0 + kb];
            let orow = &mut out_stripe[i * nc..(i + 1) * nc];
            for (dk, &a) in xrow.iter().enumerate() {
                if a != 0.0 {
                    let wrow = &tile[dk * nc..dk * nc + nc];
                    for (o, &wv) in orow.iter_mut().zip(wrow) {
                        *o += a * wv;
                    }
                }
            }
        }
        k0 += kb;
    }
}

/// Fan `stripes` disjoint column ranges across scoped threads.  Workers
/// compute into private stripe buffers seeded from `out` (preserving the
/// accumulate contract); the main thread scatters them back — no shared
/// mutable state, no unsafe.
fn matmul_into_striped(x: &[f32], rows: usize, w: &Factor, out: &mut [f32],
                       stripes: usize) {
    let cols = w.cols;
    let base = cols / stripes;
    let rem = cols % stripes;
    let mut bounds = Vec::with_capacity(stripes);
    let mut c0 = 0;
    for si in 0..stripes {
        let nc = base + usize::from(si < rem);
        bounds.push((c0, nc));
        c0 += nc;
    }
    let out_ro: &[f32] = out;
    let bufs: Vec<Vec<f32>> = std::thread::scope(|scope| {
        let handles: Vec<_> = bounds
            .iter()
            .map(|&(c0, nc)| {
                scope.spawn(move || {
                    let mut buf = vec![0f32; rows * nc];
                    for i in 0..rows {
                        buf[i * nc..(i + 1) * nc]
                            .copy_from_slice(&out_ro[i * cols + c0..i * cols + c0 + nc]);
                    }
                    matmul_stripe(x, rows, w, c0, nc, &mut buf);
                    buf
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("gemm worker panicked")).collect()
    });
    for (&(c0, nc), buf) in bounds.iter().zip(&bufs) {
        for i in 0..rows {
            out[i * cols + c0..i * cols + c0 + nc].copy_from_slice(&buf[i * nc..(i + 1) * nc]);
        }
    }
}

// ---------------------------------------------------------------------------
// Linear layers
// ---------------------------------------------------------------------------

/// One rank-truncated compression target: `W ≈ W1 @ W2`.
pub struct FactorizedLinear {
    pub name: String,
    /// (m, k) — `U_k Σ_k^{1/2}`.
    pub w1: Factor,
    /// (k, n) — `Σ_k^{1/2} V_kᵀ`.
    pub w2: Factor,
}

impl FactorizedLinear {
    pub fn new(name: &str, w1: Factor, w2: Factor) -> Result<FactorizedLinear> {
        if w1.cols != w2.rows {
            bail!("{name}: factor rank mismatch, w1 is {}x{} but w2 is {}x{}",
                  w1.rows, w1.cols, w2.rows, w2.cols);
        }
        Ok(FactorizedLinear { name: name.to_string(), w1, w2 })
    }

    pub fn in_dim(&self) -> usize {
        self.w1.rows
    }

    pub fn out_dim(&self) -> usize {
        self.w2.cols
    }

    pub fn rank(&self) -> usize {
        self.w1.cols
    }

    /// `y = (x @ W1) @ W2` for `x` (rows, m) → (rows, n).
    pub fn apply(&self, x: &[f32], rows: usize) -> Vec<f32> {
        let mid = matmul(x, rows, &self.w1);
        matmul(&mid, rows, &self.w2)
    }

    /// Truncate to rank `k` (clamped to `[1, rank()]`) — drops the smallest
    /// singular directions, exactly the Dobi truncation-position semantics.
    pub fn set_rank(&mut self, k: usize) {
        let k = k.clamp(1, self.rank());
        self.w1.truncate_cols(k);
        self.w2.truncate_rows(k);
    }

    /// Factorized FLOPs for a (rows, m) input: `2·rows·k·(m+n)`.
    pub fn flops(&self, rows: usize) -> u64 {
        2 * rows as u64 * self.rank() as u64 * (self.in_dim() + self.out_dim()) as u64
    }
}

/// A serving-side weight application: dense passthrough or low-rank.
pub enum Linear {
    Dense { name: String, w: Factor },
    LowRank(FactorizedLinear),
}

impl Linear {
    pub fn name(&self) -> &str {
        match self {
            Linear::Dense { name, .. } => name,
            Linear::LowRank(f) => &f.name,
        }
    }

    pub fn in_dim(&self) -> usize {
        match self {
            Linear::Dense { w, .. } => w.rows,
            Linear::LowRank(f) => f.in_dim(),
        }
    }

    pub fn out_dim(&self) -> usize {
        match self {
            Linear::Dense { w, .. } => w.cols,
            Linear::LowRank(f) => f.out_dim(),
        }
    }

    /// Effective rank (full for dense).
    pub fn rank(&self) -> usize {
        match self {
            Linear::Dense { w, .. } => w.rows.min(w.cols),
            Linear::LowRank(f) => f.rank(),
        }
    }

    pub fn apply(&self, x: &[f32], rows: usize) -> Vec<f32> {
        match self {
            Linear::Dense { w, .. } => matmul(x, rows, w),
            Linear::LowRank(f) => f.apply(x, rows),
        }
    }

    pub fn resident_bytes(&self) -> usize {
        match self {
            Linear::Dense { w, .. } => w.resident_bytes(),
            Linear::LowRank(f) => f.w1.resident_bytes() + f.w2.resident_bytes(),
        }
    }

    pub fn flops(&self, rows: usize) -> u64 {
        match self {
            Linear::Dense { w, .. } => 2 * rows as u64 * w.rows as u64 * w.cols as u64,
            Linear::LowRank(f) => f.flops(rows),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mathx::XorShift;

    /// Unblocked triple-loop reference.
    fn naive(x: &[f32], rows: usize, w: &[f32], inner: usize, cols: usize) -> Vec<f32> {
        let mut out = vec![0f32; rows * cols];
        for i in 0..rows {
            for k in 0..inner {
                let a = x[i * inner + k];
                for j in 0..cols {
                    out[i * cols + j] += a * w[k * cols + j];
                }
            }
        }
        out
    }

    fn randv(rng: &mut XorShift, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32 * scale).collect()
    }

    fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0f32, f32::max)
    }

    #[test]
    fn blocked_matches_naive_on_ragged_shapes() {
        let mut rng = XorShift::new(1);
        // deliberately not multiples of K_BLOCK
        for &(rows, inner, cols) in &[(9usize, 67usize, 45usize), (1, 130, 3), (17, 64, 128)] {
            let x = randv(&mut rng, rows * inner, 1.0);
            let w = randv(&mut rng, inner * cols, 0.1);
            let got = matmul(&x, rows, &Factor::f32(inner, cols, w.clone()));
            let want = naive(&x, rows, &w, inner, cols);
            assert!(max_abs_diff(&got, &want) < 1e-4, "{rows}x{inner}x{cols}");
        }
    }

    #[test]
    fn factorized_full_rank_matches_dense_reference() {
        // Acceptance criterion: f32 full-rank factorized apply == dense W x
        // within 1e-4.  W is defined as the exact product W1 @ W2.
        let (rows, m, k, n) = (16usize, 48usize, 32usize, 32usize); // k == min(m, n)
        let mut rng = XorShift::new(2);
        let w1 = randv(&mut rng, m * k, 0.2);
        let w2 = randv(&mut rng, k * n, 0.2);
        let w = naive(&w1, m, &w2, k, n); // dense W = W1 @ W2, (m, n)
        let x = randv(&mut rng, rows * m, 1.0);
        let lin = FactorizedLinear::new(
            "t", Factor::f32(m, k, w1), Factor::f32(k, n, w2)).unwrap();
        let dense = naive(&x, rows, &w, m, n);
        let fact = lin.apply(&x, rows);
        assert!(max_abs_diff(&fact, &dense) < 1e-4,
                "max diff {}", max_abs_diff(&fact, &dense));
    }

    #[test]
    fn f16_factor_close_to_f32() {
        let (rows, m, n) = (8usize, 40usize, 24usize);
        let mut rng = XorShift::new(3);
        let w = randv(&mut rng, m * n, 0.1);
        let x = randv(&mut rng, rows * m, 1.0);
        let exact = matmul(&x, rows, &Factor::f32(m, n, w.clone()));
        let half = matmul(&x, rows, &Factor::f16_from_f32(m, n, &w));
        // f16 has ~1e-3 relative precision; sums of 40 terms stay well under 0.1
        assert!(max_abs_diff(&exact, &half) < 0.05);
        assert!(max_abs_diff(&exact, &half) > 0.0, "f16 path suspiciously exact");
    }

    #[test]
    fn i8_factors_close_to_f32_both_axes() {
        let (rows, m, n) = (8usize, 32usize, 48usize);
        let mut rng = XorShift::new(4);
        let w = randv(&mut rng, m * n, 0.1);
        let x = randv(&mut rng, rows * m, 1.0);
        let exact = matmul(&x, rows, &Factor::f32(m, n, w.clone()));
        for f in [Factor::i8_cols_from_f32(m, n, &w), Factor::i8_rows_from_f32(m, n, &w)] {
            let got = matmul(&x, rows, &f);
            // int8 absmax: ~0.4% per-element error; conservative bound
            assert!(max_abs_diff(&exact, &got) < 0.2);
        }
    }

    #[test]
    fn i8_roundtrip_matches_quant_codec() {
        // decode_rows must agree with quant::dequantize_i8 exactly
        let (m, n) = (12usize, 10usize);
        let mut rng = XorShift::new(5);
        let w = randv(&mut rng, m * n, 0.3);
        let f = Factor::i8_cols_from_f32(m, n, &w);
        let via_tile = f.to_f32();
        if let FactorData::I8 { codes, scales, .. } = &f.data {
            let via_codec = crate::quant::dequantize_i8(codes, m, n, scales, (1, n));
            assert_eq!(via_tile, via_codec);
        } else {
            panic!("expected i8 factor");
        }
    }

    #[test]
    fn set_rank_equals_manual_truncation() {
        let (rows, m, k, n, k2) = (5usize, 20usize, 16usize, 12usize, 6usize);
        let mut rng = XorShift::new(6);
        let w1 = randv(&mut rng, m * k, 0.2);
        let w2 = randv(&mut rng, k * n, 0.2);
        // manual: keep first k2 cols of w1 / rows of w2
        let w1t: Vec<f32> = (0..m * k2).map(|i| w1[(i / k2) * k + (i % k2)]).collect();
        let w2t: Vec<f32> = w2[..k2 * n].to_vec();
        let x = randv(&mut rng, rows * m, 1.0);
        let manual = FactorizedLinear::new(
            "m", Factor::f32(m, k2, w1t), Factor::f32(k2, n, w2t)).unwrap()
            .apply(&x, rows);
        let mut lin = FactorizedLinear::new(
            "t", Factor::f32(m, k, w1), Factor::f32(k, n, w2)).unwrap();
        lin.set_rank(k2);
        assert_eq!(lin.rank(), k2);
        assert!(max_abs_diff(&lin.apply(&x, rows), &manual) < 1e-6);
    }

    #[test]
    fn truncation_preserves_i8_scales_layout() {
        let (m, k) = (10usize, 8usize);
        let mut rng = XorShift::new(7);
        let w1 = randv(&mut rng, m * k, 0.2);
        let mut f_cols = Factor::i8_cols_from_f32(m, k, &w1); // per-column scales
        f_cols.truncate_cols(3);
        assert_eq!((f_cols.rows, f_cols.cols), (m, 3));
        if let FactorData::I8 { scales, .. } = &f_cols.data {
            assert_eq!(scales.len(), 3);
        }
        let mut f_rows = Factor::i8_rows_from_f32(k, m, &w1); // per-row scales
        f_rows.truncate_rows(5);
        assert_eq!((f_rows.rows, f_rows.cols), (5, m));
        if let FactorData::I8 { scales, .. } = &f_rows.data {
            assert_eq!(scales.len(), 5);
        }
        // decoded truncation == truncated decode
        let full = Factor::i8_cols_from_f32(m, k, &w1).to_f32();
        let trunc = f_cols.to_f32();
        for r in 0..m {
            for c in 0..3 {
                assert_eq!(trunc[r * 3 + c], full[r * k + c]);
            }
        }
    }

    #[test]
    fn decode_rows_cols_matches_full_decode() {
        let (m, n) = (20usize, 30usize);
        let mut rng = XorShift::new(21);
        let w = randv(&mut rng, m * n, 0.3);
        for f in [Factor::f32(m, n, w.clone()),
                  Factor::f16_from_f32(m, n, &w),
                  Factor::i8_cols_from_f32(m, n, &w),
                  Factor::i8_rows_from_f32(m, n, &w)] {
            let full = f.to_f32();
            for &(r0, nr, c0, nc) in &[(0usize, 5usize, 0usize, 7usize), (3, 9, 11, 19),
                                       (19, 1, 29, 1), (0, 20, 0, 30)] {
                let mut sub = vec![0f32; nr * nc];
                f.decode_rows_cols(r0, nr, c0, nc, &mut sub);
                for r in 0..nr {
                    for c in 0..nc {
                        assert_eq!(sub[r * nc + c], full[(r0 + r) * n + c0 + c],
                                   "block ({r0},{nr},{c0},{nc}) at ({r},{c})");
                    }
                }
            }
        }
    }

    #[test]
    fn threaded_matmul_bit_identical_to_serial() {
        // big enough to clear the work floor, ragged so stripes are uneven
        let (rows, inner, cols) = (4usize, 256usize, 321usize);
        let mut rng = XorShift::new(22);
        let x = randv(&mut rng, rows * inner, 1.0);
        let w = randv(&mut rng, inner * cols, 0.1);
        for f in [Factor::f32(inner, cols, w.clone()),
                  Factor::f16_from_f32(inner, cols, &w),
                  Factor::i8_cols_from_f32(inner, cols, &w),
                  Factor::i8_rows_from_f32(inner, cols, &w)] {
            // baseline through the single-stripe kernel directly: immune
            // to other tests mutating the process-wide thread count
            let mut serial = vec![0f32; rows * cols];
            matmul_stripe(&x, rows, &f, 0, cols, &mut serial);
            for t in [2usize, 3, 4] {
                let mut par = vec![0f32; rows * cols];
                matmul_into_striped(&x, rows, &f, &mut par, t);
                assert_eq!(serial, par, "stripes={t} drifted from serial");
            }
            // the accumulate contract survives striping too: seeding out
            // with prior values must give the same bits either way
            let mut acc_serial = serial.clone();
            matmul_stripe(&x, rows, &f, 0, cols, &mut acc_serial);
            let mut acc_par = serial.clone();
            matmul_into_striped(&x, rows, &f, &mut acc_par, 4);
            assert_eq!(acc_serial, acc_par, "striped accumulate broke the += contract");
            // public entry point: bit-identical whatever the global says
            // (any concurrent setting yields the same bits, proven above)
            set_decode_threads(4);
            let via_public = matmul(&x, rows, &f);
            set_decode_threads(1);
            assert_eq!(serial, via_public, "matmul() drifted from the stripe kernel");
        }
    }

    #[test]
    fn decode_threads_clamped_and_thread_local() {
        set_decode_threads(0);
        assert_eq!(decode_threads(), 1, "zero must clamp to 1");
        set_decode_threads(3);
        assert_eq!(decode_threads(), 3);
        // thread-local: another thread's setting never leaks over
        std::thread::spawn(|| {
            assert_eq!(decode_threads(), 1);
            set_decode_threads(7);
        })
        .join()
        .unwrap();
        assert_eq!(decode_threads(), 3);
        set_decode_threads(1);
    }

    #[test]
    fn rank_mismatch_rejected() {
        assert!(FactorizedLinear::new(
            "bad",
            Factor::f32(4, 3, vec![0.0; 12]),
            Factor::f32(2, 5, vec![0.0; 10]),
        )
        .is_err());
    }

    #[test]
    fn flops_accounting() {
        let lin = FactorizedLinear::new(
            "f", Factor::f32(100, 10, vec![0.0; 1000]),
            Factor::f32(10, 50, vec![0.0; 500])).unwrap();
        assert_eq!(lin.flops(4), 2 * 4 * 10 * 150);
        let dense = Linear::Dense { name: "d".into(), w: Factor::f32(100, 50, vec![0.0; 5000]) };
        assert_eq!(dense.flops(4), 2 * 4 * 100 * 50);
    }
}
