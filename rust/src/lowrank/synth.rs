//! Synthetic nano-model builders: deterministic random weights shaped like
//! `python/compile/model.py` checkpoints, as in-memory [`FactorizedModel`]s
//! or as `.dobiw` tensor lists.  Used by unit/integration tests and the
//! speed benches so the native backend is exercisable on a fresh checkout
//! with no compiled artifacts.

use crate::lowrank::kernel::{matmul, Factor, FactorizedLinear, Linear};
use crate::lowrank::model::{target_dims, FactorizedModel, LayerWeights, LAYER_MATS};
use crate::mathx::XorShift;
use crate::storage::{f16_tensor, f32_tensor, i8_tensor, Tensor};

/// Number of projected image prefix tokens synthetic VLM models use.
pub const SYNTH_IMG_TOKENS: usize = 2;

#[derive(Debug, Clone, Copy)]
pub struct TinyDims {
    pub vocab: usize,
    pub d: usize,
    pub heads: usize,
    pub layers: usize,
    pub ff: usize,
}

impl TinyDims {
    /// (m, n) of one compression target (delegates to the loader's
    /// [`target_dims`] so fixtures and loader cannot drift).
    pub fn mat_dims(&self, mat: &str) -> (usize, usize) {
        target_dims(mat, self.d, self.ff)
    }

    /// The synthetic nano model `dobi compress --synth`, the compress
    /// bench, and the compress e2e tests all share: byte vocab (so the
    /// tokenizer's ids are always in range) with d/ff sized so the
    /// compression targets dominate the embedding — a 0.4 global ratio
    /// then leaves a meaningful per-target budget to allocate.
    pub fn nano() -> TinyDims {
        TinyDims { vocab: 256, d: 48, heads: 2, layers: 2, ff: 64 }
    }
}

/// How the synthetic store encodes the seven per-layer targets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SynthStyle {
    /// Plain dense f32 matrices.
    DenseF32,
    /// `name.w1`/`name.w2` int8 factor pairs with absmax scales
    /// (the remapped Dobi layout: W1 per-column, W2 per-row scales).
    FactorQ8,
    /// `name.w1`/`name.w2` f16 factor pairs (the precision-16 ablation).
    FactorF16,
}

fn randv(rng: &mut XorShift, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32 * scale).collect()
}

/// Deterministic factor pair for one target: W1 (m, k), W2 (k, n) with
/// k = min(m, n) (full rank, so dense and factorized twins agree).
fn factors(rng: &mut XorShift, m: usize, n: usize) -> (Vec<f32>, Vec<f32>, usize) {
    let k = m.min(n);
    let scale = 1.0 / (m as f32).sqrt();
    (randv(rng, m * k, scale), randv(rng, k * n, scale), k)
}

/// Build an in-memory model.  `factorized` picks low-rank vs dense layers;
/// the dense twin uses the exact products `W1 @ W2`, so for a fixed
/// `TinyDims`/`img_dim` both twins compute the same function.
pub fn tiny_model(dims: TinyDims, img_dim: usize, factorized: bool) -> FactorizedModel {
    let mut rng = XorShift::new(42);
    let d = dims.d;
    let embed = randv(&mut rng, dims.vocab * d, 0.05);
    let mut layers = Vec::new();
    for li in 0..dims.layers {
        let mut mats: Vec<Linear> = Vec::with_capacity(7);
        for mat in LAYER_MATS {
            let (m, n) = dims.mat_dims(mat);
            let (w1, w2, k) = factors(&mut rng, m, n);
            let name = format!("layers.{li}.{mat}");
            if factorized {
                mats.push(Linear::LowRank(
                    FactorizedLinear::new(&name, Factor::f32(m, k, w1), Factor::f32(k, n, w2))
                        .expect("synth factors consistent"),
                ));
            } else {
                let w = matmul(&w1, m, &Factor::f32(k, n, w2));
                mats.push(Linear::Dense { name, w: Factor::f32(m, n, w) });
            }
        }
        let mut it = mats.into_iter();
        layers.push(LayerWeights {
            attn_norm: vec![1.0; d],
            mlp_norm: vec![1.0; d],
            wq: it.next().unwrap(),
            wk: it.next().unwrap(),
            wv: it.next().unwrap(),
            wo: it.next().unwrap(),
            w_gate: it.next().unwrap(),
            w_up: it.next().unwrap(),
            w_down: it.next().unwrap(),
        });
    }
    let img_proj = if img_dim > 0 {
        Some(randv(&mut rng, img_dim * SYNTH_IMG_TOKENS * d, 0.1))
    } else {
        None
    };
    FactorizedModel {
        id: "synth/tiny".into(),
        vocab: dims.vocab,
        d_model: d,
        n_heads: dims.heads,
        d_ff: dims.ff,
        img_dim,
        n_img_tokens: if img_dim > 0 { SYNTH_IMG_TOKENS } else { 0 },
        action_head: false,
        embed,
        final_norm: vec![1.0; d],
        layers,
        img_proj,
        act_head: None,
    }
}

/// Tensors for a `.dobiw` store holding the same weights [`tiny_model`]
/// builds (same seed stream), in the requested storage style.
pub fn tiny_store_tensors(dims: TinyDims, img_dim: usize, style: SynthStyle) -> Vec<Tensor> {
    let mut rng = XorShift::new(42);
    let d = dims.d;
    let ones = vec![1.0f32; d];
    let mut out = Vec::new();
    out.push(f32_tensor("embed", vec![dims.vocab, d], &randv(&mut rng, dims.vocab * d, 0.05)));
    for li in 0..dims.layers {
        out.push(f32_tensor(&format!("layers.{li}.attn_norm"), vec![d], &ones));
        out.push(f32_tensor(&format!("layers.{li}.mlp_norm"), vec![d], &ones));
        for mat in LAYER_MATS {
            let (m, n) = dims.mat_dims(mat);
            let (w1, w2, k) = factors(&mut rng, m, n);
            let name = format!("layers.{li}.{mat}");
            match style {
                SynthStyle::DenseF32 => {
                    let w = matmul(&w1, m, &Factor::f32(k, n, w2));
                    out.push(f32_tensor(&name, vec![m, n], &w));
                }
                SynthStyle::FactorF16 => {
                    out.push(f16_tensor(&format!("{name}.w1"), vec![m, k], &w1));
                    out.push(f16_tensor(&format!("{name}.w2"), vec![k, n], &w2));
                }
                SynthStyle::FactorQ8 => {
                    let f1 = Factor::i8_cols_from_f32(m, k, &w1);
                    let f2 = Factor::i8_rows_from_f32(k, n, &w2);
                    for (fname, f, scale_shape) in [
                        (format!("{name}.w1"), f1, vec![1, k]),
                        (format!("{name}.w2"), f2, vec![k, 1]),
                    ] {
                        let (rows, cols) = (f.rows, f.cols);
                        if let crate::lowrank::kernel::FactorData::I8 { codes, scales, .. } =
                            f.data
                        {
                            out.push(i8_tensor(&format!("{fname}.q8"), vec![rows, cols], &codes));
                            out.push(f32_tensor(&format!("{fname}.scales"), scale_shape, &scales));
                        }
                    }
                }
            }
        }
    }
    out.push(f32_tensor("final_norm", vec![d], &ones));
    if img_dim > 0 {
        out.push(f32_tensor(
            "img_proj",
            vec![img_dim, SYNTH_IMG_TOKENS * d],
            &randv(&mut rng, img_dim * SYNTH_IMG_TOKENS * d, 0.1),
        ));
    }
    out
}

/// Manifest JSON (one model, the given variants) for a synthetic artifacts
/// dir — enough structure for `Manifest::load` and the native backend.
pub fn tiny_manifest_json(dims: TinyDims, img_dim: usize,
                          variants: &[(&str, &str, f64, &str)]) -> String {
    // variants: (id, kind, ratio, weights-file)
    let mats: usize = LAYER_MATS
        .iter()
        .map(|m| {
            let (a, b) = dims.mat_dims(m);
            a * b
        })
        .sum();
    let total = dims.vocab * dims.d + dims.d + dims.layers * (2 * dims.d + mats);
    let mut vjson = Vec::new();
    for (id, kind, ratio, weights) in variants {
        vjson.push(format!(
            r#"{{"id": "{id}", "model": "tiny", "method": "dobi", "ratio": {ratio},
                "kind": "{kind}", "kernel": "xla", "weights": "{weights}",
                "param_names": [], "hlo": {{"2x16": "unused.hlo.txt"}},
                "inputs": ["tokens"], "stored_params": {total}, "bytes": 1000,
                "ref_ppl": {{}}, "ranks": {{}}}}"#
        ));
    }
    format!(
        r#"{{
  "profile": "synthetic",
  "models": {{
    "tiny": {{
      "config": {{"vocab": {vocab}, "d_model": {d}, "n_layers": {layers},
                  "n_heads": {heads}, "d_ff": {ff}, "img_dim": {img},
                  "n_img_tokens": {imgtok}}},
      "total_params": {total},
      "fixed_params": 0
    }}
  }},
  "variants": [{variants}],
  "corpora": {{}},
  "eval": {{"batch": 2, "seq": 16, "windows": 1}}
}}"#,
        vocab = dims.vocab,
        d = dims.d,
        layers = dims.layers,
        heads = dims.heads,
        ff = dims.ff,
        img = img_dim,
        imgtok = if img_dim > 0 { SYNTH_IMG_TOKENS } else { 0 },
        total = total,
        variants = vjson.join(", ")
    )
}
