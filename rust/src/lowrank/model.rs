//! Native factorized transformer: the LLaMA-architecture forward
//! (RMSNorm, interleaved RoPE, causal attention, SwiGLU, tied LM head)
//! executed in-process over [`Linear`] weights — dense or rank-truncated
//! factors — loaded straight from the `.dobiw` store.
//!
//! Mirrors `python/compile/model.py` exactly: same parameter naming
//! (`embed`, `layers.{i}.{attn_norm,mlp_norm,wq,wk,wv,wo,w_gate,w_up,
//! w_down}`, `final_norm`, optional `img_proj`/`act_head`), same RoPE
//! pairing, same VLM prefix and VLA head semantics — so the byte-level
//! corpora, eval harness, and coordinator work unchanged on this backend.

use anyhow::{anyhow, bail, Result};

use crate::config::{ModelInfo, Variant};
use crate::lowrank::kernel::{Factor, FactorData, FactorizedLinear, Linear};
use crate::runtime::ForwardModel;
use crate::storage::{Dtype, Store};

/// RoPE base; `python/compile/model.py::ModelConfig.rope_theta` default.
/// Not exported through the manifest, so pinned here.
pub const ROPE_THETA: f64 = 10_000.0;

const RMS_EPS: f32 = 1e-5;

/// The seven per-layer compression targets, manifest order.
pub const LAYER_MATS: [&str; 7] = ["wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down"];

/// (m, n) of one compression target given the model widths — the single
/// source of truth shared by the loader and the synth fixture writer.
pub fn target_dims(mat: &str, d: usize, ff: usize) -> (usize, usize) {
    match mat {
        "w_gate" | "w_up" => (d, ff),
        "w_down" => (ff, d),
        _ => (d, d), // wq wk wv wo
    }
}

pub struct LayerWeights {
    pub attn_norm: Vec<f32>,
    pub mlp_norm: Vec<f32>,
    pub wq: Linear,
    pub wk: Linear,
    pub wv: Linear,
    pub wo: Linear,
    pub w_gate: Linear,
    pub w_up: Linear,
    pub w_down: Linear,
}

impl LayerWeights {
    pub fn mats(&self) -> [&Linear; 7] {
        [&self.wq, &self.wk, &self.wv, &self.wo, &self.w_gate, &self.w_up, &self.w_down]
    }

    fn mats_mut(&mut self) -> [&mut Linear; 7] {
        [&mut self.wq, &mut self.wk, &mut self.wv, &mut self.wo,
         &mut self.w_gate, &mut self.w_up, &mut self.w_down]
    }
}

/// A fully-resident native model: factors stay in storage precision and
/// decode tile-by-tile inside the blocked GEMMs.
pub struct FactorizedModel {
    pub id: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub img_dim: usize,
    pub n_img_tokens: usize,
    pub action_head: bool,
    pub embed: Vec<f32>,      // (vocab, d)
    pub final_norm: Vec<f32>, // (d,)
    pub layers: Vec<LayerWeights>,
    pub img_proj: Option<Vec<f32>>, // (img_dim, n_img_tokens * d)
    pub act_head: Option<Vec<f32>>, // (d, 5)
}

// ---------------------------------------------------------------------------
// Loading
// ---------------------------------------------------------------------------

fn vec_f32(store: &Store, name: &str, want_len: usize) -> Result<Vec<f32>> {
    let (vals, _) = store.tensor_f32(name)?;
    anyhow::ensure!(vals.len() == want_len,
                    "tensor `{name}`: {} elements, expected {want_len}", vals.len());
    Ok(vals)
}

/// Read `name` from the store as a [`Factor`] in its stored precision:
/// plain f32/f16 tensors pass through; `name.q8` + `name.scales` pairs stay
/// int8 with their broadcast axis.  Returns Ok(None) when absent.
fn factor_from_store(store: &Store, name: &str) -> Result<Option<Factor>> {
    if let Some(t) = store.tensors.get(name) {
        anyhow::ensure!(t.shape.len() == 2, "`{name}`: factors must be 2-D");
        let (rows, cols) = (t.shape[0], t.shape[1]);
        let data = match t.dtype {
            Dtype::F32 => FactorData::F32(t.to_f32()),
            Dtype::F16 => {
                let halves: Vec<u16> = t
                    .data
                    .chunks_exact(2)
                    .map(|c| u16::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                FactorData::F16(halves)
            }
            Dtype::I8 => bail!("`{name}`: bare int8 tensor without `.scales` companion"),
            Dtype::I32 => bail!("`{name}`: int32 is not a weight precision"),
        };
        return Ok(Some(Factor { rows, cols, data }));
    }
    let Some(q) = store.tensors.get(&format!("{name}.q8")) else {
        return Ok(None);
    };
    let s = store
        .tensors
        .get(&format!("{name}.scales"))
        .ok_or_else(|| anyhow!("`{name}.q8` present but `{name}.scales` missing"))?;
    anyhow::ensure!(q.shape.len() == 2 && s.shape.len() == 2,
                    "`{name}`: quantized tensors must be 2-D");
    anyhow::ensure!(q.dtype == Dtype::I8, "`{name}.q8`: expected int8 codes");
    let (rows, cols) = (q.shape[0], q.shape[1]);
    let per_row = match (s.shape[0], s.shape[1]) {
        (1, c) if c == cols => false,
        (r, 1) if r == rows => true,
        other => bail!("`{name}.scales`: unsupported shape {other:?} for ({rows}, {cols})"),
    };
    Ok(Some(Factor::i8(rows, cols, q.as_i8(), s.to_f32(), per_row)?))
}

/// Load `name` as a [`Linear`]: a stored dense matrix, or a
/// `name.w1`/`name.w2` factor pair (each possibly quantized).
fn linear_from_store(store: &Store, name: &str, m: usize, n: usize) -> Result<Linear> {
    if let Some(w) = factor_from_store(store, name)? {
        anyhow::ensure!(w.rows == m && w.cols == n,
                        "`{name}`: stored {}x{}, model wants {m}x{n}", w.rows, w.cols);
        return Ok(Linear::Dense { name: name.to_string(), w });
    }
    let w1 = factor_from_store(store, &format!("{name}.w1"))?
        .ok_or_else(|| anyhow!("`{name}`: neither dense nor `.w1`/`.w2` factors in store"))?;
    let w2 = factor_from_store(store, &format!("{name}.w2"))?
        .ok_or_else(|| anyhow!("`{name}.w2` missing (have `.w1`)"))?;
    anyhow::ensure!(w1.rows == m && w2.cols == n,
                    "`{name}`: factors give {}x{}, model wants {m}x{n}", w1.rows, w2.cols);
    Ok(Linear::LowRank(FactorizedLinear::new(name, w1, w2)?))
}

impl FactorizedModel {
    /// Assemble a model for `variant` from an open store.  Unlike the PJRT
    /// loader there is no shape filter: the native forward accepts any
    /// (b, s), and `ForwardModel::shapes()` stays empty (shape-agnostic)
    /// so the engine runs exact-sized batches with no padding rows.
    pub fn from_store(info: &ModelInfo, variant: &Variant,
                      store: &Store) -> Result<FactorizedModel> {
        if variant.kind == "pruned" {
            bail!("{}: pruned variants need per-layer head counts that the manifest \
                   does not carry; serve them via the PJRT backend", variant.id);
        }
        let (d, f) = (info.d_model, info.d_ff);
        anyhow::ensure!(info.n_heads > 0 && d % info.n_heads == 0,
                        "{}: d_model {d} not divisible by {} heads", variant.id, info.n_heads);
        let mut layers = Vec::with_capacity(info.n_layers);
        for li in 0..info.n_layers {
            let attn_norm = vec_f32(store, &format!("layers.{li}.attn_norm"), d)?;
            let mlp_norm = vec_f32(store, &format!("layers.{li}.mlp_norm"), d)?;
            let mut mats = Vec::with_capacity(7);
            for mat in LAYER_MATS {
                let (m, n) = target_dims(mat, d, f);
                mats.push(linear_from_store(store, &format!("layers.{li}.{mat}"), m, n)?);
            }
            let mut it = mats.into_iter();
            let mut layer = LayerWeights {
                attn_norm,
                mlp_norm,
                wq: it.next().unwrap(),
                wk: it.next().unwrap(),
                wv: it.next().unwrap(),
                wo: it.next().unwrap(),
                w_gate: it.next().unwrap(),
                w_up: it.next().unwrap(),
                w_down: it.next().unwrap(),
            };
            // Honor the Dobi pipeline's trained truncation positions: the
            // manifest's per-target rank is authoritative when it is lower
            // than what the store holds.
            for lin in layer.mats_mut() {
                let rank = variant.ranks.get(lin.name()).copied();
                if let (Some(k), Linear::LowRank(fl)) = (rank, lin) {
                    if k >= 1 && k < fl.rank() {
                        fl.set_rank(k);
                    }
                }
            }
            layers.push(layer);
        }
        let embed = vec_f32(store, "embed", info.vocab * d)?;
        let final_norm = vec_f32(store, "final_norm", d)?;
        let img_proj = if info.img_dim > 0 {
            Some(vec_f32(store, "img_proj", info.img_dim * info.n_img_tokens * d)?)
        } else {
            None
        };
        let act_head = if info.action_head {
            Some(vec_f32(store, "act_head", d * 5)?)
        } else {
            None
        };
        Ok(FactorizedModel {
            id: variant.id.clone(),
            vocab: info.vocab,
            d_model: d,
            n_heads: info.n_heads,
            d_ff: f,
            img_dim: info.img_dim,
            n_img_tokens: info.n_img_tokens,
            action_head: info.action_head,
            embed,
            final_norm,
            layers,
            img_proj,
            act_head,
        })
    }

    pub fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Host bytes kept resident (factors in storage precision + f32 rest).
    pub fn resident_bytes(&self) -> usize {
        let mut total = (self.embed.len() + self.final_norm.len()) * 4;
        for l in &self.layers {
            total += (l.attn_norm.len() + l.mlp_norm.len()) * 4;
            for lin in l.mats() {
                total += lin.resident_bytes();
            }
        }
        total += self.img_proj.as_ref().map_or(0, |v| v.len() * 4);
        total += self.act_head.as_ref().map_or(0, |v| v.len() * 4);
        total
    }

    /// Matmul FLOPs of one forward at (b, s) — the quantity the speed
    /// benches compare against the dense-equivalent model.
    pub fn matmul_flops(&self, b: usize, s: usize) -> u64 {
        let rows = b * (s + self.prefix_len());
        let mut total = 0u64;
        for l in &self.layers {
            for lin in l.mats() {
                total += lin.flops(rows);
            }
        }
        // Output head, as forward() actually runs it: the tied LM head over
        // the b*s non-prefix positions, or the (d, 5) action head over the
        // b last positions for VLA models.
        total
            + if self.action_head {
                2 * b as u64 * self.d_model as u64 * 5
            } else {
                2 * (b * s) as u64 * self.d_model as u64 * self.vocab as u64
            }
    }

    /// Uniformly scale every factorized target's rank to
    /// `ceil(fraction * current_rank)` (min 1) — the bench sweep knob.
    pub fn set_rank_fraction(&mut self, fraction: f64) {
        for l in &mut self.layers {
            for lin in l.mats_mut() {
                if let Linear::LowRank(fl) = lin {
                    let k = ((fl.rank() as f64 * fraction).ceil() as usize).max(1);
                    fl.set_rank(k);
                }
            }
        }
    }

    fn prefix_len(&self) -> usize {
        if self.img_dim > 0 {
            self.n_img_tokens
        } else {
            0
        }
    }

    // -- forward pass -------------------------------------------------------

    /// Embedding (+ projected image prefix for VLM/VLA): the (b*(p+s), d)
    /// trunk input shared by [`Self::forward`] and [`Self::forward_taps`].
    fn embed_input(&self, b: usize, s: usize, tokens: &[i32],
                   image: Option<&[f32]>) -> Result<Vec<f32>> {
        anyhow::ensure!(b > 0 && s > 0, "{}: empty shape {b}x{s}", self.id);
        anyhow::ensure!(tokens.len() == b * s, "tokens len {} != {b}x{s}", tokens.len());
        let d = self.d_model;
        let p = self.prefix_len();
        let st = p + s; // total sequence length inside the trunk
        let rows = b * st;
        let mut h = vec![0f32; rows * d];
        if p > 0 {
            let img = image.ok_or_else(|| anyhow!("{}: image input required", self.id))?;
            anyhow::ensure!(img.len() == b * self.img_dim, "image len mismatch");
            let proj = self.img_proj.as_ref().expect("img_proj present when img_dim > 0");
            // prefix = image @ img_proj, accumulated straight into the
            // zeroed h rows (no per-request weight copy on the hot path).
            let pd = p * d;
            for bi in 0..b {
                let dst = &mut h[bi * st * d..bi * st * d + pd];
                let xrow = &img[bi * self.img_dim..(bi + 1) * self.img_dim];
                for (ii, &xv) in xrow.iter().enumerate() {
                    if xv != 0.0 {
                        let wrow = &proj[ii * pd..(ii + 1) * pd];
                        for (slot, &wv) in dst.iter_mut().zip(wrow) {
                            *slot += xv * wv;
                        }
                    }
                }
            }
        } else {
            anyhow::ensure!(image.is_none(), "{}: unexpected image input", self.id);
        }
        for bi in 0..b {
            for si in 0..s {
                let t = tokens[bi * s + si];
                if t < 0 || t as usize >= self.vocab {
                    bail!("{}: token id {t} outside vocab {}", self.id, self.vocab);
                }
                let dst = (bi * st + p + si) * d;
                h[dst..dst + d].copy_from_slice(&self.embed[t as usize * d..(t as usize + 1) * d]);
            }
        }
        Ok(h)
    }

    /// Run every transformer layer over `h` in place — the ONE trunk loop
    /// shared by serving ([`Self::forward`]) and calibration
    /// ([`Self::forward_taps`]), so the activations compression sees are
    /// by construction the activations serving computes.
    ///
    /// `taps`, when set, receives one copy per *capture point* (four per
    /// layer), keyed by the representative target: `layers.{i}.wq`
    /// (post-attn-norm, shared by wq/wk/wv), `layers.{i}.wo` (attention
    /// context), `layers.{i}.w_gate` (post-mlp-norm, shared by
    /// w_gate/w_up), and `layers.{i}.w_down` (gated hidden).  Storing
    /// representatives instead of per-target clones keeps calibration
    /// memory at 4 buffers/layer instead of 7;
    /// `compress::calib::tap_key` maps any target name to its
    /// representative.
    fn run_trunk(&self, h: &mut [f32], b: usize, st: usize,
                 mut taps: Option<&mut std::collections::BTreeMap<String, Vec<f32>>>) {
        let d = self.d_model;
        let rows = b * st;
        let (cos, sin) = rope_cache(0, st, self.d_head());
        let mut normed = vec![0f32; rows * d];
        for (li, layer) in self.layers.iter().enumerate() {
            rmsnorm(h, &layer.attn_norm, d, &mut normed);
            if let Some(t) = taps.as_deref_mut() {
                t.insert(format!("layers.{li}.wq"), normed.clone());
            }
            let mut wo_in = taps.as_ref().map(|_| Vec::new());
            let attn = self.attention(&normed, layer, b, st, &cos, &sin, wo_in.as_mut());
            if let (Some(t), Some(x)) = (taps.as_deref_mut(), wo_in) {
                t.insert(format!("layers.{li}.wo"), x);
            }
            add_inplace(h, &attn);
            rmsnorm(h, &layer.mlp_norm, d, &mut normed);
            if let Some(t) = taps.as_deref_mut() {
                t.insert(format!("layers.{li}.w_gate"), normed.clone());
            }
            let mut down_in = taps.as_ref().map(|_| Vec::new());
            let out = mlp(&normed, rows, layer, down_in.as_mut());
            if let (Some(t), Some(x)) = (taps.as_deref_mut(), down_in) {
                t.insert(format!("layers.{li}.w_down"), x);
            }
            add_inplace(h, &out);
        }
    }

    /// Execute the (b, s) forward.  `tokens` row-major (b, s); `image`
    /// required iff `img_dim > 0`.  Returns logits (b, s, vocab) or VLA
    /// actions (b, 5).
    pub fn forward(&self, b: usize, s: usize, tokens: &[i32],
                   image: Option<&[f32]>) -> Result<Vec<f32>> {
        let mut h = self.embed_input(b, s, tokens, image)?;
        let d = self.d_model;
        let p = self.prefix_len();
        let st = p + s;
        let rows = b * st;
        self.run_trunk(&mut h, b, st, None);
        let mut normed = vec![0f32; rows * d];
        rmsnorm(&h, &self.final_norm, d, &mut normed);

        if self.action_head {
            // VLA: last position -> (x, y, z, angle, gripper-logit).
            let head = self.act_head.as_ref().expect("act_head present");
            let mut out = vec![0f32; b * 5];
            for bi in 0..b {
                let hrow = &normed[(bi * st + st - 1) * d..(bi * st + st) * d];
                for j in 0..5 {
                    let mut acc = 0f32;
                    for (k, &x) in hrow.iter().enumerate() {
                        acc += x * head[k * 5 + j];
                    }
                    out[bi * 5 + j] = if j < 4 { acc.tanh() } else { acc };
                }
            }
            return Ok(out);
        }

        // Tied LM head over the non-prefix positions: logits = h @ embedᵀ.
        let v = self.vocab;
        let mut logits = vec![0f32; b * s * v];
        for bi in 0..b {
            for si in 0..s {
                let hrow = &normed[(bi * st + p + si) * d..(bi * st + p + si + 1) * d];
                let orow = &mut logits[(bi * s + si) * v..(bi * s + si + 1) * v];
                for (vi, slot) in orow.iter_mut().enumerate() {
                    let erow = &self.embed[vi * d..(vi + 1) * d];
                    let mut acc = 0f32;
                    for k in 0..d {
                        acc += hrow[k] * erow[k];
                    }
                    *slot = acc;
                }
            }
        }
        Ok(logits)
    }

    /// Calibration pass: run the trunk and capture each compression
    /// target's row-major (b·(p+s), in_dim) input — the native mirror of
    /// `python/compile/dobi/pipeline.py::collect_calibration`.  Keyed by
    /// representative target name (see [`Self::run_trunk`]); resolve an
    /// arbitrary target with `compress::calib::tap_key`.
    pub fn forward_taps(&self, b: usize, s: usize, tokens: &[i32],
                        image: Option<&[f32]>)
                        -> Result<std::collections::BTreeMap<String, Vec<f32>>> {
        let mut h = self.embed_input(b, s, tokens, image)?;
        let st = self.prefix_len() + s;
        let mut taps = std::collections::BTreeMap::new();
        self.run_trunk(&mut h, b, st, Some(&mut taps));
        Ok(taps)
    }

    /// Multi-head causal attention over (b, st) rows of `x` (post-norm).
    /// `wo_tap`, when set, receives a copy of the context rows — the input
    /// of the `wo` compression target (calibration capture).
    fn attention(&self, x: &[f32], layer: &LayerWeights, b: usize, st: usize,
                 cos: &[f32], sin: &[f32], wo_tap: Option<&mut Vec<f32>>) -> Vec<f32> {
        let d = self.d_model;
        let nh = self.n_heads;
        let dh = self.d_head();
        let rows = b * st;
        let mut q = layer.wq.apply(x, rows);
        let mut k = layer.wk.apply(x, rows);
        let v = layer.wv.apply(x, rows);
        apply_rope(&mut q, b, st, nh, dh, cos, sin);
        apply_rope(&mut k, b, st, nh, dh, cos, sin);

        let mut ctx = vec![0f32; rows * d];
        for bi in 0..b {
            let span = bi * st * d..(bi + 1) * st * d;
            causal_attend(&q[span.clone()], &k[span.clone()], &v[span.clone()],
                          st, st, nh, dh, &mut ctx[span]);
        }
        if let Some(tap) = wo_tap {
            *tap = ctx.clone();
        }
        layer.wo.apply(&ctx, rows)
    }
}

/// Causal softmax attention of `n_q` query rows over `n_k` key/value rows,
/// all in the head-interleaved (rows, nh·dh) layout.  Query row `i` holds
/// absolute position `n_k - n_q + i` and attends keys `0..=` that position
/// — with `n_q == n_k` this is the full batched forward's causal mask;
/// with `n_q < n_k` it is the KV-cache decode step (new rows attend the
/// whole cache plus themselves).  The ONE attention kernel shared by both
/// paths, so incremental decode is numerically the full forward.
fn causal_attend(q: &[f32], k: &[f32], v: &[f32], n_q: usize, n_k: usize,
                 nh: usize, dh: usize, ctx: &mut [f32]) {
    debug_assert!(n_k >= n_q);
    let d = nh * dh;
    debug_assert!(q.len() == n_q * d && k.len() == n_k * d && v.len() == n_k * d);
    let scale = 1.0 / (dh as f32).sqrt();
    let base = n_k - n_q;
    let mut scores = vec![0f32; n_k];
    for hi in 0..nh {
        let off = hi * dh;
        for i in 0..n_q {
            let last = base + i; // causal: keys 0..=last
            let qrow = &q[i * d + off..i * d + off + dh];
            let mut max = f32::NEG_INFINITY;
            for (j, slot) in scores[..=last].iter_mut().enumerate() {
                let krow = &k[j * d + off..j * d + off + dh];
                let mut acc = 0f32;
                for t in 0..dh {
                    acc += qrow[t] * krow[t];
                }
                let sc = acc * scale;
                *slot = sc;
                max = max.max(sc);
            }
            let mut denom = 0f32;
            for slot in scores[..=last].iter_mut() {
                *slot = (*slot - max).exp();
                denom += *slot;
            }
            let inv = 1.0 / denom;
            let crow = &mut ctx[i * d + off..i * d + off + dh];
            for (j, &w) in scores[..=last].iter().enumerate() {
                let vrow = &v[j * d + off..j * d + off + dh];
                let w = w * inv;
                for t in 0..dh {
                    crow[t] += w * vrow[t];
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Incremental decode (per-session KV cache)
// ---------------------------------------------------------------------------

/// One layer's decode state: RoPE-rotated key rows and raw value rows,
/// each (len, d) row-major, appended as the session decodes.
struct LayerKv {
    k: Vec<f32>,
    v: Vec<f32>,
}

/// Per-session attention state across all layers.  Buffers are allocated
/// to `capacity` rows up front so the decode hot loop never reallocates;
/// `len` counts appended positions (image prefix + prompt + generated).
pub struct KvCache {
    layers: Vec<LayerKv>,
    len: usize,
    capacity: usize,
    d: usize,
}

impl KvCache {
    /// Appended positions so far.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Maximum positions this cache admits.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Positions still available before the cache is full.
    pub fn remaining(&self) -> usize {
        self.capacity - self.len
    }

    /// Drop cached rows (session reset) without releasing the buffers.
    pub fn clear(&mut self) {
        for l in &mut self.layers {
            l.k.clear();
            l.v.clear();
        }
        self.len = 0;
    }

    /// Roll the cache back to `len` positions, discarding every row
    /// appended after that point (speculative-decode rejection path).
    /// Buffers keep their capacity; re-appending after a rollback
    /// reproduces the untruncated state bit-for-bit because appended
    /// rows never depend on rows after their own position.
    pub fn truncate_to(&mut self, len: usize) {
        assert!(len <= self.len,
                "KvCache::truncate_to({len}) beyond current len {}", self.len);
        for l in &mut self.layers {
            l.k.truncate(len * self.d);
            l.v.truncate(len * self.d);
        }
        self.len = len;
    }

    /// Host bytes of the K/V rows cached so far, derived from the actual
    /// buffer contents — not a hardcoded bytes-per-element — so the
    /// accounting stays honest if cached rows stop being f32.
    pub fn resident_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| std::mem::size_of_val(l.k.as_slice()) + std::mem::size_of_val(l.v.as_slice()))
            .sum()
    }
}

impl FactorizedModel {
    /// Allocate a decode session's KV cache: per-layer K/V buffers sized
    /// for `capacity` total positions (prefix + prompt + generated).
    pub fn new_kv_cache(&self, capacity: usize) -> KvCache {
        let d = self.d_model;
        let layers = (0..self.layers.len())
            .map(|_| LayerKv {
                k: Vec::with_capacity(capacity * d),
                v: Vec::with_capacity(capacity * d),
            })
            .collect();
        KvCache { layers, len: 0, capacity, d }
    }

    /// KV-aware incremental forward: append `tokens` (plus the projected
    /// image prefix on the very first call of a VLM session) to the cache
    /// and return the **last position's** logits (vocab,) — the single-row
    /// logits head; decode never materializes the (s, vocab) matrix the
    /// batched forward pays for.
    ///
    /// Runs the same trunk math as [`Self::forward`] — shared RMSNorm /
    /// RoPE (at the absolute position offset) / [`causal_attend`] / SwiGLU
    /// helpers — over only the new rows, attending cached K/V, so
    /// `prefill(prompt)` + `step(token)*` reproduces the full forward's
    /// logits at every decoded position while doing O(len) attention work
    /// per token instead of O(len²) per window.
    pub fn forward_kv(&self, tokens: &[i32], kv: &mut KvCache,
                      image: Option<&[f32]>) -> Result<Vec<f32>> {
        if kv.len > 0 && tokens.len() == 1 && image.is_none() {
            // Single-token decode step: run the fused path at n=1 so the
            // step math exists exactly ONCE — serial stepping and the
            // scheduler's fused ticks cannot drift apart.
            let mut refs: [&mut KvCache; 1] = [kv];
            let mut all = self.forward_kv_multi(tokens, &mut refs)?;
            return Ok(all.pop().expect("n=1 forward returns one row"));
        }
        anyhow::ensure!(!self.action_head,
                        "{}: VLA heads emit one action, not a token stream — \
                         no incremental decode path", self.id);
        anyhow::ensure!(kv.layers.len() == self.layers.len() && kv.d == self.d_model,
                        "{}: KV cache built for a different model", self.id);
        anyhow::ensure!(!tokens.is_empty(), "{}: empty decode step", self.id);
        let d = self.d_model;
        let base = kv.len;
        // New trunk rows: the image prefix participates only at the first
        // call (absolute position 0), exactly as in the batched forward.
        let (mut h, s_new) = if base == 0 {
            let h = self.embed_input(1, tokens.len(), tokens, image)?;
            (h, self.prefix_len() + tokens.len())
        } else {
            anyhow::ensure!(image.is_none(),
                            "{}: image features are consumed at prefill", self.id);
            let mut h = vec![0f32; tokens.len() * d];
            for (si, &t) in tokens.iter().enumerate() {
                if t < 0 || t as usize >= self.vocab {
                    bail!("{}: token id {t} outside vocab {}", self.id, self.vocab);
                }
                h[si * d..(si + 1) * d]
                    .copy_from_slice(&self.embed[t as usize * d..(t as usize + 1) * d]);
            }
            (h, tokens.len())
        };
        anyhow::ensure!(base + s_new <= kv.capacity,
                        "{}: KV cache overflow ({base} + {s_new} > capacity {})",
                        self.id, kv.capacity);
        let nh = self.n_heads;
        let dh = self.d_head();
        let (cos, sin) = rope_cache(base, s_new, dh);
        let n_k = base + s_new;
        let mut normed = vec![0f32; s_new * d];
        let mut ctx = vec![0f32; s_new * d];
        for (layer, lkv) in self.layers.iter().zip(kv.layers.iter_mut()) {
            rmsnorm(&h, &layer.attn_norm, d, &mut normed);
            let mut q = layer.wq.apply(&normed, s_new);
            let mut k_new = layer.wk.apply(&normed, s_new);
            let v_new = layer.wv.apply(&normed, s_new);
            apply_rope(&mut q, 1, s_new, nh, dh, &cos, &sin);
            apply_rope(&mut k_new, 1, s_new, nh, dh, &cos, &sin);
            lkv.k.extend_from_slice(&k_new);
            lkv.v.extend_from_slice(&v_new);
            for slot in ctx.iter_mut() {
                *slot = 0.0;
            }
            causal_attend(&q, &lkv.k, &lkv.v, s_new, n_k, nh, dh, &mut ctx);
            let attn = layer.wo.apply(&ctx, s_new);
            add_inplace(&mut h, &attn);
            rmsnorm(&h, &layer.mlp_norm, d, &mut normed);
            let out = mlp(&normed, s_new, layer, None);
            add_inplace(&mut h, &out);
        }
        kv.len = n_k;
        // Single-row logits head: final norm + tied LM head on the last
        // appended position only.
        let last = &h[(s_new - 1) * d..s_new * d];
        let mut normed_last = vec![0f32; d];
        rmsnorm(last, &self.final_norm, d, &mut normed_last);
        let v = self.vocab;
        let mut logits = vec![0f32; v];
        for (vi, slot) in logits.iter_mut().enumerate() {
            let erow = &self.embed[vi * d..(vi + 1) * d];
            let mut acc = 0f32;
            for t in 0..d {
                acc += normed_last[t] * erow[t];
            }
            *slot = acc;
        }
        Ok(logits)
    }

    /// Fused multi-session decode step: one single-token step for each of
    /// `tokens.len()` *prefilled* sessions, their rows stacked into one
    /// (n_sessions, d) batch so the trunk — and every quantized weight
    /// tile inside the blocked GEMMs — is walked ONCE per call instead of
    /// once per session.  RMSNorm / SwiGLU / the matmuls run over the
    /// stacked rows; RoPE rotates each row at its own session's absolute
    /// position; attention stays per-session against each session's own
    /// [`KvCache`]; the logits head is batched over the stacked rows.
    ///
    /// Every per-row computation is the same code in the same order as
    /// [`Self::forward_kv`] with a single token, so the fused step is
    /// **bit-identical** to stepping the sessions serially — the
    /// scheduler's parity contract (and its error-fallback path) relies
    /// on this.  Validation happens up front: on `Err` no cache has been
    /// touched, so callers can retry sessions individually.
    pub fn forward_kv_multi(&self, tokens: &[i32],
                            kvs: &mut [&mut KvCache]) -> Result<Vec<Vec<f32>>> {
        anyhow::ensure!(!self.action_head,
                        "{}: VLA heads emit one action, not a token stream — \
                         no incremental decode path", self.id);
        let n = tokens.len();
        anyhow::ensure!(n > 0 && kvs.len() == n,
                        "{}: {} tokens for {} sessions", self.id, n, kvs.len());
        let d = self.d_model;
        for (i, kv) in kvs.iter().enumerate() {
            anyhow::ensure!(kv.layers.len() == self.layers.len() && kv.d == d,
                            "{}: KV cache {i} built for a different model", self.id);
            anyhow::ensure!(!kv.is_empty(),
                            "{}: session {i} not prefilled — fused steps are step-only",
                            self.id);
            anyhow::ensure!(kv.len + 1 <= kv.capacity,
                            "{}: KV cache {i} overflow ({} + 1 > capacity {})",
                            self.id, kv.len, kv.capacity);
        }
        // Stacked embedding rows, one per session.
        let mut h = vec![0f32; n * d];
        for (si, &t) in tokens.iter().enumerate() {
            if t < 0 || t as usize >= self.vocab {
                bail!("{}: token id {t} outside vocab {}", self.id, self.vocab);
            }
            h[si * d..(si + 1) * d]
                .copy_from_slice(&self.embed[t as usize * d..(t as usize + 1) * d]);
        }
        let nh = self.n_heads;
        let dh = self.d_head();
        let half = dh / 2;
        // Per-row RoPE tables at each session's own absolute position —
        // the same `rope_cache(base, 1, _)` values the serial step uses.
        let mut cos = vec![0f32; n * half];
        let mut sin = vec![0f32; n * half];
        for (i, kv) in kvs.iter().enumerate() {
            let (c, s) = rope_cache(kv.len, 1, dh);
            cos[i * half..(i + 1) * half].copy_from_slice(&c);
            sin[i * half..(i + 1) * half].copy_from_slice(&s);
        }
        let mut normed = vec![0f32; n * d];
        let mut ctx = vec![0f32; n * d];
        for (li, layer) in self.layers.iter().enumerate() {
            rmsnorm(&h, &layer.attn_norm, d, &mut normed);
            let mut q = layer.wq.apply(&normed, n);
            let mut k_new = layer.wk.apply(&normed, n);
            let v_new = layer.wv.apply(&normed, n);
            apply_rope(&mut q, 1, n, nh, dh, &cos, &sin);
            apply_rope(&mut k_new, 1, n, nh, dh, &cos, &sin);
            for slot in ctx.iter_mut() {
                *slot = 0.0;
            }
            for (i, kv) in kvs.iter_mut().enumerate() {
                let lkv = &mut kv.layers[li];
                lkv.k.extend_from_slice(&k_new[i * d..(i + 1) * d]);
                lkv.v.extend_from_slice(&v_new[i * d..(i + 1) * d]);
                causal_attend(&q[i * d..(i + 1) * d], &lkv.k, &lkv.v, 1, kv.len + 1,
                              nh, dh, &mut ctx[i * d..(i + 1) * d]);
            }
            let attn = layer.wo.apply(&ctx, n);
            add_inplace(&mut h, &attn);
            rmsnorm(&h, &layer.mlp_norm, d, &mut normed);
            let out = mlp(&normed, n, layer, None);
            add_inplace(&mut h, &out);
        }
        for kv in kvs.iter_mut() {
            kv.len += 1;
        }
        // Batched single-row logits head: final norm + tied LM head over
        // the n stacked last-position rows.
        rmsnorm(&h, &self.final_norm, d, &mut normed);
        let v = self.vocab;
        let mut all = Vec::with_capacity(n);
        for i in 0..n {
            let nrow = &normed[i * d..(i + 1) * d];
            let mut logits = vec![0f32; v];
            for (vi, slot) in logits.iter_mut().enumerate() {
                let erow = &self.embed[vi * d..(vi + 1) * d];
                let mut acc = 0f32;
                for t in 0..d {
                    acc += nrow[t] * erow[t];
                }
                *slot = acc;
            }
            all.push(logits);
        }
        Ok(all)
    }

    /// Speculative-verify forward: append `tokens` to one *prefilled*
    /// session's cache in a single multi-row trunk walk and return the
    /// logits of **every** appended position, row-major
    /// (tokens.len() × vocab).  Row `i` attends cached positions
    /// `0..=base+i` through the same [`causal_attend`] kernel the serial
    /// step uses, and the blocked GEMMs compute each row independently of
    /// its batch, so row `i` is **bit-identical** to the logits a serial
    /// [`Self::forward_kv`] step would produce after feeding
    /// `tokens[..i]` — the property that makes greedy speculative decode
    /// exactly equal to pure target decode.  The caller rolls rejected
    /// rows back with [`KvCache::truncate_to`].
    pub fn forward_kv_rows(&self, tokens: &[i32], kv: &mut KvCache) -> Result<Vec<f32>> {
        anyhow::ensure!(!self.action_head,
                        "{}: VLA heads emit one action, not a token stream — \
                         no incremental decode path", self.id);
        anyhow::ensure!(kv.layers.len() == self.layers.len() && kv.d == self.d_model,
                        "{}: KV cache built for a different model", self.id);
        anyhow::ensure!(!kv.is_empty(),
                        "{}: session not prefilled — verify steps are step-only", self.id);
        anyhow::ensure!(!tokens.is_empty(), "{}: empty verify step", self.id);
        let d = self.d_model;
        let base = kv.len;
        let s_new = tokens.len();
        anyhow::ensure!(base + s_new <= kv.capacity,
                        "{}: KV cache overflow ({base} + {s_new} > capacity {})",
                        self.id, kv.capacity);
        let mut h = vec![0f32; s_new * d];
        for (si, &t) in tokens.iter().enumerate() {
            if t < 0 || t as usize >= self.vocab {
                bail!("{}: token id {t} outside vocab {}", self.id, self.vocab);
            }
            h[si * d..(si + 1) * d]
                .copy_from_slice(&self.embed[t as usize * d..(t as usize + 1) * d]);
        }
        let nh = self.n_heads;
        let dh = self.d_head();
        let (cos, sin) = rope_cache(base, s_new, dh);
        let n_k = base + s_new;
        let mut normed = vec![0f32; s_new * d];
        let mut ctx = vec![0f32; s_new * d];
        for (layer, lkv) in self.layers.iter().zip(kv.layers.iter_mut()) {
            rmsnorm(&h, &layer.attn_norm, d, &mut normed);
            let mut q = layer.wq.apply(&normed, s_new);
            let mut k_new = layer.wk.apply(&normed, s_new);
            let v_new = layer.wv.apply(&normed, s_new);
            apply_rope(&mut q, 1, s_new, nh, dh, &cos, &sin);
            apply_rope(&mut k_new, 1, s_new, nh, dh, &cos, &sin);
            lkv.k.extend_from_slice(&k_new);
            lkv.v.extend_from_slice(&v_new);
            for slot in ctx.iter_mut() {
                *slot = 0.0;
            }
            causal_attend(&q, &lkv.k, &lkv.v, s_new, n_k, nh, dh, &mut ctx);
            let attn = layer.wo.apply(&ctx, s_new);
            add_inplace(&mut h, &attn);
            rmsnorm(&h, &layer.mlp_norm, d, &mut normed);
            let out = mlp(&normed, s_new, layer, None);
            add_inplace(&mut h, &out);
        }
        kv.len = n_k;
        // All-rows logits head: final norm + tied LM head on every
        // appended position (the verify step needs each row's argmax).
        rmsnorm(&h, &self.final_norm, d, &mut normed);
        let v = self.vocab;
        let mut logits = vec![0f32; s_new * v];
        for si in 0..s_new {
            let nrow = &normed[si * d..(si + 1) * d];
            let lrow = &mut logits[si * v..(si + 1) * v];
            for (vi, slot) in lrow.iter_mut().enumerate() {
                let erow = &self.embed[vi * d..(vi + 1) * d];
                let mut acc = 0f32;
                for t in 0..d {
                    acc += nrow[t] * erow[t];
                }
                *slot = acc;
            }
        }
        Ok(logits)
    }
}

/// RMSNorm rows of `x` (rows × d) into `out` with gain `g`.
fn rmsnorm(x: &[f32], g: &[f32], d: usize, out: &mut [f32]) {
    debug_assert_eq!(g.len(), d);
    for (xrow, orow) in x.chunks_exact(d).zip(out.chunks_exact_mut(d)) {
        let ms: f32 = xrow.iter().map(|&v| v * v).sum::<f32>() / d as f32;
        let inv = 1.0 / (ms + RMS_EPS).sqrt();
        for j in 0..d {
            orow[j] = xrow[j] * inv * g[j];
        }
    }
}

/// LLaMA interleaved RoPE applied in place to a (b·st, nh·dh) buffer.
/// Positions run over the full (prefix + text) sequence, matching the
/// python trunk.
fn apply_rope(x: &mut [f32], b: usize, st: usize, nh: usize, dh: usize,
              cos: &[f32], sin: &[f32]) {
    let half = dh / 2;
    let d = nh * dh;
    for bi in 0..b {
        for pos in 0..st {
            let row = (bi * st + pos) * d;
            for hi in 0..nh {
                let off = row + hi * dh;
                for j in 0..half {
                    let c = cos[pos * half + j];
                    let s = sin[pos * half + j];
                    let e = x[off + 2 * j];
                    let o = x[off + 2 * j + 1];
                    x[off + 2 * j] = e * c - o * s;
                    x[off + 2 * j + 1] = e * s + o * c;
                }
            }
        }
    }
}

/// (cos, sin) caches of shape (len, dh/2) for absolute positions
/// `start..start + len`, angle = pos · θ^(−2i/dh).  The full forward uses
/// `start = 0`; the KV-cache decode path rotates appended rows at their
/// absolute offset so cached and freshly-computed keys share one frame.
fn rope_cache(start: usize, len: usize, dh: usize) -> (Vec<f32>, Vec<f32>) {
    let half = dh / 2;
    let mut cos = vec![0f32; len * half];
    let mut sin = vec![0f32; len * half];
    for i in 0..len {
        for j in 0..half {
            let inv = ROPE_THETA.powf(-((2 * j) as f64) / dh as f64);
            let ang = (start + i) as f64 * inv;
            cos[i * half + j] = ang.cos() as f32;
            sin[i * half + j] = ang.sin() as f32;
        }
    }
    (cos, sin)
}

/// SwiGLU MLP over (rows, d) post-norm activations.  `down_tap`, when
/// set, receives a copy of the gated hidden rows — the input of the
/// `w_down` compression target (calibration capture).
fn mlp(x: &[f32], rows: usize, layer: &LayerWeights,
       down_tap: Option<&mut Vec<f32>>) -> Vec<f32> {
    let g = layer.w_gate.apply(x, rows);
    let mut u = layer.w_up.apply(x, rows);
    for (ui, &gi) in u.iter_mut().zip(&g) {
        let silu = gi / (1.0 + (-gi).exp());
        *ui *= silu;
    }
    if let Some(tap) = down_tap {
        *tap = u.clone();
    }
    layer.w_down.apply(&u, rows)
}

fn add_inplace(a: &mut [f32], b: &[f32]) {
    debug_assert_eq!(a.len(), b.len());
    for (ai, &bi) in a.iter_mut().zip(b) {
        *ai += bi;
    }
}

impl ForwardModel for FactorizedModel {
    fn forward(&self, b: usize, s: usize, tokens: &[i32],
               image: Option<&[f32]>) -> Result<Vec<f32>> {
        FactorizedModel::forward(self, b, s, tokens, image)
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn img_dim(&self) -> usize {
        self.img_dim
    }

    fn action_head(&self) -> bool {
        self.action_head
    }

    // `shapes()` keeps the trait default (empty = shape-agnostic): the
    // engine then packs each native batch to its exact request count
    // instead of padding to an exported PJRT batch dim.
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lowrank::synth::{tiny_model, SYNTH_IMG_TOKENS, TinyDims};
    use crate::mathx::XorShift;

    fn dims() -> TinyDims {
        TinyDims { vocab: 61, d: 16, heads: 2, layers: 2, ff: 24 }
    }

    #[test]
    fn forward_shapes_and_finiteness() {
        let m = tiny_model(dims(), 0, false);
        let (b, s) = (2usize, 7usize);
        let tokens: Vec<i32> = (0..(b * s) as i32).map(|i| i % 61).collect();
        let out = m.forward(b, s, &tokens, None).unwrap();
        assert_eq!(out.len(), b * s * 61);
        assert!(out.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn causal_mask_blocks_future_tokens() {
        let m = tiny_model(dims(), 0, false);
        let (b, s) = (1usize, 8usize);
        let mut tokens: Vec<i32> = (0..s as i32).collect();
        let base = m.forward(b, s, &tokens, None).unwrap();
        tokens[s - 1] = 60; // perturb only the last position
        let pert = m.forward(b, s, &tokens, None).unwrap();
        let v = m.vocab;
        // positions 0..s-2 must be bit-identical; the last may change
        assert_eq!(&base[..(s - 1) * v], &pert[..(s - 1) * v]);
        assert!(base[(s - 1) * v..] != pert[(s - 1) * v..],
                "last-position logits should react to its own token");
    }

    #[test]
    fn forward_deterministic() {
        let m = tiny_model(dims(), 0, false);
        let tokens: Vec<i32> = (0..12).map(|i| (i * 7) % 61).collect();
        let a = m.forward(2, 6, &tokens, None).unwrap();
        let b = m.forward(2, 6, &tokens, None).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn rejects_bad_inputs() {
        let m = tiny_model(dims(), 0, false);
        assert!(m.forward(1, 4, &[0, 1, 2], None).is_err()); // wrong len
        assert!(m.forward(1, 4, &[0, 1, 2, 61], None).is_err()); // token OOB
        assert!(m.forward(1, 4, &[0, 1, 2, -1], None).is_err()); // negative id
        assert!(m.forward(1, 4, &[0, 1, 2, 3], Some(&[0.0; 4])).is_err()); // no img path
    }

    #[test]
    fn factorized_full_rank_matches_dense_model() {
        // Same weights, one model dense and one with exact full-rank
        // factors: logits must agree to f32-accumulation tolerance.
        let dense = tiny_model(dims(), 0, false);
        let fact = tiny_model(dims(), 0, true);
        let tokens: Vec<i32> = (0..20).map(|i| (i * 13) % 61).collect();
        let a = dense.forward(2, 10, &tokens, None).unwrap();
        let b = fact.forward(2, 10, &tokens, None).unwrap();
        let max = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).fold(0f32, f32::max);
        assert!(max < 1e-3, "max logit diff {max}");
    }

    #[test]
    fn vlm_prefix_and_vla_head() {
        let m = tiny_model(dims(), 6, false); // img_dim 6, 2 prefix tokens
        let (b, s) = (2usize, 5usize);
        let tokens = vec![1i32; b * s];
        let image: Vec<f32> = (0..b * 6).map(|i| i as f32 * 0.1).collect();
        assert!(m.forward(b, s, &tokens, None).is_err()); // image required
        let out = m.forward(b, s, &tokens, Some(&image)).unwrap();
        assert_eq!(out.len(), b * s * m.vocab);
        // different images must change the logits (prefix is attended to)
        let image2: Vec<f32> = image.iter().map(|x| x + 1.0).collect();
        let out2 = m.forward(b, s, &tokens, Some(&image2)).unwrap();
        assert!(out != out2);

        let mut vla = tiny_model(dims(), 6, false);
        vla.action_head = true;
        let mut rng = XorShift::new(9);
        vla.act_head = Some((0..vla.d_model * 5).map(|_| rng.normal() as f32 * 0.3).collect());
        let act = vla.forward(b, s, &tokens, Some(&image)).unwrap();
        assert_eq!(act.len(), b * 5);
        for bi in 0..b {
            for j in 0..4 {
                assert!(act[bi * 5 + j].abs() <= 1.0, "coords/angle are tanh-bounded");
            }
        }
    }

    #[test]
    fn rank_fraction_reduces_flops() {
        let mut m = tiny_model(dims(), 0, true);
        let full = m.matmul_flops(2, 8);
        m.set_rank_fraction(0.25);
        let quarter = m.matmul_flops(2, 8);
        assert!(quarter < full, "{quarter} !< {full}");
        let tokens: Vec<i32> = (0..16).collect();
        assert!(m.forward(2, 8, &tokens, None).unwrap().iter().all(|x| x.is_finite()));
    }

    #[test]
    fn forward_taps_capture_every_capture_point() {
        let m = tiny_model(dims(), 0, false);
        let (b, s) = (2usize, 6usize);
        let tokens: Vec<i32> = (0..(b * s) as i32).map(|i| i % 61).collect();
        let taps = m.forward_taps(b, s, &tokens, None).unwrap();
        let td = dims();
        // four capture points per layer (wk/wv alias wq, w_up aliases
        // w_gate — that resolution lives in compress::calib::tap_key)
        assert_eq!(taps.len(), 4 * td.layers);
        let rows = b * s;
        for li in 0..td.layers {
            for rep in ["wq", "wo", "w_gate", "w_down"] {
                let (in_dim, _) = target_dims(rep, td.d, td.ff);
                let tap = &taps[&format!("layers.{li}.{rep}")];
                assert_eq!(tap.len(), rows * in_dim, "layers.{li}.{rep} tap shape");
                assert!(tap.iter().all(|x| x.is_finite()));
            }
        }
        // tapping must not perturb the forward itself
        let a = m.forward(b, s, &tokens, None).unwrap();
        let _ = m.forward_taps(b, s, &tokens, None).unwrap();
        let c = m.forward(b, s, &tokens, None).unwrap();
        assert_eq!(a, c);
    }

    /// Last-position logits of a full (1, s) forward — the reference the
    /// incremental path must reproduce.
    fn full_last_logits(m: &FactorizedModel, ctx: &[i32], image: Option<&[f32]>) -> Vec<f32> {
        let s = ctx.len();
        let out = m.forward(1, s, ctx, image).unwrap();
        out[(s - 1) * m.vocab..s * m.vocab].to_vec()
    }

    fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0f32, f32::max)
    }

    #[test]
    fn kv_prefill_and_steps_match_full_forward() {
        for factorized in [false, true] {
            let m = tiny_model(dims(), 0, factorized);
            let prompt: Vec<i32> = (0..9).map(|i| (i * 11) % 61).collect();
            let mut kv = m.new_kv_cache(32);
            let pre = m.forward_kv(&prompt, &mut kv, None).unwrap();
            assert_eq!(kv.len(), prompt.len());
            let mut ctx = prompt.clone();
            let want = full_last_logits(&m, &ctx, None);
            assert!(max_abs_diff(&pre, &want) < 1e-4,
                    "prefill logits drifted (factorized={factorized})");
            // greedy-decode 6 positions; every step must match the full
            // forward over the grown context
            let mut last = pre;
            for _ in 0..6 {
                let next = crate::mathx::argmax(&last) as i32;
                ctx.push(next);
                last = m.forward_kv(&[next], &mut kv, None).unwrap();
                let want = full_last_logits(&m, &ctx, None);
                assert!(max_abs_diff(&last, &want) < 1e-4,
                        "step logits drifted at len {} (factorized={factorized})", ctx.len());
            }
            assert_eq!(kv.len(), ctx.len());
            assert!(kv.resident_bytes() > 0);
        }
    }

    #[test]
    fn kv_multi_token_steps_match_single_token_steps() {
        let m = tiny_model(dims(), 0, false);
        let toks: Vec<i32> = (0..12).map(|i| (i * 7 + 3) % 61).collect();
        // one prefill of 12 vs prefill(5) + step batches of 4 and 3
        let mut kv_a = m.new_kv_cache(16);
        let a = m.forward_kv(&toks, &mut kv_a, None).unwrap();
        let mut kv_b = m.new_kv_cache(16);
        m.forward_kv(&toks[..5], &mut kv_b, None).unwrap();
        m.forward_kv(&toks[5..9], &mut kv_b, None).unwrap();
        let b = m.forward_kv(&toks[9..], &mut kv_b, None).unwrap();
        assert_eq!(kv_a.len(), kv_b.len());
        assert!(max_abs_diff(&a, &b) < 1e-5, "chunked decode drifted");
    }

    #[test]
    fn kv_vlm_prefix_applied_once_at_prefill() {
        let m = tiny_model(dims(), 6, false); // 2 prefix tokens
        let prompt = vec![1i32, 2, 3];
        let image: Vec<f32> = (0..6).map(|i| i as f32 * 0.2).collect();
        let mut kv = m.new_kv_cache(16);
        // image required at prefill, rejected afterwards
        assert!(m.forward_kv(&prompt, &mut kv, None).is_err());
        let pre = m.forward_kv(&prompt, &mut kv, Some(&image)).unwrap();
        assert_eq!(kv.len(), SYNTH_IMG_TOKENS + prompt.len());
        let want = full_last_logits(&m, &prompt, Some(&image));
        assert!(max_abs_diff(&pre, &want) < 1e-4);
        assert!(m.forward_kv(&[4], &mut kv, Some(&image)).is_err(), "image after prefill");
        let step = m.forward_kv(&[4], &mut kv, None).unwrap();
        let ctx = vec![1i32, 2, 3, 4];
        let want = full_last_logits(&m, &ctx, Some(&image));
        assert!(max_abs_diff(&step, &want) < 1e-4);
    }

    #[test]
    fn kv_cache_enforces_capacity_and_model_match() {
        let m = tiny_model(dims(), 0, false);
        let mut kv = m.new_kv_cache(4);
        assert_eq!(kv.remaining(), 4);
        m.forward_kv(&[1, 2, 3], &mut kv, None).unwrap();
        assert_eq!(kv.remaining(), 1);
        assert!(m.forward_kv(&[4, 5], &mut kv, None).is_err(), "overflow must fail");
        m.forward_kv(&[4], &mut kv, None).unwrap();
        assert_eq!(kv.remaining(), 0);
        kv.clear();
        assert!(kv.is_empty() && kv.capacity() == 4);
        m.forward_kv(&[7, 8], &mut kv, None).unwrap();
        // a cache from a differently-shaped model is rejected
        let other = tiny_model(TinyDims { vocab: 61, d: 16, heads: 2, layers: 3, ff: 24 }, 0, false);
        let mut kv_other = other.new_kv_cache(8);
        assert!(m.forward_kv(&[1], &mut kv_other, None).is_err());
        // VLA models have no decode path
        let mut vla = tiny_model(dims(), 6, false);
        vla.action_head = true;
        vla.act_head = Some(vec![0.1; vla.d_model * 5]);
        let mut kv_vla = vla.new_kv_cache(8);
        assert!(vla.forward_kv(&[1], &mut kv_vla, None).is_err());
    }

    #[test]
    fn fused_multi_step_bit_identical_to_serial_steps() {
        for factorized in [false, true] {
            let m = tiny_model(dims(), 0, factorized);
            // three sessions at *different* context lengths (distinct RoPE
            // offsets per stacked row — the hard part of fusing)
            let prompts: [Vec<i32>; 3] = [
                (0..5).map(|i| (i * 11) % 61).collect(),
                (0..9).map(|i| (i * 7 + 2) % 61).collect(),
                (0..2).map(|i| (i * 13 + 5) % 61).collect(),
            ];
            let mut serial: Vec<KvCache> = Vec::new();
            let mut fused: Vec<KvCache> = Vec::new();
            let mut last_serial = Vec::new();
            for p in &prompts {
                let mut a = m.new_kv_cache(32);
                last_serial.push(m.forward_kv(p, &mut a, None).unwrap());
                serial.push(a);
                let mut b = m.new_kv_cache(32);
                m.forward_kv(p, &mut b, None).unwrap();
                fused.push(b);
            }
            for round in 0..5 {
                // greedy next token per session off the serial logits
                let toks: Vec<i32> = last_serial
                    .iter()
                    .map(|l| crate::mathx::argmax(l) as i32)
                    .collect();
                for (i, kv) in serial.iter_mut().enumerate() {
                    last_serial[i] = m.forward_kv(&[toks[i]], kv, None).unwrap();
                }
                let mut refs: Vec<&mut KvCache> = fused.iter_mut().collect();
                let got = m.forward_kv_multi(&toks, &mut refs).unwrap();
                assert_eq!(got, last_serial,
                           "fused round {round} drifted (factorized={factorized})");
            }
            for (a, b) in serial.iter().zip(&fused) {
                assert_eq!(a.len(), b.len());
            }
        }
    }

    #[test]
    fn fused_multi_step_validates_without_mutating() {
        let m = tiny_model(dims(), 0, false);
        let mut ready = m.new_kv_cache(8);
        m.forward_kv(&[1, 2, 3], &mut ready, None).unwrap();
        // un-prefilled partner: the whole call must fail...
        let mut empty = m.new_kv_cache(8);
        {
            let mut refs: Vec<&mut KvCache> = vec![&mut ready, &mut empty];
            assert!(m.forward_kv_multi(&[4, 5], &mut refs).is_err());
        }
        // ...without having touched the prefilled cache
        assert_eq!(ready.len(), 3);
        // full partner: same contract
        let mut full = m.new_kv_cache(4);
        m.forward_kv(&[1, 2, 3, 4], &mut full, None).unwrap();
        {
            let mut refs: Vec<&mut KvCache> = vec![&mut ready, &mut full];
            assert!(m.forward_kv_multi(&[5, 6], &mut refs).is_err());
        }
        assert_eq!(ready.len(), 3);
        assert_eq!(full.len(), 4);
        // arity mismatch and token OOB
        {
            let mut refs: Vec<&mut KvCache> = vec![&mut ready];
            assert!(m.forward_kv_multi(&[1, 2], &mut refs).is_err());
            assert!(m.forward_kv_multi(&[61], &mut refs).is_err());
        }
        // fused-vs-serial single-session degenerate case still exact
        let mut alone = m.new_kv_cache(8);
        m.forward_kv(&[1, 2, 3], &mut alone, None).unwrap();
        let want = m.forward_kv(&[7], &mut ready, None).unwrap();
        let mut refs: Vec<&mut KvCache> = vec![&mut alone];
        let got = m.forward_kv_multi(&[7], &mut refs).unwrap();
        assert_eq!(got[0], want);
    }

    #[test]
    fn verify_rows_bit_identical_to_serial_steps() {
        for factorized in [false, true] {
            let m = tiny_model(dims(), 0, factorized);
            let prompt: Vec<i32> = (0..7).map(|i| (i * 11 + 1) % 61).collect();
            let draft = [3i32, 41, 17, 9];
            // serial reference: one forward_kv step per draft token
            let mut kv_s = m.new_kv_cache(32);
            m.forward_kv(&prompt, &mut kv_s, None).unwrap();
            let mut serial = Vec::new();
            for &t in &draft {
                serial.extend(m.forward_kv(&[t], &mut kv_s, None).unwrap());
            }
            // batched verify: all draft rows in ONE multi-row step
            let mut kv_r = m.new_kv_cache(32);
            m.forward_kv(&prompt, &mut kv_r, None).unwrap();
            let rows = m.forward_kv_rows(&draft, &mut kv_r).unwrap();
            assert_eq!(rows.len(), draft.len() * m.vocab);
            // exact equality, not tolerance: the speculative parity
            // guarantee (greedy spec decode == pure target decode) rests
            // on the batched rows being the serial steps bit-for-bit
            assert_eq!(rows, serial, "verify rows drifted (factorized={factorized})");
            assert_eq!(kv_r.len(), kv_s.len());
        }
    }

    #[test]
    fn truncate_to_rollback_then_reappend_is_bit_exact() {
        let m = tiny_model(dims(), 0, false);
        let prompt: Vec<i32> = (0..6).map(|i| (i * 5 + 2) % 61).collect();
        let mut kv = m.new_kv_cache(32);
        m.forward_kv(&prompt, &mut kv, None).unwrap();
        let base = kv.len();
        let bytes_before = kv.resident_bytes();
        let first = m.forward_kv_rows(&[10, 20, 30], &mut kv).unwrap();
        // reject all three speculative rows, then replay them: the cache
        // must behave as if the rejected rows never existed
        kv.truncate_to(base);
        assert_eq!(kv.len(), base);
        assert_eq!(kv.resident_bytes(), bytes_before);
        let again = m.forward_kv_rows(&[10, 20, 30], &mut kv).unwrap();
        assert_eq!(first, again, "rollback + replay must be bit-exact");
        // partial rollback: keep one accepted row, step a correction
        kv.truncate_to(base + 1);
        let corrected = m.forward_kv(&[55], &mut kv, None).unwrap();
        let mut kv_ref = m.new_kv_cache(32);
        m.forward_kv(&prompt, &mut kv_ref, None).unwrap();
        m.forward_kv(&[10], &mut kv_ref, None).unwrap();
        let want = m.forward_kv(&[55], &mut kv_ref, None).unwrap();
        assert_eq!(corrected, want, "post-rollback step must match clean decode");
        // no-op truncate is allowed
        let len = kv.len();
        kv.truncate_to(len);
        assert_eq!(kv.len(), len);
    }

    #[test]
    #[should_panic(expected = "beyond current len")]
    fn truncate_to_beyond_len_panics() {
        let m = tiny_model(dims(), 0, false);
        let mut kv = m.new_kv_cache(8);
        m.forward_kv(&[1, 2], &mut kv, None).unwrap();
        kv.truncate_to(3);
    }

    #[test]
    fn verify_rows_validates_inputs() {
        let m = tiny_model(dims(), 0, false);
        // step-only: an empty cache has no prefill to verify against
        let mut empty = m.new_kv_cache(8);
        assert!(m.forward_kv_rows(&[1, 2], &mut empty).is_err());
        let mut kv = m.new_kv_cache(6);
        m.forward_kv(&[1, 2, 3], &mut kv, None).unwrap();
        assert!(m.forward_kv_rows(&[], &mut kv).is_err(), "empty verify step");
        assert!(m.forward_kv_rows(&[61], &mut kv).is_err(), "token OOB");
        assert!(m.forward_kv_rows(&[1, 2, 3, 4], &mut kv).is_err(), "overflow");
        assert_eq!(kv.len(), 3, "failed verify must not grow the cache");
    }

    #[test]
    fn resident_bytes_counts_quantized_footprint() {
        let dense = tiny_model(dims(), 0, false);
        let bytes = dense.resident_bytes();
        // embed + norms + 2 layers x 7 mats, all f32
        let td = dims();
        let per_layer = 2 * td.d + 4 * td.d * td.d + 2 * td.d * td.ff + td.ff * td.d;
        let want = 4 * (td.vocab * td.d + td.d + td.layers * per_layer);
        assert_eq!(bytes, want);
    }
}
