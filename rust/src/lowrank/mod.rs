//! Native low-rank execution backend — rank-truncated factorized
//! inference in-process, no PJRT.
//!
//! This is the serving-side realization of the Dobi-SVD deliverable: a
//! model whose compression targets are stored as `W ≈ W1 W2` rank-k
//! factors (`W1 = U_k Σ_k^{1/2}`, `W2 = Σ_k^{1/2} V_kᵀ`, remap layout) and
//! *executed* in that form, so the FLOP reduction `2·k·(m+n)` vs `2·m·n`
//! is realized at inference time rather than only on disk — the point
//! SVD-LLM V2 makes about truncation needing to pay off at serve time.
//!
//! Layering:
//! * [`kernel`] — cache-blocked GEMM over f32/f16/int8 factors, decoded
//!   tile-by-tile through [`crate::quant`]; [`kernel::FactorizedLinear`].
//! * [`model`]  — [`model::FactorizedModel`], the full LLaMA-style forward
//!   (RMSNorm / RoPE / causal attention / SwiGLU / tied head, plus
//!   VLM prefix + VLA head) loadable from the `.dobiw` store.
//! * [`synth`]  — deterministic synthetic models/stores so tests and
//!   benches run without compiled artifacts.
//! * [`NativeBackend`] — the [`crate::runtime::Backend`] implementation
//!   the coordinator, eval harness, and CLI route to via `--backend`.

pub mod kernel;
pub mod model;
pub mod synth;

use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::config::Manifest;
use crate::runtime::{Backend, LoadStats, Loaded};

pub use kernel::{decode_threads, matmul, set_decode_threads, Factor, FactorData,
                 FactorizedLinear, Linear};
pub use model::{FactorizedModel, KvCache};

/// In-process factorized inference backend.
pub struct NativeBackend;

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native-lowrank"
    }

    /// `shapes` is ignored: the native forward is shape-agnostic, and the
    /// engine validates requested shapes against the manifest upstream.
    fn load_variant(&self, manifest: &Manifest, id: &str,
                    _shapes: Option<&[(usize, usize)]>) -> Result<Loaded> {
        let t0 = Instant::now();
        let v = manifest.variant(id)?;
        let info = manifest
            .models
            .get(&v.model)
            .ok_or_else(|| anyhow!("model `{}` missing from manifest", v.model))?;
        let store = manifest.open_store(v)?;
        let model = FactorizedModel::from_store(info, v, &store)?;
        let stats = LoadStats {
            weight_bytes: model.resident_bytes(),
            file_bytes: store.file_bytes,
            payload_bytes: store.payload_bytes(),
            load_weights_s: t0.elapsed().as_secs_f64(),
            compile_s: 0.0,
        };
        Ok(Loaded { model: Box::new(model), stats })
    }
}

#[cfg(test)]
mod tests {
    use super::synth::{tiny_manifest_json, tiny_store_tensors, SynthStyle, TinyDims};
    use super::*;
    use crate::storage::write_store;

    fn dims() -> TinyDims {
        TinyDims { vocab: 61, d: 16, heads: 2, layers: 2, ff: 24 }
    }

    fn artifacts(style: SynthStyle, tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("dobi_lowrank_backend_{tag}"));
        std::fs::create_dir_all(&dir).unwrap();
        let kind = if style == SynthStyle::DenseF32 { "dense" } else { "factorized" };
        write_store(&dir.join("w.dobiw"), &tiny_store_tensors(dims(), 0, style)).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            tiny_manifest_json(dims(), 0, &[("tiny/x", kind, 0.6, "w.dobiw")]),
        )
        .unwrap();
        dir
    }

    #[test]
    fn backend_loads_dense_store_and_serves() {
        let dir = artifacts(SynthStyle::DenseF32, "dense");
        let m = Manifest::load(&dir).unwrap();
        let loaded = NativeBackend.load_variant(&m, "tiny/x", None).unwrap();
        assert!(loaded.stats.weight_bytes > 0);
        let tokens: Vec<i32> = (0..32).map(|i| i % 61).collect();
        let out = loaded.model.forward(2, 16, &tokens, None).unwrap();
        assert_eq!(out.len(), 2 * 16 * 61);
        assert!(out.iter().all(|x| x.is_finite()));
        // shape-agnostic: the engine exact-sizes native batches, no padding
        assert!(loaded.model.shapes().is_empty());
    }

    #[test]
    fn backend_loads_quantized_factors_and_tracks_footprint() {
        let dense_dir = artifacts(SynthStyle::DenseF32, "dense2");
        let q8_dir = artifacts(SynthStyle::FactorQ8, "q8");
        let md = Manifest::load(&dense_dir).unwrap();
        let mq = Manifest::load(&q8_dir).unwrap();
        let dense = NativeBackend.load_variant(&md, "tiny/x", None).unwrap();
        let q8 = NativeBackend.load_variant(&mq, "tiny/x", None).unwrap();
        // int8 factors must be resident-smaller than the dense f32 twin
        assert!(q8.stats.weight_bytes < dense.stats.weight_bytes,
                "{} !< {}", q8.stats.weight_bytes, dense.stats.weight_bytes);
        // and still compute something close to it
        let tokens: Vec<i32> = (0..16).map(|i| (i * 3) % 61).collect();
        let a = dense.model.forward(1, 16, &tokens, None).unwrap();
        let b = q8.model.forward(1, 16, &tokens, None).unwrap();
        let max = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).fold(0f32, f32::max);
        assert!(max < 1.0, "quantized logits drifted by {max}");
        assert!(max > 0.0, "quantization should not be bit-exact");
    }

    #[test]
    fn backend_loads_f16_factors() {
        let dir = artifacts(SynthStyle::FactorF16, "f16");
        let m = Manifest::load(&dir).unwrap();
        let loaded = NativeBackend.load_variant(&m, "tiny/x", None).unwrap();
        let tokens: Vec<i32> = (0..16).collect();
        assert!(loaded.model.forward(1, 16, &tokens, None).unwrap()
            .iter().all(|x| x.is_finite()));
    }

    #[test]
    fn unknown_variant_fails() {
        let dir = artifacts(SynthStyle::DenseF32, "dense3");
        let m = Manifest::load(&dir).unwrap();
        assert!(NativeBackend.load_variant(&m, "tiny/nope", None).is_err());
    }
}
