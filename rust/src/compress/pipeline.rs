//! Whole-model native compression driver: dense `.dobiw` weights in,
//! rank-allocated remapped factors + a factor-only manifest out — the
//! Rust mirror of `python/compile/dobi/pipeline.py::dobi_compress`, end
//! to end: calibration → whitened truncation-position search → budgeted
//! rank allocation → IPCA weight reconstruction → remap quantization →
//! `.dobiw` writer.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::config::{AllocMode, CompressConfig, Precision};
use crate::json::Json;
use crate::lowrank::kernel::{Factor, FactorData, FactorizedLinear, Linear};
use crate::lowrank::model::{target_dims, LayerWeights, LAYER_MATS};
use crate::lowrank::FactorizedModel;
use crate::mathx::{self, XorShift};
use crate::metrics::{names as metric_names, Registry};
use crate::runtime::ForwardModel;
use crate::storage::{encode_store, f16_tensor, f32_tensor, hash, i8_tensor, write_store, Tensor};
use crate::trace::{phases, TraceBuffer};

use super::calib;
use super::rank::{whitener, RankAllocator, TargetSpectrum, Waterfill, Whitener};
use super::remap::reconstruct_factors;
use super::report::{RunReport, TargetReport};
use super::svd::{last_sweeps, set_svd_threads};
use super::train::{LearnedAlloc, TrainReport};

/// Trace/metrics/progress sinks for one `dobi compress` run.  The
/// compress pipeline records `compress_*` phase spans into `trace`,
/// emits `compress_*` metric families into `metrics`, and (optionally)
/// prints a line per phase to stderr.  The [`disabled`] form costs
/// nothing measurable: the ring is inert at capacity 0 and the phase
/// timing is a handful of `Instant` reads either way.
///
/// [`disabled`]: CompressTelemetry::disabled
pub struct CompressTelemetry {
    /// Ring the `compress_*` phase spans land in (export with
    /// `trace::export_chrome` for Perfetto).
    pub trace: Arc<TraceBuffer>,
    /// Registry the `compress_*` metric families are emitted into.
    pub metrics: Arc<Registry>,
    /// Emit a line per pipeline phase to stderr (`--progress`).
    pub progress: bool,
}

impl CompressTelemetry {
    /// Live telemetry with a trace ring of `trace_cap` events
    /// (0 keeps the ring inert, exactly like `--trace-buffer 0`).
    pub fn new(trace_cap: usize, progress: bool) -> CompressTelemetry {
        CompressTelemetry {
            trace: Arc::new(TraceBuffer::new(trace_cap)),
            metrics: Arc::new(Registry::default()),
            progress,
        }
    }

    /// Inert sinks — what the untraced [`compress_model`] wrapper uses.
    pub fn disabled() -> CompressTelemetry {
        CompressTelemetry::new(0, false)
    }
}

/// Everything `dobi compress` produces for one model: the store tensors,
/// the rank plan and its accounting, and an in-memory f32-factor twin
/// (the "directly factorized" reference the round-trip parity tests
/// compare the reloaded store against).
pub struct CompressedArtifact {
    pub model_name: String,
    pub variant_id: String,
    pub tensors: Vec<Tensor>,
    pub ranks: BTreeMap<String, usize>,
    pub spectra: Vec<TargetSpectrum>,
    pub total_params: usize,
    pub fixed_params: usize,
    /// Remapped stored-parameter accounting: fixed + sum k·max(m, n).
    pub stored_params: usize,
    pub achieved_ratio: f64,
    pub payload_bytes: usize,
    pub reference: FactorizedModel,
    /// Rank-allocation mode that produced the plan ("waterfill"/"learned").
    pub alloc: String,
    /// Optimizer diagnostics when the learned allocator ran.
    pub train_report: Option<TrainReport>,
    /// The full knob set that produced this artifact — stamped verbatim
    /// into the release's provenance block.
    pub config: CompressConfig,
    /// The structured run record the artifact writers persist as
    /// `<variant>.run.json` (the write phase is appended at write time).
    pub run_report: RunReport,
}

fn dense_weight(lin: &Linear, id: &str) -> Result<Vec<f32>> {
    match lin {
        Linear::Dense { w, .. } => Ok(w.to_f32()),
        Linear::LowRank(_) => bail!(
            "{id}: `{}` is already factorized — compress expects a dense source variant",
            lin.name()
        ),
    }
}

/// Push the storage tensors of one factor pair at the requested precision,
/// using exactly the layout `aot._arrays_from_store` / the native loader
/// expect: plain `<f>.w1`/`<f>.w2` tensors, or `.q8` + `.scales` pairs
/// with W1 per-column (1, k) and W2 per-row (k, 1) scales.
fn push_factor_tensors(out: &mut Vec<Tensor>, name: &str, w1: &[f32], w2: &[f32],
                       m: usize, n: usize, k: usize, precision: Precision) {
    match precision {
        Precision::F32 => {
            out.push(f32_tensor(&format!("{name}.w1"), vec![m, k], w1));
            out.push(f32_tensor(&format!("{name}.w2"), vec![k, n], w2));
        }
        Precision::F16 => {
            out.push(f16_tensor(&format!("{name}.w1"), vec![m, k], w1));
            out.push(f16_tensor(&format!("{name}.w2"), vec![k, n], w2));
        }
        Precision::Q8 => {
            let f1 = Factor::i8_cols_from_f32(m, k, w1);
            let f2 = Factor::i8_rows_from_f32(k, n, w2);
            for (fname, f, scale_shape) in [
                (format!("{name}.w1"), f1, vec![1, k]),
                (format!("{name}.w2"), f2, vec![k, 1]),
            ] {
                let (rows, cols) = (f.rows, f.cols);
                if let FactorData::I8 { codes, scales, .. } = f.data {
                    out.push(i8_tensor(&format!("{fname}.q8"), vec![rows, cols], &codes));
                    out.push(f32_tensor(&format!("{fname}.scales"), scale_shape, &scales));
                }
            }
        }
    }
}

/// Compress a dense model: calibrate, search truncation positions under
/// the global budget (greedy waterfill or the learned differentiable
/// optimizer, per `cfg.alloc`), reconstruct weights from truncated
/// activations, and emit remap-quantized store tensors plus the in-memory
/// reference twin.  Untraced convenience wrapper over
/// [`compress_model_traced`] with inert telemetry.
pub fn compress_model(dense: &FactorizedModel, model_name: &str, cfg: &CompressConfig,
                      calib_tokens: &[i32]) -> Result<CompressedArtifact> {
    compress_model_traced(dense, model_name, cfg, calib_tokens, &CompressTelemetry::disabled())
}

/// [`compress_model`] with live telemetry: every pipeline phase lands in
/// the trace ring as a `compress_*` span (per-target SVD and remap spans,
/// learned-alloc iterations replayed as instants from the train
/// trajectory), the `compress_*` metric families are emitted, and the
/// returned artifact carries the structured [`RunReport`].
pub fn compress_model_traced(dense: &FactorizedModel, model_name: &str, cfg: &CompressConfig,
                             calib_tokens: &[i32],
                             tel: &CompressTelemetry) -> Result<CompressedArtifact> {
    anyhow::ensure!(cfg.ratio > 0.0 && cfg.ratio <= 1.0,
                    "ratio {} outside (0, 1]", cfg.ratio);
    // Jacobi sweep workers for every SVD this run performs (whitened
    // spectra + IPCA folds); results are bit-identical at any count.
    set_svd_threads(cfg.svd_threads);
    let run_start = Instant::now();
    let phase_obs = |name: &'static str, d: Duration| {
        tel.metrics
            .histogram_with(metric_names::COMPRESS_PHASE_SECONDS, &[("phase", name)])
            .observe(d);
    };
    let d = dense.d_model;
    let ff = dense.d_ff;

    // Target inventory + dense weights (manifest order).
    let mut names = Vec::new();
    let mut weights = Vec::new();
    let mut dims = Vec::new();
    for (li, layer) in dense.layers.iter().enumerate() {
        for (mat, lin) in LAYER_MATS.iter().zip(layer.mats()) {
            let name = format!("layers.{li}.{mat}");
            weights.push(dense_weight(lin, &name)?);
            dims.push(target_dims(mat, d, ff));
            names.push(name);
        }
    }
    let target_params: usize = dims.iter().map(|&(m, n)| m * n).sum();
    let fixed_params = count_fixed_params(dense);
    let total_params = fixed_params + target_params;
    if tel.progress {
        eprintln!("[compress] inventory: {} targets, {} total params", names.len(), total_params);
    }

    // Calibration + whitened truncation-loss spectra.  Targets that
    // multiply the same activations (wq/wk/wv; w_gate/w_up) share one
    // whitener — the Gram + Cholesky is the expensive part of scoring.
    let calib_start = Instant::now();
    let cal = calib::collect(dense, calib_tokens, cfg.calib_batches, cfg.calib_batch,
                             cfg.calib_seq, cfg.seed)?;
    let calib_end = Instant::now();
    tel.trace.push_span(phases::COMPRESS_CALIB, 0, calib_start, calib_end, || {
        format!("batches={} batch={} seq={}", cfg.calib_batches, cfg.calib_batch, cfg.calib_seq)
    });
    phase_obs(phases::COMPRESS_CALIB, calib_end - calib_start);
    let calib_secs = (calib_end - calib_start).as_secs_f64();
    if tel.progress {
        eprintln!("[compress] calib: {} windows of {}x{} in {calib_secs:.3}s",
                  cfg.calib_batches, cfg.calib_batch, cfg.calib_seq);
    }

    let mut whiteners: BTreeMap<String, Whitener> = BTreeMap::new();
    let mut spectra = Vec::with_capacity(names.len());
    // (sweeps, seconds) of each target's spectrum SVD, manifest order —
    // joined into the run report's per-target table by the remap loop.
    let mut svd_meta: Vec<(usize, f64)> = Vec::with_capacity(names.len());
    let mut whiten_secs = 0f64;
    let mut svd_secs = 0f64;
    for ((name, w), &(m, n)) in names.iter().zip(&weights).zip(&dims) {
        let key = calib::tap_key(name);
        if !whiteners.contains_key(&key) {
            let t = Instant::now();
            let built = whitener(cal.batches(name), m);
            let end = Instant::now();
            whiten_secs += (end - t).as_secs_f64();
            let tap = key.clone();
            tel.trace.push_span(phases::COMPRESS_WHITEN, 0, t, end,
                                || format!("tap={tap} m={m}"));
            whiteners.insert(key.clone(), built);
        }
        let wh = whiteners.get(&key).ok_or_else(|| anyhow!("whitener for `{key}` vanished"))?;
        let t = Instant::now();
        let spec = wh.spectrum(name, w, n)?;
        let end = Instant::now();
        let sweeps = last_sweeps();
        let sec = (end - t).as_secs_f64();
        svd_secs += sec;
        svd_meta.push((sweeps, sec));
        tel.trace.push_span(phases::COMPRESS_SVD, 0, t, end, || {
            format!("target={name} dims={m}x{n} sweeps={sweeps} threads={}", cfg.svd_threads)
        });
        tel.metrics
            .counter_with(metric_names::COMPRESS_SVD_SWEEPS, &[("target", name)])
            .add(sweeps as u64);
        spectra.push(spec);
    }
    phase_obs(phases::COMPRESS_WHITEN, Duration::from_secs_f64(whiten_secs));
    phase_obs(phases::COMPRESS_SVD, Duration::from_secs_f64(svd_secs));
    if tel.progress {
        eprintln!("[compress] spectra: {} targets (whiten {whiten_secs:.3}s, svd {svd_secs:.3}s)",
                  names.len());
    }

    // Global budget (stored params, remapped accounting) -> per-target
    // ranks, through the configured allocator behind the one
    // `RankAllocator` trait.  The learned impl additionally parks its
    // optimizer diagnostics, drained here for the CLI/bench reports.
    let budget = cfg.budget.unwrap_or((cfg.ratio * total_params as f64).round() as usize);
    let target_budget = budget.saturating_sub(fixed_params);
    let learned = match cfg.alloc {
        AllocMode::Learned => Some(LearnedAlloc::new(cfg.train_iters, cfg.train_lr)),
        AllocMode::Waterfill => None,
    };
    let allocator: &dyn RankAllocator =
        learned.as_ref().map(|l| l as &dyn RankAllocator).unwrap_or(&Waterfill);
    debug_assert_eq!(allocator.name(), cfg.alloc.to_string());
    let alloc_start = Instant::now();
    let (ks, _) = allocator.allocate(&spectra, target_budget, cfg.k_min);
    let alloc_end = Instant::now();
    let train_report: Option<TrainReport> = learned.as_ref().and_then(|l| l.take_report());
    tel.trace.push_span(phases::COMPRESS_ALLOC, 0, alloc_start, alloc_end, || {
        format!("mode={} budget={target_budget} k_min={}", cfg.alloc, cfg.k_min)
    });
    phase_obs(phases::COMPRESS_ALLOC, alloc_end - alloc_start);
    let alloc_secs = (alloc_end - alloc_start).as_secs_f64();
    if let Some(r) = &train_report {
        // Replay the optimizer trajectory into the ring as zero-width
        // spans at their measured offsets — the allocator stays trace-
        // agnostic behind the `RankAllocator` trait, yet Perfetto shows
        // each sampled iteration inside the `compress_alloc` envelope.
        for s in &r.trajectory {
            let at = alloc_start + Duration::from_micros(s.t_us);
            tel.trace.push_span(phases::COMPRESS_TRAIN_ITER, 0, at, at, || {
                format!("iter={} tail={:.6} lambda={:.4} tau={:.4} expected_cost={:.1}",
                        s.iter, s.tail, s.lambda, s.tau, s.expected_cost)
            });
        }
    }
    if tel.progress {
        eprintln!("[compress] alloc: mode {} in {alloc_secs:.3}s", cfg.alloc);
    }

    // Reconstruct + quantize each target; assemble the reference twin.
    let codec = match cfg.precision {
        Precision::F32 => "f32",
        Precision::F16 => "f16",
        Precision::Q8 => "q8",
    };
    let mut tensors = Vec::new();
    tensors.push(f32_tensor("embed", vec![dense.vocab, d], &dense.embed));
    let mut ranks = BTreeMap::new();
    let mut stored_params = fixed_params;
    let mut ref_layers = Vec::with_capacity(dense.layers.len());
    let mut target_rows: Vec<TargetReport> = Vec::with_capacity(names.len());
    let mut remap_secs = 0f64;
    let mut ti = 0usize;
    for (li, layer) in dense.layers.iter().enumerate() {
        tensors.push(f32_tensor(&format!("layers.{li}.attn_norm"), vec![d], &layer.attn_norm));
        tensors.push(f32_tensor(&format!("layers.{li}.mlp_norm"), vec![d], &layer.mlp_norm));
        let mut mats: Vec<Linear> = Vec::with_capacity(7);
        for _ in LAYER_MATS {
            let name = &names[ti];
            let (m, n) = dims[ti];
            let t = Instant::now();
            let (w1, w2, k) = reconstruct_factors(&weights[ti], m, n,
                                                  cal.batches(name), ks[ti]);
            push_factor_tensors(&mut tensors, name, &w1, &w2, m, n, k, cfg.precision);
            let end = Instant::now();
            remap_secs += (end - t).as_secs_f64();
            tel.trace.push_span(phases::COMPRESS_REMAP, 0, t, end,
                                || format!("target={name} rank={k} codec={codec}"));
            let tail = spectra[ti].loss_at(k);
            let err = recon_error(&weights[ti], &w1, &w2, m, n, k);
            tel.metrics
                .gauge_with(metric_names::COMPRESS_RANK_KEPT, &[("target", name)])
                .set(k as i64);
            tel.metrics
                .histogram(metric_names::COMPRESS_TAIL_ENERGY_RATE)
                .observe_value(tail);
            let (svd_sweeps, svd_seconds) = svd_meta[ti];
            target_rows.push(TargetReport {
                name: name.clone(),
                m,
                n,
                rank: k,
                max_rank: spectra[ti].max_rank(),
                tail_energy: tail,
                recon_error: err,
                svd_sweeps,
                svd_seconds,
                codec: codec.to_string(),
            });
            mats.push(Linear::LowRank(FactorizedLinear::new(
                name, Factor::f32(m, k, w1), Factor::f32(k, n, w2))?));
            ranks.insert(name.clone(), k);
            stored_params += k * m.max(n);
            ti += 1;
        }
        let mut it = mats.into_iter();
        ref_layers.push(LayerWeights {
            attn_norm: layer.attn_norm.clone(),
            mlp_norm: layer.mlp_norm.clone(),
            wq: it.next().unwrap(),
            wk: it.next().unwrap(),
            wv: it.next().unwrap(),
            wo: it.next().unwrap(),
            w_gate: it.next().unwrap(),
            w_up: it.next().unwrap(),
            w_down: it.next().unwrap(),
        });
    }
    tensors.push(f32_tensor("final_norm", vec![d], &dense.final_norm));
    if let Some(proj) = &dense.img_proj {
        tensors.push(f32_tensor("img_proj",
                                vec![dense.img_dim, dense.n_img_tokens * d], proj));
    }
    if let Some(head) = &dense.act_head {
        tensors.push(f32_tensor("act_head", vec![d, 5], head));
    }

    // Name by the effective target ratio so `--budget` runs are labeled
    // truthfully rather than inheriting the unused default `--ratio`.
    // Learned-allocation variants carry a `-learned` tag so both modes of
    // the same ratio can coexist in one appended manifest.
    let name_ratio = match cfg.budget {
        Some(b) => b as f64 / total_params as f64,
        None => cfg.ratio,
    };
    let alloc_tag = match cfg.alloc {
        AllocMode::Waterfill => "",
        AllocMode::Learned => "-learned",
    };
    let variant_id = format!("{model_name}/dobi{alloc_tag}_{:.0}", name_ratio * 100.0);
    phase_obs(phases::COMPRESS_REMAP, Duration::from_secs_f64(remap_secs));
    tel.metrics
        .counter_with(metric_names::COMPRESS_TARGETS, &[("variant", &variant_id)])
        .add(names.len() as u64);
    if let Some(r) = &train_report {
        tel.metrics
            .counter_with(metric_names::COMPRESS_TRAIN_ITERS, &[("variant", &variant_id)])
            .add(r.iters as u64);
    }
    let run_end = Instant::now();
    let total_seconds = (run_end - run_start).as_secs_f64();
    {
        let vid = variant_id.clone();
        let n_targets = names.len();
        tel.trace.push_span(phases::COMPRESS_RUN, 0, run_start, run_end,
                            || format!("variant={vid} targets={n_targets}"));
    }
    let mut run_report = RunReport {
        variant_id: variant_id.clone(),
        model: model_name.to_string(),
        alloc: cfg.alloc.to_string(),
        writer: "dobi-native".into(),
        format: "DOBIW1".into(),
        crate_version: env!("CARGO_PKG_VERSION").into(),
        config: cfg.to_json(),
        total_seconds,
        phases: Vec::new(),
        targets: target_rows,
        train: train_report.clone(),
    };
    run_report.push_phase(phases::COMPRESS_CALIB, calib_secs);
    run_report.push_phase(phases::COMPRESS_WHITEN, whiten_secs);
    run_report.push_phase(phases::COMPRESS_SVD, svd_secs);
    run_report.push_phase(phases::COMPRESS_ALLOC, alloc_secs);
    run_report.push_phase(phases::COMPRESS_REMAP, remap_secs);
    if tel.progress {
        eprintln!("[compress] done: {variant_id} in {total_seconds:.3}s \
                   (stored {stored_params}/{total_params} params)");
    }
    let payload_bytes = tensors.iter().map(|t| t.data.len()).sum();
    let reference = FactorizedModel {
        id: variant_id.clone(),
        vocab: dense.vocab,
        d_model: d,
        n_heads: dense.n_heads,
        d_ff: ff,
        img_dim: dense.img_dim,
        n_img_tokens: dense.n_img_tokens,
        action_head: dense.action_head,
        embed: dense.embed.clone(),
        final_norm: dense.final_norm.clone(),
        layers: ref_layers,
        img_proj: dense.img_proj.clone(),
        act_head: dense.act_head.clone(),
    };
    Ok(CompressedArtifact {
        model_name: model_name.to_string(),
        variant_id,
        tensors,
        ranks,
        spectra,
        total_params,
        fixed_params,
        stored_params,
        achieved_ratio: stored_params as f64 / total_params as f64,
        payload_bytes,
        reference,
        alloc: cfg.alloc.to_string(),
        train_report,
        config: cfg.clone(),
        run_report,
    })
}

/// Relative Frobenius reconstruction error `‖W − W1·W2‖_F / ‖W‖_F` of one
/// target's f32 factor pair (pre-quantization), f64 accumulation.
fn recon_error(w: &[f32], w1: &[f32], w2: &[f32], m: usize, n: usize, k: usize) -> f64 {
    let mut num = 0f64;
    let mut den = 0f64;
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0f64;
            for t in 0..k {
                acc += w1[i * k + t] as f64 * w2[t * n + j] as f64;
            }
            let wv = w[i * n + j] as f64;
            let diff = wv - acc;
            num += diff * diff;
            den += wv * wv;
        }
    }
    if den > 0.0 { (num / den).sqrt() } else { 0.0 }
}

fn count_fixed_params(m: &FactorizedModel) -> usize {
    let mut fixed = m.embed.len() + m.final_norm.len();
    for l in &m.layers {
        fixed += l.attn_norm.len() + l.mlp_norm.len();
    }
    fixed += m.img_proj.as_ref().map_or(0, |v| v.len());
    fixed += m.act_head.as_ref().map_or(0, |v| v.len());
    fixed
}

// ---------------------------------------------------------------------------
// Artifacts-dir writer (store + factor-only manifest)
// ---------------------------------------------------------------------------

fn jnum(x: usize) -> Json {
    Json::Num(x as f64)
}

/// The `models.<name>` manifest entry for this artifact.
fn model_json(art: &CompressedArtifact) -> Json {
    let m = &art.reference;
    let config = Json::obj(vec![
        ("vocab", jnum(m.vocab)),
        ("d_model", jnum(m.d_model)),
        ("n_layers", jnum(m.layers.len())),
        ("n_heads", jnum(m.n_heads)),
        ("d_ff", jnum(m.d_ff)),
        ("img_dim", jnum(m.img_dim)),
        ("n_img_tokens", jnum(m.n_img_tokens)),
        ("action_head", Json::Bool(m.action_head)),
    ]);
    Json::obj(vec![
        ("config", config),
        ("total_params", jnum(art.total_params)),
        ("fixed_params", jnum(art.fixed_params)),
    ])
}

/// The provenance block stamped into the variant entry: the content hash
/// of the exact container bytes the writer emits (deterministic encode —
/// see `storage::encode_store`), per-tensor section hashes, the full
/// `CompressConfig` dump, and the writer's identity.  Loads re-hash the
/// on-disk store against this pin and refuse mismatches.
fn provenance_json(art: &CompressedArtifact) -> Json {
    let raw = encode_store(&art.tensors);
    let tensors: BTreeMap<String, Json> = art
        .tensors
        .iter()
        .map(|t| (t.name.clone(), Json::Str(hash::sha256_hex(&t.data))))
        .collect();
    Json::obj(vec![
        ("store_sha256", Json::Str(hash::sha256_hex(&raw))),
        ("tensors", Json::Obj(tensors)),
        ("config", art.config.to_json()),
        ("toolchain", Json::obj(vec![
            ("writer", Json::Str("dobi-native".into())),
            ("format", Json::Str("DOBIW1".into())),
            ("crate_version", Json::Str(env!("CARGO_PKG_VERSION").into())),
        ])),
    ])
}

/// The factor-only variant entry: an **empty** `hlo` map — served
/// natively at any shape via the router's any-seq mode, no phantom HLO
/// entries.
fn variant_json(art: &CompressedArtifact, weights_file: &str) -> Json {
    let ranks = Json::Obj(art.ranks.iter().map(|(k, &v)| (k.clone(), jnum(v))).collect());
    Json::obj(vec![
        ("id", Json::Str(art.variant_id.clone())),
        ("model", Json::Str(art.model_name.clone())),
        ("method", Json::Str("dobi".into())),
        ("ratio", Json::Num(art.achieved_ratio)),
        ("kind", Json::Str("factorized".into())),
        ("kernel", Json::Str("native".into())),
        ("weights", Json::Str(weights_file.into())),
        ("param_names", Json::Arr(Vec::new())),
        ("hlo", Json::Obj(BTreeMap::new())),
        ("inputs", Json::Arr(vec![Json::Str("tokens".into())])),
        ("stored_params", jnum(art.stored_params)),
        ("bytes", jnum(art.payload_bytes)),
        ("ref_ppl", Json::Obj(BTreeMap::new())),
        ("ranks", ranks),
        ("alloc", Json::Str(art.alloc.clone())),
        ("provenance", provenance_json(art)),
        ("run_report", Json::Str(RunReport::file_name(&art.variant_id))),
    ])
}

/// Manifest JSON for a standalone compressed artifacts dir: one model,
/// one factor-only variant.
pub fn manifest_json(art: &CompressedArtifact, weights_file: &str,
                     eval_batch: usize, eval_seq: usize) -> String {
    Json::obj(vec![
        ("profile", Json::Str("native-compress".into())),
        ("models", Json::Obj(BTreeMap::from([(art.model_name.clone(), model_json(art))]))),
        ("variants", Json::Arr(vec![variant_json(art, weights_file)])),
        ("corpora", Json::Obj(BTreeMap::new())),
        ("eval", Json::obj(vec![
            ("batch", jnum(eval_batch)),
            ("seq", jnum(eval_seq)),
            ("windows", jnum(1)),
        ])),
    ])
    .to_string()
}

/// Write a self-contained artifacts dir (`manifest.json` + the compressed
/// `.dobiw` store + the `<variant>.run.json` run report) loadable by
/// `Manifest::load` + the native backend.
/// Deliberately does NOT garbage-collect stores a previous manifest in
/// the dir referenced: an accidental `--out` into a populated artifacts
/// dir already clobbers the manifest, but the store files stay
/// recoverable on disk — deleting them is reserved for the explicit
/// `--replace` path and [`gc_orphan_stores`].  Returns the weights path.
pub fn write_artifacts(dir: &Path, art: &CompressedArtifact) -> Result<PathBuf> {
    write_artifacts_traced(dir, art, &CompressTelemetry::disabled())
}

/// [`write_artifacts`] with telemetry: the write lands in the trace ring
/// as a `compress_write` span and in the phase-seconds histogram.
pub fn write_artifacts_traced(dir: &Path, art: &CompressedArtifact,
                              tel: &CompressTelemetry) -> Result<PathBuf> {
    let t = Instant::now();
    std::fs::create_dir_all(dir)
        .map_err(|e| anyhow!("creating {}: {e}", dir.display()))?;
    let weights_file = format!("{}.dobiw", art.variant_id.replace('/', "_"));
    let wpath = dir.join(&weights_file);
    write_store(&wpath, &art.tensors)?;
    std::fs::write(dir.join("manifest.json"), manifest_json(art, &weights_file, 2, 16))
        .map_err(|e| anyhow!("writing manifest: {e}"))?;
    let end = Instant::now();
    record_write(dir, art, tel, t, end)?;
    Ok(wpath)
}

/// Shared tail of both writers: the `compress_write` span + phase metric,
/// and the `<variant>.run.json` persistence with the write phase folded
/// into the report's shares.
fn record_write(dir: &Path, art: &CompressedArtifact, tel: &CompressTelemetry,
                start: Instant, end: Instant) -> Result<()> {
    let bytes = art.payload_bytes;
    tel.trace.push_span(phases::COMPRESS_WRITE, 0, start, end,
                        || format!("dir={} bytes={bytes}", dir.display()));
    tel.metrics
        .histogram_with(metric_names::COMPRESS_PHASE_SECONDS,
                        &[("phase", phases::COMPRESS_WRITE)])
        .observe(end - start);
    let write_secs = (end - start).as_secs_f64();
    let mut report = art.run_report.clone();
    report.push_phase(phases::COMPRESS_WRITE, write_secs);
    report.total_seconds += write_secs;
    let rpath = dir.join(RunReport::file_name(&art.variant_id));
    std::fs::write(&rpath, report.to_json().to_string())
        .map_err(|e| anyhow!("writing run report {}: {e}", rpath.display()))?;
    if tel.progress {
        eprintln!("[compress] write: store + manifest + run report in {write_secs:.3}s");
    }
    Ok(())
}

/// Delete `.dobiw` stores in `dir` that no variant of its manifest
/// references — the leak left behind when a variant is replaced (or a
/// standalone `write_artifacts` overwrites an older manifest).  Only
/// top-level `.dobiw` files are candidates; anything a variant's
/// `weights` field names (by relative path or bare file name) survives.
/// Returns the deleted paths.
pub fn gc_orphan_stores(dir: &Path) -> Result<Vec<PathBuf>> {
    let m = crate::json::load(&dir.join("manifest.json"))?;
    let mut referenced = std::collections::BTreeSet::new();
    for v in m.get("variants").and_then(Json::as_arr).into_iter().flatten() {
        if let Some(w) = v.get("weights").and_then(Json::as_str) {
            referenced.insert(w.to_string());
            if let Some(name) = Path::new(w).file_name().and_then(|f| f.to_str()) {
                referenced.insert(name.to_string());
            }
        }
    }
    let mut removed = Vec::new();
    let entries = std::fs::read_dir(dir)
        .map_err(|e| anyhow!("reading {}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| anyhow!("reading {}: {e}", dir.display()))?;
        let path = entry.path();
        let is_store = path.is_file()
            && path.extension().and_then(|e| e.to_str()) == Some("dobiw");
        if !is_store {
            continue;
        }
        let name = match path.file_name().and_then(|f| f.to_str()) {
            Some(n) => n.to_string(),
            None => continue,
        };
        if !referenced.contains(&name) {
            std::fs::remove_file(&path)
                .map_err(|e| anyhow!("removing orphan {}: {e}", path.display()))?;
            removed.push(path);
        }
    }
    Ok(removed)
}

/// Append the compressed variant to an **existing** artifacts dir: write
/// the store beside the resident ones and merge the manifest in place —
/// the variant list gains one entry, the model entry is added if absent
/// (and shape-checked when present), every other manifest field (corpora,
/// eval, suites, other models/variants) is preserved byte-for-byte at the
/// JSON level.  Dense and compressed variants then serve from a single
/// manifest.  Duplicate variant ids are refused; see
/// [`append_artifacts_opts`] for the explicit-replacement mode.  Returns
/// the weights path.
pub fn append_artifacts(dir: &Path, art: &CompressedArtifact) -> Result<PathBuf> {
    append_artifacts_opts(dir, art, false)
}

/// [`append_artifacts`] with replacement: when `replace` is set and the
/// manifest already carries the variant id, the resident entry is swapped
/// for the new one and any store file the replacement orphaned is
/// garbage-collected ([`gc_orphan_stores`]) — re-compressing at the same
/// ratio no longer leaks the superseded `.dobiw` on disk.
pub fn append_artifacts_opts(dir: &Path, art: &CompressedArtifact,
                             replace: bool) -> Result<PathBuf> {
    append_artifacts_traced(dir, art, replace, &CompressTelemetry::disabled())
}

/// [`append_artifacts_opts`] with telemetry — see [`write_artifacts_traced`].
pub fn append_artifacts_traced(dir: &Path, art: &CompressedArtifact, replace: bool,
                               tel: &CompressTelemetry) -> Result<PathBuf> {
    let t0 = Instant::now();
    let mpath = dir.join("manifest.json");
    anyhow::ensure!(mpath.exists(),
                    "--append expects an existing artifacts dir (no {})", mpath.display());
    let doc = crate::json::load(&mpath)?;
    let Json::Obj(mut root) = doc else { bail!("manifest root must be an object") };

    // Variant ids are unique per manifest: re-compressing at the same
    // ratio must be an explicit overwrite decision (--replace), not a
    // silent dup.
    let mut variants = match root.remove("variants") {
        Some(Json::Arr(v)) => v,
        _ => bail!("manifest has no `variants` array"),
    };
    let resident =
        variants.iter().any(|v| v.get("id").and_then(Json::as_str) == Some(&art.variant_id));
    if resident && !replace {
        bail!("variant `{}` already in {} (pick another --ratio/--budget, pass \
               --replace to swap it, or write a standalone dir with --out)",
              art.variant_id, mpath.display());
    }
    if resident {
        variants.retain(|v| v.get("id").and_then(Json::as_str) != Some(&art.variant_id));
    }

    // Model entry: insert, or verify the resident one matches our source.
    let mut models = match root.remove("models") {
        Some(Json::Obj(m)) => m,
        _ => bail!("manifest has no `models` object"),
    };
    match models.get(&art.model_name) {
        None => {
            models.insert(art.model_name.clone(), model_json(art));
        }
        Some(existing) => {
            let c = existing
                .get("config")
                .ok_or_else(|| anyhow!("model `{}`: no config", art.model_name))?;
            let m = &art.reference;
            for (key, want) in [("vocab", m.vocab), ("d_model", m.d_model),
                                ("n_layers", m.layers.len()), ("n_heads", m.n_heads),
                                ("d_ff", m.d_ff)] {
                // non-panicking read: a hand-edited/foreign manifest with a
                // missing or non-numeric field is a merge refusal, not a crash
                let have = c.get(key).and_then(Json::as_usize);
                anyhow::ensure!(have == Some(want),
                                "model `{}` in the resident manifest has {key}={have:?}, \
                                 compressed source has {want} — refusing to merge",
                                art.model_name);
            }
        }
    }

    let weights_file = format!("{}.dobiw", art.variant_id.replace('/', "_"));
    let wpath = dir.join(&weights_file);
    write_store(&wpath, &art.tensors)?;
    variants.push(variant_json(art, &weights_file));
    root.insert("models".into(), Json::Obj(models));
    root.insert("variants".into(), Json::Arr(variants));
    std::fs::write(&mpath, Json::Obj(root).to_string())
        .map_err(|e| anyhow!("writing manifest: {e}"))?;
    if resident {
        // The replaced entry may have pointed at a differently-named
        // store (foreign naming scheme, pre-rename manifest): collect it.
        gc_orphan_stores(dir)?;
    }
    record_write(dir, art, tel, t0, Instant::now())?;
    Ok(wpath)
}

/// Mean LM cross-entropy over `n_windows` deterministic (b, s) windows of
/// `tokens` — the eval-loss scalar the round-trip parity tests compare
/// between the reloaded store and the in-memory reference.
pub fn eval_loss<M: ForwardModel>(model: &M, tokens: &[i32], b: usize, s: usize,
                                  n_windows: usize, seed: u64) -> Result<f64> {
    let mut rng = XorShift::new(seed);
    let vocab = model.vocab();
    let mut total = 0f64;
    for _ in 0..n_windows {
        let toks = calib::sample_windows(tokens, b, s, &mut rng)?;
        let logits = model.forward(b, s, &toks, None)?;
        total += mathx::lm_cross_entropy(&logits, &toks, b, s, vocab) as f64;
    }
    Ok(total / n_windows as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Manifest;
    use crate::lowrank::synth::{tiny_model, TinyDims};

    fn dims() -> TinyDims {
        TinyDims { vocab: 61, d: 16, heads: 2, layers: 2, ff: 24 }
    }

    fn cfg(ratio: f64, precision: Precision) -> CompressConfig {
        CompressConfig {
            ratio,
            precision,
            calib_batches: 3,
            calib_batch: 2,
            calib_seq: 12,
            ..Default::default()
        }
    }

    fn corpus() -> Vec<i32> {
        super::super::calib::synth_calib_tokens(61, 600, 17)
    }

    #[test]
    fn compress_meets_budget_and_builds_reference() {
        let dense = tiny_model(dims(), 0, false);
        let art = compress_model(&dense, "tiny", &cfg(0.4, Precision::Q8), &corpus()).unwrap();
        assert_eq!(art.ranks.len(), 7 * dims().layers);
        let budget = (0.4 * art.total_params as f64).round() as usize;
        assert!(art.stored_params <= budget,
                "stored {} over budget {budget}", art.stored_params);
        assert!(art.achieved_ratio > 0.05, "suspiciously tiny ratio");
        assert!(art.ranks.values().all(|&k| k >= 1));
        // reference twin serves and has the allocated ranks
        for layer in &art.reference.layers {
            for lin in layer.mats() {
                assert_eq!(lin.rank(), art.ranks[lin.name()], "{}", lin.name());
            }
        }
        let tokens: Vec<i32> = (0..24).map(|i| i % 61).collect();
        let out = art.reference.forward(2, 12, &tokens, None).unwrap();
        assert!(out.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn higher_ratio_buys_rank_and_keeps_quality() {
        let dense = tiny_model(dims(), 0, false);
        let toks = corpus();
        let lo = compress_model(&dense, "tiny", &cfg(0.3, Precision::F32), &toks).unwrap();
        let hi = compress_model(&dense, "tiny", &cfg(0.6, Precision::F32), &toks).unwrap();
        assert!(hi.stored_params > lo.stored_params,
                "0.6 must store more than 0.3: {} vs {}", hi.stored_params, lo.stored_params);
        let sum = |a: &CompressedArtifact| a.ranks.values().sum::<usize>();
        assert!(sum(&hi) > sum(&lo), "larger budget must buy rank somewhere");
        let l_lo = eval_loss(&lo.reference, &toks, 2, 12, 4, 3).unwrap();
        let l_hi = eval_loss(&hi.reference, &toks, 2, 12, 4, 3).unwrap();
        let l_dense = eval_loss(&dense, &toks, 2, 12, 4, 3).unwrap();
        assert!(l_hi <= l_lo + 0.1, "more budget hurt: {l_hi} vs {l_lo}");
        assert!(l_dense <= l_lo + 0.1, "dense must be best: {l_dense} vs {l_lo}");
    }

    #[test]
    fn explicit_budget_overrides_ratio() {
        let dense = tiny_model(dims(), 0, false);
        let mut c = cfg(0.9, Precision::F32);
        let total = 61 * 16 + 16 + 2 * (2 * 16 + 4 * 16 * 16 + 3 * 16 * 24);
        c.budget = Some(total * 3 / 10);
        let art = compress_model(&dense, "tiny", &c, &corpus()).unwrap();
        assert_eq!(art.total_params, total);
        assert!(art.stored_params <= total * 3 / 10,
                "stored {} over explicit budget {}", art.stored_params, total * 3 / 10);
    }

    #[test]
    fn rejects_factorized_source_and_bad_ratio() {
        let fact = tiny_model(dims(), 0, true);
        assert!(compress_model(&fact, "tiny", &cfg(0.4, Precision::Q8), &corpus()).is_err());
        let dense = tiny_model(dims(), 0, false);
        assert!(compress_model(&dense, "tiny", &cfg(0.0, Precision::Q8), &corpus()).is_err());
        assert!(compress_model(&dense, "tiny", &cfg(1.5, Precision::Q8), &corpus()).is_err());
    }

    #[test]
    fn artifacts_dir_loads_through_manifest() {
        let dense = tiny_model(dims(), 0, false);
        let art = compress_model(&dense, "tiny", &cfg(0.5, Precision::Q8), &corpus()).unwrap();
        let dir = std::env::temp_dir().join("dobi_compress_pipe_manifest");
        let _ = std::fs::remove_dir_all(&dir);
        write_artifacts(&dir, &art).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.profile, "native-compress");
        let v = m.variant("tiny/dobi_50").unwrap();
        assert!(v.hlo.is_empty(), "factor-only manifest must carry no HLO entries");
        assert_eq!(v.kind, "factorized");
        assert_eq!(v.stored_params, art.stored_params);
        assert_eq!(v.ranks.len(), art.ranks.len());
        assert!(m.path(&v.weights).exists());
        let info = &m.models["tiny"];
        assert_eq!(info.vocab, 61);
        assert_eq!(info.d_model, 16);
        assert_eq!(info.n_layers, 2);
    }

    #[test]
    fn append_merges_variants_into_one_manifest() {
        let dense = tiny_model(dims(), 0, false);
        let toks = corpus();
        let a40 = compress_model(&dense, "tiny", &cfg(0.4, Precision::Q8), &toks).unwrap();
        let a60 = compress_model(&dense, "tiny", &cfg(0.6, Precision::Q8), &toks).unwrap();
        let dir = std::env::temp_dir().join("dobi_compress_pipe_append");
        let _ = std::fs::remove_dir_all(&dir);
        // no manifest yet: append must refuse (standalone write is --out)
        assert!(append_artifacts(&dir, &a40).is_err());
        write_artifacts(&dir, &a40).unwrap();
        append_artifacts(&dir, &a60).unwrap();
        // duplicate id refused
        assert!(append_artifacts(&dir, &a60).is_err());
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.models.len(), 1, "both variants share the one model entry");
        assert_eq!(m.variants.len(), 2);
        for id in ["tiny/dobi_40", "tiny/dobi_60"] {
            let v = m.variant(id).unwrap();
            assert!(v.hlo.is_empty());
            assert!(m.path(&v.weights).exists(), "{id} store written");
            // both serve from the merged manifest
            let store = crate::storage::Store::open(&m.path(&v.weights)).unwrap();
            let loaded = FactorizedModel::from_store(&m.models["tiny"], v, &store).unwrap();
            let out = loaded.forward(1, 8, &[1, 2, 3, 4, 5, 6, 7, 8], None).unwrap();
            assert!(out.iter().all(|x| x.is_finite()));
        }
        // eval block preserved from the original standalone write
        assert_eq!((m.eval_batch, m.eval_seq), (2, 16));
    }

    #[test]
    fn append_refuses_model_shape_mismatch() {
        let toks = corpus();
        let dense = tiny_model(dims(), 0, false);
        let art = compress_model(&dense, "tiny", &cfg(0.4, Precision::F32), &toks).unwrap();
        let dir = std::env::temp_dir().join("dobi_compress_pipe_append_clash");
        let _ = std::fs::remove_dir_all(&dir);
        write_artifacts(&dir, &art).unwrap();
        // same model name, different geometry
        let other_dims = TinyDims { vocab: 61, d: 20, heads: 2, layers: 2, ff: 24 };
        let other = tiny_model(other_dims, 0, false);
        let toks61 = corpus();
        let clash = compress_model(&other, "tiny", &cfg(0.6, Precision::F32), &toks61).unwrap();
        let err = append_artifacts(&dir, &clash).unwrap_err().to_string();
        assert!(err.contains("refusing to merge"), "err: {err}");
    }

    #[test]
    fn learned_alloc_compresses_end_to_end() {
        let dense = tiny_model(dims(), 0, false);
        let mut c = cfg(0.4, Precision::F32);
        c.alloc = crate::config::AllocMode::Learned;
        c.train_iters = 60;
        let art = compress_model(&dense, "tiny", &c, &corpus()).unwrap();
        assert_eq!(art.variant_id, "tiny/dobi-learned_40",
                   "learned variants carry the alloc tag");
        assert_eq!(art.alloc, "learned");
        let report = art.train_report.as_ref().expect("learned mode reports");
        assert_eq!(report.iters, 60);
        assert!((report.shares.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        let budget = (0.4 * art.total_params as f64).round() as usize;
        assert!(art.stored_params <= budget,
                "stored {} over budget {budget}", art.stored_params);
        assert!(art.ranks.values().all(|&k| k >= 1));
        let tokens: Vec<i32> = (0..24).map(|i| i % 61).collect();
        let out = art.reference.forward(2, 12, &tokens, None).unwrap();
        assert!(out.iter().all(|x| x.is_finite()));
        // the manifest round-trips the alloc mode
        let dir = std::env::temp_dir().join("dobi_compress_pipe_learned");
        let _ = std::fs::remove_dir_all(&dir);
        write_artifacts(&dir, &art).unwrap();
        let m = Manifest::load(&dir).unwrap();
        let v = m.variant("tiny/dobi-learned_40").unwrap();
        assert_eq!(v.alloc, "learned");
        // waterfill manifests read back their mode too (and old manifests
        // without the field default to it — covered by Manifest::load)
        let wf = compress_model(&dense, "tiny", &cfg(0.4, Precision::F32), &corpus()).unwrap();
        assert_eq!(wf.alloc, "waterfill");
        assert!(wf.train_report.is_none());
    }

    #[test]
    fn single_layer_model_compresses() {
        // the single-layer degenerate case from the waterfill edge-case
        // sweep, driven through the whole pipeline
        let one = TinyDims { vocab: 61, d: 16, heads: 2, layers: 1, ff: 24 };
        let dense = tiny_model(one, 0, false);
        let art = compress_model(&dense, "tiny", &cfg(0.5, Precision::F32), &corpus()).unwrap();
        assert_eq!(art.ranks.len(), 7, "one layer -> seven targets");
        assert_eq!(art.spectra.len(), 7);
        assert!(art.ranks.values().all(|&k| k >= 1));
        let out = art.reference.forward(1, 8, &[1, 2, 3, 4, 5, 6, 7, 8], None).unwrap();
        assert!(out.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn replace_swaps_variant_and_gc_collects_orphans() {
        let dense = tiny_model(dims(), 0, false);
        let toks = corpus();
        let a40 = compress_model(&dense, "tiny", &cfg(0.4, Precision::Q8), &toks).unwrap();
        let a60 = compress_model(&dense, "tiny", &cfg(0.6, Precision::Q8), &toks).unwrap();
        let dir = std::env::temp_dir().join("dobi_compress_pipe_replace");
        let _ = std::fs::remove_dir_all(&dir);
        write_artifacts(&dir, &a40).unwrap();
        append_artifacts(&dir, &a60).unwrap();
        // same id again: refused without --replace, swapped with it
        assert!(append_artifacts(&dir, &a60).is_err());
        let a60f32 = compress_model(&dense, "tiny", &cfg(0.6, Precision::F32), &toks).unwrap();
        assert_eq!(a60f32.variant_id, a60.variant_id);
        append_artifacts_opts(&dir, &a60f32, true).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.variants.len(), 2, "replace must not grow the variant list");
        // the replacement is live: f32 payload is the larger one
        let v = m.variant("tiny/dobi_60").unwrap();
        assert_eq!(v.bytes, a60f32.payload_bytes);
        let store = crate::storage::Store::open(&m.path(&v.weights)).unwrap();
        let loaded = FactorizedModel::from_store(&m.models["tiny"], v, &store).unwrap();
        let out = loaded.forward(1, 8, &[1, 2, 3, 4, 5, 6, 7, 8], None).unwrap();
        assert!(out.iter().all(|x| x.is_finite()));
        // a stray store nothing references is collected on demand
        let stray = dir.join("tiny_dobi_99.dobiw");
        std::fs::write(&stray, b"junk").unwrap();
        let removed = gc_orphan_stores(&dir).unwrap();
        assert_eq!(removed, vec![stray.clone()]);
        assert!(!stray.exists());
        // referenced stores survive GC
        assert!(m.path(&v.weights).exists());
        assert!(dir.join("tiny_dobi_40.dobiw").exists());
        // a standalone --out write into the same dir clobbers the manifest
        // but must NOT delete the now-unreferenced stores (only the
        // explicit --replace path and gc_orphan_stores may do that)
        write_artifacts(&dir, &a40).unwrap();
        let m2 = Manifest::load(&dir).unwrap();
        assert_eq!(m2.variants.len(), 1);
        assert!(dir.join("tiny_dobi_40.dobiw").exists());
        assert!(dir.join("tiny_dobi_60.dobiw").exists(),
                "standalone writes must leave foreign stores recoverable");
        // the explicit collector then reclaims it on request
        let removed = gc_orphan_stores(&dir).unwrap();
        assert_eq!(removed, vec![dir.join("tiny_dobi_60.dobiw")]);
    }

    #[test]
    fn provenance_stamped_and_tampered_store_refused() {
        let dense = tiny_model(dims(), 0, false);
        let art = compress_model(&dense, "tiny", &cfg(0.4, Precision::Q8), &corpus()).unwrap();
        let dir = std::env::temp_dir().join("dobi_compress_pipe_prov");
        let _ = std::fs::remove_dir_all(&dir);
        write_artifacts(&dir, &art).unwrap();
        let m = Manifest::load(&dir).unwrap();
        let v = m.variant(&art.variant_id).unwrap();
        let p = v.provenance.as_ref().expect("compress stamps provenance");
        assert_eq!(p.store_sha256.len(), 64);
        assert_eq!(p.tensors.len(), art.tensors.len(), "every tensor gets a section hash");
        assert_eq!(p.config.path("alloc").and_then(Json::as_str), Some("waterfill"));
        assert_eq!(p.config.path("seed").and_then(Json::as_usize),
                   Some(art.config.seed as usize));
        assert_eq!(p.toolchain.path("format").and_then(Json::as_str), Some("DOBIW1"));
        // verified load succeeds, and the pin is the hash of what's on disk
        let store = m.open_store(v).unwrap();
        assert_eq!(store.content_sha256, p.store_sha256);
        // wholesale replacement with a DIFFERENT valid store: CRC-clean,
        // so the raw reader accepts it — only the provenance pin refuses
        let other = compress_model(&dense, "tiny", &cfg(0.4, Precision::F32), &corpus()).unwrap();
        write_store(&m.path(&v.weights), &other.tensors).unwrap();
        assert!(crate::storage::Store::open(&m.path(&v.weights)).is_ok(),
                "replacement store must be structurally valid for this test to bite");
        let err = m.open_store(v).unwrap_err().to_string();
        assert!(err.contains("provenance mismatch"), "err: {err}");
        // restoring the original bytes makes the pin verify again
        write_store(&m.path(&v.weights), &art.tensors).unwrap();
        assert!(m.open_store(v).is_ok());
        // append path stamps provenance too
        let a60 = compress_model(&dense, "tiny", &cfg(0.6, Precision::Q8), &corpus()).unwrap();
        append_artifacts(&dir, &a60).unwrap();
        let m2 = Manifest::load(&dir).unwrap();
        for id in [art.variant_id.as_str(), a60.variant_id.as_str()] {
            let v = m2.variant(id).unwrap();
            assert!(v.provenance.is_some(), "{id} missing provenance");
            assert!(m2.open_store(v).is_ok(), "{id} must verify");
        }
    }

    #[test]
    fn run_report_is_persisted_and_deterministic() {
        let dense = tiny_model(dims(), 0, false);
        let toks = corpus();
        let a = compress_model(&dense, "tiny", &cfg(0.4, Precision::Q8), &toks).unwrap();
        let b = compress_model(&dense, "tiny", &cfg(0.4, Precision::Q8), &toks).unwrap();
        // the per-target table is deterministic modulo timing
        assert_eq!(a.run_report.targets.len(), 7 * dims().layers);
        for (x, y) in a.run_report.targets.iter().zip(&b.run_report.targets) {
            assert_eq!((x.name.as_str(), x.m, x.n, x.rank, x.max_rank, &x.codec),
                       (y.name.as_str(), y.m, y.n, y.rank, y.max_rank, &y.codec));
            assert!((x.tail_energy - y.tail_energy).abs() < 1e-12, "{}", x.name);
            assert!((x.recon_error - y.recon_error).abs() < 1e-12, "{}", x.name);
            assert!(x.rank <= x.max_rank && x.recon_error.is_finite());
            assert!(x.svd_sweeps >= 1, "{}: sweeps recorded", x.name);
        }
        // report rows line up with the allocated ranks
        for t in &a.run_report.targets {
            assert_eq!(a.ranks[&t.name], t.rank, "{}", t.name);
        }
        // persisted next to the store, referenced from the manifest
        // entry, write phase folded in, shares summing to 1
        let dir = std::env::temp_dir().join("dobi_compress_pipe_runreport");
        let _ = std::fs::remove_dir_all(&dir);
        write_artifacts(&dir, &a).unwrap();
        let file = RunReport::file_name(&a.variant_id);
        let j = crate::json::load(&dir.join(&file)).unwrap();
        let r = RunReport::from_json(&j).unwrap();
        assert_eq!(r.variant_id, a.variant_id);
        assert_eq!(r.targets.len(), a.run_report.targets.len());
        let share_sum: f64 = r.phases.iter().map(|p| p.share).sum();
        assert!((share_sum - 1.0).abs() < 1e-9, "shares sum to {share_sum}");
        assert!(r.phases.iter().any(|p| p.phase == phases::COMPRESS_WRITE),
                "write phase folded in at write time");
        let m = Manifest::load(&dir).unwrap();
        let v = m.variant(&a.variant_id).unwrap();
        assert_eq!(v.run_report.as_deref(), Some(file.as_str()),
                   "manifest entry references the run report file");
        assert!(r.render().contains("layers.0.wq"));
        // the append path persists a report too
        let a60 = compress_model(&dense, "tiny", &cfg(0.6, Precision::Q8), &toks).unwrap();
        append_artifacts(&dir, &a60).unwrap();
        assert!(dir.join(RunReport::file_name(&a60.variant_id)).exists());
    }

    #[test]
    fn traced_compress_covers_every_declared_phase() {
        let dense = tiny_model(dims(), 0, false);
        let mut c = cfg(0.4, Precision::F32);
        c.alloc = crate::config::AllocMode::Learned;
        c.train_iters = 40;
        let tel = CompressTelemetry::new(65_536, false);
        let art = compress_model_traced(&dense, "tiny", &c, &corpus(), &tel).unwrap();
        let dir = std::env::temp_dir().join("dobi_compress_pipe_traced");
        let _ = std::fs::remove_dir_all(&dir);
        write_artifacts_traced(&dir, &art, &tel).unwrap();
        let events = tel.trace.drain(false);
        let seen: std::collections::BTreeSet<&str> = events.iter().map(|e| e.name).collect();
        for ph in phases::ALL.iter().filter(|p| p.starts_with("compress_")) {
            assert!(seen.contains(*ph), "phase {ph} never recorded");
        }
        for name in &seen {
            assert!(phases::ALL.contains(name), "undeclared phase {name}");
        }
        // chrome export categorizes every event as `compress`
        let doc = crate::trace::export_chrome(&events);
        let evs = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
        assert_eq!(evs.len(), events.len());
        assert!(evs.iter().all(|e| e.str_of("cat") == "compress"));
        // metric families emitted under the declared names
        assert_eq!(tel.metrics.family_total(metric_names::COMPRESS_TARGETS),
                   7 * dims().layers as u64);
        assert_eq!(tel.metrics.family_total(metric_names::COMPRESS_TRAIN_ITERS), 40);
        // artifact report carries the learned trajectory for persistence
        let train = art.run_report.train.as_ref().expect("learned run reports train block");
        assert!(!train.trajectory.is_empty());
        // a zero-capacity ring records nothing at all
        let off = CompressTelemetry::new(0, false);
        let _ = compress_model_traced(&dense, "tiny", &cfg(0.4, Precision::F32), &corpus(),
                                      &off)
            .unwrap();
        assert_eq!(off.trace.recorded(), 0, "--trace-buffer 0 must record zero events");
        assert!(off.trace.drain(true).is_empty());
    }

    #[test]
    fn q8_store_tracks_f32_reference_closely() {
        let dense = tiny_model(dims(), 0, false);
        let toks = corpus();
        let art = compress_model(&dense, "tiny", &cfg(0.5, Precision::Q8), &toks).unwrap();
        let dir = std::env::temp_dir().join("dobi_compress_pipe_q8");
        let _ = std::fs::remove_dir_all(&dir);
        write_artifacts(&dir, &art).unwrap();
        let m = Manifest::load(&dir).unwrap();
        let v = m.variant(&art.variant_id).unwrap();
        let store = crate::storage::Store::open(&m.path(&v.weights)).unwrap();
        let loaded =
            FactorizedModel::from_store(&m.models["tiny"], v, &store).unwrap();
        let l_store = eval_loss(&loaded, &toks, 2, 12, 4, 9).unwrap();
        let l_ref = eval_loss(&art.reference, &toks, 2, 12, 4, 9).unwrap();
        assert!((l_store - l_ref).abs() < 0.3,
                "int8 store drifted from f32 reference: {l_store} vs {l_ref}");
    }
}
