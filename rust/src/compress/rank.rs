//! Activation-aware truncation-position search: whitened per-target
//! spectra + global budgeted rank allocation.
//!
//! For each compression target W (m_in x n_out) with calibration inputs
//! X_i, the loss of truncating the *activation* A = [X_1; ...; X_B] W at
//! rank k is `sum_{i>k} sigma_i^2(A)`.  Rather than stacking activations,
//! we whiten per SVD-LLM (Wang et al., 2024): with the Gram matrix
//! `G = sum_i X_i^T X_i = L L^T` (Cholesky), the singular values of
//! `L^T W` are exactly those of the stacked A — so one weight-sized SVD
//! per target yields the full truncation-loss curve.
//!
//! Ranks are then allocated across all targets under a global stored-
//! parameter budget by greedy waterfilling over loss sensitivity: each
//! step spends `max(m, n)` parameters (the remapped storage cost of one
//! rank unit, `truncation.py::remap_ratio`) on the target with the
//! largest marginal loss reduction per parameter — the discrete-grid
//! evaluation of the paper's differentiable truncation objective, in the
//! loss-sensitivity-balanced spirit of Zero Sum SVD (Abbasi et al., 2025).

use anyhow::Result;

use super::svd::{cholesky_lower, svd_thin};

/// Truncation-loss curve of one compression target.
#[derive(Debug, Clone)]
pub struct TargetSpectrum {
    pub name: String,
    /// Input (row) dimension of the target matrix.
    pub m: usize,
    /// Output (column) dimension.
    pub n: usize,
    /// `sigma_i^2` of the whitened weight, descending; len min(m, n).
    pub sigma2: Vec<f64>,
}

impl TargetSpectrum {
    /// Remapped storage cost of one rank unit (Algo 3: k·max(m,n) params).
    pub fn unit_cost(&self) -> usize {
        self.m.max(self.n)
    }

    pub fn max_rank(&self) -> usize {
        self.m.min(self.n)
    }

    /// Normalized truncation loss at rank k: tail energy / total energy.
    pub fn loss_at(&self, k: usize) -> f64 {
        let total: f64 = self.sigma2.iter().sum();
        if total <= 0.0 {
            return 0.0;
        }
        self.sigma2.iter().skip(k).sum::<f64>() / total
    }
}

/// Accumulate the Gram matrix `G = sum_i X_i^T X_i` (m x m, f64) from
/// per-batch row-major (rows, m) activations.
pub fn gram(xs: &[Vec<f32>], m: usize) -> Vec<f64> {
    let mut g = vec![0f64; m * m];
    for x in xs {
        assert_eq!(x.len() % m, 0, "calibration batch not row-major (rows, {m})");
        let rows = x.len() / m;
        for r in 0..rows {
            let row = &x[r * m..(r + 1) * m];
            for i in 0..m {
                let xi = row[i] as f64;
                if xi != 0.0 {
                    for j in 0..m {
                        g[i * m + j] += xi * row[j] as f64;
                    }
                }
            }
        }
    }
    g
}

/// The whitening factor of one calibration input, reusable across every
/// target that multiplies the same activations (wq/wk/wv share their
/// post-attn-norm input, w_gate/w_up their post-mlp-norm input — the
/// Gram + Cholesky, the expensive part at O(rows·m²) + O(m³), is paid
/// once per shared input instead of once per target).
pub struct Whitener {
    m: usize,
    /// Lower Cholesky factor of the (jittered) Gram; `None` when the Gram
    /// is numerically degenerate even after jitter (e.g. all-zero
    /// calibration) — spectra then fall back to the plain weight SVD so
    /// compression never aborts on a pathological target.
    l: Option<Vec<f64>>,
}

/// Build the whitener `L` with `sum_i X_i^T X_i + jit·I = L L^T`,
/// escalating the diagonal jitter until the factorization succeeds.
pub fn whitener(xs: &[Vec<f32>], m: usize) -> Whitener {
    let g = gram(xs, m);
    let mean_diag = (0..m).map(|i| g[i * m + i]).sum::<f64>() / m as f64;
    // Degenerate calibration (all-zero or non-finite activations) carries
    // no whitening signal: take the documented plain-weight-spectrum
    // fallback instead of Cholesky-factoring a pure-jitter Gram, whose
    // ~1e-20-scaled spectrum would starve the target in allocation.
    if !mean_diag.is_finite() || mean_diag <= 0.0 {
        return Whitener { m, l: None };
    }
    let mut l = None;
    for jit_scale in [1e-8, 1e-6, 1e-4] {
        let jit = jit_scale * mean_diag;
        let mut gj = g.clone();
        for i in 0..m {
            gj[i * m + i] += jit;
        }
        if let Some(found) = cholesky_lower(&gj, m) {
            l = Some(found);
            break;
        }
    }
    Whitener { m, l }
}

impl Whitener {
    /// Whitened spectrum of one target: `sigma^2(L^T W)` — exactly the
    /// singular values of the stacked calibration activations `X W`.
    pub fn spectrum(&self, name: &str, w: &[f32], n: usize) -> Result<TargetSpectrum> {
        let m = self.m;
        anyhow::ensure!(w.len() == m * n, "{name}: weight is not {m}x{n}");
        let spectrum_of = |mat: &[f32]| -> Vec<f64> {
            svd_thin(mat, m, n).s.iter().map(|&s| (s as f64) * (s as f64)).collect()
        };
        let sigma2 = match &self.l {
            Some(l) => {
                // L^T W: (m, n); L is lower so L^T[i, r] = L[r, i], r >= i.
                // Rows accumulate in f64 (the subsystem's working
                // precision) and cast once, so the tail singular values
                // the allocator compares are not f32 rounding noise.
                let mut lw = vec![0f32; m * n];
                let mut row = vec![0f64; n];
                for i in 0..m {
                    row.iter_mut().for_each(|v| *v = 0.0);
                    for r in i..m {
                        let lv = l[r * m + i];
                        if lv != 0.0 {
                            let wrow = &w[r * n..(r + 1) * n];
                            for (o, &wv) in row.iter_mut().zip(wrow) {
                                *o += lv * wv as f64;
                            }
                        }
                    }
                    for (o, &v) in lw[i * n..(i + 1) * n].iter_mut().zip(row.iter()) {
                        *o = v as f32;
                    }
                }
                spectrum_of(&lw)
            }
            None => spectrum_of(w),
        };
        Ok(TargetSpectrum { name: name.to_string(), m, n, sigma2 })
    }
}

/// One-shot convenience: build the whitener for `xs` and score `w`.
pub fn whitened_spectrum(name: &str, w: &[f32], m: usize, n: usize,
                         xs: &[Vec<f32>]) -> Result<TargetSpectrum> {
    whitener(xs, m).spectrum(name, w, n)
}

/// A rank-allocation policy: integer ranks for every target under a
/// global stored-parameter budget.  Two implementations exist — the
/// greedy discrete [`Waterfill`] below, and the differentiable
/// truncation-position optimizer (`train::LearnedAlloc`, the paper's
/// actual "Dobi" objective) — and the compression pipeline consumes
/// either through this one trait (`dobi compress --alloc`).
pub trait RankAllocator {
    /// Short mode name recorded in the variant manifest (`alloc` field).
    fn name(&self) -> &'static str;

    /// Returns `(ranks, spent)` with the same contract as
    /// [`allocate_ranks`]: every target gets at least
    /// `min(k_min, max_rank)` even when that floor overshoots `budget`.
    fn allocate(&self, specs: &[TargetSpectrum], budget: usize,
                k_min: usize) -> (Vec<usize>, usize);
}

/// The SVD-LLM-style greedy waterfill baseline as a [`RankAllocator`].
#[derive(Debug, Clone, Copy, Default)]
pub struct Waterfill;

impl RankAllocator for Waterfill {
    fn name(&self) -> &'static str {
        "waterfill"
    }

    fn allocate(&self, specs: &[TargetSpectrum], budget: usize,
                k_min: usize) -> (Vec<usize>, usize) {
        allocate_ranks(specs, budget, k_min)
    }
}

/// Greedy waterfilling: allocate integer ranks to every target under a
/// global budget of stored parameters (remapped accounting: a rank unit
/// on target t costs `max(m_t, n_t)`).  Starts all targets at
/// `min(k_min, max_rank)` and repeatedly buys the rank increment with the
/// best marginal `sigma^2 / cost` until the budget is exhausted or every
/// target is full rank.  Deterministic: ties resolve to the lowest index.
///
/// Returns `(ranks, spent)`.  The floor allocation is granted even when
/// it exceeds the budget (a model cannot serve rank-0 factors); callers
/// see the overshoot in `spent`.
pub fn allocate_ranks(specs: &[TargetSpectrum], budget: usize,
                      k_min: usize) -> (Vec<usize>, usize) {
    let k_min = k_min.max(1);
    let mut ks: Vec<usize> = specs.iter().map(|t| k_min.min(t.max_rank())).collect();
    let mut spent: usize = specs.iter().zip(&ks).map(|(t, &k)| k * t.unit_cost()).sum();
    loop {
        let mut best: Option<(usize, f64)> = None;
        for (i, t) in specs.iter().enumerate() {
            if ks[i] >= t.max_rank() || spent + t.unit_cost() > budget {
                continue;
            }
            // marginal loss reduction of rank ks[i] -> ks[i]+1, per param
            let gain = t.sigma2.get(ks[i]).copied().unwrap_or(0.0) / t.unit_cost() as f64;
            match best {
                Some((_, g)) if gain <= g => {}
                _ => best = Some((i, gain)),
            }
        }
        let Some((i, _)) = best else { break };
        ks[i] += 1;
        spent += specs[i].unit_cost();
    }
    (ks, spent)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::testutil::randv;
    use crate::mathx::XorShift;

    /// sigma^2 of the stacked activations [X_1 W; ...; X_B W], via the
    /// unwhitened route (direct SVD of the tall stack) — the reference the
    /// whitened computation must match.
    fn stacked_spectrum(xs: &[Vec<f32>], w: &[f32], m: usize, n: usize) -> Vec<f64> {
        let rows: usize = xs.iter().map(|x| x.len() / m).sum();
        let mut a = vec![0f32; rows * n];
        let mut r0 = 0usize;
        for x in xs {
            let br = x.len() / m;
            for r in 0..br {
                for j in 0..n {
                    let mut acc = 0f32;
                    for t in 0..m {
                        acc += x[r * m + t] * w[t * n + j];
                    }
                    a[(r0 + r) * n + j] = acc;
                }
            }
            r0 += br;
        }
        svd_thin(&a, rows, n).s.iter().map(|&s| (s as f64) * (s as f64)).collect()
    }

    #[test]
    fn whitened_matches_stacked_activation_spectrum() {
        let mut rng = XorShift::new(11);
        let (m, n) = (10usize, 8usize);
        let w = randv(&mut rng, m * n, 0.4);
        let xs: Vec<Vec<f32>> = (0..3).map(|_| randv(&mut rng, 20 * m, 1.0)).collect();
        let spec = whitened_spectrum("t", &w, m, n, &xs).unwrap();
        let reference = stacked_spectrum(&xs, &w, m, n);
        assert_eq!(spec.sigma2.len(), n);
        for (a, b) in spec.sigma2.iter().zip(&reference) {
            assert!((a - b).abs() < 1e-3 * reference[0].max(1.0),
                    "whitened {a} vs stacked {b}");
        }
    }

    #[test]
    fn degenerate_calibration_falls_back_to_weight_spectrum() {
        let mut rng = XorShift::new(12);
        let (m, n) = (6usize, 5usize);
        let w = randv(&mut rng, m * n, 0.4);
        let xs = vec![vec![0f32; 4 * m]]; // all-zero activations
        let spec = whitened_spectrum("t", &w, m, n, &xs).unwrap();
        // the fallback is the PLAIN weight spectrum — not a jitter-scaled
        // near-zero one that would starve the target during allocation
        let plain: Vec<f64> =
            svd_thin(&w, m, n).s.iter().map(|&s| (s as f64) * (s as f64)).collect();
        assert_eq!(spec.sigma2.len(), plain.len());
        for (a, b) in spec.sigma2.iter().zip(&plain) {
            assert!((a - b).abs() < 1e-9 * plain[0].max(1.0), "{a} vs {b}");
        }
        assert!(spec.sigma2[0] > 1e-3, "weight energy must survive the fallback");
    }

    fn spec(name: &str, m: usize, n: usize, sigma2: Vec<f64>) -> TargetSpectrum {
        TargetSpectrum { name: name.into(), m, n, sigma2 }
    }

    #[test]
    fn waterfill_respects_budget_and_prefers_energy() {
        // target a holds all the energy; b is nearly flat noise.
        let a = spec("a", 10, 10, vec![100.0, 50.0, 25.0, 12.0, 6.0, 3.0, 1.0, 0.5, 0.2, 0.1]);
        let b = spec("b", 10, 10, vec![1.0; 10]);
        let budget = 8 * 10; // 8 rank units at cost 10 each
        let (ks, spent) = allocate_ranks(&[a, b], budget, 1);
        assert!(spent <= budget);
        assert_eq!(spent, 80, "greedy fills the whole budget when gains remain");
        assert!(ks[0] > ks[1], "energy-heavy target gets more rank: {ks:?}");
        assert!(ks[0] >= 1 && ks[1] >= 1);
    }

    #[test]
    fn waterfill_floor_allocation_when_budget_tiny() {
        let a = spec("a", 4, 6, vec![1.0, 0.5, 0.2, 0.1]);
        let b = spec("b", 6, 4, vec![1.0, 0.5, 0.2, 0.1]);
        let (ks, spent) = allocate_ranks(&[a, b], 0, 1);
        assert_eq!(ks, vec![1, 1], "floor rank granted even over budget");
        assert_eq!(spent, 12);
    }

    #[test]
    fn waterfill_monotone_in_budget() {
        let mut rng = XorShift::new(13);
        let specs: Vec<TargetSpectrum> = (0..4)
            .map(|i| {
                let mut s2: Vec<f64> =
                    (0..8).map(|_| (rng.normal().abs() + 0.01) * 10.0).collect();
                s2.sort_by(|a, b| b.partial_cmp(a).unwrap());
                spec(&format!("t{i}"), 8, 8 + i, s2)
            })
            .collect();
        let (lo, _) = allocate_ranks(&specs, 100, 1);
        let (hi, _) = allocate_ranks(&specs, 200, 1);
        for (a, b) in lo.iter().zip(&hi) {
            assert!(b >= a, "rank shrank with a larger budget: {lo:?} vs {hi:?}");
        }
    }

    #[test]
    fn waterfill_caps_at_full_rank() {
        let a = spec("a", 4, 4, vec![5.0, 3.0, 2.0, 1.0]);
        let (ks, spent) = allocate_ranks(&[a], usize::MAX / 2, 1);
        assert_eq!(ks, vec![4]);
        assert_eq!(spent, 16);
    }

    #[test]
    fn zero_budget_grants_exactly_the_floor() {
        // zero budget: every target still gets its floor (a model cannot
        // serve rank-0 factors) and nothing more — `spent` reports the
        // overshoot honestly
        let specs = vec![
            spec("a", 8, 4, vec![9.0, 4.0, 1.0, 0.5]),
            spec("b", 4, 8, vec![9.0, 4.0, 1.0, 0.5]),
            spec("c", 2, 2, vec![1.0, 0.1]),
        ];
        let (ks, spent) = allocate_ranks(&specs, 0, 3);
        assert_eq!(ks, vec![3, 3, 2], "floor is min(k_min, max_rank) per target");
        assert_eq!(spent, 3 * 8 + 3 * 8 + 2 * 2);
        // a budget exactly equal to the floor cost adds nothing
        let (ks2, spent2) = allocate_ranks(&specs, spent, 3);
        assert_eq!(ks2, ks);
        assert_eq!(spent2, spent);
    }

    #[test]
    fn budget_above_all_ranks_fills_everything_and_stops() {
        let specs = vec![
            spec("a", 6, 4, vec![5.0, 3.0, 2.0, 1.0]),
            spec("b", 4, 10, vec![8.0, 4.0, 2.0, 1.0]),
            spec("c", 3, 3, vec![1.0, 1.0, 1.0]),
        ];
        let full: usize = specs.iter().map(|t| t.max_rank() * t.unit_cost()).sum();
        for budget in [full, full + 1, full * 10, usize::MAX / 4] {
            let (ks, spent) = allocate_ranks(&specs, budget, 1);
            assert_eq!(ks, vec![4, 4, 3], "budget {budget}");
            assert_eq!(spent, full, "never spends past full rank");
        }
        // one param short of full: something must stay truncated
        let (ks, spent) = allocate_ranks(&specs, full - 1, 1);
        assert!(spent < full);
        assert!(ks.iter().zip(&specs).any(|(&k, t)| k < t.max_rank()),
                "budget {} cannot buy full rank everywhere", full - 1);
    }

    #[test]
    fn exact_tie_spectra_break_to_the_lowest_index() {
        // identical spectra and costs: every marginal gain ties, so the
        // deterministic tie-break must hand the odd increment to the
        // lowest index — bit-stable across runs and platforms
        let mk = || spec("t", 6, 6, vec![7.0, 7.0, 3.0, 1.0, 0.5, 0.25]);
        let specs = vec![mk(), mk(), mk()];
        // floor 3 x 1 = 18 params; budget for 7 increments of cost 6
        let (ks, spent) = allocate_ranks(&specs, 18 + 7 * 6, 1);
        assert_eq!(spent, 18 + 7 * 6, "ties must not stall the fill");
        assert_eq!(ks, vec![4, 3, 3],
                   "7 = 3+2+2 round-robin-by-gain with lowest-index ties: {ks:?}");
        let (ks2, _) = allocate_ranks(&specs, 18 + 7 * 6, 1);
        assert_eq!(ks, ks2, "tie-break must be deterministic");
    }

    #[test]
    fn single_target_model_allocates_standalone() {
        // the single-layer / single-target degenerate case: the whole
        // budget belongs to one spectrum
        let a = spec("only", 12, 8, vec![20.0, 10.0, 5.0, 2.0, 1.0, 0.5, 0.2, 0.1]);
        let (ks, spent) = allocate_ranks(std::slice::from_ref(&a), 5 * 12, 1);
        assert_eq!(ks, vec![5]);
        assert_eq!(spent, 5 * 12);
        // budget between rank steps: partial remainder stays unspent
        let (ks2, spent2) = allocate_ranks(std::slice::from_ref(&a), 5 * 12 + 7, 1);
        assert_eq!(ks2, vec![5]);
        assert_eq!(spent2, 5 * 12, "7 params cannot buy a 12-param rank unit");
    }

    #[test]
    fn waterfill_trait_impl_matches_free_function() {
        let specs = vec![
            spec("a", 10, 10, vec![100.0, 50.0, 25.0, 12.0, 6.0, 3.0, 1.0, 0.5, 0.2, 0.1]),
            spec("b", 10, 10, vec![1.0; 10]),
        ];
        let alloc: &dyn RankAllocator = &Waterfill;
        assert_eq!(alloc.name(), "waterfill");
        assert_eq!(alloc.allocate(&specs, 80, 1), allocate_ranks(&specs, 80, 1));
    }

    #[test]
    fn loss_curve_monotone() {
        let t = spec("t", 6, 6, vec![10.0, 5.0, 2.0, 1.0, 0.5, 0.1]);
        let losses: Vec<f64> = (0..=6).map(|k| t.loss_at(k)).collect();
        assert!((losses[0] - 1.0).abs() < 1e-12);
        assert_eq!(losses[6], 0.0);
        for w in losses.windows(2) {
            assert!(w[0] >= w[1]);
        }
    }
}
