//! Native compression pipeline — dense weights in, a servable compressed
//! `.dobiw` store out, no Python on the path.
//!
//! This subsystem mirrors `python/compile/dobi/` in Rust, closing the
//! loop the serving stack opened: `dobi compress` turns a dense model
//! into rank-truncated remapped factors that the native backend executes
//! directly.  Layering:
//!
//! * [`svd`]      — one-sided Jacobi thin SVD + Cholesky (pure Rust, f32
//!   in/out, f64 accumulation, deterministic).
//! * [`calib`]    — calibration windows through the existing low-rank
//!   forward, tapping every compression target's input.
//! * [`rank`]     — SVD-LLM-style whitened truncation-loss spectra, the
//!   [`rank::RankAllocator`] trait, and the greedy waterfill baseline.
//! * [`train`]    — the differentiable truncation-position optimizer
//!   (autodiff tape, sigmoid truncation gates, Taylor-stabilized SVD
//!   gradients, Adam + exact budget renormalization): `--alloc learned`.
//! * [`remap`]    — IPCA dominant-subspace tracking, EYM-optimal weight
//!   reconstruction `W~ = W V V^T`, and the symmetric-sqrt factor split.
//! * [`report`]   — the per-release run report (`<variant>.run.json`):
//!   phase wall-clock shares, per-target table, train trajectory.
//! * [`pipeline`] — the whole-model driver + `.dobiw`/manifest writers
//!   (factor-only manifests with an empty `hlo` map, served through the
//!   router's any-seq mode), instrumented with `compress_*` trace phases
//!   and metric families.

pub mod calib;
pub mod pipeline;
pub mod rank;
pub mod remap;
pub mod report;
pub mod svd;
pub mod train;

pub use calib::{collect, sample_windows, synth_calib_tokens, tap_key, Calibration};
pub use pipeline::{append_artifacts, append_artifacts_opts, compress_model,
                   compress_model_traced, eval_loss, gc_orphan_stores, write_artifacts,
                   CompressTelemetry, CompressedArtifact};
pub use rank::{allocate_ranks, whitened_spectrum, whitener, RankAllocator, TargetSpectrum,
               Waterfill, Whitener};
pub use remap::{reconstruct_factors, Ipca};
pub use report::{PhaseShare, RunReport, TargetReport};
pub use svd::{cholesky_lower, last_sweeps, set_svd_threads, svd_thin, svd_thin_f64, Svd, SvdF64};
pub use train::{learn_ranks, AllocPick, LearnedAlloc, TrainConfig, TrainReport, TrainSample};

/// Test helpers shared by this subsystem's unit-test modules.
#[cfg(test)]
pub(crate) mod testutil {
    use crate::mathx::XorShift;

    /// Deterministic N(0, scale²) vector off the shared xorshift stream.
    pub fn randv(rng: &mut XorShift, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32 * scale).collect()
    }

    /// Unblocked triple-loop reference matmul: (m, k) @ (k, n) row-major.
    pub fn matmul_ref(a: &[f32], m: usize, k: usize, b: &[f32], n: usize) -> Vec<f32> {
        let mut out = vec![0f32; m * n];
        for i in 0..m {
            for t in 0..k {
                let av = a[i * k + t];
                for j in 0..n {
                    out[i * n + j] += av * b[t * n + j];
                }
            }
        }
        out
    }
}
