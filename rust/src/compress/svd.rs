//! Pure-Rust thin SVD via one-sided Jacobi — the factorization kernel of
//! the native compression pipeline (no LAPACK offline).
//!
//! One-sided Jacobi orthogonalizes the *columns* of A by plane rotations:
//! after convergence the column norms are the singular values, the
//! normalized columns are U, and the accumulated rotations are V.  It is
//! slower than bidiagonalization-based drivers but is simple, numerically
//! robust (every step is an exact orthogonal transform), and fully
//! deterministic — the pair sweep order is fixed, so identical inputs
//! produce identical factors on every platform.  Accumulation runs in f64
//! (mirroring `python/compile/dobi/ipca.py::robust_svd` working precision);
//! inputs and outputs are the crate-wide f32.

/// Relative off-diagonal threshold: rotate while
/// `|a_p . a_q| > TOL * ||a_p|| * ||a_q||`.
const TOL: f64 = 1e-9;

/// Sweep cap — one-sided Jacobi converges quadratically, so ~10 sweeps
/// suffice in practice; 60 is a generous safety bound.
const MAX_SWEEPS: usize = 60;

/// Thin SVD `A = U diag(s) Vt` of a row-major (m, n) matrix with
/// `r = min(m, n)`: `u` is (m, r), `s` is descending, `vt` is (r, n).
#[derive(Debug, Clone)]
pub struct Svd {
    pub u: Vec<f32>,
    pub s: Vec<f32>,
    pub vt: Vec<f32>,
}

impl Svd {
    pub fn rank(&self) -> usize {
        self.s.len()
    }
}

/// Thin SVD of a row-major (m, n) f32 matrix.  Non-finite entries are
/// sanitized to zero (the `robust_svd` contract).  Singular vectors of
/// zero singular values come out as zero columns — callers truncate well
/// above that regime.
pub fn svd_thin(a: &[f32], m: usize, n: usize) -> Svd {
    assert_eq!(a.len(), m * n, "svd_thin: {m}x{n} needs {} elems", m * n);
    assert!(m > 0 && n > 0, "svd_thin: empty matrix");
    let clean: Vec<f64> =
        a.iter().map(|&x| if x.is_finite() { x as f64 } else { 0.0 }).collect();
    if m >= n {
        let (u, s, vt) = jacobi_tall(&clean, m, n);
        Svd {
            u: u.iter().map(|&x| x as f32).collect(),
            s: s.iter().map(|&x| x as f32).collect(),
            vt: vt.iter().map(|&x| x as f32).collect(),
        }
    } else {
        // Wide: decompose the transpose.  A^T = U1 S V1^T  =>
        // A = V1 S U1^T, so U = V1 (m, m) and Vt = U1^T (m, n).
        let mut at = vec![0f64; n * m];
        for i in 0..m {
            for j in 0..n {
                at[j * m + i] = clean[i * n + j];
            }
        }
        let (u1, s, vt1) = jacobi_tall(&at, n, m); // u1 (n, m), vt1 (m, m)
        let mut u = vec![0f32; m * m];
        for r in 0..m {
            for c in 0..m {
                u[r * m + c] = vt1[c * m + r] as f32;
            }
        }
        let mut vt = vec![0f32; m * n];
        for r in 0..m {
            for c in 0..n {
                vt[r * n + c] = u1[c * m + r] as f32;
            }
        }
        Svd { u, s: s.iter().map(|&x| x as f32).collect(), vt }
    }
}

/// One-sided Jacobi on a tall row-major (m, n) matrix, m >= n.
/// Returns (u: (m, n) row-major, s: n descending, vt: (n, n) row-major).
fn jacobi_tall(a: &[f64], m: usize, n: usize) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
    debug_assert!(m >= n);
    // Column-contiguous working copies: cols[j*m..] is column j of A,
    // vcols[j*n..] is column j of V (accumulated rotations, init I).
    let mut cols = vec![0f64; n * m];
    for i in 0..m {
        for j in 0..n {
            cols[j * m + i] = a[i * n + j];
        }
    }
    let mut vcols = vec![0f64; n * n];
    for j in 0..n {
        vcols[j * n + j] = 1.0;
    }
    for _sweep in 0..MAX_SWEEPS {
        let mut converged = true;
        for p in 0..n.saturating_sub(1) {
            for q in p + 1..n {
                let (alpha, beta, gamma) = {
                    let cp = &cols[p * m..p * m + m];
                    let cq = &cols[q * m..q * m + m];
                    let mut aa = 0f64;
                    let mut bb = 0f64;
                    let mut gg = 0f64;
                    for i in 0..m {
                        aa += cp[i] * cp[i];
                        bb += cq[i] * cq[i];
                        gg += cp[i] * cq[i];
                    }
                    (aa, bb, gg)
                };
                if gamma == 0.0 || gamma.abs() <= TOL * (alpha * beta).sqrt() {
                    continue;
                }
                converged = false;
                let zeta = (beta - alpha) / (2.0 * gamma);
                let t = if zeta >= 0.0 {
                    1.0 / (zeta + (1.0 + zeta * zeta).sqrt())
                } else {
                    -1.0 / (-zeta + (1.0 + zeta * zeta).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                rotate_pair(&mut cols, m, p, q, c, s);
                rotate_pair(&mut vcols, n, p, q, c, s);
            }
        }
        if converged {
            break;
        }
    }
    // Column norms are the singular values; sort descending (ties by
    // original index, so the result is deterministic).
    let sigma: Vec<f64> = (0..n)
        .map(|j| cols[j * m..j * m + m].iter().map(|&x| x * x).sum::<f64>().sqrt())
        .collect();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&x, &y| sigma[y].partial_cmp(&sigma[x]).unwrap().then(x.cmp(&y)));
    let mut u = vec![0f64; m * n];
    let mut s_out = vec![0f64; n];
    let mut vt = vec![0f64; n * n];
    for (jj, &j) in order.iter().enumerate() {
        s_out[jj] = sigma[j];
        if sigma[j] > 1e-300 {
            let inv = 1.0 / sigma[j];
            for i in 0..m {
                u[i * n + jj] = cols[j * m + i] * inv;
            }
        }
        for i in 0..n {
            vt[jj * n + i] = vcols[j * n + i];
        }
    }
    (u, s_out, vt)
}

/// Apply the plane rotation to columns p < q of a column-contiguous
/// (len, k) buffer: col_p <- c*col_p - s*col_q, col_q <- s*col_p + c*col_q.
fn rotate_pair(cols: &mut [f64], len: usize, p: usize, q: usize, c: f64, s: f64) {
    debug_assert!(p < q);
    let (lo, hi) = cols.split_at_mut(q * len);
    let cp = &mut lo[p * len..p * len + len];
    let cq = &mut hi[..len];
    for i in 0..len {
        let x = cp[i];
        let y = cq[i];
        cp[i] = c * x - s * y;
        cq[i] = s * x + c * y;
    }
}

/// Lower-triangular Cholesky factor of a symmetric PSD row-major (n, n)
/// matrix: `G = L L^T`.  Returns `None` when a pivot is non-positive
/// (G not positive definite) — callers jitter the diagonal and retry.
pub fn cholesky_lower(g: &[f64], n: usize) -> Option<Vec<f64>> {
    assert_eq!(g.len(), n * n, "cholesky: shape mismatch");
    let mut l = vec![0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut s = g[i * n + j];
            for t in 0..j {
                s -= l[i * n + t] * l[j * n + t];
            }
            if i == j {
                if s <= 0.0 {
                    return None;
                }
                l[i * n + i] = s.sqrt();
            } else {
                l[i * n + j] = s / l[j * n + j];
            }
        }
    }
    Some(l)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::testutil::{matmul_ref, randv};
    use crate::mathx::XorShift;

    fn max_abs(xs: &[f32]) -> f32 {
        xs.iter().fold(0f32, |acc, &x| acc.max(x.abs()))
    }

    /// ||U diag(s) Vt - A||_max
    fn recon_err(svd: &Svd, a: &[f32], m: usize, n: usize) -> f32 {
        let r = svd.rank();
        let mut us = svd.u.clone(); // (m, r) scaled by s
        for i in 0..m {
            for j in 0..r {
                us[i * r + j] *= svd.s[j];
            }
        }
        let recon = matmul_ref(&us, m, r, &svd.vt, n);
        recon.iter().zip(a).map(|(x, y)| (x - y).abs()).fold(0f32, f32::max)
    }

    /// ||M^T M - I||_max for a row-major (rows, c) matrix with orthonormal
    /// columns.
    fn orth_err(mat: &[f32], rows: usize, c: usize) -> f32 {
        let mut worst = 0f32;
        for i in 0..c {
            for j in 0..c {
                let mut acc = 0f32;
                for r in 0..rows {
                    acc += mat[r * c + i] * mat[r * c + j];
                }
                let want = if i == j { 1.0 } else { 0.0 };
                worst = worst.max((acc - want).abs());
            }
        }
        worst
    }

    #[test]
    fn known_diagonal_decomposition() {
        // A = diag(3, 2, 1) embedded in 4x3: exact singular values known.
        let mut a = vec![0f32; 12];
        a[0] = 3.0;
        a[1 * 3 + 1] = 2.0;
        a[2 * 3 + 2] = 1.0;
        let svd = svd_thin(&a, 4, 3);
        assert!((svd.s[0] - 3.0).abs() < 1e-5);
        assert!((svd.s[1] - 2.0).abs() < 1e-5);
        assert!((svd.s[2] - 1.0).abs() < 1e-5);
        assert!(recon_err(&svd, &a, 4, 3) < 1e-5);
    }

    #[test]
    fn known_rank_one_outer_product() {
        // A = u v^T with ||u|| = 5, ||v|| = sqrt(2): sigma = 5*sqrt(2).
        let u = [3.0f32, 4.0];
        let v = [1.0f32, 1.0, 0.0];
        let mut a = vec![0f32; 6];
        for i in 0..2 {
            for j in 0..3 {
                a[i * 3 + j] = u[i] * v[j];
            }
        }
        let svd = svd_thin(&a, 2, 3);
        assert!((svd.s[0] - 5.0 * 2f32.sqrt()).abs() < 1e-4, "sigma {}", svd.s[0]);
        assert!(svd.s[1].abs() < 1e-5, "rank-1 matrix has one singular value");
        assert!(recon_err(&svd, &a, 2, 3) < 1e-5);
    }

    #[test]
    fn orthogonality_and_reconstruction_random() {
        let mut rng = XorShift::new(3);
        for &(m, n) in &[(8usize, 8usize), (20, 12), (12, 20), (40, 9), (1, 5), (5, 1)] {
            let a = randv(&mut rng, m * n, 0.7);
            let svd = svd_thin(&a, m, n);
            let r = m.min(n);
            assert_eq!(svd.u.len(), m * r);
            assert_eq!(svd.vt.len(), r * n);
            let scale = max_abs(&a).max(1.0);
            assert!(recon_err(&svd, &a, m, n) < 1e-4 * scale, "{m}x{n} recon");
            assert!(orth_err(&svd.u, m, r) < 1e-4, "{m}x{n} U orth");
            // rows of Vt are the columns of V: check V^T V = I via the
            // transpose view (Vt is (r, n); its rows must be orthonormal).
            let mut v = vec![0f32; n * r];
            for i in 0..r {
                for j in 0..n {
                    v[j * r + i] = svd.vt[i * n + j];
                }
            }
            assert!(orth_err(&v, n, r) < 1e-4, "{m}x{n} V orth");
            // descending order
            for w in svd.s.windows(2) {
                assert!(w[0] >= w[1], "singular values not sorted: {:?}", svd.s);
            }
        }
    }

    #[test]
    fn rank_deficient_input_truncates_cleanly() {
        // A = B C with inner dim 3 => exactly 3 nonzero singular values.
        let mut rng = XorShift::new(4);
        let b = randv(&mut rng, 10 * 3, 1.0);
        let c = randv(&mut rng, 3 * 8, 1.0);
        let a = matmul_ref(&b, 10, 3, &c, 8);
        let svd = svd_thin(&a, 10, 8);
        assert!(svd.s[2] > 1e-3, "true rank directions survive");
        for &s in &svd.s[3..] {
            assert!(s < 1e-4 * svd.s[0], "spurious singular value {s}");
        }
        assert!(recon_err(&svd, &a, 10, 8) < 1e-4 * max_abs(&a));
    }

    #[test]
    fn deterministic_across_calls() {
        let mut rng = XorShift::new(5);
        let a = randv(&mut rng, 16 * 12, 0.5);
        let s1 = svd_thin(&a, 16, 12);
        let s2 = svd_thin(&a, 16, 12);
        assert_eq!(s1.u, s2.u);
        assert_eq!(s1.s, s2.s);
        assert_eq!(s1.vt, s2.vt);
    }

    #[test]
    fn sanitizes_non_finite() {
        let a = vec![f32::NAN, 1.0, f32::INFINITY, 2.0];
        let svd = svd_thin(&a, 2, 2);
        assert!(svd.s.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn matches_gram_eigenvalues() {
        // sigma_i^2 must equal the eigenvalues of A^T A; cross-check via
        // trace identities: sum sigma^2 == tr(A^T A), sum sigma^4 == ||A^T A||_F^2.
        let mut rng = XorShift::new(6);
        let (m, n) = (14usize, 9usize);
        let a = randv(&mut rng, m * n, 0.8);
        let svd = svd_thin(&a, m, n);
        let mut at = vec![0f32; n * m];
        for i in 0..m {
            for j in 0..n {
                at[j * m + i] = a[i * n + j];
            }
        }
        let gram = matmul_ref(&at, n, m, &a, n);
        let tr: f32 = (0..n).map(|i| gram[i * n + i]).sum();
        let fro2: f32 = gram.iter().map(|&x| x * x).sum();
        let s2: f32 = svd.s.iter().map(|&s| s * s).sum();
        let s4: f32 = svd.s.iter().map(|&s| s * s * s * s).sum();
        assert!((tr - s2).abs() < 1e-3 * tr.abs(), "{tr} vs {s2}");
        assert!((fro2 - s4).abs() < 1e-3 * fro2.abs(), "{fro2} vs {s4}");
    }

    #[test]
    fn cholesky_recovers_spd_factor() {
        // G = B B^T + I is SPD; check L L^T == G.
        let mut rng = XorShift::new(7);
        let n = 10usize;
        let b = randv(&mut rng, n * n, 0.5);
        let mut g = vec![0f64; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut acc = 0f64;
                for t in 0..n {
                    acc += b[i * n + t] as f64 * b[j * n + t] as f64;
                }
                g[i * n + j] = acc + if i == j { 1.0 } else { 0.0 };
            }
        }
        let l = cholesky_lower(&g, n).expect("SPD factors");
        for i in 0..n {
            for j in 0..n {
                let mut acc = 0f64;
                for t in 0..n {
                    acc += l[i * n + t] * l[j * n + t];
                }
                assert!((acc - g[i * n + j]).abs() < 1e-9, "LL^T mismatch at ({i},{j})");
            }
        }
        // upper entries untouched (strictly lower + diagonal only)
        for i in 0..n {
            for j in i + 1..n {
                assert_eq!(l[i * n + j], 0.0);
            }
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        // G = [[1, 2], [2, 1]] has a negative eigenvalue.
        let g = vec![1.0, 2.0, 2.0, 1.0];
        assert!(cholesky_lower(&g, 2).is_none());
    }
}
