//! Pure-Rust thin SVD via one-sided Jacobi — the factorization kernel of
//! the native compression pipeline (no LAPACK offline).
//!
//! One-sided Jacobi orthogonalizes the *columns* of A by plane rotations:
//! after convergence the column norms are the singular values, the
//! normalized columns are U, and the accumulated rotations are V.  It is
//! slower than bidiagonalization-based drivers but is simple, numerically
//! robust (every step is an exact orthogonal transform), and fully
//! deterministic.  Accumulation runs in f64 (mirroring
//! `python/compile/dobi/ipca.py::robust_svd` working precision); the
//! classic entry point [`svd_thin`] is f32 in/out, and [`svd_thin_f64`]
//! exposes the full-precision factors (the train subsystem's
//! finite-difference gradient checks need them).
//!
//! ## Parallel sweeps
//!
//! Pairs are visited in the round-robin tournament ordering: each sweep
//! is `n-1` rounds of `⌊n/2⌋` *disjoint* column pairs.  Because the pairs
//! of a round share no columns, their rotations commute — a round can be
//! fanned across scoped worker threads ([`set_svd_threads`], the
//! `decode_threads` idiom from `lowrank::kernel`, including its
//! work-floor guard) and the result is **bit-identical for every thread
//! count**: the ordering is fixed, each pair's rotation depends only on
//! its own two columns, and no accumulation order changes.

/// Relative off-diagonal threshold: rotate while
/// `|a_p . a_q| > TOL * ||a_p|| * ||a_q||`.
const TOL: f64 = 1e-9;

/// Sweep cap — one-sided Jacobi converges quadratically, so ~10 sweeps
/// suffice in practice; 60 is a generous safety bound.
const MAX_SWEEPS: usize = 60;

thread_local! {
    /// Worker threads the Jacobi sweeps may fan rotation pairs across.
    /// Thread-local like `kernel::DECODE_THREADS`: `dobi compress
    /// --svd-threads` sets it on the one thread running the pipeline, so
    /// concurrent SVDs elsewhere can't oversubscribe the host.
    static SVD_THREADS: std::cell::Cell<usize> = const { std::cell::Cell::new(1) };
}

/// Work floor: a round is threaded only when its pair count times the
/// column length clears this (each pair costs ~5·m MACs).  Workers are
/// scoped-spawned per ROUND — tens of µs each — so the floor is set
/// where a round's compute (~5·2^16 MACs ≈ hundreds of µs) clearly
/// dominates the spawn; a persistent worker pool would lift the
/// overhead for smaller rounds (same follow-up as the GEMM pool).
const PAR_MIN_PAIR_ELEMS: usize = 1 << 16;

/// Set the calling thread's Jacobi worker count (clamped to >= 1).
pub fn set_svd_threads(n: usize) {
    SVD_THREADS.with(|c| c.set(n.max(1)));
}

/// The calling thread's Jacobi worker count.
pub fn svd_threads() -> usize {
    SVD_THREADS.with(|c| c.get())
}

thread_local! {
    /// Sweeps the calling thread's most recent [`jacobi_tall`] run took
    /// to converge — the per-target SVD-iterations figure the compress
    /// run report records.  Thread-local like [`SVD_THREADS`]: the
    /// pipeline reads it right after each decomposition on its own
    /// thread, so concurrent SVDs elsewhere can't clobber it.
    static LAST_SWEEPS: std::cell::Cell<usize> = const { std::cell::Cell::new(0) };
}

/// Jacobi sweeps the calling thread's most recent thin SVD took.
pub fn last_sweeps() -> usize {
    LAST_SWEEPS.with(|c| c.get())
}

/// Thin SVD `A = U diag(s) Vt` of a row-major (m, n) matrix with
/// `r = min(m, n)`: `u` is (m, r), `s` is descending, `vt` is (r, n).
#[derive(Debug, Clone)]
pub struct Svd {
    pub u: Vec<f32>,
    pub s: Vec<f32>,
    pub vt: Vec<f32>,
}

impl Svd {
    pub fn rank(&self) -> usize {
        self.s.len()
    }
}

/// [`Svd`] at the f64 working precision of the Jacobi core.
#[derive(Debug, Clone)]
pub struct SvdF64 {
    pub u: Vec<f64>,
    pub s: Vec<f64>,
    pub vt: Vec<f64>,
}

impl SvdF64 {
    pub fn rank(&self) -> usize {
        self.s.len()
    }
}

/// Thin SVD of a row-major (m, n) f32 matrix.  Non-finite entries are
/// sanitized to zero (the `robust_svd` contract).  Singular vectors of
/// zero singular values come out as zero columns — callers truncate well
/// above that regime.
pub fn svd_thin(a: &[f32], m: usize, n: usize) -> Svd {
    assert_eq!(a.len(), m * n, "svd_thin: {m}x{n} needs {} elems", m * n);
    // sanitize fused into the widening cast: ONE pass over the matrix
    let clean: Vec<f64> =
        a.iter().map(|&x| if x.is_finite() { x as f64 } else { 0.0 }).collect();
    let svd = svd_thin_sanitized(clean, m, n);
    Svd {
        u: svd.u.iter().map(|&x| x as f32).collect(),
        s: svd.s.iter().map(|&x| x as f32).collect(),
        vt: svd.vt.iter().map(|&x| x as f32).collect(),
    }
}

/// Thin SVD of a row-major (m, n) f64 matrix (non-finite sanitized to 0).
pub fn svd_thin_f64(a: &[f64], m: usize, n: usize) -> SvdF64 {
    assert_eq!(a.len(), m * n, "svd_thin_f64: {m}x{n} needs {} elems", m * n);
    let clean: Vec<f64> = a.iter().map(|&x| if x.is_finite() { x } else { 0.0 }).collect();
    svd_thin_sanitized(clean, m, n)
}

/// Core thin-SVD entry over an already-sanitized owned buffer.
fn svd_thin_sanitized(clean: Vec<f64>, m: usize, n: usize) -> SvdF64 {
    assert!(m > 0 && n > 0, "svd_thin: empty matrix");
    if m >= n {
        let (u, s, vt) = jacobi_tall(&clean, m, n);
        SvdF64 { u, s, vt }
    } else {
        // Wide: decompose the transpose.  A^T = U1 S V1^T  =>
        // A = V1 S U1^T, so U = V1 (m, m) and Vt = U1^T (m, n).
        let mut at = vec![0f64; n * m];
        for i in 0..m {
            for j in 0..n {
                at[j * m + i] = clean[i * n + j];
            }
        }
        let (u1, s, vt1) = jacobi_tall(&at, n, m); // u1 (n, m), vt1 (m, m)
        let mut u = vec![0f64; m * m];
        for r in 0..m {
            for c in 0..m {
                u[r * m + c] = vt1[c * m + r];
            }
        }
        let mut vt = vec![0f64; m * n];
        for r in 0..m {
            for c in 0..n {
                vt[r * n + c] = u1[c * m + r];
            }
        }
        SvdF64 { u, s, vt }
    }
}

/// The round-robin (circle-method) tournament schedule for `n` columns:
/// `n-1` rounds (n rounded up to even) of disjoint `(p < q)` pairs, every
/// unordered pair exactly once per cycle.  Fixed schedule → fixed
/// rotation ordering → deterministic factors at any thread count.
fn round_robin_rounds(n: usize) -> Vec<Vec<(usize, usize)>> {
    if n < 2 {
        return Vec::new();
    }
    let np = n + n % 2; // pad odd n with a bye slot
    let mut rot: Vec<usize> = (1..np).collect();
    let mut rounds = Vec::with_capacity(np - 1);
    for _ in 0..np - 1 {
        let mut pairs = Vec::with_capacity(np / 2);
        let seat = |i: usize| if i == 0 { 0 } else { rot[i - 1] };
        for i in 0..np / 2 {
            let (a, b) = (seat(i), seat(np - 1 - i));
            if a < n && b < n {
                pairs.push((a.min(b), a.max(b)));
            }
        }
        rounds.push(pairs);
        rot.rotate_left(1);
    }
    rounds
}

/// One Jacobi pair step on owned column data: decide from the current
/// dot products, rotate both the data columns (len m) and the V
/// accumulator columns (len n).  Returns whether a rotation was applied.
/// Depends only on this pair's columns — the disjoint pairs of a round
/// can run in any order (or in parallel) with identical results.
fn rotate_if_needed(cp: &mut [f64], cq: &mut [f64], vp: &mut [f64], vq: &mut [f64]) -> bool {
    let mut alpha = 0f64;
    let mut beta = 0f64;
    let mut gamma = 0f64;
    for (x, y) in cp.iter().zip(cq.iter()) {
        alpha += x * x;
        beta += y * y;
        gamma += x * y;
    }
    if gamma == 0.0 || gamma.abs() <= TOL * (alpha * beta).sqrt() {
        return false;
    }
    let zeta = (beta - alpha) / (2.0 * gamma);
    let t = if zeta >= 0.0 {
        1.0 / (zeta + (1.0 + zeta * zeta).sqrt())
    } else {
        -1.0 / (-zeta + (1.0 + zeta * zeta).sqrt())
    };
    let c = 1.0 / (1.0 + t * t).sqrt();
    let s = c * t;
    for (x, y) in cp.iter_mut().zip(cq.iter_mut()) {
        let (a, b) = (*x, *y);
        *x = c * a - s * b;
        *y = s * a + c * b;
    }
    for (x, y) in vp.iter_mut().zip(vq.iter_mut()) {
        let (a, b) = (*x, *y);
        *x = c * a - s * b;
        *y = s * a + c * b;
    }
    true
}

/// One-sided Jacobi on a tall row-major (m, n) matrix, m >= n.
/// Returns (u: (m, n) row-major, s: n descending, vt: (n, n) row-major).
fn jacobi_tall(a: &[f64], m: usize, n: usize) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
    // Per-call worker count: the caller's setting, gated by the work
    // floor (threading a trivial round just pays spawn cost).
    let threads = if (n / 2) * m >= PAR_MIN_PAIR_ELEMS { svd_threads() } else { 1 };
    jacobi_tall_threads(a, m, n, threads)
}

/// [`jacobi_tall`] with an explicit worker count (the floor-free entry the
/// bit-equality tests drive directly, mirroring `matmul_into_striped`).
fn jacobi_tall_threads(a: &[f64], m: usize, n: usize,
                       threads: usize) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
    debug_assert!(m >= n);
    // Column-owned working copies: cols[j] is column j of A (len m),
    // vcols[j] is column j of V (len n, accumulated rotations, init I).
    let mut cols: Vec<Vec<f64>> = (0..n)
        .map(|j| (0..m).map(|i| a[i * n + j]).collect())
        .collect();
    let mut vcols: Vec<Vec<f64>> = (0..n)
        .map(|j| {
            let mut v = vec![0f64; n];
            v[j] = 1.0;
            v
        })
        .collect();
    let rounds = round_robin_rounds(n);
    let mut sweeps = 0usize;
    for _sweep in 0..MAX_SWEEPS {
        sweeps += 1;
        let mut converged = true;
        for pairs in &rounds {
            let rotated = if threads > 1 && pairs.len() >= 2 {
                run_round_parallel(&mut cols, &mut vcols, pairs, threads)
            } else {
                let mut any = false;
                for &(p, q) in pairs {
                    let (cp, cq) = pair_mut(&mut cols, p, q);
                    let (vp, vq) = pair_mut(&mut vcols, p, q);
                    any |= rotate_if_needed(cp, cq, vp, vq);
                }
                any
            };
            converged &= !rotated;
        }
        if converged {
            break;
        }
    }
    LAST_SWEEPS.with(|c| c.set(sweeps));
    // Column norms are the singular values; sort descending (ties by
    // original index, so the result is deterministic).
    let sigma: Vec<f64> = (0..n)
        .map(|j| cols[j].iter().map(|&x| x * x).sum::<f64>().sqrt())
        .collect();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&x, &y| sigma[y].partial_cmp(&sigma[x]).unwrap().then(x.cmp(&y)));
    let mut u = vec![0f64; m * n];
    let mut s_out = vec![0f64; n];
    let mut vt = vec![0f64; n * n];
    for (jj, &j) in order.iter().enumerate() {
        s_out[jj] = sigma[j];
        if sigma[j] > 1e-300 {
            let inv = 1.0 / sigma[j];
            for i in 0..m {
                u[i * n + jj] = cols[j][i] * inv;
            }
        }
        vt[jj * n..(jj + 1) * n].copy_from_slice(&vcols[j]);
    }
    (u, s_out, vt)
}

/// Two distinct mutable column borrows out of the column store.
fn pair_mut(cols: &mut [Vec<f64>], p: usize, q: usize) -> (&mut [f64], &mut [f64]) {
    debug_assert!(p < q);
    let (lo, hi) = cols.split_at_mut(q);
    (&mut lo[p], &mut hi[0])
}

/// Run one round's disjoint pairs across scoped worker threads.  Each
/// worker *owns* its pairs' four column vectors (moved out of the store,
/// moved back after the join) — no shared mutable state, no unsafe.
/// Chunking is deterministic but irrelevant to the result: disjoint
/// pairs commute exactly.
fn run_round_parallel(cols: &mut [Vec<f64>], vcols: &mut [Vec<f64>],
                      pairs: &[(usize, usize)], threads: usize) -> bool {
    struct Task {
        p: usize,
        q: usize,
        cp: Vec<f64>,
        cq: Vec<f64>,
        vp: Vec<f64>,
        vq: Vec<f64>,
    }
    let mut tasks: Vec<Task> = pairs
        .iter()
        .map(|&(p, q)| Task {
            p,
            q,
            cp: std::mem::take(&mut cols[p]),
            cq: std::mem::take(&mut cols[q]),
            vp: std::mem::take(&mut vcols[p]),
            vq: std::mem::take(&mut vcols[q]),
        })
        .collect();
    let workers = threads.min(tasks.len());
    let chunk = tasks.len().div_ceil(workers);
    let mut any = false;
    std::thread::scope(|scope| {
        let handles: Vec<_> = tasks
            .chunks_mut(chunk)
            .map(|batch| {
                scope.spawn(move || {
                    let mut rotated = false;
                    for t in batch {
                        rotated |=
                            rotate_if_needed(&mut t.cp, &mut t.cq, &mut t.vp, &mut t.vq);
                    }
                    rotated
                })
            })
            .collect();
        for h in handles {
            any |= h.join().expect("jacobi worker panicked");
        }
    });
    for t in tasks {
        cols[t.p] = t.cp;
        cols[t.q] = t.cq;
        vcols[t.p] = t.vp;
        vcols[t.q] = t.vq;
    }
    any
}

/// Lower-triangular Cholesky factor of a symmetric PSD row-major (n, n)
/// matrix: `G = L L^T`.  Returns `None` when a pivot is non-positive
/// (G not positive definite) — callers jitter the diagonal and retry.
pub fn cholesky_lower(g: &[f64], n: usize) -> Option<Vec<f64>> {
    assert_eq!(g.len(), n * n, "cholesky: shape mismatch");
    let mut l = vec![0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut s = g[i * n + j];
            for t in 0..j {
                s -= l[i * n + t] * l[j * n + t];
            }
            if i == j {
                if s <= 0.0 {
                    return None;
                }
                l[i * n + i] = s.sqrt();
            } else {
                l[i * n + j] = s / l[j * n + j];
            }
        }
    }
    Some(l)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::testutil::{matmul_ref, randv};
    use crate::mathx::XorShift;

    fn max_abs(xs: &[f32]) -> f32 {
        xs.iter().fold(0f32, |acc, &x| acc.max(x.abs()))
    }

    /// ||U diag(s) Vt - A||_max
    fn recon_err(svd: &Svd, a: &[f32], m: usize, n: usize) -> f32 {
        let r = svd.rank();
        let mut us = svd.u.clone(); // (m, r) scaled by s
        for i in 0..m {
            for j in 0..r {
                us[i * r + j] *= svd.s[j];
            }
        }
        let recon = matmul_ref(&us, m, r, &svd.vt, n);
        recon.iter().zip(a).map(|(x, y)| (x - y).abs()).fold(0f32, f32::max)
    }

    /// ||M^T M - I||_max for a row-major (rows, c) matrix with orthonormal
    /// columns.
    fn orth_err(mat: &[f32], rows: usize, c: usize) -> f32 {
        let mut worst = 0f32;
        for i in 0..c {
            for j in 0..c {
                let mut acc = 0f32;
                for r in 0..rows {
                    acc += mat[r * c + i] * mat[r * c + j];
                }
                let want = if i == j { 1.0 } else { 0.0 };
                worst = worst.max((acc - want).abs());
            }
        }
        worst
    }

    #[test]
    fn known_diagonal_decomposition() {
        // A = diag(3, 2, 1) embedded in 4x3: exact singular values known.
        let mut a = vec![0f32; 12];
        a[0] = 3.0;
        a[1 * 3 + 1] = 2.0;
        a[2 * 3 + 2] = 1.0;
        let svd = svd_thin(&a, 4, 3);
        assert!((svd.s[0] - 3.0).abs() < 1e-5);
        assert!((svd.s[1] - 2.0).abs() < 1e-5);
        assert!((svd.s[2] - 1.0).abs() < 1e-5);
        assert!(recon_err(&svd, &a, 4, 3) < 1e-5);
    }

    #[test]
    fn known_rank_one_outer_product() {
        // A = u v^T with ||u|| = 5, ||v|| = sqrt(2): sigma = 5*sqrt(2).
        let u = [3.0f32, 4.0];
        let v = [1.0f32, 1.0, 0.0];
        let mut a = vec![0f32; 6];
        for i in 0..2 {
            for j in 0..3 {
                a[i * 3 + j] = u[i] * v[j];
            }
        }
        let svd = svd_thin(&a, 2, 3);
        assert!((svd.s[0] - 5.0 * 2f32.sqrt()).abs() < 1e-4, "sigma {}", svd.s[0]);
        assert!(svd.s[1].abs() < 1e-5, "rank-1 matrix has one singular value");
        assert!(recon_err(&svd, &a, 2, 3) < 1e-5);
    }

    #[test]
    fn orthogonality_and_reconstruction_random() {
        let mut rng = XorShift::new(3);
        for &(m, n) in &[(8usize, 8usize), (20, 12), (12, 20), (40, 9), (1, 5), (5, 1)] {
            let a = randv(&mut rng, m * n, 0.7);
            let svd = svd_thin(&a, m, n);
            let r = m.min(n);
            assert_eq!(svd.u.len(), m * r);
            assert_eq!(svd.vt.len(), r * n);
            let scale = max_abs(&a).max(1.0);
            assert!(recon_err(&svd, &a, m, n) < 1e-4 * scale, "{m}x{n} recon");
            assert!(orth_err(&svd.u, m, r) < 1e-4, "{m}x{n} U orth");
            // rows of Vt are the columns of V: check V^T V = I via the
            // transpose view (Vt is (r, n); its rows must be orthonormal).
            let mut v = vec![0f32; n * r];
            for i in 0..r {
                for j in 0..n {
                    v[j * r + i] = svd.vt[i * n + j];
                }
            }
            assert!(orth_err(&v, n, r) < 1e-4, "{m}x{n} V orth");
            // descending order
            for w in svd.s.windows(2) {
                assert!(w[0] >= w[1], "singular values not sorted: {:?}", svd.s);
            }
        }
    }

    #[test]
    fn rank_deficient_input_truncates_cleanly() {
        // A = B C with inner dim 3 => exactly 3 nonzero singular values.
        let mut rng = XorShift::new(4);
        let b = randv(&mut rng, 10 * 3, 1.0);
        let c = randv(&mut rng, 3 * 8, 1.0);
        let a = matmul_ref(&b, 10, 3, &c, 8);
        let svd = svd_thin(&a, 10, 8);
        assert!(svd.s[2] > 1e-3, "true rank directions survive");
        for &s in &svd.s[3..] {
            assert!(s < 1e-4 * svd.s[0], "spurious singular value {s}");
        }
        assert!(recon_err(&svd, &a, 10, 8) < 1e-4 * max_abs(&a));
    }

    #[test]
    fn deterministic_across_calls() {
        let mut rng = XorShift::new(5);
        let a = randv(&mut rng, 16 * 12, 0.5);
        let s1 = svd_thin(&a, 16, 12);
        let s2 = svd_thin(&a, 16, 12);
        assert_eq!(s1.u, s2.u);
        assert_eq!(s1.s, s2.s);
        assert_eq!(s1.vt, s2.vt);
    }

    #[test]
    fn sanitizes_non_finite() {
        let a = vec![f32::NAN, 1.0, f32::INFINITY, 2.0];
        let svd = svd_thin(&a, 2, 2);
        assert!(svd.s.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn matches_gram_eigenvalues() {
        // sigma_i^2 must equal the eigenvalues of A^T A; cross-check via
        // trace identities: sum sigma^2 == tr(A^T A), sum sigma^4 == ||A^T A||_F^2.
        let mut rng = XorShift::new(6);
        let (m, n) = (14usize, 9usize);
        let a = randv(&mut rng, m * n, 0.8);
        let svd = svd_thin(&a, m, n);
        let mut at = vec![0f32; n * m];
        for i in 0..m {
            for j in 0..n {
                at[j * m + i] = a[i * n + j];
            }
        }
        let gram = matmul_ref(&at, n, m, &a, n);
        let tr: f32 = (0..n).map(|i| gram[i * n + i]).sum();
        let fro2: f32 = gram.iter().map(|&x| x * x).sum();
        let s2: f32 = svd.s.iter().map(|&s| s * s).sum();
        let s4: f32 = svd.s.iter().map(|&s| s * s * s * s).sum();
        assert!((tr - s2).abs() < 1e-3 * tr.abs(), "{tr} vs {s2}");
        assert!((fro2 - s4).abs() < 1e-3 * fro2.abs(), "{fro2} vs {s4}");
    }

    #[test]
    fn f64_entry_matches_f32_entry() {
        let mut rng = XorShift::new(8);
        let a32 = randv(&mut rng, 15 * 10, 0.6);
        let a64: Vec<f64> = a32.iter().map(|&x| x as f64).collect();
        let s32 = svd_thin(&a32, 15, 10);
        let s64 = svd_thin_f64(&a64, 15, 10);
        assert_eq!(s64.rank(), 10);
        for (a, b) in s32.s.iter().zip(&s64.s) {
            assert!((*a as f64 - b).abs() < 1e-6, "{a} vs {b}");
        }
        // f64 factors are strictly more orthogonal than the f32 casts
        let u32: Vec<f32> = s64.u.iter().map(|&x| x as f32).collect();
        assert!(orth_err(&u32, 15, 10) < 1e-5);
    }

    #[test]
    fn round_robin_schedule_is_a_partition() {
        for n in [2usize, 3, 5, 8, 13] {
            let rounds = round_robin_rounds(n);
            let expected_rounds = n + n % 2 - 1;
            assert_eq!(rounds.len(), expected_rounds, "n={n}");
            let mut seen = std::collections::BTreeSet::new();
            for pairs in &rounds {
                let mut used = std::collections::BTreeSet::new();
                for &(p, q) in pairs {
                    assert!(p < q && q < n, "n={n}: bad pair ({p},{q})");
                    // disjoint within the round — the parallel-safety invariant
                    assert!(used.insert(p) && used.insert(q),
                            "n={n}: column reused within a round");
                    assert!(seen.insert((p, q)), "n={n}: pair ({p},{q}) repeated");
                }
            }
            assert_eq!(seen.len(), n * (n - 1) / 2, "n={n}: pairs missing");
        }
        assert!(round_robin_rounds(1).is_empty());
    }

    #[test]
    fn threaded_sweeps_bit_identical_to_serial() {
        // Forced worker counts through the floor-free entry, exactly like
        // the kernel's striped-GEMM test: every thread count must produce
        // the same bits, because each round's pairs are disjoint.
        let mut rng = XorShift::new(9);
        for &(m, n) in &[(24usize, 16usize), (20, 7), (12, 12)] {
            let a: Vec<f64> =
                randv(&mut rng, m * n, 0.5).iter().map(|&x| x as f64).collect();
            let serial = jacobi_tall_threads(&a, m, n, 1);
            for t in [2usize, 3, 4] {
                let par = jacobi_tall_threads(&a, m, n, t);
                assert_eq!(serial.0, par.0, "{m}x{n} u drifted at {t} threads");
                assert_eq!(serial.1, par.1, "{m}x{n} s drifted at {t} threads");
                assert_eq!(serial.2, par.2, "{m}x{n} vt drifted at {t} threads");
            }
        }
    }

    #[test]
    fn public_path_bit_identical_above_work_floor() {
        // (n/2)*m = 4*16384 == PAR_MIN_PAIR_ELEMS: the public entry
        // engages the worker pool, and must still match the serial bits.
        let (m, n) = (16384usize, 8usize);
        let mut rng = XorShift::new(10);
        let a = randv(&mut rng, m * n, 0.3);
        set_svd_threads(1);
        let serial = svd_thin(&a, m, n);
        set_svd_threads(3);
        let par = svd_thin(&a, m, n);
        set_svd_threads(1);
        assert_eq!(serial.u, par.u);
        assert_eq!(serial.s, par.s);
        assert_eq!(serial.vt, par.vt);
    }

    #[test]
    fn svd_threads_clamped_and_thread_local() {
        set_svd_threads(0);
        assert_eq!(svd_threads(), 1, "zero must clamp to 1");
        set_svd_threads(5);
        assert_eq!(svd_threads(), 5);
        std::thread::spawn(|| {
            assert_eq!(svd_threads(), 1, "setting must not leak across threads");
            set_svd_threads(9);
        })
        .join()
        .unwrap();
        assert_eq!(svd_threads(), 5);
        set_svd_threads(1);
    }

    #[test]
    fn last_sweeps_reports_the_most_recent_decomposition() {
        let mut rng = XorShift::new(12);
        let a = randv(&mut rng, 12 * 8, 0.5);
        let _ = svd_thin(&a, 12, 8);
        let s = last_sweeps();
        assert!((1..=MAX_SWEEPS).contains(&s), "sweeps out of range: {s}");
        std::thread::spawn(|| {
            assert_eq!(last_sweeps(), 0, "sweep count must not leak across threads");
        })
        .join()
        .unwrap();
    }

    #[test]
    fn cholesky_recovers_spd_factor() {
        // G = B B^T + I is SPD; check L L^T == G.
        let mut rng = XorShift::new(7);
        let n = 10usize;
        let b = randv(&mut rng, n * n, 0.5);
        let mut g = vec![0f64; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut acc = 0f64;
                for t in 0..n {
                    acc += b[i * n + t] as f64 * b[j * n + t] as f64;
                }
                g[i * n + j] = acc + if i == j { 1.0 } else { 0.0 };
            }
        }
        let l = cholesky_lower(&g, n).expect("SPD factors");
        for i in 0..n {
            for j in 0..n {
                let mut acc = 0f64;
                for t in 0..n {
                    acc += l[i * n + t] * l[j * n + t];
                }
                assert!((acc - g[i * n + j]).abs() < 1e-9, "LL^T mismatch at ({i},{j})");
            }
        }
        // upper entries untouched (strictly lower + diagonal only)
        for i in 0..n {
            for j in i + 1..n {
                assert_eq!(l[i * n + j], 0.0);
            }
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        // G = [[1, 2], [2, 1]] has a negative eigenvalue.
        let g = vec![1.0, 2.0, 2.0, 1.0];
        assert!(cholesky_lower(&g, 2).is_none());
    }
}
