//! Differentiable truncation-position optimizer — the "Dobi" in
//! Dobi-SVD, natively.
//!
//! The waterfill allocator (`rank::allocate_ranks`) greedily walks the
//! discrete rank grid.  This subsystem optimizes the same whitened
//! truncation objective *continuously*, the way the paper does: each
//! target's truncation position becomes a learnable real number, hard
//! truncation relaxes to temperature-annealed sigmoid gates over the
//! singular values ([`gate`]), the objective's gradients flow through a
//! tiny reverse-mode tape ([`tape`]), and Adam plus an exact Lagrangian
//! budget renormalization ([`optim`]) keep the expected stored-parameter
//! cost pinned to the budget at every step.  [`taylor`] holds the
//! FD-validated Taylor-stabilized adjoint through the gated
//! truncated-SVD reconstruction; the training loop consumes it through
//! its closed-form [`taylor::spectrum_sensitivity`] score, which damps
//! the learning rate of targets whose near-degenerate spectra would make
//! that reconstruction gradient explode (the optimizer's own gate
//! gradients do NOT route through the full adjoint — the spectra are
//! fixed inputs here).
//!
//! [`learn_ranks`] drives the loop and rounds the converged positions to
//! integer ranks.  The rounding is **waterfill-guarded**: the discrete
//! greedy solution is always computed at the same budget, and the learned
//! allocation is kept only when it strictly improves the discrete
//! surrogate loss — so `--alloc learned` can never regress the objective
//! against the baseline it claims to beat, and ties collapse to the
//! greedy allocation bit-for-bit.

pub mod gate;
pub mod optim;
pub mod tape;
pub mod taylor;

use super::rank::{allocate_ranks, RankAllocator, TargetSpectrum};
use gate::{surrogate_loss, GateModel, TAU_HI, TAU_LO};
use optim::{project_to_budget, Adam};

/// Knobs of the truncation-position optimizer (CLI: `--train-iters`,
/// `--train-lr`; defaults tuned on the synth nano twin).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Optimization steps (each: objective + Adam + budget projection).
    pub iters: usize,
    /// Adam learning rate on the positions (rank units per step, before
    /// the per-target sensitivity damping).
    pub lr: f64,
    /// Dual-ascent rate coupling the projection multiplier back into the
    /// objective's Lagrangian term.
    pub dual_rate: f64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig { iters: 300, lr: 0.3, dual_rate: 0.5 }
    }
}

/// Bound on the Lagrangian multiplier.  The tail and cost terms of the
/// objective are both normalized to O(1), so the equilibrium multiplier
/// is O(1) too; the clamp only engages when the budget projection
/// saturates (budget outside the attainable sigmoid range).
const LAMBDA_MAX: f64 = 1e3;

/// Which allocation the waterfill guard kept.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AllocPick {
    /// The learned rounding strictly improved the discrete surrogate.
    Learned,
    /// The greedy baseline was at least as good (incl. exact ties).
    Waterfill,
}

/// One sampled optimizer step of a [`learn_ranks`] run — the trajectory
/// the compress run report persists per release (and the compress trace
/// replays as `compress_train_iter` instants).
#[derive(Debug, Clone, PartialEq)]
pub struct TrainSample {
    /// 0-based optimizer step this sample was taken at.
    pub iter: usize,
    /// Normalized truncation loss at this step.
    pub tail: f64,
    /// Lagrangian multiplier after this step's dual update.
    pub lambda: f64,
    /// Annealed gate temperature at this step.
    pub tau: f64,
    /// Expected stored params after this step's budget projection.
    pub expected_cost: f64,
    /// µs since the loop started when the step finished.
    pub t_us: u64,
}

/// Cap on persisted trajectory samples: long runs are subsampled to an
/// even stride so the run report stays bounded (first/last always kept).
const TRAJECTORY_CAP: usize = 256;

/// Diagnostics of one [`learn_ranks`] run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub iters: usize,
    /// Normalized truncation loss at the (projected) warm start / end.
    pub tail_init: f64,
    pub tail_final: f64,
    /// Expected stored params after the final projection (≈ budget).
    pub expected_cost: f64,
    /// Final Lagrangian multiplier (positive = budget binds).
    pub lambda: f64,
    /// Per-target expected-cost shares (sum to 1) at convergence.
    pub shares: Vec<f64>,
    /// Per-target Taylor sensitivity of the truncation gradient
    /// ([`taylor::spectrum_sensitivity`]); large = near-degenerate
    /// spectrum under a half-open gate, damped learning rate.
    pub sensitivity: Vec<f64>,
    /// Discrete surrogate loss of both candidate allocations.
    pub learned_surrogate: f64,
    pub waterfill_surrogate: f64,
    pub picked: AllocPick,
    /// Sampled per-step loss/λ/τ/budget trajectory (≤ [`TRAJECTORY_CAP`]
    /// entries, empty when the floor short-circuit skipped the loop).
    pub trajectory: Vec<TrainSample>,
}

/// Learn per-target truncation ranks under a global stored-parameter
/// budget.  Returns `(ranks, spent, report)`; like the waterfill, the
/// `k_min` floor is granted even when it overshoots a tiny budget.
pub fn learn_ranks(specs: &[TargetSpectrum], budget: usize, k_min: usize,
                   cfg: &TrainConfig) -> (Vec<usize>, usize, TrainReport) {
    let (wf_ks, wf_spent) = allocate_ranks(specs, budget, k_min);
    // No targets, or a budget at/below the floor cost: nothing for the
    // optimizer to trade — the floor allocation IS the answer (and a
    // zero target budget would otherwise drive every gate to an exactly
    // underflowed 0.0, where the budget-share normalize has no mass).
    let floor_cost: usize = specs
        .iter()
        .map(|t| k_min.max(1).min(t.max_rank()) * t.unit_cost())
        .sum();
    if specs.is_empty() || budget <= floor_cost {
        let surrogate = surrogate_loss(specs, &wf_ks);
        let energy: f64 = specs.iter().map(|t| t.sigma2.iter().sum::<f64>()).sum();
        let tail = if energy > 0.0 { surrogate / energy } else { 0.0 };
        let report = TrainReport {
            iters: 0,
            tail_init: tail,
            tail_final: tail,
            expected_cost: wf_spent as f64,
            lambda: 0.0,
            shares: Vec::new(),
            sensitivity: vec![0.0; specs.len()],
            learned_surrogate: surrogate,
            waterfill_surrogate: surrogate,
            picked: AllocPick::Waterfill,
            trajectory: Vec::new(),
        };
        return (wf_ks, wf_spent, report);
    }

    // Warm start at the greedy solution, pinned to the budget.
    let mut model = GateModel::from_ranks(specs, &wf_ks, k_min);
    project_to_budget(&mut model, budget as f64);

    // Per-target conditioning: spectra with near-degenerate pairs under
    // half-open gates have exploding (Taylor-bounded) reconstruction
    // gradients — move their truncation boundary more cautiously.
    let sensitivity: Vec<f64> = (0..specs.len())
        .map(|i| {
            let sigma: Vec<f64> =
                model.targets[i].sigma2.iter().map(|&s2| s2.max(0.0).sqrt()).collect();
            taylor::spectrum_sensitivity(&sigma, &model.gates(i))
        })
        .collect();
    let mean_sens =
        sensitivity.iter().sum::<f64>() / sensitivity.len() as f64;
    let lr_scale: Vec<f64> = sensitivity
        .iter()
        .map(|&s| if mean_sens > 0.0 { 1.0 / (1.0 + s / mean_sens) } else { 1.0 })
        .collect();

    let tail_init = model.objective(0.0).tail;
    let mut adam = Adam::new(cfg.lr, specs.len());
    let mut lambda = 0.0f64;
    // Even-stride subsampling keeps the persisted trajectory bounded;
    // the final step is always appended below.
    let stride = cfg.iters.div_ceil(TRAJECTORY_CAP).max(1);
    let mut trajectory = Vec::with_capacity(cfg.iters.min(TRAJECTORY_CAP) + 1);
    let loop_start = std::time::Instant::now();
    for step in 0..cfg.iters {
        // anneal the soft step: wide early (gradients see far-away
        // indices), sharp late (expected ranks ≈ integer ranks)
        let frac = if cfg.iters > 1 { step as f64 / (cfg.iters - 1) as f64 } else { 1.0 };
        model.tau = TAU_HI * (TAU_LO / TAU_HI).powf(frac);
        let obj = model.objective(lambda);
        adam.step(&mut model.pos, &obj.grad, &lr_scale);
        let delta = project_to_budget(&mut model, budget as f64);
        // Dual tracking, bounded: a saturated projection (budget at or
        // beyond the attainable sigmoid range, e.g. --ratio 1.0) returns
        // the full ±bracket as delta — clamping keeps λ and the reported
        // diagnostics on the O(1) scale of the normalized objective
        // instead of integrating ±1e4 per step into garbage.
        lambda = (lambda + cfg.dual_rate * delta).clamp(-LAMBDA_MAX, LAMBDA_MAX);
        if step % stride == 0 || step + 1 == cfg.iters {
            trajectory.push(TrainSample {
                iter: step,
                tail: obj.tail,
                lambda,
                tau: model.tau,
                expected_cost: obj.expected_cost,
                t_us: loop_start.elapsed().as_micros() as u64,
            });
        }
    }
    let final_obj = model.objective(lambda); // iters == 0: the warm start

    // Round, then guard against the greedy baseline on the discrete
    // surrogate: learned wins only by strict improvement.
    let (lk, lspent) = model.round_to_ranks(budget);
    let learned_surrogate = surrogate_loss(specs, &lk);
    let waterfill_surrogate = surrogate_loss(specs, &wf_ks);
    let (ks, spent, picked) = if learned_surrogate < waterfill_surrogate {
        (lk, lspent, AllocPick::Learned)
    } else {
        (wf_ks, wf_spent, AllocPick::Waterfill)
    };
    let report = TrainReport {
        iters: cfg.iters,
        tail_init,
        tail_final: final_obj.tail,
        expected_cost: final_obj.expected_cost,
        lambda,
        shares: final_obj.shares,
        sensitivity,
        learned_surrogate,
        waterfill_surrogate,
        picked,
        trajectory,
    };
    (ks, spent, report)
}

/// The learned allocator behind `dobi compress --alloc learned`.  The
/// trait's return carries only the allocation; the optimizer diagnostics
/// of the latest [`RankAllocator::allocate`] call land in an interior
/// report slot the pipeline drains with [`LearnedAlloc::take_report`].
#[derive(Debug, Clone, Default)]
pub struct LearnedAlloc {
    pub cfg: TrainConfig,
    last_report: std::cell::RefCell<Option<TrainReport>>,
}

impl LearnedAlloc {
    pub fn new(iters: usize, lr: f64) -> LearnedAlloc {
        LearnedAlloc {
            cfg: TrainConfig { iters, lr, ..Default::default() },
            last_report: std::cell::RefCell::new(None),
        }
    }

    /// Diagnostics of the most recent `allocate` call, if any.
    pub fn take_report(&self) -> Option<TrainReport> {
        self.last_report.borrow_mut().take()
    }
}

impl RankAllocator for LearnedAlloc {
    fn name(&self) -> &'static str {
        "learned"
    }

    fn allocate(&self, specs: &[TargetSpectrum], budget: usize,
                k_min: usize) -> (Vec<usize>, usize) {
        let (ks, spent, report) = learn_ranks(specs, budget, k_min, &self.cfg);
        *self.last_report.borrow_mut() = Some(report);
        (ks, spent)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::rank::Waterfill;
    use crate::mathx::XorShift;

    fn spec(name: &str, m: usize, n: usize, sigma2: Vec<f64>) -> TargetSpectrum {
        TargetSpectrum { name: name.into(), m, n, sigma2 }
    }

    /// Deterministic pseudo-random spec set shaped like a small model
    /// (mixed costs, geometric-ish decaying spectra).
    fn spec_set(seed: u64, n_targets: usize) -> Vec<TargetSpectrum> {
        let mut rng = XorShift::new(seed);
        (0..n_targets)
            .map(|i| {
                let (m, n) = if i % 3 == 0 { (24, 16) } else { (16, 24) };
                let decay = 0.8 + 0.15 * (rng.below(100) as f64 / 100.0);
                let scale = 1.0 + rng.below(40) as f64;
                let mut s2: Vec<f64> = (0..16)
                    .map(|j| scale * decay.powi(j as i32) * (0.2 + rng.normal().abs()))
                    .collect();
                s2.sort_by(|a, b| b.partial_cmp(a).unwrap());
                spec(&format!("t{i}"), m, n, s2)
            })
            .collect()
    }

    #[test]
    fn learned_never_loses_to_waterfill_on_the_surrogate() {
        for seed in [3u64, 7, 11, 19] {
            let specs = spec_set(seed, 8);
            let total: usize = specs.iter().map(|t| t.unit_cost() * t.max_rank()).sum();
            let budget = total * 2 / 5;
            let cfg = TrainConfig { iters: 120, ..Default::default() };
            let (ks, spent, report) = learn_ranks(&specs, budget, 1, &cfg);
            assert!(spent <= budget, "seed {seed}: spent {spent} over {budget}");
            assert!(report.learned_surrogate.is_finite());
            let kept = surrogate_loss(&specs, &ks);
            assert!(kept <= report.waterfill_surrogate + 1e-12,
                    "seed {seed}: guard failed: {kept} vs {}", report.waterfill_surrogate);
            if report.picked == AllocPick::Waterfill {
                let (wf, _) = allocate_ranks(&specs, budget, 1);
                assert_eq!(ks, wf, "waterfill pick must return the greedy allocation");
            }
        }
    }

    #[test]
    fn deterministic_across_runs() {
        let specs = spec_set(5, 6);
        let budget: usize =
            specs.iter().map(|t| t.unit_cost() * t.max_rank()).sum::<usize>() / 3;
        let cfg = TrainConfig { iters: 60, ..Default::default() };
        let (a, sa, ra) = learn_ranks(&specs, budget, 1, &cfg);
        let (b, sb, rb) = learn_ranks(&specs, budget, 1, &cfg);
        assert_eq!(a, b);
        assert_eq!(sa, sb);
        assert_eq!(ra.picked, rb.picked);
        assert_eq!(ra.lambda, rb.lambda);
    }

    #[test]
    fn report_diagnostics_are_sane() {
        let specs = spec_set(9, 5);
        let budget: usize =
            specs.iter().map(|t| t.unit_cost() * t.max_rank()).sum::<usize>() / 2;
        let cfg = TrainConfig { iters: 80, ..Default::default() };
        let (_, _, r) = learn_ranks(&specs, budget, 1, &cfg);
        assert_eq!(r.iters, 80);
        assert_eq!(r.shares.len(), 5);
        assert!((r.shares.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(r.sensitivity.iter().all(|s| s.is_finite() && *s >= 0.0));
        assert!(r.lambda.is_finite());
        // projection pinned the expected cost to the budget
        assert!((r.expected_cost - budget as f64).abs() < 1.0,
                "expected {} vs budget {budget}", r.expected_cost);
        assert!(r.tail_init.is_finite() && r.tail_final.is_finite());
        assert!(r.tail_final <= 1.0 + 1e-9 && r.tail_final >= 0.0);
    }

    #[test]
    fn trajectory_samples_the_optimizer_loop() {
        let specs = spec_set(9, 5);
        let budget: usize =
            specs.iter().map(|t| t.unit_cost() * t.max_rank()).sum::<usize>() / 2;
        let cfg = TrainConfig { iters: 80, ..Default::default() };
        let (_, _, r) = learn_ranks(&specs, budget, 1, &cfg);
        assert_eq!(r.trajectory.len(), 80, "80 iters under the cap: one sample each");
        assert_eq!(r.trajectory.last().map(|s| s.iter), Some(79));
        for w in r.trajectory.windows(2) {
            assert!(w[1].iter > w[0].iter, "iters must ascend");
            assert!(w[1].t_us >= w[0].t_us, "time must be monotone");
            assert!(w[1].tau < w[0].tau, "tau anneals downward");
        }
        for s in &r.trajectory {
            assert!(s.tail.is_finite() && s.tail >= 0.0);
            assert!(s.lambda.is_finite() && s.expected_cost.is_finite());
        }
        // long runs subsample to the cap (+1 for the always-kept last step)
        let long = TrainConfig { iters: 600, ..Default::default() };
        let (_, _, rl) = learn_ranks(&specs, budget, 1, &long);
        assert!(rl.trajectory.len() <= TRAJECTORY_CAP + 1,
                "trajectory unbounded: {}", rl.trajectory.len());
        assert_eq!(rl.trajectory.last().map(|s| s.iter), Some(599));
        // the floor short-circuit records nothing
        let (_, _, r0) = learn_ranks(&specs, 0, 2, &TrainConfig::default());
        assert!(r0.trajectory.is_empty());
    }

    #[test]
    fn zero_iters_falls_back_to_waterfill() {
        let specs = spec_set(13, 4);
        let budget: usize =
            specs.iter().map(|t| t.unit_cost() * t.max_rank()).sum::<usize>() / 3;
        let cfg = TrainConfig { iters: 0, ..Default::default() };
        let (ks, _, r) = learn_ranks(&specs, budget, 1, &cfg);
        let (wf, _) = allocate_ranks(&specs, budget, 1);
        assert_eq!(ks, wf, "no optimization steps -> greedy allocation");
        assert_eq!(r.picked, AllocPick::Waterfill);
    }

    #[test]
    fn saturated_budget_stays_bounded_and_fills_ranks() {
        // budget == full capacity: the projection saturates every step
        // (sigmoid sums can only approach sum(r_i)), so the clamped dual
        // must stay on the diagnostic scale and the rounding must still
        // deliver full rank everywhere.
        let specs = spec_set(17, 4);
        let full: usize = specs.iter().map(|t| t.unit_cost() * t.max_rank()).sum();
        let cfg = TrainConfig { iters: 50, ..Default::default() };
        let (ks, spent, r) = learn_ranks(&specs, full, 1, &cfg);
        assert_eq!(spent, full, "full budget must buy full rank");
        for (k, t) in ks.iter().zip(&specs) {
            assert_eq!(*k, t.max_rank());
        }
        assert!(r.lambda.is_finite() && r.lambda.abs() <= 1e3,
                "saturated projection leaked into lambda: {}", r.lambda);
        assert!(r.tail_final.is_finite() && r.expected_cost.is_finite());
    }

    #[test]
    fn empty_specs_are_a_no_op() {
        let (ks, spent, r) = learn_ranks(&[], 100, 1, &TrainConfig::default());
        assert!(ks.is_empty());
        assert_eq!(spent, 0);
        assert_eq!(r.picked, AllocPick::Waterfill);
    }

    #[test]
    fn floor_level_budgets_short_circuit_to_the_floor() {
        // zero / sub-floor budgets must not panic (the projection would
        // otherwise underflow every gate to exactly 0.0) — they return
        // the same floor allocation the waterfill grants
        let specs = spec_set(29, 5);
        for budget in [0usize, 10, 24 * 2] {
            let (ks, spent, r) = learn_ranks(&specs, budget, 2, &TrainConfig::default());
            let (wf, wf_spent) = allocate_ranks(&specs, budget, 2);
            assert_eq!(ks, wf, "budget {budget}");
            assert_eq!(spent, wf_spent);
            assert_eq!(r.picked, AllocPick::Waterfill);
            assert_eq!(r.iters, 0, "no optimization below the floor");
            assert!(r.tail_init.is_finite());
        }
    }

    #[test]
    fn allocator_trait_objects_agree_with_direct_calls() {
        let specs = spec_set(21, 6);
        let budget: usize =
            specs.iter().map(|t| t.unit_cost() * t.max_rank()).sum::<usize>() * 2 / 5;
        let learned = LearnedAlloc::new(60, 0.3);
        let allocs: Vec<Box<dyn RankAllocator>> =
            vec![Box::new(Waterfill), Box::new(learned.clone())];
        assert_eq!(allocs[0].name(), "waterfill");
        assert_eq!(allocs[1].name(), "learned");
        let (wk, ws) = allocs[0].allocate(&specs, budget, 1);
        assert_eq!((wk, ws), allocate_ranks(&specs, budget, 1));
        let (lk, ls) = allocs[1].allocate(&specs, budget, 1);
        assert_eq!((lk, ls), {
            let (k, s, _) = learn_ranks(&specs, budget, 1, &learned.cfg);
            (k, s)
        });
    }

    #[test]
    fn optimizer_converges_near_the_greedy_optimum_before_the_guard() {
        // Concentrated spectra make the optimum unambiguous; the PRE-guard
        // rounded allocation must already be at (or within 5% of) the
        // greedy surrogate — the guard is a safety net, not a crutch.
        let specs = vec![
            spec("hot", 16, 16, (0..16).map(|j| 200.0 * 0.5f64.powi(j)).collect()),
            spec("cold", 16, 16, vec![1.0; 16]),
            spec("warm", 16, 24, (0..16).map(|j| 40.0 * 0.7f64.powi(j)).collect()),
        ];
        let total: usize = specs.iter().map(|t| t.unit_cost() * t.max_rank()).sum();
        let budget = total * 2 / 5;
        let cfg = TrainConfig { iters: 250, ..Default::default() };
        let (ks, spent, r) = learn_ranks(&specs, budget, 1, &cfg);
        assert!(spent <= budget);
        assert!(r.learned_surrogate <= r.waterfill_surrogate * 1.05 + 1e-9,
                "pre-guard rounding drifted: learned {} vs greedy {}",
                r.learned_surrogate, r.waterfill_surrogate);
        // the energy-heavy target must out-rank the flat one
        assert!(ks[0] > ks[1], "allocation ignored the spectrum: {ks:?}");
    }
}
