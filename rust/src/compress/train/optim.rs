//! Optimizer machinery for the gate model: Adam over the truncation
//! positions, plus the Lagrangian renormalization step that pins the
//! expected stored-parameter cost to the budget after every update.
//!
//! The projection solves, by bisection on the shared multiplier step δ,
//!
//! ```text
//! Σ_i c_i Σ_j sigmoid((k̃_i - δ·ĉ_i - j - ½) / τ)  =  budget
//! ```
//!
//! with `ĉ_i = c_i / mean(c)` — a *cost-weighted* logit shift, i.e. one
//! dual-ascent step of the budget Lagrangian rather than a plain uniform
//! shift: targets whose rank units cost more params are pushed harder,
//! which is what makes the optimizer's fixed point balance marginal
//! energy **per parameter** (the waterfill criterion) instead of raw
//! marginal energy.  The expected cost is strictly decreasing in δ, so
//! bisection is exact to tolerance and fully deterministic.  δ feeds back
//! into the objective's λ (dual tracking) so per-position gradients carry
//! the grow/shrink sign Adam needs.

use super::gate::{gate_sum, GateModel};

/// Adam over one scalar position per target, with an optional per-target
/// learning-rate damping (the Taylor sensitivity scaling the driver
/// derives for ill-conditioned spectra).
pub struct Adam {
    lr: f64,
    beta1: f64,
    beta2: f64,
    eps: f64,
    t: u32,
    m: Vec<f64>,
    v: Vec<f64>,
}

impl Adam {
    pub fn new(lr: f64, n: usize) -> Adam {
        Adam { lr, beta1: 0.9, beta2: 0.999, eps: 1e-8, t: 0, m: vec![0.0; n], v: vec![0.0; n] }
    }

    /// One bias-corrected step; `lr_scale[i]` damps target i's step.
    pub fn step(&mut self, pos: &mut [f64], grad: &[f64], lr_scale: &[f64]) {
        assert_eq!(pos.len(), self.m.len(), "adam: position count changed");
        assert_eq!(grad.len(), pos.len());
        assert_eq!(lr_scale.len(), pos.len());
        self.t += 1;
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        for i in 0..pos.len() {
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * grad[i];
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * grad[i] * grad[i];
            let mh = self.m[i] / bc1;
            let vh = self.v[i] / bc2;
            pos[i] -= self.lr * lr_scale[i] * mh / (vh.sqrt() + self.eps);
        }
    }
}

/// Bisection bounds for the multiplier step.  Positions live in
/// `[0, r_i]` with r at most a few thousand, so ±1e4 mean-cost units of
/// shift saturate every gate long before the bracket is exhausted.
const DELTA_BRACKET: f64 = 1e4;
/// Bisection iteration cap; the loop normally exits earlier on
/// [`COST_TOL`], the cap only bounds pathological plateaus.
const BISECT_ITERS: usize = 60;
/// Relative expected-cost tolerance at which the bisection stops — far
/// tighter than the integer rounding can distinguish, far cheaper than
/// driving the bracket to 2^-60.
const COST_TOL: f64 = 1e-9;

/// Renormalize the model's expected stored-parameter cost to exactly
/// `budget` (to bisection tolerance) via the cost-weighted position
/// shift.  Positions are NOT clamped to `[0, r_i]` here — the soft step
/// is defined on all of ℝ and only saturation of every gate can reach
/// the extreme budgets; the integer rounding clamps at the end.  Returns
/// the multiplier step δ (positive = the step had to shrink the model).
/// Budgets outside the attainable open interval saturate at the nearest
/// bracket bound.
pub fn project_to_budget(model: &mut GateModel, budget: f64) -> f64 {
    let n = model.targets.len();
    if n == 0 {
        return 0.0;
    }
    let mean_cost: f64 = model.targets.iter().map(|t| t.cost).sum::<f64>() / n as f64;
    let chat: Vec<f64> = model.targets.iter().map(|t| t.cost / mean_cost).collect();
    let base = model.pos.clone();
    // Allocation-free probe: the bisection evaluates the cost surface
    // O(BISECT_ITERS) times per optimizer step, so it must not
    // materialize gate vectors or touch the model until the final write.
    let tau = model.tau;
    let dims: Vec<(f64, usize)> =
        model.targets.iter().map(|t| (t.cost, t.sigma2.len())).collect();
    let cost_at = |d: f64| -> f64 {
        dims.iter()
            .zip(&base)
            .zip(&chat)
            .map(|(((c, r), b), ch)| c * gate_sum(b - d * ch, *r, tau))
            .sum()
    };
    let (mut lo, mut hi) = (-DELTA_BRACKET, DELTA_BRACKET);
    let tol = COST_TOL * budget.abs().max(1.0);
    let delta = if cost_at(lo) < budget {
        lo // budget above the attainable max: saturate open
    } else if cost_at(hi) > budget {
        hi // budget below the attainable min: saturate closed
    } else {
        let mut mid = 0.5 * (lo + hi);
        for _ in 0..BISECT_ITERS {
            let c = cost_at(mid);
            if (c - budget).abs() <= tol {
                break;
            }
            if c > budget {
                lo = mid;
            } else {
                hi = mid;
            }
            mid = 0.5 * (lo + hi);
        }
        mid
    };
    for i in 0..n {
        model.pos[i] = base[i] - delta * chat[i];
    }
    delta
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::rank::TargetSpectrum;

    fn spec(name: &str, m: usize, n: usize, sigma2: Vec<f64>) -> TargetSpectrum {
        TargetSpectrum { name: name.into(), m, n, sigma2 }
    }

    fn model() -> GateModel {
        let specs = vec![
            spec("a", 8, 6, vec![50.0, 20.0, 8.0, 3.0, 1.0, 0.4]),
            spec("b", 12, 6, vec![10.0, 9.0, 8.0, 7.0, 6.0, 5.0]),
        ];
        GateModel::from_ranks(&specs, &[3, 3], 1)
    }

    #[test]
    fn adam_descends_a_quadratic() {
        // minimize (x - 3)² + (y + 1)²
        let mut pos = vec![0.0, 0.0];
        let mut adam = Adam::new(0.1, 2);
        for _ in 0..500 {
            let grad = vec![2.0 * (pos[0] - 3.0), 2.0 * (pos[1] + 1.0)];
            adam.step(&mut pos, &grad, &[1.0, 1.0]);
        }
        assert!((pos[0] - 3.0).abs() < 1e-2 && (pos[1] + 1.0).abs() < 1e-2, "{pos:?}");
    }

    #[test]
    fn adam_lr_scale_damps_a_coordinate() {
        let mut pos = vec![0.0, 0.0];
        let mut adam = Adam::new(0.1, 2);
        for _ in 0..20 {
            let grad = vec![1.0, 1.0];
            adam.step(&mut pos, &grad, &[1.0, 0.1]);
        }
        assert!(pos[0].abs() > 5.0 * pos[1].abs(),
                "damped coordinate moved as fast: {pos:?}");
    }

    #[test]
    fn projection_pins_expected_cost() {
        let mut m = model();
        for budget in [20.0f64, 40.0, 60.0] {
            project_to_budget(&mut m, budget);
            assert!((m.expected_cost() - budget).abs() < 1e-6,
                    "expected cost {} != budget {budget}", m.expected_cost());
        }
    }

    #[test]
    fn projection_direction_matches_sign() {
        let mut m = model();
        let over = m.expected_cost() + 15.0;
        let d_grow = project_to_budget(&mut m, over);
        assert!(d_grow < 0.0, "growing the budget must shift positions up");
        let mut m2 = model();
        let under = m2.expected_cost() - 15.0;
        let d_shrink = project_to_budget(&mut m2, under);
        assert!(d_shrink > 0.0, "shrinking the budget must shift positions down");
    }

    #[test]
    fn projection_saturates_out_of_range_budgets() {
        let mut m = model();
        // max attainable: all gates open -> sum c_i r_i = 8*6 + 12*6 = 120
        project_to_budget(&mut m, 1e9);
        assert!(m.expected_cost() > 119.9, "gates must saturate open: {}", m.expected_cost());
        assert!(m.pos.iter().zip(&m.targets).all(|(&p, t)| p >= t.sigma2.len() as f64),
                "positions must clear full rank: {:?}", m.pos);
        let mut m2 = model();
        project_to_budget(&mut m2, 0.0);
        assert!(m2.expected_cost() < 1.0, "near-zero budget must close the gates");
    }

    #[test]
    fn projection_weights_shift_by_cost() {
        // equal spectra, unequal unit costs: the expensive target must be
        // pushed down harder by a shrinking projection
        let specs = vec![
            spec("cheap", 6, 6, vec![1.0; 6]),
            spec("dear", 60, 6, vec![1.0; 6]),
        ];
        let mut m = GateModel::from_ranks(&specs, &[3, 3], 1);
        let before = m.pos.clone();
        project_to_budget(&mut m, m.expected_cost() * 0.5);
        let drop0 = before[0] - m.pos[0];
        let drop1 = before[1] - m.pos[1];
        assert!(drop1 > drop0 * 2.0,
                "cost-weighted shift missing: cheap dropped {drop0}, dear {drop1}");
    }
}
