//! Continuous truncation gates: the differentiable surrogate of "keep the
//! top-k singular values of every target".
//!
//! Each compression target `i` carries ONE learnable scalar — its
//! continuous truncation position `k̃_i` — from which per-singular-value
//! gates are derived as a temperature-`τ` soft step:
//!
//! ```text
//! g_ij = sigmoid((k̃_i - j - 1/2) / τ)        j = 0 .. r_i-1
//! ```
//!
//! so `g ≈ 1` for indices below the position and `≈ 0` above it, with a
//! `τ`-wide transition band.  Parameterizing the *position* (not one free
//! logit per singular value) matches the paper's objective — Dobi-SVD
//! learns where to truncate, not an arbitrary re-weighting — and avoids
//! the partial-credit pathology of independent gates, whose convex
//! continuous optimum smears fractional gate mass over the whole spectrum
//! and rounds badly.
//!
//! The training objective combines, through the autodiff [`Tape`]:
//!
//! * the **whitened truncation loss** `Σ_i Σ_j (1 - g_ij)² σ²_ij / E`
//!   (exactly the activation-space reconstruction error the waterfill
//!   allocator discretely greedifies, normalized by total energy `E`);
//! * the **Lagrangian budget term** `λ · Σ_i c_i Σ_j g_ij / C_tot`, whose
//!   multiplier the driver adapts from the projection step so that
//!   per-target gradients carry a sign (grow if the spectrum justifies
//!   the parameters, shrink otherwise);
//! * the softmax-ish [`Tape::normalize`] of per-target expected costs —
//!   the *budget shares* diagnostic surfaced in the train report.
//!
//! The **expected stored-parameter cost** of a target is
//! `c_i · Σ_j g_ij` with `c_i = max(m, n)` (remapped rank-unit cost, same
//! accounting as `rank::allocate_ranks`), so budget feasibility is
//! differentiable too — [`super::optim::project_to_budget`] renormalizes
//! it to the exact budget after every step.

use super::super::rank::TargetSpectrum;
use super::tape::{sigmoid, Tape};

/// Initial soft-step temperature (annealed down by the driver).
pub const TAU_HI: f64 = 2.0;
/// Final temperature: sharp enough that rounding the position and
/// rounding the expected rank agree.
pub const TAU_LO: f64 = 0.25;

/// One compression target's slice of the gate model.
pub struct GateTarget {
    pub name: String,
    /// Stored-parameter cost of one rank unit: `max(m, n)`.
    pub cost: f64,
    /// Whitened squared singular values, descending.
    pub sigma2: Vec<f64>,
    /// Rank floor (from the allocator's `k_min`, clamped to max rank).
    pub k_min: usize,
}

/// Everything the objective needs from one evaluation of the tape.
pub struct Objective {
    /// Full scalar objective (tail + Lagrangian term).
    pub loss: f64,
    /// Normalized truncation-loss component alone.
    pub tail: f64,
    /// Expected stored params `Σ c_i Σ g_ij` at the current positions.
    pub expected_cost: f64,
    /// d loss / d k̃_i.
    pub grad: Vec<f64>,
    /// Normalized per-target budget shares (sum to 1).
    pub shares: Vec<f64>,
}

/// The learnable truncation positions over all targets.
pub struct GateModel {
    pub targets: Vec<GateTarget>,
    /// Continuous truncation positions, one per target, in `[0, r_i]`.
    pub pos: Vec<f64>,
    /// Current soft-step temperature.
    pub tau: f64,
    /// Total spectral energy `Σ σ²` (objective normalizer).
    pub energy: f64,
    /// `Σ_i c_i r_i` (cost-term normalizer).
    pub cost_total: f64,
}

/// Sum of the soft-step gates of one target at position `pos` — the
/// target's expected rank.  Allocation-free scalar form shared by the
/// model accessors and the budget projection's bisection probes (which
/// call it tens of times per optimizer step).
pub fn gate_sum(pos: f64, r: usize, tau: f64) -> f64 {
    (0..r).map(|j| sigmoid((pos - j as f64 - 0.5) / tau)).sum()
}

impl GateModel {
    /// Build from spectra, warm-started at an integer allocation (the
    /// greedy waterfill solution — the optimizer explores around it).
    pub fn from_ranks(specs: &[TargetSpectrum], init: &[usize], k_min: usize) -> GateModel {
        assert_eq!(specs.len(), init.len(), "gate model: init rank per target");
        let targets: Vec<GateTarget> = specs
            .iter()
            .map(|t| GateTarget {
                name: t.name.clone(),
                cost: t.unit_cost() as f64,
                sigma2: t.sigma2.clone(),
                k_min: k_min.max(1).min(t.max_rank()),
            })
            .collect();
        let energy: f64 = targets.iter().map(|t| t.sigma2.iter().sum::<f64>()).sum();
        let cost_total: f64 =
            targets.iter().map(|t| t.cost * t.sigma2.len() as f64).sum();
        let pos = init.iter().map(|&k| k as f64).collect();
        GateModel {
            targets,
            pos,
            tau: TAU_HI,
            energy: energy.max(f64::MIN_POSITIVE),
            cost_total: cost_total.max(1.0),
        }
    }

    /// Soft gates of target `i` at the current position/temperature.
    pub fn gates(&self, i: usize) -> Vec<f64> {
        let r = self.targets[i].sigma2.len();
        (0..r)
            .map(|j| sigmoid((self.pos[i] - j as f64 - 0.5) / self.tau))
            .collect()
    }

    /// Expected stored params of target `i`: `c_i · Σ_j g_ij`.
    pub fn target_cost(&self, i: usize) -> f64 {
        self.targets[i].cost * gate_sum(self.pos[i], self.targets[i].sigma2.len(), self.tau)
    }

    /// Expected stored params across all targets (the budget surface the
    /// projection step pins).
    pub fn expected_cost(&self) -> f64 {
        (0..self.targets.len()).map(|i| self.target_cost(i)).sum()
    }

    /// Build the objective graph on a fresh tape, run backward, and
    /// return value + gradients + diagnostics.
    pub fn objective(&self, lambda: f64) -> Objective {
        let mut tape = Tape::new();
        let mut pos_vars = Vec::with_capacity(self.targets.len());
        let mut cost_vars = Vec::with_capacity(self.targets.len());
        let mut tail_acc: Option<usize> = None;
        for (i, t) in self.targets.iter().enumerate() {
            let r = t.sigma2.len();
            let pos = tape.leaf(&[self.pos[i]]);
            pos_vars.push(pos);
            let idx: Vec<f64> = (0..r).map(|j| j as f64 + 0.5).collect();
            let idx = tape.constant(&idx);
            let d = tape.sub(pos, idx);
            let z = tape.scale(d, 1.0 / self.tau);
            let g = tape.sigmoid(z);
            let ones = tape.constant(&vec![1.0; r]);
            let omg = tape.sub(ones, g);
            let sq = tape.mul(omg, omg);
            let s2 = tape.constant(&t.sigma2);
            // (1, r) @ (r, 1) — the per-target whitened tail energy
            let tail = tape.matmul(sq, 1, r, s2, 1);
            tail_acc = Some(match tail_acc {
                None => tail,
                Some(acc) => tape.add(acc, tail),
            });
            let gsum = tape.sum(g);
            cost_vars.push(tape.scale(gsum, t.cost));
        }
        let tail_total = tail_acc.expect("gate model has no targets");
        let costs = tape.concat(&cost_vars);
        let shares = tape.normalize(costs);
        let cost_sum = tape.sum(costs);
        let tail_term = tape.scale(tail_total, 1.0 / self.energy);
        let cost_term = tape.scale(cost_sum, lambda / self.cost_total);
        let obj = tape.add(tail_term, cost_term);
        let grads = tape.backward(obj);
        Objective {
            loss: tape.value(obj)[0],
            tail: tape.value(tail_term)[0],
            expected_cost: tape.value(cost_sum)[0],
            grad: pos_vars.iter().map(|&v| grads.wrt(v)[0]).collect(),
            shares: tape.value(shares).to_vec(),
        }
    }

    /// Round the continuous positions to integer ranks under the budget:
    /// nearest-integer positions (clamped to `[k_min, r]`), then a
    /// deterministic local repair — sell the cheapest marginal energy
    /// while over budget, buy the best marginal energy-per-param while
    /// under (the same move set as the waterfill, so the result is always
    /// single-unit-exchange stable).  Ties resolve to the lowest index.
    /// Returns `(ranks, spent)`; like the waterfill, the floor allocation
    /// may overshoot a tiny budget.
    pub fn round_to_ranks(&self, budget: usize) -> (Vec<usize>, usize) {
        let mut ks: Vec<usize> = self
            .targets
            .iter()
            .zip(&self.pos)
            .map(|(t, &p)| {
                (p.round() as isize).clamp(t.k_min as isize, t.sigma2.len() as isize) as usize
            })
            .collect();
        let cost = |i: usize| self.targets[i].cost as usize;
        let mut spent: usize = ks.iter().enumerate().map(|(i, &k)| k * cost(i)).sum();
        // sell while over budget (stop at the floor: a floor allocation
        // over a tiny budget is granted, mirroring `allocate_ranks`)
        while spent > budget {
            let mut best: Option<(usize, f64)> = None;
            for (i, t) in self.targets.iter().enumerate() {
                if ks[i] <= t.k_min {
                    continue;
                }
                let pain = t.sigma2.get(ks[i] - 1).copied().unwrap_or(0.0) / t.cost;
                match best {
                    Some((_, b)) if pain >= b => {}
                    _ => best = Some((i, pain)),
                }
            }
            let Some((i, _)) = best else { break };
            ks[i] -= 1;
            spent -= cost(i);
        }
        // buy while affordable gains remain
        loop {
            let mut best: Option<(usize, f64)> = None;
            for (i, t) in self.targets.iter().enumerate() {
                if ks[i] >= t.sigma2.len() || spent + cost(i) > budget {
                    continue;
                }
                let gain = t.sigma2.get(ks[i]).copied().unwrap_or(0.0) / t.cost;
                match best {
                    Some((_, b)) if gain <= b => {}
                    _ => best = Some((i, gain)),
                }
            }
            let Some((i, _)) = best else { break };
            ks[i] += 1;
            spent += cost(i);
        }
        (ks, spent)
    }
}

/// Whitened truncation loss of an integer allocation: `Σ_i tail_i(k_i)` —
/// the discrete objective both allocators are scored on.
pub fn surrogate_loss(specs: &[TargetSpectrum], ks: &[usize]) -> f64 {
    specs
        .iter()
        .zip(ks)
        .map(|(t, &k)| t.sigma2.iter().skip(k).sum::<f64>())
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(name: &str, m: usize, n: usize, sigma2: Vec<f64>) -> TargetSpectrum {
        TargetSpectrum { name: name.into(), m, n, sigma2 }
    }

    fn toy() -> Vec<TargetSpectrum> {
        vec![
            spec("a", 8, 6, vec![50.0, 20.0, 8.0, 3.0, 1.0, 0.4]),
            spec("b", 6, 8, vec![10.0, 9.0, 8.0, 7.0, 6.0, 5.0]),
        ]
    }

    #[test]
    fn soft_gates_are_a_descending_step() {
        let specs = toy();
        let mut m = GateModel::from_ranks(&specs, &[3, 2], 1);
        m.tau = 0.25;
        let g = m.gates(0);
        for w in g.windows(2) {
            assert!(w[0] >= w[1], "gates not monotone: {g:?}");
        }
        assert!(g[0] > 0.99 && g[2] > 0.85, "kept indices must be open: {g:?}");
        assert!(g[3] < 0.15 && g[5] < 0.01, "dropped indices must be closed: {g:?}");
    }

    #[test]
    fn expected_cost_tracks_positions() {
        let specs = toy();
        let m = GateModel::from_ranks(&specs, &[3, 2], 1);
        // at tau = TAU_HI the soft step is wide, but cost must still be
        // roughly cost-weighted positions
        let want = 8.0 * 3.0 + 8.0 * 2.0;
        let got = m.expected_cost();
        assert!((got - want).abs() < want * 0.35, "expected {want}, got {got}");
        // sharpening the step tightens the agreement
        let mut sharp = GateModel::from_ranks(&specs, &[3, 2], 1);
        sharp.tau = 0.1;
        assert!((sharp.expected_cost() - want).abs() < 0.5);
    }

    #[test]
    fn objective_gradient_matches_fd() {
        let specs = toy();
        let mut m = GateModel::from_ranks(&specs, &[3, 4], 1);
        m.tau = 0.6;
        m.pos = vec![2.7, 3.2];
        let lambda = 0.8;
        let obj = m.objective(lambda);
        let h = 1e-6;
        for i in 0..2 {
            let mut up = GateModel::from_ranks(&specs, &[3, 4], 1);
            up.tau = 0.6;
            up.pos = m.pos.clone();
            up.pos[i] += h;
            let mut dn = GateModel::from_ranks(&specs, &[3, 4], 1);
            dn.tau = 0.6;
            dn.pos = m.pos.clone();
            dn.pos[i] -= h;
            let fd = (up.objective(lambda).loss - dn.objective(lambda).loss) / (2.0 * h);
            assert!((obj.grad[i] - fd).abs() < 1e-6 * (1.0 + fd.abs()),
                    "d/dpos[{i}]: {} vs fd {fd}", obj.grad[i]);
        }
    }

    #[test]
    fn shares_sum_to_one_and_follow_cost() {
        let specs = toy();
        let m = GateModel::from_ranks(&specs, &[4, 1], 1);
        let obj = m.objective(0.0);
        let total: f64 = obj.shares.iter().sum();
        assert!((total - 1.0).abs() < 1e-12);
        assert!(obj.shares[0] > obj.shares[1],
                "target with more expected rank must hold the larger share");
        assert!((obj.expected_cost - m.expected_cost()).abs() < 1e-9);
    }

    #[test]
    fn rounding_repairs_to_budget() {
        let specs = toy();
        let mut m = GateModel::from_ranks(&specs, &[3, 3], 1);
        m.pos = vec![4.4, 4.4]; // naive rounding would cost (4+4)*8 = 64
        let budget = 6 * 8;
        let (ks, spent) = m.round_to_ranks(budget);
        assert!(spent <= budget, "spent {spent} over budget {budget}");
        assert_eq!(spent, budget, "repair must re-buy the freed budget");
        // target a holds concentrated energy, so it keeps more rank
        assert!(ks[0] >= ks[1], "{ks:?}");
    }

    #[test]
    fn rounding_honors_floor_even_over_budget() {
        let specs = toy();
        let mut m = GateModel::from_ranks(&specs, &[2, 2], 2);
        m.pos = vec![0.0, 0.0];
        let (ks, spent) = m.round_to_ranks(0);
        assert_eq!(ks, vec![2, 2], "floor ranks granted");
        assert_eq!(spent, 2 * 8 + 2 * 8);
    }

    #[test]
    fn surrogate_matches_loss_at_definition() {
        let specs = toy();
        let l = surrogate_loss(&specs, &[3, 2]);
        let want: f64 = specs[0].sigma2[3..].iter().sum::<f64>()
            + specs[1].sigma2[2..].iter().sum::<f64>();
        assert!((l - want).abs() < 1e-12);
        assert_eq!(surrogate_loss(&specs, &[6, 6]), 0.0);
    }
}
