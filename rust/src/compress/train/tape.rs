//! Minimal reverse-mode autodiff over f64 vectors — the engine under the
//! differentiable truncation-position objective.
//!
//! A [`Tape`] is an append-only list of nodes; every op records its
//! parents and returns a [`Var`] handle.  [`Tape::backward`] seeds the
//! (scalar) root with 1 and walks the nodes in reverse, accumulating
//! vector-Jacobian products into per-node gradient buffers.  The op set
//! is exactly what the Dobi gate objective needs — sigmoid, elementwise
//! add/sub/mul, constant scale, sum, matmul, concat, and the softmax-ish
//! `normalize` (x / sum x) used for the budget-share diagnostics — all in
//! f64 so the finite-difference validation tests can run at 1e-6 steps
//! without drowning in rounding noise.
//!
//! Broadcasting is deliberately tiny: `add`/`sub`/`mul` accept a length-1
//! *left* operand against a vector right operand (the `k̃ - j` soft-step
//! argument in the gate model), nothing else.  Graphs here are a few
//! hundred nodes, so the tape is rebuilt every iteration rather than
//! retaining structure between steps.

/// Handle to one tape node.
pub type Var = usize;

/// Numerically stable logistic function (never overflows `exp`).
pub fn sigmoid(x: f64) -> f64 {
    if x >= 0.0 {
        1.0 / (1.0 + (-x).exp())
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

enum Op {
    /// Differentiable input (gradients are accumulated and reported).
    Leaf,
    /// Constant input — a terminal like [`Op::Leaf`]; callers simply
    /// never read gradients back for it.
    Const,
    Sigmoid { x: Var },
    Add { a: Var, b: Var },
    Sub { a: Var, b: Var },
    Mul { a: Var, b: Var },
    Scale { a: Var, c: f64 },
    Sum { a: Var },
    /// (m, k) @ (k, n) row-major.
    Matmul { a: Var, b: Var, m: usize, k: usize, n: usize },
    Concat { parts: Vec<Var> },
    /// y = x / sum(x).
    Normalize { a: Var },
}

struct Node {
    op: Op,
    value: Vec<f64>,
}

/// Reverse-mode tape; build a fresh one per optimization step.
#[derive(Default)]
pub struct Tape {
    nodes: Vec<Node>,
}

impl Tape {
    pub fn new() -> Tape {
        Tape { nodes: Vec::new() }
    }

    fn push(&mut self, op: Op, value: Vec<f64>) -> Var {
        self.nodes.push(Node { op, value });
        self.nodes.len() - 1
    }

    /// Differentiable input vector.
    pub fn leaf(&mut self, vals: &[f64]) -> Var {
        self.push(Op::Leaf, vals.to_vec())
    }

    /// Constant (no gradient flows into it).
    pub fn constant(&mut self, vals: &[f64]) -> Var {
        self.push(Op::Const, vals.to_vec())
    }

    pub fn value(&self, v: Var) -> &[f64] {
        &self.nodes[v].value
    }

    pub fn sigmoid(&mut self, x: Var) -> Var {
        let y: Vec<f64> = self.nodes[x].value.iter().map(|&v| sigmoid(v)).collect();
        self.push(Op::Sigmoid { x }, y)
    }

    /// Elementwise a + b (a may be length 1, broadcast against b).
    pub fn add(&mut self, a: Var, b: Var) -> Var {
        let y = self.broadcast_zip(a, b, |x, y| x + y);
        self.push(Op::Add { a, b }, y)
    }

    /// Elementwise a - b (a may be length 1, broadcast against b).
    pub fn sub(&mut self, a: Var, b: Var) -> Var {
        let y = self.broadcast_zip(a, b, |x, y| x - y);
        self.push(Op::Sub { a, b }, y)
    }

    /// Elementwise a * b (a may be length 1, broadcast against b).
    pub fn mul(&mut self, a: Var, b: Var) -> Var {
        let y = self.broadcast_zip(a, b, |x, y| x * y);
        self.push(Op::Mul { a, b }, y)
    }

    pub fn scale(&mut self, a: Var, c: f64) -> Var {
        let y: Vec<f64> = self.nodes[a].value.iter().map(|&v| v * c).collect();
        self.push(Op::Scale { a, c }, y)
    }

    /// Scalar (length-1) sum of all elements.
    pub fn sum(&mut self, a: Var) -> Var {
        let s: f64 = self.nodes[a].value.iter().sum();
        self.push(Op::Sum { a }, vec![s])
    }

    /// Row-major (m, k) @ (k, n).  `dot` is the (1, k) @ (k, 1) case.
    pub fn matmul(&mut self, a: Var, m: usize, k: usize, b: Var, n: usize) -> Var {
        assert_eq!(self.nodes[a].value.len(), m * k, "matmul: a is not {m}x{k}");
        assert_eq!(self.nodes[b].value.len(), k * n, "matmul: b is not {k}x{n}");
        let (av, bv) = (&self.nodes[a].value, &self.nodes[b].value);
        let mut y = vec![0f64; m * n];
        for i in 0..m {
            for t in 0..k {
                let x = av[i * k + t];
                if x != 0.0 {
                    for j in 0..n {
                        y[i * n + j] += x * bv[t * n + j];
                    }
                }
            }
        }
        self.push(Op::Matmul { a, b, m, k, n }, y)
    }

    /// Concatenate parts into one vector (gradients split back).
    pub fn concat(&mut self, parts: &[Var]) -> Var {
        let mut y = Vec::new();
        for &p in parts {
            y.extend_from_slice(&self.nodes[p].value);
        }
        self.push(Op::Concat { parts: parts.to_vec() }, y)
    }

    /// Softmax-ish normalization y = x / sum(x) — turns nonnegative
    /// magnitudes into shares summing to 1 (the budget-share view of the
    /// expected per-target costs).
    pub fn normalize(&mut self, a: Var) -> Var {
        let s: f64 = self.nodes[a].value.iter().sum();
        assert!(s != 0.0, "normalize: zero-sum input");
        let y: Vec<f64> = self.nodes[a].value.iter().map(|&v| v / s).collect();
        self.push(Op::Normalize { a }, y)
    }

    fn broadcast_zip(&self, a: Var, b: Var, f: impl Fn(f64, f64) -> f64) -> Vec<f64> {
        let (av, bv) = (&self.nodes[a].value, &self.nodes[b].value);
        if av.len() == bv.len() {
            av.iter().zip(bv).map(|(&x, &y)| f(x, y)).collect()
        } else if av.len() == 1 {
            bv.iter().map(|&y| f(av[0], y)).collect()
        } else {
            panic!("shape mismatch: {} vs {} (only length-1 LEFT broadcast)", av.len(), bv.len());
        }
    }

    /// Reverse sweep from a scalar root.  Returns per-node gradients;
    /// read them back with [`Gradients::wrt`].
    pub fn backward(&self, root: Var) -> Gradients {
        assert_eq!(self.nodes[root].value.len(), 1, "backward root must be scalar");
        let mut g: Vec<Vec<f64>> = self.nodes.iter().map(|n| vec![0f64; n.value.len()]).collect();
        g[root][0] = 1.0;
        for id in (0..=root).rev() {
            if g[id].iter().all(|&x| x == 0.0) {
                continue;
            }
            let gy = g[id].clone();
            match &self.nodes[id].op {
                Op::Leaf | Op::Const => {}
                Op::Sigmoid { x } => {
                    let y = &self.nodes[id].value;
                    for (i, &gv) in gy.iter().enumerate() {
                        g[*x][i] += gv * y[i] * (1.0 - y[i]);
                    }
                }
                Op::Add { a, b } => {
                    self.accum_bcast(&mut g, *a, &gy, 1.0);
                    self.accum_full(&mut g, *b, &gy, 1.0);
                }
                Op::Sub { a, b } => {
                    self.accum_bcast(&mut g, *a, &gy, 1.0);
                    self.accum_full(&mut g, *b, &gy, -1.0);
                }
                Op::Mul { a, b } => {
                    let bv = self.nodes[*b].value.clone();
                    if self.nodes[*a].value.len() == 1 {
                        g[*a][0] += gy.iter().zip(&bv).map(|(&gv, &y)| gv * y).sum::<f64>();
                    } else {
                        for (i, &gv) in gy.iter().enumerate() {
                            g[*a][i] += gv * bv[i];
                        }
                    }
                    let av = &self.nodes[*a].value;
                    for (i, &gv) in gy.iter().enumerate() {
                        let x = if av.len() == 1 { av[0] } else { av[i] };
                        g[*b][i] += gv * x;
                    }
                }
                Op::Scale { a, c } => {
                    self.accum_full(&mut g, *a, &gy, *c);
                }
                Op::Sum { a } => {
                    for gv in g[*a].iter_mut() {
                        *gv += gy[0];
                    }
                }
                Op::Matmul { a, b, m, k, n } => {
                    // dL/dA = dY @ B^T; dL/dB = A^T @ dY
                    let (m, k, n) = (*m, *k, *n);
                    let bv = self.nodes[*b].value.clone();
                    let av = self.nodes[*a].value.clone();
                    for i in 0..m {
                        for t in 0..k {
                            let mut acc = 0f64;
                            for j in 0..n {
                                acc += gy[i * n + j] * bv[t * n + j];
                            }
                            g[*a][i * k + t] += acc;
                        }
                    }
                    for t in 0..k {
                        for j in 0..n {
                            let mut acc = 0f64;
                            for i in 0..m {
                                acc += av[i * k + t] * gy[i * n + j];
                            }
                            g[*b][t * n + j] += acc;
                        }
                    }
                }
                Op::Concat { parts } => {
                    let mut at = 0usize;
                    for &p in parts.iter() {
                        let len = self.nodes[p].value.len();
                        for i in 0..len {
                            g[p][i] += gy[at + i];
                        }
                        at += len;
                    }
                }
                Op::Normalize { a } => {
                    // y_i = x_i / s: dL/dx_i = (g_i - sum_j g_j y_j) / s
                    let y = self.nodes[id].value.clone();
                    let s: f64 = self.nodes[*a].value.iter().sum();
                    let gdoty: f64 = gy.iter().zip(&y).map(|(&gv, &yv)| gv * yv).sum();
                    for (i, &gv) in gy.iter().enumerate() {
                        g[*a][i] += (gv - gdoty) / s;
                    }
                }
            }
        }
        Gradients { g }
    }

    fn accum_full(&self, g: &mut [Vec<f64>], dst: Var, gy: &[f64], w: f64) {
        debug_assert_eq!(self.nodes[dst].value.len(), gy.len());
        for (o, &gv) in g[dst].iter_mut().zip(gy) {
            *o += w * gv;
        }
    }

    /// Accumulate into a possibly-broadcast (length-1) left operand.
    fn accum_bcast(&self, g: &mut [Vec<f64>], dst: Var, gy: &[f64], w: f64) {
        if self.nodes[dst].value.len() == 1 && gy.len() != 1 {
            g[dst][0] += w * gy.iter().sum::<f64>();
        } else {
            self.accum_full(g, dst, gy, w);
        }
    }
}

/// Per-node gradients from one [`Tape::backward`] sweep.
pub struct Gradients {
    g: Vec<Vec<f64>>,
}

impl Gradients {
    pub fn wrt(&self, v: Var) -> &[f64] {
        &self.g[v]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Central finite difference of a scalar tape program at `x`.
    fn fd(build: impl Fn(&mut Tape, Var) -> Var, x: &[f64], h: f64) -> Vec<f64> {
        let eval = |xs: &[f64]| -> f64 {
            let mut t = Tape::new();
            let leaf = t.leaf(xs);
            let root = build(&mut t, leaf);
            t.value(root)[0]
        };
        (0..x.len())
            .map(|i| {
                let mut up = x.to_vec();
                up[i] += h;
                let mut dn = x.to_vec();
                dn[i] -= h;
                (eval(&up) - eval(&dn)) / (2.0 * h)
            })
            .collect()
    }

    fn check(build: impl Fn(&mut Tape, Var) -> Var + Copy, x: &[f64]) {
        let mut t = Tape::new();
        let leaf = t.leaf(x);
        let root = build(&mut t, leaf);
        let grads = t.backward(root);
        let analytic = grads.wrt(leaf);
        let numeric = fd(build, x, 1e-6);
        for (i, (a, n)) in analytic.iter().zip(&numeric).enumerate() {
            assert!((a - n).abs() < 1e-6 * (1.0 + n.abs()),
                    "grad[{i}]: analytic {a} vs fd {n}");
        }
    }

    #[test]
    fn sigmoid_sum_grad_matches_fd() {
        check(|t, x| {
            let s = t.sigmoid(x);
            t.sum(s)
        }, &[-3.0, -0.5, 0.0, 0.7, 4.0]);
    }

    #[test]
    fn stable_sigmoid_extremes() {
        assert_eq!(sigmoid(800.0), 1.0);
        assert_eq!(sigmoid(-800.0), 0.0);
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-15);
    }

    #[test]
    fn elementwise_chain_grad_matches_fd() {
        // sum((1 - sigmoid(x))^2 * w) — the per-target tail-loss shape
        check(|t, x| {
            let g = t.sigmoid(x);
            let one = t.constant(&[1.0, 1.0, 1.0, 1.0]);
            let r = t.sub(one, g);
            let sq = t.mul(r, r);
            let w = t.constant(&[4.0, 2.0, 1.0, 0.5]);
            let wl = t.mul(sq, w);
            t.sum(wl)
        }, &[1.5, 0.2, -0.4, -2.0]);
    }

    #[test]
    fn broadcast_sub_grad_matches_fd() {
        // scalar position against an index ramp: the soft-step argument
        check(|t, x| {
            let idx = t.constant(&[0.5, 1.5, 2.5, 3.5, 4.5]);
            let d = t.sub(x, idx);
            let z = t.scale(d, 1.0 / 0.7);
            let g = t.sigmoid(z);
            t.sum(g)
        }, &[2.3]);
    }

    #[test]
    fn matmul_grad_matches_fd() {
        let b = [1.0, -2.0, 0.5, 3.0, 0.25, -1.0];
        check(move |t, x| {
            let bv = t.constant(&b); // (3, 2)
            let y = t.matmul(x, 2, 3, bv, 2); // (2, 3) @ (3, 2)
            let sq = t.mul(y, y);
            t.sum(sq)
        }, &[0.3, -1.2, 0.8, 2.0, -0.1, 0.6]);
    }

    #[test]
    fn matmul_grad_wrt_right_operand() {
        let a = [0.3, -1.2, 0.8, 2.0, -0.1, 0.6];
        check(move |t, x| {
            let av = t.constant(&a); // (2, 3)
            let y = t.matmul(av, 2, 3, x, 2); // x is (3, 2)
            let sq = t.mul(y, y);
            t.sum(sq)
        }, &[1.0, -2.0, 0.5, 3.0, 0.25, -1.0]);
    }

    #[test]
    fn normalize_grad_matches_fd() {
        check(|t, x| {
            let y = t.normalize(x);
            let w = t.constant(&[3.0, 1.0, -2.0, 0.5]);
            let wy = t.mul(y, w);
            t.sum(wy)
        }, &[2.0, 1.0, 4.0, 0.5]);
    }

    #[test]
    fn normalize_outputs_shares() {
        let mut t = Tape::new();
        let x = t.leaf(&[1.0, 3.0]);
        let y = t.normalize(x);
        assert_eq!(t.value(y), &[0.25, 0.75]);
    }

    #[test]
    fn concat_routes_gradients_to_parts() {
        let mut t = Tape::new();
        let a = t.leaf(&[1.0, 2.0]);
        let b = t.leaf(&[3.0]);
        let c = t.concat(&[a, b]);
        let w = t.constant(&[5.0, 7.0, 11.0]);
        let wc = t.mul(c, w);
        let root = t.sum(wc);
        assert_eq!(t.value(root), &[5.0 + 14.0 + 33.0]);
        let g = t.backward(root);
        assert_eq!(g.wrt(a), &[5.0, 7.0]);
        assert_eq!(g.wrt(b), &[11.0]);
    }

    #[test]
    fn composite_objective_grad_matches_fd() {
        // A miniature of the full gate objective: soft-step gates from a
        // position scalar, tail loss via matmul, plus a cost term.
        check(|t, x| {
            let idx = t.constant(&[0.5, 1.5, 2.5, 3.5]);
            let d = t.sub(x, idx);
            let z = t.scale(d, 2.0);
            let g = t.sigmoid(z);
            let one = t.constant(&[1.0; 4]);
            let r = t.sub(one, g);
            let sq = t.mul(r, r);
            let s2 = t.constant(&[9.0, 4.0, 1.0, 0.25]);
            let tail = t.matmul(sq, 1, 4, s2, 1);
            let cost = t.sum(g);
            let cost_term = t.scale(cost, 0.35);
            t.add(tail, cost_term)
        }, &[1.8]);
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn mismatched_lengths_rejected() {
        let mut t = Tape::new();
        let a = t.leaf(&[1.0, 2.0]);
        let b = t.leaf(&[1.0, 2.0, 3.0]);
        t.add(a, b);
    }

    #[test]
    #[should_panic(expected = "backward root must be scalar")]
    fn vector_root_rejected() {
        let mut t = Tape::new();
        let a = t.leaf(&[1.0, 2.0]);
        t.backward(a);
    }

    #[test]
    fn constants_receive_no_reported_grad_but_leaves_do() {
        let mut t = Tape::new();
        let a = t.leaf(&[2.0]);
        let c = t.constant(&[3.0]);
        let y = t.mul(a, c);
        let root = t.sum(y);
        let g = t.backward(root);
        assert_eq!(g.wrt(a), &[3.0]);
    }
}
