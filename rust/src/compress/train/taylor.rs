//! Taylor-series-stabilized gradient through the gated truncated-SVD
//! reconstruction — the numerical fix that makes Dobi-SVD's truncation
//! objective differentiable in practice (paper §3.1).
//!
//! The map is `A -> Â = U diag(g ∘ σ) Vᵀ` with `A = U diag(σ) Vᵀ` the
//! thin SVD and `g` the per-singular-value truncation gates.  The exact
//! adjoint routes through the SVD differential, whose rotation terms
//! carry factors `F_ij = 1 / (σ_j² - σ_i²)`: for near-degenerate
//! singular-value pairs the raw coefficient diverges (the singular
//! subspace is arbitrarily rotatable, so a hard truncation boundary
//! INSIDE a degenerate cluster has an exploding, direction-unstable
//! gradient — exactly the failure the paper patches with a Taylor
//! expansion of the offending terms).  [`stabilized_inv_gap`] replaces
//! `1/d` with `d / (d² + ε²)`, the first Padé/Taylor regularization of
//! the inverse gap: it agrees with `1/d` to O(ε²/d²) for well-separated
//! pairs and is bounded by `1/(2ε)` at exact degeneracy.
//!
//! Derivation of the adjoint (validated to machine precision against
//! JAX autodiff, and to 1e-4 against central finite differences by the
//! tests below): with `T = Uᵀ Ḡ V`, `M = T Σ D_g`, `N = Tᵀ D_g Σ`,
//! `K = F ∘ M`, `K' = F ∘ N`,
//!
//! ```text
//! Ā = U [ (K + Kᵀ) Σ  +  diag(g ∘ diag(T))  +  Σ (K' + K'ᵀ) ] Vᵀ
//!     + (I - U Uᵀ) Ḡ V D_g Vᵀ                 (thin part, m > n only)
//! ḡ_j = σ_j T_jj
//! ```
//!
//! Note the projection term needs no `Σ^{-1}`: the gate scaling
//! `h(σ) = g σ` is linear in σ, so the usual small-singular-value
//! instability of the thin-SVD adjoint cancels structurally.

use super::super::svd::svd_thin_f64;

/// Relative Taylor regularization scale: `ε = TAYLOR_EPS_REL · σ_max²`.
/// Small enough that well-separated spectra (gap ≳ 1e-2·σ_max²) see an
/// O(1e-8) relative perturbation — the finite-difference tests pass at
/// 1e-4 — while exact degeneracy stays bounded by `1/(2ε)`.
pub const TAYLOR_EPS_REL: f64 = 1e-6;

/// Taylor-stabilized inverse spectral gap `1/d` with `d = σ_j² - σ_i²`:
/// `d / (d² + ε²)`, `ε = TAYLOR_EPS_REL · scale2`.  The denominator is
/// floored at `MIN_POSITIVE`: for an (all-)zero spectrum `ε²` underflows
/// to 0.0 and the exact-degenerate gap would otherwise return 0/0 = NaN
/// — the floor keeps it an exact 0 (and subnormal gaps merely large, not
/// infinite).
pub fn stabilized_inv_gap(d: f64, scale2: f64) -> f64 {
    let eps = TAYLOR_EPS_REL * scale2;
    d / (d * d + eps * eps).max(f64::MIN_POSITIVE)
}

/// Output of [`gated_recon_grad`]: the reconstruction loss pieces and the
/// stabilized adjoints.
pub struct GatedGrad {
    /// `Â = U diag(g∘σ) Vᵀ`, row-major (m, n).
    pub recon: Vec<f64>,
    /// `dL/dA` for `L = Σ ḡ ∘ Â` with the provided upstream `ḡ = d_recon`.
    pub d_a: Vec<f64>,
    /// `dL/dg_j = σ_j uⱼᵀ Ḡ vⱼ`.
    pub d_g: Vec<f64>,
    /// Singular values of `A`, descending.
    pub sigma: Vec<f64>,
}

/// Gated-truncation reconstruction and its stabilized gradients.
///
/// `a` is row-major (m, n); `gates` has `min(m, n)` entries in [0, 1];
/// `d_recon` is the upstream gradient `∂L/∂Â` (same shape as `a`).
/// Works for any aspect ratio (wide inputs route through the transpose,
/// mirroring `svd_thin`).
pub fn gated_recon_grad(a: &[f64], m: usize, n: usize, gates: &[f64],
                        d_recon: &[f64]) -> GatedGrad {
    assert_eq!(a.len(), m * n, "gated_recon_grad: a is not {m}x{n}");
    assert_eq!(d_recon.len(), m * n, "gated_recon_grad: upstream is not {m}x{n}");
    assert_eq!(gates.len(), m.min(n), "gated_recon_grad: need min(m, n) gates");
    if m >= n {
        return gated_recon_grad_tall(a, m, n, gates, d_recon);
    }
    // Wide: SVD(Aᵀ) = V Σ Uᵀ shares singular values, and the gated
    // reconstruction commutes with transposition, so run the tall path on
    // Aᵀ with Ḡᵀ and transpose the matrix outputs back.
    let at = transpose(a, m, n);
    let dt = transpose(d_recon, m, n);
    let g = gated_recon_grad_tall(&at, n, m, gates, &dt);
    GatedGrad {
        recon: transpose(&g.recon, n, m),
        d_a: transpose(&g.d_a, n, m),
        d_g: g.d_g,
        sigma: g.sigma,
    }
}

fn transpose(a: &[f64], m: usize, n: usize) -> Vec<f64> {
    let mut t = vec![0f64; n * m];
    for i in 0..m {
        for j in 0..n {
            t[j * m + i] = a[i * n + j];
        }
    }
    t
}

fn gated_recon_grad_tall(a: &[f64], m: usize, n: usize, gates: &[f64],
                         d_recon: &[f64]) -> GatedGrad {
    debug_assert!(m >= n);
    // Full f64 SVD: the finite-difference validation runs at 1e-5 steps,
    // which an f32-rounded factorization could not support.
    let svd = svd_thin_f64(a, m, n);
    let (u, s, vt) = (svd.u, svd.s, svd.vt); // (m, n), n, (n, n)

    // Â = U diag(g σ) Vᵀ
    let mut recon = vec![0f64; m * n];
    for i in 0..m {
        for j in 0..n {
            let h = gates[j] * s[j];
            if h != 0.0 {
                let uij = u[i * n + j];
                for c in 0..n {
                    recon[i * n + c] += uij * h * vt[j * n + c];
                }
            }
        }
    }

    // T = Uᵀ Ḡ V  (n, n): T_jc = Σ_i u_ij (Ḡ V)_ic
    let gv = {
        // Ḡ V: (m, n); V_tc = vt[c * n + t]
        let mut out = vec![0f64; m * n];
        for i in 0..m {
            for t in 0..n {
                let x = d_recon[i * n + t];
                if x != 0.0 {
                    for c in 0..n {
                        out[i * n + c] += x * vt[c * n + t];
                    }
                }
            }
        }
        out
    };
    let mut tmat = vec![0f64; n * n];
    for j in 0..n {
        for c in 0..n {
            let mut acc = 0f64;
            for i in 0..m {
                acc += u[i * n + j] * gv[i * n + c];
            }
            tmat[j * n + c] = acc;
        }
    }

    // dL/dg_j = σ_j T_jj
    let d_g: Vec<f64> = (0..n).map(|j| s[j] * tmat[j * n + j]).collect();

    // Rotation terms with the stabilized inverse gaps.
    // K_ij  = F_ij M_ij,  M_ij = T_ij σ_j g_j
    // K'_ij = F_ij N_ij,  N_ij = T_ji g_j σ_j
    let scale2 = s[0] * s[0];
    let mut core = vec![0f64; n * n];
    for i in 0..n {
        for j in 0..n {
            if i != j {
                let f = stabilized_inv_gap(s[j] * s[j] - s[i] * s[i], scale2);
                let k_ij = f * tmat[i * n + j] * s[j] * gates[j];
                let kp_ij = f * tmat[j * n + i] * gates[j] * s[j];
                // (K + Kᵀ)Σ lands σ_j on column j; Σ(K' + K'ᵀ) lands σ_i
                // on row i — accumulate each K entry into both places.
                core[i * n + j] += k_ij * s[j];
                core[j * n + i] += k_ij * s[i];
                core[i * n + j] += kp_ij * s[i];
                core[j * n + i] += kp_ij * s[j];
            }
        }
    }
    for j in 0..n {
        core[j * n + j] += gates[j] * tmat[j * n + j];
    }

    // Ā = U core Vᵀ + (I - UUᵀ) Ḡ V D_g Vᵀ.  First cv = core Vᵀ (n, n),
    // then accumulate U cv.
    let mut cv = vec![0f64; n * n];
    for j in 0..n {
        for t in 0..n {
            let x = core[j * n + t];
            if x != 0.0 {
                for c in 0..n {
                    cv[j * n + c] += x * vt[t * n + c];
                }
            }
        }
    }
    let mut d_a = vec![0f64; m * n];
    for i in 0..m {
        for j in 0..n {
            let uij = u[i * n + j];
            if uij != 0.0 {
                for c in 0..n {
                    d_a[i * n + c] += uij * cv[j * n + c];
                }
            }
        }
    }
    // thin projection part: W = Ḡ V D_g; Ā += (W - U (Uᵀ W)) Vᵀ
    let mut w = gv;
    for i in 0..m {
        for j in 0..n {
            w[i * n + j] *= gates[j];
        }
    }
    let mut utw = vec![0f64; n * n];
    for j in 0..n {
        for c in 0..n {
            let mut acc = 0f64;
            for i in 0..m {
                acc += u[i * n + j] * w[i * n + c];
            }
            utw[j * n + c] = acc;
        }
    }
    let mut proj = w; // becomes W - U (Uᵀ W)
    for i in 0..m {
        for j in 0..n {
            let uij = u[i * n + j];
            if uij != 0.0 {
                for c in 0..n {
                    proj[i * n + c] -= uij * utw[j * n + c];
                }
            }
        }
    }
    for i in 0..m {
        for t in 0..n {
            let x = proj[i * n + t];
            if x != 0.0 {
                for c in 0..n {
                    d_a[i * n + c] += x * vt[t * n + c];
                }
            }
        }
    }
    GatedGrad { recon, d_a, d_g, sigma: s }
}

/// Frobenius norm of the stabilized `dL/dA` under an all-ones downstream
/// probe on the canonical spectral embedding `A = diag(σ)` — a per-target
/// conditioning score for the truncation objective.  Spectra with
/// near-degenerate pairs straddling partially-open gates score high
/// (their reconstruction rotates freely under calibration noise); the
/// train driver damps those targets' learning rates accordingly.
///
/// Closed form: on the diagonal embedding `U = V = I` and `T = Ḡ = 1`,
/// so the projection term vanishes and the adjoint core collapses to the
/// symmetric matrix
///
/// ```text
/// core_jj   = g_j
/// core_ij   = F_ij (σ_j g_j - σ_i g_i)(σ_i + σ_j)        i ≠ j
/// ```
///
/// (substitute `T = 1` into the `(K+Kᵀ)Σ + Σ(K'+K'ᵀ)` terms and collect;
/// the i↔j contributions coincide).  Evaluating it directly is O(r²)
/// with no SVD — on real-model spectra (r in the thousands) the general
/// [`gated_recon_grad`] route would pay an O(r³) Jacobi factorization of
/// an already-diagonal matrix per target.  A test pins this closed form
/// to the general path.
pub fn spectrum_sensitivity(sigma: &[f64], gates: &[f64]) -> f64 {
    let r = sigma.len();
    assert_eq!(gates.len(), r, "sensitivity: gates/sigma length mismatch");
    if r == 0 {
        return 0.0;
    }
    let scale2 = sigma[0] * sigma[0];
    let mut fro2 = 0f64;
    for j in 0..r {
        fro2 += gates[j] * gates[j];
        for i in 0..j {
            let f = stabilized_inv_gap(sigma[j] * sigma[j] - sigma[i] * sigma[i], scale2);
            let core = f * (sigma[j] * gates[j] - sigma[i] * gates[i])
                * (sigma[i] + sigma[j]);
            fro2 += 2.0 * core * core;
        }
    }
    (fro2 / r as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::testutil::randv;
    use crate::mathx::XorShift;

    /// Build (m, n) with a prescribed spectrum via two random rotations
    /// (U0, V0 from the f64 SVD of seeded Gaussian matrices).
    fn with_spectrum(sigmas: &[f64], m: usize, n: usize, seed: u64) -> Vec<f64> {
        let mut rng = XorShift::new(seed);
        let ru: Vec<f64> = randv(&mut rng, m * m, 1.0).iter().map(|&x| x as f64).collect();
        let rv: Vec<f64> = randv(&mut rng, n * n, 1.0).iter().map(|&x| x as f64).collect();
        let us = svd_thin_f64(&ru, m, m);
        let vs = svd_thin_f64(&rv, n, n);
        let r = m.min(n);
        assert!(sigmas.len() <= r);
        let mut a = vec![0f64; m * n];
        for (k, &sg) in sigmas.iter().enumerate() {
            for i in 0..m {
                for j in 0..n {
                    a[i * n + j] += us.u[i * m + k] * sg * vs.u[j * n + k];
                }
            }
        }
        a
    }

    fn probe_loss(a: &[f64], m: usize, n: usize, gates: &[f64], c: &[f64]) -> f64 {
        let zeros = vec![0f64; m * n];
        let g = gated_recon_grad(a, m, n, gates, &zeros);
        g.recon.iter().zip(c).map(|(&r, &w)| r * w).sum()
    }

    /// The acceptance-criterion test: central finite differences validate
    /// the Taylor-stabilized gradient to 1e-4 on a synthetic
    /// near-degenerate spectrum (gap 1% of σ_max — wide enough that the
    /// true gradient exists, narrow enough that the raw `1/(σ²-σ²)`
    /// coefficients are ~100x amplified).
    #[test]
    fn fd_validates_gradient_on_near_degenerate_spectrum() {
        let (m, n) = (6usize, 5usize);
        let a = with_spectrum(&[3.0, 1.01, 1.0, 0.3, 0.05], m, n, 41);
        let mut rng = XorShift::new(42);
        let gates: Vec<f64> = (0..n).map(|_| {
            super::super::tape::sigmoid(rng.normal())
        }).collect();
        let c: Vec<f64> = randv(&mut rng, m * n, 1.0).iter().map(|&x| x as f64).collect();
        let g = gated_recon_grad(&a, m, n, &gates, &c);
        // h balances central-difference truncation (O(h²), amplified by
        // the near-degenerate third derivative) against the Jacobi SVD's
        // 1e-9 convergence noise divided by 2h.
        let h = 1e-4;
        let mut worst = 0f64;
        let mut gmax = 0f64;
        for p in 0..m * n {
            let mut up = a.clone();
            up[p] += h;
            let mut dn = a.clone();
            dn[p] -= h;
            let fd = (probe_loss(&up, m, n, &gates, &c)
                      - probe_loss(&dn, m, n, &gates, &c)) / (2.0 * h);
            worst = worst.max((g.d_a[p] - fd).abs());
            gmax = gmax.max(fd.abs());
        }
        assert!(worst < 1e-4 * gmax.max(1.0),
                "stabilized dA drifted {worst} from FD (scale {gmax})");
        // gate gradient to the same bar
        for j in 0..n {
            let mut up = gates.clone();
            up[j] += h;
            let mut dn = gates.clone();
            dn[j] -= h;
            let fd = (probe_loss(&a, m, n, &up, &c) - probe_loss(&a, m, n, &dn, &c))
                / (2.0 * h);
            assert!((g.d_g[j] - fd).abs() < 1e-4 * fd.abs().max(1.0),
                    "d_g[{j}] {} vs fd {fd}", g.d_g[j]);
        }
    }

    #[test]
    fn fd_validates_gradient_wide_and_square() {
        let mut rng = XorShift::new(43);
        for &(m, n) in &[(4usize, 7usize), (5, 5)] {
            let r = m.min(n);
            let a: Vec<f64> = randv(&mut rng, m * n, 0.8).iter().map(|&x| x as f64).collect();
            let gates: Vec<f64> =
                (0..r).map(|_| super::super::tape::sigmoid(rng.normal())).collect();
            let c: Vec<f64> = randv(&mut rng, m * n, 1.0).iter().map(|&x| x as f64).collect();
            let g = gated_recon_grad(&a, m, n, &gates, &c);
            let h = 1e-4;
            for p in (0..m * n).step_by(3) {
                let mut up = a.clone();
                up[p] += h;
                let mut dn = a.clone();
                dn[p] -= h;
                let fd = (probe_loss(&up, m, n, &gates, &c)
                          - probe_loss(&dn, m, n, &gates, &c)) / (2.0 * h);
                assert!((g.d_a[p] - fd).abs() < 1e-4 * (1.0 + fd.abs()),
                        "{m}x{n} dA[{p}]: {} vs {fd}", g.d_a[p]);
            }
        }
    }

    /// Exactly degenerate pairs: the raw adjoint is unbounded (the true
    /// map is non-differentiable), the stabilized one must stay finite and
    /// below the ε-bound — the whole point of the Taylor fix.
    #[test]
    fn exact_degeneracy_stays_bounded() {
        let (m, n) = (6usize, 5usize);
        let a = with_spectrum(&[2.0, 1.0, 1.0, 1.0, 1e-9], m, n, 44);
        let gates = [0.9, 0.8, 0.5, 0.2, 0.1];
        let c = vec![1.0; m * n];
        let g = gated_recon_grad(&a, m, n, &gates, &c);
        assert!(g.d_a.iter().all(|x| x.is_finite()), "degenerate gradient not finite");
        // |F| <= 1/(2ε) with ε = TAYLOR_EPS_REL σ_max²; the full contraction
        // adds O(n²) bounded terms — generous structural bound:
        let bound = (n * n) as f64 / (2.0 * TAYLOR_EPS_REL) * 10.0;
        assert!(g.d_a.iter().all(|&x| x.abs() < bound),
                "stabilized gradient exceeded the ε-bound");
    }

    #[test]
    fn stabilized_gap_limits() {
        // far from degeneracy: matches 1/d to O(ε²/d²)
        let d = 0.5;
        assert!((stabilized_inv_gap(d, 1.0) - 1.0 / d).abs() < 1e-10);
        // at degeneracy: exactly 0 (odd function), near it: bounded
        assert_eq!(stabilized_inv_gap(0.0, 1.0), 0.0);
        let eps = TAYLOR_EPS_REL;
        assert!(stabilized_inv_gap(eps, 1.0) <= 1.0 / (2.0 * eps) + 1.0);
        // odd symmetry
        assert_eq!(stabilized_inv_gap(-d, 1.0), -stabilized_inv_gap(d, 1.0));
        // zero/denormal scale (all-zero spectrum): never NaN/inf
        assert_eq!(stabilized_inv_gap(0.0, 0.0), 0.0);
        assert!(stabilized_inv_gap(1e-300, 0.0).is_finite());
    }

    #[test]
    fn zero_spectrum_sensitivity_is_finite() {
        // a pruned / zero-init target: sensitivity must stay finite so it
        // cannot poison the mean-based LR damping in learn_ranks
        let s = spectrum_sensitivity(&[0.0, 0.0, 0.0], &[0.9, 0.5, 0.1]);
        assert!(s.is_finite(), "zero spectrum gave {s}");
        // only the diagonal (gate) terms survive: sqrt(sum g² / r)
        let want = ((0.81 + 0.25 + 0.01f64) / 3.0).sqrt();
        assert!((s - want).abs() < 1e-12, "{s} vs {want}");
    }

    #[test]
    fn reconstruction_matches_gated_spectrum() {
        // On A = diag(σ): Â must be diag(g∘σ) exactly (up to SVD noise).
        let sigma = [4.0, 2.0, 1.0];
        let gates = [1.0, 0.5, 0.0];
        let mut a = vec![0f64; 9];
        for j in 0..3 {
            a[j * 3 + j] = sigma[j];
        }
        let zeros = vec![0f64; 9];
        let g = gated_recon_grad(&a, 3, 3, &gates, &zeros);
        for i in 0..3 {
            for j in 0..3 {
                let want = if i == j { gates[j] * sigma[j] } else { 0.0 };
                assert!((g.recon[i * 3 + j] - want).abs() < 1e-5,
                        "recon[{i},{j}] = {}", g.recon[i * 3 + j]);
            }
        }
        assert!((g.sigma[0] - 4.0).abs() < 1e-5);
    }

    #[test]
    fn sensitivity_closed_form_matches_general_adjoint() {
        // The O(r²) closed form must agree with running the full stabilized
        // adjoint on the diagonal embedding under the all-ones probe.
        let sigma = [5.0f64, 2.5, 2.49, 0.9, 0.1];
        let gates = [0.97, 0.8, 0.55, 0.3, 0.02];
        let r = sigma.len();
        let mut a = vec![0f64; r * r];
        for j in 0..r {
            a[j * r + j] = sigma[j];
        }
        let ones = vec![1.0; r * r];
        let g = gated_recon_grad(&a, r, r, &gates, &ones);
        let general = (g.d_a.iter().map(|&x| x * x).sum::<f64>() / r as f64).sqrt();
        let closed = spectrum_sensitivity(&sigma, &gates);
        assert!((closed - general).abs() < 1e-6 * general.max(1.0),
                "closed form {closed} vs general adjoint {general}");
    }

    #[test]
    fn sensitivity_ranks_degenerate_spectra_higher() {
        // same energy, one spectrum has a near-degenerate pair under a
        // half-open gate: its truncation gradient must be far larger
        let clean = [3.0f64, 2.0, 1.0, 0.5];
        let degen = [3.0f64, 1.50001, 1.5, 0.5];
        let gates = [1.0, 0.6, 0.4, 0.1];
        let s_clean = spectrum_sensitivity(&clean, &gates);
        let s_degen = spectrum_sensitivity(&degen, &gates);
        assert!(s_clean.is_finite() && s_degen.is_finite());
        assert!(s_degen > 4.0 * s_clean,
                "degenerate spectrum not flagged: {s_degen} vs {s_clean}");
    }
}
