//! Calibration capture: sample token windows, run the dense forward, and
//! collect the per-target input activations the truncation search and the
//! IPCA reconstruction consume — the native mirror of
//! `python/compile/dobi/pipeline.py::collect_calibration`.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::lowrank::FactorizedModel;
use crate::mathx::XorShift;

/// Representative tap key of a compression target: targets that multiply
/// the same buffer share one stored tap (wq/wk/wv the post-attn-norm
/// matrix, w_gate/w_up the post-mlp-norm matrix), so calibration keeps 4
/// buffers per layer instead of 7 identical-copy ones.  Mirrors the
/// capture points `FactorizedModel::run_trunk` records.
pub fn tap_key(name: &str) -> String {
    for (alias, rep) in [(".wk", ".wq"), (".wv", ".wq"), (".w_up", ".w_gate")] {
        if let Some(prefix) = name.strip_suffix(alias) {
            return format!("{prefix}{rep}");
        }
    }
    name.to_string()
}

/// Per-target calibration activations: one row-major (rows, in_dim)
/// input matrix per calibration batch, stored per capture point (see
/// [`tap_key`]) and looked up per target.
#[derive(Debug, Default)]
pub struct Calibration {
    pub taps: BTreeMap<String, Vec<Vec<f32>>>,
    pub n_batches: usize,
}

impl Calibration {
    /// Batches captured for target `name`, resolved through [`tap_key`]
    /// (empty slice when the name is unknown).
    pub fn batches(&self, name: &str) -> &[Vec<f32>] {
        self.taps.get(&tap_key(name)).map(|v| v.as_slice()).unwrap_or(&[])
    }
}

/// Deterministic synthetic calibration corpus (token ids in [0, vocab)),
/// for fixtures and `dobi compress --synth` where no tokbin is supplied.
pub fn synth_calib_tokens(vocab: usize, len: usize, seed: u64) -> Vec<i32> {
    let mut rng = XorShift::new(seed);
    (0..len).map(|_| rng.below(vocab.max(1)) as i32).collect()
}

/// Draw `b` random contiguous windows of `s` tokens each (the python
/// pipeline's `rng.integers(0, hi)` scheme), concatenated row-major —
/// the window sampling shared by calibration and eval-loss batches.
pub fn sample_windows(tokens: &[i32], b: usize, s: usize,
                      rng: &mut XorShift) -> Result<Vec<i32>> {
    anyhow::ensure!(b >= 1 && s >= 1, "windows need b/s >= 1");
    anyhow::ensure!(tokens.len() > s + 1,
                    "corpus too short: {} tokens for seq {s}", tokens.len());
    let hi = tokens.len() - s - 1;
    let mut toks = Vec::with_capacity(b * s);
    for _ in 0..b {
        let at = rng.below(hi);
        toks.extend_from_slice(&tokens[at..at + s]);
    }
    Ok(toks)
}

/// Run `n_batches` calibration forwards of shape (batch, seq) over random
/// windows of `tokens`, collecting every target's input.  VLM/VLA trunks
/// calibrate with a zero image (the text path dominates the compression
/// targets).  Windows are sampled with the same `rng.integers(0, hi)`
/// scheme as the python pipeline.
pub fn collect(model: &FactorizedModel, tokens: &[i32], n_batches: usize,
               batch: usize, seq: usize, seed: u64) -> Result<Calibration> {
    anyhow::ensure!(n_batches >= 1, "calibration needs n_batches >= 1");
    for (i, &t) in tokens.iter().enumerate() {
        anyhow::ensure!(t >= 0 && (t as usize) < model.vocab,
                        "calibration token {t} at {i} outside vocab {}", model.vocab);
    }
    let mut rng = XorShift::new(seed);
    let image = if model.img_dim > 0 { Some(vec![0f32; batch * model.img_dim]) } else { None };
    let mut cal = Calibration::default();
    for _ in 0..n_batches {
        let toks = sample_windows(tokens, batch, seq, &mut rng)?;
        let taps = model.forward_taps(batch, seq, &toks, image.as_deref())?;
        for (name, x) in taps {
            cal.taps.entry(name).or_default().push(x);
        }
    }
    cal.n_batches = n_batches;
    Ok(cal)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lowrank::synth::{tiny_model, TinyDims};

    fn dims() -> TinyDims {
        TinyDims { vocab: 61, d: 16, heads: 2, layers: 2, ff: 24 }
    }

    #[test]
    fn collects_per_batch_taps_for_all_targets() {
        let m = tiny_model(dims(), 0, false);
        let tokens = synth_calib_tokens(61, 400, 9);
        let cal = collect(&m, &tokens, 3, 2, 8, 5).unwrap();
        assert_eq!(cal.n_batches, 3);
        // stored: one tap per capture point...
        assert_eq!(cal.taps.len(), 4 * dims().layers);
        // ...resolvable for every one of the 7 per-layer targets
        for li in 0..dims().layers {
            for mat in ["wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down"] {
                let name = format!("layers.{li}.{mat}");
                let batches = cal.batches(&name);
                assert_eq!(batches.len(), 3, "{name}: one tap per batch");
                let in_dim = if mat == "w_down" { dims().ff } else { dims().d };
                for x in batches {
                    assert_eq!(x.len(), 2 * 8 * in_dim, "{name}: (rows, in_dim)");
                }
            }
        }
        // aliases resolve to the same stored buffer
        assert_eq!(cal.batches("layers.0.wk"), cal.batches("layers.0.wq"));
        assert_eq!(cal.batches("layers.1.w_up"), cal.batches("layers.1.w_gate"));
        assert!(cal.batches("layers.0.nope").is_empty());
    }

    #[test]
    fn tap_key_resolves_aliases_only() {
        assert_eq!(tap_key("layers.3.wk"), "layers.3.wq");
        assert_eq!(tap_key("layers.3.wv"), "layers.3.wq");
        assert_eq!(tap_key("layers.0.w_up"), "layers.0.w_gate");
        for stay in ["layers.0.wq", "layers.0.wo", "layers.2.w_gate", "layers.2.w_down"] {
            assert_eq!(tap_key(stay), stay);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let m = tiny_model(dims(), 0, false);
        let tokens = synth_calib_tokens(61, 300, 1);
        let a = collect(&m, &tokens, 2, 2, 6, 7).unwrap();
        let b = collect(&m, &tokens, 2, 2, 6, 7).unwrap();
        assert_eq!(a.taps, b.taps);
        let c = collect(&m, &tokens, 2, 2, 6, 8).unwrap();
        assert!(a.taps != c.taps, "different seed must sample different windows");
    }

    #[test]
    fn rejects_short_corpus_and_bad_tokens() {
        let m = tiny_model(dims(), 0, false);
        assert!(collect(&m, &[1, 2, 3], 1, 1, 8, 0).is_err());
        let mut toks = synth_calib_tokens(61, 100, 2);
        toks[50] = 61; // out of vocab
        assert!(collect(&m, &toks, 1, 1, 8, 0).is_err());
    }

    #[test]
    fn vlm_trunk_calibrates_with_zero_image() {
        let m = tiny_model(dims(), 6, false);
        let tokens = synth_calib_tokens(61, 200, 3);
        let cal = collect(&m, &tokens, 2, 2, 6, 4).unwrap();
        // prefix rows count toward the tap: rows = b * (prefix + s)
        let rows = 2 * (m.n_img_tokens + 6);
        assert_eq!(cal.batches("layers.0.wq")[0].len(), rows * dims().d);
    }
}
