//! IPCA weight reconstruction + remapped factor extraction — the native
//! mirror of `python/compile/dobi/ipca.py` and `remap.py`.
//!
//! Given calibration activations `A_i = X_i W`, the EYM-optimal rank-k
//! update is `W~ = W V V^T` where `V` spans the dominant subspace of the
//! stacked per-batch right-singular bases (paper §3.2, Algo 2).  Full PCA
//! would materialize an n x (batches*k) stack; [`Ipca`] keeps an n x k
//! running basis and folds one batch at a time, weighting columns by
//! their accumulated singular values so early batches are not washed out.
//!
//! [`reconstruct_factors`] then exploits that `W~` is already a rank-k
//! product: with `B0 = W V` (m x k), a single small SVD `B0 = U S P^T`
//! yields `W~ = U S (V P)^T`, and the symmetric-sqrt split
//! `W1 = U sqrt(S)`, `W2 = sqrt(S) (V P)^T` keeps both factors at
//! comparable dynamic range — the property that makes them int8-friendly
//! (`remap.py::factorize`, paper Fig 5/6).

use super::svd::svd_thin;

/// Streaming dominant-subspace tracker over right-singular bases.
/// Peak memory O(n * 2k), constant in the number of batches (Fig 3c).
pub struct Ipca {
    n: usize,
    k: usize,
    /// (n, kk) row-major orthonormal columns; kk <= k grows to k.
    basis: Vec<f32>,
    /// kk accumulated singular weights.
    weights: Vec<f32>,
    kk: usize,
    n_seen: usize,
}

impl Ipca {
    pub fn new(n: usize, k: usize) -> Ipca {
        assert!(k >= 1 && k <= n, "ipca: k {k} outside [1, {n}]");
        Ipca { n, k, basis: Vec::new(), weights: Vec::new(), kk: 0, n_seen: 0 }
    }

    pub fn n_seen(&self) -> usize {
        self.n_seen
    }

    /// Fold one batch's (basis: (n, kin) row-major, weights: kin).
    pub fn partial_fit(&mut self, v_i: &[f32], s_i: &[f32]) {
        let kin = s_i.len();
        assert_eq!(v_i.len(), self.n * kin, "ipca: basis not (n, {kin})");
        self.n_seen += 1;
        if self.kk == 0 {
            self.kk = kin.min(self.k);
            self.basis = vec![0f32; self.n * self.kk];
            for i in 0..self.n {
                for j in 0..self.kk {
                    self.basis[i * self.kk + j] = v_i[i * kin + j];
                }
            }
            self.weights = s_i[..self.kk].to_vec();
            return;
        }
        // stacked = [basis * weights | v_i * s_i]  (n, kk + kin)
        let cols = self.kk + kin;
        let mut stacked = vec![0f32; self.n * cols];
        for i in 0..self.n {
            for j in 0..self.kk {
                stacked[i * cols + j] = self.basis[i * self.kk + j] * self.weights[j];
            }
            for j in 0..kin {
                stacked[i * cols + self.kk + j] = v_i[i * kin + j] * s_i[j];
            }
        }
        let svd = svd_thin(&stacked, self.n, cols);
        let r = svd.rank();
        let kk = self.k.min(r);
        let mut basis = vec![0f32; self.n * kk];
        for i in 0..self.n {
            for j in 0..kk {
                basis[i * kk + j] = svd.u[i * r + j];
            }
        }
        self.basis = basis;
        self.weights = svd.s[..kk].to_vec();
        self.kk = kk;
    }

    /// The tracked orthonormal basis as ((n, kk) row-major, kk).
    pub fn components(&self) -> (&[f32], usize) {
        assert!(self.kk > 0, "ipca: partial_fit never called");
        (&self.basis, self.kk)
    }
}

/// Top-k right-singular basis of one activation batch (rows, n):
/// returns (V_k: (n, k) row-major, s_k).
pub fn batch_right_basis(a: &[f32], rows: usize, n: usize,
                         k: usize) -> (Vec<f32>, Vec<f32>) {
    let svd = svd_thin(a, rows, n);
    let r = svd.rank();
    let k = k.min(r);
    let mut v = vec![0f32; n * k];
    for i in 0..n {
        for j in 0..k {
            v[i * k + j] = svd.vt[j * n + i];
        }
    }
    (v, svd.s[..k].to_vec())
}

/// Reconstructed rank-k factors of one target from truncated calibration
/// activations.  `w` is (m, n) row-major; `xs` are per-batch (rows, m)
/// calibration inputs.  Returns `(w1: (m, k'), w2: (k', n), k')` with
/// `k' = k` unless the calibration subspace is narrower (then `k' < k`).
pub fn reconstruct_factors(w: &[f32], m: usize, n: usize, xs: &[Vec<f32>],
                           k: usize) -> (Vec<f32>, Vec<f32>, usize) {
    assert_eq!(w.len(), m * n, "reconstruct: weight not {m}x{n}");
    assert!(k >= 1 && k <= m.min(n), "reconstruct: rank {k} outside [1, {}]", m.min(n));
    assert!(!xs.is_empty(), "reconstruct: no calibration batches");
    // Track a basis wider than k (as the python pipeline does) so the
    // k-dim cut of the converged subspace is stable.
    let k_track = (k + 16).max(k * 5 / 4).min(m.min(n));
    let mut tracker = Ipca::new(n, k_track);
    for x in xs {
        let rows = x.len() / m;
        assert_eq!(x.len(), rows * m, "calibration batch not (rows, {m})");
        // a = x @ w  (rows, n)
        let mut a = vec![0f32; rows * n];
        for r in 0..rows {
            for t in 0..m {
                let xv = x[r * m + t];
                if xv != 0.0 {
                    let wrow = &w[t * n..(t + 1) * n];
                    let orow = &mut a[r * n..(r + 1) * n];
                    for (o, &wv) in orow.iter_mut().zip(wrow) {
                        *o += xv * wv;
                    }
                }
            }
        }
        let (v_i, s_i) = batch_right_basis(&a, rows, n, k_track);
        tracker.partial_fit(&v_i, &s_i);
    }
    let (basis, kk) = tracker.components();
    let k = k.min(kk);
    // v: (n, k) leading columns of the tracked basis
    let mut v = vec![0f32; n * k];
    for i in 0..n {
        for j in 0..k {
            v[i * k + j] = basis[i * kk + j];
        }
    }
    // b0 = w @ v  (m, k)
    let mut b0 = vec![0f32; m * k];
    for i in 0..m {
        for t in 0..n {
            let wv = w[i * n + t];
            if wv != 0.0 {
                for j in 0..k {
                    b0[i * k + j] += wv * v[t * k + j];
                }
            }
        }
    }
    // b0 = U S P^T  (m >= k always: k <= min(m, n)), so rank == k slots.
    let svd = svd_thin(&b0, m, k);
    let r = svd.rank(); // == k
    let rs: Vec<f32> = svd.s.iter().map(|&s| s.max(0.0).sqrt()).collect();
    // w1 = U sqrt(S)  (m, k)
    let mut w1 = vec![0f32; m * k];
    for i in 0..m {
        for j in 0..k {
            w1[i * k + j] = svd.u[i * r + j] * rs[j];
        }
    }
    // w2 = sqrt(S) P^T V^T  (k, n): first ps = diag(rs) @ vt  (k, k),
    // then w2[j, i] = sum_l ps[j, l] * v[i, l].
    let mut w2 = vec![0f32; k * n];
    for j in 0..k {
        for i in 0..n {
            let mut acc = 0f32;
            for l in 0..k {
                acc += rs[j] * svd.vt[j * k + l] * v[i * k + l];
            }
            w2[j * n + i] = acc;
        }
    }
    (w1, w2, k)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::testutil::{matmul_ref, randv};
    use crate::mathx::XorShift;

    fn fro(xs: &[f32]) -> f64 {
        xs.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    #[test]
    fn ipca_tracks_dominant_subspace_of_identical_batches() {
        // Every batch contributes the same basis: IPCA must return it.
        let n = 6usize;
        let k = 2usize;
        // orthonormal 2-col basis: e0, e3
        let mut v = vec![0f32; n * k];
        v[0] = 1.0;
        v[3 * k + 1] = 1.0;
        let s = vec![5.0f32, 2.0];
        let mut tr = Ipca::new(n, k);
        for _ in 0..4 {
            tr.partial_fit(&v, &s);
        }
        let (b, kk) = tr.components();
        assert_eq!(kk, k);
        assert_eq!(tr.n_seen(), 4);
        // columns span {e0, e3} (up to sign): check projector equality
        let proj = |basis: &[f32]| -> Vec<f32> {
            let mut bt = vec![0f32; k * n];
            for i in 0..n {
                for j in 0..k {
                    bt[j * n + i] = basis[i * k + j];
                }
            }
            matmul_ref(basis, n, k, &bt, n)
        };
        let got = proj(b);
        let want = proj(&v);
        for (a, c) in got.iter().zip(&want) {
            assert!((a - c).abs() < 1e-4, "projector drifted");
        }
    }

    #[test]
    fn reconstruct_matches_oracle_on_lowrank_activations() {
        // X has an exact rank-3 column space => rank-3 reconstruction must
        // reproduce X W almost exactly.
        let mut rng = XorShift::new(21);
        let (m, n, true_k) = (10usize, 8usize, 3usize);
        let w = randv(&mut rng, m * n, 0.5);
        let mix = randv(&mut rng, true_k * m, 0.8);
        let xs: Vec<Vec<f32>> = (0..3)
            .map(|_| {
                let z = randv(&mut rng, 20 * true_k, 1.0);
                matmul_ref(&z, 20, true_k, &mix, m)
            })
            .collect();
        let (w1, w2, k) = reconstruct_factors(&w, m, n, &xs, true_k);
        assert_eq!(k, true_k);
        let wk = matmul_ref(&w1, m, k, &w2, n);
        for x in &xs {
            let rows = x.len() / m;
            let a = matmul_ref(x, rows, m, &w, n);
            let ak = matmul_ref(x, rows, m, &wk, n);
            let err = a.iter().zip(&ak).map(|(p, q)| (p - q).abs()).fold(0f32, f32::max);
            assert!(err < 1e-3 * (1.0 + fro(&a) as f32), "activation err {err}");
        }
    }

    #[test]
    fn full_rank_reconstruction_recovers_weight() {
        // k = min(m, n) with rich calibration => W~ == W (VV^T == I on the
        // activation row space, which is everything).
        let mut rng = XorShift::new(22);
        let (m, n) = (7usize, 6usize);
        let w = randv(&mut rng, m * n, 0.5);
        let xs: Vec<Vec<f32>> = (0..3).map(|_| randv(&mut rng, 15 * m, 1.0)).collect();
        let (w1, w2, k) = reconstruct_factors(&w, m, n, &xs, n);
        assert_eq!(k, n);
        let wk = matmul_ref(&w1, m, k, &w2, n);
        let err = wk.iter().zip(&w).map(|(p, q)| (p - q).abs()).fold(0f32, f32::max);
        assert!(err < 1e-3, "full-rank reconstruction err {err}");
    }

    #[test]
    fn factors_have_balanced_scale() {
        // symmetric-sqrt split: ||W1||_F ~= ||W2||_F (the int8-friendliness
        // property the remap relies on).
        let mut rng = XorShift::new(23);
        let (m, n, k) = (12usize, 9usize, 4usize);
        let w = randv(&mut rng, m * n, 0.5);
        let xs: Vec<Vec<f32>> = (0..2).map(|_| randv(&mut rng, 20 * m, 1.0)).collect();
        let (w1, w2, _) = reconstruct_factors(&w, m, n, &xs, k);
        let (f1, f2) = (fro(&w1), fro(&w2));
        assert!(f1 > 0.0 && f2 > 0.0);
        let ratio = f1 / f2;
        assert!(ratio > 0.5 && ratio < 2.0, "factor scales unbalanced: {ratio}");
    }

    #[test]
    fn narrow_calibration_clamps_rank() {
        // 2-row batches can only witness a 2-dim activation subspace; a
        // rank-5 request must clamp to what the calibration supports.
        let mut rng = XorShift::new(24);
        let (m, n) = (8usize, 6usize);
        let w = randv(&mut rng, m * n, 0.5);
        let xs = vec![randv(&mut rng, 2 * m, 1.0)];
        let (w1, w2, k) = reconstruct_factors(&w, m, n, &xs, 5);
        assert!(k <= 2, "rank {k} exceeds witnessed subspace");
        assert_eq!(w1.len(), m * k);
        assert_eq!(w2.len(), k * n);
    }
}
