//! The per-release compression run report: a structured record of one
//! `dobi compress` invocation — toolchain + config echo, per-phase
//! wall-clock shares, a per-target table (dims, kept rank, whitened tail
//! energy, reconstruction error, SVD sweeps/time, quant codec), and the
//! full learned-alloc training trajectory when that optimizer ran.
//!
//! The pipeline assembles a [`RunReport`] while it compresses, the
//! artifact writers persist it as `<variant>.run.json` next to the store
//! (referenced from the manifest entry's `run_report` field), and
//! `dobi inspect --run <id>` renders it back as text tables or raw JSON.

use anyhow::{anyhow, Result};

use crate::bench::{fmt_f, Table};
use crate::json::Json;

use super::train::{AllocPick, TrainReport, TrainSample};

/// Wall-clock accounting for one pipeline phase.  `share` is the fraction
/// of the summed phase time (the run envelope is excluded from the sum so
/// shares add up to 1 across the non-overlapping phases).
#[derive(Debug, Clone, PartialEq)]
pub struct PhaseShare {
    /// A `compress_*` phase name from [`crate::trace::phases`].
    pub phase: String,
    pub seconds: f64,
    pub share: f64,
}

/// One compression target's row in the run report.
#[derive(Debug, Clone, PartialEq)]
pub struct TargetReport {
    /// Manifest name, e.g. `layers.0.wq`.
    pub name: String,
    /// Input (row) dimension.
    pub m: usize,
    /// Output (column) dimension.
    pub n: usize,
    /// Rank the allocator kept.
    pub rank: usize,
    /// min(m, n) — the full rank the target was truncated from.
    pub max_rank: usize,
    /// Whitened tail energy at the kept rank (normalized truncation loss).
    pub tail_energy: f64,
    /// Relative reconstruction error `‖W − W1·W2‖_F / ‖W‖_F`.
    pub recon_error: f64,
    /// Jacobi sweeps the whitened spectrum SVD took.
    pub svd_sweeps: usize,
    /// Wall-clock seconds of that SVD.
    pub svd_seconds: f64,
    /// Stored-factor codec ("f32" / "f16" / "q8").
    pub codec: String,
}

/// The whole-run record `dobi compress` persists per release.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub variant_id: String,
    pub model: String,
    /// Rank-allocation mode ("waterfill" / "learned").
    pub alloc: String,
    /// Writer identity, mirroring the provenance toolchain block.
    pub writer: String,
    pub format: String,
    pub crate_version: String,
    /// Verbatim `CompressConfig` dump.
    pub config: Json,
    /// Whole-run wall clock (the `compress_run` envelope).
    pub total_seconds: f64,
    /// Per-phase wall clock; shares sum to 1.
    pub phases: Vec<PhaseShare>,
    pub targets: Vec<TargetReport>,
    /// Learned-alloc optimizer diagnostics incl. the sampled trajectory,
    /// present iff the learned allocator ran.
    pub train: Option<TrainReport>,
}

fn jnum(x: usize) -> Json {
    Json::Num(x as f64)
}

fn pick_str(p: AllocPick) -> &'static str {
    match p {
        AllocPick::Learned => "learned",
        AllocPick::Waterfill => "waterfill",
    }
}

fn pick_parse(s: &str) -> Result<AllocPick> {
    match s {
        "learned" => Ok(AllocPick::Learned),
        "waterfill" => Ok(AllocPick::Waterfill),
        other => Err(anyhow!("run report: unknown alloc pick `{other}`")),
    }
}

fn train_json(t: &TrainReport) -> Json {
    let vec_json = |v: &[f64]| Json::Arr(v.iter().map(|&x| Json::Num(x)).collect());
    let trajectory = t
        .trajectory
        .iter()
        .map(|s| {
            Json::obj(vec![
                ("iter", jnum(s.iter)),
                ("tail", Json::Num(s.tail)),
                ("lambda", Json::Num(s.lambda)),
                ("tau", Json::Num(s.tau)),
                ("expected_cost", Json::Num(s.expected_cost)),
                ("t_us", jnum(s.t_us as usize)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("iters", jnum(t.iters)),
        ("tail_init", Json::Num(t.tail_init)),
        ("tail_final", Json::Num(t.tail_final)),
        ("expected_cost", Json::Num(t.expected_cost)),
        ("lambda", Json::Num(t.lambda)),
        ("shares", vec_json(&t.shares)),
        ("sensitivity", vec_json(&t.sensitivity)),
        ("learned_surrogate", Json::Num(t.learned_surrogate)),
        ("waterfill_surrogate", Json::Num(t.waterfill_surrogate)),
        ("picked", Json::Str(pick_str(t.picked).into())),
        ("trajectory", Json::Arr(trajectory)),
    ])
}

fn train_parse(j: &Json) -> Result<TrainReport> {
    let missing = |k: &str| anyhow!("run report train block: missing `{k}`");
    let num = |k: &str| j.get(k).and_then(Json::as_f64).ok_or_else(|| missing(k));
    let vec_f64 = |k: &str| -> Result<Vec<f64>> {
        j.get(k)
            .and_then(Json::as_arr)
            .ok_or_else(|| missing(k))?
            .iter()
            .map(|x| x.as_f64().ok_or_else(|| anyhow!("train `{k}`: non-numeric entry")))
            .collect()
    };
    let mut trajectory = Vec::new();
    for s in j.get("trajectory").and_then(Json::as_arr).ok_or_else(|| missing("trajectory"))? {
        let field = |k: &str| {
            s.get(k).and_then(Json::as_f64).ok_or_else(|| anyhow!("trajectory sample: bad `{k}`"))
        };
        trajectory.push(TrainSample {
            iter: field("iter")? as usize,
            tail: field("tail")?,
            lambda: field("lambda")?,
            tau: field("tau")?,
            expected_cost: field("expected_cost")?,
            t_us: field("t_us")? as u64,
        });
    }
    Ok(TrainReport {
        iters: num("iters")? as usize,
        tail_init: num("tail_init")?,
        tail_final: num("tail_final")?,
        expected_cost: num("expected_cost")?,
        lambda: num("lambda")?,
        shares: vec_f64("shares")?,
        sensitivity: vec_f64("sensitivity")?,
        learned_surrogate: num("learned_surrogate")?,
        waterfill_surrogate: num("waterfill_surrogate")?,
        picked: pick_parse(
            j.get("picked").and_then(Json::as_str).ok_or_else(|| missing("picked"))?,
        )?,
        trajectory,
    })
}

impl RunReport {
    /// The on-disk file name next to the store: `<variant>.run.json` with
    /// the `/` of the variant id flattened exactly like the `.dobiw` name.
    pub fn file_name(variant_id: &str) -> String {
        format!("{}.run.json", variant_id.replace('/', "_"))
    }

    /// Append one phase's wall clock and renormalize so the listed
    /// shares always sum to 1 (the writers use this to fold the
    /// `compress_write` phase in after the compute phases were recorded).
    pub fn push_phase(&mut self, phase: &str, seconds: f64) {
        self.phases.push(PhaseShare { phase: phase.to_string(), seconds, share: 0.0 });
        let total: f64 = self.phases.iter().map(|p| p.seconds).sum();
        if total > 0.0 {
            for p in &mut self.phases {
                p.share = p.seconds / total;
            }
        }
    }

    pub fn to_json(&self) -> Json {
        let phases = self
            .phases
            .iter()
            .map(|p| {
                Json::obj(vec![
                    ("phase", Json::Str(p.phase.clone())),
                    ("seconds", Json::Num(p.seconds)),
                    ("share", Json::Num(p.share)),
                ])
            })
            .collect();
        let targets = self
            .targets
            .iter()
            .map(|t| {
                Json::obj(vec![
                    ("name", Json::Str(t.name.clone())),
                    ("m", jnum(t.m)),
                    ("n", jnum(t.n)),
                    ("rank", jnum(t.rank)),
                    ("max_rank", jnum(t.max_rank)),
                    ("tail_energy", Json::Num(t.tail_energy)),
                    ("recon_error", Json::Num(t.recon_error)),
                    ("svd_sweeps", jnum(t.svd_sweeps)),
                    ("svd_seconds", Json::Num(t.svd_seconds)),
                    ("codec", Json::Str(t.codec.clone())),
                ])
            })
            .collect();
        Json::obj(vec![
            ("kind", Json::Str("dobi-run-report".into())),
            ("variant_id", Json::Str(self.variant_id.clone())),
            ("model", Json::Str(self.model.clone())),
            ("alloc", Json::Str(self.alloc.clone())),
            ("toolchain", Json::obj(vec![
                ("writer", Json::Str(self.writer.clone())),
                ("format", Json::Str(self.format.clone())),
                ("crate_version", Json::Str(self.crate_version.clone())),
            ])),
            ("config", self.config.clone()),
            ("total_seconds", Json::Num(self.total_seconds)),
            ("phases", Json::Arr(phases)),
            ("targets", Json::Arr(targets)),
            (
                "train",
                match &self.train {
                    Some(t) => train_json(t),
                    None => Json::Null,
                },
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<RunReport> {
        let missing = |k: &str| anyhow!("run report: missing `{k}`");
        let str_field = |k: &str| -> Result<String> {
            Ok(j.get(k).and_then(Json::as_str).ok_or_else(|| missing(k))?.to_string())
        };
        anyhow::ensure!(
            j.get("kind").and_then(Json::as_str) == Some("dobi-run-report"),
            "not a dobi run report (kind field mismatch)"
        );
        let tc = j.get("toolchain").ok_or_else(|| missing("toolchain"))?;
        let tc_str = |k: &str| -> Result<String> {
            Ok(tc.get(k).and_then(Json::as_str).ok_or_else(|| missing(k))?.to_string())
        };
        let mut phases = Vec::new();
        for p in j.get("phases").and_then(Json::as_arr).ok_or_else(|| missing("phases"))? {
            phases.push(PhaseShare {
                phase: p
                    .get("phase")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("phase row: missing `phase`"))?
                    .to_string(),
                seconds: p.get("seconds").and_then(Json::as_f64).unwrap_or(0.0),
                share: p.get("share").and_then(Json::as_f64).unwrap_or(0.0),
            });
        }
        let mut targets = Vec::new();
        for t in j.get("targets").and_then(Json::as_arr).ok_or_else(|| missing("targets"))? {
            let us = |k: &str| {
                t.get(k).and_then(Json::as_usize).ok_or_else(|| anyhow!("target row: bad `{k}`"))
            };
            targets.push(TargetReport {
                name: t
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("target row: missing `name`"))?
                    .to_string(),
                m: us("m")?,
                n: us("n")?,
                rank: us("rank")?,
                max_rank: us("max_rank")?,
                tail_energy: t.get("tail_energy").and_then(Json::as_f64).unwrap_or(0.0),
                recon_error: t.get("recon_error").and_then(Json::as_f64).unwrap_or(0.0),
                svd_sweeps: us("svd_sweeps")?,
                svd_seconds: t.get("svd_seconds").and_then(Json::as_f64).unwrap_or(0.0),
                codec: t
                    .get("codec")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("target row: missing `codec`"))?
                    .to_string(),
            });
        }
        let train = match j.get("train") {
            None | Some(Json::Null) => None,
            Some(t) => Some(train_parse(t)?),
        };
        Ok(RunReport {
            variant_id: str_field("variant_id")?,
            model: str_field("model")?,
            alloc: str_field("alloc")?,
            writer: tc_str("writer")?,
            format: tc_str("format")?,
            crate_version: tc_str("crate_version")?,
            config: j.get("config").cloned().ok_or_else(|| missing("config"))?,
            total_seconds: j.get("total_seconds").and_then(Json::as_f64).unwrap_or(0.0),
            phases,
            targets,
            train,
        })
    }

    /// Text rendering for `dobi inspect --run`: a header line, the phase
    /// wall-clock table, the per-target table, and the learned-alloc
    /// summary when present.
    pub fn render(&self) -> String {
        let mut out = format!(
            "run report: {} (model {}, alloc {}, {} v{}, {:.3}s total)\n",
            self.variant_id, self.model, self.alloc, self.writer, self.crate_version,
            self.total_seconds
        );
        let mut pt = Table::new("phase wall clock", &["phase", "seconds", "share"]);
        for p in &self.phases {
            pt.row(vec![
                p.phase.clone(),
                fmt_f(p.seconds, 4),
                format!("{:.1}%", p.share * 100.0),
            ]);
        }
        out.push_str(&pt.render());
        let mut tt = Table::new(
            "targets",
            &["target", "dims", "rank", "tail_energy", "recon_err", "sweeps", "svd_s", "codec"],
        );
        for t in &self.targets {
            tt.row(vec![
                t.name.clone(),
                format!("{}x{}", t.m, t.n),
                format!("{}/{}", t.rank, t.max_rank),
                fmt_f(t.tail_energy, 4),
                fmt_f(t.recon_error, 4),
                t.svd_sweeps.to_string(),
                fmt_f(t.svd_seconds, 4),
                t.codec.clone(),
            ]);
        }
        out.push_str(&tt.render());
        if let Some(t) = &self.train {
            out.push_str(&format!(
                "train: {} iters, tail {:.4} -> {:.4}, lambda {:.3}, picked {} \
                 (surrogates: learned {:.4} vs waterfill {:.4}), {} trajectory samples\n",
                t.iters, t.tail_init, t.tail_final, t.lambda, pick_str(t.picked),
                t.learned_surrogate, t.waterfill_surrogate, t.trajectory.len()
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> RunReport {
        RunReport {
            variant_id: "tiny/dobi_40".into(),
            model: "tiny".into(),
            alloc: "learned".into(),
            writer: "dobi-native".into(),
            format: "DOBIW1".into(),
            crate_version: "0.1.0".into(),
            config: Json::obj(vec![("ratio", Json::Num(0.4))]),
            total_seconds: 1.25,
            phases: vec![
                PhaseShare { phase: "compress_calib".into(), seconds: 0.75, share: 0.75 },
                PhaseShare { phase: "compress_svd".into(), seconds: 0.25, share: 0.25 },
            ],
            targets: vec![TargetReport {
                name: "layers.0.wq".into(),
                m: 16,
                n: 16,
                rank: 5,
                max_rank: 16,
                tail_energy: 0.031,
                recon_error: 0.012,
                svd_sweeps: 7,
                svd_seconds: 0.004,
                codec: "q8".into(),
            }],
            train: Some(TrainReport {
                iters: 40,
                tail_init: 0.5,
                tail_final: 0.1,
                expected_cost: 1000.0,
                lambda: 0.2,
                shares: vec![1.0],
                sensitivity: vec![0.3],
                learned_surrogate: 0.09,
                waterfill_surrogate: 0.11,
                picked: AllocPick::Learned,
                trajectory: vec![TrainSample {
                    iter: 0,
                    tail: 0.5,
                    lambda: 0.0,
                    tau: 2.0,
                    expected_cost: 1100.0,
                    t_us: 12,
                }],
            }),
        }
    }

    #[test]
    fn json_round_trip_preserves_every_field() {
        let r = sample();
        let text = r.to_json().to_string();
        let back = RunReport::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.to_json().to_string(), text, "round trip must be lossless");
        assert_eq!(back.variant_id, r.variant_id);
        assert_eq!(back.phases, r.phases);
        assert_eq!(back.targets, r.targets);
        let (bt, rt) = (back.train.unwrap(), r.train.unwrap());
        assert_eq!(bt.trajectory, rt.trajectory);
        assert_eq!(bt.picked, rt.picked);
        assert_eq!(bt.iters, rt.iters);
        // a waterfill report (no train block) round-trips to None
        let mut wf = sample();
        wf.train = None;
        let back = RunReport::from_json(&wf.to_json()).unwrap();
        assert!(back.train.is_none());
    }

    #[test]
    fn push_phase_keeps_shares_normalized() {
        let mut r = sample();
        r.phases.clear();
        r.push_phase("compress_calib", 3.0);
        r.push_phase("compress_svd", 1.0);
        assert!((r.phases.iter().map(|p| p.share).sum::<f64>() - 1.0).abs() < 1e-12);
        assert!((r.phases[0].share - 0.75).abs() < 1e-12);
        r.push_phase("compress_write", 4.0);
        assert!((r.phases.iter().map(|p| p.share).sum::<f64>() - 1.0).abs() < 1e-12);
        assert!((r.phases[2].share - 0.5).abs() < 1e-12);
    }

    #[test]
    fn from_json_rejects_foreign_documents() {
        assert!(RunReport::from_json(&Json::obj(vec![("kind", Json::Str("other".into()))]))
            .is_err());
        let mut j = sample().to_json();
        if let Json::Obj(map) = &mut j {
            map.remove("targets");
        }
        assert!(RunReport::from_json(&j).is_err(), "missing targets must refuse");
    }

    #[test]
    fn render_mentions_phases_targets_and_train() {
        let text = sample().render();
        for needle in ["tiny/dobi_40", "compress_calib", "layers.0.wq", "5/16", "q8",
                       "picked learned", "75.0%"] {
            assert!(text.contains(needle), "missing `{needle}` in:\n{text}");
        }
        assert_eq!(RunReport::file_name("tiny/dobi_40"), "tiny_dobi_40.run.json");
    }
}
