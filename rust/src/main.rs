//! `dobi` CLI — leader entrypoint.
//!
//! Subcommands:
//!   inspect   — list artifacts: variants, sizes, ranks, ref PPLs
//!   compress  — native Dobi compression: dense store -> remapped factors
//!   eval      — perplexity + task accuracy for one variant
//!   generate  — sample text from a variant
//!   serve     — TCP line-protocol server over the engine
//!   memsim    — Table-10-style constrained-device projection
//!   lint      — self-hosted static analysis (drift + panic/lock rules)
//!   parity    — pallas-kernel vs xla-graph numerical parity check

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use dobi::cli::Args;
use dobi::config::{AllocMode, BackendKind, CompressConfig, EngineConfig, Manifest, Precision,
                   ServeConfig};
use dobi::coordinator::Engine;
use dobi::corpusio;
use dobi::evalx;
use dobi::json::Json;
use dobi::memsim::DeviceModel;
use dobi::runtime::{make_backend, Backend, ForwardModel, Runtime};
use dobi::serve::{ServeRuntime, SpecParams};
use dobi::server::Server;

fn main() {
    let args = Args::from_env(&["verbose", "all", "tasks", "synth", "stream", "no-stream",
                                "no-control", "replace", "json", "progress"]);
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get_or("artifacts", dobi::DEFAULT_ARTIFACTS))
}

fn backend_kind(args: &Args) -> Result<BackendKind> {
    BackendKind::parse(args.get_or("backend", "auto"))
}

fn backend(args: &Args) -> Result<Box<dyn Backend>> {
    make_backend(backend_kind(args)?)
}

fn run(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("inspect") => inspect(args),
        Some("compress") => compress(args),
        Some("eval") => eval(args),
        Some("generate") => generate(args),
        Some("serve") => serve(args),
        Some("memsim") => memsim_cmd(args),
        Some("lint") => lint(args),
        Some("parity") => parity(args),
        Some("debug-fwd") => debug_fwd(args),
        Some("debug-probe") => debug_probe(args),
        Some("kernel-report") => kernel_report(args),
        other => {
            eprintln!(
                "dobi — Dobi-SVD compression + serving stack\n\
                 usage: dobi <inspect|compress|eval|generate|serve|memsim|parity>\n\
                 \x20      [--artifacts DIR] [--backend auto|pjrt|native] ...\n\
                 \n\
                 inspect [--json] [--run ID]  list variants and storage accounting\n\
                 \x20        (--json: machine-readable table with full\n\
                 \x20        provenance sha256 per variant; --run renders a\n\
                 \x20        variant's compression run report — phase\n\
                 \x20        wall-clock shares + per-target table, --json for\n\
                 \x20        the raw document)\n\
                 compress --out DIR | --append DIR [--replace] [--ratio R]\n\
                 \x20        [--alloc waterfill|learned] [--train-iters N] [--train-lr F]\n\
                 \x20        [--precision q8|f16|f32] [--variant ID | --synth]\n\
                 \x20        [--calib FILE.tokbin] [--budget PARAMS] [--svd-threads T]\n\
                 \x20        [--trace-out PATH] [--trace-buffer N] [--progress]\n\
                 \x20        native Dobi compression: dense store ->\n\
                 \x20        rank-allocated remapped factors; --append merges\n\
                 \x20        the variant into an existing artifacts dir\n\
                 \x20        (--replace swaps a resident variant and GCs its\n\
                 \x20        orphaned store); --alloc learned runs the\n\
                 \x20        differentiable truncation-position optimizer;\n\
                 \x20        every run persists a <variant>.run.json report\n\
                 \x20        next to the store, --trace-out exports the\n\
                 \x20        compress_* phase spans as Chrome/Perfetto JSON\n\
                 \x20        (--trace-buffer sizes the ring, default 65536,\n\
                 \x20        0 disables), --progress prints a line per phase\n\
                 eval --variant ID [--tasks]  PPL on all corpora (+ task suites)\n\
                 generate --variant ID --prompt TEXT [--tokens N] [--temperature T]\n\
                 serve --variants A,B --port P [--max-sessions N]\n\
                 \x20     [--decode-threads T] [--stream | --no-stream]\n\
                 \x20     [--no-control] [--spec-draft ID] [--spec-k N]\n\
                 \x20     [--trace-buffer N]\n\
                 \x20     incremental decode runtime (KV cache + continuous\n\
                 \x20     batching + fused multi-session steps + streaming;\n\
                 \x20     T > 1 threads the blocked GEMM column-wise);\n\
                 \x20     control ops {\"op\":\"swap\"|\"list\"|\"health\"|\n\
                 \x20     \"metrics\"|\"trace\"} manage zero-downtime hot swaps\n\
                 \x20     and expose labeled metrics + request-lifecycle\n\
                 \x20     traces unless --no-control (--trace-buffer sizes\n\
                 \x20     the span ring, default 4096, 0 disables tracing);\n\
                 \x20     --spec-draft makes greedy requests decode\n\
                 \x20     speculatively (draft variant proposes N tokens per\n\
                 \x20     round, the target verifies in one batched step —\n\
                 \x20     output stays bit-identical to plain decode)\n\
                 memsim --model NAME [--capacity-mb M] [--bandwidth-mbs B]\n\
                 lint [--root DIR] [--format text|json] [--rule NAME]\n\
                 \x20    self-hosted static analysis of this checkout: panic\n\
                 \x20    freedom on the serve paths, lock ordering, and\n\
                 \x20    metric/protocol/flag/trace-phase drift between code,\n\
                 \x20    constants modules, and the README spec tables;\n\
                 \x20    exit 1 iff any deny-level finding remains\n\
                 parity                       pallas vs xla HLO numerics (pjrt only)\n\
                 \n\
                 --backend: pjrt executes AOT HLO artifacts (needs the real xla\n\
                 bindings); native runs rank-truncated factorized inference\n\
                 in-process; auto prefers pjrt and falls back to native."
            );
            if other.is_some() {
                Err(anyhow!("unknown subcommand {other:?}"))
            } else {
                Ok(())
            }
        }
    }
}

fn inspect(args: &Args) -> Result<()> {
    let m = Manifest::load(&artifacts_dir(args))?;
    if let Some(id) = args.get("run") {
        let v = m.variant(id)?;
        let file = v.run_report.as_ref().ok_or_else(|| {
            anyhow!("variant `{id}` carries no run report (manifests written before \
                     run reports existed lack the field; re-compress to get one)")
        })?;
        let doc = dobi::json::load(&m.path(file))?;
        if args.has("json") {
            println!("{doc}");
        } else {
            print!("{}", dobi::compress::RunReport::from_json(&doc)?.render());
        }
        return Ok(());
    }
    if args.has("json") {
        println!("{}", inspect_json(&m));
        return Ok(());
    }
    println!("profile: {}  models: {}  variants: {}", m.profile, m.models.len(),
             m.variants.len());
    for (name, info) in &m.models {
        println!("model {name}: d={} L={} H={} ff={} params={}", info.d_model,
                 info.n_layers, info.n_heads, info.d_ff, info.total_params);
    }
    let mut t = dobi::bench::Table::new(
        "variants",
        &["id", "method", "ratio", "alloc", "kind", "stored", "MB", "shapes", "sha256",
          "ppl(wiki)"],
    );
    for v in &m.variants {
        t.row(vec![
            v.id.clone(),
            v.method.clone(),
            format!("{:.1}", v.ratio),
            if v.kind == "factorized" { v.alloc.clone() } else { "-".into() },
            v.kind.clone(),
            format!("{}", v.stored_params),
            format!("{:.2}", v.bytes as f64 / 1e6),
            format!("{}", v.hlo.len()),
            // provenance pin: the manifest's content hash of the store
            // (verified at every load); pre-provenance variants show "-"
            v.provenance
                .as_ref()
                .map(|p| p.store_sha256[..12].to_string())
                .unwrap_or_else(|| "-".into()),
            v.ref_ppl
                .get("wiki-syn")
                .map(|p| format!("{p:.2}"))
                .unwrap_or_else(|| "-".into()),
        ]);
    }
    t.print();
    Ok(())
}

/// `dobi inspect --json`: the machine-readable variant table.  CI and
/// serve_smoke assert provenance (full store sha256) and allocation
/// against this instead of regex-scraping the human table.
fn inspect_json(m: &Manifest) -> String {
    use std::collections::BTreeMap;
    let mut models = BTreeMap::new();
    for (name, info) in &m.models {
        let mut o = BTreeMap::new();
        o.insert("d_model".into(), Json::Num(info.d_model as f64));
        o.insert("n_layers".into(), Json::Num(info.n_layers as f64));
        o.insert("n_heads".into(), Json::Num(info.n_heads as f64));
        o.insert("d_ff".into(), Json::Num(info.d_ff as f64));
        o.insert("total_params".into(), Json::Num(info.total_params as f64));
        models.insert(name.clone(), Json::Obj(o));
    }
    let variants: Vec<Json> = m
        .variants
        .iter()
        .map(|v| {
            let mut o = BTreeMap::new();
            o.insert("id".into(), Json::Str(v.id.clone()));
            o.insert("model".into(), Json::Str(v.model.clone()));
            o.insert("method".into(), Json::Str(v.method.clone()));
            o.insert("kind".into(), Json::Str(v.kind.clone()));
            o.insert("ratio".into(), Json::Num(v.ratio));
            o.insert("alloc".into(), Json::Str(v.alloc.clone()));
            o.insert("stored_params".into(), Json::Num(v.stored_params as f64));
            o.insert("bytes".into(), Json::Num(v.bytes as f64));
            o.insert("store_sha256".into(),
                     match v.provenance.as_ref() {
                         Some(p) => Json::Str(p.store_sha256.clone()),
                         None => Json::Null,
                     });
            let ppl: BTreeMap<String, Json> = v
                .ref_ppl
                .iter()
                .map(|(k, p)| (k.clone(), Json::Num(*p)))
                .collect();
            o.insert("ref_ppl".into(), Json::Obj(ppl));
            Json::Obj(o)
        })
        .collect();
    let mut root = BTreeMap::new();
    root.insert("profile".into(), Json::Str(m.profile.clone()));
    root.insert("models".into(), Json::Obj(models));
    root.insert("variants".into(), Json::Arr(variants));
    Json::Obj(root).to_string()
}

/// Native compression: a dense source (a manifest variant, or the synth
/// nano model with `--synth`) -> calibrated rank allocation -> remapped
/// factors -> a self-contained artifacts dir servable by `--backend
/// native` (factor-only manifest, no HLO entries).
fn compress(args: &Args) -> Result<()> {
    use dobi::compress::pipeline::{append_artifacts_traced, write_artifacts_traced};
    use dobi::compress::{calib, compress_model_traced, AllocPick, CompressTelemetry};
    use dobi::lowrank::synth::{tiny_model, TinyDims};
    use dobi::lowrank::FactorizedModel;

    let append = args.get("append").map(PathBuf::from);
    let out = match (&append, args.get("out")) {
        (Some(_), Some(_)) => return Err(anyhow!("--out and --append are exclusive")),
        (Some(dir), None) => dir.clone(),
        (None, Some(o)) => PathBuf::from(o),
        (None, None) => return Err(anyhow!("--out DIR (or --append DIR) required")),
    };
    let defaults = CompressConfig::default();
    let cfg = CompressConfig {
        ratio: args.f64_or("ratio", 0.4),
        budget: args.get("budget").map(|v| {
            v.parse().unwrap_or_else(|_| panic!("--budget expects an integer, got `{v}`"))
        }),
        precision: Precision::parse(args.get_or("precision", "q8"))?,
        calib_batches: args.usize_or("calib-batches", 8),
        calib_batch: args.usize_or("calib-batch", 4),
        calib_seq: args.usize_or("calib-seq", 32),
        seed: args.usize_or("seed", 11) as u64,
        k_min: args.usize_or("k-min", 1),
        alloc: AllocMode::parse(args.get_or("alloc", "waterfill"))?,
        train_iters: args.usize_or("train-iters", defaults.train_iters),
        train_lr: args.f64_or("train-lr", defaults.train_lr),
        svd_threads: args.usize_or("svd-threads", 1),
    };
    let (model_name, dense) = if args.has("synth") {
        ("tiny".to_string(), tiny_model(TinyDims::nano(), 0, false))
    } else {
        let m = Manifest::load(&artifacts_dir(args))?;
        let id = args
            .get("variant")
            .ok_or_else(|| anyhow!("--variant ID required (or --synth)"))?;
        let v = m.variant(id)?;
        let info = m
            .models
            .get(&v.model)
            .ok_or_else(|| anyhow!("model `{}` missing from manifest", v.model))?;
        let store = m.open_store(v)?;
        (v.model.clone(), FactorizedModel::from_store(info, v, &store)?)
    };
    let calib_tokens = match args.get("calib") {
        Some(path) => corpusio::read_tokbin(std::path::Path::new(path))?,
        None => calib::synth_calib_tokens(dense.vocab, 4096, cfg.seed),
    };
    // Telemetry: the `compress_*` phase spans land in a ring sized by
    // --trace-buffer (0 keeps it inert), exported as Chrome/Perfetto JSON
    // when --trace-out PATH is given; --progress prints a line per phase.
    let tel = CompressTelemetry::new(args.usize_or("trace-buffer", 65_536),
                                     args.has("progress"));
    let t0 = std::time::Instant::now();
    let art = compress_model_traced(&dense, &model_name, &cfg, &calib_tokens, &tel)?;
    let wpath = if append.is_some() {
        append_artifacts_traced(&out, &art, args.has("replace"), &tel)?
    } else {
        write_artifacts_traced(&out, &art, &tel)?
    };
    let dt = t0.elapsed().as_secs_f64();
    if let Some(path) = args.get("trace-out") {
        let events = tel.trace.drain(true);
        std::fs::write(path, dobi::trace::export_chrome(&events).to_string())
            .map_err(|e| anyhow!("writing trace {path}: {e}"))?;
        println!("trace: {} events -> {path} (load in Perfetto / chrome://tracing)",
                 events.len());
    }

    if let Some(r) = &art.train_report {
        let picked = match r.picked {
            AllocPick::Learned => "learned rounding (strictly better surrogate)",
            AllocPick::Waterfill => "waterfill rounding (guard: greedy was >= as good)",
        };
        println!(
            "[train] {} iters: tail {:.5} -> {:.5}, lambda {:.4}, expected cost {:.0}\n\
             [train] surrogate learned {:.5} vs waterfill {:.5} -> {picked}",
            r.iters, r.tail_init, r.tail_final, r.lambda, r.expected_cost,
            r.learned_surrogate, r.waterfill_surrogate);
    }
    let mut t = dobi::bench::Table::new(
        &format!("dobi compress — {} @ ratio {:.2} [{}] alloc {}", art.variant_id, cfg.ratio,
                 cfg.precision, cfg.alloc),
        &["target", "m x n", "rank", "kept", "trunc loss"],
    );
    for spec in &art.spectra {
        let k = art.ranks[&spec.name];
        t.row(vec![
            spec.name.clone(),
            format!("{}x{}", spec.m, spec.n),
            format!("{k}"),
            format!("{:.2}", k as f64 / spec.max_rank() as f64),
            format!("{:.4}", spec.loss_at(k)),
        ]);
    }
    t.print();
    println!(
        "stored {} / {} params (achieved ratio {:.3}), {} payload bytes -> {}\n\
         compressed in {dt:.2}s; serve with: dobi generate --artifacts {} \\\n\
         \x20 --variant {} --backend native",
        art.stored_params, art.total_params, art.achieved_ratio, art.payload_bytes,
        wpath.display(), out.display(), art.variant_id
    );
    println!("run report: {} (render with: dobi inspect --artifacts {} --run {})",
             out.join(dobi::compress::RunReport::file_name(&art.variant_id)).display(),
             out.display(), art.variant_id);
    Ok(())
}

fn eval(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let m = Manifest::load(&dir)?;
    let id = args.get("variant").ok_or_else(|| anyhow!("--variant required"))?;
    let be = backend(args)?;
    let shapes = [(m.eval_batch, m.eval_seq)];
    let loaded = be.load_variant(&m, id, Some(&shapes))?;
    let model = loaded.model;
    println!("loaded {id} [{}]: {} resident bytes, load {:.2}s, compile {:.2}s",
             be.name(), loaded.stats.weight_bytes, loaded.stats.load_weights_s,
             loaded.stats.compile_s);
    for corpus in m.corpora.keys() {
        let ppl = evalx::perplexity(&model, &m, corpus)?;
        let reference = m.variant(id)?.ref_ppl.get(corpus).copied();
        match reference {
            Some(r) if r.is_finite() => {
                println!("{corpus}: ppl {ppl:.3} (python ref {r:.3}, diff {:+.2}%)",
                         100.0 * (ppl - r) / r)
            }
            _ => println!("{corpus}: ppl {ppl:.3}"),
        }
    }
    if args.has("tasks") {
        let suites = corpusio::read_suites(&m.path(m.suites_file.as_deref().unwrap()))?;
        for suite in &suites {
            let r = evalx::run_suite(&model, suite, m.eval_batch, m.eval_seq, usize::MAX)?;
            println!("{}: acc {:.3} (n={})", r.name, r.accuracy, r.n);
        }
    }
    Ok(())
}

fn generate(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let m = Manifest::load(&dir)?;
    let id = args.get("variant").ok_or_else(|| anyhow!("--variant required"))?;
    let prompt = args.get_or("prompt", "The ");
    let n = args.usize_or("tokens", 64);
    let temp = args.f64_or("temperature", 0.7) as f32;
    let be = backend(args)?;
    let v = m.variant(id)?;
    // Factor-only variants export no HLO shapes: the native forward is
    // shape-agnostic, so fall back to (1, eval_seq).
    let (b, s) = v
        .shapes()
        .into_iter()
        .min_by_key(|&(b, _)| b)
        .unwrap_or((1, m.eval_seq));
    let model = be.load_variant(&m, id, Some(&[(b, s)]))?.model;
    let t0 = std::time::Instant::now();
    let text = evalx::generate(&model, b, s, prompt, n, temp, args.usize_or("seed", 7) as u64)?;
    let dt = t0.elapsed().as_secs_f64();
    println!("{prompt}{text}");
    println!("\n[{n} tokens in {dt:.2}s = {:.1} tok/s]", n as f64 / dt);
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let ids: Vec<String> = args
        .get("variants")
        .ok_or_else(|| anyhow!("--variants A,B required"))?
        .split(',')
        .map(String::from)
        .collect();
    let cfg = EngineConfig {
        max_batch: args.usize_or("max-batch", 4),
        batch_deadline_us: args.usize_or("deadline-us", 2000) as u64,
        queue_depth: args.usize_or("queue-depth", 256),
        workers: 1,
        backend: backend_kind(args)?,
    };
    // Incremental decode runtime (KV caches + continuous batching +
    // streaming), on by default; `--no-stream` keeps only the legacy
    // sliding-window engine path, `--stream` makes its absence an error
    // instead of a warning (e.g. PJRT-only artifacts).
    let serve_cfg = ServeConfig {
        max_sessions: args.usize_or("max-sessions", 8),
        queue_depth: args.usize_or("queue-depth", 256),
        decode_threads: args.usize_or("decode-threads", 1),
        spec_draft: args.get("spec-draft").map(String::from),
        spec_k: args.usize_or("spec-k", 4).max(1),
        trace_buffer: args.usize_or("trace-buffer", 4096),
        ..Default::default()
    };
    let spec_defaults = serve_cfg
        .spec_draft
        .clone()
        .map(|draft| SpecParams { draft, k: serve_cfg.spec_k });
    let runtime = if args.has("no-stream") {
        None
    } else {
        match ServeRuntime::start(dir.clone(), &ids, serve_cfg) {
            Ok(rt) => Some(Arc::new(rt)),
            Err(e) if args.has("stream") => {
                return Err(anyhow!("--stream requested but the decode runtime \
                                    cannot serve these variants: {e:#}"));
            }
            Err(e) => {
                eprintln!("[serve] incremental decode unavailable ({e:#}); \
                           sliding-window fallback only");
                None
            }
        }
    };
    // The engine exists for variants the decode runtime does not serve
    // (PJRT-only artifacts): starting it with only those avoids loading
    // every native model's weights twice.
    let fallback_ids: Vec<String> = match &runtime {
        Some(rt) => ids.iter().filter(|id| !rt.variants().contains(*id)).cloned().collect(),
        None => ids.clone(),
    };
    let engine = if fallback_ids.is_empty() {
        None
    } else {
        Some(Arc::new(Engine::start(dir, &fallback_ids, cfg, None)?))
    };
    // Speculative serve defaults need the decode runtime AND a draft the
    // runtime actually carries — fail loudly, not token-by-token.
    if let Some(sp) = &spec_defaults {
        let Some(rt) = &runtime else {
            return Err(anyhow!("--spec-draft needs the incremental decode runtime \
                                (serve without --no-stream)"));
        };
        anyhow::ensure!(rt.variants().iter().any(|v| v == &sp.draft),
                        "--spec-draft `{}` is not served by the decode runtime \
                         (add it to --variants)", sp.draft);
    }
    let port = args.usize_or("port", 7433) as u16;
    let mut builder = Server::builder()
        .port(port)
        .control(!args.has("no-control"))
        .spec_defaults(spec_defaults.clone());
    if let Some(engine) = &engine {
        builder = builder.engine(engine.clone());
    }
    if let Some(rt) = &runtime {
        builder = builder.runtime(rt.clone());
    }
    let server = builder.start()?;
    println!("serving {} on {} (streaming {}, control ops {}{}; ctrl-c to stop)",
             ids.join(", "), server.addr,
             if runtime.is_some() { "on" } else { "off" },
             if args.has("no-control") { "off" } else { "on" },
             match &spec_defaults {
                 Some(sp) => format!(", spec draft {} k={}", sp.draft, sp.k),
                 None => String::new(),
             });
    loop {
        std::thread::sleep(std::time::Duration::from_secs(10));
        let mut status = String::new();
        if let Some(engine) = &engine {
            let s = engine.stats();
            status.push_str(&format!(
                "served={} batches={} mean_batch={:.2} p50={:.1}ms p99={:.1}ms rejects={}",
                s.served, s.batches, s.mean_batch, s.p50_latency_s * 1e3,
                s.p99_latency_s * 1e3, s.queue_full_rejects));
        }
        if let Some(rt) = &runtime {
            let d = rt.stats();
            if !status.is_empty() {
                status.push_str(" | ");
            }
            status.push_str(&format!(
                "sessions: active={} queued={} finished={} tokens={} swaps={} draining={}",
                d.active_sessions, d.queue_depth, d.sessions_finished, d.tokens_emitted,
                d.swaps, d.draining_sessions));
        }
        println!("{status}");
    }
}

fn memsim_cmd(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let m = Manifest::load(&dir)?;
    let model_name = args.get_or("model", "llama-nano");
    let device = DeviceModel {
        name: "custom".into(),
        capacity: (args.f64_or("capacity-mb", 6.0) * 1e6) as usize,
        bandwidth: args.f64_or("bandwidth-mbs", 64.0) * 1e6,
    };
    let be = backend(args)?;
    let (b, s) = (m.eval_batch, m.eval_seq);
    let mut t = dobi::bench::Table::new(
        &format!("memsim on {} (cap {:.1} MB, {} backend)",
                 device.name, device.capacity as f64 / 1e6, be.name()),
        &["variant", "MB", "resident", "tok/s", "speedup"],
    );
    let mut base_tps = None;
    let needs_hlo = be.name() == "pjrt";
    for v in m.variants_for_model(model_name) {
        if !(v.method == "dense" || v.method == "dobi") || v.kernel == "pallas" {
            continue;
        }
        // The native backend serves any shape; only PJRT needs an exported
        // HLO for the eval shape.
        if needs_hlo && v.hlo_for(b, s).is_none() {
            continue;
        }
        let model = be.load_variant(&m, &v.id, Some(&[(b, s)]))?.model;
        let tokens = vec![1i32; b * s];
        let r = dobi::bench::bench("fwd", 1, 5, || {
            model.forward(b, s, &tokens, None).unwrap();
        });
        let sim = device.tokens_per_s(v.bytes, r.stats.mean, b * s);
        if v.method == "dense" {
            base_tps = Some(sim.tokens_per_s);
        }
        let speedup = base_tps.map(|bt| sim.tokens_per_s / bt).unwrap_or(1.0);
        t.row(vec![
            v.id.clone(),
            format!("{:.2}", v.bytes as f64 / 1e6),
            format!("{}", sim.resident),
            format!("{:.1}", sim.tokens_per_s),
            format!("{speedup:.2}x"),
        ]);
    }
    t.print();
    Ok(())
}

fn debug_fwd(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let m = Manifest::load(&dir)?;
    let id = args.get_or("variant", "llama-nano/dense");
    let be = backend(args)?;
    let (b, s) = (m.eval_batch, m.eval_seq);
    let model = be.load_variant(&m, id, Some(&[(b, s)]))?.model;
    let vocab = model.vocab();
    let tokens: Vec<i32> = (0..(b * s) as i32).map(|i| i % 251).collect();
    let logits = model.forward(b, s, &tokens, None)?;
    let base = (s - 1) * vocab;
    println!("rust logits[0,{},:6]: {:?}", s - 1, &logits[base..base + 6]);
    let info = m.corpora.get("wiki-syn").unwrap();
    let toks = corpusio::read_tokbin(&m.path(&info.eval_windows))?;
    let w0 = &toks[..b * s];
    let lg = model.forward(b, s, w0, None)?;
    let ce = dobi::mathx::lm_cross_entropy(&lg, w0, b, s, vocab);
    println!("rust CE window0: {ce} ppl: {}", (ce as f64).exp());
    Ok(())
}

/// L1 structural perf report: VMEM/MXU/roofline estimates for every
/// compressed matrix of a variant (EXPERIMENTS.md §Perf L1).
fn kernel_report(args: &Args) -> Result<()> {
    use dobi::perf::{estimate_factorized, estimate_gemm, speedup_estimate, DEFAULT_TILING};
    let dir = artifacts_dir(args);
    let m = Manifest::load(&dir)?;
    let id = args.get_or("variant", "llama-nano/dobi_60");
    let v = m.variant(id)?;
    let info = &m.models[&v.model];
    let rows = m.eval_batch * m.eval_seq;
    let mut t = dobi::bench::Table::new(
        &format!("L1 kernel roofline — {id} (rows={rows}, tiling 128^3)"),
        &["matrix", "m x n", "k", "VMEM KB", "MXU util", "AI f/B", "bound", "est speedup"],
    );
    let dims: Vec<(&str, usize, usize)> = vec![
        ("wq/wk/wv/wo", info.d_model, info.d_model),
        ("w_gate/w_up", info.d_model, info.d_ff),
        ("w_down", info.d_ff, info.d_model),
    ];
    for (name, mm, nn) in dims {
        // representative rank: mean over this matrix kind's trained ranks
        let kind_key = name.split('/').next().unwrap();
        let matching: Vec<usize> = v
            .ranks
            .iter()
            .filter(|(rk, _)| rk.ends_with(kind_key))
            .map(|(_, &k)| k)
            .collect();
        let k = if matching.is_empty() {
            mm.min(nn) // dense variant: full rank
        } else {
            (matching.iter().sum::<usize>() / matching.len()).max(8)
        };
        let (g1, g2) = estimate_factorized(rows, mm, nn, k, DEFAULT_TILING, 4);
        let dense = estimate_gemm(rows, mm, nn, DEFAULT_TILING, 4);
        t.row(vec![
            name.into(),
            format!("{mm}x{nn}"),
            format!("{k}"),
            format!("{:.0}", g1.vmem_bytes.max(g2.vmem_bytes) as f64 / 1024.0),
            format!("{:.2}", (g1.mxu_utilization + g2.mxu_utilization) / 2.0),
            format!("{:.1}", g1.arithmetic_intensity.min(g2.arithmetic_intensity)),
            if g1.compute_bound && g2.compute_bound { "compute" } else { "memory" }.into(),
            format!("{:.2}x vs dense ({})",
                    speedup_estimate(rows, mm, nn, k, DEFAULT_TILING),
                    if dense.compute_bound { "compute" } else { "memory" }),
        ]);
    }
    t.print();
    println!("note: interpret-mode wallclock is not a TPU proxy; these are the\n\
              structural estimates recorded in EXPERIMENTS.md §Perf (L1).");
    Ok(())
}

fn debug_probe(args: &Args) -> Result<()> {
    use dobi::runtime::{f32_literal, i32_literal};
    let dir = artifacts_dir(args);
    let m = Manifest::load(&dir)?;
    let v = m.variant(args.get_or("variant", "llama-nano/dense"))?;
    let rt = Runtime::new()?;
    let exe = rt.compile_hlo(std::path::Path::new(args.get_or("hlo", "/tmp/probe.hlo.txt")))?;
    let store = m.open_store(v)?;
    let tokens: Vec<i32> = (0..256).map(|i| i % 251).collect();
    let mut lits = vec![i32_literal(&tokens, &[4, 64])?];
    for name in &v.param_names {
        let (vals, shape) = store.tensor_f32(name)?;
        lits.push(f32_literal(&vals, &shape)?);
    }
    let out = exe.execute::<xla::Literal>(&lits).map_err(|e| anyhow!("{e:?}"))?;
    let lit = out[0][0].to_literal_sync().map_err(|e| anyhow!("{e:?}"))?;
    let vals = lit.to_tuple1().map_err(|e| anyhow!("{e:?}"))?
        .to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
    println!("rust probe[:8]: {:?}", &vals[..8.min(vals.len())]);
    println!("rust probe[-3:]: {:?}", &vals[vals.len().saturating_sub(3)..]);
    Ok(())
}

/// `dobi lint` — the self-hosted static analysis (`rust/src/analysis/`)
/// over a checkout.  Text findings print one `file:line: [severity] rule:
/// message` per line; `--format json` emits `{"findings": [...], "deny": N}`
/// for CI.  Exit 1 iff any deny-level finding remains.
fn lint(args: &Args) -> Result<()> {
    use dobi::analysis;
    let root = PathBuf::from(args.get_or("root", "."));
    let ctx = analysis::Context::load(&root)?;
    let findings = analysis::run(&ctx, args.get("rule"))?;
    let denies = findings
        .iter()
        .filter(|f| f.severity == analysis::Severity::Deny)
        .count();
    match args.get_or("format", "text") {
        "text" => {
            for f in &findings {
                println!("{}:{}: [{}] {}: {}", f.file, f.line, f.severity.as_str(), f.rule,
                         f.message);
            }
            println!("{} finding(s), {denies} deny", findings.len());
        }
        "json" => {
            let arr: Vec<Json> = findings
                .iter()
                .map(|f| {
                    Json::obj(vec![
                        ("rule", Json::Str(f.rule.to_string())),
                        ("severity", Json::Str(f.severity.as_str().to_string())),
                        ("file", Json::Str(f.file.clone())),
                        ("line", Json::Num(f.line as f64)),
                        ("message", Json::Str(f.message.clone())),
                    ])
                })
                .collect();
            let doc = Json::obj(vec![
                ("findings", Json::Arr(arr)),
                ("deny", Json::Num(denies as f64)),
            ]);
            println!("{doc}");
        }
        other => bail!("unknown --format `{other}` (expected text or json)"),
    }
    if denies > 0 {
        bail!("{denies} deny-level finding(s)");
    }
    Ok(())
}

fn parity(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let m = Manifest::load(&dir)?;
    let rt = Runtime::new()?;
    let (b, s) = (m.eval_batch, m.eval_seq);
    let pairs: Vec<(String, String)> = m
        .variants
        .iter()
        .filter(|v| v.kernel == "pallas")
        .filter_map(|vp| {
            let base = vp.id.replace("-pallas", "");
            m.variants.iter().find(|v| v.id == base).map(|vb| (vp.id.clone(), vb.id.clone()))
        })
        .collect();
    anyhow::ensure!(!pairs.is_empty(), "no pallas variants in manifest");
    for (pid, bid) in pairs {
        let mp = rt.load_variant(&m, &pid, Some(&[(b, s)]))?;
        let mb = rt.load_variant(&m, &bid, Some(&[(b, s)]))?;
        let tokens: Vec<i32> = (0..b * s).map(|i| (i % 251) as i32).collect();
        let lp = mp.forward(b, s, &tokens, None)?;
        let lb = mb.forward(b, s, &tokens, None)?;
        let max_abs = lp
            .iter()
            .zip(&lb)
            .map(|(a, c)| (a - c).abs())
            .fold(0f32, f32::max);
        println!("{pid} vs {bid}: max |Δlogit| = {max_abs:.5}");
        anyhow::ensure!(max_abs < 0.05, "pallas/xla parity broken: {max_abs}");
    }
    println!("parity OK");
    Ok(())
}
