//! Byte-level tokenizer — the exact contract of `python/compile/data.py`:
//! token id == utf-8 byte value, vocab = 256.

pub const VOCAB_SIZE: usize = 256;

#[derive(Debug, Clone, Default)]
pub struct ByteTokenizer;

impl ByteTokenizer {
    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.as_bytes().iter().map(|&b| b as i32).collect()
    }

    pub fn decode(&self, tokens: &[i32]) -> String {
        let bytes: Vec<u8> = tokens.iter().map(|&t| (t & 0xFF) as u8).collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }

    /// Encode into a fixed window, left-truncating (keep the most recent
    /// context) and right-aligning — the generation loop's sliding window.
    pub fn encode_window(&self, text: &str, seq: usize, pad: i32) -> Vec<i32> {
        let toks = self.encode(text);
        let mut out = vec![pad; seq];
        let take = toks.len().min(seq);
        let src = &toks[toks.len() - take..];
        out[seq - take..].copy_from_slice(src);
        out
    }

    /// Encode prompt+continuation into a window, returning the
    /// continuation's [start, end) token span (for option scoring).
    /// Falls back to truncating the prompt from the left if needed.
    pub fn encode_pair(&self, prompt: &str, cont: &str, seq: usize, pad: i32)
                       -> (Vec<i32>, usize, usize) {
        let p = self.encode(prompt);
        let c = self.encode(cont);
        let c_len = c.len().min(seq.saturating_sub(1));
        let c = &c[..c_len];
        let p_room = seq - c_len;
        let p_take = p.len().min(p_room);
        let p = &p[p.len() - p_take..];
        let mut out = vec![pad; seq];
        let start = p_take;
        out[..p_take].copy_from_slice(p);
        out[start..start + c_len].copy_from_slice(c);
        (out, start, start + c_len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let t = ByteTokenizer;
        let s = "Hello, Dobi-SVD! 123";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn roundtrip_unicode() {
        let t = ByteTokenizer;
        let s = "ünïcödé ✓";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn tokens_in_vocab() {
        let t = ByteTokenizer;
        assert!(t.encode("é✓ xyz").iter().all(|&x| (0..256).contains(&x)));
    }

    #[test]
    fn window_right_aligned() {
        let t = ByteTokenizer;
        let w = t.encode_window("abcdef", 4, 0);
        assert_eq!(w, t.encode("cdef"));
        let w2 = t.encode_window("ab", 4, 32);
        assert_eq!(w2, vec![32, 32, 97, 98]);
    }

    #[test]
    fn pair_span_correct() {
        let t = ByteTokenizer;
        let (w, s, e) = t.encode_pair("abc", "XY", 8, 0);
        assert_eq!(&w[s..e], &t.encode("XY")[..]);
        assert_eq!(&w[..3], &t.encode("abc")[..]);
        assert_eq!((s, e), (3, 5));
    }

    #[test]
    fn pair_truncates_prompt_not_continuation() {
        let t = ByteTokenizer;
        let (w, s, e) = t.encode_pair("0123456789", "AB", 6, 0);
        assert_eq!(&w[s..e], &t.encode("AB")[..]);
        assert_eq!(&w[..s], &t.encode("6789")[..]);
    }
}
