//! Execution runtimes: the [`Backend`] abstraction over the two ways a
//! variant can serve forwards, plus the PJRT implementation.
//!
//! * [`PjrtBackend`] — AOT HLO artifacts through the PJRT client (this
//!   module; requires the real `xla` bindings — the offline build links
//!   an API stub whose client constructor fails cleanly).
//! * [`crate::lowrank::NativeBackend`] — in-process rank-truncated
//!   factorized inference, no PJRT.
//!
//! [`make_backend`] maps a [`BackendKind`] (the CLI `--backend` flag /
//! `EngineConfig.backend`) to an instance; `Auto` prefers PJRT and falls
//! back to native, so the same binary serves real artifacts when the
//! native library is present and synthetic/low-rank models everywhere.
//!
//! Loading pipeline per variant (see DESIGN.md §4):
//!   manifest -> `.dobiw` store -> dequantized f32 host tensors ->
//!   device buffers (uploaded once) -> `HloModuleProto::from_text_file`
//!   -> `XlaComputation` -> `client.compile` per exported (B, S) shape.
//!
//! Per-request work is then ONE token-literal upload + `execute_b` over
//! the resident weight buffers — no weight marshalling on the hot path.
//! PJRT handles are not `Send`; the coordinator confines a `Runtime` to
//! its executor thread (see `coordinator::engine`).

use std::collections::BTreeMap;
use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::config::{BackendKind, Manifest, Variant};

/// Anything that can run a forward pass.  The evaluation harness and the
/// coordinator are generic over this so their logic is unit-testable with
/// mock models (no PJRT) while production uses [`LoadedModel`] or the
/// native [`crate::lowrank::FactorizedModel`].
pub trait ForwardModel {
    /// Execute the (b, s) forward.  `tokens` is row-major (b, s); `image`
    /// must be Some((b, img_dim) flat) iff `img_dim() > 0`.
    fn forward(&self, b: usize, s: usize, tokens: &[i32],
               image: Option<&[f32]>) -> Result<Vec<f32>>;
    fn vocab(&self) -> usize;
    fn img_dim(&self) -> usize;
    fn action_head(&self) -> bool;

    /// (batch, seq) shapes this model serves.  Empty means
    /// shape-agnostic — any (b, s) executes (native backend, mocks); the
    /// batch planner then packs to the request count.
    fn shapes(&self) -> Vec<(usize, usize)> {
        Vec::new()
    }
}

impl ForwardModel for Box<dyn ForwardModel> {
    fn forward(&self, b: usize, s: usize, tokens: &[i32],
               image: Option<&[f32]>) -> Result<Vec<f32>> {
        (**self).forward(b, s, tokens, image)
    }

    fn vocab(&self) -> usize {
        (**self).vocab()
    }

    fn img_dim(&self) -> usize {
        (**self).img_dim()
    }

    fn action_head(&self) -> bool {
        (**self).action_head()
    }

    fn shapes(&self) -> Vec<(usize, usize)> {
        (**self).shapes()
    }
}

// ---------------------------------------------------------------------------
// Backend abstraction
// ---------------------------------------------------------------------------

/// A loaded variant plus its load-time accounting, backend-agnostic.
pub struct Loaded {
    pub model: Box<dyn ForwardModel>,
    pub stats: LoadStats,
}

/// An execution backend: turns a manifest variant into a servable model.
/// The coordinator engine, eval harness, memsim CLI, and benches are
/// routed through this so PJRT artifacts and native low-rank factors are
/// interchangeable behind the `--backend` flag.
pub trait Backend {
    fn name(&self) -> &'static str;
    fn load_variant(&self, manifest: &Manifest, id: &str,
                    shapes: Option<&[(usize, usize)]>) -> Result<Loaded>;
}

/// PJRT-artifact backend (thin [`Backend`] shim over [`Runtime`]).
pub struct PjrtBackend {
    pub runtime: Runtime,
}

impl PjrtBackend {
    pub fn new() -> Result<PjrtBackend> {
        Ok(PjrtBackend { runtime: Runtime::new()? })
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn load_variant(&self, manifest: &Manifest, id: &str,
                    shapes: Option<&[(usize, usize)]>) -> Result<Loaded> {
        let model = self.runtime.load_variant(manifest, id, shapes)?;
        let stats = model.stats.clone();
        Ok(Loaded { model: Box::new(model), stats })
    }
}

/// Instantiate the backend for `kind`.  `Auto` tries PJRT first (real
/// artifacts, real xla bindings) and falls back to the native low-rank
/// backend when the PJRT client cannot come up (e.g. the offline stub).
pub fn make_backend(kind: BackendKind) -> Result<Box<dyn Backend>> {
    match kind {
        BackendKind::Pjrt => Ok(Box::new(PjrtBackend::new()?)),
        BackendKind::Native => Ok(Box::new(crate::lowrank::NativeBackend)),
        BackendKind::Auto => match PjrtBackend::new() {
            Ok(b) => Ok(Box::new(b)),
            Err(e) => {
                // Loud fallback: a user with real artifacts must be able to
                // see they are NOT being served by PJRT and why.
                eprintln!("[backend] PJRT unavailable ({e:#}); falling back to native-lowrank");
                Ok(Box::new(crate::lowrank::NativeBackend))
            }
        },
    }
}

pub struct Runtime {
    pub client: xla::PjRtClient,
}

impl Runtime {
    pub fn new() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load a variant: weights uploaded, every exported shape compiled
    /// (or only `shapes` if given — compilation is the slow part).
    pub fn load_variant(&self, manifest: &Manifest, id: &str,
                        shapes: Option<&[(usize, usize)]>) -> Result<LoadedModel> {
        let v = manifest.variant(id)?.clone();
        let minfo = manifest
            .models
            .get(&v.model)
            .ok_or_else(|| anyhow!("model `{}` missing from manifest", v.model))?;
        let t0 = Instant::now();
        let store = manifest.open_store(&v)?;
        let mut weights = Vec::with_capacity(v.param_names.len());
        let mut weight_lits = Vec::with_capacity(v.param_names.len());
        let mut weight_bytes = 0usize;
        for name in &v.param_names {
            let (vals, shape) = store
                .tensor_f32(name)
                .with_context(|| format!("loading weight `{name}` for {id}"))?;
            weight_bytes += vals.len() * 4;
            let lit = f32_literal(&vals, &shape)?;
            let buf = self
                .client
                .buffer_from_host_literal(None, &lit)
                .map_err(|e| anyhow!("uploading `{name}`: {e:?}"))?;
            // PJRT's host->device transfer is asynchronous: the source
            // literal MUST outlive the copy.  Keep it for the model's
            // lifetime (host RAM is cheap; dropping early is a UAF).
            weight_lits.push(lit);
            weights.push(buf);
        }
        let load_weights_s = t0.elapsed().as_secs_f64();

        let mut exes = BTreeMap::new();
        let mut compile_s = 0.0;
        for (key, file) in &v.hlo {
            if let Some(filter) = shapes {
                let ok = crate::config::parse_shape_key(key)
                    .map(|bs| filter.contains(&bs))
                    .unwrap_or(false);
                if !ok {
                    continue;
                }
            }
            let tc = Instant::now();
            let exe = self.compile_hlo(&manifest.path(file))?;
            compile_s += tc.elapsed().as_secs_f64();
            exes.insert(key.clone(), exe);
        }
        anyhow::ensure!(!exes.is_empty(), "{id}: no executable compiled (shape filter?)");
        Ok(LoadedModel {
            variant: v,
            vocab: minfo.vocab,
            img_dim: minfo.img_dim,
            action_head: minfo.action_head,
            weights,
            _weight_lits: weight_lits,
            exes,
            stats: LoadStats {
                weight_bytes,
                file_bytes: store.file_bytes,
                payload_bytes: store.payload_bytes(),
                load_weights_s,
                compile_s,
            },
        })
    }

    pub fn compile_hlo(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parsing HLO {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", path.display()))
    }
}

pub fn f32_literal(vals: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, shape, &bytes)
        .map_err(|e| anyhow!("f32 literal: {e:?}"))
}

pub fn i32_literal(vals: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S32, shape, &bytes)
        .map_err(|e| anyhow!("i32 literal: {e:?}"))
}

#[derive(Debug, Clone, Default)]
pub struct LoadStats {
    pub weight_bytes: usize,   // f32-resident bytes on device
    pub file_bytes: usize,     // .dobiw on disk
    pub payload_bytes: usize,  // stored tensor payloads (quantized size)
    pub load_weights_s: f64,
    pub compile_s: f64,
}

/// A fully-resident model variant: weights on device + one executable per
/// exported (batch, seq) shape.
pub struct LoadedModel {
    pub variant: Variant,
    pub vocab: usize,
    pub img_dim: usize,
    pub action_head: bool,
    weights: Vec<xla::PjRtBuffer>,
    /// Host copies backing `weights` — PJRT uploads are async and borrow
    /// the literal storage; see `load_variant`.
    _weight_lits: Vec<xla::Literal>,
    exes: BTreeMap<String, xla::PjRtLoadedExecutable>,
    pub stats: LoadStats,
}

impl LoadedModel {
    pub fn shapes(&self) -> Vec<(usize, usize)> {
        self.exes.keys().filter_map(|k| crate::config::parse_shape_key(k)).collect()
    }

    pub fn has_shape(&self, b: usize, s: usize) -> bool {
        self.exes.contains_key(&format!("{b}x{s}"))
    }

    /// Output element count per call for shape (b, s): logits b*s*vocab
    /// for LMs, b*5 actions for the VLA head.
    pub fn out_elems(&self, b: usize, s: usize) -> usize {
        if self.action_head {
            b * 5
        } else {
            b * s * self.vocab
        }
    }

    /// Execute the (b, s) forward.  `tokens` is row-major (b, s);
    /// `image` must be Some((b, img_dim) flat) for multimodal variants.
    pub fn forward(&self, b: usize, s: usize, tokens: &[i32],
                   image: Option<&[f32]>) -> Result<Vec<f32>> {
        anyhow::ensure!(tokens.len() == b * s, "tokens len {} != {b}x{s}", tokens.len());
        let exe = self
            .exes
            .get(&format!("{b}x{s}"))
            .ok_or_else(|| anyhow!("{}: shape {b}x{s} not compiled", self.variant.id))?;
        let tok_lit = i32_literal(tokens, &[b, s])?;
        let out = if self.img_dim > 0 {
            // Multimodal path: xla_extension 0.5.1's buffer-args execute
            // aborts on (tokens, image) input sets (see EXPERIMENTS.md
            // known issues); the literal-args path is correct, at the cost
            // of restaging weights per call.  Weight literals are already
            // host-resident for the async-upload lifetime rule.
            let img = image.ok_or_else(|| anyhow!("{}: image input required", self.variant.id))?;
            anyhow::ensure!(img.len() == b * self.img_dim, "image len mismatch");
            let img_lit = f32_literal(img, &[b, self.img_dim])?;
            let mut args: Vec<&xla::Literal> = Vec::with_capacity(2 + self._weight_lits.len());
            args.push(&tok_lit);
            args.push(&img_lit);
            for w in &self._weight_lits {
                args.push(w);
            }
            exe.execute::<&xla::Literal>(&args)
                .map_err(|e| anyhow!("execute(mm) {}@{b}x{s}: {e:?}", self.variant.id))?
        } else {
            anyhow::ensure!(image.is_none(), "{}: unexpected image input", self.variant.id);
            let client = self.first_weight_client()?;
            let tok_buf = client
                .buffer_from_host_literal(None, &tok_lit)
                .map_err(|e| anyhow!("uploading tokens: {e:?}"))?;
            let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(1 + self.weights.len());
            args.push(&tok_buf);
            for w in &self.weights {
                args.push(w);
            }
            exe.execute_b(&args)
                .map_err(|e| anyhow!("execute {}@{b}x{s}: {e:?}", self.variant.id))?
        };
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result: {e:?}"))?;
        // aot.py lowers with return_tuple=True -> 1-tuple.
        let inner = lit.to_tuple1().map_err(|e| anyhow!("untupling: {e:?}"))?;
        let vals = inner.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
        anyhow::ensure!(vals.len() == self.out_elems(b, s),
                        "output len {} != expected {}", vals.len(), self.out_elems(b, s));
        Ok(vals)
    }

    fn first_weight_client(&self) -> Result<&xla::PjRtClient> {
        self.weights
            .first()
            .map(|w| w.client())
            .ok_or_else(|| anyhow!("variant has no weights"))
    }
}

impl ForwardModel for LoadedModel {
    fn forward(&self, b: usize, s: usize, tokens: &[i32],
               image: Option<&[f32]>) -> Result<Vec<f32>> {
        LoadedModel::forward(self, b, s, tokens, image)
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn img_dim(&self) -> usize {
        self.img_dim
    }

    fn action_head(&self) -> bool {
        self.action_head
    }

    fn shapes(&self) -> Vec<(usize, usize)> {
        LoadedModel::shapes(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::BackendKind;

    #[test]
    fn auto_backend_always_resolves() {
        // With the real xla bindings this is PJRT; with the offline stub it
        // must fall back to the native low-rank backend instead of failing.
        let b = make_backend(BackendKind::Auto).unwrap();
        assert!(b.name() == "pjrt" || b.name() == "native-lowrank");
    }

    #[test]
    fn native_backend_always_available() {
        assert_eq!(make_backend(BackendKind::Native).unwrap().name(), "native-lowrank");
    }
}
