//! Readers for the python-produced corpus / task artifacts
//! (`*.tokbin`, `tasks.json`, `vqa.json`, `vla.json`).

use std::path::Path;

use anyhow::{anyhow, bail, Result};

use crate::json::{self, Json};

pub const TOKBIN_MAGIC: &[u8; 6] = b"DOBT1\x00";

/// CRC-32 (IEEE 802.3, zlib-compatible) — the checksum the python writer
/// uses; implemented here to stay dependency-free.
pub fn crc32(data: &[u8]) -> u32 {
    // table generated on first use
    static TABLE: std::sync::OnceLock<[u32; 256]> = std::sync::OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB88320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    });
    let mut c = 0xFFFFFFFFu32;
    for &b in data {
        c = table[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFFFFFF
}

/// Read a token stream: magic + u32 count + u16[count] LE + u32 crc.
pub fn read_tokbin(path: &Path) -> Result<Vec<i32>> {
    let raw = std::fs::read(path).map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
    if raw.len() < 14 || &raw[..6] != TOKBIN_MAGIC {
        bail!("{}: bad tokbin magic", path.display());
    }
    let n = u32::from_le_bytes(raw[6..10].try_into().unwrap()) as usize;
    let body_end = 10 + 2 * n;
    if raw.len() < body_end + 4 {
        bail!("{}: truncated tokbin", path.display());
    }
    let body = &raw[10..body_end];
    let want = u32::from_le_bytes(raw[body_end..body_end + 4].try_into().unwrap());
    if crc32(body) != want {
        bail!("{}: tokbin crc mismatch", path.display());
    }
    Ok(body
        .chunks_exact(2)
        .map(|c| u16::from_le_bytes([c[0], c[1]]) as i32)
        .collect())
}

// ---------------------------------------------------------------------------
// Task suites (zero-shot multiple choice)
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct Task {
    pub prompt: String,
    pub options: Vec<String>,
    pub answer: usize,
}

#[derive(Debug, Clone)]
pub struct TaskSuite {
    pub name: String,
    pub tasks: Vec<Task>,
}

pub fn read_suites(path: &Path) -> Result<Vec<TaskSuite>> {
    let doc = json::load(path)?;
    let suites = doc
        .get("suites")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("tasks.json: missing `suites`"))?;
    suites.iter().map(parse_suite).collect()
}

fn parse_suite(j: &Json) -> Result<TaskSuite> {
    let name = j.str_of("name").to_string();
    let tasks = j
        .get("tasks")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("suite {name}: missing tasks"))?
        .iter()
        .map(|t| {
            let options: Vec<String> = t
                .get("options")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(|o| o.as_str().map(String::from)).collect())
                .unwrap_or_default();
            let answer = t.usize_of("answer");
            anyhow::ensure!(answer < options.len(), "answer index out of range");
            Ok(Task { prompt: t.str_of("prompt").to_string(), options, answer })
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(TaskSuite { name, tasks })
}

// ---------------------------------------------------------------------------
// Multimodal eval sets
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct VqaSample {
    pub image: Vec<f32>,
    pub question: String,
    pub options: Vec<String>,
    pub answer: usize,
}

pub fn read_vqa(path: &Path) -> Result<(usize, Vec<VqaSample>)> {
    let doc = json::load(path)?;
    let img_dim = doc.usize_of("img_dim");
    let samples = doc
        .get("samples")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("vqa.json: missing samples"))?
        .iter()
        .map(|s| {
            let image: Vec<f32> = s
                .get("image")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(|x| x.as_f64().map(|f| f as f32)).collect())
                .unwrap_or_default();
            anyhow::ensure!(image.len() == img_dim, "image dim mismatch");
            Ok(VqaSample {
                image,
                question: s.str_of("question").to_string(),
                options: s
                    .get("options")
                    .and_then(Json::as_arr)
                    .map(|a| a.iter().filter_map(|o| o.as_str().map(String::from)).collect())
                    .unwrap_or_default(),
                answer: s.usize_of("answer"),
            })
        })
        .collect::<Result<Vec<_>>>()?;
    Ok((img_dim, samples))
}

#[derive(Debug, Clone)]
pub struct VlaSample {
    pub image: Vec<f32>,
    pub instruction: String,
    pub coords: [f32; 3],
    pub angle: f32,
    pub gripper: i32,
}

pub fn read_vla(path: &Path) -> Result<(usize, Vec<VlaSample>)> {
    let doc = json::load(path)?;
    let img_dim = doc.usize_of("img_dim");
    let samples = doc
        .get("samples")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("vla.json: missing samples"))?
        .iter()
        .map(|s| {
            let image: Vec<f32> = s
                .get("image")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(|x| x.as_f64().map(|f| f as f32)).collect())
                .unwrap_or_default();
            let cv: Vec<f32> = s
                .get("coords")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(|x| x.as_f64().map(|f| f as f32)).collect())
                .unwrap_or_default();
            anyhow::ensure!(cv.len() == 3, "coords must be length 3");
            Ok(VlaSample {
                image,
                instruction: s.str_of("instruction").to_string(),
                coords: [cv[0], cv[1], cv[2]],
                angle: s.f64_of("angle") as f32,
                gripper: s.f64_of("gripper") as i32,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    Ok((img_dim, samples))
}

/// Deterministic eval windows: the python side wrote `n * batch * seq`
/// tokens flat; reshape to (n, batch*seq) blocks in order.
pub fn eval_windows(tokens: &[i32], n: usize, batch: usize, seq: usize) -> Result<Vec<Vec<i32>>> {
    let need = n * batch * seq;
    anyhow::ensure!(tokens.len() >= need,
                    "eval window stream too short: {} < {need}", tokens.len());
    Ok((0..n).map(|i| tokens[i * batch * seq..(i + 1) * batch * seq].to_vec()).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_known_vectors() {
        // zlib reference values
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), 0xCBF43926);
        assert_eq!(crc32(b"hello"), 0x3610A686);
    }

    #[test]
    fn tokbin_roundtrip(){
        let dir = std::env::temp_dir().join("dobi_test_tokbin");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.tokbin");
        let toks: Vec<u16> = (0..300u16).map(|i| i % 256).collect();
        let mut raw = Vec::new();
        raw.extend_from_slice(TOKBIN_MAGIC);
        raw.extend_from_slice(&(toks.len() as u32).to_le_bytes());
        let body: Vec<u8> = toks.iter().flat_map(|t| t.to_le_bytes()).collect();
        raw.extend_from_slice(&body);
        raw.extend_from_slice(&crc32(&body).to_le_bytes());
        std::fs::write(&p, &raw).unwrap();
        let got = read_tokbin(&p).unwrap();
        assert_eq!(got.len(), 300);
        assert_eq!(got[257], 1);
    }

    #[test]
    fn tokbin_rejects_corruption() {
        let dir = std::env::temp_dir().join("dobi_test_tokbin2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.tokbin");
        let mut raw = Vec::new();
        raw.extend_from_slice(TOKBIN_MAGIC);
        raw.extend_from_slice(&2u32.to_le_bytes());
        raw.extend_from_slice(&[1, 0, 2, 0]);
        raw.extend_from_slice(&crc32(&[1, 0, 2, 0]).to_le_bytes());
        let mut bad = raw.clone();
        bad[11] ^= 0xFF;
        std::fs::write(&p, &bad).unwrap();
        assert!(read_tokbin(&p).is_err());
    }

    #[test]
    fn suites_parse() {
        let dir = std::env::temp_dir().join("dobi_test_suites");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("tasks.json");
        std::fs::write(&p, r#"{"suites":[{"name":"s","tasks":[
            {"prompt":"p","options":["a","b"],"answer":1}]}]}"#).unwrap();
        let s = read_suites(&p).unwrap();
        assert_eq!(s[0].name, "s");
        assert_eq!(s[0].tasks[0].answer, 1);
    }

    #[test]
    fn suites_reject_bad_answer() {
        let dir = std::env::temp_dir().join("dobi_test_suites2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("tasks.json");
        std::fs::write(&p, r#"{"suites":[{"name":"s","tasks":[
            {"prompt":"p","options":["a"],"answer":3}]}]}"#).unwrap();
        assert!(read_suites(&p).is_err());
    }

    #[test]
    fn eval_windows_shapes() {
        let toks: Vec<i32> = (0..24).collect();
        let w = eval_windows(&toks, 2, 3, 4).unwrap();
        assert_eq!(w.len(), 2);
        assert_eq!(w[0], (0..12).collect::<Vec<i32>>());
        assert!(eval_windows(&toks, 3, 3, 4).is_err());
    }
}
