//! Typed configuration: manifest parsing (the python→rust contract) and
//! engine/serve tunables.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Result};

use crate::json::{self, Json};

// ---------------------------------------------------------------------------
// Backend selection
// ---------------------------------------------------------------------------

/// Which execution backend serves forwards (see `runtime::make_backend`).
///
/// * `Pjrt`   — AOT HLO artifacts through the PJRT client (requires the
///              real `xla` bindings; the offline build links a stub that
///              fails cleanly at load time).
/// * `Native` — in-process rank-truncated factorized inference
///              (`lowrank::FactorizedModel`), no PJRT required.
/// * `Auto`   — PJRT when it comes up, else fall back to native.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendKind {
    #[default]
    Auto,
    Pjrt,
    Native,
}

impl BackendKind {
    /// Parse a `--backend` flag value.
    pub fn parse(s: &str) -> Result<BackendKind> {
        Ok(match s {
            "auto" => BackendKind::Auto,
            "pjrt" => BackendKind::Pjrt,
            "native" | "lowrank" => BackendKind::Native,
            other => bail!("unknown backend `{other}` (expected auto|pjrt|native)"),
        })
    }
}

impl std::fmt::Display for BackendKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            BackendKind::Auto => "auto",
            BackendKind::Pjrt => "pjrt",
            BackendKind::Native => "native",
        })
    }
}

// ---------------------------------------------------------------------------
// Compression tunables
// ---------------------------------------------------------------------------

/// Storage precision of the remapped factors `dobi compress` emits
/// (paper Algo 3: "8+16" packs int8 halves into one fp16 footprint; the
/// "16" ablation keeps both factors at fp16; f32 is the lossless
/// debugging layout).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Precision {
    #[default]
    Q8,
    F16,
    F32,
}

impl Precision {
    /// Parse a `--precision` flag value.
    pub fn parse(s: &str) -> Result<Precision> {
        Ok(match s {
            "q8" | "8+16" | "int8" => Precision::Q8,
            "f16" | "16" => Precision::F16,
            "f32" | "32" => Precision::F32,
            other => bail!("unknown precision `{other}` (expected q8|f16|f32)"),
        })
    }
}

impl std::fmt::Display for Precision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Precision::Q8 => "q8",
            Precision::F16 => "f16",
            Precision::F32 => "f32",
        })
    }
}

/// How `dobi compress` allocates ranks across targets under the global
/// budget (`--alloc`):
///
/// * `Waterfill` — the SVD-LLM-style greedy discrete waterfill
///   (`compress::rank::Waterfill`), the fast baseline.
/// * `Learned`   — the paper's differentiable truncation-position
///   optimizer (`compress::train::LearnedAlloc`): sigmoid gates over the
///   whitened spectra, Adam under an exact Lagrangian budget
///   renormalization, waterfill-guarded rounding.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AllocMode {
    #[default]
    Waterfill,
    Learned,
}

impl AllocMode {
    /// Parse an `--alloc` flag value.
    pub fn parse(s: &str) -> Result<AllocMode> {
        Ok(match s {
            "waterfill" | "greedy" => AllocMode::Waterfill,
            "learned" | "dobi" | "train" => AllocMode::Learned,
            other => bail!("unknown alloc mode `{other}` (expected waterfill|learned)"),
        })
    }
}

impl std::fmt::Display for AllocMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            AllocMode::Waterfill => "waterfill",
            AllocMode::Learned => "learned",
        })
    }
}

/// `dobi compress` knobs (defaults mirror the python pipeline's
/// calibration schedule at nano scale).
#[derive(Debug, Clone)]
pub struct CompressConfig {
    /// Target stored-parameter ratio in (0, 1].
    pub ratio: f64,
    /// Explicit stored-parameter budget; overrides `ratio` when set.
    pub budget: Option<usize>,
    /// Factor storage precision.
    pub precision: Precision,
    /// Calibration batches / batch size / window length / window seed.
    pub calib_batches: usize,
    pub calib_batch: usize,
    pub calib_seq: usize,
    pub seed: u64,
    /// Rank floor per target (every target keeps at least this rank).
    pub k_min: usize,
    /// Rank-allocation policy (waterfill baseline vs learned positions).
    pub alloc: AllocMode,
    /// Learned-mode optimization steps (`--train-iters`).
    pub train_iters: usize,
    /// Learned-mode Adam learning rate (`--train-lr`).
    pub train_lr: f64,
    /// Worker threads for the one-sided Jacobi SVD sweeps
    /// (`--svd-threads`; bit-identical results at any count).
    pub svd_threads: usize,
}

impl Default for CompressConfig {
    fn default() -> Self {
        CompressConfig {
            ratio: 0.4,
            budget: None,
            precision: Precision::Q8,
            calib_batches: 8,
            calib_batch: 4,
            calib_seq: 32,
            seed: 11,
            k_min: 1,
            alloc: AllocMode::Waterfill,
            train_iters: 300,
            train_lr: 0.3,
            svd_threads: 1,
        }
    }
}

impl CompressConfig {
    /// Full-fidelity JSON dump of the knobs that produced an artifact —
    /// stamped into the variant's provenance block so a release records
    /// exactly how to reproduce it.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("ratio", Json::Num(self.ratio)),
            ("budget", self.budget.map_or(Json::Null, |b| Json::Num(b as f64))),
            ("precision", Json::Str(self.precision.to_string())),
            ("calib_batches", Json::Num(self.calib_batches as f64)),
            ("calib_batch", Json::Num(self.calib_batch as f64)),
            ("calib_seq", Json::Num(self.calib_seq as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("k_min", Json::Num(self.k_min as f64)),
            ("alloc", Json::Str(self.alloc.to_string())),
            ("train_iters", Json::Num(self.train_iters as f64)),
            ("train_lr", Json::Num(self.train_lr)),
            ("svd_threads", Json::Num(self.svd_threads as f64)),
        ])
    }
}

// ---------------------------------------------------------------------------
// Engine tunables
// ---------------------------------------------------------------------------

/// Coordinator/batcher knobs (defaults chosen by the §Perf pass).
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Max requests fused into one executable call (must match an exported
    /// HLO batch dim; the router picks the best available shape).
    pub max_batch: usize,
    /// How long the batcher waits to fill a batch before flushing.
    pub batch_deadline_us: u64,
    /// Bounded queue depth per variant (backpressure beyond this).
    pub queue_depth: usize,
    /// Worker threads (1 device → 1 executor by default; >1 exercises
    /// contention handling in tests).
    pub workers: usize,
    /// Execution backend the executor thread instantiates.
    pub backend: BackendKind,
}

impl Default for EngineConfig {
    fn default() -> Self {
        // deadline=2000us: the §Perf batcher ablation shows a flat plateau
        // from 500-8000us with +-15% run-to-run noise on 1 core; 2000us sits
        // mid-plateau (EXPERIMENTS.md §Perf L3 / bench_speed -- batcher).
        EngineConfig {
            max_batch: 4,
            batch_deadline_us: 2_000,
            queue_depth: 256,
            workers: 1,
            backend: BackendKind::Auto,
        }
    }
}

/// Incremental decode runtime knobs (`serve::ServeRuntime` — the
/// KV-cached continuous-batching scheduler behind `dobi serve`).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Sessions decoding concurrently per scheduler tick; further opens
    /// queue FIFO-fair until a slot frees.
    pub max_sessions: usize,
    /// Queued-session bound beyond which opens are rejected
    /// (backpressure, mirroring `EngineConfig.queue_depth`).
    pub queue_depth: usize,
    /// Per-session KV capacity in positions (image prefix + prompt +
    /// generated); sessions that would outgrow it finish early with a
    /// `length` stop reason.
    pub kv_capacity: usize,
    /// Worker threads the blocked GEMM fans output columns across inside
    /// the decode scheduler (`dobi serve --decode-threads`); 1 keeps the
    /// single-threaded kernel.  Threaded and serial GEMMs are
    /// bit-identical, so this is purely a throughput knob.
    pub decode_threads: usize,
    /// Default speculative draft variant (`dobi serve --spec-draft`):
    /// greedy generate requests without their own `"spec"` field decode
    /// speculatively against this draft.  None (the default) leaves
    /// speculation fully client-driven.
    pub spec_draft: Option<String>,
    /// Tokens drafted per speculative round when `spec_draft` applies or
    /// the client's `"spec"` object omits `k` (`--spec-k`).
    pub spec_k: usize,
    /// Request-lifecycle trace ring capacity in events
    /// (`dobi serve --trace-buffer N`); 0 disables tracing entirely —
    /// the ring allocates nothing and record calls are inert.
    pub trace_buffer: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_sessions: 8,
            queue_depth: 256,
            kv_capacity: crate::coordinator::MAX_ANY_SEQ,
            decode_threads: 1,
            spec_draft: None,
            spec_k: 4,
            trace_buffer: 4096,
        }
    }
}

// ---------------------------------------------------------------------------
// Manifest
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub img_dim: usize,
    pub n_img_tokens: usize,
    pub action_head: bool,
    pub total_params: usize,
    pub fixed_params: usize,
}

/// Content-hash pinning a variant's `.dobiw` release: the manifest
/// records what `dobi compress` wrote, every load re-hashes what is on
/// disk, and a mismatch is a refusal — not a warning.  Manifests written
/// before provenance stamping simply lack the block (`None`): they load
/// unverified, preserving back-compat with the synth fixtures and any
/// python-side artifacts.
#[derive(Debug, Clone)]
pub struct Provenance {
    /// SHA-256 (hex) of the whole `.dobiw` container file.
    pub store_sha256: String,
    /// SHA-256 (hex) per tensor payload (section hashes).
    pub tensors: BTreeMap<String, String>,
    /// The `CompressConfig` dump that produced the release.
    pub config: Json,
    /// Writer identity: format magic, crate version.
    pub toolchain: Json,
}

impl Provenance {
    pub fn to_json(&self) -> Json {
        let tensors =
            Json::Obj(self.tensors.iter().map(|(k, v)| (k.clone(), Json::Str(v.clone()))).collect());
        Json::obj(vec![
            ("store_sha256", Json::Str(self.store_sha256.clone())),
            ("tensors", tensors),
            ("config", self.config.clone()),
            ("toolchain", self.toolchain.clone()),
        ])
    }

    /// Parse a variant's `provenance` block.  Returns `None` when the
    /// block is absent; a present block must carry a string
    /// `store_sha256` (anything else is a malformed manifest).
    fn from_json(v: &Json) -> Result<Option<Provenance>> {
        let Some(p) = v.get("provenance") else { return Ok(None) };
        let store_sha256 = p
            .get("store_sha256")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("provenance block without a `store_sha256` string"))?
            .to_string();
        let mut tensors = BTreeMap::new();
        for (name, h) in p.get("tensors").and_then(Json::as_obj).into_iter().flatten() {
            let hex = h
                .as_str()
                .ok_or_else(|| anyhow!("provenance tensor hash for `{name}` is not a string"))?;
            tensors.insert(name.clone(), hex.to_string());
        }
        Ok(Some(Provenance {
            store_sha256,
            tensors,
            config: p.get("config").cloned().unwrap_or(Json::Null),
            toolchain: p.get("toolchain").cloned().unwrap_or(Json::Null),
        }))
    }
}

#[derive(Debug, Clone)]
pub struct Variant {
    pub id: String,
    pub model: String,
    pub method: String,
    pub ratio: f64,
    pub kind: String,   // dense | factorized | pruned
    pub kernel: String, // xla | pallas
    pub weights: String,
    pub param_names: Vec<String>,
    /// shape key "BxS" -> hlo file
    pub hlo: BTreeMap<String, String>,
    pub inputs: Vec<String>,
    pub stored_params: usize,
    pub bytes: usize,
    pub ref_ppl: BTreeMap<String, f64>,
    pub perturb_x: Option<usize>,
    /// per-target truncation ranks (factorized variants only)
    pub ranks: BTreeMap<String, usize>,
    /// rank-allocation mode that produced the variant ("waterfill" /
    /// "learned"); older manifests without the field read as waterfill
    pub alloc: String,
    /// Content-hash pin for the weights store; `None` on pre-provenance
    /// manifests (loaded unverified).
    pub provenance: Option<Provenance>,
    /// Relative path of the `<variant>.run.json` compression run report
    /// `dobi compress` wrote next to the store; `None` on manifests from
    /// before run reports existed (`dobi inspect --run` then refuses with
    /// a clear message instead of guessing file names).
    pub run_report: Option<String>,
}

impl Variant {
    /// Parse "4x64" -> (4, 64).
    pub fn shapes(&self) -> Vec<(usize, usize)> {
        self.hlo.keys().filter_map(|k| parse_shape_key(k)).collect()
    }

    pub fn hlo_for(&self, batch: usize, seq: usize) -> Option<&str> {
        self.hlo.get(&format!("{batch}x{seq}")).map(|s| s.as_str())
    }

    /// Best shape for a given number of pending requests at a seq length:
    /// the smallest exported batch >= want (or the largest available).
    pub fn pick_batch(&self, want: usize, seq: usize) -> Option<usize> {
        let mut batches: Vec<usize> = self
            .shapes()
            .into_iter()
            .filter(|&(_, s)| s == seq)
            .map(|(b, _)| b)
            .collect();
        batches.sort_unstable();
        batches.iter().copied().find(|&b| b >= want).or(batches.last().copied())
    }
}

pub fn parse_shape_key(k: &str) -> Option<(usize, usize)> {
    let (b, s) = k.split_once('x')?;
    Some((b.parse().ok()?, s.parse().ok()?))
}

#[derive(Debug, Clone)]
pub struct CorpusInfo {
    pub name: String,
    pub train: String,
    pub eval_windows: String,
    pub n_windows: usize,
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub profile: String,
    pub models: BTreeMap<String, ModelInfo>,
    pub variants: Vec<Variant>,
    pub corpora: BTreeMap<String, CorpusInfo>,
    pub suites_file: Option<String>,
    pub vqa_file: Option<String>,
    pub vla_file: Option<String>,
    pub eval_batch: usize,
    pub eval_seq: usize,
    pub eval_windows: usize,
    pub analysis: Json,
    pub training: Json,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let doc = json::load(&dir.join("manifest.json"))?;
        let mut models = BTreeMap::new();
        for (name, m) in doc.get("models").and_then(Json::as_obj).into_iter().flatten() {
            let c = m.get("config").ok_or_else(|| anyhow!("model {name}: no config"))?;
            models.insert(
                name.clone(),
                ModelInfo {
                    name: name.clone(),
                    vocab: c.usize_of("vocab"),
                    d_model: c.usize_of("d_model"),
                    n_layers: c.usize_of("n_layers"),
                    n_heads: c.usize_of("n_heads"),
                    d_ff: c.usize_of("d_ff"),
                    img_dim: c.usize_of("img_dim"),
                    n_img_tokens: c.usize_of("n_img_tokens"),
                    action_head: c.get("action_head").and_then(Json::as_bool).unwrap_or(false),
                    total_params: m.usize_of("total_params"),
                    fixed_params: m.usize_of("fixed_params"),
                },
            );
        }
        let mut variants = Vec::new();
        for v in doc.get("variants").and_then(Json::as_arr).into_iter().flatten() {
            let mut hlo = BTreeMap::new();
            for (k, f) in v.get("hlo").and_then(Json::as_obj).into_iter().flatten() {
                hlo.insert(k.clone(), f.as_str().unwrap_or_default().to_string());
            }
            let mut ref_ppl = BTreeMap::new();
            for (k, f) in v.get("ref_ppl").and_then(Json::as_obj).into_iter().flatten() {
                ref_ppl.insert(k.clone(), f.as_f64().unwrap_or(f64::NAN));
            }
            let mut ranks = BTreeMap::new();
            for (k, f) in v.get("ranks").and_then(Json::as_obj).into_iter().flatten() {
                ranks.insert(k.clone(), f.as_f64().unwrap_or(0.0) as usize);
            }
            variants.push(Variant {
                id: v.str_of("id").to_string(),
                model: v.str_of("model").to_string(),
                method: v.str_of("method").to_string(),
                ratio: v.f64_of("ratio"),
                kind: v.str_of("kind").to_string(),
                kernel: v.str_of("kernel").to_string(),
                weights: v.str_of("weights").to_string(),
                param_names: v
                    .get("param_names")
                    .and_then(Json::as_arr)
                    .map(|a| a.iter().filter_map(|x| x.as_str().map(String::from)).collect())
                    .unwrap_or_default(),
                hlo,
                inputs: v
                    .get("inputs")
                    .and_then(Json::as_arr)
                    .map(|a| a.iter().filter_map(|x| x.as_str().map(String::from)).collect())
                    .unwrap_or_default(),
                stored_params: v.usize_of("stored_params"),
                bytes: v.usize_of("bytes"),
                ref_ppl,
                perturb_x: v.get("perturb_x").and_then(Json::as_usize),
                ranks,
                alloc: v
                    .get("alloc")
                    .and_then(Json::as_str)
                    .unwrap_or("waterfill")
                    .to_string(),
                provenance: Provenance::from_json(v)?,
                run_report: v.get("run_report").and_then(Json::as_str).map(String::from),
            });
        }
        let mut corpora = BTreeMap::new();
        for (name, c) in doc.get("corpora").and_then(Json::as_obj).into_iter().flatten() {
            corpora.insert(
                name.clone(),
                CorpusInfo {
                    name: name.clone(),
                    train: c.str_of("train").to_string(),
                    eval_windows: c.str_of("eval_windows").to_string(),
                    n_windows: c.usize_of("n_windows"),
                },
            );
        }
        let eval = doc.get("eval").ok_or_else(|| anyhow!("manifest: missing eval"))?;
        Ok(Manifest {
            dir: dir.to_path_buf(),
            profile: doc.str_of("profile").to_string(),
            models,
            variants,
            corpora,
            suites_file: doc.get("suites").and_then(Json::as_str).map(String::from),
            vqa_file: doc.get("vqa").and_then(Json::as_str).map(String::from),
            vla_file: doc.get("vla").and_then(Json::as_str).map(String::from),
            eval_batch: eval.usize_of("batch"),
            eval_seq: eval.usize_of("seq"),
            eval_windows: eval.usize_of("windows"),
            analysis: doc.get("analysis").cloned().unwrap_or(Json::Null),
            training: doc.get("training").cloned().unwrap_or(Json::Null),
        })
    }

    pub fn variant(&self, id: &str) -> Result<&Variant> {
        self.variants
            .iter()
            .find(|v| v.id == id)
            .ok_or_else(|| anyhow!("variant `{id}` not in manifest ({} known)", self.variants.len()))
    }

    pub fn variants_for_model(&self, model: &str) -> Vec<&Variant> {
        self.variants.iter().filter(|v| v.model == model).collect()
    }

    pub fn path(&self, rel: &str) -> PathBuf {
        self.dir.join(rel)
    }

    /// Open a variant's weights store, verifying its content hashes
    /// against the manifest's provenance pin when one is present.  This
    /// is THE load path for `.dobiw` stores: a release whose bytes do not
    /// match what `dobi compress` stamped is refused loudly, before any
    /// tensor reaches a model.  Pre-provenance manifests (no block) load
    /// unverified for back-compat.
    pub fn open_store(&self, v: &Variant) -> Result<crate::storage::Store> {
        let path = self.path(&v.weights);
        let store = crate::storage::Store::open(&path)?;
        let Some(p) = &v.provenance else { return Ok(store) };
        anyhow::ensure!(
            store.content_sha256 == p.store_sha256,
            "provenance mismatch for `{}`: {} hashes to {} but the manifest pins {} — \
             the store was modified or replaced since `dobi compress` wrote it; refusing to load",
            v.id, path.display(), store.content_sha256, p.store_sha256
        );
        for (name, want) in &p.tensors {
            let t = store.tensors.get(name).ok_or_else(|| {
                anyhow!("provenance mismatch for `{}`: tensor `{name}` pinned in the \
                         manifest is missing from {}", v.id, path.display())
            })?;
            let got = t.payload_sha256();
            anyhow::ensure!(
                &got == want,
                "provenance mismatch for `{}`: tensor `{name}` hashes to {got} but the \
                 manifest pins {want} — refusing to load", v.id
            );
        }
        Ok(store)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_key_parsing() {
        assert_eq!(parse_shape_key("4x64"), Some((4, 64)));
        assert_eq!(parse_shape_key("16x32"), Some((16, 32)));
        assert_eq!(parse_shape_key("bad"), None);
    }

    #[test]
    fn pick_batch_prefers_smallest_fitting() {
        let mut hlo = BTreeMap::new();
        for k in ["1x32", "4x32", "16x32", "4x64"] {
            hlo.insert(k.to_string(), format!("{k}.hlo.txt"));
        }
        let v = Variant {
            id: "m/x".into(), model: "m".into(), method: "dobi".into(), ratio: 0.6,
            kind: "factorized".into(), kernel: "xla".into(), weights: "w".into(),
            param_names: vec![], hlo, inputs: vec!["tokens".into()],
            stored_params: 0, bytes: 0, ref_ppl: BTreeMap::new(), perturb_x: None,
            ranks: BTreeMap::new(), alloc: "waterfill".into(), provenance: None,
            run_report: None,
        };
        assert_eq!(v.pick_batch(3, 32), Some(4));
        assert_eq!(v.pick_batch(1, 32), Some(1));
        assert_eq!(v.pick_batch(99, 32), Some(16));
        assert_eq!(v.pick_batch(2, 64), Some(4));
        assert_eq!(v.pick_batch(1, 128), None);
    }

    #[test]
    fn engine_defaults_sane() {
        let c = EngineConfig::default();
        assert!(c.max_batch >= 1 && c.queue_depth >= c.max_batch);
        assert_eq!(c.backend, BackendKind::Auto);
    }

    #[test]
    fn serve_defaults_sane() {
        let c = ServeConfig::default();
        assert!(c.max_sessions >= 1 && c.queue_depth >= c.max_sessions);
        assert_eq!(c.kv_capacity, crate::coordinator::MAX_ANY_SEQ);
        assert!(c.decode_threads >= 1);
        assert!(c.spec_draft.is_none(), "speculation stays opt-in by default");
        assert!(c.spec_k >= 1);
        assert!(c.trace_buffer > 0, "tracing is on by default (0 disables)");
    }

    #[test]
    fn precision_parses() {
        assert_eq!(Precision::parse("q8").unwrap(), Precision::Q8);
        assert_eq!(Precision::parse("8+16").unwrap(), Precision::Q8);
        assert_eq!(Precision::parse("f16").unwrap(), Precision::F16);
        assert_eq!(Precision::parse("f32").unwrap(), Precision::F32);
        assert!(Precision::parse("int3").is_err());
        assert_eq!(Precision::F16.to_string(), "f16");
    }

    #[test]
    fn compress_defaults_sane() {
        let c = CompressConfig::default();
        assert!(c.ratio > 0.0 && c.ratio <= 1.0);
        assert!(c.calib_batches >= 1 && c.calib_batch >= 1 && c.calib_seq >= 1);
        assert_eq!(c.precision, Precision::Q8);
        assert!(c.budget.is_none());
        assert_eq!(c.alloc, AllocMode::Waterfill, "waterfill stays the default");
        assert!(c.train_iters >= 1 && c.train_lr > 0.0);
        assert_eq!(c.svd_threads, 1);
    }

    #[test]
    fn alloc_mode_parses() {
        assert_eq!(AllocMode::parse("waterfill").unwrap(), AllocMode::Waterfill);
        assert_eq!(AllocMode::parse("greedy").unwrap(), AllocMode::Waterfill);
        assert_eq!(AllocMode::parse("learned").unwrap(), AllocMode::Learned);
        assert_eq!(AllocMode::parse("dobi").unwrap(), AllocMode::Learned);
        assert!(AllocMode::parse("magic").is_err());
        assert_eq!(AllocMode::Learned.to_string(), "learned");
        assert_eq!(AllocMode::default(), AllocMode::Waterfill);
    }

    #[test]
    fn provenance_round_trips_and_rejects_malformed() {
        let p = Provenance {
            store_sha256: "ab".repeat(32),
            tensors: BTreeMap::from([("embed".to_string(), "cd".repeat(32))]),
            config: CompressConfig::default().to_json(),
            toolchain: Json::obj(vec![("writer", Json::Str("dobi-native".into()))]),
        };
        let v = Json::obj(vec![("id", Json::Str("m/x".into())), ("provenance", p.to_json())]);
        let back = Provenance::from_json(&v).unwrap().expect("block present");
        assert_eq!(back.store_sha256, p.store_sha256);
        assert_eq!(back.tensors, p.tensors);
        assert_eq!(back.config.path("alloc").and_then(Json::as_str), Some("waterfill"));
        // absent block -> None (pre-provenance manifests load unverified)
        let bare = Json::obj(vec![("id", Json::Str("m/x".into()))]);
        assert!(Provenance::from_json(&bare).unwrap().is_none());
        // present-but-malformed block is a manifest error, not a silent skip
        let bad = Json::obj(vec![(
            "provenance",
            Json::obj(vec![("store_sha256", Json::Num(7.0))]),
        )]);
        assert!(Provenance::from_json(&bad).is_err());
    }

    #[test]
    fn compress_config_json_dump_is_complete() {
        let c = CompressConfig {
            budget: Some(1234),
            alloc: AllocMode::Learned,
            ..Default::default()
        };
        let j = c.to_json();
        assert_eq!(j.path("budget").and_then(Json::as_usize), Some(1234));
        assert_eq!(j.path("precision").and_then(Json::as_str), Some("q8"));
        assert_eq!(j.path("alloc").and_then(Json::as_str), Some("learned"));
        assert_eq!(j.path("seed").and_then(Json::as_usize), Some(11));
        assert_eq!(j.path("train_iters").and_then(Json::as_usize), Some(300));
        // unset budget serializes as null, not a fake number
        assert!(matches!(CompressConfig::default().to_json().path("budget"),
                         Some(Json::Null)));
    }

    #[test]
    fn backend_kind_parses() {
        assert_eq!(BackendKind::parse("auto").unwrap(), BackendKind::Auto);
        assert_eq!(BackendKind::parse("pjrt").unwrap(), BackendKind::Pjrt);
        assert_eq!(BackendKind::parse("native").unwrap(), BackendKind::Native);
        assert_eq!(BackendKind::parse("lowrank").unwrap(), BackendKind::Native);
        assert!(BackendKind::parse("tpu").is_err());
        assert_eq!(BackendKind::Native.to_string(), "native");
    }
}
