//! Measurement harness (criterion is not in the offline registry).
//!
//! Warmup + repeated timed runs + summary statistics, plus table rendering
//! helpers shared by all `rust/benches/*` binaries so the paper tables
//! print with consistent formatting.

pub mod loadgen;

use std::time::Instant;

use crate::mathx::{summarize, Stats};

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub stats: Stats,       // seconds per iteration
    pub iters: usize,
}

impl BenchResult {
    pub fn throughput(&self, units_per_iter: f64) -> f64 {
        if self.stats.mean > 0.0 {
            units_per_iter / self.stats.mean
        } else {
            0.0
        }
    }
}

/// Time `f` for `iters` iterations after `warmup` untimed runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    BenchResult { name: name.to_string(), stats: summarize(&samples), iters }
}

/// Adaptive: run until `min_time_s` elapsed (at least `min_iters`).
pub fn bench_for<F: FnMut()>(name: &str, min_time_s: f64, min_iters: usize,
                             mut f: F) -> BenchResult {
    f(); // warmup
    let mut samples = Vec::new();
    let start = Instant::now();
    while samples.len() < min_iters || start.elapsed().as_secs_f64() < min_time_s {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
        if samples.len() > 10_000 {
            break;
        }
    }
    BenchResult { name: name.to_string(), stats: summarize(&samples), iters: samples.len() }
}

// ---------------------------------------------------------------------------
// Table rendering (paper-style rows)
// ---------------------------------------------------------------------------

pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = format!("\n== {} ==\n", self.title);
        let line = |cells: &[String], w: &[usize]| -> String {
            cells
                .iter()
                .zip(w)
                .map(|(c, &w)| format!("{c:<w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&line(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&line(r, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

pub fn fmt_f(x: f64, prec: usize) -> String {
    if x.is_nan() {
        "-".to_string()
    } else {
        format!("{x:.prec$}")
    }
}

/// Artifacts dir for tests/benches: $DOBI_ARTIFACTS or ./artifacts.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var("DOBI_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from(crate::DEFAULT_ARTIFACTS))
}

/// True when artifacts exist; integration tests/benches skip otherwise
/// (unit tests never need them).
pub fn artifacts_available() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

/// Write a machine-readable bench report (`BENCH_<name>.json`) so the
/// perf trajectory is trackable across PRs.  Emitted into $BENCH_OUT (or
/// the working directory); returns the path written.
pub fn write_bench_json(name: &str, doc: &crate::json::Json)
                        -> std::io::Result<std::path::PathBuf> {
    let dir = std::env::var("BENCH_OUT")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("."));
    let path = dir.join(format!("BENCH_{name}.json"));
    std::fs::write(&path, doc.to_string())?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iters() {
        let mut n = 0;
        let r = bench("x", 2, 5, || n += 1);
        assert_eq!(n, 7);
        assert_eq!(r.iters, 5);
        assert!(r.stats.mean >= 0.0);
    }

    #[test]
    fn bench_for_reaches_min() {
        let r = bench_for("x", 0.01, 3, || std::thread::sleep(std::time::Duration::from_micros(100)));
        assert!(r.iters >= 3);
    }

    #[test]
    fn throughput_math() {
        let r = BenchResult {
            name: "t".into(),
            stats: Stats { mean: 0.5, ..Default::default() },
            iters: 1,
        };
        assert!((r.throughput(100.0) - 200.0).abs() < 1e-9);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("T", &["a", "bbbb"]);
        t.row(vec!["xx".into(), "1".into()]);
        let s = t.render();
        assert!(s.contains("== T =="));
        assert!(s.contains("xx"));
    }

    #[test]
    #[should_panic]
    fn table_rejects_bad_arity() {
        let mut t = Table::new("T", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }
}
