//! Open-loop workload generator for serving benches: Poisson arrivals at a
//! target rate, fixed-duration runs, latency collection.  Closed-loop
//! clients (the examples) understate tail latency because they self-throttle;
//! the latency-vs-offered-load curve needs open-loop arrivals.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::{Engine, Response, SubmitError};
use crate::mathx::{summarize, Stats, XorShift};
use crate::tokenizer::ByteTokenizer;

#[derive(Debug, Clone)]
pub struct LoadResult {
    pub offered_rps: f64,
    pub achieved_rps: f64,
    pub submitted: usize,
    pub completed: usize,
    pub rejected: usize,
    pub latency: Stats,
}

/// Drive `engine` with Poisson arrivals at `rate_rps` for `duration`.
/// Requests that hit backpressure count as rejected (that is the correct
/// open-loop semantics: the client does not wait).
pub fn poisson_load(engine: &Arc<Engine>, variant: &str, seq: usize, rate_rps: f64,
                    duration: Duration, seed: u64) -> LoadResult {
    let tok = ByteTokenizer;
    let mut rng = XorShift::new(seed);
    let window = tok.encode_window("the quick brown fox jumps over the lazy dog ", seq, 32);
    let start = Instant::now();
    let mut next_arrival = start;
    let mut submitted = 0usize;
    let mut rejected = 0usize;
    let mut pending: Vec<mpsc::Receiver<Response>> = Vec::new();
    while start.elapsed() < duration {
        let now = Instant::now();
        if now < next_arrival {
            std::thread::sleep(next_arrival - now);
        }
        // exponential inter-arrival
        let u = rng.f64().max(1e-12);
        next_arrival += Duration::from_secs_f64(-u.ln() / rate_rps);
        match engine.submit(variant, window.clone(), None) {
            Ok(rx) => {
                submitted += 1;
                pending.push(rx);
            }
            Err(SubmitError::QueueFull { .. }) => rejected += 1,
            Err(_) => break,
        }
    }
    let mut latencies = Vec::with_capacity(pending.len());
    let mut completed = 0usize;
    for rx in pending {
        if let Ok(resp) = rx.recv_timeout(Duration::from_secs(30)) {
            latencies.push(resp.total_s);
            completed += 1;
        }
    }
    let wall = start.elapsed().as_secs_f64();
    LoadResult {
        offered_rps: rate_rps,
        achieved_rps: completed as f64 / wall,
        submitted,
        completed,
        rejected,
        latency: summarize(&latencies),
    }
}

#[cfg(test)]
mod tests {
    use crate::mathx::XorShift;

    #[test]
    fn exponential_interarrival_mean_matches_rate() {
        let mut rng = XorShift::new(3);
        let rate = 50.0;
        let n = 20_000;
        let mut total = 0.0;
        for _ in 0..n {
            let u: f64 = rng.f64().max(1e-12);
            total += -u.ln() / rate;
        }
        let mean = total / n as f64;
        assert!((mean - 1.0 / rate).abs() < 0.002, "mean {mean}");
    }
}
