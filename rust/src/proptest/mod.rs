//! Mini property-testing substrate (the proptest crate is not in the
//! offline registry).  Deterministic xorshift generation + shrinking-free
//! counterexample reporting; enough for the coordinator/storage invariants.

use crate::mathx::XorShift;

pub struct Gen {
    pub rng: XorShift,
}

impl Gen {
    pub fn new(seed: u64) -> Gen {
        Gen { rng: XorShift::new(seed) }
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below(hi.saturating_sub(lo).max(1))
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.f64() * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.below(2) == 1
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f64_in(lo as f64, hi as f64) as f32).collect()
    }

    pub fn vec_i32(&mut self, len: usize, lo: i32, hi: i32) -> Vec<i32> {
        (0..len).map(|_| lo + self.rng.below((hi - lo).max(1) as usize) as i32).collect()
    }

    pub fn ascii_string(&mut self, len: usize) -> String {
        (0..len).map(|_| (b'a' + self.rng.below(26) as u8) as char).collect()
    }
}

/// Run `prop` over `cases` generated inputs; panics with the failing seed
/// so the case replays deterministically.
pub fn check<F: Fn(&mut Gen) -> Result<(), String>>(name: &str, cases: usize, prop: F) {
    for case in 0..cases {
        let seed = 0x9E3779B97F4A7C15u64.wrapping_mul(case as u64 + 1);
        let mut g = Gen::new(seed);
        if let Err(msg) = prop(&mut g) {
            panic!("property `{name}` failed on case {case} (seed {seed:#x}): {msg}");
        }
    }
}

#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial() {
        check("trivial", 50, |g| {
            let x = g.usize_in(1, 10);
            prop_assert!((1..10).contains(&x), "x={x} out of range");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property `fails`")]
    fn check_reports_failure() {
        check("fails", 10, |g| {
            let x = g.usize_in(0, 100);
            prop_assert!(x < 101, "unreachable");
            prop_assert!(x % 7 != 3, "x={x} hit the bad class");
            Ok(())
        });
    }

    #[test]
    fn gen_deterministic_per_case() {
        let mut a = Gen::new(42);
        let mut b = Gen::new(42);
        assert_eq!(a.vec_i32(10, 0, 100), b.vec_i32(10, 0, 100));
        assert_eq!(a.ascii_string(8), b.ascii_string(8));
    }
}
