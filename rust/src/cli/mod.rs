//! Tiny argument-parsing substrate (no clap offline).
//!
//! Grammar: `binary <subcommand> [--flag value] [--switch] [positional...]`.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub flags: BTreeMap<String, String>,
    pub switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (without argv[0]).
    /// `switch_names` lists flags that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, switch_names: &[&str]) -> Args {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if switch_names.contains(&name) {
                    out.switches.push(name.to_string());
                } else if let Some(v) = it.peek() {
                    if v.starts_with("--") {
                        out.switches.push(name.to_string());
                    } else {
                        out.flags.insert(name.to_string(), it.next().unwrap());
                    }
                } else {
                    out.switches.push(name.to_string());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env(switch_names: &[&str]) -> Args {
        Args::parse(std::env::args().skip(1), switch_names)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got `{v}`")))
            .unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a number, got `{v}`")))
            .unwrap_or(default)
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(v: &[&str], sw: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()), sw)
    }

    #[test]
    fn parses_subcommand_flags_switches() {
        let a = mk(&["serve", "--port", "9000", "--verbose", "extra"], &["verbose"]);
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.get("port"), Some("9000"));
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn parses_eq_form() {
        let a = mk(&["eval", "--ratio=0.6"], &[]);
        assert_eq!(a.f64_or("ratio", 1.0), 0.6);
    }

    #[test]
    fn trailing_flag_becomes_switch() {
        let a = mk(&["x", "--flag"], &[]);
        assert!(a.has("flag"));
    }

    #[test]
    fn flag_before_another_flag_is_switch() {
        let a = mk(&["x", "--a", "--b", "1"], &[]);
        assert!(a.has("a"));
        assert_eq!(a.get("b"), Some("1"));
    }

    #[test]
    fn defaults() {
        let a = mk(&["x"], &[]);
        assert_eq!(a.usize_or("n", 5), 5);
        assert_eq!(a.get_or("s", "d"), "d");
    }
}
