//! Numeric substrate: stable softmax/logsumexp/NLL over logits, summary
//! statistics, and a deterministic xorshift RNG (no rand crate offline).

/// Numerically stable log-sum-exp over a slice.
pub fn logsumexp(xs: &[f32]) -> f32 {
    let m = xs.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    if !m.is_finite() {
        return m;
    }
    let s: f32 = xs.iter().map(|&x| (x - m).exp()).sum();
    m + s.ln()
}

/// In-place softmax.
pub fn softmax(xs: &mut [f32]) {
    let lse = logsumexp(xs);
    for x in xs.iter_mut() {
        *x = (*x - lse).exp();
    }
}

/// Negative log-likelihood of `target` under `logits` (one position).
///
/// A target outside the vocabulary has probability zero, so its NLL is
/// `+inf` — returned rather than panicking, so a corrupt token stream
/// poisons the measurement loudly instead of aborting the serving
/// process.  Callers ([`lm_cross_entropy`], [`span_nll`]) propagate it.
pub fn nll(logits: &[f32], target: usize) -> f32 {
    match logits.get(target) {
        Some(&l) => logsumexp(logits) - l,
        None => f32::INFINITY,
    }
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Mean next-token cross-entropy over a (B, S, V) logits block and (B, S)
/// targets — identical definition to python's `lm_loss` so PPLs match.
pub fn lm_cross_entropy(logits: &[f32], tokens: &[i32], b: usize, s: usize, v: usize) -> f32 {
    assert_eq!(logits.len(), b * s * v, "logits size mismatch");
    assert_eq!(tokens.len(), b * s, "tokens size mismatch");
    let mut total = 0.0f64;
    let mut count = 0usize;
    for bi in 0..b {
        for si in 0..s.saturating_sub(1) {
            let row = &logits[(bi * s + si) * v..(bi * s + si + 1) * v];
            let tgt = tokens[bi * s + si + 1] as usize;
            total += nll(row, tgt) as f64;
            count += 1;
        }
    }
    (total / count.max(1) as f64) as f32
}

/// Length-normalized NLL of a continuation span `[start, end)` within one
/// sequence of a (B,S,V) block — the lm-eval-harness option score.
pub fn span_nll(logits: &[f32], tokens: &[i32], s: usize, v: usize, bi: usize,
                start: usize, end: usize) -> f32 {
    let mut total = 0.0f32;
    let mut n = 0usize;
    for si in start.max(1)..end {
        let row = &logits[(bi * s + si - 1) * v..(bi * s + si) * v];
        total += nll(row, tokens[bi * s + si] as usize);
        n += 1;
    }
    if n == 0 {
        f32::INFINITY
    } else {
        total / n as f32
    }
}

// ---------------------------------------------------------------------------
// Statistics
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Default)]
pub struct Stats {
    pub n: usize,
    pub mean: f64,
    pub min: f64,
    pub max: f64,
    pub std: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

/// Summary statistics of a sample (used by the bench harness and metrics).
pub fn summarize(xs: &[f64]) -> Stats {
    if xs.is_empty() {
        return Stats::default();
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = sorted.len();
    let mean = sorted.iter().sum::<f64>() / n as f64;
    let var = sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    let q = |p: f64| sorted[((p * (n - 1) as f64).round() as usize).min(n - 1)];
    Stats {
        n,
        mean,
        min: sorted[0],
        max: sorted[n - 1],
        std: var.sqrt(),
        p50: q(0.5),
        p95: q(0.95),
        p99: q(0.99),
    }
}

// ---------------------------------------------------------------------------
// Deterministic RNG (xorshift64*)
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct XorShift {
    state: u64,
}

impl XorShift {
    pub fn new(seed: u64) -> Self {
        Self { state: seed.max(1) }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n.max(1) as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Sample an index from unnormalized positive weights.
    pub fn weighted(&mut self, ws: &[f64]) -> usize {
        let total: f64 = ws.iter().sum();
        let mut r = self.f64() * total;
        for (i, w) in ws.iter().enumerate() {
            r -= w;
            if r <= 0.0 {
                return i;
            }
        }
        ws.len() - 1
    }
}

/// Temperature sampling from logits (temperature 0 = greedy).
pub fn sample_logits(logits: &[f32], temperature: f32, rng: &mut XorShift) -> usize {
    if temperature <= 0.0 {
        return argmax(logits);
    }
    let mut probs: Vec<f32> = logits.iter().map(|&x| x / temperature).collect();
    softmax(&mut probs);
    let ws: Vec<f64> = probs.iter().map(|&p| p as f64).collect();
    rng.weighted(&ws)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn logsumexp_stable() {
        let xs = [1000.0f32, 1000.0, 1000.0];
        let lse = logsumexp(&xs);
        assert!((lse - (1000.0 + 3.0f32.ln())).abs() < 1e-3);
        assert!(logsumexp(&[f32::NEG_INFINITY, 0.0]).abs() < 1e-6);
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut xs = vec![0.5f32, -1.0, 3.0, 0.0];
        softmax(&mut xs);
        let s: f32 = xs.iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
        assert!(xs.iter().all(|&p| p >= 0.0));
    }

    #[test]
    fn nll_uniform_is_log_v() {
        let logits = vec![0.0f32; 256];
        assert!((nll(&logits, 7) - (256f32).ln()).abs() < 1e-4);
    }

    #[test]
    fn nll_out_of_vocab_is_infinite_not_panic() {
        // regression: `logits[target]` used to panic on ids >= V
        let logits = vec![0.0f32; 8];
        assert_eq!(nll(&logits, 8), f32::INFINITY);
        assert_eq!(nll(&logits, usize::MAX), f32::INFINITY);
    }

    #[test]
    fn lm_ce_survives_corrupt_token_ids() {
        // V=4 but the stream contains an id far outside the vocab (e.g. a
        // negative i32 cast): the mean must go +inf, not abort.
        let logits = vec![0.0f32; 3 * 4];
        let ce = lm_cross_entropy(&logits, &[0, -1, 2], 1, 3, 4);
        assert!(ce.is_infinite() && ce > 0.0);
        // and a clean stream stays finite
        let ok = lm_cross_entropy(&logits, &[0, 1, 2], 1, 3, 4);
        assert!(ok.is_finite());
    }

    #[test]
    fn span_nll_survives_corrupt_token_ids() {
        let logits = vec![0.0f32; 4 * 3];
        let x = span_nll(&logits, &[0, 1, 9, 0], 4, 3, 0, 2, 4);
        assert!(x.is_infinite() && x > 0.0);
    }

    #[test]
    fn lm_ce_matches_manual() {
        // B=1, S=3, V=2; logits prefer token 0 everywhere
        let logits = vec![2.0, 0.0, 2.0, 0.0, 2.0, 0.0];
        let tokens = vec![0, 0, 1];
        let ce = lm_cross_entropy(&logits, &tokens, 1, 3, 2);
        let p0 = nll(&[2.0, 0.0], 0);
        let p1 = nll(&[2.0, 0.0], 1);
        assert!((ce - (p0 + p1) / 2.0).abs() < 1e-5);
    }

    #[test]
    fn span_nll_basic() {
        let logits = vec![0.0f32; 4 * 3]; // S=4, V=3, B=1
        let tokens = vec![0, 1, 2, 0];
        let x = span_nll(&logits, &tokens, 4, 3, 0, 2, 4);
        assert!((x - (3f32).ln()).abs() < 1e-5);
    }

    #[test]
    fn stats_percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = summarize(&xs);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert!((s.p50 - 50.0).abs() <= 1.0);
        assert!((s.mean - 50.5).abs() < 1e-9);
        assert!(s.p99 >= 98.0);
    }

    #[test]
    fn rng_deterministic_and_spread() {
        let mut a = XorShift::new(42);
        let mut b = XorShift::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut counts = [0usize; 10];
        for _ in 0..10_000 {
            counts[a.below(10)] += 1;
        }
        assert!(counts.iter().all(|&c| c > 700 && c < 1300));
    }

    #[test]
    fn rng_normal_moments() {
        let mut r = XorShift::new(7);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.05);
        assert!((var - 1.0).abs() < 0.1);
    }

    #[test]
    fn greedy_sampling_is_argmax() {
        let mut r = XorShift::new(1);
        assert_eq!(sample_logits(&[0.1, 5.0, 0.3], 0.0, &mut r), 1);
    }

    #[test]
    fn weighted_sampling_biased() {
        let mut r = XorShift::new(2);
        let mut hits = 0;
        for _ in 0..1000 {
            if r.weighted(&[0.9, 0.1]) == 0 {
                hits += 1;
            }
        }
        assert!(hits > 800);
    }
}
