//! Evaluation harness: perplexity on the fixed eval windows, zero-shot
//! multiple-choice accuracy (length-normalized NLL, lm-eval-harness
//! style), greedy/temperature generation, and the VQA/VLA metrics.
//!
//! Works directly on a `LoadedModel` (deterministic, single-threaded) —
//! the serving engine is exercised separately by the integration tests
//! and the throughput benches.

use anyhow::{anyhow, Result};

use crate::config::Manifest;
use crate::corpusio::{self, Task, TaskSuite, VlaSample, VqaSample};
use crate::mathx::{self, XorShift};
use crate::runtime::ForwardModel;
use crate::tokenizer::ByteTokenizer;

/// Perplexity over the python-exported eval windows of `corpus` —
/// bit-compatible with `aot.reference_ppls` (same windows, same order,
/// same mean-CE-then-exp definition).
pub fn perplexity<M: ForwardModel>(model: &M, manifest: &Manifest, corpus: &str) -> Result<f64> {
    let info = manifest
        .corpora
        .get(corpus)
        .ok_or_else(|| anyhow!("corpus `{corpus}` not in manifest"))?;
    let toks = corpusio::read_tokbin(&manifest.path(&info.eval_windows))?;
    let (b, s) = (manifest.eval_batch, manifest.eval_seq);
    let windows = corpusio::eval_windows(&toks, info.n_windows, b, s)?;
    let vocab = model.vocab();
    let mut total = 0.0f64;
    for w in &windows {
        let logits = model.forward(b, s, w, None)?;
        total += mathx::lm_cross_entropy(&logits, w, b, s, vocab) as f64;
    }
    Ok((total / windows.len() as f64).exp())
}

// ---------------------------------------------------------------------------
// Zero-shot multiple choice
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct SuiteResult {
    pub name: String,
    pub accuracy: f64,
    pub n: usize,
}

/// Score one task: pick the option with lowest length-normalized NLL.
pub fn score_task<M: ForwardModel>(model: &M, task: &Task, b: usize, s: usize) -> Result<usize> {
    let tok = ByteTokenizer;
    let vocab = model.vocab();
    let mut best = (f32::INFINITY, 0usize);
    // Batch options into the exported batch dim.
    let mut spans = Vec::new();
    let mut tokens = vec![0i32; b * s];
    let n_opt = task.options.len();
    anyhow::ensure!(n_opt <= b * 4, "too many options for batch");
    let mut oi = 0;
    while oi < n_opt {
        let take = (n_opt - oi).min(b);
        spans.clear();
        for r in 0..b {
            let opt = &task.options[(oi + r.min(take - 1)).min(n_opt - 1)];
            let (w, st, en) = tok.encode_pair(&task.prompt, opt, s, b' ' as i32);
            tokens[r * s..(r + 1) * s].copy_from_slice(&w);
            spans.push((st, en));
        }
        let logits = model.forward(b, s, &tokens, None)?;
        for r in 0..take {
            let (st, en) = spans[r];
            let nll = mathx::span_nll(&logits, &tokens, s, vocab, r, st, en);
            if nll < best.0 {
                best = (nll, oi + r);
            }
        }
        oi += take;
    }
    Ok(best.1)
}

pub fn run_suite<M: ForwardModel>(model: &M, suite: &TaskSuite, b: usize, s: usize,
                 limit: usize) -> Result<SuiteResult> {
    let mut correct = 0usize;
    let n = suite.tasks.len().min(limit);
    for task in suite.tasks.iter().take(n) {
        if score_task(model, task, b, s)? == task.answer {
            correct += 1;
        }
    }
    Ok(SuiteResult { name: suite.name.clone(), accuracy: correct as f64 / n.max(1) as f64, n })
}

// ---------------------------------------------------------------------------
// Generation
// ---------------------------------------------------------------------------

/// Sliding-window generation: re-run the fixed-shape forward per token.
pub fn generate<M: ForwardModel>(model: &M, b: usize, s: usize, prompt: &str,
                n_tokens: usize, temperature: f32, seed: u64) -> Result<String> {
    let tok = ByteTokenizer;
    let vocab = model.vocab();
    let mut rng = XorShift::new(seed);
    let mut ctx = tok.encode(prompt);
    let mut out = Vec::new();
    for _ in 0..n_tokens {
        let mut window = vec![b' ' as i32; s];
        let take = ctx.len().min(s);
        window[s - take..].copy_from_slice(&ctx[ctx.len() - take..]);
        // Fill batch rows with the same window (b is the exported shape).
        let mut tokens = vec![0i32; b * s];
        for r in 0..b {
            tokens[r * s..(r + 1) * s].copy_from_slice(&window);
        }
        let logits = model.forward(b, s, &tokens, None)?;
        let base = (s - 1) * vocab;
        let next = mathx::sample_logits(&logits[base..base + vocab], temperature, &mut rng) as i32;
        ctx.push(next);
        out.push(next);
    }
    Ok(tok.decode(&out))
}

// ---------------------------------------------------------------------------
// VQA / VLA
// ---------------------------------------------------------------------------

pub fn run_vqa<M: ForwardModel>(model: &M, samples: &[VqaSample], b: usize, s: usize,
               limit: usize) -> Result<SuiteResult> {
    let tok = ByteTokenizer;
    let vocab = model.vocab();
    let n = samples.len().min(limit);
    let mut correct = 0usize;
    for sample in samples.iter().take(n) {
        let mut best = (f32::INFINITY, 0usize);
        for (i, opt) in sample.options.iter().enumerate() {
            let (w, st, en) = tok.encode_pair(&sample.question, opt, s, b' ' as i32);
            let mut tokens = vec![0i32; b * s];
            let mut image = vec![0f32; b * model.img_dim()];
            for r in 0..b {
                tokens[r * s..(r + 1) * s].copy_from_slice(&w);
                image[r * model.img_dim()..(r + 1) * model.img_dim()]
                    .copy_from_slice(&sample.image);
            }
            let logits = model.forward(b, s, &tokens, Some(&image))?;
            let nll = mathx::span_nll(&logits, &tokens, s, vocab, 0, st, en);
            if nll < best.0 {
                best = (nll, i);
            }
        }
        if best.1 == sample.answer {
            correct += 1;
        }
    }
    Ok(SuiteResult { name: "vqa".into(), accuracy: correct as f64 / n.max(1) as f64, n })
}

#[derive(Debug, Clone, Default)]
pub struct VlaResult {
    pub coords_mse: f64,
    pub angle_mse: f64,
    pub gripper_acc: f64,
    pub n: usize,
}

pub fn run_vla<M: ForwardModel>(model: &M, samples: &[VlaSample], b: usize, s: usize,
               limit: usize) -> Result<VlaResult> {
    let tok = ByteTokenizer;
    anyhow::ensure!(model.action_head(), "model has no action head");
    let n = samples.len().min(limit);
    let mut res = VlaResult { n, ..Default::default() };
    let mut i = 0;
    while i < n {
        let take = (n - i).min(b);
        let mut tokens = vec![b' ' as i32; b * s];
        let mut image = vec![0f32; b * model.img_dim()];
        for r in 0..take {
            let sm = &samples[i + r];
            let w = tok.encode_window(&sm.instruction, s, b' ' as i32);
            tokens[r * s..(r + 1) * s].copy_from_slice(&w);
            image[r * model.img_dim()..(r + 1) * model.img_dim()].copy_from_slice(&sm.image);
        }
        let out = model.forward(b, s, &tokens, Some(&image))?;
        for r in 0..take {
            let sm = &samples[i + r];
            let a = &out[r * 5..(r + 1) * 5];
            for d in 0..3 {
                res.coords_mse += ((a[d] - sm.coords[d]) as f64).powi(2) / 3.0;
            }
            res.angle_mse += ((a[3] - sm.angle) as f64).powi(2);
            let pred_grip = (a[4] > 0.0) as i32;
            if pred_grip == sm.gripper {
                res.gripper_acc += 1.0;
            }
        }
        i += take;
    }
    res.coords_mse /= n as f64;
    res.angle_mse /= n as f64;
    res.gripper_acc /= n as f64;
    Ok(res)
}

#[cfg(test)]
mod tests {
    //! Logic tests on a mock ForwardModel (no PJRT); the PJRT-backed paths
    //! are covered by rust/tests/integration.rs over real artifacts.
    use super::*;
    use crate::mathx::span_nll;

    /// Bigram mock LM: P(next = (prev + 1) % V) is high — so continuations
    /// that increment byte values are "likely", everything else is not.
    struct MockLm {
        vocab: usize,
        action: bool,
        img: usize,
    }

    impl ForwardModel for MockLm {
        fn forward(&self, b: usize, s: usize, tokens: &[i32],
                   image: Option<&[f32]>) -> Result<Vec<f32>> {
            if self.action {
                // action head: deterministic function of the first image feature
                let img = image.unwrap();
                let mut out = vec![0f32; b * 5];
                for r in 0..b {
                    let x = img[r * self.img];
                    out[r * 5] = x.tanh();
                    out[r * 5 + 3] = (-x).tanh();
                    out[r * 5 + 4] = x; // gripper logit
                }
                return Ok(out);
            }
            let mut out = vec![0f32; b * s * self.vocab];
            for r in 0..b {
                for p in 0..s {
                    let prev = tokens[r * s + p] as usize % self.vocab;
                    let want = (prev + 1) % self.vocab;
                    out[(r * s + p) * self.vocab + want] = 8.0;
                }
            }
            Ok(out)
        }

        fn vocab(&self) -> usize {
            self.vocab
        }

        fn img_dim(&self) -> usize {
            self.img
        }

        fn action_head(&self) -> bool {
            self.action
        }
    }

    fn lm() -> MockLm {
        MockLm { vocab: 256, action: false, img: 0 }
    }

    #[test]
    fn span_nll_prefers_likely_continuation() {
        let mut logits = vec![0f32; 3 * 4];
        for p in 0..3 {
            logits[p * 4 + 2] = 6.0;
        }
        let good = vec![0, 2, 2];
        let bad = vec![0, 1, 1];
        let g = span_nll(&logits, &good, 3, 4, 0, 1, 3);
        let b = span_nll(&logits, &bad, 3, 4, 0, 1, 3);
        assert!(g < b);
    }

    #[test]
    fn score_task_picks_model_preferred_option() {
        // prompt ends with 'a' (97); the mock prefers strictly incrementing
        // bytes, so "bcd" beats "xyz" and "qqq".
        let task = Task {
            prompt: "a".into(),
            options: vec!["qqq".into(), "bcd".into(), "xyz".into()],
            answer: 1,
        };
        let got = score_task(&lm(), &task, 4, 16).unwrap();
        assert_eq!(got, 1);
    }

    #[test]
    fn run_suite_counts_accuracy() {
        let mk = |ans_good: bool| Task {
            prompt: "a".into(),
            options: if ans_good {
                vec!["bcd".into(), "zzz".into()]
            } else {
                vec!["bcd".into(), "zzz".into()]
            },
            answer: if ans_good { 0 } else { 1 },
        };
        let suite = TaskSuite {
            name: "t".into(),
            tasks: vec![mk(true), mk(true), mk(false), mk(true)],
        };
        let r = run_suite(&lm(), &suite, 2, 16, usize::MAX).unwrap();
        assert_eq!(r.n, 4);
        assert!((r.accuracy - 0.75).abs() < 1e-9);
    }

    #[test]
    fn run_suite_respects_limit() {
        let t = Task { prompt: "a".into(), options: vec!["b".into(), "z".into()], answer: 0 };
        let suite = TaskSuite { name: "t".into(), tasks: vec![t.clone(), t.clone(), t] };
        let r = run_suite(&lm(), &suite, 2, 8, 2).unwrap();
        assert_eq!(r.n, 2);
    }

    #[test]
    fn generate_greedy_increments_bytes() {
        // greedy sampling under the bigram mock yields consecutive bytes
        let text = generate(&lm(), 1, 8, "a", 4, 0.0, 1).unwrap();
        assert_eq!(text.as_bytes(), b"bcde");
    }

    #[test]
    fn generate_deterministic_per_seed() {
        let a = generate(&lm(), 1, 8, "hi", 6, 0.9, 5).unwrap();
        let b = generate(&lm(), 1, 8, "hi", 6, 0.9, 5).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn run_vla_metrics_exact_on_mock() {
        let model = MockLm { vocab: 256, action: true, img: 2 };
        let samples: Vec<VlaSample> = (0..6)
            .map(|i| {
                let x = (i as f32 - 3.0) / 3.0;
                VlaSample {
                    image: vec![x, 0.0],
                    instruction: "go".into(),
                    coords: [x.tanh(), 0.0, 0.0],
                    angle: (-x).tanh(),
                    gripper: (x > 0.0) as i32,
                }
            })
            .collect();
        let r = run_vla(&model, &samples, 2, 4, 6).unwrap();
        assert!(r.coords_mse < 1e-10);
        assert!(r.angle_mse < 1e-10);
        // x == 0 sample: logit 0 -> predicted 0, label gripper 0 -> correct
        assert!((r.gripper_acc - 1.0).abs() < 1e-9);
    }

    #[test]
    fn run_vla_rejects_non_action_model() {
        assert!(run_vla(&lm(), &[], 1, 4, 1).is_err());
    }

    #[test]
    fn run_vqa_on_mock() {
        let model = MockLm { vocab: 256, action: false, img: 3 };
        let samples = vec![VqaSample {
            image: vec![0.0; 3],
            question: "a".into(),
            options: vec!["zzz".into(), "bcd".into()],
            answer: 1,
        }];
        let r = run_vqa(&model, &samples, 2, 16, 1).unwrap();
        assert!((r.accuracy - 1.0).abs() < 1e-9);
    }
}
