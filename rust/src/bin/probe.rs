//! Dev tool: execute /tmp/p_<name>.hlo.txt with /tmp/p_<name>.in inputs
//! and diff against /tmp/p_<name>.npy (f32 raw after the npy header).
use anyhow::{anyhow, Result};
use dobi::runtime::Runtime;

fn main() -> Result<()> {
    let name = std::env::args().nth(1).expect("probe name");
    let rt = Runtime::new()?;
    let exe = rt.compile_hlo(std::path::Path::new(&format!("/tmp/p_{name}.hlo.txt")))?;
    let raw = std::fs::read(format!("/tmp/p_{name}.in"))?;
    let mut i = 0usize;
    let rd_u32 = |raw: &[u8], i: &mut usize| { let v = u32::from_le_bytes(raw[*i..*i+4].try_into().unwrap()); *i += 4; v };
    let n = rd_u32(&raw, &mut i) as usize;
    let mut lits = Vec::new();
    for _ in 0..n {
        let code = raw[i]; let ndim = raw[i+1] as usize; i += 2;
        let mut shape = Vec::new();
        for _ in 0..ndim { shape.push(rd_u32(&raw, &mut i) as usize); }
        let elems: usize = shape.iter().product();
        let nbytes = elems * 4;
        let bytes = &raw[i..i+nbytes]; i += nbytes;
        let ty = if code == 0 { xla::ElementType::F32 } else { xla::ElementType::S32 };
        lits.push(xla::Literal::create_from_shape_and_untyped_data(ty, &shape, bytes)
            .map_err(|e| anyhow!("{e:?}"))?);
    }
    let out = exe.execute::<xla::Literal>(&lits).map_err(|e| anyhow!("{e:?}"))?;
    let vals = out[0][0].to_literal_sync().map_err(|e| anyhow!("{e:?}"))?
        .to_tuple1().map_err(|e| anyhow!("{e:?}"))?
        .to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
    let npy = std::fs::read(format!("/tmp/p_{name}.npy"))?;
    let hlen = u16::from_le_bytes(npy[8..10].try_into().unwrap()) as usize;
    let data = &npy[10 + hlen..];
    let expect: Vec<f32> = data.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect();
    assert_eq!(vals.len(), expect.len(), "len mismatch {} vs {}", vals.len(), expect.len());
    let mut max = 0f32; let mut worst = 0usize;
    for (j, (a, b)) in vals.iter().zip(&expect).enumerate() {
        let d = (a - b).abs();
        if d > max { max = d; worst = j; }
    }
    println!("{name}: max|delta| = {max:.6} at {worst} (rust {} vs py {})", vals[worst], expect[worst]);
    Ok(())
}
