//! `dobi lint` — self-hosted static analysis for the serve stack's
//! cross-cutting invariants.
//!
//! The serving layers (PRs 6-8) are tied together by conventions that no
//! compiler checks: metric family names must agree between code, the README
//! family table, and the smoke test; wire-protocol ops/fields must match the
//! spec table; trace phases must match the exporter's known list; the serve
//! hot path must not panic; nested locks must follow the declared order.
//! This module makes those conventions machine-checked: a comment/string-
//! aware lexer ([`lexer`]) feeds a small rule engine ([`rules`]) whose
//! findings gate CI.
//!
//! Findings are suppressed inline with
//! `// dobi-lint: allow(rule-name, reason)` on the offending line or the
//! line above. A suppression without a reason is itself a deny-level
//! finding — the reason is the reviewable artifact.
//!
//! Severities: `deny` findings fail `dobi lint` (exit 1) and block CI;
//! `warn` findings are advisory (today only the indexing heuristic of
//! `panic-freedom`, which cannot see bounds invariants).

pub mod lexer;
pub mod rules;

use anyhow::{anyhow, Result};
use lexer::{lex, Tok, Token};
use std::path::Path;

/// Finding severity. Only [`Severity::Deny`] affects the exit code.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Severity {
    Warn,
    Deny,
}

impl Severity {
    pub fn as_str(self) -> &'static str {
        match self {
            Severity::Warn => "warn",
            Severity::Deny => "deny",
        }
    }
}

/// One rule violation, anchored to a repo-relative file and 1-based line
/// (line 0 = whole file / artifact missing).
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: &'static str,
    pub severity: Severity,
    pub file: String,
    pub line: u32,
    pub message: String,
}

/// A parsed `// dobi-lint: allow(rule, reason)` comment.
#[derive(Debug, Clone)]
pub struct Suppression {
    pub line: u32,
    pub rule: String,
    pub reason: Option<String>,
}

/// A lexed source file plus the derived facts every rule needs: which lines
/// are `#[cfg(test)]` code, and which suppressions are declared.
#[derive(Debug)]
pub struct SourceFile {
    /// Repo-relative, '/'-separated path (e.g. `rust/src/serve/stream.rs`).
    pub path: String,
    pub text: String,
    /// Full token stream, comments included (suppressions live there).
    pub tokens: Vec<Token>,
    /// Code-only tokens: `tokens` minus comments. Rules match on this.
    pub code: Vec<Token>,
    /// Inclusive line ranges covered by `#[cfg(test)]` items.
    pub test_ranges: Vec<(u32, u32)>,
    pub suppressions: Vec<Suppression>,
}

impl SourceFile {
    pub fn new(path: &str, text: &str) -> SourceFile {
        let tokens = lex(text);
        let code: Vec<Token> = tokens
            .iter()
            .filter(|t| !matches!(t.kind, Tok::LineComment(_) | Tok::BlockComment(_)))
            .cloned()
            .collect();
        let test_ranges = find_test_ranges(&code);
        let suppressions = find_suppressions(&tokens);
        SourceFile { path: path.to_string(), text: text.to_string(), tokens, code, test_ranges, suppressions }
    }

    /// Is `line` inside a `#[cfg(test)]` item?
    pub fn in_test(&self, line: u32) -> bool {
        self.test_ranges.iter().any(|&(a, b)| line >= a && line <= b)
    }
}

/// Everything the rules see. Built from the real tree by [`Context::load`];
/// tests construct synthetic contexts directly from fixture strings.
#[derive(Debug)]
pub struct Context {
    /// All `.rs` files under `rust/src`, paths repo-relative.
    pub files: Vec<SourceFile>,
    /// README.md content (the drift rules parse its spec tables).
    pub readme: String,
}

impl Context {
    /// Load the real repository rooted at `root`.
    pub fn load(root: &Path) -> Result<Context> {
        let src = root.join("rust").join("src");
        let readme_path = root.join("README.md");
        if !src.is_dir() || !readme_path.is_file() {
            return Err(anyhow!(
                "`{}` does not look like the repo root (need rust/src/ and README.md); \
                 run from the checkout root or pass --root DIR",
                root.display()
            ));
        }
        let readme = std::fs::read_to_string(&readme_path)?;
        let mut paths = Vec::new();
        collect_rs(&src, &mut paths)?;
        paths.sort();
        let mut files = Vec::new();
        for p in &paths {
            let text = std::fs::read_to_string(p)?;
            let rel = p
                .strip_prefix(root)
                .unwrap_or(p)
                .components()
                .map(|c| c.as_os_str().to_string_lossy().into_owned())
                .collect::<Vec<_>>()
                .join("/");
            files.push(SourceFile::new(&rel, &text));
        }
        Ok(Context { files, readme })
    }

    /// The unique file whose path ends with `suffix`, if present.
    pub fn file(&self, suffix: &str) -> Option<&SourceFile> {
        self.files.iter().find(|f| f.path.ends_with(suffix))
    }
}

fn collect_rs(dir: &Path, out: &mut Vec<std::path::PathBuf>) -> Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Run all rules (or just `only`) over `ctx`, apply suppressions, and check
/// suppression hygiene. Findings come back sorted by (file, line, rule).
pub fn run(ctx: &Context, only: Option<&str>) -> Result<Vec<Finding>> {
    if let Some(name) = only {
        if !rules::RULES.iter().any(|r| r.name == name) {
            let known: Vec<&str> = rules::RULES.iter().map(|r| r.name).collect();
            return Err(anyhow!("unknown rule `{name}` (known: {})", known.join(", ")));
        }
    }
    let mut raw = Vec::new();
    for rule in rules::RULES {
        if only.map(|n| n == rule.name).unwrap_or(true) {
            raw.extend((rule.run)(ctx));
        }
    }
    let mut kept = Vec::new();
    for f in raw {
        let suppressed = ctx
            .files
            .iter()
            .find(|s| s.path == f.file)
            .map(|s| {
                s.suppressions
                    .iter()
                    .any(|sp| sp.rule == f.rule && (sp.line == f.line || sp.line + 1 == f.line))
            })
            .unwrap_or(false);
        if !suppressed {
            kept.push(f);
        }
    }
    // Suppression hygiene rides along on full runs: a typo'd rule name would
    // silently suppress nothing, and a reasonless allow hides the judgment
    // call a reviewer needs to see.
    if only.is_none() {
        for file in &ctx.files {
            for sp in &file.suppressions {
                if !rules::RULES.iter().any(|r| r.name == sp.rule) {
                    kept.push(Finding {
                        rule: "suppression",
                        severity: Severity::Deny,
                        file: file.path.clone(),
                        line: sp.line,
                        message: format!("allow() names unknown rule `{}`", sp.rule),
                    });
                } else if sp.reason.as_deref().unwrap_or("").is_empty() {
                    kept.push(Finding {
                        rule: "suppression",
                        severity: Severity::Deny,
                        file: file.path.clone(),
                        line: sp.line,
                        message: format!(
                            "allow({}) needs a reason: `// dobi-lint: allow({}, why it is safe)`",
                            sp.rule, sp.rule
                        ),
                    });
                }
            }
        }
    }
    kept.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
    Ok(kept)
}

/// Find `#[cfg(test)]` attributes in the code-token stream and return the
/// line ranges of the items they cover (attribute line through the item's
/// closing brace; braceless items cover just the attribute's lines).
fn find_test_ranges(code: &[Token]) -> Vec<(u32, u32)> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i + 6 < code.len() {
        let is_attr = matches!(code[i].kind, Tok::Punct('#'))
            && matches!(code[i + 1].kind, Tok::Punct('['))
            && matches!(&code[i + 2].kind, Tok::Ident(w) if w == "cfg")
            && matches!(code[i + 3].kind, Tok::Punct('('))
            && matches!(&code[i + 4].kind, Tok::Ident(w) if w == "test")
            && matches!(code[i + 5].kind, Tok::Punct(')'))
            && matches!(code[i + 6].kind, Tok::Punct(']'));
        if !is_attr {
            i += 1;
            continue;
        }
        let start_line = code[i].line;
        // Scan forward for the item body's opening brace; a `;` first means
        // a braceless item (`#[cfg(test)] use …;`).
        let mut j = i + 7;
        let mut open = None;
        while j < code.len() {
            match code[j].kind {
                Tok::Punct('{') => {
                    open = Some(j);
                    break;
                }
                Tok::Punct(';') => break,
                _ => j += 1,
            }
        }
        let end_line = match open {
            Some(o) => match_brace(code, o).map(|c| code[c].line).unwrap_or(u32::MAX),
            None => code.get(j).map(|t| t.line).unwrap_or(start_line),
        };
        out.push((start_line, end_line));
        i = j.max(i + 7);
    }
    out
}

/// Index of the `}` matching the `{` at `open` (both in code tokens).
pub(crate) fn match_brace(code: &[Token], open: usize) -> Option<usize> {
    let mut depth = 0i64;
    for (j, t) in code.iter().enumerate().skip(open) {
        match t.kind {
            Tok::Punct('{') => depth += 1,
            Tok::Punct('}') => {
                depth -= 1;
                if depth == 0 {
                    return Some(j);
                }
            }
            _ => {}
        }
    }
    None
}

fn find_suppressions(tokens: &[Token]) -> Vec<Suppression> {
    let mut out = Vec::new();
    for t in tokens {
        let text = match &t.kind {
            Tok::LineComment(s) => s,
            _ => continue,
        };
        let Some(pos) = text.find("dobi-lint:") else { continue };
        // Only a comment that IS the directive counts: nothing but comment
        // sigils and whitespace may precede the marker. Doc comments that
        // quote the syntax in prose (backticks, words before it) are not
        // suppressions.
        if !text[..pos].chars().all(|c| matches!(c, '/' | '!' | ' ' | '\t')) {
            continue;
        }
        let rest = text[pos + "dobi-lint:".len()..].trim_start();
        let Some(body) = rest.strip_prefix("allow(") else { continue };
        let Some(end) = body.rfind(')') else { continue };
        let inner = &body[..end];
        let (rule, reason) = match inner.split_once(',') {
            Some((r, why)) => (r.trim().to_string(), Some(why.trim().to_string())),
            None => (inner.trim().to_string(), None),
        };
        out.push(Suppression { line: t.line, rule, reason });
    }
    out
}
