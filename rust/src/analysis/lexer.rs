//! Comment/string-aware Rust lexer for the `dobi lint` pass.
//!
//! Hand-rolled with no external deps (the same vendored-offline discipline
//! as `storage/hash.rs`): just enough of the Rust lexical grammar that rules
//! can ask "which identifiers / string literals appear in *code*" without
//! being fooled by comment text, string contents, raw strings
//! (`r#"…"#`), byte strings, nested block comments, or the `'a`
//! lifetime vs `'a'` char-literal ambiguity.
//!
//! Fidelity target: token *kinds* and start lines. Numeric literals are not
//! decoded, multi-char operators surface as single `Punct` chars, and string
//! contents keep their escape sequences unresolved — none of the rules need
//! more.

/// One lexed token kind.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Tok {
    /// Identifier or keyword.
    Ident(String),
    /// Lifetime such as `'a` or `'static` (text without the quote).
    Lifetime(String),
    /// String literal content (cooked, raw, byte, or raw-byte), without
    /// delimiters; escape sequences are left unresolved.
    Str(String),
    /// Char or byte-char literal (`'a'`, `b'\n'`); content is not kept.
    CharLit,
    /// Numeric literal; value is not kept.
    Num,
    /// Line comment text (without the leading `//`).
    LineComment(String),
    /// Block comment text (without delimiters), nesting already balanced.
    BlockComment(String),
    /// Any other single character.
    Punct(char),
}

/// A token plus the 1-based line its first character sits on.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Token {
    pub kind: Tok,
    pub line: u32,
}

/// Lex `src` into a token stream. Never fails: unterminated constructs
/// extend to end-of-file, unknown bytes become `Punct`.
pub fn lex(src: &str) -> Vec<Token> {
    let mut lx = Lexer { s: src.as_bytes(), i: 0, line: 1, out: Vec::new() };
    lx.run();
    lx.out
}

fn is_ident_start(c: u8) -> bool {
    c.is_ascii_alphabetic() || c == b'_'
}

fn is_ident_cont(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

struct Lexer<'a> {
    s: &'a [u8],
    i: usize,
    line: u32,
    out: Vec<Token>,
}

impl Lexer<'_> {
    fn peek(&self, k: usize) -> u8 {
        self.s.get(self.i + k).copied().unwrap_or(0)
    }

    fn bump(&mut self) {
        if self.peek(0) == b'\n' {
            self.line += 1;
        }
        self.i += 1;
    }

    fn slice(&self, from: usize, to: usize) -> String {
        String::from_utf8_lossy(&self.s[from..to]).into_owned()
    }

    fn run(&mut self) {
        while self.i < self.s.len() {
            let c = self.peek(0);
            match c {
                b' ' | b'\t' | b'\r' | b'\n' => self.bump(),
                b'/' if self.peek(1) == b'/' => self.line_comment(),
                b'/' if self.peek(1) == b'*' => self.block_comment(),
                b'"' => self.cooked_str(),
                b'\'' => self.quote(),
                b'0'..=b'9' => self.number(),
                c if is_ident_start(c) => self.ident_like(),
                _ => {
                    let line = self.line;
                    self.bump();
                    self.out.push(Token { kind: Tok::Punct(c as char), line });
                }
            }
        }
    }

    fn line_comment(&mut self) {
        let line = self.line;
        self.i += 2;
        let start = self.i;
        while self.i < self.s.len() && self.peek(0) != b'\n' {
            self.i += 1;
        }
        let text = self.slice(start, self.i);
        self.out.push(Token { kind: Tok::LineComment(text), line });
    }

    /// Block comment with Rust's nesting: `/* outer /* inner */ still out */`.
    fn block_comment(&mut self) {
        let line = self.line;
        self.bump();
        self.bump();
        let start = self.i;
        let mut depth = 1usize;
        let mut end = self.s.len();
        while self.i < self.s.len() {
            if self.peek(0) == b'/' && self.peek(1) == b'*' {
                depth += 1;
                self.bump();
                self.bump();
            } else if self.peek(0) == b'*' && self.peek(1) == b'/' {
                depth -= 1;
                if depth == 0 {
                    end = self.i;
                    self.bump();
                    self.bump();
                    break;
                }
                self.bump();
                self.bump();
            } else {
                self.bump();
            }
        }
        let text = self.slice(start, end.min(self.i).max(start));
        self.out.push(Token { kind: Tok::BlockComment(text), line });
    }

    /// `"…"` with `\"` / `\\` escapes. Also entered (past the `b`) for `b"…"`.
    fn cooked_str(&mut self) {
        let line = self.line;
        self.bump(); // opening quote
        let start = self.i;
        while self.i < self.s.len() {
            match self.peek(0) {
                b'\\' => {
                    self.bump();
                    self.bump();
                }
                b'"' => break,
                _ => self.bump(),
            }
        }
        let text = self.slice(start, self.i);
        self.bump(); // closing quote
        self.out.push(Token { kind: Tok::Str(text), line });
    }

    /// `'` starts either a lifetime (`'a`, `'static`, `'_`) or a char
    /// literal (`'a'`, `'\n'`, `'('`). Disambiguation: an identifier run
    /// directly followed by a closing `'` is a char literal, otherwise a
    /// lifetime; a leading backslash or non-identifier char is always a
    /// char literal.
    fn quote(&mut self) {
        let line = self.line;
        self.bump(); // the quote
        if self.peek(0) == b'\\' {
            self.bump(); // backslash
            if self.peek(0) == b'u' {
                while self.i < self.s.len() && self.peek(0) != b'\'' {
                    self.bump();
                }
            } else {
                self.bump(); // the escaped char
            }
            self.bump(); // closing quote
            self.out.push(Token { kind: Tok::CharLit, line });
            return;
        }
        if is_ident_start(self.peek(0)) {
            let start = self.i;
            while is_ident_cont(self.peek(0)) {
                self.bump();
            }
            if self.peek(0) == b'\'' {
                self.bump();
                self.out.push(Token { kind: Tok::CharLit, line });
            } else {
                let text = self.slice(start, self.i);
                self.out.push(Token { kind: Tok::Lifetime(text), line });
            }
            return;
        }
        // Punctuation/digit char literal: consume to the closing quote.
        while self.i < self.s.len() && self.peek(0) != b'\'' {
            self.bump();
        }
        self.bump();
        self.out.push(Token { kind: Tok::CharLit, line });
    }

    fn number(&mut self) {
        let line = self.line;
        while is_ident_cont(self.peek(0)) {
            self.i += 1;
        }
        // Fractional part — but not `..` range syntax (`0..n`).
        if self.peek(0) == b'.' && self.peek(1).is_ascii_digit() {
            self.i += 1;
            while is_ident_cont(self.peek(0)) {
                self.i += 1;
            }
        }
        self.out.push(Token { kind: Tok::Num, line });
    }

    /// Identifier, or one of the string prefixes `r" r#" b" b' br" br#"`.
    fn ident_like(&mut self) {
        let line = self.line;
        if self.peek(0) == b'r' && (self.peek(1) == b'"' || self.peek(1) == b'#') {
            if self.try_raw_string(1, line) {
                return;
            }
        }
        if self.peek(0) == b'b' {
            match self.peek(1) {
                b'"' => {
                    self.bump(); // the b
                    self.cooked_str();
                    return;
                }
                b'\'' => {
                    self.bump();
                    self.quote();
                    return;
                }
                b'r' if self.peek(2) == b'"' || self.peek(2) == b'#' => {
                    if self.try_raw_string(2, line) {
                        return;
                    }
                }
                _ => {}
            }
        }
        let start = self.i;
        while is_ident_cont(self.peek(0)) {
            self.bump();
        }
        let text = self.slice(start, self.i);
        self.out.push(Token { kind: Tok::Ident(text), line });
    }

    /// Attempt `r##"…"##` (or `br…`) with `prefix` chars before the hashes.
    /// Returns false without consuming anything for raw *identifiers*
    /// (`r#match`), which then lex as ident-ish tokens.
    fn try_raw_string(&mut self, prefix: usize, line: u32) -> bool {
        let mut j = self.i + prefix;
        let mut hashes = 0usize;
        while self.s.get(j).copied() == Some(b'#') {
            hashes += 1;
            j += 1;
        }
        if self.s.get(j).copied() != Some(b'"') {
            return false; // raw identifier or lone `r#`
        }
        for _ in 0..prefix + hashes + 1 {
            self.bump();
        }
        let start = self.i;
        loop {
            if self.i >= self.s.len() {
                self.out.push(Token { kind: Tok::Str(self.slice(start, self.i)), line });
                return true;
            }
            if self.peek(0) == b'"' {
                let mut k = 1usize;
                while k <= hashes && self.peek(k) == b'#' {
                    k += 1;
                }
                if k == hashes + 1 {
                    let text = self.slice(start, self.i);
                    for _ in 0..hashes + 1 {
                        self.bump();
                    }
                    self.out.push(Token { kind: Tok::Str(text), line });
                    return true;
                }
            }
            self.bump();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<Tok> {
        lex(src).into_iter().map(|t| t.kind).collect()
    }

    fn strs(src: &str) -> Vec<String> {
        lex(src)
            .into_iter()
            .filter_map(|t| match t.kind {
                Tok::Str(s) => Some(s),
                _ => None,
            })
            .collect()
    }

    #[test]
    fn idents_and_punct() {
        assert_eq!(
            kinds("fn f(x: u8) {}"),
            vec![
                Tok::Ident("fn".into()),
                Tok::Ident("f".into()),
                Tok::Punct('('),
                Tok::Ident("x".into()),
                Tok::Punct(':'),
                Tok::Ident("u8".into()),
                Tok::Punct(')'),
                Tok::Punct('{'),
                Tok::Punct('}'),
            ]
        );
    }

    #[test]
    fn raw_strings_with_hashes() {
        assert_eq!(strs(r####"let s = r#"inner "quoted" text"#;"####),
                   vec![r#"inner "quoted" text"#.to_string()]);
        // Hash-count must match exactly: `"#` inside a `##` string is content.
        assert_eq!(strs("r##\"has \"# inside\"##"), vec!["has \"# inside".to_string()]);
        assert_eq!(strs("r\"plain raw\""), vec!["plain raw".to_string()]);
    }

    #[test]
    fn byte_strings_and_byte_chars() {
        assert_eq!(strs(r##"let b = b"bytes"; let r = br#"raw bytes"#;"##),
                   vec!["bytes".to_string(), "raw bytes".to_string()]);
        let k = kinds(r"let c = b'\n';");
        assert!(k.contains(&Tok::CharLit), "{k:?}");
    }

    #[test]
    fn nested_block_comments() {
        let k = kinds("a /* outer /* inner */ still comment */ b");
        assert_eq!(
            k,
            vec![
                Tok::Ident("a".into()),
                Tok::BlockComment(" outer /* inner */ still comment ".into()),
                Tok::Ident("b".into()),
            ]
        );
    }

    #[test]
    fn lifetime_vs_char_literal() {
        // `'a` in a generic position is a lifetime; `'a'` is a char.
        let k = kinds("fn f<'a>(x: &'a str) -> char { 'a' }");
        assert!(k.contains(&Tok::Lifetime("a".into())), "{k:?}");
        assert_eq!(k.iter().filter(|t| **t == Tok::CharLit).count(), 1);
        assert!(kinds("&'static str").contains(&Tok::Lifetime("static".into())));
        // Escaped quote and unicode escapes stay single char literals.
        assert_eq!(kinds(r"'\''"), vec![Tok::CharLit]);
        assert_eq!(kinds(r"'\u{1F600}'"), vec![Tok::CharLit]);
    }

    #[test]
    fn strings_hide_code_and_comments_hide_strings() {
        // A `.unwrap()` spelled inside a string must not surface as idents.
        let k = kinds(r#"let msg = "call .unwrap() here";"#);
        assert!(!k.contains(&Tok::Ident("unwrap".into())), "{k:?}");
        // A quote inside a comment must not open a string.
        let k = kinds("// it's \"quoted\"\nnext");
        assert_eq!(k.last(), Some(&Tok::Ident("next".into())));
    }

    #[test]
    fn line_numbers() {
        let toks = lex("a\nb\n\nc");
        let lines: Vec<(String, u32)> = toks
            .into_iter()
            .filter_map(|t| match t.kind {
                Tok::Ident(s) => Some((s, t.line)),
                _ => None,
            })
            .collect();
        assert_eq!(lines, vec![("a".into(), 1), ("b".into(), 2), ("c".into(), 4)]);
    }

    #[test]
    fn numbers_do_not_swallow_ranges() {
        let k = kinds("for i in 0..n {}");
        assert!(k.contains(&Tok::Ident("n".into())), "{k:?}");
        assert_eq!(k.iter().filter(|t| matches!(t, Tok::Num)).count(), 1);
        assert_eq!(kinds("1.5e-3"), vec![Tok::Num, Tok::Punct('-'), Tok::Num]);
    }
}
