//! The `dobi lint` rule set.
//!
//! Each rule is a pure function `fn(&Context) -> Vec<Finding>` over lexed
//! sources plus the README — rules that enforce cross-artifact agreement
//! (code ↔ constants module ↔ README spec tables) parse both sides and
//! report any asymmetric difference. Policy that cannot be derived from
//! the tree (the lock partial order, the CLI flag → config-field map) is
//! declared here as data, where a reviewer can see and amend it.

use super::lexer::{Tok, Token};
use super::{match_brace, Context, Finding, Severity, SourceFile};
use std::collections::{BTreeMap, BTreeSet};

/// A registered rule: name (used by `--rule` and `allow(...)`), a one-line
/// summary for docs/help, and the implementation.
pub struct Rule {
    pub name: &'static str,
    pub summary: &'static str,
    pub run: fn(&Context) -> Vec<Finding>,
}

pub const RULES: &[Rule] = &[
    Rule {
        name: "panic-freedom",
        summary: "no unwrap/expect/panic-class macros on the serve request paths \
                  (serve/, server/, trace/, metrics/); indexing is a warn-level heuristic",
        run: panic_freedom,
    },
    Rule {
        name: "lock-order",
        summary: "nested lock acquisitions follow the declared partial order \
                  registry -> metrics -> trace",
        run: lock_order,
    },
    Rule {
        name: "metric-drift",
        summary: "serve_*/compress_* family names agree across metrics::names, code, \
                  and the README family table",
        run: metric_drift,
    },
    Rule {
        name: "protocol-drift",
        summary: "wire-protocol ops and fields agree across stream.rs declarations, \
                  parse code, and the README protocol v1 table",
        run: protocol_drift,
    },
    Rule {
        name: "flag-drift",
        summary: "serve/compress CLI flags map to ServeConfig/CompressConfig/EngineConfig \
                  fields and are mentioned in the README",
        run: flag_drift,
    },
    Rule {
        name: "trace-phase-pairing",
        summary: "trace phases (serve and compress_* lifecycles) agree across \
                  trace::phases, record sites, the exporter's known-phase list, and \
                  the README Observability table",
        run: trace_phases,
    },
];

fn finding(rule: &'static str, severity: Severity, file: &str, line: u32, message: String) -> Finding {
    Finding { rule, severity, file: file.to_string(), line, message }
}

fn deny(rule: &'static str, file: &str, line: u32, message: String) -> Finding {
    finding(rule, Severity::Deny, file, line, message)
}

fn warn(rule: &'static str, file: &str, line: u32, message: String) -> Finding {
    finding(rule, Severity::Warn, file, line, message)
}

// ---------------------------------------------------------------------------
// Shared token-walking helpers

pub(crate) struct FnSpan {
    pub name: String,
    /// Code-token indices of the body's `{` and matching `}`.
    pub body: (usize, usize),
}

/// Every `fn name … { … }` in the code-token stream (bodies by brace match;
/// signature `;`/`[]`/`()` nesting respected, so `fn f(x: [u8; 4])` works).
pub(crate) fn fn_spans(code: &[Token]) -> Vec<FnSpan> {
    let mut out = Vec::new();
    for i in 0..code.len() {
        if !matches!(&code[i].kind, Tok::Ident(w) if w == "fn") {
            continue;
        }
        let Some(Tok::Ident(name)) = code.get(i + 1).map(|t| &t.kind) else { continue };
        let mut j = i + 2;
        let mut depth = 0i64;
        let mut open = None;
        while j < code.len() {
            match code[j].kind {
                Tok::Punct('(') | Tok::Punct('[') => depth += 1,
                Tok::Punct(')') | Tok::Punct(']') => depth -= 1,
                Tok::Punct('{') if depth == 0 => {
                    open = Some(j);
                    break;
                }
                Tok::Punct(';') if depth == 0 => break,
                _ => {}
            }
            j += 1;
        }
        if let Some(o) = open {
            if let Some(c) = match_brace(code, o) {
                out.push(FnSpan { name: name.clone(), body: (o, c) });
            }
        }
    }
    out
}

/// `const NAME: &str = "value";` declarations as (name, value, line).
fn str_consts(code: &[Token]) -> Vec<(String, String, u32)> {
    let mut out = Vec::new();
    for i in 0..code.len() {
        if !matches!(&code[i].kind, Tok::Ident(w) if w == "const") {
            continue;
        }
        let Some(Tok::Ident(name)) = code.get(i + 1).map(|t| &t.kind) else { continue };
        let shape_ok = matches!(code.get(i + 2).map(|t| &t.kind), Some(Tok::Punct(':')))
            && matches!(code.get(i + 3).map(|t| &t.kind), Some(Tok::Punct('&')))
            && matches!(code.get(i + 4).map(|t| &t.kind), Some(Tok::Ident(w)) if w == "str")
            && matches!(code.get(i + 5).map(|t| &t.kind), Some(Tok::Punct('=')));
        if !shape_ok {
            continue;
        }
        if let Some(Tok::Str(v)) = code.get(i + 6).map(|t| &t.kind) {
            out.push((name.clone(), v.clone(), code[i].line));
        }
    }
    out
}

/// The string elements of `const NAME: &[&str] = &["a", "b", …];`.
fn str_array_const(code: &[Token], name: &str) -> Option<Vec<String>> {
    let i = code.iter().position(|t| matches!(&t.kind, Tok::Ident(w) if w == name))?;
    let eq = (i..code.len()).find(|&j| matches!(code[j].kind, Tok::Punct('=')))?;
    let open = (eq..code.len()).find(|&j| matches!(code[j].kind, Tok::Punct('[')))?;
    let mut out = Vec::new();
    for t in &code[open + 1..] {
        match &t.kind {
            Tok::Str(s) => out.push(s.clone()),
            Tok::Punct(']') => return Some(out),
            _ => {}
        }
    }
    Some(out)
}

/// The identifier elements of `const NAME: &[&str] = &[A, B, …];`.
fn ident_array_const(code: &[Token], name: &str) -> Option<Vec<String>> {
    let i = code.iter().position(|t| matches!(&t.kind, Tok::Ident(w) if w == name))?;
    let eq = (i..code.len()).find(|&j| matches!(code[j].kind, Tok::Punct('=')))?;
    let open = (eq..code.len()).find(|&j| matches!(code[j].kind, Tok::Punct('[')))?;
    let mut out = Vec::new();
    for t in &code[open + 1..] {
        match &t.kind {
            Tok::Ident(s) => out.push(s.clone()),
            Tok::Punct(']') => return Some(out),
            _ => {}
        }
    }
    Some(out)
}

/// README section starting at the line that begins with `heading`, ending
/// before the next `## `/`### ` heading. Returns (1-based start line, text).
fn section<'a>(readme: &'a str, heading: &str) -> Option<(u32, &'a str)> {
    let mut start_line = 0u32;
    let mut start_byte = None;
    let mut byte = 0usize;
    for (idx, line) in readme.lines().enumerate() {
        if start_byte.is_none() {
            if line.starts_with(heading) {
                start_line = idx as u32 + 1;
                start_byte = Some(byte);
            }
        } else if line.starts_with("## ") || line.starts_with("### ") {
            return Some((start_line, &readme[start_byte.unwrap_or(0)..byte]));
        }
        byte += line.len() + 1;
    }
    start_byte.map(|b| (start_line, &readme[b..]))
}

/// 1-based README line of the first occurrence of `needle` inside a section
/// that starts at `sec_line`.
fn line_in(sec: &str, sec_line: u32, needle: &str) -> u32 {
    for (idx, line) in sec.lines().enumerate() {
        if line.contains(needle) {
            return sec_line + idx as u32;
        }
    }
    sec_line
}

/// Words between backticks on one line, filtered to `[a-z_]+`.
fn backtick_words(line: &str) -> Vec<String> {
    line.split('`')
        .skip(1)
        .step_by(2)
        .filter(|w| !w.is_empty() && w.bytes().all(|c| c.is_ascii_lowercase() || c == b'_'))
        .map(|w| w.to_string())
        .collect()
}

/// Markdown table rows of a section (lines starting with `|`, separator rows
/// skipped) as (line-offset-within-section, line text).
fn table_rows(sec: &str) -> Vec<(u32, &str)> {
    sec.lines()
        .enumerate()
        .filter(|(_, l)| l.trim_start().starts_with('|') && !l.contains("---"))
        .map(|(i, l)| (i as u32, l))
        .collect()
}

/// The namespaces metric families live in: the serve request path and the
/// compression pipeline.
const FAMILY_PREFIXES: &[&str] = &["serve_", "compress_"];

/// Is `s` a metric family name (`serve_`/`compress_` plus a nonempty
/// lowercase tail)?
fn is_family(s: &str) -> bool {
    FAMILY_PREFIXES.iter().any(|p| match s.strip_prefix(p) {
        Some(rest) => {
            !rest.is_empty() && rest.bytes().all(|c| c.is_ascii_lowercase() || c == b'_')
        }
        None => false,
    })
}

/// All metric family names appearing anywhere in `text`.
fn families_in(text: &str) -> BTreeSet<String> {
    let b = text.as_bytes();
    let mut out = BTreeSet::new();
    let ident = |c: u8| c.is_ascii_alphanumeric() || c == b'_';
    for prefix in FAMILY_PREFIXES {
        let p = prefix.as_bytes();
        let n = p.len();
        let mut i = 0usize;
        while i + n <= b.len() {
            if &b[i..i + n] == p && (i == 0 || !ident(b[i - 1])) {
                let mut j = i + n;
                while j < b.len() && (b[j].is_ascii_lowercase() || b[j] == b'_') {
                    j += 1;
                }
                if j > i + n {
                    out.insert(String::from_utf8_lossy(&b[i..j]).into_owned());
                }
                i = j;
            } else {
                i += 1;
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Rule: panic-freedom

/// Directories whose non-test code is the serve request path.
const PANIC_DIRS: &[&str] = &["serve/", "server/", "trace/", "metrics/"];
/// Compute-kernel files where indexing *is* the idiom (bounds are shape
/// invariants pinned by parity tests); the indexing heuristic skips them.
const INDEX_EXEMPT: &[&str] = &["serve/session.rs", "serve/spec.rs"];

fn in_dirs(path: &str, dirs: &[&str]) -> bool {
    dirs.iter().any(|d| {
        path.strip_prefix("rust/src/").map(|p| p.starts_with(d)).unwrap_or(false)
    })
}

fn panic_freedom(ctx: &Context) -> Vec<Finding> {
    let mut out = Vec::new();
    for f in ctx.files.iter().filter(|f| in_dirs(&f.path, PANIC_DIRS)) {
        let code = &f.code;
        let index_exempt = INDEX_EXEMPT.iter().any(|e| f.path.ends_with(e));
        for i in 0..code.len() {
            if f.in_test(code[i].line) {
                continue;
            }
            if matches!(code[i].kind, Tok::Punct('.')) {
                if let Some(Tok::Ident(m)) = code.get(i + 1).map(|t| &t.kind) {
                    if (m == "unwrap" || m == "expect")
                        && matches!(code.get(i + 2).map(|t| &t.kind), Some(Tok::Punct('(')))
                    {
                        out.push(deny(
                            "panic-freedom",
                            &f.path,
                            code[i + 1].line,
                            format!(
                                "`.{m}()` on the serve request path — a poisoned lock or \
                                 unexpected None here kills the scheduler; handle the \
                                 failure (e.g. `lock_or_recover`, `unwrap_or`, `let-else`)"
                            ),
                        ));
                    }
                }
            }
            if let Tok::Ident(mac) = &code[i].kind {
                if matches!(mac.as_str(), "panic" | "unreachable" | "todo" | "unimplemented")
                    && matches!(code.get(i + 1).map(|t| &t.kind), Some(Tok::Punct('!')))
                {
                    out.push(deny(
                        "panic-freedom",
                        &f.path,
                        code[i].line,
                        format!("`{mac}!` on the serve request path — return a typed error instead"),
                    ));
                }
                // Heuristic: `ident[` is indexing; the lexer cannot prove a
                // bounds invariant, so this is warn-level only.
                if !index_exempt
                    && matches!(code.get(i + 1).map(|t| &t.kind), Some(Tok::Punct('[')))
                {
                    out.push(warn(
                        "panic-freedom",
                        &f.path,
                        code[i].line,
                        format!("indexing `{mac}[…]` can panic — prefer `.get()` when the bound is not a local invariant"),
                    ));
                }
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Rule: lock-order

/// The declared lock partial order. A lock later in this list may be taken
/// while holding an earlier one, never the reverse. Receivers are classified
/// by the identifiers in the receiver expression.
const LOCK_CLASSES: &[(&str, &[&str])] = &[
    ("registry", &["registry", "reg"]),
    ("metrics", &["metrics", "counters", "gauges", "histograms", "res"]),
    ("trace", &["trace", "slot", "slots"]),
];

struct LockSite {
    class: usize,
    line: u32,
    recv: String,
}

fn classify_recv(names: &[String]) -> Option<usize> {
    for n in names {
        for (idx, (_, pats)) in LOCK_CLASSES.iter().enumerate() {
            if pats.iter().any(|p| n == p) {
                return Some(idx);
            }
        }
    }
    None
}

/// Classified lock acquisitions (`recv.lock()` or `lock_or_recover(&recv)`)
/// inside one fn body, in source order.
fn lock_sites(code: &[Token], body: (usize, usize)) -> Vec<LockSite> {
    let (a, b) = body;
    let mut out = Vec::new();
    let mut j = a;
    while j <= b {
        if matches!(code[j].kind, Tok::Punct('.'))
            && matches!(code.get(j + 1).map(|t| &t.kind), Some(Tok::Ident(w)) if w == "lock")
            && matches!(code.get(j + 2).map(|t| &t.kind), Some(Tok::Punct('(')))
        {
            let mut names = Vec::new();
            let mut k = j;
            while k > a && names.len() < 4 {
                k -= 1;
                match &code[k].kind {
                    Tok::Ident(w) => names.push(w.clone()),
                    Tok::Punct('.') | Tok::Punct('(') | Tok::Punct(')')
                    | Tok::Punct('[') | Tok::Punct(']') => {}
                    _ => break,
                }
            }
            if let Some(class) = classify_recv(&names) {
                let recv = names.first().cloned().unwrap_or_default();
                out.push(LockSite { class, line: code[j].line, recv });
            }
            j += 3;
            continue;
        }
        if matches!(&code[j].kind, Tok::Ident(w) if w == "lock_or_recover")
            && matches!(code.get(j + 1).map(|t| &t.kind), Some(Tok::Punct('(')))
        {
            let mut names = Vec::new();
            let mut k = j + 2;
            let mut depth = 1i64;
            while k <= b && depth > 0 && names.len() < 6 {
                match &code[k].kind {
                    Tok::Punct('(') => depth += 1,
                    Tok::Punct(')') => depth -= 1,
                    Tok::Ident(w) => names.push(w.clone()),
                    _ => {}
                }
                k += 1;
            }
            names.reverse(); // innermost-last first, mirroring the backward walk
            if let Some(class) = classify_recv(&names) {
                let recv = names.first().cloned().unwrap_or_default();
                out.push(LockSite { class, line: code[j].line, recv });
            }
        }
        j += 1;
    }
    out
}

fn lock_order(ctx: &Context) -> Vec<Finding> {
    let mut out = Vec::new();
    for f in &ctx.files {
        for span in fn_spans(&f.code) {
            if f.in_test(f.code[span.body.0].line) {
                continue;
            }
            let sites = lock_sites(&f.code, span.body);
            let mut deepest: Option<&LockSite> = None;
            for s in &sites {
                if let Some(prev) = deepest {
                    if s.class < prev.class {
                        out.push(deny(
                            "lock-order",
                            &f.path,
                            s.line,
                            format!(
                                "`{}` ({}) acquired after `{}` ({}) in `fn {}` — the declared \
                                 order is registry -> metrics -> trace",
                                s.recv,
                                LOCK_CLASSES[s.class].0,
                                prev.recv,
                                LOCK_CLASSES[prev.class].0,
                                span.name
                            ),
                        ));
                    }
                }
                if deepest.map(|p| s.class > p.class).unwrap_or(true) {
                    deepest = Some(s);
                }
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Rule: metric-drift

const NAMES_RS: &str = "metrics/names.rs";
const METRICS_HEADING: &str = "### Labeled metrics";

fn metric_drift(ctx: &Context) -> Vec<Finding> {
    let mut out = Vec::new();
    let Some(nf) = ctx.file(NAMES_RS) else {
        out.push(deny(
            "metric-drift",
            "rust/src/metrics/names.rs",
            0,
            "metrics::names module missing — metric families need one constants module".into(),
        ));
        return out;
    };
    let consts = str_consts(&nf.code);
    let const_vals: BTreeSet<&str> = consts.iter().map(|(_, v, _)| v.as_str()).collect();
    let Some((sec_line, sec)) = section(&ctx.readme, METRICS_HEADING) else {
        out.push(deny(
            "metric-drift",
            "README.md",
            0,
            format!("README `{METRICS_HEADING}` section missing"),
        ));
        return out;
    };
    let readme_fams = families_in(sec);
    for (name, val, line) in &consts {
        if !readme_fams.contains(val) {
            out.push(deny(
                "metric-drift",
                &nf.path,
                *line,
                format!("family `{val}` (const {name}) is undocumented in the README family table"),
            ));
        }
    }
    for fam in &readme_fams {
        if !const_vals.contains(fam.as_str()) {
            out.push(deny(
                "metric-drift",
                "README.md",
                line_in(sec, sec_line, fam),
                format!("README documents family `{fam}` but metrics::names has no such constant"),
            ));
        }
    }
    for f in &ctx.files {
        // trace::phases declares `compress_*` phase names as string consts;
        // those are phase values (trace-phase-pairing's jurisdiction), not
        // bare metric-family literals.
        if f.path.ends_with(NAMES_RS) || f.path.ends_with(PHASES_RS) {
            continue;
        }
        for t in &f.code {
            if let Tok::Str(s) = &t.kind {
                if is_family(s) && !f.in_test(t.line) {
                    out.push(deny(
                        "metric-drift",
                        &f.path,
                        t.line,
                        format!("metric family literal `\"{s}\"` — reference `metrics::names` instead"),
                    ));
                }
            }
        }
    }
    for (name, _, line) in &consts {
        let used = ctx
            .files
            .iter()
            .filter(|f| !f.path.ends_with(NAMES_RS))
            .any(|f| f.code.iter().any(|t| matches!(&t.kind, Tok::Ident(w) if w == name)));
        if !used {
            out.push(deny(
                "metric-drift",
                &nf.path,
                *line,
                format!("metric constant {name} is never referenced outside metrics::names"),
            ));
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Rule: protocol-drift

const PROTOCOL_HEADING: &str = "### Wire protocol (v1)";

fn protocol_drift(ctx: &Context) -> Vec<Finding> {
    let mut out = Vec::new();
    let Some(sf) = ctx.file("serve/stream.rs") else {
        out.push(deny(
            "protocol-drift",
            "rust/src/serve/stream.rs",
            0,
            "serve/stream.rs missing".into(),
        ));
        return out;
    };
    let ops = str_array_const(&sf.code, "PROTOCOL_OPS");
    let fields = str_array_const(&sf.code, "PROTOCOL_FIELDS");
    let (Some(ops), Some(fields)) = (ops, fields) else {
        out.push(deny(
            "protocol-drift",
            &sf.path,
            0,
            "stream.rs must declare PROTOCOL_OPS and PROTOCOL_FIELDS (the v1 vocabulary)".into(),
        ));
        return out;
    };
    // The declared vocabulary must actually be parsed: every op/field string
    // appears in some fn body (parse_request or its typed-field helpers).
    let mut body_lits = BTreeSet::new();
    for span in fn_spans(&sf.code) {
        for t in &sf.code[span.body.0..=span.body.1] {
            if let Tok::Str(s) = &t.kind {
                if !sf.in_test(t.line) {
                    body_lits.insert(s.clone());
                }
            }
        }
    }
    for op in &ops {
        if !body_lits.contains(op) {
            out.push(deny(
                "protocol-drift",
                &sf.path,
                0,
                format!("declared op `{op}` never appears in stream.rs parse code"),
            ));
        }
    }
    for fd in &fields {
        if !body_lits.contains(fd) {
            out.push(deny(
                "protocol-drift",
                &sf.path,
                0,
                format!("declared field `{fd}` never appears in stream.rs parse code"),
            ));
        }
    }
    let Some((sec_line, sec)) = section(&ctx.readme, PROTOCOL_HEADING) else {
        out.push(deny(
            "protocol-drift",
            "README.md",
            0,
            format!("README `{PROTOCOL_HEADING}` section missing"),
        ));
        return out;
    };
    let mut readme_ops: BTreeMap<String, u32> = BTreeMap::new();
    let mut readme_fields: BTreeMap<String, u32> = BTreeMap::new();
    for (off, row) in table_rows(sec) {
        let words = backtick_words(row);
        if let Some((first, rest)) = words.split_first() {
            readme_ops.entry(first.clone()).or_insert(sec_line + off);
            for w in rest {
                readme_fields.entry(w.clone()).or_insert(sec_line + off);
            }
        }
    }
    if readme_ops.is_empty() {
        out.push(deny(
            "protocol-drift",
            "README.md",
            sec_line,
            "README protocol section has no spec table (rows `| op | fields |`)".into(),
        ));
        return out;
    }
    for op in &ops {
        if !readme_ops.contains_key(op) {
            out.push(deny(
                "protocol-drift",
                &sf.path,
                0,
                format!("op `{op}` is parsed but missing from the README protocol table"),
            ));
        }
    }
    for (op, line) in &readme_ops {
        if !ops.contains(op) {
            out.push(deny(
                "protocol-drift",
                "README.md",
                *line,
                format!("README protocol table lists op `{op}` that stream.rs does not declare"),
            ));
        }
    }
    for fd in &fields {
        if !readme_fields.contains_key(fd) {
            out.push(deny(
                "protocol-drift",
                &sf.path,
                0,
                format!("field `{fd}` is parsed but missing from the README protocol table"),
            ));
        }
    }
    for (fd, line) in &readme_fields {
        if !fields.contains(fd) {
            out.push(deny(
                "protocol-drift",
                "README.md",
                *line,
                format!("README protocol table lists field `{fd}` that stream.rs does not declare"),
            ));
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Rule: flag-drift

/// CLI flag → config struct field. Derivable spellings still appear here so
/// the mapping is reviewable in one place.
const FLAG_MAP: &[(&str, &str)] = &[
    ("max-batch", "max_batch"),
    ("deadline-us", "batch_deadline_us"),
    ("queue-depth", "queue_depth"),
    ("max-sessions", "max_sessions"),
    ("decode-threads", "decode_threads"),
    ("spec-draft", "spec_draft"),
    ("spec-k", "spec_k"),
    ("trace-buffer", "trace_buffer"),
    ("ratio", "ratio"),
    ("budget", "budget"),
    ("precision", "precision"),
    ("calib-batches", "calib_batches"),
    ("calib-batch", "calib_batch"),
    ("calib-seq", "calib_seq"),
    ("seed", "seed"),
    ("k-min", "k_min"),
    ("alloc", "alloc"),
    ("train-iters", "train_iters"),
    ("train-lr", "train_lr"),
    ("svd-threads", "svd_threads"),
];

/// Flags that configure infrastructure rather than a config-struct field
/// (addresses, paths, mode switches). Still require a README mention.
const FLAG_INFRA: &[&str] = &[
    "artifacts", "variants", "port", "backend", "stream", "no-stream", "no-control",
    "out", "append", "replace", "calib", "variant", "synth", "trace-out", "progress",
];

const FLAG_ACCESSORS: &[&str] = &["get", "get_or", "usize_or", "f64_or", "has"];

fn flag_drift(ctx: &Context) -> Vec<Finding> {
    let mut out = Vec::new();
    let Some(mf) = ctx.file("src/main.rs") else {
        out.push(deny("flag-drift", "rust/src/main.rs", 0, "main.rs missing".into()));
        return out;
    };
    let config_idents: BTreeSet<String> = match ctx.file("config/mod.rs") {
        Some(cf) => cf
            .code
            .iter()
            .filter_map(|t| match &t.kind {
                Tok::Ident(w) => Some(w.clone()),
                _ => None,
            })
            .collect(),
        None => {
            out.push(deny("flag-drift", "rust/src/config/mod.rs", 0, "config/mod.rs missing".into()));
            return out;
        }
    };
    // Flags read inside `fn serve` / `fn compress` via the Args accessors.
    let mut flags: BTreeMap<String, u32> = BTreeMap::new();
    let code = &mf.code;
    for span in fn_spans(code)
        .into_iter()
        .filter(|s| s.name == "serve" || s.name == "compress")
    {
        let (a, b) = span.body;
        for j in a..=b {
            if matches!(code[j].kind, Tok::Punct('.'))
                && matches!(code.get(j + 1).map(|t| &t.kind),
                            Some(Tok::Ident(w)) if FLAG_ACCESSORS.contains(&w.as_str()))
                && matches!(code.get(j + 2).map(|t| &t.kind), Some(Tok::Punct('(')))
            {
                if let Some(Tok::Str(s)) = code.get(j + 3).map(|t| &t.kind) {
                    flags.entry(s.clone()).or_insert(code[j + 3].line);
                }
            }
        }
    }
    let mentioned = readme_flags(&ctx.readme);
    for (flag, line) in &flags {
        if !mentioned.contains(flag) {
            out.push(deny(
                "flag-drift",
                &mf.path,
                *line,
                format!("`--{flag}` is read by serve/compress but never mentioned in README.md"),
            ));
        }
        if let Some((_, field)) = FLAG_MAP.iter().find(|(f, _)| f == flag) {
            if !config_idents.contains(*field) {
                out.push(deny(
                    "flag-drift",
                    &mf.path,
                    *line,
                    format!("`--{flag}` maps to config field `{field}`, which does not exist in config/mod.rs"),
                ));
            }
        } else if !FLAG_INFRA.contains(&flag.as_str()) {
            out.push(deny(
                "flag-drift",
                &mf.path,
                *line,
                format!(
                    "`--{flag}` has no entry in the flag-drift rule's FLAG_MAP (config field) \
                     or FLAG_INFRA allowlist — declare where it lands"
                ),
            ));
        }
    }
    for (flag, field) in FLAG_MAP {
        if !flags.contains_key(*flag) {
            out.push(deny(
                "flag-drift",
                &mf.path,
                0,
                format!("stale FLAG_MAP entry: `--{flag}` (-> {field}) is not read in fn serve/fn compress"),
            ));
        }
    }
    out
}

/// Every `--flag` spelling mentioned anywhere in the README.
fn readme_flags(readme: &str) -> BTreeSet<String> {
    let b = readme.as_bytes();
    let mut out = BTreeSet::new();
    let mut i = 0usize;
    while i + 2 < b.len() {
        if b[i] == b'-' && b[i + 1] == b'-' && b[i + 2].is_ascii_lowercase() && (i == 0 || b[i - 1] != b'-') {
            let mut j = i + 2;
            while j < b.len() && (b[j].is_ascii_lowercase() || b[j].is_ascii_digit() || b[j] == b'-') {
                j += 1;
            }
            out.insert(String::from_utf8_lossy(&b[i + 2..j]).into_owned());
            i = j;
        } else {
            i += 1;
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Rule: trace-phase-pairing

const PHASES_RS: &str = "trace/phases.rs";
const TRACE_HEADING: &str = "### Request-lifecycle tracing";
const RECORDERS: &[&str] = &["span", "push_span", "push_instant"];

fn trace_phases(ctx: &Context) -> Vec<Finding> {
    let mut out = Vec::new();
    let Some(pf) = ctx.file(PHASES_RS) else {
        out.push(deny(
            "trace-phase-pairing",
            "rust/src/trace/phases.rs",
            0,
            "trace::phases module missing — phase names need one constants module".into(),
        ));
        return out;
    };
    let consts = str_consts(&pf.code);
    let Some(all) = ident_array_const(&pf.code, "ALL") else {
        out.push(deny(
            "trace-phase-pairing",
            &pf.path,
            0,
            "phases::ALL (the exporter's known-phase list) is missing".into(),
        ));
        return out;
    };
    for (name, _, line) in &consts {
        if !all.contains(name) {
            out.push(deny(
                "trace-phase-pairing",
                &pf.path,
                *line,
                format!("phase const {name} is missing from phases::ALL"),
            ));
        }
    }
    for a in &all {
        if !consts.iter().any(|(n, _, _)| n == a) {
            out.push(deny(
                "trace-phase-pairing",
                &pf.path,
                0,
                format!("phases::ALL references `{a}`, which is not a phase const"),
            ));
        }
    }
    // Record sites must pass a phases:: constant, not a string literal.
    for f in &ctx.files {
        let code = &f.code;
        for i in 0..code.len() {
            if matches!(code[i].kind, Tok::Punct('.'))
                && matches!(code.get(i + 1).map(|t| &t.kind),
                            Some(Tok::Ident(w)) if RECORDERS.contains(&w.as_str()))
                && matches!(code.get(i + 2).map(|t| &t.kind), Some(Tok::Punct('(')))
            {
                if let Some(Tok::Str(s)) = code.get(i + 3).map(|t| &t.kind) {
                    if !f.in_test(code[i + 3].line) {
                        out.push(deny(
                            "trace-phase-pairing",
                            &f.path,
                            code[i + 3].line,
                            format!("phase recorded as string literal `\"{s}\"` — use `trace::phases`"),
                        ));
                    }
                }
            }
        }
    }
    let Some((sec_line, sec)) = section(&ctx.readme, TRACE_HEADING) else {
        out.push(deny(
            "trace-phase-pairing",
            "README.md",
            0,
            format!("README `{TRACE_HEADING}` section missing"),
        ));
        return out;
    };
    let mut readme_phases: BTreeMap<String, u32> = BTreeMap::new();
    for (off, row) in table_rows(sec) {
        if let Some(first) = backtick_words(row).into_iter().next() {
            readme_phases.entry(first).or_insert(sec_line + off);
        }
    }
    if readme_phases.is_empty() {
        out.push(deny(
            "trace-phase-pairing",
            "README.md",
            sec_line,
            "README tracing section has no phase table (rows `| phase | … |`)".into(),
        ));
        return out;
    }
    for (name, val, line) in &consts {
        if !readme_phases.contains_key(val) {
            out.push(deny(
                "trace-phase-pairing",
                &pf.path,
                *line,
                format!("phase `{val}` (const {name}) is undocumented in the README phase table"),
            ));
        }
    }
    for (ph, line) in &readme_phases {
        if !consts.iter().any(|(_, v, _)| v == ph) {
            out.push(deny(
                "trace-phase-pairing",
                "README.md",
                *line,
                format!("README phase table lists `{ph}`, which trace::phases does not declare"),
            ));
        }
    }
    out
}

// Re-exported for the engine's suppression hygiene and the CLI's rule list.
pub fn rule_names() -> Vec<&'static str> {
    RULES.iter().map(|r| r.name).collect()
}

// Keep the helper visible to unit/fixture tests without re-lexing.
#[allow(dead_code)]
pub(crate) fn source(path: &str, text: &str) -> SourceFile {
    SourceFile::new(path, text)
}
