//! Quantization substrate: f16 codec + int8/int4 absmax (de)quantization.
//!
//! Mirrors `python/compile/dobi/remap.py` so the `.dobiw` reader can
//! reconstruct factors bit-identically to the python reference, and the
//! memsim/storage accounting can price each precision.

/// Convert one IEEE 754 half (as u16) to f32.
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h >> 15) & 1) as u32;
    let exp = ((h >> 10) & 0x1F) as u32;
    let frac = (h & 0x3FF) as u32;
    let bits = if exp == 0 {
        if frac == 0 {
            sign << 31 // signed zero
        } else {
            // subnormal: normalize
            let mut e = 127 - 15 + 1;
            let mut f = frac;
            while f & 0x400 == 0 {
                f <<= 1;
                e -= 1;
            }
            (sign << 31) | ((e as u32) << 23) | ((f & 0x3FF) << 13)
        }
    } else if exp == 0x1F {
        (sign << 31) | (0xFF << 23) | (frac << 13) // inf / nan
    } else {
        (sign << 31) | ((exp + 127 - 15) << 23) | (frac << 13)
    };
    f32::from_bits(bits)
}

/// Convert f32 to IEEE 754 half (round-to-nearest-even), saturating.
pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 31) & 1) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let frac = bits & 0x7FFFFF;
    if exp == 0xFF {
        // inf / nan
        return (sign << 15) | 0x7C00 | if frac != 0 { 0x200 } else { 0 };
    }
    let e = exp - 127 + 15;
    if e >= 0x1F {
        return (sign << 15) | 0x7C00; // overflow -> inf
    }
    if e <= 0 {
        if e < -10 {
            return sign << 15; // underflow -> zero (|x| < 2^-25 half-ulp)
        }
        // subnormal: shift the implicit-1 mantissa into place with
        // round-to-nearest-even (a carry out of the mantissa correctly
        // promotes to the smallest normal).
        let m = frac | 0x800000;
        let shift = (14 - e) as u32; // 14..=24
        let f = m >> shift;
        let rem = m & ((1u32 << shift) - 1);
        let half = 1u32 << (shift - 1);
        let f = if rem > half || (rem == half && (f & 1) == 1) { f + 1 } else { f };
        return (sign << 15) | f as u16;
    }
    let mut h = (sign << 15) | ((e as u16) << 10) | ((frac >> 13) as u16);
    // round to nearest even
    let round_bits = frac & 0x1FFF;
    if round_bits > 0x1000 || (round_bits == 0x1000 && (h & 1) == 1) {
        h = h.wrapping_add(1);
    }
    h
}

pub fn f16_slice_to_f32(halves: &[u16]) -> Vec<f32> {
    halves.iter().map(|&h| f16_to_f32(h)).collect()
}

/// Dequantize int8 codes with broadcastable scales.
/// `q` is row-major (rows, cols); scales shape is (1, cols) or (rows, 1)
/// exactly as the python writer emits.
pub fn dequantize_i8(q: &[i8], rows: usize, cols: usize, scales: &[f32],
                     scales_shape: (usize, usize)) -> Vec<f32> {
    assert_eq!(q.len(), rows * cols, "code count mismatch");
    let mut out = vec![0f32; rows * cols];
    match scales_shape {
        (1, c) => {
            assert_eq!(c, cols, "per-column scales mismatch");
            for r in 0..rows {
                for cidx in 0..cols {
                    out[r * cols + cidx] = q[r * cols + cidx] as f32 * scales[cidx];
                }
            }
        }
        (r, 1) => {
            assert_eq!(r, rows, "per-row scales mismatch");
            for ridx in 0..rows {
                let s = scales[ridx];
                for cidx in 0..cols {
                    out[ridx * cols + cidx] = q[ridx * cols + cidx] as f32 * s;
                }
            }
        }
        other => panic!("unsupported scales shape {other:?}"),
    }
    out
}

/// Symmetric absmax quantization along columns (axis 0): returns
/// (codes, per-column scales).  Matches `remap.quantize_absmax(axis=0)`.
pub fn quantize_i8_cols(w: &[f32], rows: usize, cols: usize, bits: u32) -> (Vec<i8>, Vec<f32>) {
    assert_eq!(w.len(), rows * cols);
    let qmax = ((1i32 << (bits - 1)) - 1) as f32;
    let mut scales = vec![0f32; cols];
    for c in 0..cols {
        let mut m = 0f32;
        for r in 0..rows {
            m = m.max(w[r * cols + c].abs());
        }
        scales[c] = if m == 0.0 { 1.0 / qmax } else { m / qmax };
    }
    let mut q = vec![0i8; rows * cols];
    for r in 0..rows {
        for c in 0..cols {
            let v = (w[r * cols + c] / scales[c]).round().clamp(-qmax, qmax);
            q[r * cols + c] = v as i8;
        }
    }
    (q, scales)
}

/// Bytes needed to store a tensor at the given precision (packed).
pub fn storage_bytes(n_elems: usize, bits: u32) -> usize {
    (n_elems * bits as usize + 7) / 8
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f16_known_values() {
        assert_eq!(f16_to_f32(0x3C00), 1.0);
        assert_eq!(f16_to_f32(0xC000), -2.0);
        assert_eq!(f16_to_f32(0x0000), 0.0);
        assert_eq!(f16_to_f32(0x7C00), f32::INFINITY);
        assert!((f16_to_f32(0x3555) - 0.333252).abs() < 1e-5);
    }

    #[test]
    fn f16_roundtrip_exactish() {
        for &x in &[0.0f32, 1.0, -1.0, 0.5, 65504.0, 6.1e-5, 3.14159, -0.007] {
            let back = f16_to_f32(f32_to_f16(x));
            let tol = (x.abs() * 1e-3).max(1e-7);
            assert!((back - x).abs() <= tol, "{x} -> {back}");
        }
    }

    #[test]
    fn f16_subnormals() {
        let x = 1e-6f32;
        let back = f16_to_f32(f32_to_f16(x));
        assert!((back - x).abs() < 1e-6);
        assert!(back > 0.0);
    }

    #[test]
    fn f16_saturates() {
        assert_eq!(f16_to_f32(f32_to_f16(1e10)), f32::INFINITY);
        assert!(f16_to_f32(f32_to_f16(f32::NAN)).is_nan());
    }

    #[test]
    fn f16_signed_zero_and_infinities() {
        assert_eq!(f32_to_f16(0.0), 0x0000);
        assert_eq!(f32_to_f16(-0.0), 0x8000);
        assert!(f16_to_f32(0x8000) == 0.0 && f16_to_f32(0x8000).is_sign_negative());
        assert_eq!(f32_to_f16(f32::INFINITY), 0x7C00);
        assert_eq!(f32_to_f16(f32::NEG_INFINITY), 0xFC00);
        assert_eq!(f16_to_f32(0xFC00), f32::NEG_INFINITY);
        // NaN encodes to a quiet NaN with a nonzero payload, either sign
        let h = f32_to_f16(f32::NAN);
        assert_eq!(h & 0x7C00, 0x7C00);
        assert_ne!(h & 0x03FF, 0);
    }

    #[test]
    fn f16_exhaustive_decode_encode_roundtrip() {
        // Every finite half and both infinities must survive
        // f16 -> f32 -> f16 bit-exactly (decode is exact, and re-encoding
        // an exactly-representable value must not round).  NaNs excluded:
        // payloads legitimately collapse to a canonical quiet NaN.
        for h in 0u16..=u16::MAX {
            let is_nan = (h & 0x7C00) == 0x7C00 && (h & 0x03FF) != 0;
            if is_nan {
                continue;
            }
            let back = f32_to_f16(f16_to_f32(h));
            assert_eq!(back, h, "h={h:#06x} -> {} -> {back:#06x}", f16_to_f32(h));
        }
    }

    #[test]
    fn f16_round_to_nearest_even_ties_normal_range() {
        // 1 + 2^-11 sits exactly between 0x3C00 (1.0) and 0x3C01: the tie
        // must go to the even mantissa (0x3C00).
        assert_eq!(f32_to_f16(1.0 + 2f32.powi(-11)), 0x3C00);
        // 1 + 2^-10 + 2^-11 ties between 0x3C01 (odd) and 0x3C02 (even).
        assert_eq!(f32_to_f16(1.0 + 2f32.powi(-10) + 2f32.powi(-11)), 0x3C02);
        // just above the tie rounds up
        assert_eq!(f32_to_f16(1.0 + 2f32.powi(-11) + 2f32.powi(-20)), 0x3C01);
        // mantissa carry into the exponent: 2047.5 ulp of 0x67FF -> 0x6800
        let just_below_2048 = 2047.9999f32;
        assert_eq!(f32_to_f16(just_below_2048), 0x6800); // rounds to 2048.0
    }

    #[test]
    fn f16_round_to_nearest_even_ties_subnormal_range() {
        let min_sub = 2f32.powi(-24); // smallest positive half-subnormal
        // 1.5 * 2^-24 ties between codes 1 and 2 -> even (2)
        assert_eq!(f32_to_f16(1.5 * min_sub), 2);
        // 2.5 * 2^-24 ties between 2 and 3 -> even (2)
        assert_eq!(f32_to_f16(2.5 * min_sub), 2);
        // half the smallest subnormal ties with zero -> zero (even)
        assert_eq!(f32_to_f16(0.5 * min_sub), 0);
        // just above that must round up to the smallest subnormal
        assert_eq!(f32_to_f16(0.75 * min_sub), 1);
        // and the subnormal/normal boundary: the largest subnormal + half
        // an ulp promotes to the smallest normal (0x0400)
        let largest_sub = 1023.0 * min_sub;
        let half_ulp = 0.5 * min_sub;
        assert_eq!(f32_to_f16(largest_sub + half_ulp), 0x0400);
    }

    #[test]
    fn f16_subnormal_decode_values() {
        assert_eq!(f16_to_f32(0x0001), 2f32.powi(-24));
        assert_eq!(f16_to_f32(0x0200), 2f32.powi(-15)); // 512 * 2^-24
        assert_eq!(f16_to_f32(0x03FF), 1023.0 * 2f32.powi(-24));
        assert_eq!(f16_to_f32(0x0400), 2f32.powi(-14)); // smallest normal
    }

    #[test]
    fn quant_dequant_roundtrip_cols() {
        let w: Vec<f32> = (0..12).map(|i| (i as f32 - 6.0) * 0.1).collect();
        let (q, s) = quantize_i8_cols(&w, 3, 4, 8);
        let back = dequantize_i8(&q, 3, 4, &s, (1, 4));
        for (a, b) in w.iter().zip(&back) {
            assert!((a - b).abs() <= s.iter().cloned().fold(0f32, f32::max) / 2.0 + 1e-6);
        }
    }

    #[test]
    fn dequant_row_scales() {
        let q = vec![1i8, 2, 3, 4];
        let out = dequantize_i8(&q, 2, 2, &[0.5, 2.0], (2, 1));
        assert_eq!(out, vec![0.5, 1.0, 6.0, 8.0]);
    }

    #[test]
    fn zero_column_safe() {
        let w = vec![0f32; 6];
        let (q, s) = quantize_i8_cols(&w, 3, 2, 8);
        assert!(q.iter().all(|&x| x == 0));
        assert!(s.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn storage_bytes_packed() {
        assert_eq!(storage_bytes(100, 8), 100);
        assert_eq!(storage_bytes(100, 4), 50);
        assert_eq!(storage_bytes(101, 4), 51);
        assert_eq!(storage_bytes(10, 16), 20);
    }
}
