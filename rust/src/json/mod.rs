//! Minimal JSON parser/serializer (substrate — the image has no serde).
//!
//! Supports the full JSON grammar we exchange with the python compile path
//! (manifest.json, tasks.json, vqa/vla.json): objects, arrays, strings with
//! escapes, numbers (f64), booleans, null.  Not streaming; files are a few
//! MB at most.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `a.b.c` path access.
    pub fn path(&self, path: &str) -> Option<&Json> {
        let mut cur = self;
        for part in path.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Convenience: required string field (panics with context — used on
    /// trusted manifest data where absence is a build bug, not user input).
    pub fn str_of(&self, key: &str) -> &str {
        self.get(key)
            .and_then(Json::as_str)
            .unwrap_or_else(|| panic!("manifest: missing string field `{key}`"))
    }

    pub fn f64_of(&self, key: &str) -> f64 {
        self.get(key)
            .and_then(Json::as_f64)
            .unwrap_or_else(|| panic!("manifest: missing number field `{key}`"))
    }

    pub fn usize_of(&self, key: &str) -> usize {
        self.f64_of(key) as usize
    }

    /// Build an object from (key, value) pairs — writer-side convenience
    /// shared by the manifest writer and the bench-report emitters.
    pub fn obj(entries: Vec<(&str, Json)>) -> Json {
        Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    // -- serializer (via `Display`; `.to_string()` comes with it) ------------
    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    /// Compact single-line serialization — one reply per line is the
    /// server's framing, so no pretty-printing.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut out = String::new();
        self.write(&mut out);
        f.write_str(&out)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            // python json.dump without allow_nan=False emits these;
            // tolerate them on input (we never emit them).
            Some(b'N') => self.lit("NaN", Json::Num(f64::NAN)),
            Some(b'I') => self.lit("Infinity", Json::Num(f64::INFINITY)),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{word}`")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
            if self.peek() == Some(b'I') {
                return self.lit("Infinity", Json::Num(f64::NEG_INFINITY));
            }
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            // Surrogate pairs
                            if (0xD800..0xDC00).contains(&cp) {
                                if self.b.get(self.i + 1) == Some(&b'\\')
                                    && self.b.get(self.i + 2) == Some(&b'u')
                                {
                                    let hex2 =
                                        std::str::from_utf8(&self.b[self.i + 3..self.i + 7])
                                            .map_err(|_| self.err("bad surrogate"))?;
                                    let lo = u32::from_str_radix(hex2, 16)
                                        .map_err(|_| self.err("bad surrogate"))?;
                                    self.i += 6;
                                    let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    out.push(char::from_u32(c).ok_or_else(|| self.err("bad cp"))?);
                                } else {
                                    return Err(self.err("lone surrogate"));
                                }
                            } else {
                                out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            }
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }
}

pub fn load(path: &std::path::Path) -> anyhow::Result<Json> {
    let s = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    Json::parse(&s).map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, {"b": "c\n"}, null], "d": 2}"#).unwrap();
        assert_eq!(j.path("d").unwrap().as_f64(), Some(2.0));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].get("b").unwrap().as_str(), Some("c\n"));
    }

    #[test]
    fn parse_unicode_escapes() {
        let j = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(j.as_str(), Some("é😀"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"x"],"nested":{"t":true,"n":null},"s":"a\"b"}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    #[test]
    fn big_numbers_roundtrip_as_ints() {
        let j = Json::Num(1234567.0);
        assert_eq!(j.to_string(), "1234567");
    }
}
