//! Serving metrics substrate: counters, gauges, latency histograms with
//! streaming percentiles — shared by the coordinator and the bench harness.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::mathx::{summarize, Stats};

#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Point-in-time level (queue depth, active sessions) — unlike a
/// [`Counter`] it moves both ways.
#[derive(Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, dv: i64) {
        self.0.fetch_add(dv, Ordering::Relaxed);
    }

    pub fn sub(&self, dv: i64) {
        self.0.fetch_sub(dv, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Latency histogram: fixed log-spaced buckets (1us .. ~100s) plus a
/// bounded reservoir of raw samples for exact percentiles in reports.
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    bounds_us: Vec<u64>,
    samples: Mutex<Vec<f64>>, // seconds; capped reservoir
    cap: usize,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new(4096)
    }
}

impl Histogram {
    pub fn new(cap: usize) -> Self {
        let mut bounds_us = Vec::new();
        let mut b = 1u64;
        while b < 100_000_000 {
            bounds_us.push(b);
            b = (b as f64 * 1.6).ceil() as u64;
        }
        let buckets = (0..=bounds_us.len()).map(|_| AtomicU64::new(0)).collect();
        Histogram { buckets, bounds_us, samples: Mutex::new(Vec::new()), cap }
    }

    pub fn observe(&self, d: Duration) {
        self.observe_raw(d.as_micros() as u64, d.as_secs_f64());
    }

    /// Record a dimensionless value (e.g. a fused batch size or an
    /// acceptance rate) — same reservoir/percentile machinery; the
    /// log-bucket counters are latency-shaped and not meaningful for
    /// these, stats come from the reservoir.  Name such histograms
    /// `*_size` or `*_rate` so [`Registry::render`] omits the seconds
    /// label.
    pub fn observe_value(&self, v: f64) {
        self.observe_raw((v * 1e6) as u64, v);
    }

    fn observe_raw(&self, us: u64, v: f64) {
        let idx = self.bounds_us.partition_point(|&b| b < us);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        let mut s = self.samples.lock().unwrap();
        if s.len() < self.cap {
            s.push(v);
        } else {
            // reservoir: overwrite pseudo-randomly for long runs
            let i = (us as usize * 2654435761) % self.cap;
            s[i] = v;
        }
    }

    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    pub fn stats(&self) -> Stats {
        summarize(&self.samples.lock().unwrap())
    }
}

/// Named registry the engine exposes (`dobi serve --metrics` dump).
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, std::sync::Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, std::sync::Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, std::sync::Arc<Histogram>>>,
}

impl Registry {
    pub fn counter(&self, name: &str) -> std::sync::Arc<Counter> {
        self.counters
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    pub fn gauge(&self, name: &str) -> std::sync::Arc<Gauge> {
        self.gauges
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    pub fn histogram(&self, name: &str) -> std::sync::Arc<Histogram> {
        self.histograms
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| std::sync::Arc::new(Histogram::default()))
            .clone()
    }

    /// Plain-text dump (name value / name p50 p95 p99).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (k, c) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("{k} {}\n", c.get()));
        }
        for (k, g) in self.gauges.lock().unwrap().iter() {
            out.push_str(&format!("{k} {}\n", g.get()));
        }
        for (k, h) in self.histograms.lock().unwrap().iter() {
            let s = h.stats();
            // dimensionless histograms (observe_value: `*_size` batch
            // sizes, `*_rate` ratios) get no seconds label
            let u = if k.ends_with("_size") || k.ends_with("_rate") { "" } else { "s" };
            out.push_str(&format!(
                "{k} count={} mean={:.6}{u} p50={:.6}{u} p95={:.6}{u} p99={:.6}{u}\n",
                h.count(), s.mean, s.p50, s.p95, s.p99
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_concurrent() {
        let c = std::sync::Arc::new(Counter::default());
        let mut hs = Vec::new();
        for _ in 0..4 {
            let c2 = c.clone();
            hs.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    c2.inc();
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(c.get(), 4000);
    }

    #[test]
    fn histogram_percentiles() {
        let h = Histogram::default();
        for i in 1..=100 {
            h.observe(Duration::from_millis(i));
        }
        let s = h.stats();
        assert_eq!(h.count(), 100);
        assert!((s.p50 - 0.05).abs() < 0.01);
        assert!(s.p99 >= 0.09);
    }

    #[test]
    fn registry_same_instance() {
        let r = Registry::default();
        r.counter("a").inc();
        r.counter("a").inc();
        assert_eq!(r.counter("a").get(), 2);
        let text = r.render();
        assert!(text.contains("a 2"));
    }

    #[test]
    fn gauge_moves_both_ways() {
        let r = Registry::default();
        let g = r.gauge("active");
        g.add(3);
        g.sub(1);
        assert_eq!(r.gauge("active").get(), 2);
        g.set(-4);
        assert_eq!(g.get(), -4);
        assert!(r.render().contains("active -4"));
    }

    #[test]
    fn histogram_observes_raw_values() {
        let h = Histogram::default();
        for v in [1.0f64, 2.0, 3.0, 4.0] {
            h.observe_value(v);
        }
        let s = h.stats();
        assert_eq!(h.count(), 4);
        assert!((s.mean - 2.5).abs() < 1e-9);
        assert!(s.p50 >= 2.0 && s.p50 <= 3.0);
    }

    #[test]
    fn histogram_reservoir_bounded() {
        let h = Histogram::new(16);
        for i in 0..1000 {
            h.observe(Duration::from_micros(i));
        }
        assert!(h.samples.lock().unwrap().len() <= 16);
        assert_eq!(h.count(), 1000);
    }
}
